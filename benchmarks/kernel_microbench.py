"""Kernel microbenchmarks: fused consensus update / flash attention / WKV6.

On this CPU container kernels run in interpret mode (Python), so absolute
us_per_call is NOT hardware-representative; the derived column therefore
also reports the analytic HBM-traffic ratio fused-vs-unfused — the number
that transfers to TPU (the kernels are memory-bound).
"""

import time

import jax
import jax.numpy as jnp

from repro.kernels.consensus_update.consensus_update import cdsgd_update_2d
from repro.kernels.consensus_update.ref import cdsgd_update_ref
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rwkv_scan.rwkv_scan import wkv6_pallas
from repro.kernels.rwkv_scan.ref import wkv6_ref


def _time(fn, *args, reps=3):
    fn(*args)  # compile / warm
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return 1e6 * (time.time() - t0) / reps


def run():
    key = jax.random.PRNGKey(0)
    rows = []

    # consensus update: S=3 ring stencil, 1M params
    rows_n = 8192
    nb = jax.random.normal(key, (3, rows_n, 128), jnp.float32)
    g = jax.random.normal(key, (rows_n, 128), jnp.float32)
    w = jnp.array([1 / 3, 1 / 3, 1 / 3], jnp.float32)
    t_kernel = _time(jax.jit(lambda *a: cdsgd_update_2d(*a, 0.05, interpret=True)), nb, w, g)
    t_ref = _time(jax.jit(lambda *a: cdsgd_update_ref(*a, 0.05)), nb, w, g)
    # unfused traffic: read 3 neighbors + grad + write mix + read mix + write out
    # fused traffic: read 3 neighbors + grad + write out
    rows.append(("kernel/consensus_update",
                 t_kernel, f"ref_us={t_ref:.0f};hbm_traffic_fused/unfused={5/7:.3f}"))

    # flash attention 1k seq
    q = jax.random.normal(key, (1, 4, 1024, 64), jnp.float32)
    k = jax.random.normal(key, (1, 2, 1024, 64), jnp.float32)
    v = jax.random.normal(key, (1, 2, 1024, 64), jnp.float32)
    t_kernel = _time(jax.jit(lambda *a: flash_attention(*a, causal=True, interpret=True)), q, k, v)
    t_ref = _time(jax.jit(lambda *a: attention_ref(*a, causal=True)), q, k, v)
    s_mat = 4 * 1024 * 1024 * 4 * 2  # S+P matrices fp32, per head
    flash_extra = 4 * 1024 * 64 * 4
    rows.append(("kernel/flash_attention", t_kernel,
                 f"ref_us={t_ref:.0f};score_matrix_bytes_avoided={s_mat}"))

    # wkv6 4-head 512-seq
    r = jax.random.normal(key, (4, 512, 64))
    kk = jax.random.normal(key, (4, 512, 64))
    vv = jax.random.normal(key, (4, 512, 64))
    ww = jax.nn.sigmoid(jax.random.normal(key, (4, 512, 64))) * 0.5 + 0.45
    u = 0.1 * jax.random.normal(key, (4, 64))
    t_kernel = _time(jax.jit(lambda *a: wkv6_pallas(*a, chunk=128, interpret=True)), r, kk, vv, ww, u)
    t_ref = _time(jax.jit(wkv6_ref), r, kk, vv, ww, u)
    rows.append(("kernel/wkv6_scan", t_kernel, f"ref_us={t_ref:.0f};state_hbm_roundtrips=0"))

    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    run()
