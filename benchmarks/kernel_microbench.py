"""Kernel microbenchmarks: fused consensus update / flash attention / WKV6.

On this CPU container kernels run in interpret mode (Python), so absolute
us_per_call is NOT hardware-representative; the derived column therefore
also reports the analytic HBM-traffic ratio fused-vs-unfused — the number
that transfers to TPU (the kernels are memory-bound).

Analytic HBM sweeps per element (S = stencil size, neighbor count + self):

* CDSGD  unfused: mix (S reads + 1 write) + axpy (read mix + read grad +
  write out)                      = S + 4 sweeps
* CDSGD  fused:   S neighbor reads + grad read + out write = S + 2 sweeps
* CDMSGD unfused: mix (S+1) + momentum update (read v + read grad +
  write v') + param update (read mix + read v' + write out) = S + 7 sweeps
* CDMSGD fused:   S + grad + v reads, out + v' writes       = S + 4 sweeps

``bucketed_model_update`` compares the whole-model flat-buffer path (one
``pallas_call`` per dtype bucket, one collective per circulant shift per
bucket — see repro.core.flatbuf) against the per-leaf launch baseline, and
emits one machine-readable ``JSON,{...}`` line for the perf trajectory.

``exchange_wire`` reports the analytic bytes-on-wire per consensus step for
each exchange precision (f32/bf16/int8/fp8 — see benchmarks/README.md for
how to read the columns), and ``alias_accounting`` reports the extra HBM
output allocation of the fused update with and without
``input_output_aliases`` (aliased = params/momentum update in place).

``schedule_overlap`` compares the StepProgram engine's ``sync`` vs
``overlap`` exchange schedules (see :mod:`repro.core.engine`): interpret-
mode step time plus an assertion — from the actual carried buffers — that
the overlap double-buffer puts exactly the sync schedule's quantized bytes
on the wire (the schedule changes WHEN the payload moves, never how much).

``stale_ring`` benchmarks the bounded-staleness wire ring
(``MixingProgram(staleness=S, faults=...)``): asserts — from the actual
carried :class:`repro.core.consensus.WireRing` buffers — that the per-step
bytes on the wire stay EXACTLY the sync schedule's bytes at every ring
depth S (only the sender-selected generation moves; the stale slots and
age counters are local state), and reports the parameter drift vs the
fault-free run as S grows under an injected straggler+drop schedule —
at both shipped wire precisions (int8 AND fp8).

``compressor_frontier`` maps the bytes-vs-drift frontier of the
``compressor=`` axis (f32 / int8 / fp8 / topk:p / rank:r — see
``repro.core.consensus.MixingProgram``): every byte count is read from
the actual carried overlap wire buffers and cross-checked against the
analytic accounting; asserts topk:0.01 moves >= 25x fewer bytes per
neighbor than the f32 wire at bounded 20-step drift, and that error
feedback strictly beats no-EF top-k at equal density.

``sparse_update`` compares the two operand forms of the fused update on
a top-k wire at density p in {0.1, 0.01}: dense (``topk_decompress_2d``
each neighbor, then the dense kernel — the ``sparse_update=False``
reference) vs sparse (the compact ``TopKWire`` fields fed straight to
the gather-dequant-accumulate kernel).  Reports measured kernel
walltime plus the accounted HBM bytes from
:func:`repro.analysis.roofline.consensus_update_cost`, and asserts the
sparse form strictly cheaper in BOTH measures at p = 0.01 (the
acceptance point is p <= 0.05).

``--smoke`` runs only the consensus-path benches (CI-friendly);
``--json-out FILE`` writes the records as a JSON file (the CI workflow
publishes it as the ``BENCH_9.json`` artifact).
"""

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.core import consensus as consensus_lib
from repro.core import flatbuf
from repro.core.topology import make_topology
from repro.kernels.consensus_update import ops as cons_ops
from repro.kernels.consensus_update.consensus_update import (
    LANE,
    cdsgd_update_2d,
    cdmsgd_update_2d,
    sr_quantize_2d,
)
from repro.kernels.consensus_update.ref import cdsgd_update_ref
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rwkv_scan.rwkv_scan import wkv6_pallas
from repro.kernels.rwkv_scan.ref import wkv6_ref


def _time(fn, *args, reps=3):
    fn(*args)  # compile / warm
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return 1e6 * (time.time() - t0) / reps


def _per_leaf_cdsgd(tree, neighbor_trees, weights, grads, alpha, interpret=True):
    """The pre-flatbuf baseline: one padded kernel launch per pytree leaf."""

    def leaf(x, g, *nbrs):
        def tiles(t):
            flat = t.reshape(-1)
            rows = -(-flat.shape[0] // LANE)
            pad = rows * LANE - flat.shape[0]
            if pad:
                flat = jnp.pad(flat, (0, pad))
            return flat.reshape(rows, LANE), t.size

        stacked = jnp.stack([tiles(t)[0] for t in (x,) + nbrs])
        gt, n = tiles(g)
        out = cdsgd_update_2d(stacked, weights, gt, alpha, interpret=interpret)
        return out.reshape(-1)[:n].reshape(x.shape).astype(x.dtype)

    return jax.tree.map(leaf, tree, grads, *neighbor_trees)


def bucketed_model_update():
    """Whole-model fused (bucketed) vs per-leaf launches on a mixed pytree.

    Returns (row, json_record): launch counts from the actual jaxprs,
    analytic HBM bytes, and collectives-per-step for a ring (2 non-zero
    shifts) in the sharded execution mode.
    """
    key = jax.random.PRNGKey(0)
    tree = {}
    for i, (n, dt) in enumerate([(7 * 9, jnp.float32), (300, jnp.float32),
                                 (128 * 64, jnp.float32), (513, jnp.float32),
                                 (4096, jnp.bfloat16), (130, jnp.bfloat16),
                                 (256 * 16, jnp.float32), (1000, jnp.bfloat16)]):
        tree[f"p{i}"] = jax.random.normal(
            jax.random.fold_in(key, i), (n,)).astype(dt)
    left = jax.tree.map(lambda x: x + 1, tree)
    right = jax.tree.map(lambda x: x - 1, tree)
    grads = jax.tree.map(jnp.ones_like, tree)
    w = jnp.array([1 / 3, 1 / 3, 1 / 3], jnp.float32)
    s = 3                                       # ring stencil: self + 2

    fused_fn = jax.jit(lambda t, l, r, g: cons_ops.cdsgd_update_tree(
        t, [l, r], w, g, 0.05, interpret=True))
    leaf_fn = jax.jit(lambda t, l, r, g: _per_leaf_cdsgd(
        t, [l, r], w, g, 0.05, interpret=True))
    t_fused = _time(fused_fn, tree, left, right, grads)
    t_leaf = _time(leaf_fn, tree, left, right, grads)

    launches_fused = str(jax.make_jaxpr(fused_fn)(
        tree, left, right, grads)).count("pallas_call")
    launches_leaf = str(jax.make_jaxpr(leaf_fn)(
        tree, left, right, grads)).count("pallas_call")

    spec = flatbuf.make_flat_spec(tree)
    n_leaves = spec.n_leaves
    # fused kernel: S neighbor reads + grad read + out write over the padded
    # buckets; per-leaf baseline pads each leaf identically, but the unfused
    # optimizer (mix + axpy per leaf) sweeps the unpadded params S+4 times.
    bytes_fused = sum((s + 2) * b.bytes for b in spec.buckets)
    bytes_unpadded = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))
    bytes_unfused_opt = (s + 4) * bytes_unpadded
    # sharded ring: one ppermute per non-zero shift — per bucket vs per leaf
    coll_fused = 2 * spec.n_buckets
    coll_leaf = 2 * n_leaves

    rec = {
        "bench": "consensus/bucketed_model_update",
        "n_leaves": n_leaves,
        "n_buckets": spec.n_buckets,
        "kernel_launches": {"per_leaf": launches_leaf, "fused": launches_fused},
        "collectives_per_step_ring": {"per_leaf": coll_leaf, "fused": coll_fused},
        "hbm_bytes": {"unfused_optimizer": bytes_unfused_opt,
                      "fused_kernel": bytes_fused},
        "wire_bytes_per_shift": {e: spec.exchange_bytes(e)
                                 for e in flatbuf.EXCHANGE_DTYPES},
        "us_per_call_interp": {"per_leaf": round(t_leaf, 1),
                               "fused": round(t_fused, 1)},
    }
    assert launches_fused < launches_leaf
    assert bytes_fused < bytes_unfused_opt
    row = ("kernel/bucketed_model_update", t_fused,
           f"per_leaf_us={t_leaf:.0f};launches={launches_fused}/{launches_leaf};"
           f"collectives={coll_fused}/{coll_leaf};"
           f"hbm_fused/unfused={bytes_fused / bytes_unfused_opt:.3f}")
    return row, rec


def exchange_wire():
    """Analytic bytes-on-wire per consensus step, per exchange precision.

    Model: 1M f32 params (the paper-figure training dtype) on a ring
    (degree 2).  int8/fp8 pay 1 byte/element + one f32 scale per 128-lane
    row, so the f32->int8 wire ratio is 512/132 = 3.88x.
    """
    spec = flatbuf.make_flat_spec(
        {"w": jax.ShapeDtypeStruct((1024, 1024), jnp.float32)})
    topo = make_topology("ring", 8)
    per_step = {
        exch: consensus_lib.exchange_bytes_per_step(spec, topo, exch)["per_step_bytes"]
        for exch in flatbuf.EXCHANGE_DTYPES}
    ratio = per_step["f32"] / per_step["int8"]
    assert ratio >= 3.5, f"int8 exchange must cut wire bytes >=3.5x, got {ratio:.2f}"
    rec = {"bench": "consensus/exchange_wire", "model": "1M f32, ring deg 2",
           "per_step_bytes": per_step,
           "ratio_f32_over_int8": round(ratio, 3)}
    row = ("kernel/exchange_wire", 0.0,
           ";".join(f"{k}={v}" for k, v in per_step.items())
           + f";f32/int8={ratio:.2f}x")
    return row, rec


def alias_accounting(rows_n: int = 8192):
    """Extra HBM output bytes of the fused CDMSGD bucket update, aliased
    (input_output_aliases: grad->params, momentum->momentum') vs not."""
    nb = jnp.ones((3, rows_n, 128), jnp.float32)
    g = jnp.ones((rows_n, 128), jnp.float32)
    mom = jnp.ones((rows_n, 128), jnp.float32)
    w = jnp.array([1 / 3, 1 / 3, 1 / 3], jnp.float32)
    bucket_bytes = rows_n * 128 * 4

    out = {}
    for name, alias in (("aliased", True), ("unaliased", False)):
        jaxpr = jax.make_jaxpr(lambda *a: cdmsgd_update_2d(
            *a, 0.05, 0.9, alias=alias, interpret=True))(nb, w, g, mom)
        groups = cons_ops.alias_groups(jaxpr)
        n_aliased = len(groups[0]) if groups else 0
        out[name] = {"aliased_outputs": n_aliased,
                     "extra_output_bytes": (2 - n_aliased) * bucket_bytes}
    assert out["aliased"]["extra_output_bytes"] == 0
    rec = {"bench": "consensus/alias_accounting",
           "bucket_bytes": bucket_bytes, **out}
    row = ("kernel/alias_accounting", 0.0,
           f"extra_hbm_out_aliased={out['aliased']['extra_output_bytes']};"
           f"unaliased={out['unaliased']['extra_output_bytes']}")
    return row, rec


def schedule_overlap(steps_timed: int = 3):
    """sync vs overlap StepProgram schedule: step time (interpret mode, not
    hardware-representative) and — the number that transfers — the
    bytes-on-wire accounting.  The overlap schedule carries the quantized
    payload + row scales double-buffered in the optimizer state; it must
    move EXACTLY the sync schedule's bytes per neighbor
    (``FlatSpec.exchange_bytes``), one step later, off the grad->update
    critical path.  Asserted from the actual carried buffers."""
    from repro.core import engine
    from repro.core.optim import CDSGD
    from repro.core.trainer import CollaborativeTrainer

    key = jax.random.PRNGKey(0)
    topo = make_topology("ring", 4)
    params = {"w": jax.random.normal(key, (256, 128), jnp.float32),
              "b": jax.random.normal(key, (300,), jnp.float32)}

    def loss(p, b):
        return 0.5 * (jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)), {}

    batch = {"x": jnp.zeros((4, 1), jnp.float32)}
    us, wire_per_nbr = {}, {}
    for schedule in ("sync", "overlap"):
        # donate=False: _time re-invokes the jitted step on the same buffers
        tr = CollaborativeTrainer(loss, params, topo, CDSGD(0.01, fused=True),
                                  schedule=schedule, exchange="int8",
                                  donate=False)
        us[schedule] = _time(tr._step_fn, tr.state.params,
                             tr.state.opt_state, batch, reps=steps_timed)
        if schedule == "overlap":
            wire_per_nbr[schedule] = engine.wire_bytes_per_neighbor(
                tr.state.opt_state.wire)
        else:
            spec = flatbuf.make_flat_spec(tr.state.params, lead=1)
            wire_per_nbr[schedule] = spec.exchange_bytes("int8")
    assert wire_per_nbr["overlap"] == wire_per_nbr["sync"], wire_per_nbr
    degree = topo.degree()
    rec = {
        "bench": "consensus/schedule_overlap",
        "model": "33k f32 params, ring deg 2, int8 wire",
        "us_per_step_interp": {k: round(v, 1) for k, v in us.items()},
        "wire_bytes_per_neighbor": wire_per_nbr,
        "wire_bytes_per_step": {k: v * degree for k, v in wire_per_nbr.items()},
        "overlap_exchange_off_critical_path": True,   # proven per-config by
        # the dryrun's exchange_schedule record (jaxpr taint analysis)
    }
    row = ("kernel/schedule_overlap", us["overlap"],
           f"sync_us={us['sync']:.0f};"
           f"wire_bytes/step sync={rec['wire_bytes_per_step']['sync']}"
           f" overlap={rec['wire_bytes_per_step']['overlap']} (equal)")
    return row, rec


def multi_round(steps_timed: int = 3):
    """k-round i-CDSGD (MixingProgram strategy layer) wire accounting.

    Asserts, from the program-level accounting AND the carried buffers,
    that (a) a k-round strategy puts exactly ``k x`` the single-round sync
    bytes on the wire per step, and (b) error feedback adds ZERO wire
    bytes — the EF-compressed payload has the sync payload's exact layout
    (the residual is local f32 optimizer state that never moves)."""
    from repro.core import consensus as C
    from repro.core import engine
    from repro.core.optim import CDSGD
    from repro.core.trainer import CollaborativeTrainer

    key = jax.random.PRNGKey(0)
    topo = make_topology("ring", 4)
    params = {"w": jax.random.normal(key, (256, 128), jnp.float32),
              "b": jax.random.normal(key, (300,), jnp.float32)}

    def loss(p, b):
        return 0.5 * (jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)), {}

    batch = {"x": jnp.zeros((4, 1), jnp.float32)}
    k = 3
    us, wire = {}, {}
    for label, kw in (("k1", {}),
                      (f"k{k}", {"consensus_rounds": k}),
                      ("k1_ef", {"error_feedback": True})):
        tr = CollaborativeTrainer(loss, params, topo, CDSGD(0.01, fused=True),
                                  exchange="int8", donate=False, **kw)
        us[label] = _time(tr._step_fn, tr.state.params, tr.state.opt_state,
                          batch, reps=steps_timed)
        wire[label] = tr.wire_bytes_per_step
    assert wire[f"k{k}"] == k * wire["k1"], wire
    assert wire["k1_ef"] == wire["k1"], wire

    # EF payload layout == plain payload layout, from the actual buffers
    comm = tr.comm                      # the EF trainer's comm
    fl = comm.flat
    spec = fl.spec(tr.state.params)
    bufs = fl.pack(tr.state.params, spec)
    plain = fl.quantize_stage(bufs, jnp.int32(0))
    ef_wire, _res = fl.strategy.quantize_ef(
        bufs, jnp.int32(0), fl.strategy.residual_init(bufs))
    per_nbr = {"plain": engine.wire_bytes_per_neighbor(plain),
               "ef": engine.wire_bytes_per_neighbor(ef_wire)}
    assert per_nbr["ef"] == per_nbr["plain"] == spec.exchange_bytes("int8")

    rec = {
        "bench": "consensus/multi_round",
        "model": "33k f32 params, ring deg 2, int8 wire",
        "rounds": k,
        "us_per_step_interp": {kk: round(v, 1) for kk, v in us.items()},
        "wire_bytes_per_step": wire,
        "ef_wire_bytes_per_neighbor": per_nbr,
        "k_round_wire_is_k_x_sync": True,
        "ef_extra_wire_bytes": 0,
    }
    row = ("kernel/multi_round", us[f"k{k}"],
           f"k1_us={us['k1']:.0f};wire/step k1={wire['k1']} "
           f"k{k}={wire[f'k{k}']} (={k}x);ef extra wire=0")
    return row, rec


def momentum_mix(steps_timed: int = 3):
    """Momentum-consensus mixing (MixingProgram momentum_mixing="mixed")
    wire accounting.

    Asserts, from the program-level accounting AND the actual carried
    overlap buffers, that (a) putting the momentum buffer on the wire
    moves exactly **2x** the params-only bytes at equal precision (two
    payload trees, same quantization layout each), and (b) error feedback
    on top still adds ZERO wire bytes (one residual per bucket per
    payload, all local f32 state)."""
    from repro.core import engine
    from repro.core.optim import CDMSGD
    from repro.core.trainer import CollaborativeTrainer

    key = jax.random.PRNGKey(0)
    topo = make_topology("ring", 4)
    params = {"w": jax.random.normal(key, (256, 128), jnp.float32),
              "b": jax.random.normal(key, (300,), jnp.float32)}

    def loss(p, b):
        return 0.5 * (jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)), {}

    batch = {"x": jnp.zeros((4, 1), jnp.float32)}
    us, wire = {}, {}
    for label, kw in (("plain", {}),
                      ("mixed", {"momentum_mixing": "mixed"}),
                      ("mixed_ef", {"momentum_mixing": "mixed",
                                    "error_feedback": True})):
        tr = CollaborativeTrainer(loss, params, topo,
                                  CDMSGD(0.01, mu=0.9, fused=True),
                                  exchange="int8", donate=False, **kw)
        us[label] = _time(tr._step_fn, tr.state.params, tr.state.opt_state,
                          batch, reps=steps_timed)
        wire[label] = tr.wire_bytes_per_step
    assert wire["mixed"] == 2 * wire["plain"], wire
    assert wire["mixed_ef"] == wire["mixed"], wire

    # from the actual carried buffers: the overlap double-buffer holds the
    # momentum payload too, at exactly 2x the params-only sync bytes
    tr_o = CollaborativeTrainer(loss, params, topo,
                                CDMSGD(0.01, mu=0.9, fused=True),
                                exchange="int8", schedule="overlap",
                                momentum_mixing="mixed", donate=False)
    spec = flatbuf.make_flat_spec(tr_o.state.params, lead=1)
    per_nbr = engine.wire_bytes_per_neighbor(tr_o.state.opt_state.wire)
    assert per_nbr == 2 * spec.exchange_bytes("int8"), \
        (per_nbr, spec.exchange_bytes("int8"))

    rec = {
        "bench": "consensus/momentum_mix",
        "model": "33k f32 params, ring deg 2, int8 wire, CDMSGD mu=0.9",
        "us_per_step_interp": {k: round(v, 1) for k, v in us.items()},
        "wire_bytes_per_step": wire,
        "wire_bytes_per_neighbor_from_buffers": {
            "params_only": spec.exchange_bytes("int8"), "mixed": per_nbr},
        "mixed_wire_is_2x_params_only": True,
        "ef_extra_wire_bytes": 0,
    }
    row = ("kernel/momentum_mix", us["mixed"],
           f"plain_us={us['plain']:.0f};wire/step plain={wire['plain']} "
           f"mixed={wire['mixed']} (=2x);ef extra wire=0")
    return row, rec


def stale_ring(steps_timed: int = 3, drift_steps: int = 10):
    """Bounded-staleness ring (MixingProgram staleness=S + FaultSchedule)
    wire accounting and robustness trajectory.

    Asserts, from the actual carried WireRing buffers, that the bytes ONE
    neighbor transfer moves per step equal the sync schedule's
    ``FlatSpec.exchange_bytes`` at EVERY ring depth S — the ring deepens
    the local state (S generations + age counters), never the wire.
    Reports the max parameter drift vs the fault-free overlap run after
    ``drift_steps`` steps under an injected straggler+drop schedule — the
    price of absorbing the faults instead of stalling the step."""
    from repro.core import engine
    from repro.core.optim import CDSGD
    from repro.core.trainer import CollaborativeTrainer

    key = jax.random.PRNGKey(0)
    topo = make_topology("ring", 4)
    params = {"w": jax.random.normal(key, (256, 128), jnp.float32),
              "b": jax.random.normal(key, (300,), jnp.float32)}

    def loss(p, b):
        return 0.5 * (jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)), {}

    batch = {"x": jnp.zeros((4, 1), jnp.float32)}
    spec = flatbuf.make_flat_spec(
        jax.tree.map(lambda x: jnp.broadcast_to(x[None], (4,) + x.shape),
                     params), lead=1)
    sync_bytes = spec.exchange_bytes("int8")
    fault = "stall:1:1:3,drop:0:2"

    def make(S, fs, exch="int8"):
        return CollaborativeTrainer(loss, params, topo, CDSGD(0.01, fused=True),
                                    schedule="overlap", exchange=exch,
                                    staleness=S, fault_schedule=fs,
                                    donate=False)

    base = make(1, None)
    for _ in range(drift_steps):
        base.step(batch)

    us, drift, drift_fp8, ring_bytes = {}, {}, {}, {}
    sync_bytes_fp8 = spec.exchange_bytes("fp8")
    base_fp8 = make(1, None, exch="fp8")
    for _ in range(drift_steps):
        base_fp8.step(batch)
    for S in (1, 2, 4):
        tr = make(S, fault)
        ring_bytes[f"S{S}"] = engine.wire_bytes_per_neighbor(
            tr.state.opt_state.wire)
        # the ring never widens the wire: one selected generation moves
        assert ring_bytes[f"S{S}"] == sync_bytes, (S, ring_bytes, sync_bytes)
        us[f"S{S}"] = _time(tr._step_fn, tr.state.params,
                            tr.state.opt_state, batch, reps=steps_timed)
        for _ in range(drift_steps):
            tr.step(batch)
        drift[f"S{S}"] = max(
            float(jnp.max(jnp.abs(a - b))) for a, b in
            zip(jax.tree.leaves(tr.state.params),
                jax.tree.leaves(base.state.params)))
        # fp8 leg: same fault schedule at the precision we already ship —
        # the frontier table must not have holes where only int8 was run
        tr8 = make(S, fault, exch="fp8")
        assert engine.wire_bytes_per_neighbor(
            tr8.state.opt_state.wire) == sync_bytes_fp8
        for _ in range(drift_steps):
            tr8.step(batch)
        drift_fp8[f"S{S}"] = max(
            float(jnp.max(jnp.abs(a - b))) for a, b in
            zip(jax.tree.leaves(tr8.state.params),
                jax.tree.leaves(base_fp8.state.params)))

    rec = {
        "bench": "consensus/stale_ring",
        "model": "33k f32 params, ring deg 2, int8 wire, CDSGD",
        "fault_schedule": fault,
        "us_per_step_interp": {k: round(v, 1) for k, v in us.items()},
        "wire_bytes_per_neighbor": ring_bytes,
        "sync_wire_bytes_per_neighbor": sync_bytes,
        "ring_bytes_independent_of_S": True,
        "drift_vs_faultfree": drift,
        "drift_vs_faultfree_fp8": drift_fp8,
    }
    row = ("kernel/stale_ring", us["S4"],
           f"wire/nbr S1={ring_bytes['S1']} S2={ring_bytes['S2']} "
           f"S4={ring_bytes['S4']} (=sync {sync_bytes});"
           f"drift S1={drift['S1']:.1e} S4={drift['S4']:.1e}")
    return row, rec


def compressor_frontier(steps_timed: int = 3, drift_steps: int = 20):
    """Bytes-vs-drift frontier of the ``compressor=`` axis
    (f32 / int8 / fp8 / topk:p / rank:r — see repro.core.consensus).

    Every byte count comes from the actual carried wire buffers
    (:func:`repro.core.engine.wire_bytes_per_neighbor` on the overlap
    double-buffer), cross-checked against the analytic accounting
    (``MixingStrategy.bytes_per_neighbor`` and the trainer's
    ``wire_bytes_per_step``).  Asserts the headline claims:

    * topk:0.01 moves >= 25x fewer bytes per neighbor than the f32 wire;
    * 20-step parameter drift vs the same-schedule f32 run stays bounded
      for every compressed leg;
    * at equal density p, error feedback strictly beats no-EF top-k —
      the reason the biased compressors are EF-only at config time.
    """
    import dataclasses

    from repro.core import engine
    from repro.core.optim import CDSGD, stacked_comm_ops
    from repro.core.trainer import CollaborativeTrainer

    key = jax.random.PRNGKey(0)
    topo = make_topology("ring", 4)
    base_p = {"w": jax.random.normal(key, (256, 128), jnp.float32),
              "b": jax.random.normal(key, (300,), jnp.float32)}
    # de-synchronize the agents so the consensus signal is live and the
    # drift measures compression quality, not just SR noise
    stacked = jax.tree.map(
        lambda x: x[None] + 0.01 * jax.random.normal(
            jax.random.fold_in(key, 7), (4,) + x.shape, x.dtype), base_p)

    def loss(p, b):
        return 0.5 * (jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)), {}

    batch = {"x": jnp.zeros((4, 1), jnp.float32)}
    spec = flatbuf.make_flat_spec(stacked, lead=1)
    degree = topo.degree()

    def make(compressor):
        kw = {"compressor": compressor} if compressor != "none" else {}
        if compressor.startswith(("topk", "rank")):
            kw["error_feedback"] = True  # biased compressors are EF-only
        return CollaborativeTrainer(loss, stacked, topo,
                                    CDSGD(0.01, fused=True), stack=False,
                                    schedule="overlap", donate=False, **kw)

    legs = ("none", "int8", "fp8", "topk:0.1", "topk:0.01", "rank:4", "rank:1")
    us, bytes_nbr, drift = {}, {}, {}
    f32_params = None
    for leg in legs:
        tr = make(leg)
        name = "f32" if leg == "none" else leg
        actual = engine.wire_bytes_per_neighbor(tr.state.opt_state.wire)
        # accounting == actual buffers, at every layer that reports bytes
        analytic = tr.comm.flat.strategy.bytes_per_neighbor(spec)
        assert actual == analytic, (name, actual, analytic)
        assert tr.wire_bytes_per_step == actual * degree, (
            name, tr.wire_bytes_per_step, actual, degree)
        bytes_nbr[name] = actual
        us[name] = _time(tr._step_fn, tr.state.params,
                         tr.state.opt_state, batch, reps=steps_timed)
        for _ in range(drift_steps):
            tr.step(batch)
        if leg == "none":
            f32_params = tr.state.params
            drift[name] = 0.0
        else:
            drift[name] = max(
                float(jnp.max(jnp.abs(a - b))) for a, b in
                zip(jax.tree.leaves(tr.state.params),
                    jax.tree.leaves(f32_params)))

    # no-EF top-k control at equal p, driven through the engine directly —
    # make_mixing_program refuses the combination at config time, which is
    # exactly the claim this leg substantiates
    prog = dataclasses.replace(
        consensus_lib.make_mixing_program(
            topo, compressor="topk:0.1", error_feedback=True),
        error_feedback=False)
    opt = CDSGD(0.01, fused=True)
    comm = stacked_comm_ops(topo, interpret=True, exchange=prog.exchange,
                            program=prog)
    sp = engine.StepProgram(
        optimizer=opt, comm=comm,
        grad_phase=engine.make_grad_phase(loss, 1),
        update_phase=engine.make_update_phase(opt, comm, "overlap"),
        schedule="overlap")
    state = sp.init_state(stacked)
    step = jax.jit(sp.step_fn)
    params = stacked
    for _ in range(drift_steps):
        params, state, _ = step(params, state, batch)
    drift["topk:0.1_noef"] = max(
        float(jnp.max(jnp.abs(a - b))) for a, b in
        zip(jax.tree.leaves(params), jax.tree.leaves(f32_params)))

    ratio = bytes_nbr["f32"] / bytes_nbr["topk:0.01"]
    assert ratio >= 25.0, (ratio, bytes_nbr)
    # bounded drift: the SR-unbiased wires must track the f32 run tightly;
    # the biased EF legs pay the Lyapunov radius inflation (1 + 2d/(1-d))
    # instead — their envelope is the f32 trajectory's own magnitude (the
    # compressed runs stay in the same ball; divergence would blow past it)
    pmax = max(float(jnp.max(jnp.abs(a)))
               for a in jax.tree.leaves(f32_params))
    assert drift["int8"] < 0.2 and drift["fp8"] < 0.5, drift
    for name, d in drift.items():
        assert d < 2.0 * pmax, (name, d, pmax, drift)
    assert drift["topk:0.1"] < drift["topk:0.1_noef"], drift

    rec = {
        "bench": "consensus/compressor_frontier",
        "model": "33k f32 params, ring deg 2, CDSGD, overlap schedule",
        "us_per_step_interp": {k: round(v, 1) for k, v in us.items()},
        "wire_bytes_per_neighbor": bytes_nbr,
        "bytes_ratio_f32_over_topk001": round(ratio, 2),
        "drift_vs_f32_20step": drift,
        "accounting_matches_actual_buffers": True,
        "ef_beats_noef_at_equal_p": True,
    }
    row = ("kernel/compressor_frontier", us["topk:0.01"],
           f"bytes/nbr f32={bytes_nbr['f32']} int8={bytes_nbr['int8']} "
           f"topk:0.01={bytes_nbr['topk:0.01']} rank:1={bytes_nbr['rank:1']} "
           f"(f32/topk:0.01={ratio:.0f}x);"
           f"drift topk:0.01={drift['topk:0.01']:.1e} "
           f"ef<noef@p=0.1 {drift['topk:0.1']:.1e}<{drift['topk:0.1_noef']:.1e}")
    return row, rec


def sparse_update(rows_n: int = 8192):
    """The two operand forms of the fused update on a top-k wire.

    One f32 bucket of ``rows_n`` lane rows, ring stencil S = 2 neighbors.
    Per density p the SAME compressed payloads drive both paths:

    * dense reference (``sparse_update=False``): ``topk_decompress_2d``
      each neighbor into a dense f32 bucket, then the dense kernel reads
      ``rows * 128`` elements per neighbor;
    * sparse (``sparse_update=True`` default): the compact int8 values /
      int32 indices / row scales feed ``cdsgd_update_sparse_2d`` directly
      — ``k_rows * 128`` elements per neighbor.

    Walltime is interpret-mode (not hardware-representative); the number
    that transfers is the accounted HBM byte ratio from
    ``consensus_update_cost`` (the kernels are memory-bound).  Asserts
    sparse strictly cheaper in BOTH measures at p = 0.01.
    """
    from repro.analysis.roofline import consensus_update_cost
    from repro.kernels.consensus_update import topk as tk
    from repro.kernels.consensus_update.consensus_update import (
        cdsgd_update_sparse_2d,
    )

    key = jax.random.PRNGKey(0)
    topo = make_topology("ring", 4)
    n_nbr = topo.degree()                         # 2
    slf = jax.random.normal(key, (rows_n, 128), jnp.float32)
    g = jax.random.normal(jax.random.fold_in(key, 1), (rows_n, 128),
                          jnp.float32)
    w = jnp.array([1 / 3, 1 / 3, 1 / 3], jnp.float32)   # [self, nbr, nbr]
    spec = flatbuf.make_flat_spec(
        {"w": jax.ShapeDtypeStruct((rows_n * 128,), jnp.float32)})

    per_p, us = {}, {}
    for p in (0.1, 0.01):
        k_rows = tk.topk_k_rows(rows_n, p)
        wires = [tk.topk_compress_2d(
            jax.random.normal(jax.random.fold_in(key, 10 + i),
                              (rows_n, 128), jnp.float32),
            k_rows, jnp.int32(i), interpret=True) for i in range(n_nbr)]
        vals = jnp.stack([v for v, _, _ in wires])
        idx = jnp.stack([i for _, i, _ in wires])
        scs = jnp.stack([s for _, _, s in wires])

        # single grid step for both forms: the comparison isolates the
        # operand form, not the block schedule
        def dense_fn(vals, idx, scs, slf, g):
            # the sparse_update=False reference: decompress to dense f32,
            # unit row scales, self separate at weights[0]
            nb = jnp.stack([tk.topk_decompress_2d(vals[i], idx[i], scs[i],
                                                  rows_n)
                            for i in range(n_nbr)])
            unit = jnp.ones((n_nbr, rows_n, 1), jnp.float32)
            return cdsgd_update_2d(nb, w, g, 0.05, scales=unit,
                                   self_buf=slf, block_rows=rows_n,
                                   interpret=True)

        def sparse_fn(vals, idx, scs, slf, g):
            return cdsgd_update_sparse_2d(vals, idx, scs, w, g, 0.05,
                                          self_buf=slf, block_rows=rows_n,
                                          interpret=True)

        t_dense = _time(jax.jit(dense_fn), vals, idx, scs, slf, g)
        t_sparse = _time(jax.jit(sparse_fn), vals, idx, scs, slf, g)
        # parity while we're here: same payloads, same answer (FMA
        # contraction of the dense accumulate is the only divergence)
        d = float(jnp.max(jnp.abs(
            jax.jit(dense_fn)(vals, idx, scs, slf, g)
            - jax.jit(sparse_fn)(vals, idx, scs, slf, g))))
        assert d < 1e-5, d

        prog = consensus_lib.make_mixing_program(
            topo, compressor=f"topk:{p}", error_feedback=True)
        cost = consensus_update_cost(spec, prog, n_nbr)
        per_p[str(p)] = {
            "k_rows": k_rows,
            "us_per_call_interp": {"dense": round(t_dense, 1),
                                   "sparse": round(t_sparse, 1)},
            "walltime_ratio_dense_over_sparse": round(t_dense / t_sparse, 2),
            "hbm_bytes": {"dense": cost["dense_bytes"],
                          "sparse": cost["sparse_bytes"]},
            "hbm_bytes_ratio": round(cost["bytes_ratio"], 2),
            "flops_ratio": round(cost["flops_ratio"], 2),
            "max_abs_diff_dense_vs_sparse": d,
        }
        us[p] = (t_dense, t_sparse)

    # the acceptance point: at p <= 0.05 sparse is strictly cheaper in
    # measured walltime AND accounted HBM bytes
    t_dense, t_sparse = us[0.01]
    assert t_sparse < t_dense, (t_sparse, t_dense)
    assert (per_p["0.01"]["hbm_bytes"]["sparse"]
            < per_p["0.01"]["hbm_bytes"]["dense"]), per_p["0.01"]

    rec = {
        "bench": "consensus/sparse_update",
        "model": f"{rows_n * 128 // 1000}k f32 bucket, ring deg 2, CDSGD",
        "per_density": per_p,
        "sparse_strictly_cheaper_at_p001": True,
    }
    row = ("kernel/sparse_update", us[0.01][1],
           f"dense_us={us[0.01][0]:.0f}@p=0.01;"
           f"hbm sparse/dense="
           f"{per_p['0.01']['hbm_bytes']['sparse'] / per_p['0.01']['hbm_bytes']['dense']:.3f};"
           f"walltime_ratio={per_p['0.01']['walltime_ratio_dense_over_sparse']}x")
    return row, rec


def run(smoke: bool = False, json_out: str = None):
    key = jax.random.PRNGKey(0)
    rows = []
    records = []

    # consensus update: S=3 ring stencil, 1M params
    rows_n = 8192
    nb = jax.random.normal(key, (3, rows_n, 128), jnp.float32)
    g = jax.random.normal(key, (rows_n, 128), jnp.float32)
    mom = jax.random.normal(key, (rows_n, 128), jnp.float32)
    w = jnp.array([1 / 3, 1 / 3, 1 / 3], jnp.float32)
    t_kernel = _time(jax.jit(lambda *a: cdsgd_update_2d(*a, 0.05, interpret=True)), nb, w, g)
    t_ref = _time(jax.jit(lambda *a: cdsgd_update_ref(*a, 0.05)), nb, w, g)
    # CDSGD: fused (3 nbr reads + grad + write = 5) vs unfused mix+axpy (7)
    rows.append(("kernel/consensus_update",
                 t_kernel, f"ref_us={t_ref:.0f};hbm_traffic_fused/unfused={5/7:.3f}"))
    t_mom = _time(jax.jit(lambda *a: cdmsgd_update_2d(*a, 0.05, 0.9, interpret=True)),
                  nb, w, g, mom)
    # CDMSGD momentum path: fused 3+2 reads+2 writes = 7 sweeps vs unfused
    # mix(4) + momentum(3) + param(3) = 10 sweeps (see module docstring)
    rows.append(("kernel/consensus_update_momentum",
                 t_mom, f"hbm_traffic_fused/unfused={7/10:.3f}"))
    records.append({"bench": "consensus/hbm_ratio",
                    "cdsgd": {"fused_sweeps": 5, "unfused_sweeps": 7},
                    "cdmsgd": {"fused_sweeps": 7, "unfused_sweeps": 10}})

    # quantized exchange: quantize + int8-neighbor fused update (neighbors
    # on the wire are int8 + row scales; self rides native at weights[0])
    q, sc = jax.jit(lambda x: sr_quantize_2d(x, 0, interpret=True))(g)
    nb_q = jnp.stack([q, q])
    sc_q = jnp.stack([sc, sc])
    w_q = jnp.array([1 / 3, 1 / 3, 1 / 3], jnp.float32)  # [self, nbr, nbr]
    slf = jax.random.normal(key, (rows_n, 128), jnp.float32)
    t_quant = _time(jax.jit(lambda x: sr_quantize_2d(x, 0, interpret=True)), g)
    t_qmom = _time(jax.jit(lambda n, s, sb, *a: cdmsgd_update_2d(
        n, w_q, *a, 0.05, 0.9, scales=s, self_buf=sb, interpret=True)),
        nb_q, sc_q, slf, g, mom)
    rows.append(("kernel/consensus_update_momentum_int8", t_qmom,
                 f"quantize_us={t_quant:.0f};dequant=in-register"))

    # whole-model bucketed update vs per-leaf launches
    row, rec = bucketed_model_update()
    rows.append(row)
    records.append(rec)

    # bytes-on-wire per exchange precision + in-place aliasing accounting
    # + sync-vs-overlap schedule step time / wire-byte equality
    # + k-round strategy wire accounting (k x sync; EF adds 0)
    # + momentum-mixing wire accounting (2x params-only; EF still +0)
    # + staleness-ring wire accounting (bytes independent of S) and
    #   drift-vs-S under an injected straggler+drop schedule
    # + compressor bytes-vs-drift frontier (topk/rank EF rail)
    # + sparse vs dense operand form of the fused update on the top-k wire
    for fn in (exchange_wire, alias_accounting, schedule_overlap, multi_round,
               momentum_mix, stale_ring, compressor_frontier, sparse_update):
        row, rec = fn()
        rows.append(row)
        records.append(rec)

    if smoke:
        _emit(rows, records, json_out)
        return rows

    # flash attention 1k seq
    q = jax.random.normal(key, (1, 4, 1024, 64), jnp.float32)
    k = jax.random.normal(key, (1, 2, 1024, 64), jnp.float32)
    v = jax.random.normal(key, (1, 2, 1024, 64), jnp.float32)
    t_kernel = _time(jax.jit(lambda *a: flash_attention(*a, causal=True, interpret=True)), q, k, v)
    t_ref = _time(jax.jit(lambda *a: attention_ref(*a, causal=True)), q, k, v)
    s_mat = 4 * 1024 * 1024 * 4 * 2  # S+P matrices fp32, per head
    rows.append(("kernel/flash_attention", t_kernel,
                 f"ref_us={t_ref:.0f};score_matrix_bytes_avoided={s_mat}"))

    # wkv6 4-head 512-seq
    r = jax.random.normal(key, (4, 512, 64))
    kk = jax.random.normal(key, (4, 512, 64))
    vv = jax.random.normal(key, (4, 512, 64))
    ww = jax.nn.sigmoid(jax.random.normal(key, (4, 512, 64))) * 0.5 + 0.45
    u = 0.1 * jax.random.normal(key, (4, 64))
    t_kernel = _time(jax.jit(lambda *a: wkv6_pallas(*a, chunk=128, interpret=True)), r, kk, vv, ww, u)
    t_ref = _time(jax.jit(wkv6_ref), r, kk, vv, ww, u)
    rows.append(("kernel/wkv6_scan", t_kernel, f"ref_us={t_ref:.0f};state_hbm_roundtrips=0"))

    _emit(rows, records, json_out)
    return rows


def _emit(rows, records, json_out):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print("JSON," + json.dumps(records))
    if json_out:
        with open(json_out, "w") as f:
            json.dump(records, f, indent=2)
        print(f"wrote {json_out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="consensus-path benches only (fast; used by CI)")
    ap.add_argument("--json-out", default=None,
                    help="also write the JSON records to this file")
    args = ap.parse_args()
    run(smoke=args.smoke, json_out=args.json_out)
