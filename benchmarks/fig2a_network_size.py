"""Fig 2(a): effect of network size (N = 2, 4, 8, 16 agents).

Paper claims: convergence slows as agents grow, but all sizes reach
similar accuracy levels.
"""

from benchmarks.common import emit, run_experiment


def run(steps: int = 150):
    rows = [
        run_experiment(f"fig2a/agents{n}", "cdmsgd", steps=steps, agents=n, mu=0.9)
        for n in (2, 4, 8, 16)
    ]
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
