"""Table 1: convergence-rate regimes of CDSGD on a strongly convex problem.

Measures the empirical per-step contraction of V(x_k) - V* on the known
quadratic and compares against the paper's regimes:

* fixed step, no gradient noise      -> linear rate O(gamma^k) (Thm 1)
* fixed step, stochastic gradients   -> linear to a noise floor (Thm 1)
* diminishing step, stochastic       -> sublinear O(1/k^eps) to zero (Thm 3)
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lyapunov, schedules
from repro.core.topology import make_topology

N, D = 5, 8


def _setup(seed=0):
    rng = np.random.default_rng(seed)
    eigs = jnp.asarray(rng.uniform(0.5, 2.0, size=(N, D)), jnp.float32)
    centers = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
    t = make_topology("ring", N, lazy_beta=0.5)
    pi = jnp.asarray(t.pi, jnp.float32)
    return eigs, centers, t, pi


def _run(noise: float, sched, steps: int = 800, seed: int = 0):
    eigs, centers, t, pi = _setup()
    rng = np.random.default_rng(seed + 1)
    x = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)

    def v_value(x, alpha):
        fsum = jnp.sum(0.5 * eigs * (x - centers) ** 2)
        return float(lyapunov.lyapunov_value(fsum, x, pi, alpha))

    # V* from a long noiseless run at the final step size
    xs = jnp.zeros((N, D))
    a_inf = float(sched(jnp.asarray(steps)))
    for _ in range(6000):
        xs = pi @ xs - a_inf * eigs * (xs - centers)
    v_star = v_value(xs, a_inf)

    vals = []
    for k in range(steps):
        a = float(sched(jnp.asarray(k)))
        g = eigs * (x - centers)
        if noise:
            g = g + noise * jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
        x = pi @ x - a * g
        vals.append(max(v_value(x, a_inf) - v_star, 1e-12))
    return np.array(vals)


def run():
    t0 = time.time()
    rows = []

    v = _run(0.0, schedules.fixed(0.05))
    # empirical contraction over the clean-decay region
    head = v[: np.argmax(v < 1e-8) or 200]
    rate = float(np.exp(np.mean(np.diff(np.log(head[:100])))))
    rows.append(("table1/fixed_noiseless", f"rate_per_step={rate:.4f};final={v[-1]:.2e};regime=linear"))

    v = _run(0.5, schedules.fixed(0.05))
    floor = float(np.mean(v[-100:]))
    rows.append(("table1/fixed_noisy", f"noise_floor={floor:.3e};regime=linear_to_floor"))

    v = _run(0.5, schedules.diminishing(theta=2.0, eps=1.0, t=10.0))
    tail_ratio = float(np.mean(v[-50:]) / np.mean(v[200:250]))
    rows.append(("table1/diminishing_noisy",
                 f"final={float(np.mean(v[-50:])):.3e};tail_ratio={tail_ratio:.3f};regime=sublinear_to_zero"))

    us = 1e6 * (time.time() - t0) / 3
    for name, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    run()
