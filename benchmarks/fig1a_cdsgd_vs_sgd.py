"""Fig 1(a): CDSGD vs centralized SGD — accuracy + generalization gap.

Paper claims: CDSGD converges slower but reaches comparable accuracy, with
a *smaller* generalization gap (train - validation accuracy).
"""

from benchmarks.common import emit, run_experiment


def run(steps: int = 150):
    rows = [
        run_experiment("fig1a/sgd", "sgd", steps=steps),
        run_experiment("fig1a/cdsgd", "cdsgd", steps=steps),
    ]
    emit(rows)
    gap = {r["name"]: r["train_acc"] - r["val_acc"] for r in rows}
    print(f"fig1a/generalization_gap,0.0,sgd={gap['fig1a/sgd']:.4f};"
          f"cdsgd={gap['fig1a/cdsgd']:.4f}")
    return rows


if __name__ == "__main__":
    run()
