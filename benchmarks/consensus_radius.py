"""Proposition 1: measured consensus error vs the bound alpha L/(1-lambda_2).

One row per (topology, alpha); derived reports measured/bound — values
<= 1 mean the paper's bound holds (it should, with slack).
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.core import lyapunov
from repro.core.consensus import consensus_error_stacked
from repro.core.topology import make_topology

N, D = 8, 8


def run():
    rng = np.random.default_rng(0)
    eigs = jnp.asarray(rng.uniform(0.5, 2.0, size=(N, D)), jnp.float32)
    centers = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
    t0 = time.time()
    rows = []
    for topo in ("ring", "torus", "erdos_renyi"):
        t = make_topology(topo, N)
        pi = jnp.asarray(t.pi, jnp.float32)
        for alpha in (0.1, 0.05, 0.01):
            x = jnp.zeros((N, D))
            l_emp = 0.0
            for k in range(600):
                g = eigs * (x - centers)
                if k > 300:
                    l_emp = max(l_emp, float(jnp.max(jnp.linalg.norm(g, axis=1))))
                x = pi @ x - alpha * g
            err = float(consensus_error_stacked(x))
            bound = lyapunov.consensus_bound(alpha, l_emp, t)
            rows.append((f"prop1/{topo}_a{alpha:g}",
                         f"measured={err:.3e};bound={bound:.3e};ratio={err/max(bound,1e-12):.3f}"))
    us = 1e6 * (time.time() - t0) / len(rows)
    for name, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    run()
