"""Table 1 (methods comparison): CDSGD vs gossip SGD vs time-varying CDSGD.

The paper's Table 1 contrasts CDSGD with gossip SGD [7] (decentralized but
*unconstrained* random pairwise communication).  This benchmark runs both,
plus the time-varying-topology extension (paper future work §6.ii:
alternating row/column line graphs on a 2x4 grid whose union is
connected), on the synthetic classification task.
"""

import numpy as np

from repro.core.optim import GossipSGD, TimeVaryingCDSGD
from repro.core.topology import Topology, metropolis_pi

from benchmarks.common import base_params, dataset, emit, run_experiment


def _grid_line_topologies(rows=2, cols=4):
    n = rows * cols

    def adj(edges):
        a = np.zeros((n, n))
        for i, j in edges:
            a[i, j] = a[j, i] = 1.0
        return a

    row_edges = [(r * cols + c, r * cols + c + 1)
                 for r in range(rows) for c in range(cols - 1)]
    col_edges = [(r * cols + c, (r + 1) * cols + c)
                 for r in range(rows - 1) for c in range(cols)]
    return (Topology("grid_rows", metropolis_pi(adj(row_edges))),
            Topology("grid_cols", metropolis_pi(adj(col_edges))))


def run(steps: int = 150, agents: int = 8):
    rows = [
        run_experiment("table1m/cdsgd_ring", "cdsgd", steps=steps,
                       agents=agents, topology="ring"),
        run_experiment("table1m/gossip", "gossip", steps=steps, agents=agents,
                       n_agents=agents),
        run_experiment("table1m/cdsgd_timevarying", "cdsgd_tv", steps=steps,
                       agents=agents, topologies=_grid_line_topologies()),
    ]
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
