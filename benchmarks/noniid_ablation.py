"""Beyond-paper ablation: non-IID (label-skew) data partitions.

The paper lists "collaborative learning with extreme non-IID data" as
future work (§6.i).  This benchmark runs CDSGD/CDMSGD/FedAvg on the
label-sorted partition and reports the accuracy drop vs IID — consensus
mixing is what lets an agent learn classes it never sees locally.
"""

from benchmarks.common import emit, run_experiment


def run(steps: int = 150):
    rows = []
    for opt, kw in [("cdmsgd", {"mu": 0.9}), ("fedavg", {"mu": 0.9, "local_steps": 1}),
                    ("cdsgd", {})]:
        iid = run_experiment(f"noniid/{opt}_iid", opt, steps=steps, **kw)
        skew = run_experiment(f"noniid/{opt}_skew", opt, steps=steps, non_iid=True, **kw)
        rows.extend([iid, skew])
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
