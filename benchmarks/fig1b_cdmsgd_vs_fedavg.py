"""Fig 1(b): CDMSGD vs Federated Averaging (the paper's headline result).

Paper claims: CDMSGD is slightly slower to converge than FedAvg (which
brute-force averages on a parameter server every epoch) but performs
better at steady state, approaching centralized-SGD accuracy.
"""

from benchmarks.common import emit, run_experiment


def run(steps: int = 200):
    rows = [
        run_experiment("fig1b/fedavg_e1", "fedavg", steps=steps, mu=0.9, local_steps=1),
        run_experiment("fig1b/fedavg_e5", "fedavg", steps=steps, mu=0.9, local_steps=5),
        run_experiment("fig1b/cdmsgd", "cdmsgd", steps=steps, mu=0.9),
        run_experiment("fig1b/cdmsgd_nesterov", "cdmsgd_nesterov", steps=steps, mu=0.9),
        run_experiment("fig1b/sgd", "sgd", steps=steps),
    ]
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
