"""Shared benchmark harness: paper-experiment runner + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (one per variant):
``us_per_call`` is the mean optimizer-step wall time; ``derived`` packs the
figure's headline quantity (accuracy / consensus / rate), semicolon-keyed.
"""

from __future__ import annotations

import functools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_topology, make_optimizer
from repro.core.trainer import CollaborativeTrainer, train_loop
from repro.data import AgentPartitioner, make_classification
from repro.nn.paper_models import (
    classifier_loss,
    cnn_classifier_apply,
    cnn_classifier_template,
    mlp_classifier_apply,
    mlp_classifier_template,
)
from repro.nn.param import init_params

MLP_LOSS = functools.partial(classifier_loss, mlp_classifier_apply)
CNN_LOSS = functools.partial(classifier_loss, cnn_classifier_apply)


@functools.lru_cache(maxsize=4)
def dataset(kind: str = "flat", n: int = 4096, n_classes: int = 10):
    if kind == "image":
        return make_classification(n, n_classes=n_classes, image_hw=16, seed=0)
    return make_classification(n, n_classes=n_classes, dim=64, seed=0)


@functools.lru_cache(maxsize=4)
def base_params(kind: str = "flat", n_classes: int = 10):
    key = jax.random.PRNGKey(0)
    if kind == "image":
        return init_params(cnn_classifier_template(16, 3, n_classes), key)
    return init_params(mlp_classifier_template(64, n_classes, width=50, depth=6), key)


def run_experiment(
    name: str,
    optimizer: str,
    *,
    kind: str = "flat",
    steps: int = 150,
    agents: int = 5,
    topology: str = "fully_connected",
    lr: float = 0.05,
    schedule=None,
    batch: int = 64,
    eval_every: int = 25,
    n_classes: int = 10,
    non_iid: bool = False,
    **opt_kw,
) -> Dict:
    train, val = dataset(kind, n_classes=n_classes)
    params = base_params(kind, n_classes)
    loss = CNN_LOSS if kind == "image" else MLP_LOSS
    part = AgentPartitioner(train, agents, seed=0, non_iid=non_iid)
    topo = make_topology(topology, agents)
    opt = make_optimizer(optimizer, schedule if schedule is not None else lr, **opt_kw)
    tr = CollaborativeTrainer(loss, params, topo, opt)
    eval_batch = {"x": jnp.asarray(val.x), "y": jnp.asarray(val.y)}

    batches = part.batches(batch)
    tr.step(next(batches))          # compile
    t0 = time.time()
    train_loop(tr, batches, steps - 1, eval_batch=eval_batch, eval_every=eval_every)
    dt = time.time() - t0
    ev = tr.evaluate(eval_batch)
    last = tr.history.rows[-1]
    return {
        "name": name,
        "us_per_call": 1e6 * dt / max(steps - 1, 1),
        "train_acc": last.get("acc", float("nan")),
        "val_acc": ev["acc_mean"],
        "val_acc_var": ev["acc_var"],
        "consensus": last.get("consensus_error", float("nan")),
        "loss": last.get("loss", float("nan")),
        "history": tr.history,
        "lambda2": topo.lambda2,
    }


def emit(rows: List[Dict]) -> None:
    for r in rows:
        derived = (f"val_acc={r['val_acc']:.4f};train_acc={r['train_acc']:.4f};"
                   f"consensus={r['consensus']:.3e};acc_var={r['val_acc_var']:.2e}")
        print(f"{r['name']},{r['us_per_call']:.1f},{derived}")
