"""Fig 5: step-size effects (fixed 1e-1/1e-2/1e-3 + decaying).

Paper claims: large steps converge fastest but with unstable consensus;
tiny steps give stable consensus but very slow convergence (0.01 is the
sweet spot); decaying steps drive consensus error toward zero (Thm 3/4).
"""

from repro.core import schedules

from benchmarks.common import emit, run_experiment


def run(steps: int = 150):
    rows = []
    for lr in (0.1, 0.01, 0.001):
        rows.append(run_experiment(f"fig5/fixed_{lr:g}", "cdmsgd",
                                   steps=steps, lr=lr, mu=0.9))
    rows.append(run_experiment(
        "fig5/decaying", "cdmsgd", steps=steps, mu=0.9,
        schedule=schedules.diminishing(theta=2.0, eps=1.0, t=20.0)))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
