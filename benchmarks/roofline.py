"""Roofline aggregation: results/dryrun/*.json -> the §Roofline table.

Prints one CSV row per (arch, shape, mesh): the three terms in seconds,
the dominant bottleneck, and the MODEL_FLOPS / HLO_FLOPs utilization
ratio.  Also emits a markdown table to results/roofline.md for
EXPERIMENTS.md inclusion.

Records carrying an ``update_cost`` block (dry-runs with a top-k
compressor — see ``repro.analysis.roofline.consensus_update_cost``) get
one extra ``roofline/update_cost`` row pricing the fused consensus
update's two operand forms: dense (decompress-then-update) vs sparse
(gather-dequant-accumulate on the compact wire), bytes and FLOPs per
step from the FlatSpec bucket geometry.
"""

import glob
import json
import os
import time

RESULTS = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       "results", "dryrun")


def load_records(pattern: str = "*.json"):
    recs = []
    for p in sorted(glob.glob(os.path.join(RESULTS, pattern))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def run(mesh_filter: str = "16x16"):
    recs = load_records()
    rows = []
    md = ["| arch | shape | mesh | compute (s) | memory (s) | collective (s) | dominant | useful-FLOP ratio | fits 16GB |",
          "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("status") != "ok":
            if "skip" in str(r.get("status", "")):
                md.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | skipped (sub-quadratic rule) | — | — |")
            continue
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        rl = r["roofline"]
        name = f"roofline/{r['arch']}__{r['shape']}__{r['mesh']}"
        ratio = rl["useful_flops_ratio"]
        ratio_s = f"{ratio:.3f}" if ratio == ratio else "n/a"
        derived = (f"compute={rl['compute_s']:.3e};memory={rl['memory_s']:.3e};"
                   f"collective={rl['collective_s']:.3e};dominant={rl['dominant']};"
                   f"useful_ratio={ratio_s};fits={r['fits_v5e_16gb']}")
        rows.append((name, derived))
        md.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {rl['compute_s']:.3e} "
                  f"| {rl['memory_s']:.3e} | {rl['collective_s']:.3e} | **{rl['dominant']}** "
                  f"| {ratio_s} | {r['fits_v5e_16gb']} |")
        uc = r.get("update_cost")
        if uc:
            rows.append((
                f"roofline/update_cost/{r['arch']}__{r['shape']}__{r['mesh']}",
                f"sparse_update={uc['sparse_update']};"
                f"dense_bytes={uc['dense_bytes']};"
                f"sparse_bytes={uc['sparse_bytes']};"
                f"bytes_ratio={uc['bytes_ratio']:.2f};"
                f"flops_ratio={uc['flops_ratio']:.2f};"
                f"n_buckets={len(uc['per_bucket'])}"))
    t0 = time.time()
    for name, derived in rows:
        print(f"{name},{1e6*(time.time()-t0):.1f},{derived}")
    out = os.path.join(os.path.dirname(RESULTS), "roofline.md")
    with open(out, "w") as f:
        f.write("\n".join(md) + "\n")
    print(f"roofline/markdown_table,0.0,written={out};rows={len(rows)}")
    return rows


if __name__ == "__main__":
    run(mesh_filter="")
