"""Benchmark entry point: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run``        — everything
``PYTHONPATH=src python -m benchmarks.run fig1a``  — one benchmark

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).
"""

import sys
import time

from benchmarks import (
    consensus_radius,
    fig1a_cdsgd_vs_sgd,
    fig1b_cdmsgd_vs_fedavg,
    fig2a_network_size,
    fig2b_topology,
    fig4_datasets,
    fig5_step_size,
    kernel_microbench,
    noniid_ablation,
    roofline,
    table1_methods,
    table1_rates,
)

BENCHES = {
    "fig1a": fig1a_cdsgd_vs_sgd.run,
    "fig1b": fig1b_cdmsgd_vs_fedavg.run,
    "fig2a": fig2a_network_size.run,
    "fig2b": fig2b_topology.run,
    "fig4": fig4_datasets.run,
    "fig5": fig5_step_size.run,
    "table1": table1_rates.run,
    "table1_methods": table1_methods.run,
    "prop1": consensus_radius.run,
    "noniid": noniid_ablation.run,
    "kernels": kernel_microbench.run,
    "roofline": lambda: roofline.run(mesh_filter=""),
}


def main() -> None:
    names = sys.argv[1:] or list(BENCHES)
    print("name,us_per_call,derived")
    t0 = time.time()
    for n in names:
        if n not in BENCHES:
            raise SystemExit(f"unknown benchmark {n!r}; available: {sorted(BENCHES)}")
        BENCHES[n]()
    print(f"benchmarks/total,{1e6 * (time.time() - t0):.0f},count={len(names)}")


if __name__ == "__main__":
    main()
