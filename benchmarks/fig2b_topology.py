"""Fig 2(b): effect of topology sparsity (lambda_2 sweep).

Paper claims: sparser networks (larger second eigenvalue) converge faster
in average accuracy but with *less stable consensus* (higher variance of
accuracy across agents).
"""

from benchmarks.common import emit, run_experiment


def run(steps: int = 150, agents: int = 8):
    rows = []
    for topo in ("fully_connected", "torus", "ring", "chain"):
        r = run_experiment(f"fig2b/{topo}", "cdmsgd", steps=steps, agents=agents,
                           topology=topo, mu=0.9)
        r["name"] = f"fig2b/{topo}(l2={r['lambda2']:.3f})"
        rows.append(r)
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
