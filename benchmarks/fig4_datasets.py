"""Fig 4: the MNIST-MLP and CIFAR-CNN analogues of Fig 1.

The paper's MNIST model is a 20x50-unit ReLU MLP; its CIFAR model is the
conv32/32-pool-conv64/64-pool-dense512 CNN.  We run both model families on
the synthetic stand-ins (offline container) with SGD / CDSGD / CDMSGD /
FedAvg, checking the same orderings hold on a second model family.
"""

from benchmarks.common import emit, run_experiment


def run(steps: int = 80):
    rows = []
    for opt, kw in [("sgd", {}), ("cdsgd", {}), ("cdmsgd", {"mu": 0.9}),
                    ("fedavg", {"mu": 0.9, "local_steps": 1})]:
        rows.append(run_experiment(f"fig4/cnn_{opt}", opt, kind="image",
                                   steps=steps, lr=0.02, **kw))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
