"""HLO analyzer: trip-count-aware FLOPs / collective-bytes accounting.

Builds a small sharded scan program in a subprocess (8 host devices) and
checks the analyzer recovers the exact analytic numbers that
``compiled.cost_analysis()`` undercounts (loop body counted once).
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis.hlo import analyze_hlo

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_analyzer_on_synthetic_hlo_text():
    hlo = textwrap.dedent("""\
    HloModule test, num_partitions=4

    %body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p = (s32[], f32[8,16]{1,0}) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
      %one = s32[] constant(1)
      %i2 = s32[] add(%i, %one)
      %ag = f32[8,32]{1,0} all-gather(%x), channel_id=1, replica_groups={}, dimensions={1}
      %w = f32[32,16]{1,0} constant({...})
      %y = f32[8,16]{1,0} dot(%ag, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%i2, %y)
    }

    %cond (p: (s32[], f32[8,16])) -> pred[] {
      %p = (s32[], f32[8,16]{1,0}) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %n = s32[] constant(7)
      ROOT %lt = pred[] compare(%i, %n), direction=LT
    }

    ENTRY %main (a: f32[8,16]) -> f32[8,16] {
      %a = f32[8,16]{1,0} parameter(0)
      %z = s32[] constant(0)
      %t0 = (s32[], f32[8,16]{1,0}) tuple(%z, %a)
      %wh = (s32[], f32[8,16]{1,0}) while(%t0), condition=%cond, body=%body
      ROOT %out = f32[8,16]{1,0} get-tuple-element(%wh), index=1
    }
    """)
    st = analyze_hlo(hlo)
    assert st.trip_counts == {"body": 7}
    # dot: 2 * (8*16) * 32 = 8192 flops x 7 trips
    assert st.dot_flops == 7 * 2 * 8 * 16 * 32
    # all-gather operand: 8*16*4 bytes x 7 trips
    assert st.collective_bytes["all-gather"] == 7 * 8 * 16 * 4
    assert st.collective_count["all-gather"] == 7


@pytest.mark.slow
def test_analyzer_matches_real_compiled_scan():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp
        from jax import lax
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.analysis.hlo import analyze_hlo
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh(4, 2)
        def f(w, x):
            def body(h, wi):
                return jnp.tanh(h @ wi), None
            h, _ = lax.scan(body, x, w)
            return lax.with_sharding_constraint(
                h, NamedSharding(mesh, P("data","model"))).sum()
        w = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32,
                                 sharding=NamedSharding(mesh, P(None,None,"model")))
        x = jax.ShapeDtypeStruct((16, 128), jnp.float32,
                                 sharding=NamedSharding(mesh, P("data",None)))
        with mesh:
            comp = jax.jit(f).lower(w, x).compile()
        st = analyze_hlo(comp.as_text())
        print("RESULT " + json.dumps({
            "flops": st.dot_flops,
            "trips": list(st.trip_counts.values()),
            "ag_bytes": st.collective_bytes["all-gather"],
        }))
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=560, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads([l for l in out.stdout.splitlines()
                      if l.startswith("RESULT ")][-1][len("RESULT "):])
    # per-device: batch shard 4 rows, contraction 128, output cols 64, x10 trips
    assert res["flops"] == 10 * 2 * 4 * 128 * 64
    assert 10 in res["trips"]
    assert res["ag_bytes"] == 10 * 4 * 64 * 4  # (4,64) f32 operand x 10
