"""ISSUE-9: the sparse (top-k wire) operand form of the fused consensus
update, plus adaptive per-bucket density (``topk:auto:B``).

Covers:
* kernel-level round trip, per optimizer family: the gather-dequant-
  accumulate kernel on the compact ``TopKWire`` fields == the
  decompress-then-dense reference on the SAME payloads.  Few-ULP, not
  bit-for-bit: XLA contracts the dense kernel's multiply-accumulate into
  an FMA (one rounding) while the sparse scatter-add cannot fuse — the
  only divergence source, bounded well inside the 1e-5 acceptance;
* trainer-level sparse-vs-dense parity for every family supporting
  top-k, sync AND overlap (the sharded twin is the subprocess test);
* ``topk:auto:B`` — the parser, the per-bucket density solver (budget
  met within one lane row per bucket), the bytes counted from the
  ACTUAL carried wire buffers, and the cost line's per-bucket densities;
* the ``sparse_update`` knob: default-on for top-k, ``False`` keeps the
  dense reference path, explicit ``True`` without top-k is an
  actionable config error;
* ``consensus_update_cost`` pricing (dense vs sparse operand bytes and
  FLOPs per bucket from the FlatSpec);
* top-k kernel edge cases (satellite): all-zero bucket, k_rows clamp at
  ``p * rows * 128 < 128``, threshold ties, single-row bucket.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import consensus as C
from repro.core import engine, flatbuf
from repro.core.optim import make_optimizer
from repro.core.topology import make_topology
from repro.core.trainer import CollaborativeTrainer
from repro.kernels.consensus_update import topk as tk
from repro.kernels.consensus_update.consensus_update import (
    cdadam_update_2d,
    cdadam_update_sparse_2d,
    cdmsgd_nesterov_update_2d,
    cdmsgd_nesterov_update_sparse_2d,
    cdmsgd_update_2d,
    cdmsgd_update_sparse_2d,
    cdsgd_update_2d,
    cdsgd_update_sparse_2d,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
N_AGENTS = 4

# the dense kernel FMA-contracts its accumulate; the scatter-add form
# cannot, so equality is a few ULP at f32 — far inside the 1e-5 criterion
KERNEL_ATOL = 1e-6
FAMILIES = ("cdsgd", "cdmsgd", "cdmsgd_nesterov", "cdadam")


def _wire(rows, k_rows, n_nbr=2, seed=0):
    """n_nbr compressed neighbor payloads + self/grad/momentum buffers."""
    key = jax.random.PRNGKey(seed)
    wires = [tk.topk_compress_2d(
        jax.random.normal(jax.random.fold_in(key, i), (rows, 128),
                          jnp.float32), k_rows, jnp.int32(i), interpret=True)
        for i in range(n_nbr)]
    vals = jnp.stack([w[0] for w in wires])
    idx = jnp.stack([w[1] for w in wires])
    scs = jnp.stack([w[2] for w in wires])
    mk = lambda j: jax.random.normal(jax.random.fold_in(key, 100 + j),
                                     (rows, 128), jnp.float32)
    return vals, idx, scs, mk(0), mk(1), mk(2), mk(3)


def _dense_nbrs(vals, idx, scs, rows):
    nb = jnp.stack([tk.topk_decompress_2d(vals[i], idx[i], scs[i], rows)
                    for i in range(vals.shape[0])])
    unit = jnp.ones(nb.shape[:2] + (1,), jnp.float32)
    return nb, unit


@pytest.mark.parametrize("family", FAMILIES)
def test_sparse_kernel_matches_dense_oracle(family):
    """Same compressed payloads through both operand forms, every output
    buffer (params AND momentum/moment/lookahead) within KERNEL_ATOL."""
    rows, k_rows = 12, 2
    vals, idx, scs, slf, g, mom, v2 = _wire(rows, k_rows)
    w = jnp.array([0.5, 0.25, 0.25], jnp.float32)
    nb, unit = _dense_nbrs(vals, idx, scs, rows)
    kw = dict(self_buf=slf, interpret=True)
    if family == "cdsgd":
        dense = (cdsgd_update_2d(nb, w, g, 0.05, scales=unit, **kw),)
        sparse = (cdsgd_update_sparse_2d(vals, idx, scs, w, g, 0.05, **kw),)
    elif family == "cdmsgd":
        dense = cdmsgd_update_2d(nb, w, g, mom, 0.05, 0.9, scales=unit, **kw)
        sparse = cdmsgd_update_sparse_2d(vals, idx, scs, w, g, mom, 0.05,
                                         0.9, **kw)
    elif family == "cdmsgd_nesterov":
        dense = cdmsgd_nesterov_update_2d(nb, w, g, mom, 0.05, 0.9,
                                          scales=unit, **kw)
        sparse = cdmsgd_nesterov_update_sparse_2d(vals, idx, scs, w, g, mom,
                                                  0.05, 0.9, **kw)
    else:
        scal = (0.05, 0.9, 0.999, 1e-8, 0.1, 0.001)
        dense = cdadam_update_2d(nb, w, g, mom, v2, *scal, scales=unit, **kw)
        sparse = cdadam_update_sparse_2d(vals, idx, scs, w, g, mom, v2,
                                         *scal, **kw)
    assert len(dense) == len(sparse)
    for a, b in zip(dense, sparse):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=KERNEL_ATOL, rtol=0)


def test_sparse_kernel_vmapped_stacked_agents():
    """The stacked (vmapped) form the trainer runs: per-agent self/grad
    against one shared compact stack, parity with the per-agent dense
    calls — and the vmap does NOT silently rebind the grid (the per-block
    row0 operand idiom)."""
    rows, k_rows, A = 10, 1, 3
    vals, idx, scs, *_ = _wire(rows, k_rows)
    key = jax.random.PRNGKey(9)
    slf = jax.random.normal(key, (A, rows, 128), jnp.float32)
    g = jax.random.normal(jax.random.fold_in(key, 1), (A, rows, 128),
                          jnp.float32)
    w = jnp.tile(jnp.array([0.5, 0.25, 0.25], jnp.float32)[None], (A, 1))
    out = jax.vmap(lambda wi, si, gi: cdsgd_update_sparse_2d(
        vals, idx, scs, wi, gi, 0.05, self_buf=si, block_rows=4,
        interpret=True))(w, slf, g)
    nb, unit = _dense_nbrs(vals, idx, scs, rows)
    for a in range(A):
        ref = cdsgd_update_2d(nb, w[a], g[a], 0.05, scales=unit,
                              self_buf=slf[a], block_rows=4, interpret=True)
        np.testing.assert_allclose(np.asarray(out[a]), np.asarray(ref),
                                   atol=2 * KERNEL_ATOL, rtol=0)


# -------------------------------------------------------------------------
# trainer-level parity: sparse_update on vs off, stacked, every family
# -------------------------------------------------------------------------


def _testbed():
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.standard_normal((40, 128)), jnp.float32),
              "b": jnp.asarray(rng.standard_normal((70,)), jnp.float32)}
    topo = make_topology("ring", N_AGENTS)

    def loss(p, b):
        return 0.5 * (jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)), {}

    batch = {"x": jnp.zeros((N_AGENTS, 1), jnp.float32)}
    return params, topo, loss, batch


def _opt(family):
    kw = {"fused": True}
    if family in ("cdmsgd", "cdmsgd_nesterov"):
        kw["mu"] = 0.9
    return make_optimizer(family, 0.01, **kw)


@pytest.mark.parametrize("schedule", ["sync", "overlap"])
@pytest.mark.parametrize("family", FAMILIES)
def test_trainer_sparse_dense_parity(family, schedule):
    """The acceptance criterion: sparse-vs-dense parity within 1e-5 on
    every family supporting top-k, both exchange schedules, 3 steps.
    (3, not more: the trajectories are compared THROUGH the top-k
    selection, whose argmax ties eventually flip on ULP differences —
    per-step kernel parity stays at ~1e-7.)"""
    params, topo, loss, batch = _testbed()

    def run(sparse):
        tr = CollaborativeTrainer(loss, params, topo, _opt(family),
                                  schedule=schedule, error_feedback=True,
                                  compressor="topk:0.1",
                                  sparse_update=sparse, donate=False)
        assert tr.program.sparse_update is sparse
        for _ in range(3):
            m = tr.step(batch)
        return tr.state.params, m["loss"]

    (p_s, l_s), (p_d, l_d) = run(True), run(False)
    assert np.isclose(l_s, l_d, rtol=1e-5), (l_s, l_d)
    d = max(float(jnp.max(jnp.abs(a - b))) for a, b in
            zip(jax.tree.leaves(p_s), jax.tree.leaves(p_d)))
    assert d < 1e-5, (family, schedule, d)


def test_trainer_topk_auto_parity_and_budget():
    """topk:auto:B end-to-end: the sparse/dense parity holds under the
    adaptive densities, and the byte budget is met within one lane row
    per bucket — counted from the ACTUAL carried overlap buffers."""
    params, topo, loss, batch = _testbed()
    budget = 6500                     # 2 buckets (40-row w, 1-row b)

    def run(sparse):
        tr = CollaborativeTrainer(loss, params, topo, _opt("cdsgd"),
                                  schedule="overlap", error_feedback=True,
                                  compressor=f"topk:auto:{budget}",
                                  sparse_update=sparse, donate=False)
        for _ in range(3):
            tr.step(batch)
        return tr

    tr = run(True)
    spec = flatbuf.make_flat_spec(tr.state.params, lead=1)
    actual = engine.wire_bytes_per_neighbor(tr.state.opt_state.wire)
    assert actual == C.program_bytes_per_neighbor(spec, tr.program)
    assert actual == tr.comm.flat.strategy.bytes_per_neighbor(spec)
    assert actual <= budget
    # within one lane row per bucket of the budget (no bucket saturated
    # at this budget except the single-row one, which cannot grow)
    assert budget - actual < spec.n_buckets * tk.TOPK_LANE_ROW_BYTES, (
        actual, budget)
    tr_d = run(False)
    d = max(float(jnp.max(jnp.abs(a - b))) for a, b in
            zip(jax.tree.leaves(tr.state.params),
                jax.tree.leaves(tr_d.state.params)))
    assert d < 1e-5, d


# -------------------------------------------------------------------------
# topk:auto:B — parser + solver
# -------------------------------------------------------------------------


def test_parse_compressor_topk_auto():
    assert C.parse_compressor("topk:auto:65536") == ("topk", ("auto", 65536))
    for bad in ("topk:auto", "topk:auto:", "topk:auto:x", "topk:auto:0",
                "topk:auto:-1", "topk:auto:1.5"):
        with pytest.raises(ValueError):
            C.parse_compressor(bad)


def test_topk_auto_solver_budget_and_floors():
    lane = tk.TOPK_LANE_ROW_BYTES
    # exactly the floor: one compact row per bucket
    assert tk.topk_auto_k_rows([40, 1, 7], 3 * lane) == [1, 1, 1]
    # below the floor: actionable error
    with pytest.raises(ValueError, match="bucket"):
        tk.topk_auto_k_rows([40, 1], lane)
    # saturation: a huge budget caps every bucket at its own rows
    assert tk.topk_auto_k_rows([4, 1], 10_000 * lane) == [4, 1]
    # mid budget: spend everything affordable, never exceed it, and leave
    # less than one lane row unspent (unless every bucket is saturated)
    for rows in ([40, 1], [64, 64], [7, 3, 90]):
        for budget in (len(rows) * lane + 17, 6500, 20_000):
            k = tk.topk_auto_k_rows(rows, budget)
            assert all(1 <= ki <= ri for ki, ri in zip(k, rows))
            spent = sum(k) * lane
            assert spent <= budget
            if any(ki < ri for ki, ri in zip(k, rows)):
                assert budget - spent < lane, (rows, budget, k)


def test_topk_auto_proportional_to_rows():
    """Bigger buckets get more compact rows (proportional fill)."""
    k = tk.topk_auto_k_rows([90, 10], 20 * tk.TOPK_LANE_ROW_BYTES)
    assert k[0] > k[1] and sum(k) == 20


def test_topk_k_rows_for_dispatches_both_forms():
    rows = [40, 1]
    assert tk.topk_k_rows_for(rows, 0.1) == [tk.topk_k_rows(40, 0.1),
                                             tk.topk_k_rows(1, 0.1)]
    auto = tk.topk_k_rows_for(rows, ("auto", 6500))
    assert auto == tk.topk_auto_k_rows(rows, 6500)


def test_describe_exchange_cost_prints_auto_densities():
    params, topo, loss, _ = _testbed()
    line = C.describe_exchange_cost(
        jax.tree.map(lambda x: x[None], params), topo, "int8",
        program=C.make_mixing_program(topo, compressor="topk:auto:6500",
                                      error_feedback=True))
    assert "auto per-bucket p=[" in line, line


# -------------------------------------------------------------------------
# the sparse_update knob
# -------------------------------------------------------------------------


def test_sparse_update_defaults_and_describe():
    topo = make_topology("ring", N_AGENTS)
    p = C.make_mixing_program(topo, compressor="topk:0.1",
                              error_feedback=True)
    assert p.sparse_update is True          # default-on for top-k
    assert p.describe()["sparse_update"] is True
    p_off = C.make_mixing_program(topo, compressor="topk:0.1",
                                  error_feedback=True, sparse_update=False)
    assert p_off.sparse_update is False
    for comp in ("none", "int8", "rank:2"):
        kw = {"error_feedback": True} if comp.startswith("rank") else {}
        assert not C.make_mixing_program(
            topo, compressor=comp, **kw).sparse_update


@pytest.mark.parametrize("comp", ["none", "int8", "fp8", "rank:2"])
def test_sparse_update_rejects_non_topk(comp):
    topo = make_topology("ring", N_AGENTS)
    kw = {"error_feedback": True} if comp.startswith("rank") else {}
    with pytest.raises(ValueError, match="sparse_update"):
        C.make_mixing_program(topo, compressor=comp, sparse_update=True,
                              **kw)


# -------------------------------------------------------------------------
# consensus_update_cost: the analytic dense/sparse pricing
# -------------------------------------------------------------------------


def test_consensus_update_cost_prices_both_forms():
    from repro.analysis.roofline import consensus_update_cost
    params, topo, loss, _ = _testbed()
    spec = flatbuf.make_flat_spec(params)
    prog = C.make_mixing_program(topo, compressor="topk:0.1",
                                 error_feedback=True)
    cost = consensus_update_cost(spec, prog, topo.degree())
    assert len(cost["per_bucket"]) == spec.n_buckets
    for pb, b in zip(cost["per_bucket"], spec.buckets):
        assert pb["k_rows"] == tk.topk_k_rows(b.rows, 0.1)
        assert pb["sparse_bytes"] < pb["dense_bytes"]
        assert pb["sparse_flops"] < pb["dense_flops"]
    assert cost["bytes_ratio"] > 1.0 and cost["flops_ratio"] > 1.0
    # the dense form's extra traffic is exactly the decompressed-neighbor
    # write+read: 2 * 4 bytes * rows * 128 per neighbor per bucket
    extra = sum(2 * 4 * b.n_padded for b in spec.buckets) * topo.degree()
    assert cost["dense_bytes"] - cost["sparse_bytes"] == extra
    with pytest.raises(ValueError, match="top-k"):
        consensus_update_cost(spec, C.make_mixing_program(topo), 2)


# -------------------------------------------------------------------------
# top-k kernel edge cases (satellite)
# -------------------------------------------------------------------------


def test_topk_compress_all_zero_bucket():
    """An all-zero bucket still yields a valid payload: in-range unique
    indices, finite scales, and a decompress of exact zeros."""
    v, i, s = tk.topk_compress_2d(jnp.zeros((4, 128), jnp.float32), 1,
                                  jnp.int32(3), interpret=True)
    idx = np.asarray(i).ravel()
    assert np.all((idx >= 0) & (idx < 4 * 128)) and len(set(idx)) == 128
    assert np.all(np.isfinite(np.asarray(s)))
    dense = tk.topk_decompress_2d(v, i, s, 4)
    np.testing.assert_array_equal(np.asarray(dense), 0.0)


def test_topk_k_rows_clamps_small_p():
    """p * rows * 128 < 128 clamps to one compact lane row."""
    assert tk.topk_k_rows(4, 1e-6) == 1
    assert tk.topk_k_rows(1, 0.001) == 1
    assert tk.topk_k_rows(100, 0.001) == 1   # ceil(12.8) = 13 -> 1 row


def test_topk_threshold_ties():
    """All-equal magnitudes: every bin threshold ties.  The bracketing
    still terminates and compression still emits exactly k_rows * 128
    unique in-range indices (deterministic tie-break)."""
    x = jnp.ones((4, 128), jnp.float32)
    tau, counts = tk.topk_threshold_2d(x, 128, interpret=True)
    assert np.isfinite(float(tau))
    v, i, s = tk.topk_compress_2d(x, 2, jnp.int32(0), interpret=True)
    idx = np.asarray(i).ravel()
    assert len(np.unique(idx)) == 2 * 128
    assert np.all((idx >= 0) & (idx < 4 * 128))
    dense = tk.topk_decompress_2d(v, i, s, 4)
    on = np.asarray(dense).ravel()[idx]
    assert np.all(np.abs(on - 1.0) <= np.repeat(np.asarray(s).ravel(), 128)
                  + 1e-7)


def test_topk_single_row_bucket():
    """rows = 1: compress is a (1, 128) identity-support payload and the
    sparse kernel consumes it (k_rows == rows == 1)."""
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (1, 128), jnp.float32)
    v, i, s = tk.topk_compress_2d(x, 1, jnp.int32(0), interpret=True)
    np.testing.assert_array_equal(np.asarray(i).ravel(), np.arange(128))
    dense = tk.topk_decompress_2d(v, i, s, 1)
    assert float(jnp.max(jnp.abs(dense - x))) <= float(jnp.max(s)) + 1e-7
    # and straight into the sparse kernel
    g = jax.random.normal(jax.random.fold_in(key, 1), (1, 128), jnp.float32)
    slf = jax.random.normal(jax.random.fold_in(key, 2), (1, 128),
                            jnp.float32)
    w = jnp.array([0.5, 0.5], jnp.float32)
    out = cdsgd_update_sparse_2d(v[None], i[None], s[None], w, g, 0.05,
                                 self_buf=slf, interpret=True)
    ref = cdsgd_update_2d(dense[None], w, g, 0.05,
                          scales=jnp.ones((1, 1, 1), jnp.float32),
                          self_buf=slf, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=KERNEL_ATOL, rtol=0)


# -------------------------------------------------------------------------
# sharded twin (subprocess, 8 host devices)
# -------------------------------------------------------------------------


def run_sub(code: str, timeout=560) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-4000:]}"
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


@pytest.mark.slow
def test_sharded_sparse_dense_parity_every_family():
    """The sharded acceptance twin: on the agent-only mesh the ppermuted
    TopKWire fields feed the sparse kernels unchanged — parity with the
    dense-decompress reference within 1e-5 for EVERY top-k family under
    overlap, with every ppermute still carried-only.

    2 steps per family except cdadam's 1: with near-zero second moment
    the Adam preconditioner amplifies the dense kernel's few-ULP FMA
    contraction through the next step's top-k selection (measured:
    1.2e-7 at step 1, trajectory flip at step 2) — per-step kernel
    parity is the invariant, and it holds for all four families."""
    res = run_sub(textwrap.dedent("""
        import dataclasses, json
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.core import engine
        from repro.core.optim import make_optimizer
        from repro.launch.mesh import make_debug_mesh
        from repro.launch import steps as steps_lib
        from repro.nn.param import init_params

        cfg = dataclasses.replace(get_config("granite-3-8b").reduced(),
                                  param_dtype="float32")
        shape = InputShape("tiny_train", 16, 8, "train")
        batch = {"inputs": jnp.ones((4, 2, 16), jnp.int32),
                 "targets": jnp.ones((4, 2, 16), jnp.int32)}
        mesh = make_debug_mesh(4, 1)
        out = {}
        for family in ("cdsgd", "cdmsgd", "cdmsgd_nesterov", "cdadam"):
            kw = {"fused": True}
            if family in ("cdmsgd", "cdmsgd_nesterov"):
                kw["mu"] = 0.9
            nsteps = 1 if family == "cdadam" else 2
            ps = {}
            for sparse in (True, False):
                b = steps_lib.build_train_step(
                    cfg, shape, mesh, make_optimizer(family, 0.005, **kw),
                    mode="train", topology_name="ring",
                    mixing="ppermute_fused", schedule="overlap",
                    error_feedback=True, compressor="topk:0.1",
                    sparse_update=sparse)
                p = init_params(b.param_template, jax.random.PRNGKey(0))
                with mesh:
                    s = b.init_state(p)
                    if sparse:
                        out[family + "_report"] = (
                            engine.exchange_dependency_report(
                                b.step_fn, p, s, batch))
                    step = jax.jit(b.step_fn)
                    for _ in range(nsteps):
                        p, s, m = step(p, s, batch)
                ps[sparse] = p
            out[family + "_maxdiff"] = max(
                float(jnp.max(jnp.abs(a - bb))) for a, bb in
                zip(jax.tree.leaves(ps[True]), jax.tree.leaves(ps[False])))
        print("RESULT " + json.dumps(out))
    """), timeout=840)
    for family in FAMILIES:
        assert res[family + "_maxdiff"] < 1e-5, (family, res)
        rep = res[family + "_report"]
        # 2 ring shifts x 3 TopKWire fields, every one carried-only
        assert rep["n_ppermutes"] == 6, (family, rep)
        assert rep["n_ppermutes_carried_only"] == 6, (family, rep)
        assert rep["off_grad_update_critical_path"], (family, rep)


@pytest.mark.slow
def test_dryrun_records_update_cost(tmp_path):
    """launch/dryrun.py prices the update next to exchange_bytes_per_step
    (agent-only mesh; the production mesh skips compressed wires) and the
    cost line prints the adaptive per-bucket densities."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = textwrap.dedent(f"""
        from repro.launch import mesh as mesh_lib
        mesh_lib.make_production_mesh = (
            lambda *, multi_pod=False: mesh_lib.make_debug_mesh(4, 1))
        from repro.launch import dryrun
        dryrun.run_pair("gemma3-1b", "train_4k", mixing="ppermute_fused",
                        optimizer_name="cdsgd", fused=True,
                        schedule="overlap", error_feedback=True,
                        compressor="topk:auto:65536",
                        out_dir={str(tmp_path)!r}, analyze=False)
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=560, env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "auto per-bucket p=[" in out.stdout, out.stdout
    rec = json.loads(next(tmp_path.glob("*.json")).read_text())
    assert "exchange_bytes_per_step" in rec
    uc = rec["update_cost"]
    assert uc["sparse_update"] is True
    assert uc["sparse_bytes"] < uc["dense_bytes"]
    assert uc["sparse_flops"] < uc["dense_flops"]
    assert all(pb["k_rows"] >= 1 for pb in uc["per_bucket"])
