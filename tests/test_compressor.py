"""ISSUE-7: the ``compressor=`` axis — top-k sparse + rank-r wire payloads
on the EF rail (see the "Compressor axis" section of ARCHITECTURE.md).

Covers:
* the single ``parse_compressor`` parser + the full ``make_mixing_program``
  option matrix (every rejection is actionable: names the conflicting
  flags AND a supported alternative),
* the Pallas top-k threshold kernel (one-sweep k-th-magnitude bracketing)
  and the exact compress/decompress round trip,
* the rank-r power-iteration compressor (exact on rank-r inputs,
  orthonormal warm-start basis),
* ``compressor="int8"`` as a bit-for-bit alias of the existing dense
  quantized path (sync AND overlap; the sharded twin lives in the
  subprocess test below),
* wire-byte accounting == the actual carried buffers at every layer,
* checkpoint round-trips of the compressed OptState (wire + residual +
  rank warm-start basis) bit-exact,
* sharded overlap + topk: every ppermute carried-only
  (``exchange_dependency_report``), agent-axis-only sharding enforced.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import consensus as C
from repro.core import engine, flatbuf
from repro.core.optim import CDSGD
from repro.core.topology import make_topology
from repro.core.trainer import CollaborativeTrainer, TrainState
from repro.kernels.consensus_update.topk import (
    rank_compress_2d,
    rank_decompress_2d,
    rank_init_q,
    topk_compress_2d,
    topk_decompress_2d,
    topk_k_rows,
    topk_threshold_2d,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
N_AGENTS = 4


# -------------------------------------------------------------------------
# parse_compressor + the make_mixing_program option matrix (satellite)
# -------------------------------------------------------------------------


@pytest.mark.parametrize("spec,kind,param", [
    ("none", "none", None),
    ("int8", "int8", None),
    ("fp8", "fp8", None),
    ("topk:0.01", "topk", 0.01),
    ("topk:1", "topk", 1.0),
    ("rank:1", "rank", 1),
    ("rank:16", "rank", 16),
])
def test_parse_compressor_valid(spec, kind, param):
    assert C.parse_compressor(spec) == (kind, param)


@pytest.mark.parametrize("spec", [
    "gzip", "topk", "rank", "topk:0", "topk:1.5", "topk:x", "rank:0",
    "rank:-1", "rank:1.5", "int8:4", "none:1",
])
def test_parse_compressor_rejects(spec):
    with pytest.raises(ValueError):
        C.parse_compressor(spec)


def _expected_program_ok(compressor, error_feedback, exchange, staleness,
                         rounds, momentum_mixing):
    """The documented validity rules, mirrored (ARCHITECTURE.md table)."""
    kind, _ = C.parse_compressor(compressor)
    if kind in ("int8", "fp8") and exchange not in ("f32", kind):
        return False
    eff_exchange = kind if kind in ("int8", "fp8") else exchange
    if kind in ("topk", "rank"):
        if not error_feedback:
            return False
        if staleness > 1 or rounds > 1 or momentum_mixing != "none":
            return False
        if kind == "topk" and exchange not in ("f32", "int8"):
            return False
        if kind == "rank" and exchange != "f32":
            return False
    elif error_feedback:
        if eff_exchange not in ("int8", "fp8"):
            return False            # dense f32 wire has no error to carry
        if staleness > 1:
            return False            # EF needs the one-step-stale contract
    return True


@pytest.mark.parametrize("compressor", ["none", "int8", "fp8", "topk:0.1",
                                        "rank:2"])
@pytest.mark.parametrize("error_feedback", [False, True])
@pytest.mark.parametrize("exchange", ["f32", "int8"])
@pytest.mark.parametrize("staleness,rounds,momentum_mixing", [
    (1, 1, "none"), (2, 1, "none"), (1, 3, "none"), (1, 1, "mixed"),
])
def test_make_mixing_program_option_matrix(compressor, error_feedback,
                                           exchange, staleness, rounds,
                                           momentum_mixing):
    """The full config matrix: every combination either builds a program
    with the documented normalizations, or raises an ACTIONABLE ValueError
    (names the conflicting flag and offers an alternative)."""
    topo = make_topology("ring", N_AGENTS)
    kw = dict(compressor=compressor, error_feedback=error_feedback,
              exchange=exchange, staleness=staleness, rounds=rounds,
              momentum_mixing=momentum_mixing)
    ok = _expected_program_ok(compressor, error_feedback, exchange,
                              staleness, rounds, momentum_mixing)
    if ok:
        prog = C.make_mixing_program(topo, **kw)
        kind, _ = C.parse_compressor(compressor)
        # the documented exchange normalizations
        if kind in ("int8", "fp8"):
            assert prog.exchange == kind
        elif kind == "topk":
            assert prog.exchange == "int8"
        elif kind == "rank":
            assert prog.exchange == "f32"
        assert prog.compressed == (kind in ("topk", "rank"))
    else:
        with pytest.raises(ValueError) as ei:
            C.make_mixing_program(topo, **kw)
        msg = str(ei.value)
        # actionable: names a flag and points at an alternative
        assert "--" in msg, msg
        assert any(w in msg for w in ("use", "drop", "add", "set")), msg


def test_compressed_program_rejects_faults():
    from repro.core.faults import make_fault_schedule
    topo = make_topology("ring", N_AGENTS)
    fs = make_fault_schedule("drop:0:2", topo.n_agents)
    with pytest.raises(ValueError, match="staleness|fault"):
        C.make_mixing_program(topo, compressor="topk:0.1",
                              error_feedback=True, faults=fs)


# -------------------------------------------------------------------------
# top-k kernel: k_rows math, threshold bracketing, round trip
# -------------------------------------------------------------------------


@pytest.mark.parametrize("rows,p,want", [
    (6, 0.25, 2),      # ceil(0.25*768)=192 -> 2 lanes-rows
    (6, 1.0, 6),
    (6, 1e-6, 1),      # floor: at least one compact row
    (100, 0.01, 1),    # ceil(128)=128 -> 1 row
    (100, 0.5, 50),
])
def test_topk_k_rows_lane_aligned(rows, p, want):
    assert topk_k_rows(rows, p) == want
    assert 1 <= topk_k_rows(rows, p) <= rows


def test_topk_threshold_brackets_kth_magnitude():
    """The one-sweep Pallas histogram brackets the k-th largest magnitude:
    tau selects <= k elements and the true k-th magnitude sits within one
    geometric bin below tau."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((24, 128)), jnp.float32)
    n_bins, span = 16, 1e-4
    for k in (1, 50, 700, 24 * 128):
        tau, counts = topk_threshold_2d(x, k, n_bins=n_bins, span=span,
                                        interpret=True)
        tau = float(tau)
        a = np.abs(np.asarray(x)).ravel()
        kth = np.sort(a)[::-1][k - 1]
        assert np.sum(a >= tau) <= k
        assert tau >= kth or np.isclose(tau, kth, rtol=1e-6)
        # ...but never more than one geometric bin above it
        assert tau * span ** (1.0 / (n_bins - 1)) <= kth + 1e-12, (tau, kth)
        # histogram sanity: counts nondecreasing as thresholds shrink
        c = np.asarray(counts)
        assert np.all(np.diff(c) >= 0)


def test_topk_threshold_all_zero_bucket():
    tau, counts = topk_threshold_2d(jnp.zeros((4, 128), jnp.float32), 8,
                                    interpret=True)
    assert float(counts[-1]) == 0.0 and float(tau) > 0.0


def test_topk_compress_roundtrip():
    """Exact selection + SR-int8 values: the decompressed bucket is zero
    off-support, within one quantization step on-support, and the indices
    are the true top-K magnitudes (sorted, unique, in range)."""
    rng = np.random.default_rng(1)
    rows, k_rows = 6, 2
    x = jnp.asarray(rng.standard_normal((rows, 128)), jnp.float32)
    v, i, s = topk_compress_2d(x, k_rows, jnp.int32(7), interpret=True)
    assert v.shape == (k_rows, 128) and v.dtype == jnp.int8
    assert i.shape == (k_rows, 128) and i.dtype == jnp.int32
    assert s.shape == (k_rows, 1) and s.dtype == jnp.float32

    idx = np.asarray(i).ravel()
    assert np.all(np.diff(idx) > 0)                     # sorted, unique
    a = np.abs(np.asarray(x)).ravel()
    want = np.sort(np.argsort(a)[::-1][: k_rows * 128])
    np.testing.assert_array_equal(idx, want)            # exact top-K support

    dense = topk_decompress_2d(v, i, s, rows)
    d = np.asarray(dense).ravel()
    xf = np.asarray(x).ravel()
    off = np.ones(rows * 128, bool)
    off[idx] = False
    assert np.all(d[off] == 0.0)
    # SR int8 with per-row scales: |deq - x| <= scale (one quant step)
    step = np.repeat(np.asarray(s).ravel(), 128)
    assert np.all(np.abs(d[idx] - xf[idx]) <= step + 1e-7)


def test_topk_full_density_is_identity_support():
    """p = 1 keeps every element (the compact payload IS the bucket)."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((3, 128)), jnp.float32)
    v, i, s = topk_compress_2d(x, 3, jnp.int32(0), interpret=True)
    np.testing.assert_array_equal(np.asarray(i).ravel(),
                                  np.arange(3 * 128))
    dense = topk_decompress_2d(v, i, s, 3)
    assert float(jnp.max(jnp.abs(dense - x))) <= float(jnp.max(s)) + 1e-7


# -------------------------------------------------------------------------
# rank-r power-iteration compressor
# -------------------------------------------------------------------------


def test_rank_compressor_exact_on_rank_r():
    """One warm-started power iteration per call: on an exactly rank-r
    matrix the second call reconstructs it to fp accuracy, and the carried
    basis stays orthonormal."""
    rng = np.random.default_rng(3)
    r = 3
    m = jnp.asarray(rng.standard_normal((40, r)) @
                    rng.standard_normal((r, 128)), jnp.float32)
    q = rank_init_q(r)
    assert q.shape == (128, r)
    p1, qt1, q2 = rank_compress_2d(m, q)
    p2, qt2, q3 = rank_compress_2d(m, q2)
    assert p2.shape == (40, r) and qt2.shape == (r, 128)
    scale = float(jnp.max(jnp.abs(m)))
    err = float(jnp.max(jnp.abs(rank_decompress_2d(p2, qt2) - m)))
    assert err < 1e-3 * scale, err
    np.testing.assert_allclose(np.asarray(q3.T @ q3), np.eye(r), atol=1e-4)


def test_rank_init_q_deterministic_orthonormal():
    q = rank_init_q(4)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(rank_init_q(4)))
    np.testing.assert_allclose(np.asarray(q.T @ q), np.eye(4), atol=1e-5)


# -------------------------------------------------------------------------
# trainer-level: int8 alias parity, accounting, checkpoint round trip
# -------------------------------------------------------------------------


def _testbed():
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.standard_normal((40, 128)), jnp.float32),
              "b": jnp.asarray(rng.standard_normal((70,)), jnp.float32)}
    topo = make_topology("ring", N_AGENTS)

    def loss(p, b):
        return 0.5 * (jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)), {}

    batch = {"x": jnp.zeros((N_AGENTS, 1), jnp.float32)}
    return params, topo, loss, batch


@pytest.mark.parametrize("schedule", ["sync", "overlap"])
def test_compressor_int8_alias_bit_for_bit(schedule):
    """compressor="int8" IS the existing exchange="int8" path — identical
    trajectories bit-for-bit under both exchange schedules."""
    params, topo, loss, batch = _testbed()

    def run(**kw):
        tr = CollaborativeTrainer(loss, params, topo, CDSGD(0.01, fused=True),
                                  schedule=schedule, donate=False, **kw)
        for _ in range(3):
            tr.step(batch)
        return tr.state.params

    a = run(exchange="int8")
    b = run(compressor="int8")
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("compressor", ["topk:0.1", "rank:2"])
def test_compressed_accounting_matches_actual_buffers(compressor):
    """Satellite: ONE source of wire-byte truth.  The strategy's
    bytes_per_neighbor == program_bytes_per_neighbor == the bytes counted
    from the actual carried overlap payloads; the trainer multiplies by
    the topology degree."""
    params, topo, loss, batch = _testbed()
    tr = CollaborativeTrainer(loss, params, topo, CDSGD(0.01, fused=True),
                              schedule="overlap", error_feedback=True,
                              compressor=compressor, donate=False)
    spec = flatbuf.make_flat_spec(tr.state.params, lead=1)
    actual = engine.wire_bytes_per_neighbor(tr.state.opt_state.wire)
    assert actual == tr.comm.flat.strategy.bytes_per_neighbor(spec)
    assert actual == C.program_bytes_per_neighbor(spec, tr.program)
    assert tr.wire_bytes_per_step == actual * topo.degree()
    # and compression actually compresses vs the dense f32 wire
    assert actual < spec.exchange_bytes("f32")


@pytest.mark.parametrize("compressor", ["topk:0.25", "rank:2"])
def test_train_state_roundtrip_compressed_bit_exact(tmp_path, compressor):
    """The compressed OptState — TopKWire/RankWire payloads, EF residuals
    AND the rank warm-start basis — checkpoints and resumes bit-exact."""
    from repro.checkpoint import restore_train_state, save_train_state
    params, topo, loss, batch = _testbed()

    def make():
        return CollaborativeTrainer(loss, params, topo,
                                    CDSGD(0.01, fused=True),
                                    schedule="overlap", error_feedback=True,
                                    compressor=compressor, donate=False)

    tr = make()
    if compressor.startswith("rank"):
        assert len(tr.state.opt_state.qwarm) > 0
    for _ in range(3):
        tr.step(batch)
    d = str(tmp_path / "ckpt")
    save_train_state(d, tr.state.step, tr.state.params, tr.state.opt_state)

    tr2 = make()                     # fresh wire/residual/qwarm state ...
    p0, o0 = restore_train_state(d, tr2.state.params, tr2.state.opt_state)
    tr2.state = TrainState(params=p0, opt_state=o0, step=int(o0.step))
    for name in ("wire", "residual", "qwarm"):
        for a, b in zip(jax.tree.leaves(getattr(tr.state.opt_state, name)),
                        jax.tree.leaves(getattr(o0, name))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    m1, m2 = tr.step(batch), tr2.step(batch)
    assert m1["loss"] == m2["loss"]
    for a, b in zip(jax.tree.leaves(tr.state.params),
                    jax.tree.leaves(tr2.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ef_topk_tracks_f32_closer_than_noef():
    """The EF rationale, measured: at equal density the EF run's drift off
    the f32 trajectory is strictly below the (config-forbidden, driven
    through the engine directly) no-EF run's."""
    import dataclasses

    from repro.core.optim import stacked_comm_ops
    params, topo, loss, batch = _testbed()
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (N_AGENTS,) + x.shape) + 0.0,
        params)

    def drift(program):
        opt = CDSGD(0.01, fused=True)
        comm = stacked_comm_ops(topo, interpret=True,
                                exchange=program.exchange, program=program)
        sp = engine.StepProgram(
            optimizer=opt, comm=comm,
            grad_phase=engine.make_grad_phase(loss, 1),
            update_phase=engine.make_update_phase(opt, comm, "overlap"),
            schedule="overlap")
        state = sp.init_state(stacked)
        step = jax.jit(sp.step_fn)
        p = stacked
        for _ in range(10):
            p, state, _ = step(p, state, batch)
        return max(float(jnp.max(jnp.abs(a - b))) for a, b in
                   zip(jax.tree.leaves(p), jax.tree.leaves(ref)))

    ref_prog = C.make_mixing_program(topo)           # dense f32 reference
    opt = CDSGD(0.01, fused=True)
    comm = stacked_comm_ops(topo, interpret=True, program=ref_prog)
    sp = engine.StepProgram(
        optimizer=opt, comm=comm,
        grad_phase=engine.make_grad_phase(loss, 1),
        update_phase=engine.make_update_phase(opt, comm, "overlap"),
        schedule="overlap")
    state = sp.init_state(stacked)
    step = jax.jit(sp.step_fn)
    ref = stacked
    for _ in range(10):
        ref, state, _ = step(ref, state, batch)

    ef_prog = C.make_mixing_program(topo, compressor="topk:0.1",
                                    error_feedback=True)
    noef_prog = dataclasses.replace(ef_prog, error_feedback=False)
    assert drift(ef_prog) < drift(noef_prog)


# -------------------------------------------------------------------------
# lyapunov: the EF-delta radius inflation
# -------------------------------------------------------------------------


def test_ef_compressed_bound_reduces_and_orders():
    """delta = 0 for the SR wires (exact reduction to the uncompressed
    schedule bound); the biased compressors inflate the radius by
    (1 + 2 delta / (1 - delta)), monotone in delta."""
    from repro.core import lyapunov
    topo = make_topology("ring", N_AGENTS)
    assert lyapunov.compressor_delta("none") == 0.0
    assert lyapunov.compressor_delta("int8") == 0.0
    assert lyapunov.compressor_delta("fp8") == 0.0
    assert lyapunov.compressor_delta("topk:0.25") == pytest.approx(0.75)
    assert lyapunov.compressor_delta("rank:32") == pytest.approx(0.75)
    assert lyapunov.compressor_delta("rank:128") == 0.0

    base = lyapunov.ef_compressed_consensus_bound(0.01, 1.0, topo)
    for c in ("int8", "fp8"):
        assert lyapunov.ef_compressed_consensus_bound(
            0.01, 1.0, topo, compressor=c) == base
    b_half = lyapunov.ef_compressed_consensus_bound(
        0.01, 1.0, topo, compressor="topk:0.5")
    b_cent = lyapunov.ef_compressed_consensus_bound(
        0.01, 1.0, topo, compressor="topk:0.01")
    assert base < b_half < b_cent
    # the closed form: base x (1 + 2 delta / (1 - delta))
    assert b_half == pytest.approx(base * (1.0 + 2.0 * 0.5 / 0.5))


# -------------------------------------------------------------------------
# sharded execution (subprocess, 8 host devices — see tests/test_sharded.py)
# -------------------------------------------------------------------------


def run_sub(code: str, timeout=560) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-4000:]}"
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


@pytest.mark.slow
def test_sharded_compressed_overlap_carried_only_and_alias_parity():
    """The sharded compressed path: (a) overlap + topk keeps EVERY ppermute
    carried-only (the compressed exchange stays off the grad->update
    critical path); (b) compressor="int8" == exchange="int8" bit-for-bit
    on a model-sharded mesh; (c) compressed programs reject non-agent
    sharding with an actionable error."""
    res = run_sub(textwrap.dedent("""
        import dataclasses, json
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.core import engine
        from repro.core.optim import make_optimizer
        from repro.launch.mesh import make_debug_mesh
        from repro.launch import steps as steps_lib
        from repro.nn.param import init_params

        cfg = dataclasses.replace(get_config("granite-3-8b").reduced(),
                                  param_dtype="float32")
        shape = InputShape("tiny_train", 16, 8, "train")
        batch = {"inputs": jnp.ones((4, 2, 16), jnp.int32),
                 "targets": jnp.ones((4, 2, 16), jnp.int32)}
        out = {}

        # (a) agent-only mesh: overlap+topk, all collectives carried-only
        mesh = make_debug_mesh(4, 1)
        b = steps_lib.build_train_step(
            cfg, shape, mesh, make_optimizer("cdsgd", 0.005, fused=True),
            mode="train", topology_name="ring", mixing="ppermute_fused",
            schedule="overlap", error_feedback=True, compressor="topk:0.1")
        params = init_params(b.param_template, jax.random.PRNGKey(0))
        with mesh:
            state = b.init_state(params)
            out["topk_overlap"] = engine.exchange_dependency_report(
                b.step_fn, params, state, batch)
            p1, s1, m = jax.jit(b.step_fn)(params, state, batch)
        out["topk_run"] = {
            "finite": bool(all(jnp.all(jnp.isfinite(x)) for x in
                               jax.tree.leaves(p1))),
            "loss": float(m["loss"])}

        # (b) int8 alias parity on a model-sharded 4x2 mesh, 3 steps
        mesh2 = make_debug_mesh(4, 2)
        outs = {}
        for label, kw in (("exchange", dict(exchange="int8")),
                          ("compressor", dict(compressor="int8"))):
            b2 = steps_lib.build_train_step(
                cfg, shape, mesh2, make_optimizer("cdsgd", 0.005, fused=True),
                mode="train", topology_name="ring", mixing="ppermute_fused",
                schedule="overlap", **kw)
            p = init_params(b2.param_template, jax.random.PRNGKey(0))
            with mesh2:
                s = b2.init_state(p)
                step = jax.jit(b2.step_fn)
                for _ in range(3):
                    p, s, _ = step(p, s, batch)
            outs[label] = p
        out["alias_bit_for_bit"] = bool(all(
            bool(jnp.array_equal(a, bb)) for a, bb in
            zip(jax.tree.leaves(outs["exchange"]),
                jax.tree.leaves(outs["compressor"]))))

        # (c) compressed + non-agent sharding: actionable config error
        try:
            steps_lib.build_train_step(
                cfg, shape, mesh2, make_optimizer("cdsgd", 0.005, fused=True),
                mode="train", topology_name="ring", mixing="ppermute_fused",
                schedule="overlap", error_feedback=True,
                compressor="topk:0.1")
            out["reject"] = "NO ERROR"
        except ValueError as e:
            out["reject"] = str(e)
        print("RESULT " + json.dumps(out))
    """), timeout=840)
    rep = res["topk_overlap"]
    # 2 ring shifts x 3 TopKWire fields = 6 ppermutes, every one carried
    assert rep["n_ppermutes"] == 6, rep
    assert rep["n_ppermutes_carried_only"] == 6, rep
    assert rep["n_ppermutes_fresh"] == 0, rep
    assert rep["off_grad_update_critical_path"], rep
    assert res["topk_run"]["finite"]
    assert res["alias_bit_for_bit"]
    assert res["reject"] != "NO ERROR"
    assert "agent-only" in res["reject"] and "int8/fp8" in res["reject"]
