"""Flat-buffer fused consensus updates: pack/unpack + fused-vs-oracle parity.

The fused path must be semantics-preserving: every test pins a fused
whole-model update (one Pallas launch per dtype bucket) against either the
dense-``Pi`` stacked oracle (``mix_pytree_stacked``) or the unfused
reference optimizer, including odd leaf sizes (not a multiple of 128),
bf16 params with f32 accumulation, and momentum-state round-trips.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flatbuf
from repro.core.consensus import mix_pytree_stacked
from repro.core.optim import (
    CDSGD,
    CDMSGD,
    CDMSGDNesterov,
    CDAdam,
    stacked_comm_ops,
)
from repro.core.topology import make_topology
from repro.core.trainer import CollaborativeTrainer
from repro.kernels.consensus_update import ops as kops
from repro.kernels.consensus_update.consensus_update import (
    cdadam_update_2d,
    cdmsgd_nesterov_update_2d,
    sr_dequantize_2d,
    sr_quantize_2d,
)
from repro.kernels.consensus_update.ref import (
    cdadam_update_ref,
    cdmsgd_nesterov_update_ref,
)

KEY = jax.random.PRNGKey(0)


def tol_for_tree(tree):
    has_bf16 = any(x.dtype == jnp.bfloat16 for x in jax.tree.leaves(tree))
    return dict(rtol=2e-2, atol=2e-2) if has_bf16 else dict(rtol=3e-5, atol=3e-5)


def assert_trees_close(a, b, **tol):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la, np.float32),
                                   np.asarray(lb, np.float32), **tol)


def make_tree(lead=(), *, seed=0):
    """Mixed-dtype tree with odd (non-128-multiple) leaf sizes + a scalar."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    mk = lambda k, shape: jax.random.normal(k, tuple(lead) + shape)
    return {
        "w": mk(ks[0], (7, 9)),                              # 63 elems
        "b": mk(ks[1], (300,)),                              # odd, > 2 rows
        "h": mk(ks[2], (256,)).astype(jnp.bfloat16),         # aligned bf16
        "o": mk(ks[3], (130,)).astype(jnp.bfloat16),         # odd bf16
        "s": mk(ks[4], ()),                                  # scalar leaf
    }


# -------------------------------------------------------------------------
# pack / unpack
# -------------------------------------------------------------------------


@pytest.mark.parametrize("lead", [(), (4,)])
def test_pack_unpack_roundtrip(lead):
    tree = make_tree(lead)
    spec = flatbuf.make_flat_spec(tree, lead=len(lead))
    bufs = flatbuf.pack(tree, spec)
    assert spec.n_buckets == 2          # f32 + bf16
    for bucket, buf in zip(spec.buckets, bufs):
        assert buf.shape == tuple(lead) + (bucket.rows, flatbuf.LANE)
        assert buf.dtype == bucket.dtype
    back = flatbuf.unpack(bufs, spec)
    assert jax.tree.structure(back) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pack_casts_to_bucket_dtype():
    """f32 grads packed against a bf16 param spec land in bf16 (the unfused
    ``g.astype(param.dtype)`` semantics)."""
    params = {"h": jnp.ones((64,), jnp.bfloat16)}
    grads = {"h": jnp.full((64,), 0.3, jnp.float32)}
    spec = flatbuf.make_flat_spec(params)
    (buf,) = flatbuf.pack(grads, spec)
    assert buf.dtype == jnp.bfloat16


def test_pack_rejects_wrong_structure():
    tree = make_tree()
    spec = flatbuf.make_flat_spec(tree)
    with pytest.raises(ValueError):
        flatbuf.pack({"w": tree["w"]}, spec)


def test_slots_are_contiguous_and_disjoint():
    """Leaves pack back-to-back (single tail pad per bucket, no per-leaf
    row-alignment holes)."""
    tree = make_tree()
    spec = flatbuf.make_flat_spec(tree)
    for bucket in spec.buckets:
        offset = 0
        for slot in bucket.slots:
            assert slot.offset == offset
            offset += slot.size
        assert bucket.n_real == offset
        assert bucket.rows == -(-offset // flatbuf.LANE)


def test_spec_cache_reuses_metadata():
    """Same (treedef, shapes, dtypes, lead) -> the identical FlatSpec object
    (retraced steps must not rebuild slot metadata)."""
    a, b = make_tree(seed=0), make_tree(seed=1)       # same layout, new data
    assert flatbuf.make_flat_spec(a) is flatbuf.make_flat_spec(b)
    assert flatbuf.make_flat_spec(a, lead=0) is not flatbuf.make_flat_spec(
        jax.tree.map(lambda x: x[None], a), lead=1)
    # ShapeDtypeStructs hit the same cache entry as live arrays
    structs = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), a)
    assert flatbuf.make_flat_spec(structs) is flatbuf.make_flat_spec(a)


def test_pack_pads_once_per_bucket():
    """pack = cast + reshape + ONE concatenate + ONE tail pad per bucket."""
    tree = make_tree()
    spec = flatbuf.make_flat_spec(tree)
    jaxpr = str(jax.make_jaxpr(lambda t: flatbuf.pack(t, spec))(tree))
    assert jaxpr.count("concatenate") == spec.n_buckets
    assert jaxpr.count(" pad") == spec.n_buckets


def test_pack_single_aligned_leaf_is_no_copy():
    """A bucket that is one 128-aligned leaf packs as a pure reshape —
    no pad, no concatenate in the jaxpr (the no-copy fast path)."""
    tree = {"h": jnp.ones((4, 256), jnp.float32)}     # 1024 = 8 rows exactly
    spec = flatbuf.make_flat_spec(tree)
    jaxpr = str(jax.make_jaxpr(lambda t: flatbuf.pack(t, spec))(tree))
    assert "concatenate" not in jaxpr and " pad" not in jaxpr
    assert "reshape" in jaxpr


# -------------------------------------------------------------------------
# new kernels vs refs
# -------------------------------------------------------------------------


@pytest.mark.parametrize("rows", [8, 300])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_cdadam_kernel_sweep(rows, dt):
    nb = jax.random.normal(KEY, (3, rows, 128)).astype(dt)
    g = jax.random.normal(jax.random.PRNGKey(1), (rows, 128)).astype(dt)
    m = jax.random.normal(jax.random.PRNGKey(2), (rows, 128)).astype(dt)
    v = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (rows, 128))).astype(dt)
    w = jnp.array([0.5, 0.25, 0.25], jnp.float32)
    args = (1e-3, 0.9, 0.999, 1e-8, 0.1, 1e-3)
    out = cdadam_update_2d(nb, w, g, m, v, *args, interpret=True)
    ref = cdadam_update_ref(nb, w, g, m, v, *args)
    tol = dict(rtol=2e-2, atol=2e-2) if dt == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)
    for a, b in zip(out, ref):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), **tol)


@pytest.mark.parametrize("rows", [64, 257])
def test_cdmsgd_nesterov_kernel_sweep(rows):
    nb = jax.random.normal(KEY, (3, rows, 128))
    g = jax.random.normal(jax.random.PRNGKey(1), (rows, 128))
    v = jax.random.normal(jax.random.PRNGKey(2), (rows, 128))
    w = jnp.array([0.5, 0.25, 0.25], jnp.float32)
    out = cdmsgd_nesterov_update_2d(nb, w, g, v, 0.05, 0.9, interpret=True)
    ref = cdmsgd_nesterov_update_ref(nb, w, g, v, 0.05, 0.9)
    for a, b in zip(out, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)


# -------------------------------------------------------------------------
# fused optimizers vs the dense-Pi stacked oracle
# -------------------------------------------------------------------------

N_AGENTS = 5


def _stacked_setup(seed=0):
    topo = make_topology("ring", N_AGENTS)
    comm = stacked_comm_ops(topo)
    params = make_tree((N_AGENTS,), seed=seed)
    grads = jax.tree.map(
        lambda x: 0.1 * jax.random.normal(jax.random.PRNGKey(99), x.shape), params)
    return topo, comm, params, grads


def test_fused_cdsgd_matches_dense_pi_oracle():
    """x' = Pi x - alpha g against mix_pytree_stacked directly (eq. 5)."""
    topo, comm, params, grads = _stacked_setup()
    opt = CDSGD(0.05, fused=True)
    new, _ = opt.update(params, grads, opt.init(params), comm)
    mixed = mix_pytree_stacked(jnp.asarray(topo.pi, jnp.float32), params)
    want = jax.tree.map(lambda w, g: w - 0.05 * g.astype(w.dtype), mixed, grads)
    assert_trees_close(new, want, **tol_for_tree(params))


@pytest.mark.parametrize("cls,kw", [
    (CDSGD, {}),
    (CDMSGD, {"mu": 0.9}),
    (CDMSGDNesterov, {"mu": 0.9}),
    (CDAdam, {}),
])
def test_fused_matches_unfused_over_steps(cls, kw):
    """Three update steps: params AND optimizer state must track."""
    _, comm, params, grads = _stacked_setup()
    ref_opt = cls(0.05, **kw)
    fus_opt = cls(0.05, fused=True, **kw)
    pr, rs = params, ref_opt.init(params)
    pf, fs = params, fus_opt.init(params)
    for _ in range(3):
        gr = ref_opt.grad_params(pr, rs)
        gf = fus_opt.grad_params(pf, fs)
        assert_trees_close(gr, gf, **tol_for_tree(params))
        pr, rs = ref_opt.update(pr, grads, rs, comm)
        pf, fs = fus_opt.update(pf, grads, fs, comm)
    assert_trees_close(pr, pf, **tol_for_tree(params))


def test_fused_momentum_state_roundtrip():
    """CDMSGD momentum survives pack -> kernel -> unpack with exact
    structure/shape/dtype and reference values."""
    _, comm, params, grads = _stacked_setup()
    ref_opt = CDMSGD(0.05, mu=0.9)
    fus_opt = CDMSGD(0.05, mu=0.9, fused=True)
    _, rs = ref_opt.update(params, grads, ref_opt.init(params), comm)
    _, fs = fus_opt.update(params, grads, fus_opt.init(params), comm)
    assert jax.tree.structure(fs.inner) == jax.tree.structure(rs.inner)
    for a, b in zip(jax.tree.leaves(rs.inner), jax.tree.leaves(fs.inner)):
        assert a.shape == b.shape and a.dtype == b.dtype
    assert_trees_close(rs.inner, fs.inner, **tol_for_tree(params))


def test_fused_nesterov_lookahead_state():
    """Fused Nesterov stores the kernel-emitted lookahead; it must equal the
    unfused ``x + mu v`` recomputation."""
    _, comm, params, grads = _stacked_setup()
    ref_opt = CDMSGDNesterov(0.05, mu=0.9)
    fus_opt = CDMSGDNesterov(0.05, mu=0.9, fused=True)
    rs = ref_opt.init(params)
    fs = fus_opt.init(params)
    # before any update the lookahead is the params themselves
    assert_trees_close(fus_opt.grad_params(params, fs),
                       ref_opt.grad_params(params, rs), rtol=1e-6, atol=1e-6)
    pr, rs = ref_opt.update(params, grads, rs, comm)
    pf, fs = fus_opt.update(params, grads, fs, comm)
    assert_trees_close(fus_opt.grad_params(pf, fs),
                       ref_opt.grad_params(pr, rs), **tol_for_tree(params))


def test_fused_cdadam_moments_stay_local():
    _, comm, params, grads = _stacked_setup()
    opt = CDAdam(1e-3, fused=True)
    _, st = opt.update(params, grads, opt.init(params), comm)
    m, _ = st.inner
    want = jax.tree.map(lambda g, p: (0.1 * g).astype(p.dtype), grads, params)
    assert_trees_close(m, want, **tol_for_tree(params))


def test_fused_tree_ops_match_refs():
    """cdadam/nesterov whole-tree ops vs leafwise reference composition."""
    tree = {"a": jax.random.normal(KEY, (5, 9)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (301,))}
    left = jax.tree.map(lambda x: x + 1.0, tree)
    right = jax.tree.map(lambda x: x - 2.0, tree)
    grads = jax.tree.map(jnp.ones_like, tree)
    mom = jax.tree.map(lambda x: 0.5 * jnp.ones_like(x), tree)
    w = jnp.array([1 / 3, 1 / 3, 1 / 3], jnp.float32)

    p, v, la = kops.cdmsgd_nesterov_update_tree(
        tree, [left, right], w, grads, mom, 0.1, 0.9, interpret=True)
    want_v = jax.tree.map(lambda m_, g: 0.9 * m_ - 0.1 * g, mom, grads)
    want_p = jax.tree.map(lambda x, l, r, v_: (x + l + r) / 3 + v_,
                          tree, left, right, want_v)
    want_la = jax.tree.map(lambda p_, v_: p_ + 0.9 * v_, want_p, want_v)
    assert_trees_close(p, want_p, rtol=3e-5, atol=3e-5)
    assert_trees_close(v, want_v, rtol=3e-5, atol=3e-5)
    assert_trees_close(la, want_la, rtol=3e-5, atol=3e-5)

    second = jax.tree.map(lambda x: jnp.abs(x) + 0.5, tree)
    p2, m2, v2 = kops.cdadam_update_tree(
        tree, [left, right], w, grads, mom, second,
        1e-3, 0.9, 0.999, 1e-8, 0.1, 1e-3, interpret=True)
    want_m2 = jax.tree.map(lambda m_, g: 0.9 * m_ + 0.1 * g, mom, grads)
    want_v2 = jax.tree.map(lambda s, g: 0.999 * s + 0.001 * g * g, second, grads)
    want_p2 = jax.tree.map(
        lambda x, l, r, m_, s: (x + l + r) / 3
        - 1e-3 * (m_ / 0.1) / (jnp.sqrt(s / 1e-3) + 1e-8),
        tree, left, right, want_m2, want_v2)
    assert_trees_close(p2, want_p2, rtol=3e-5, atol=3e-5)
    assert_trees_close(m2, want_m2, rtol=3e-5, atol=3e-5)
    assert_trees_close(v2, want_v2, rtol=3e-5, atol=3e-5)


# -------------------------------------------------------------------------
# quantized exchange: stochastic rounding + fused-path parity
# -------------------------------------------------------------------------


def test_sr_quantize_roundtrip_error_bound():
    """quantize -> dequantize error is bounded by one quantization step
    (scale = row amax / 127 for int8)."""
    x = jax.random.normal(KEY, (32, 128), jnp.float32)
    q, sc = sr_quantize_2d(x, 0, exchange="int8", interpret=True)
    assert q.dtype == jnp.int8 and sc.shape == (32, 1)
    err = np.abs(np.asarray(sr_dequantize_2d(q, sc)) - np.asarray(x))
    assert np.all(err <= np.asarray(sc) + 1e-7)
    qf, scf = sr_quantize_2d(x, 0, exchange="fp8", interpret=True)
    assert qf.dtype == jnp.float8_e4m3fn
    relerr = np.abs(np.asarray(sr_dequantize_2d(qf, scf)) - np.asarray(x))
    # e4m3: 3 mantissa bits -> nearest-rounding relative error <= 2^-4
    assert np.all(relerr <= np.abs(np.asarray(x)) * 2**-4 + np.asarray(scf))


def test_sr_quantize_is_unbiased():
    """E[dequantize(quantize(x))] = x: the mean over many stochastic-rounding
    draws converges to the input (this is what keeps the 20-step quantized
    trajectory centered on the reference)."""
    x = jax.random.normal(KEY, (8, 128), jnp.float32)

    @jax.jit
    def draw(seed):
        q, sc = sr_quantize_2d(x, seed, exchange="int8", interpret=True)
        return sr_dequantize_2d(q, sc)

    mean = np.mean([np.asarray(draw(s)) for s in range(200)], axis=0)
    scale = np.asarray(jnp.max(jnp.abs(x), axis=-1, keepdims=True)) / 127.0
    # SE of the mean of 200 uniform-rounding errors ~= scale/sqrt(12*200)
    np.testing.assert_allclose(mean, np.asarray(x), atol=float(scale.max()) * 0.25)
    bias = np.abs(mean - np.asarray(x)).mean()
    assert bias < float(scale.max()) * 0.05, f"rounding is biased: {bias}"


def test_sr_quantize_deterministic_under_fixed_seed():
    x = jax.random.normal(KEY, (16, 128), jnp.float32)
    q1, s1 = sr_quantize_2d(x, 42, exchange="int8", interpret=True)
    q2, s2 = sr_quantize_2d(x, 42, exchange="int8", interpret=True)
    q3, _ = sr_quantize_2d(x, 43, exchange="int8", interpret=True)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    assert np.any(np.asarray(q1) != np.asarray(q3)), "seed must matter"


# documented tolerance of the int8 stochastic-rounding exchange: per step
# each mixed parameter absorbs quantization noise <= row_amax/127 per
# neighbor; over K steps the (unbiased) errors random-walk, so O(1)-scale
# toy parameters stay within ~2e-2 * sqrt(K/20) of the exact trajectory.
# Empirically 20 real-gradient CDMSGD steps land at ~3.4e-2 max |diff|;
# the assertion bound is 6e-2.
INT8_TRAJECTORY_TOL = 6e-2


@pytest.mark.parametrize("cls,kw", [
    (CDSGD, {}),
    (CDMSGD, {"mu": 0.9}),
])
def test_quantized_fused_tracks_reference_over_20_steps(cls, kw):
    """int8 exchange vs the unquantized reference mix, 20 update steps, on
    a mixed f32+bf16-bucket tree (both buckets must stay in tolerance)."""
    _, _, params, grads = _stacked_setup()
    topo = make_topology("ring", N_AGENTS)
    comm_q = stacked_comm_ops(topo, exchange="int8")
    comm_r = stacked_comm_ops(topo)
    qopt = cls(0.05, fused=True, **kw)
    ropt = cls(0.05, **kw)
    pq, sq = params, qopt.init(params)
    pr, sr = params, ropt.init(params)
    for _ in range(20):
        pq, sq = qopt.update(pq, grads, sq, comm_q)
        pr, sr = ropt.update(pr, grads, sr, comm_r)
    assert_trees_close(pq, pr, rtol=0, atol=INT8_TRAJECTORY_TOL)


def test_bf16_exchange_matches_reference():
    """bf16 wire: pure downcast, no scales — parity within bf16 epsilon."""
    _, _, params, grads = _stacked_setup()
    topo = make_topology("ring", N_AGENTS)
    comm_q = stacked_comm_ops(topo, exchange="bf16")
    comm_r = stacked_comm_ops(topo)
    opt_q = CDSGD(0.05, fused=True)
    opt_r = CDSGD(0.05)
    pq, _ = opt_q.update(params, grads, opt_q.init(params), comm_q)
    pr, _ = opt_r.update(params, grads, opt_r.init(params), comm_r)
    assert_trees_close(pq, pr, rtol=2e-2, atol=2e-2)


def test_quantized_gather_emits_scales_and_int8_stack():
    """Stacked gather: int8 payload stack, (A, rows, 1) f32 row scales, the
    native self stack, and [diag | zero-diagonal] (A, A+1) weights."""
    topo = make_topology("ring", N_AGENTS)
    comm = stacked_comm_ops(topo, exchange="int8")
    params = make_tree((N_AGENTS,))
    fl = comm.flat
    spec = fl.spec(params)
    bufs = fl.pack(params, spec)
    nbrs, w, scales, selfs = fl.gather(bufs, jnp.int32(0))
    assert w.shape == (N_AGENTS, N_AGENTS + 1)
    pi = np.asarray(topo.pi, np.float32)
    np.testing.assert_allclose(np.asarray(w[:, 0]), np.diag(pi), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(w[:, 1:]),
                               pi * (1 - np.eye(N_AGENTS)), rtol=1e-6)
    for nb, sc, sf, bucket, buf in zip(nbrs, scales, selfs, spec.buckets, bufs):
        assert nb.dtype == jnp.int8
        assert nb.shape == (N_AGENTS, bucket.rows, flatbuf.LANE)
        assert sc.dtype == jnp.float32 and sc.shape == (N_AGENTS, bucket.rows, 1)
        assert sf is buf                       # self rides in native precision


# -------------------------------------------------------------------------
# in-place update accounting (input_output_aliases)
# -------------------------------------------------------------------------


@pytest.mark.parametrize("cls,kw,n_aliased", [
    (CDSGD, {}, 1),                      # grad -> params
    (CDMSGD, {"mu": 0.9}, 2),            # + momentum -> momentum'
    (CDMSGDNesterov, {"mu": 0.9}, 2),    # lookahead is the one fresh buffer
    (CDAdam, {}, 3),                     # grad -> params, m -> m', v -> v'
])
def test_fused_updates_alias_grad_and_state(cls, kw, n_aliased):
    """Every fused pallas_call donates its gradient/state inputs to its
    outputs — zero extra HBM output allocation for params and momentum."""
    _, comm, params, grads = _stacked_setup()
    opt = cls(0.05, fused=True, **kw)
    state = opt.init(params)
    jaxpr = jax.make_jaxpr(
        lambda p, g, s: opt.update(p, g, s, comm))(params, grads, state)
    spec = flatbuf.make_flat_spec(params, lead=1)
    groups = kops.alias_groups(jaxpr)
    assert len(groups) == spec.n_buckets          # every launch aliases
    for g in groups:
        assert len(g) == n_aliased


def test_quantized_fused_also_aliases():
    """Quantization inserts a scales operand; the alias bookkeeping must
    shift with it."""
    topo = make_topology("ring", N_AGENTS)
    comm = stacked_comm_ops(topo, exchange="int8")
    params = make_tree((N_AGENTS,))
    grads = jax.tree.map(jnp.ones_like, params)
    opt = CDMSGD(0.05, mu=0.9, fused=True)
    state = opt.init(params)
    new_params, _ = opt.update(params, grads, state, comm)
    jaxpr = jax.make_jaxpr(
        lambda p, g, s: opt.update(p, g, s, comm))(params, grads, state)
    assert len(kops.alias_groups(jaxpr)) == flatbuf.make_flat_spec(params, lead=1).n_buckets
    for x in jax.tree.leaves(new_params):
        assert bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))


# -------------------------------------------------------------------------
# launch-count accounting + end-to-end trainer
# -------------------------------------------------------------------------


def test_one_pallas_call_per_dtype_bucket():
    """The whole fused stacked update is ONE batched pallas_call per bucket
    and the per-leaf mixing einsum is gone from the step jaxpr."""
    _, comm, params, grads = _stacked_setup()
    opt = CDMSGD(0.05, mu=0.9, fused=True)
    state = opt.init(params)
    jaxpr = str(jax.make_jaxpr(
        lambda p, g, s: opt.update(p, g, s, comm))(params, grads, state))
    spec = flatbuf.make_flat_spec(params, lead=1)
    assert spec.n_buckets == 2
    assert jaxpr.count("pallas_call") == spec.n_buckets


def test_trainer_end_to_end_fused_matches_reference():
    """CollaborativeTrainer with a fused optimizer: losses and params track
    the unfused trainer through real gradient steps."""
    from repro.nn.paper_models import (
        classifier_loss, mlp_classifier_apply, mlp_classifier_template)
    from repro.nn.param import init_params

    loss = functools.partial(classifier_loss, mlp_classifier_apply)
    params = init_params(mlp_classifier_template(8, 4, width=16, depth=2),
                         jax.random.PRNGKey(0))
    topo = make_topology("ring", 4)
    rng = np.random.default_rng(0)
    batch = {"x": jnp.asarray(rng.standard_normal((4, 8, 8)), jnp.float32),
             "y": jnp.asarray(rng.integers(0, 4, (4, 8)), jnp.int32)}

    results = {}
    for name, fused in (("ref", False), ("fused", True)):
        tr = CollaborativeTrainer(loss, params, topo,
                                  CDMSGD(0.05, mu=0.9, fused=fused))
        for _ in range(3):
            m = tr.step(batch)
        results[name] = (tr.state.params, m["loss"])
    assert abs(results["ref"][1] - results["fused"][1]) < 1e-4
    assert_trees_close(results["ref"][0], results["fused"][0],
                       rtol=1e-4, atol=1e-4)
