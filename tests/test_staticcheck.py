"""StepProgram contract checker: certification + deliberately-broken programs.

The checker (``repro.analysis.staticcheck``) is only trustworthy if it
fails CLOSED: every test here that breaks a program contract on purpose
asserts that the matching NAMED rule fails with actionable evidence, not
merely that "some rule" failed.  The happy paths assert full-matrix
certification on the paper's stacked MLP testbed; the sharded mode is
certified in a subprocess (8 host devices — the test_sharded.py idiom).
"""

import functools
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import records, staticcheck
from repro.core import consensus
from repro.core.optim import (CDSGD, CDMSGD, CDMSGDNesterov, CDAdam,
                              tree_zeros_like)
from repro.core.topology import make_topology
from repro.core.trainer import CollaborativeTrainer
from repro.nn.paper_models import (classifier_loss, mlp_classifier_apply,
                                   mlp_classifier_template)
from repro.nn.param import init_params

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
LOSS = functools.partial(classifier_loss, mlp_classifier_apply)
N_AGENTS = 4


def _testbed(seed=0):
    params = init_params(mlp_classifier_template(8, 4, width=16, depth=2),
                         jax.random.PRNGKey(seed))
    topo = make_topology("ring", N_AGENTS)
    rng = np.random.default_rng(seed)
    batch = {"x": jnp.asarray(rng.standard_normal((N_AGENTS, 8, 8)), jnp.float32),
             "y": jnp.asarray(rng.integers(0, 4, (N_AGENTS, 8)), jnp.int32)}
    return params, topo, batch


def _check(optimizer, *, label="t", checkify_indices=False, **kw):
    params, topo, batch = _testbed()
    tr = CollaborativeTrainer(LOSS, params, topo, optimizer, **kw)
    return staticcheck.check_trainer(tr, batch, label=label,
                                     checkify_indices=checkify_indices)


# -------------------------------------------------------------------------
# happy path: every stacked configuration class certifies
# -------------------------------------------------------------------------


@pytest.mark.parametrize("label,opt,kw", [
    ("sync_f32", CDSGD(0.05, fused=True), {}),
    ("overlap_int8", CDMSGD(0.05, fused=True),
     dict(schedule="overlap", exchange="int8")),
    ("sync_rounds3", CDAdam(0.05, fused=True),
     dict(exchange="int8", mixing_strategy="multi_round", consensus_rounds=3)),
    ("overlap_S4", CDSGD(0.05, fused=True),
     dict(schedule="overlap", exchange="int8", staleness=4)),
    ("overlap_ef_topk", CDSGD(0.05, fused=True),
     dict(schedule="overlap", exchange="int8", error_feedback=True,
          compressor="topk:0.25")),
    ("overlap_ef_rank", CDMSGDNesterov(0.05, fused=True),
     dict(schedule="overlap", error_feedback=True, compressor="rank:2")),
])
def test_supported_configs_certify(label, opt, kw):
    rep = _check(opt, label=label, checkify_indices=True, **kw)
    assert rep.ok, rep.summary()
    # every non-skipped rule carries a human-readable detail line
    for r in rep.results:
        if not r.skipped:
            assert r.detail, f"{r.rule} certified without evidence"


def test_report_shape_and_lookup():
    rep = _check(CDMSGD(0.05, fused=True), label="shape",
                 schedule="overlap", exchange="int8")
    d = rep.as_dict()
    assert d["version"] == staticcheck.SCHEMA_VERSION
    assert d["ok"] is True and d["label"] == "shape"
    assert {"rule", "ok", "detail", "evidence", "skipped"} <= set(d["rules"][0])
    json.dumps(d, default=str)   # machine-readable end to end
    census = rep.rule("census.ppermute_count")
    # ring of 4: 2 non-zero shifts x 2 fields (int8 + scales) x 2 buckets
    assert census.evidence["actual"] == census.evidence["predicted"]
    assert "[OK]" in rep.summary()
    with pytest.raises(KeyError):
        rep.rule("no.such.rule")


def test_census_prediction_closed_form():
    """The closed form prices fields/buckets/rounds without tracing."""
    params, topo, batch = _testbed()
    tr = CollaborativeTrainer(LOSS, params, topo, CDMSGD(0.05, fused=True),
                              schedule="overlap", exchange="int8",
                              mixing_strategy="multi_round",
                              consensus_rounds=3)
    import jax.tree_util  # noqa: F401  (spec built from live params)
    from repro.core import flatbuf
    spec = flatbuf.make_flat_spec(params, lead=1)
    pred = staticcheck.predict_collectives(tr.program, spec, "overlap",
                                           "stacked")
    # stacked execution moves wire state by gather, not collectives
    assert pred["total"] == 0


# -------------------------------------------------------------------------
# deliberately-broken programs fail the matching NAMED rule
# -------------------------------------------------------------------------


class BuggyNesterov(CDMSGDNesterov):
    """Reintroduces the PR 9 bug: fused init aliases the params tree into
    the inner state, so donating (params, opt_state) donates one buffer
    twice."""

    def init_inner(self, p):
        if self.fused:
            return (tree_zeros_like(p), p)
        return tree_zeros_like(p)


def test_double_donation_detected_with_buffer_paths():
    rep = _check(BuggyNesterov(0.05, fused=True), label="buggy-nesterov")
    r = rep.rule("alias.double_donation")
    assert not r.ok
    assert not rep.ok
    dup = r.evidence["duplicates"]
    assert dup, "evidence must name the doubly-donated buffers"
    # each duplicate names BOTH tree paths sharing one buffer
    flat = " ".join(str(p) for paths in dup for p in paths)
    assert "arg0" in flat and "arg1" in flat


class NoAliasCDSGD(CDSGD):
    """Fused CDSGD whose kernel launch silently drops in-place aliasing —
    the exact regression alias.fused_coverage exists to catch."""

    def apply_fused(self, p, grads, inner, alpha, comm, step, *,
                    exchanged=None):
        from repro.core.optim import _flat_setup
        from repro.kernels.consensus_update.consensus_update import (
            cdsgd_update_2d)
        fl = comm.flat
        spec, nbrs, w, scs, sfs, (g,) = _flat_setup(fl, p, step, grads,
                                                    exchanged=exchanged)
        outs = [jax.vmap(lambda wr, gb2: cdsgd_update_2d(
                    nb, wr, gb2, alpha, interpret=fl.interpret, alias=False))(w, gb)
                for nb, gb in zip(nbrs, g)]
        return fl.unpack(outs, spec), inner


def test_dropped_alias_detected_per_launch():
    rep = _check(NoAliasCDSGD(0.05, fused=True), label="no-alias")
    r = rep.rule("alias.fused_coverage")
    assert not r.ok
    assert "0/2" in r.detail or "alias" in r.detail


def test_seed_stride_collision_detected(monkeypatch):
    """Colliding stream strides (agent == bucket) must fail the
    config-time disjointness proof."""
    monkeypatch.setattr(consensus, "_SEED_AGENT_STRIDE",
                        consensus._SEED_BUCKET_STRIDE)
    rep = _check(CDMSGD(0.05, fused=True), label="bad-strides",
                 schedule="overlap", exchange="int8")
    r = rep.rule("seeds.strides_distinct")
    assert not r.ok


def test_claimed_overlap_on_sync_program_is_caught_stacked_census():
    """A sync-assembled stacked step claimed as overlap: stacked mode has
    no collectives, so the census stays green — the defense in stacked
    mode is the byte/alias rails.  The REAL fresh-collective detection is
    sharded (see test_sharded_claimed_overlap below); here we pin that the
    checker still runs end to end under a wrong claim without crashing."""
    params, topo, batch = _testbed()
    tr = CollaborativeTrainer(LOSS, params, topo, CDSGD(0.05, fused=True),
                              exchange="int8", schedule="sync")
    rep = staticcheck.check_program(
        tr._program.step_fn, tr.state.params, tr.state.opt_state, batch,
        program=tr.program, optimizer=tr.optimizer, schedule="overlap",
        mode="stacked", n_agents=N_AGENTS, label="sync-claiming-overlap")
    assert rep.rule("census.ppermute_count").evidence["actual"] == 0


# -------------------------------------------------------------------------
# sharded mode: census + claimed-overlap breakage (subprocess, 8 devices)
# -------------------------------------------------------------------------


def run_sub(code: str, timeout=560) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-4000:]}"
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


@pytest.mark.slow
def test_sharded_claimed_overlap_fails_critical_path_rule():
    """The acceptance scenario: a sync-assembled SHARDED program checked
    against the overlap contract must fail census.critical_path with the
    fresh ppermutes named in evidence (they read params — the exchange is
    back on the grad->update critical path)."""
    res = run_sub(textwrap.dedent("""
        import dataclasses, json
        import jax
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.core.optim import make_optimizer
        from repro.launch.mesh import make_debug_mesh
        from repro.launch import steps as steps_lib
        from repro.analysis import staticcheck

        cfg = dataclasses.replace(get_config("granite-3-8b").reduced(),
                                  param_dtype="float32")
        shape = InputShape("tiny_train", 16, 8, "train")
        mesh = make_debug_mesh(4, 2)
        opt = make_optimizer("cdmsgd", 0.05, fused=True)
        b = steps_lib.build_train_step(cfg, shape, mesh, opt, mode="train",
                                       topology_name="ring",
                                       mixing="ppermute_fused",
                                       exchange="int8", schedule="sync")
        with mesh:
            good = staticcheck.check_bundle(b, mesh, label="sync-honest")
            params = b.param_structs(mesh)
            st = b.opt_state_structs(mesh, opt)
            lied = staticcheck.check_program(
                b.step_fn, params, st, b.batch_specs,
                program=b.mixing_program, optimizer=opt, schedule="overlap",
                mode="sharded", n_agents=b.n_agents,
                label="sync-claiming-overlap",
                row_shard=2)
        cp = lied.rule("census.critical_path")
        print("RESULT " + json.dumps({
            "honest_ok": good.ok,
            "lied_ok": lied.ok,
            "critical_path_ok": cp.ok,
            "detail": cp.detail,
            "fresh_labels": sorted({l for h in cp.evidence["fresh_hits"]
                                    for l in h["labels"]}),
        }))
    """))
    assert res["honest_ok"], "the honest sync claim must certify"
    assert not res["lied_ok"]
    assert not res["critical_path_ok"]
    assert "critical path" in res["detail"]
    assert "params" in res["fresh_labels"], \
        "evidence must show the fresh collectives reading params"


# -------------------------------------------------------------------------
# dryrun record schema: v2 loader reads the pre-checker v1 artifact
# -------------------------------------------------------------------------


def test_dryrun_loader_reads_v1_artifact():
    """The seed repo ships a pre-PR-10 dryrun record (no version/verify);
    the v2 loader must normalize it instead of crashing."""
    path = os.path.join(
        REPO, "results", "dryrun",
        "granite-3-8b__train_4k__16x16__train_ppermute_fused.json")
    rec = records.load_dryrun_record(path)
    assert rec["version"] == 1
    assert rec["verify"] is None
    assert records.verify_summary(rec) == "not run"


def test_verify_summary_of_v2_record(tmp_path):
    rep = _check(CDSGD(0.05, fused=True), label="v2")
    rec = {"version": records.DRYRUN_SCHEMA_VERSION, "status": "ok",
           "verify": rep.as_dict()}
    p = tmp_path / "rec.json"
    p.write_text(json.dumps(rec, default=str))
    loaded = records.load_dryrun_record(str(p))
    assert loaded["version"] == records.DRYRUN_SCHEMA_VERSION
    s = records.verify_summary(loaded)
    assert s.startswith("ok (")
