"""Hypothesis compatibility shim.

Re-exports ``given`` / ``settings`` / ``strategies`` / ``hypothesis.extra.numpy``
when hypothesis is installed; otherwise provides a deterministic fixed-seed
fallback implementing the tiny strategy subset these tests use
(``st.integers``, ``st.floats``, ``hnp.arrays``), so the suite collects and
runs with or without hypothesis in the environment.

The fallback draws ``_N_EXAMPLES`` samples from ``np.random.default_rng(0)``
per test — less adversarial than hypothesis's shrinking search, but the same
property is exercised on a spread of inputs and failures are reproducible.
"""

try:
    from hypothesis import given, settings, strategies as st
    from hypothesis.extra import numpy as hnp

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import numpy as np

    _N_EXAMPLES = 10

    class _Strategy:
        def __init__(self, sample_fn):
            self._sample_fn = sample_fn

        def sample(self, rng):
            return self._sample_fn(rng)

    class _StModule:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value=-1e6, max_value=1e6, width=64, **_kw):
            return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    st = _StModule()

    class _HnpModule:
        @staticmethod
        def arrays(dtype, shape, elements=None):
            shape = (shape,) if isinstance(shape, int) else tuple(shape)

            def sample(rng):
                if elements is None:
                    return rng.standard_normal(shape).astype(dtype)
                n = int(np.prod(shape)) if shape else 1
                flat = [elements.sample(rng) for _ in range(n)]
                return np.asarray(flat, dtype=dtype).reshape(shape)

            return _Strategy(sample)

    hnp = _HnpModule()

    def given(**strategies):
        def decorator(fn):
            # NB: no functools.wraps — copying fn's signature would make
            # pytest resolve the strategy-drawn parameters as fixtures.
            def wrapper():
                rng = np.random.default_rng(0)
                for _ in range(_N_EXAMPLES):
                    drawn = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(**drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return decorator

    def settings(**_kw):
        def decorator(fn):
            return fn

        return decorator
