"""Exactness tests for the §Perf optimized paths vs their baselines.

Every beyond-paper optimization must be semantics-preserving; these tests
pin that: banded == masked-blockwise attention, chunked == per-step scan
recurrences (including harsh decays and carried state).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.attention import banded_attention, blockwise_attention
from repro.nn.ssm import mamba_chunked, mamba_scan, wkv6_chunked, wkv6_scan

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("s,w,qc,h,kv", [
    (256, 64, 64, 4, 2), (512, 100, 128, 2, 1), (128, 32, 128, 4, 4),
    (256, 512, 64, 2, 2),    # window >= seq handled by callers; here clipped span
])
def test_banded_equals_masked_blockwise(s, w, qc, h, kv):
    q = jax.random.normal(KEY, (2, s, h, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, s, kv, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, s, kv, 64))
    a = banded_attention(q, k, v, window=w, q_chunk=qc)
    b = blockwise_attention(q, k, v, causal=True, window=w, chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_wkv6_chunked_equals_scan(chunk):
    b, s, n_h, hs = 2, 128, 2, 32
    ks = jax.random.split(KEY, 5)
    r, k, v = (jax.random.normal(ks[i], (b, s, n_h, hs)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, n_h, hs))) * 0.7 + 0.25
    u = 0.1 * jax.random.normal(ks[4], (n_h, hs))
    y1, s1 = wkv6_scan(r, k, v, w, u)
    y2, s2 = wkv6_chunked(r, k, v, w, u, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4, atol=2e-4)


def test_wkv6_chunked_harsh_decays_stable():
    """Decays down to ~2e-3/step must not overflow (mid-chunk shift)."""
    b, s, n_h, hs = 2, 128, 2, 32
    ks = jax.random.split(KEY, 5)
    r, k, v = (jax.random.normal(ks[i], (b, s, n_h, hs)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, n_h, hs)) * 3 - 2)
    u = 0.1 * jax.random.normal(ks[4], (n_h, hs))
    y1, _ = wkv6_scan(r, k, v, w, u)
    y2, _ = wkv6_chunked(r, k, v, w, u, chunk=32)
    rel = float(jnp.max(jnp.abs(y1 - y2)) / (jnp.max(jnp.abs(y1)) + 1e-9))
    assert np.isfinite(np.asarray(y2)).all()
    assert rel < 1e-4


def test_wkv6_chunked_carries_state():
    b, s, n_h, hs = 1, 96, 2, 16
    ks = jax.random.split(KEY, 6)
    r, k, v = (jax.random.normal(ks[i], (b, s, n_h, hs)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, n_h, hs))) * 0.5 + 0.45
    u = 0.1 * jax.random.normal(ks[4], (n_h, hs))
    s0 = jax.random.normal(ks[5], (b, n_h, hs, hs))
    y1, s1 = wkv6_scan(r, k, v, w, u, s0)
    y2, s2 = wkv6_chunked(r, k, v, w, u, s0, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunk", [16, 32])
def test_mamba_chunked_equals_scan(chunk):
    b, s, di, n = 2, 128, 24, 16
    ks = jax.random.split(KEY, 5)
    u = jax.random.normal(ks[0], (b, s, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, di)))   # harsh decays
    bi = jax.random.normal(ks[2], (b, s, n))
    ci = jax.random.normal(ks[3], (b, s, n))
    a = -jnp.exp(0.3 * jax.random.normal(ks[4], (di, n)))
    y1, h1 = mamba_scan(u, dt, bi, ci, a)
    y2, h2 = mamba_chunked(u, dt, bi, ci, a, chunk=chunk)
    assert np.isfinite(np.asarray(y2)).all()
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-4, atol=1e-4)


def test_mamba_chunked_carries_state():
    b, s, di, n = 1, 64, 8, 4
    ks = jax.random.split(KEY, 6)
    u = jax.random.normal(ks[0], (b, s, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, di)) - 1)
    bi = jax.random.normal(ks[2], (b, s, n))
    ci = jax.random.normal(ks[3], (b, s, n))
    a = -jnp.exp(0.1 * jax.random.normal(ks[4], (di, n)))
    s0 = jax.random.normal(ks[5], (b, di, n))
    y1, h1 = mamba_scan(u, dt, bi, ci, a, s0)
    y2, h2 = mamba_chunked(u, dt, bi, ci, a, s0, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-4, atol=1e-4)


def test_gemma_superblock_order_is_preserved():
    """local_global regrouping keeps exact layer order & global positions."""
    from repro.configs import get_config
    from repro.nn.transformer import layer_groups
    cfg = get_config("gemma3-1b")
    groups = dict((n, c) for n, c, _ in layer_groups(cfg))
    p = cfg.local_global_period
    assert groups["lg_super"] * p + groups.get("lg_tail", 0) == cfg.n_layers
    # global layers are the last sub-layer of each period (paper: every 6th)
    assert cfg.layer_is_global(p - 1) and not cfg.layer_is_global(0)
