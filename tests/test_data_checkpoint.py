"""Data pipeline (partitioner, non-IID skew, determinism) + checkpointing."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data import (
    AgentPartitioner,
    lm_agent_batches,
    make_classification,
    make_lm_tokens,
)


@given(n_agents=st.integers(2, 8))
@settings(max_examples=10, deadline=None)
def test_partitioner_shards_equal_and_disjoint(n_agents):
    train, _ = make_classification(512, n_classes=4, dim=8)
    part = AgentPartitioner(train, n_agents, seed=1)
    sizes = {len(s) for s in part.shards}
    assert len(sizes) == 1, "shards must be equal-sized"
    all_idx = np.concatenate(part.shards)
    assert len(all_idx) == len(set(all_idx.tolist())), "shards must be disjoint"


def test_non_iid_partition_skews_labels():
    train, _ = make_classification(2000, n_classes=10, dim=8)
    iid = AgentPartitioner(train, 5, non_iid=False, seed=0).label_histograms()
    skew = AgentPartitioner(train, 5, non_iid=True, seed=0).label_histograms()

    def entropy(h):
        p = h / np.maximum(h.sum(axis=1, keepdims=True), 1)
        return -(p * np.log(p + 1e-12)).sum(axis=1).mean()

    assert entropy(skew) < 0.6 * entropy(iid)


def test_batches_shapes_and_determinism():
    train, _ = make_classification(512, n_classes=4, dim=8)
    a = AgentPartitioner(train, 4, seed=7).batches(16)
    b = AgentPartitioner(train, 4, seed=7).batches(16)
    ba, bb = next(a), next(b)
    assert ba["x"].shape == (4, 16, 8) and ba["y"].shape == (4, 16)
    np.testing.assert_array_equal(ba["x"], bb["x"])


def test_lm_tokens_deterministic_and_learnable():
    t1 = make_lm_tokens(4096, vocab=64, seed=3)
    t2 = make_lm_tokens(4096, vocab=64, seed=3)
    np.testing.assert_array_equal(t1, t2)
    # bigram structure: successor entropy < unigram entropy
    uni = np.bincount(t1, minlength=64) / len(t1)
    h_uni = -(uni * np.log(uni + 1e-12)).sum()
    pair = np.zeros((64, 64))
    for a, b in zip(t1[:-1], t1[1:]):
        pair[a, b] += 1
    cond = pair / np.maximum(pair.sum(1, keepdims=True), 1)
    h_cond = -(pair / pair.sum() * np.log(cond + 1e-12)).sum()
    assert h_cond < 0.8 * h_uni


def test_lm_agent_batches_shapes():
    toks = make_lm_tokens(8192, vocab=128, seed=0)
    it = lm_agent_batches(toks, n_agents=4, batch_per_agent=2, seq=16)
    b = next(it)
    assert b["inputs"].shape == (4, 2, 16)
    np.testing.assert_array_equal(b["inputs"][..., 1:], b["targets"][..., :-1])


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                   "b": jnp.ones((3,), jnp.bfloat16)},
        "step": jnp.int32(7),
    }
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 7, tree)
    save_checkpoint(d, 12, tree)
    assert latest_step(d) == 12
    restored = restore_checkpoint(d, tree)
    for (pa, la), (pb, lb) in zip(jax.tree_util.tree_flatten_with_path(tree)[0],
                                  jax.tree_util.tree_flatten_with_path(restored)[0]):
        np.testing.assert_array_equal(np.asarray(la, np.float32),
                                      np.asarray(lb, np.float32))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(d, {"w": jnp.zeros((3, 3))})


def test_checkpoint_missing_key_raises(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(KeyError):
        restore_checkpoint(d, {"w": jnp.zeros((2, 2)), "extra": jnp.zeros(1)})


def test_train_state_roundtrip_resumes_bit_exact(tmp_path):
    """save -> restore -> step == continuous run, bit-for-bit, INCLUDING the
    overlap wire double-buffer and the error-feedback residuals (before
    this, checkpointing params alone silently reset the carried wire to
    x_{-1} := x_0 and the residuals to zero on restore)."""
    import functools
    from repro.checkpoint import restore_train_state, save_train_state
    from repro.core.optim import CDSGD
    from repro.core.topology import make_topology
    from repro.core.trainer import CollaborativeTrainer, TrainState
    from repro.nn.paper_models import (classifier_loss, mlp_classifier_apply,
                                       mlp_classifier_template)
    from repro.nn.param import init_params

    loss = functools.partial(classifier_loss, mlp_classifier_apply)
    params = init_params(mlp_classifier_template(8, 4, width=16, depth=2),
                         jax.random.PRNGKey(0))
    topo = make_topology("ring", 4)
    rng = np.random.default_rng(0)
    batch = {"x": jnp.asarray(rng.standard_normal((4, 8, 8)), jnp.float32),
             "y": jnp.asarray(rng.integers(0, 4, (4, 8)), jnp.int32)}

    def make_trainer():
        return CollaborativeTrainer(
            loss, params, topo, CDSGD(5e-3, fused=True), schedule="overlap",
            exchange="int8", error_feedback=True, donate=False)

    tr = make_trainer()
    for _ in range(3):
        tr.step(batch)
    d = str(tmp_path / "ckpt")
    save_train_state(d, tr.state.step, tr.state.params, tr.state.opt_state)

    tr2 = make_trainer()                    # fresh wire/residual state ...
    p0, o0 = restore_train_state(d, tr2.state.params, tr2.state.opt_state)
    # ... replaced by the checkpointed one (incl. int8 wire payloads)
    tr2.state = TrainState(params=p0, opt_state=o0, step=int(o0.step))
    assert tr2.state.step == 3
    for a, b in zip(jax.tree.leaves(tr.state.opt_state.wire),
                    jax.tree.leaves(tr2.state.opt_state.wire)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    m1 = tr.step(batch)
    m2 = tr2.step(batch)
    assert m1["loss"] == m2["loss"]
    for a, b in zip(jax.tree.leaves(tr.state.params),
                    jax.tree.leaves(tr2.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(tr.state.opt_state.residual),
                    jax.tree.leaves(tr2.state.opt_state.residual)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_train_state_restore_rejects_missing_wire(tmp_path):
    """A params-only checkpoint cannot silently restore into a stateful
    trainer: the wire/residual keys are missing and restore fails loudly."""
    from repro.checkpoint import restore_train_state, save_checkpoint
    from repro.core.optim import CDSGD, OptState
    d = str(tmp_path / "ckpt")
    params = {"w": jnp.zeros((4, 2))}
    opt = CDSGD(0.01)
    save_checkpoint(d, 0, {"params": params,
                           "opt_state": opt.init(params)})
    stateful = OptState(step=jnp.int32(0), inner=(),
                        wire=((jnp.zeros((4, 1, 128), jnp.int8),
                               jnp.ones((4, 1, 1), jnp.float32)),))
    with pytest.raises(KeyError):
        restore_train_state(d, params, stateful)


def test_train_state_roundtrip_momentum_mixed_wire_bit_exact(tmp_path):
    """The ISSUE-5 widened wire contract round-trips: with
    momentum_mixing="mixed" + overlap + EF the OptState carries TWO wire
    payload trees (params + momentum int8 payloads, scales) and one
    residual per bucket per payload — save -> restore -> step must equal
    the continuous run bit-for-bit."""
    import functools
    from repro.checkpoint import restore_train_state, save_train_state
    from repro.core import flatbuf
    from repro.core.optim import CDMSGD
    from repro.core.topology import make_topology
    from repro.core.trainer import CollaborativeTrainer, TrainState
    from repro.nn.paper_models import (classifier_loss, mlp_classifier_apply,
                                       mlp_classifier_template)
    from repro.nn.param import init_params

    loss = functools.partial(classifier_loss, mlp_classifier_apply)
    params = init_params(mlp_classifier_template(8, 4, width=16, depth=2),
                         jax.random.PRNGKey(0))
    topo = make_topology("ring", 4)
    rng = np.random.default_rng(0)
    batch = {"x": jnp.asarray(rng.standard_normal((4, 8, 8)), jnp.float32),
             "y": jnp.asarray(rng.integers(0, 4, (4, 8)), jnp.int32)}

    def make_trainer():
        return CollaborativeTrainer(
            loss, params, topo, CDMSGD(5e-3, mu=0.9, fused=True),
            schedule="overlap", exchange="int8", error_feedback=True,
            momentum_mixing="mixed", donate=False)

    tr = make_trainer()
    spec = flatbuf.make_flat_spec(tr.state.params, lead=1)
    # the widened state: both payloads' wire pairs + per-payload residuals
    assert len(tr.state.opt_state.wire) == 2 * spec.n_buckets
    assert len(tr.state.opt_state.residual) == 2 * spec.n_buckets
    for _ in range(3):
        tr.step(batch)
    d = str(tmp_path / "ckpt")
    save_train_state(d, tr.state.step, tr.state.params, tr.state.opt_state)

    tr2 = make_trainer()
    p0, o0 = restore_train_state(d, tr2.state.params, tr2.state.opt_state)
    tr2.state = TrainState(params=p0, opt_state=o0, step=int(o0.step))
    for a, b in zip(jax.tree.leaves(tr.state.opt_state.wire),
                    jax.tree.leaves(tr2.state.opt_state.wire)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    m1 = tr.step(batch)
    m2 = tr2.step(batch)
    assert m1["loss"] == m2["loss"]
    for tree in ("params",):
        for a, b in zip(jax.tree.leaves(getattr(tr.state, tree)),
                        jax.tree.leaves(getattr(tr2.state, tree))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(tr.state.opt_state.residual),
                    jax.tree.leaves(tr2.state.opt_state.residual)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # a narrower (params-only-payload) checkpoint cannot silently restore
    # into the widened trainer: structure mismatch fails loudly
    tr3 = CollaborativeTrainer(
        loss, params, topo, CDMSGD(5e-3, mu=0.9, fused=True),
        schedule="overlap", exchange="int8", donate=False)
    d2 = str(tmp_path / "ckpt_narrow")
    save_train_state(d2, tr3.state.step, tr3.state.params,
                     tr3.state.opt_state)
    with pytest.raises((KeyError, ValueError)):
        restore_train_state(d2, tr2.state.params, tr2.state.opt_state)
