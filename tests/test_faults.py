"""ISSUE-6: bounded-staleness exchange with straggler/drop tolerance.

The depth-S wire ring (repro.core.consensus.WireRing) + the deterministic
fault-injection layer (repro.core.faults.FaultSchedule), stacked execution
mode.  The sharded half (real shard_map + ppermutes, subprocess mesh) is
in tests/test_sharded.py::test_sharded_bounded_staleness_acceptance.

Covered here:
* FaultSchedule spec grammar, determinism, periodicity, validation
  (incl. the step-0-publishes anchor), arrival tables and accounting;
* arrival_masked_pi row-stochasticity;
* MixingProgram staleness/faults axes: validation, trivial-fault
  normalization, EF incompatibility, sync-schedule incompatibility;
* S=1/no-faults AND engaged-ring/no-faults are bit-for-bit today's
  overlap schedule;
* end-to-end stacked fault tolerance: injected stall + permanent link
  drop at S in {1, 2, 4} — every step completes, params stay finite,
  drift vs the fault-free run is bounded;
* the ring's carried slots are shifted copies, never re-quantized, and
  the runtime send_age counters match the host-side fault tables;
* WireRing checkpoints round-trip bit-exact;
* Lyapunov: bounded_staleness_consensus_bound monotone in S, reducing
  to schedule_consensus_bound at S=1/no-faults.
"""

import functools
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import consensus as C
from repro.core import engine
from repro.core import lyapunov as L
from repro.core.faults import (
    FaultSchedule,
    arrival_masked_pi,
    make_fault_schedule,
    trivial_faults,
)
from repro.core.optim import CDSGD, make_optimizer
from repro.core.topology import fixed_schedule, make_topology
from repro.core.trainer import CollaborativeTrainer
from repro.nn.paper_models import (
    classifier_loss,
    mlp_classifier_apply,
    mlp_classifier_template,
)
from repro.nn.param import init_params

N_AGENTS = 4
LOSS = functools.partial(classifier_loss, mlp_classifier_apply)
FAULT_SPEC = "stall:1:1:3,drop:0:2"   # agent 1 stalls 3 steps, link 0<-2 down
FAULT_DRIFT_BOUND = 5e-2              # measured ~1.5e-2 on this testbed


def _testbed(seed=0):
    params = init_params(mlp_classifier_template(8, 4, width=16, depth=2),
                         jax.random.PRNGKey(seed))
    topo = make_topology("ring", N_AGENTS)
    rng = np.random.default_rng(seed)
    batch = {"x": jnp.asarray(rng.standard_normal((N_AGENTS, 8, 8)), jnp.float32),
             "y": jnp.asarray(rng.integers(0, 4, (N_AGENTS, 8)), jnp.int32)}
    return params, topo, batch


def _max_diff(a, b):
    return max(jax.tree.leaves(jax.tree.map(
        lambda x, y: float(jnp.max(jnp.abs(x - y))), a, b)))


def _trainer(params, topo, *, staleness=1, fault=None, lr=0.05,
             exchange="int8"):
    return CollaborativeTrainer(LOSS, params, topo,
                                CDSGD(lr, fused=True), interpret=True,
                                exchange=exchange, schedule="overlap",
                                staleness=staleness, fault_schedule=fault)


# --------------------------------------------------------------------------
# FaultSchedule: grammar, determinism, tables
# --------------------------------------------------------------------------

def test_fault_schedule_grammar():
    assert make_fault_schedule("none", 4) is None
    assert make_fault_schedule(None, 4) is None

    f = make_fault_schedule("straggler:2:2", 4)
    # publishes at t % 3 == 0 only
    assert f.period == 3
    assert not f.straggle[0].any()
    assert f.straggle[1, 2] and f.straggle[2, 2]
    assert not f.straggle[:, [0, 1, 3]].any()

    f = make_fault_schedule("stall:1:1:3", 4)
    assert f.period == 4
    assert list(f.straggle[:, 1]) == [False, True, True, True]

    f = make_fault_schedule("drop:0:2", 4)
    assert f.period == 1
    assert not f.linkup[0, 0, 2]
    assert f.linkup.sum() == 16 - 1

    f = make_fault_schedule("droplink:3:1:2:2", 4)
    assert f.period == 4
    assert list(f.linkup[:, 3, 1]) == [True, True, False, False]

    # comma-join takes the lcm of the parts' periods
    f = make_fault_schedule(FAULT_SPEC, 4)
    assert f.period == 4 and not f.is_trivial
    d = f.describe()
    assert d["spec"] == FAULT_SPEC and d["n_agents"] == 4
    assert d["drop_fraction"] > 0 and d["straggle_fraction"] > 0


def test_fault_schedule_random_deterministic():
    a = make_fault_schedule("random:0.3:8", 5, seed=7)
    b = make_fault_schedule("random:0.3:8", 5, seed=7)
    c = make_fault_schedule("random:0.3:8", 5, seed=8)
    assert np.array_equal(a.linkup, b.linkup)
    assert not np.array_equal(a.linkup, c.linkup)
    # diag never drops, and some off-diag link actually did
    assert all(a.linkup[t].diagonal().all() for t in range(a.period))
    assert not a.linkup.all()
    a.validate()


def test_fault_schedule_validation():
    with pytest.raises(ValueError, match="agent"):
        make_fault_schedule("straggler:9:2", 4)
    with pytest.raises(ValueError, match="start must be >= 1"):
        # a stall window touching step 0 breaks the publishes-at-0 anchor
        make_fault_schedule("stall:1:0:3", 4)
    with pytest.raises(ValueError):
        make_fault_schedule("bogus:1:2", 4)
    # hand-built schedules go through the same validator
    f = trivial_faults(4)
    bad = FaultSchedule(name="bad", n_agents=4, period=1,
                        straggle=f.straggle,
                        linkup=~f.linkup)  # diag down
    with pytest.raises(ValueError, match="diag"):
        bad.validate()


def test_fault_tables_send_age_and_arrival():
    """The host-side tables implement the exact send_age recurrence the
    runtime carries: a stalled sender's published payload ages by 1 per
    missed step, capped at S (= masked), and arrive = linkup AND age < S
    with the self link always up."""
    f = make_fault_schedule(FAULT_SPEC, 4)
    tb = f.tables(2)
    # agent 1 stalls at t=1..3: age 0,1,2,2 (capped at S=2)
    assert list(tb["send_age"][:, 1]) == [0, 1, 2, 2]
    assert not tb["send_age"][:, [0, 2, 3]].any()
    # at t=1 agent 1's payload is 1 step stale -> still arrives (S=2);
    # at t=2,3 it is S steps stale -> masked for every receiver but itself
    assert tb["arrive"][1][:, 1].all()
    for t in (2, 3):
        col = tb["arrive"][t][:, 1]
        assert col[1] and not col[[0, 2, 3]].any()
    # the dropped link 0<-2 is down at every step
    assert not tb["arrive"][:, 0, 2].any()
    # self links always arrive
    assert all(tb["arrive"][t].diagonal().all() for t in range(4))

    acc = f.arrival_accounting(2)
    assert len(acc) == f.period
    assert {"step", "arrived_links", "masked_links", "max_staleness",
            "mean_staleness"} <= set(acc[0])
    # t=1: only the drop masked, agent 1's slot is stale (staleness 2)
    assert acc[1]["masked_links"] == 1 and acc[1]["max_staleness"] == 2
    # t=2: drop + agent 1 masked for its 3 peers
    assert acc[2]["masked_links"] == 4


def test_arrival_masked_pi_row_stochastic():
    rng = np.random.default_rng(0)
    for _ in range(10):
        w = rng.random((5, 5)) + 0.1
        pi = w / w.sum(axis=1, keepdims=True)
        m = rng.random((5, 5)) < 0.6
        np.fill_diagonal(m, True)
        out = arrival_masked_pi(pi, m)
        np.testing.assert_allclose(out.sum(axis=1), 1.0, atol=1e-12)
        # masked off-diag entries are exactly zero, their mass on the diag
        off = ~m & ~np.eye(5, dtype=bool)
        assert (out[off] == 0).all()
        np.testing.assert_allclose(
            np.diag(out), np.diag(pi) + (pi * off).sum(axis=1), atol=1e-12)
    # all-arrived mask is the identity transform
    np.testing.assert_array_equal(
        arrival_masked_pi(pi, np.ones((5, 5), bool)), pi)


# --------------------------------------------------------------------------
# MixingProgram staleness/faults axes
# --------------------------------------------------------------------------

def test_mixing_program_fault_axes():
    topo = make_topology("ring", 4)
    p = C.make_mixing_program(topo)
    assert p.staleness == 1 and p.faults is None and not p.fault_tolerant
    assert p.is_trivial

    f = make_fault_schedule(FAULT_SPEC, 4)
    p = C.make_mixing_program(topo, staleness=3, faults=f)
    assert p.fault_tolerant and not p.is_trivial
    d = p.describe()
    assert d["staleness"] == 3 and d["faults"]["spec"] == FAULT_SPEC

    # trivial faults normalize away entirely
    p = C.make_mixing_program(topo, faults=trivial_faults(4))
    assert p.faults is None and not p.fault_tolerant

    with pytest.raises(ValueError, match="staleness"):
        C.make_mixing_program(topo, staleness=0)
    with pytest.raises(ValueError, match="error.feedback"):
        C.make_mixing_program(topo, exchange="int8", error_feedback=True,
                              faults=f)
    with pytest.raises(ValueError, match="n_agents|agents"):
        C.make_mixing_program(topo, faults=make_fault_schedule("drop:0:2", 5))


def test_sync_schedule_rejects_fault_program():
    params, topo, _ = _testbed()
    with pytest.raises(ValueError, match="overlap"):
        CollaborativeTrainer(LOSS, params, topo, CDSGD(0.05, fused=True),
                             interpret=True, schedule="sync",
                             staleness=2)


# --------------------------------------------------------------------------
# bit-for-bit: the ring at S=1/no-faults IS today's overlap schedule
# --------------------------------------------------------------------------

def test_no_fault_paths_bitwise_equal_plain_overlap():
    """Three configs must produce bit-identical trajectories: plain
    overlap, staleness=1 + fault_schedule='none' (normalized away), and
    the ENGAGED ring at S in {2, 4} with no faults (sel == 0 selects the
    fresh generation and the all-arrived mask is exact)."""
    params, topo, batch = _testbed()
    ref = _trainer(params, topo)
    for _ in range(8):
        ref.step(batch)

    for kw in ({"staleness": 1, "fault": "none"},
               {"staleness": 2}, {"staleness": 4}):
        tr = _trainer(params, topo, **kw)
        for _ in range(8):
            tr.step(batch)
        assert _max_diff(ref.state.params, tr.state.params) == 0.0, kw


# --------------------------------------------------------------------------
# end-to-end stacked fault tolerance
# --------------------------------------------------------------------------

@pytest.mark.parametrize("staleness", [1, 2, 4])
def test_stacked_fault_drift_bounded(staleness):
    """Injected stall (one sender s_j up to S stale for a 3-step window)
    plus a permanently dropped link: training completes every step, the
    params stay finite, and the drift vs the fault-free overlap run is
    bounded — the faults cost accuracy smoothly instead of stalling or
    diverging the run."""
    params, topo, batch = _testbed()
    ref = _trainer(params, topo)
    tr = _trainer(params, topo, staleness=staleness, fault=FAULT_SPEC)
    losses = []
    for _ in range(12):
        ref.step(batch)
        losses.append(tr.step(batch)["loss"])
    assert all(np.isfinite(l) for l in losses)
    assert all(jnp.all(jnp.isfinite(x))
               for x in jax.tree.leaves(tr.state.params))
    drift = _max_diff(ref.state.params, tr.state.params)
    assert 0 < drift < FAULT_DRIFT_BOUND, drift
    # the runtime send_age counters match the host-side fault tables at
    # the step the wire is now positioned for (consumption step = 12)
    f = tr.program.faults
    tb = f.tables(staleness)
    np.testing.assert_array_equal(
        np.asarray(tr.state.opt_state.wire.send_age),
        tb["send_age"][12 % f.period])
    # every masked mixing row still sums to exactly 1 (float64 tables)
    ft = C._fault_tables(tr.program)
    w = ft["weights"]          # (PW, A, A+1) self-separated form
    np.testing.assert_allclose(w.sum(axis=2), 1.0, atol=1e-12)


def test_ring_slots_are_shifted_copies_never_requantized():
    """advance_wire pushes the fresh generation and SHIFTS the carried
    ones bitwise — a carried slot is never re-quantized, so it keeps the
    SR stream of its original (step, agent, bucket, payload) seed and can
    never alias a live stream (the structural half of the wire_seed ring
    test in tests/test_mixing.py)."""
    params, topo, batch = _testbed()
    tr = _trainer(params, topo, staleness=3, fault=FAULT_SPEC)
    prev = jax.tree.map(lambda x: np.asarray(x), tr.state.opt_state.wire)
    for _ in range(5):
        tr.step(batch)
        cur = jax.tree.map(lambda x: np.asarray(x), tr.state.opt_state.wire)
        for (pp, ps), (cp, cs) in zip(prev.slots, cur.slots):
            np.testing.assert_array_equal(cp[:, 1:], pp[:, :-1])
            np.testing.assert_array_equal(cs[:, 1:], ps[:, :-1])
        prev = cur


def test_wire_ring_checkpoint_roundtrip():
    from repro.checkpoint import restore_train_state, save_train_state
    params, topo, batch = _testbed()
    tr = _trainer(params, topo, staleness=3, fault="straggler:2:2")
    for _ in range(5):
        tr.step(batch)
    st = tr.state
    assert isinstance(st.opt_state.wire, C.WireRing)
    with tempfile.TemporaryDirectory() as d:
        save_train_state(d, st.step, st.params, st.opt_state)
        _, o2 = restore_train_state(d, st.params, st.opt_state)
    for a, b in zip(jax.tree.leaves(st.opt_state), jax.tree.leaves(o2)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_stacked_dependency_report_labels_ring_as_wire():
    """The jaxpr taint analysis treats every WireRing leaf (slots + age
    counters) as carried wire state with no change — stacked mode has no
    collectives, so the report's flags must show the fault path adds no
    param/batch dependency to any exchange."""
    params, topo, batch = _testbed()
    tr = _trainer(params, topo, staleness=2, fault=FAULT_SPEC)
    rep = engine.exchange_dependency_report(
        tr._program.step_fn, tr.state.params, tr.state.opt_state, batch)
    assert rep["n_ppermutes"] == 0          # dense stacked mixing
    assert not rep["depends_on_params"] and not rep["depends_on_batch"]


# --------------------------------------------------------------------------
# Lyapunov: bounded-staleness consensus bound
# --------------------------------------------------------------------------

def test_bounded_staleness_bound_monotone_and_reduces():
    topo = make_topology("ring", 4)
    f = make_fault_schedule(FAULT_SPEC, 4)
    # S=1, no faults: exactly Proposition 1's schedule bound
    assert L.bounded_staleness_consensus_bound(0.01, 1.0, topo) == \
        pytest.approx(L.schedule_consensus_bound(
            0.01, 1.0, fixed_schedule(topo)))
    bounds = [L.bounded_staleness_consensus_bound(
        0.01, 1.0, topo, staleness=S, faults=f) for S in (1, 2, 4, 8)]
    # monotone non-decreasing in S (staler payloads, weaker guarantee)
    assert all(b1 >= b0 for b0, b1 in zip(bounds, bounds[1:])), bounds
    assert all(np.isfinite(b) and b > 0 for b in bounds)
    # faults strictly weaken the contraction vs the fault-free schedule
    assert L.masked_effective_lambda2(topo, f, 1) > \
        L.masked_effective_lambda2(topo, None, 1)
    with pytest.raises(ValueError):
        L.bounded_staleness_consensus_bound(0.01, 1.0, topo, staleness=0)
