"""Consensus mixing invariants: the operator w = Pi x (paper eq. 5/6)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st, hnp

from repro.core.consensus import (
    FactoredMix,
    consensus_error_pytree,
    consensus_error_stacked,
    mix_pytree_list,
    mix_pytree_stacked,
    mix_stacked,
)
from repro.core.topology import make_topology


@given(
    x=hnp.arrays(np.float32, (5, 7), elements=st.floats(-10, 10, width=32)),
)
@settings(max_examples=30, deadline=None)
def test_mixing_preserves_mean(x):
    """1^T Pi = 1^T  =>  the agent-average is invariant under mixing."""
    t = make_topology("ring", 5)
    mixed = mix_stacked(jnp.asarray(t.pi), jnp.asarray(x))
    np.testing.assert_allclose(np.mean(np.asarray(mixed), 0), x.mean(0),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("name", ["ring", "chain", "torus", "fully_connected"])
def test_mixing_contracts_consensus_error(name):
    """||x - mean|| shrinks by at least lambda_2 per mixing round."""
    t = make_topology(name, 8)
    x = jnp.asarray(np.random.randn(8, 33).astype(np.float32))
    e0 = float(consensus_error_stacked(x))
    x1 = mix_stacked(jnp.asarray(t.pi), x)
    e1 = float(consensus_error_stacked(x1))
    assert e1 <= t.lambda2 * e0 + 1e-5


def test_stacked_and_list_mixing_agree():
    t = make_topology("erdos_renyi", 6, seed=3)
    trees = [{"a": jnp.asarray(np.random.randn(3, 4).astype(np.float32)),
              "b": jnp.asarray(np.random.randn(2).astype(np.float32))}
             for _ in range(6)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
    mixed_stacked = mix_pytree_stacked(jnp.asarray(t.pi), stacked)
    mixed_list = mix_pytree_list(t.pi, trees)
    for j in range(6):
        np.testing.assert_allclose(np.asarray(mixed_stacked["a"][j]),
                                   np.asarray(mixed_list[j]["a"]), rtol=2e-5, atol=2e-5)


def test_uniform_mixing_reaches_exact_consensus_in_one_round():
    t = make_topology("fully_connected", 5)
    x = jnp.asarray(np.random.randn(5, 9).astype(np.float32))
    x1 = mix_stacked(jnp.asarray(t.pi), x)
    assert float(consensus_error_stacked(x1)) < 1e-5


def test_factored_mix_equals_kron_dense():
    """Sequential per-axis mixing == Kronecker-product Pi (DESIGN.md §5)."""
    ta = make_topology("ring", 4)
    tb = make_topology("fully_connected", 2)
    fm = FactoredMix((("a", ta), ("b", tb)))
    pi = fm.dense_pi()
    assert pi.shape == (8, 8)
    assert np.allclose(pi.sum(0), 1) and np.allclose(pi.sum(1), 1)
    assert fm.lambda2 == pytest.approx(ta.lambda2)
    x = np.random.randn(8, 5).astype(np.float32)
    want = pi @ x
    # emulate sequential mixing on the reshaped (4, 2, 5) tensor
    xr = x.reshape(4, 2, 5)
    step1 = np.einsum("jl,lbe->jbe", ta.pi, xr)          # mix over axis a
    step2 = np.einsum("km,jme->jke", tb.pi, step1)       # mix over axis b
    np.testing.assert_allclose(step2.reshape(8, 5), want, rtol=1e-5, atol=1e-5)


def test_consensus_error_pytree_zero_at_consensus():
    x = jnp.ones((4, 3))
    tree = {"w": x, "b": 2 * x}
    assert float(consensus_error_pytree(tree)) == pytest.approx(0.0, abs=1e-6)
