"""End-to-end system behaviour: the paper's experimental claims at CPU scale.

These are the fast versions of the benchmarks in benchmarks/ — each asserts
a *relative ordering* the paper reports (§5), on the synthetic stand-in
dataset (offline container; see DESIGN.md §6).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_topology, make_optimizer
from repro.core.trainer import CollaborativeTrainer, train_loop
from repro.data import AgentPartitioner, make_classification
from repro.nn.paper_models import (
    classifier_loss,
    mlp_classifier_apply,
    mlp_classifier_template,
)
from repro.nn.param import init_params

LOSS = functools.partial(classifier_loss, mlp_classifier_apply)


@pytest.fixture(scope="module")
def data():
    return make_classification(2048, n_classes=10, dim=32, seed=0)


@pytest.fixture(scope="module")
def params():
    return init_params(mlp_classifier_template(32, 10, width=50, depth=4),
                       jax.random.PRNGKey(0))


def run(optname, data, params, *, steps=120, agents=5, topology="fully_connected",
        lr=0.05, batch=64, **kw):
    train, val = data
    part = AgentPartitioner(train, agents, seed=0)
    topo = make_topology(topology, agents)
    tr = CollaborativeTrainer(LOSS, params, topo, make_optimizer(optname, lr, **kw))
    train_loop(tr, part.batches(batch), steps)
    ev = tr.evaluate({"x": jnp.asarray(val.x), "y": jnp.asarray(val.y)})
    last = tr.history.rows[-1]
    return {"train_acc": last["acc"], "val_acc": ev["acc_mean"],
            "acc_var": ev["acc_var"], "consensus": last["consensus_error"],
            "trainer": tr}


def test_cdsgd_reaches_centralized_accuracy(data, params):
    """Fig 1(a): CDSGD eventually comparable to centralized SGD."""
    sgd = run("sgd", data, params)
    cdsgd = run("cdsgd", data, params)
    assert cdsgd["val_acc"] > 0.85
    assert cdsgd["val_acc"] > sgd["val_acc"] - 0.08


def test_cdmsgd_converges_and_agents_agree(data, params):
    res = run("cdmsgd", data, params, mu=0.9)
    assert res["val_acc"] > 0.9
    assert res["acc_var"] < 1e-3, "fully-connected agents must near-agree"


def test_cdmsgd_competitive_with_fedavg(data, params):
    """Fig 1(b): CDMSGD reaches FedAvg-level steady-state accuracy."""
    fed = run("fedavg", data, params, mu=0.9)
    cdm = run("cdmsgd", data, params, mu=0.9)
    assert cdm["val_acc"] >= fed["val_acc"] - 0.05


def test_sparser_topology_less_stable_consensus(data, params):
    """Fig 2(b): sparser graph (larger lambda_2) -> larger consensus error."""
    ring = run("cdmsgd", data, params, topology="ring", agents=8, mu=0.9)
    full = run("cdmsgd", data, params, topology="fully_connected", agents=8, mu=0.9)
    assert ring["consensus"] > full["consensus"]


def test_network_size_slows_convergence(data, params):
    """Fig 2(a): more agents -> slower early convergence (same final level).

    The paper compares at equal data consumed, so the *global* batch per
    step is held fixed (128) — with a fixed per-agent batch the larger
    network would see N/2 x more data per step and the ordering inverts.
    """
    small = run("cdsgd", data, params, agents=2, steps=60, batch=64)
    large = run("cdsgd", data, params, agents=16, steps=60, batch=8)
    assert small["train_acc"] >= large["train_acc"] - 0.02


def test_mean_model_extraction(data, params):
    res = run("cdmsgd", data, params, mu=0.9)
    tr = res["trainer"]
    mean_params = tr.mean_params()
    train, val = data
    loss, metrics = LOSS(mean_params, {"x": jnp.asarray(val.x), "y": jnp.asarray(val.y)})
    assert float(metrics["acc"]) > 0.9
