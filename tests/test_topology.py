"""Topology / agent-interaction-matrix properties (paper Assumption 2)."""

import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.core.topology import (
    Topology,
    chain_adjacency,
    erdos_renyi_adjacency,
    eigenvalues,
    fully_connected_adjacency,
    lambda_2,
    lambda_n,
    lazy,
    make_topology,
    metropolis_pi,
    ring_adjacency,
    spectral_gap,
    torus2d_adjacency,
    uniform_pi,
    validate_pi,
)

TOPOLOGIES = ["fully_connected", "ring", "chain", "star", "torus", "erdos_renyi"]


@pytest.mark.parametrize("name", TOPOLOGIES)
@pytest.mark.parametrize("n", [2, 4, 5, 8, 16])
def test_pi_satisfies_assumption2(name, n):
    t = make_topology(name, n)
    pi = t.pi
    assert np.allclose(pi.sum(0), 1.0), "columns must sum to 1"
    assert np.allclose(pi.sum(1), 1.0), "rows must sum to 1"
    assert np.allclose(pi, pi.T), "undirected graph -> symmetric Pi"
    ev = eigenvalues(pi)
    assert ev[0] == pytest.approx(1.0, abs=1e-9)
    if n > 1:
        assert ev[1] < 1.0 - 1e-12, "connected graph -> simple eigenvalue 1"


@pytest.mark.parametrize("name", TOPOLOGIES)
def test_lazy_blend_is_positive_definite(name):
    """Assumption 2(d): I >= Pi > 0 holds for the lazy blend."""
    t = make_topology(name, 8, lazy_beta=0.5)
    assert t.lambdan > 0.0
    validate_pi(t.pi, require_positive=True)


@given(n=st.integers(2, 12), seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_metropolis_weights_always_doubly_stochastic(n, seed):
    adj = erdos_renyi_adjacency(n, 0.6, seed)
    pi = metropolis_pi(adj)
    validate_pi(pi)


def test_uniform_pi_is_exact_averaging():
    pi = uniform_pi(5)
    x = np.random.randn(5, 3)
    mixed = pi @ x
    assert np.allclose(mixed, x.mean(0, keepdims=True))


def test_spectral_ordering_density():
    """Denser graphs have larger spectral gap (paper §5.2 discussion)."""
    n = 16
    gaps = {name: make_topology(name, n).spectral_gap
            for name in ["chain", "ring", "torus", "fully_connected"]}
    assert gaps["chain"] < gaps["ring"] < gaps["torus"] < gaps["fully_connected"] + 1e-12


def test_ring_is_circulant_with_three_point_stencil():
    t = make_topology("ring", 8)
    sw = t.shift_weights()
    assert sw is not None and set(sw) == {0, 1, 7}
    assert all(abs(w - 1 / 3) < 1e-12 for w in sw.values())
    assert t.degree() == 2


def test_chain_is_not_circulant():
    assert make_topology("chain", 8).shift_weights() is None


def test_disconnected_rejected():
    bad = np.eye(4)
    with pytest.raises(ValueError):
        validate_pi(bad)


def test_torus_shape_validation():
    with pytest.raises(ValueError):
        make_topology("torus", 12, torus_shape=(5, 3))


def test_neighbor_lists_match_pi():
    t = make_topology("ring", 6)
    nbrs = t.neighbor_lists()
    for j, lst in enumerate(nbrs):
        assert set(l for l, _ in lst) == {(j - 1) % 6, j, (j + 1) % 6}
