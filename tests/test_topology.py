"""Topology / agent-interaction-matrix properties (paper Assumption 2)."""

import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.core.topology import (
    Topology,
    chain_adjacency,
    erdos_renyi_adjacency,
    eigenvalues,
    fully_connected_adjacency,
    lambda_2,
    lambda_n,
    lazy,
    make_topology,
    metropolis_pi,
    ring_adjacency,
    spectral_gap,
    torus2d_adjacency,
    uniform_pi,
    validate_pi,
)

TOPOLOGIES = ["fully_connected", "ring", "chain", "star", "torus", "erdos_renyi"]


@pytest.mark.parametrize("name", TOPOLOGIES)
@pytest.mark.parametrize("n", [2, 4, 5, 8, 16])
def test_pi_satisfies_assumption2(name, n):
    t = make_topology(name, n)
    pi = t.pi
    assert np.allclose(pi.sum(0), 1.0), "columns must sum to 1"
    assert np.allclose(pi.sum(1), 1.0), "rows must sum to 1"
    assert np.allclose(pi, pi.T), "undirected graph -> symmetric Pi"
    ev = eigenvalues(pi)
    assert ev[0] == pytest.approx(1.0, abs=1e-9)
    if n > 1:
        assert ev[1] < 1.0 - 1e-12, "connected graph -> simple eigenvalue 1"


@pytest.mark.parametrize("name", TOPOLOGIES)
def test_lazy_blend_is_positive_definite(name):
    """Assumption 2(d): I >= Pi > 0 holds for the lazy blend."""
    t = make_topology(name, 8, lazy_beta=0.5)
    assert t.lambdan > 0.0
    validate_pi(t.pi, require_positive=True)


@given(n=st.integers(2, 12), seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_metropolis_weights_always_doubly_stochastic(n, seed):
    adj = erdos_renyi_adjacency(n, 0.6, seed)
    pi = metropolis_pi(adj)
    validate_pi(pi)


def test_uniform_pi_is_exact_averaging():
    pi = uniform_pi(5)
    x = np.random.randn(5, 3)
    mixed = pi @ x
    assert np.allclose(mixed, x.mean(0, keepdims=True))


def test_spectral_ordering_density():
    """Denser graphs have larger spectral gap (paper §5.2 discussion)."""
    n = 16
    gaps = {name: make_topology(name, n).spectral_gap
            for name in ["chain", "ring", "torus", "fully_connected"]}
    assert gaps["chain"] < gaps["ring"] < gaps["torus"] < gaps["fully_connected"] + 1e-12


def test_ring_is_circulant_with_three_point_stencil():
    t = make_topology("ring", 8)
    sw = t.shift_weights()
    assert sw is not None and set(sw) == {0, 1, 7}
    assert all(abs(w - 1 / 3) < 1e-12 for w in sw.values())
    assert t.degree() == 2


def test_chain_is_not_circulant():
    assert make_topology("chain", 8).shift_weights() is None


def test_disconnected_rejected():
    bad = np.eye(4)
    with pytest.raises(ValueError):
        validate_pi(bad)


def test_torus_shape_validation():
    with pytest.raises(ValueError):
        make_topology("torus", 12, torus_shape=(5, 3))


def test_neighbor_lists_match_pi():
    t = make_topology("ring", 6)
    nbrs = t.neighbor_lists()
    for j, lst in enumerate(nbrs):
        assert set(l for l, _ in lst) == {(j - 1) % 6, j, (j + 1) % 6}


# -------------------------------------------------------------------------
# TopologySchedule (time-varying Pi_t, B-connectivity)
# -------------------------------------------------------------------------


def test_fixed_schedule_matches_topology():
    from repro.core.topology import fixed_schedule
    t = make_topology("ring", 8)
    s = fixed_schedule(t)
    assert s.period == 1 and s.is_static
    assert s.effective_lambda2() == pytest.approx(t.lambda2, abs=1e-9)
    assert s.effective_spectral_gap() == pytest.approx(t.spectral_gap, abs=1e-9)
    assert s.max_degree() == s.mean_degree() == t.degree()


def test_alternating_schedule_product_beats_either_factor():
    """Submultiplicativity on the disagreement subspace: the full-period
    product contraction is bounded by the product of the per-matrix
    lambda2's (hence by the slowest single factor), so the per-step
    effective lambda2 never exceeds the factors' geometric mean."""
    from repro.core.topology import make_topology_schedule
    s = make_topology_schedule("alternating:ring:torus", 8)
    assert s.period == 2
    lams = [t.lambda2 for t in s.topologies]
    period_contraction = s.effective_lambda2() ** s.period
    assert period_contraction <= np.prod(lams) + 1e-12
    assert period_contraction <= min(lams) + 1e-12
    assert s.effective_lambda2() <= float(np.prod(lams)) ** (1 / 2) + 1e-12
    assert 0.0 < s.effective_spectral_gap() < 1.0


def test_gossip_schedule_b_connected_and_doubly_stochastic():
    from repro.core.topology import make_topology_schedule
    s = make_topology_schedule("gossip:8", 6, seed=0)
    assert s.period == 8
    for t in s.topologies:
        assert np.allclose(t.pi.sum(0), 1.0) and np.allclose(t.pi, t.pi.T)
        # a single pair is NOT connected for n > 2 ...
        assert t.lambda2 == pytest.approx(1.0, abs=1e-9)
    # ... but the union over the period mixes (B-connectivity)
    assert s.effective_lambda2() < 1.0 - 1e-6
    assert s.mean_degree() == 1.0
    # deterministic: same seed -> same schedule
    s2 = make_topology_schedule("gossip:8", 6, seed=0)
    for a, b in zip(s.pi_stack(), s2.pi_stack()):
        np.testing.assert_array_equal(a, b)


def test_schedule_rounds_sharpen_effective_gap():
    """More inner rounds -> smaller effective lambda2 (never larger; equal
    only at the fp floor, e.g. uniform Pi already projects to the mean)."""
    from repro.core.topology import make_topology_schedule
    for spec_name in ("ring", "alternating:ring:torus"):
        s = make_topology_schedule(spec_name, 8)
        lams = [s.effective_lambda2(k) for k in (1, 2, 3)]
        assert lams[0] > lams[1] > lams[2] > 0.0
    # uniform fully-connected: one round already hits exact averaging
    fc = make_topology_schedule("fully_connected", 8)
    assert fc.effective_lambda2(1) == pytest.approx(0.0, abs=1e-7)
    assert fc.effective_lambda2(3) == pytest.approx(0.0, abs=1e-7)


def test_schedule_validate_rejects_disconnected_union():
    from repro.core.topology import Topology, TopologySchedule
    bad = TopologySchedule(
        name="bad", topologies=(Topology("i1", np.eye(4)),
                                Topology("i2", np.eye(4))))
    with pytest.raises(ValueError, match="B-connected"):
        bad.validate()


def test_schedule_entries_must_share_n_agents():
    from repro.core.topology import TopologySchedule
    with pytest.raises(ValueError, match="n_agents"):
        TopologySchedule(name="bad",
                         topologies=(make_topology("ring", 4),
                                     make_topology("ring", 6)))


def test_schedule_diagnostics_record():
    from repro.core.topology import make_topology_schedule
    d = make_topology_schedule("alternating", 8).diagnostics(rounds=2)
    assert d["period"] == 2 and d["rounds"] == 2
    assert len(d["per_matrix_gap"]) == 2
    assert d["transfers_per_step"] == d["mean_degree"] * 2
    assert 0 < d["effective_gap"] < 1
