"""Shared test fixtures. NOTE: no XLA device-count flags here — smoke tests
and benches must see 1 CPU device; only dryrun subprocesses get 512."""

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _np_seed():
    np.random.seed(0)
