"""Per-architecture smoke tests (REDUCED configs, as assigned) + decode
consistency: every arch runs forward/loss/one-train-step on CPU with shape
and finiteness assertions; cached decode must agree with the parallel
forward under teacher forcing.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.core import make_topology, make_optimizer
from repro.core.trainer import CollaborativeTrainer
from repro.nn import (
    count_params,
    decode_step,
    encode_for_decode,
    forward,
    init_cache,
    init_params,
    loss_fn,
    model_template,
)

B, S = 2, 16
ALL_ARCHS = list_archs()


def make_batch(cfg, b=B, s=S, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "inputs": jnp.asarray(rng.integers(1, cfg.vocab_size, (b, s)), jnp.int32),
        "targets": jnp.asarray(rng.integers(1, cfg.vocab_size, (b, s)), jnp.int32),
    }
    if cfg.modality in ("audio", "vlm"):
        batch["frontend"] = jnp.asarray(
            rng.normal(size=(b, cfg.frontend_tokens, cfg.frontend_dim)), jnp.float32)
    return batch


@functools.lru_cache(maxsize=None)
def reduced_setup(name):
    cfg = get_config(name).reduced()
    params = init_params(model_template(cfg), jax.random.PRNGKey(0))
    return cfg, params


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_reduced_config_contract(name):
    cfg = get_config(name).reduced()
    assert cfg.n_layers == 2
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_shapes_and_finite(name):
    cfg, params = reduced_setup(name)
    batch = make_batch(cfg)
    logits, aux = forward(cfg, params, batch)
    exp_seq = S + (cfg.frontend_tokens if cfg.modality in ("audio", "vlm")
                   and not cfg.is_encoder_decoder else 0)
    assert logits.shape == (B, exp_seq, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_one_train_step_no_nans(name):
    """One CDSGD step over 2 agents: loss finite, params finite, step count."""
    cfg, params = reduced_setup(name)
    topo = make_topology("fully_connected", 2)
    opt = make_optimizer("cdsgd", 0.01)
    trainer = CollaborativeTrainer(lambda p, b: loss_fn(cfg, p, b), params, topo, opt)
    batch = make_batch(cfg)
    stacked = jax.tree.map(lambda x: jnp.stack([x, x]), batch)
    m = trainer.step(stacked)
    assert np.isfinite(m["loss"])
    leaves = jax.tree.leaves(trainer.state.params)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in leaves)


DECODE_CONSISTENCY_ARCHS = [
    "granite-3-8b",        # GQA full attention
    "starcoder2-7b",       # layernorm + non-gated MLP
    "gemma3-1b",           # local/global interleave
    "h2o-danube-3-4b",     # sliding window
    "deepseek-v2-236b",    # MLA absorbed decode + MoE
    "rwkv6-1.6b",          # recurrent state
    "hymba-1.5b",          # hybrid attn + mamba
]


@pytest.mark.parametrize("name", DECODE_CONSISTENCY_ARCHS)
def test_decode_matches_forward(name):
    """Teacher-forced cached decode == parallel forward logits."""
    cfg, params = reduced_setup(name)
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S)), jnp.int32)
    logits_fwd, _ = forward(cfg, params, {"inputs": toks})

    cache = init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        logits_t, cache = decode_step(cfg, params, cache, toks[:, t : t + 1], jnp.int32(t))
        outs.append(logits_t)
    logits_dec = jnp.stack(outs, axis=1)
    diff = float(jnp.max(jnp.abs(logits_dec - logits_fwd)))
    scale = float(jnp.max(jnp.abs(logits_fwd))) + 1e-6
    assert diff / scale < 5e-2, f"decode/forward mismatch: {diff} (scale {scale})"


def test_encdec_decode_runs():
    cfg, params = reduced_setup("seamless-m4t-medium")
    fe = jnp.asarray(np.random.default_rng(0).normal(
        size=(B, cfg.frontend_tokens, cfg.frontend_dim)), jnp.float32)
    cache = init_cache(cfg, B, S, enc_len=cfg.frontend_tokens)
    cache["enc_out"] = encode_for_decode(cfg, params, fe)
    tok = jnp.ones((B, 1), jnp.int32)
    for t in range(3):
        logits, cache = decode_step(cfg, params, cache, tok, jnp.int32(t))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", ["deepseek-v2-236b", "kimi-k2-1t-a32b"])
def test_moe_aux_loss_nonzero(name):
    cfg, params = reduced_setup(name)
    loss, metrics = loss_fn(cfg, params, make_batch(cfg))
    assert float(metrics["moe_aux"]) > 0.0


def test_full_config_param_counts_in_range():
    """Full (non-reduced) configs: analytic parameter counts are plausible."""
    expect = {
        "deepseek-v2-236b": (200e9, 280e9),
        "kimi-k2-1t-a32b": (0.8e12, 1.3e12),
        "rwkv6-1.6b": (1.2e9, 2.2e9),
        "granite-3-8b": (6e9, 10e9),
        "starcoder2-7b": (6e9, 9e9),
        "gemma3-1b": (0.8e9, 1.7e9),
        "hymba-1.5b": (1.0e9, 2.2e9),
        "h2o-danube-3-4b": (3e9, 5e9),
        "seamless-m4t-medium": (0.8e9, 1.8e9),
        "internvl2-2b": (1.5e9, 3e9),
    }
    for name, (lo, hi) in expect.items():
        n = get_config(name).param_count()
        assert lo <= n <= hi, f"{name}: {n:,} outside [{lo:,.0f}, {hi:,.0f}]"


def test_moe_active_params_much_smaller_than_total():
    cfg = get_config("kimi-k2-1t-a32b")
    total, active = cfg.param_count(), cfg.active_param_count()
    assert active < 0.1 * total
