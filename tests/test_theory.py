"""Quantitative checks of the paper's analysis (Prop. 1, Thm 1, Thm 3).

The test problem is the strongly convex quadratic
``f_j(x) = 0.5 (x - c_j)^T A_j (x - c_j)`` with per-agent data (centers),
where every constant of the theory (H_m, gamma_m, L) is known in closed
form — so we can check the paper's *numbers*, not just trends.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lyapunov
from repro.core.consensus import consensus_error_stacked, mix_stacked
from repro.core.optim import CDSGD, stacked_comm_ops
from repro.core.schedules import diminishing
from repro.core.topology import make_topology

N, D = 5, 4


def make_quadratic(seed=0):
    rng = np.random.default_rng(seed)
    eigs = rng.uniform(0.5, 2.0, size=(N, D))     # H_m = 0.5, gamma_m = 2
    centers = rng.normal(size=(N, D))
    a = jnp.asarray(eigs, jnp.float32)
    c = jnp.asarray(centers, jnp.float32)

    def grad(x):                                  # (N, D) -> (N, D) exact grads
        return a * (x - c)

    return grad, a, c


def test_eq5_equals_lyapunov_sgd_identity():
    """Paper eq. 7: Pi x - a g == x - a (g + a^{-1}(I - Pi) x), exactly."""
    t = make_topology("ring", N)
    pi = jnp.asarray(t.pi, jnp.float32)
    x = jnp.asarray(np.random.randn(N, D), jnp.float32)
    g = jnp.asarray(np.random.randn(N, D), jnp.float32)
    lhs = pi @ x - 0.05 * g
    rhs = lyapunov.cdsgd_step_via_lyapunov(x, g, pi, 0.05)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("topo", ["ring", "fully_connected", "torus"])
def test_proposition1_consensus_radius(topo):
    """E||x_j - mean|| <= alpha L / (1 - lambda_2) at steady state."""
    grad, a, c = make_quadratic()
    t = make_topology(topo, N)
    pi = jnp.asarray(t.pi, jnp.float32)
    alpha = 0.05
    x = jnp.zeros((N, D))
    grad_norms = []
    for k in range(400):
        g = grad(x)
        grad_norms.append(float(jnp.max(jnp.linalg.norm(g, axis=1))))
        x = pi @ x - alpha * g
    err = float(consensus_error_stacked(x))
    l_bound = max(grad_norms[200:])                 # empirical L at steady state
    bound = lyapunov.consensus_bound(alpha, l_bound, t)
    if t.spectral_gap > 1e-9:
        assert err <= bound + 1e-6, f"{err} > Prop.1 bound {bound}"


def test_theorem1_linear_convergence_rate():
    """Deterministic gradients (Q=0): V(x_k) - V* decays at least as fast
    as the Theorem-1 envelope (1 - alpha H_hat zeta1)^k."""
    grad, a, c = make_quadratic()
    t = make_topology("ring", N, lazy_beta=0.5)     # Pi > 0 per Assumption 2d
    pi = jnp.asarray(t.pi, jnp.float32)
    alpha = 0.05
    const = lyapunov.TheoryConstants(
        gamma_m=2.0, h_m=0.5, alpha=alpha,
        lambda2=t.lambda2, lambdan=t.lambdan, zeta1=1.0, q=0.0, qm=1.0)
    assert 0 < const.contraction < 1

    def v_value(x):
        fsum = jnp.sum(0.5 * a * (x - c) ** 2)
        return float(lyapunov.lyapunov_value(fsum, x, pi, alpha))

    # V* via long optimization
    x = jnp.zeros((N, D))
    for _ in range(4000):
        g_eff = grad(x)
        x = pi @ x - alpha * g_eff
    v_star = v_value(x)

    x = jnp.asarray(np.random.default_rng(1).normal(size=(N, D)), jnp.float32)
    vals = []
    for _ in range(120):
        vals.append(v_value(x) - v_star)
        x = pi @ x - alpha * grad(x)
    vals = np.maximum(np.array(vals), 1e-12)
    envelope = vals[0] * const.contraction ** np.arange(len(vals))
    # envelope must upper-bound the decay until fp32 precision of V - V*
    # (~1e-6 x scale) takes over; small multiplicative slack
    mask = envelope > 1e-5 * vals[0]
    assert np.all(vals[mask] <= envelope[mask] * 1.05 + 1e-8)
    # and the iterates must actually converge
    assert vals[-1] < 1e-4 * vals[0]


def test_theorem3_diminishing_step_exact_consensus():
    """Proposition 2: alpha_k -> 0 with sum alpha_k = inf drives the
    consensus error to ~0 (vs a fixed-step floor)."""
    grad, a, c = make_quadratic()
    t = make_topology("ring", N)
    pi = jnp.asarray(t.pi, jnp.float32)
    sched = diminishing(theta=0.5, eps=1.0, t=1.0)

    x_dim = jnp.zeros((N, D))
    x_fix = jnp.zeros((N, D))
    for k in range(1500):
        x_dim = pi @ x_dim - sched(jnp.asarray(k)) * grad(x_dim)
        x_fix = pi @ x_fix - 0.05 * grad(x_fix)
    e_dim = float(consensus_error_stacked(x_dim))
    e_fix = float(consensus_error_stacked(x_fix))
    assert e_dim < 0.15 * e_fix, f"diminishing {e_dim} vs fixed {e_fix}"


def test_step_size_bound_formula():
    const = lyapunov.TheoryConstants(gamma_m=2.0, h_m=0.5, alpha=0.01,
                                     lambda2=0.8, lambdan=0.2, zeta1=1.0, qm=1.0)
    # eq. 15 expanded: (zeta1 - (1-lamN) Qm) / (gamma_m Qm)
    assert const.max_step_size == pytest.approx((1.0 - 0.8) / 2.0)
    assert const.gamma_hat == pytest.approx(2.0 + (1 - 0.2) / 0.01)
    assert const.h_hat == pytest.approx(0.5 + (1 - 0.8) / 0.02)


def test_noise_radius_scales_with_alpha():
    """Theorem 1 remark: smaller step -> smaller neighborhood radius."""
    radii = []
    for alpha in (0.1, 0.05, 0.01):
        const = lyapunov.TheoryConstants(gamma_m=2.0, h_m=0.5, alpha=alpha,
                                         lambda2=0.8, lambdan=0.2, q=1.0)
        radii.append(const.noise_radius)
    assert radii[0] > radii[1] > radii[2]


# -------------------------------------------------------------------------
# Schedule-aware Lyapunov bounds (time-varying Pi / multi-round i-CDSGD)
# -------------------------------------------------------------------------


def test_schedule_consensus_bound_reduces_to_prop1_when_static():
    from repro.core.lyapunov import consensus_bound, schedule_consensus_bound
    from repro.core.topology import fixed_schedule, make_topology
    t = make_topology("ring", 8)
    assert schedule_consensus_bound(0.01, 2.0, fixed_schedule(t)) == \
        pytest.approx(consensus_bound(0.01, 2.0, t), rel=1e-9)


def test_schedule_bound_monotone_in_rounds():
    """More inner consensus rounds -> tighter (never looser) consensus
    radius: the k-round product contracts the disagreement subspace at
    lambda2^k, so the Prop-1 radius a L / (1 - lambda_eff) is
    non-increasing in k (strictly decreasing off the trivial cases)."""
    from repro.core.lyapunov import schedule_consensus_bound
    from repro.core.topology import make_topology_schedule
    for spec in ("ring", "alternating:ring:torus"):
        s = make_topology_schedule(spec, 8)
        bounds = [schedule_consensus_bound(0.05, 1.0, s, k) for k in (1, 2, 4)]
        assert bounds[0] > bounds[1] > bounds[2]
    # a gossip-pair matrix is an idempotent projection (W^2 = W: averaging
    # the pair twice is averaging it once), so extra rounds buy exactly
    # nothing — the bound must be flat in k, not looser
    g = make_topology_schedule("gossip:8", 8)
    gb = [schedule_consensus_bound(0.05, 1.0, g, k) for k in (1, 2, 4)]
    assert gb[0] == pytest.approx(gb[1], rel=1e-9) == pytest.approx(gb[2], rel=1e-9)


def test_product_contraction_bounded_by_per_matrix_lambda2():
    """Time-varying Pi: the period product's disagreement contraction is
    bounded by the product of per-matrix contraction factors (so a
    schedule mixes at least as fast as its slowest telescoped factor).
    Gossip pairs show why the product view is necessary at all: each
    per-matrix lambda2 is exactly 1 (disconnected step) yet the product
    still contracts."""
    import numpy as np
    from repro.core.topology import make_topology_schedule
    s = make_topology_schedule("alternating:ring:fully_connected", 8)
    period_contraction = s.effective_lambda2() ** s.period
    assert period_contraction <= np.prod(
        [t.lambda2 for t in s.topologies]) + 1e-12
    g = make_topology_schedule("gossip:8", 6, seed=1)
    assert all(t.lambda2 == pytest.approx(1.0, abs=1e-9) for t in g.topologies)
    assert g.effective_lambda2() < 1.0


def test_schedule_theory_constants_contract():
    from repro.core.lyapunov import schedule_theory_constants
    from repro.core.topology import make_topology_schedule
    s = make_topology_schedule("ring", 8)
    c1 = schedule_theory_constants(0.05, gamma_m=2.0, h_m=0.5, schedule=s)
    c2 = schedule_theory_constants(0.05, gamma_m=2.0, h_m=0.5, schedule=s,
                                   rounds=2)
    # more rounds: stronger strong-convexity of V, faster contraction
    assert c2.h_hat > c1.h_hat
    assert c2.contraction < c1.contraction < 1.0


# -------------------------------------------------------------------------
# Momentum-consensus mixing constants (2010.11166)
# -------------------------------------------------------------------------


def test_momentum_contraction_mixed_restores_topology_rate():
    """Unmixed momentum gates the disagreement contraction at mu once
    mu > rho(Pi) (the momentum mode outlives the consensus mode — the
    noise-persistence mechanism of the large-lr instability); mixing the
    momentum with the same Pi restores the momentum-free rate rho(Pi)."""
    from repro.core.lyapunov import momentum_consensus_contraction
    t = make_topology("ring", 4)                  # rho(Pi) = 1/3
    rho_pi = momentum_consensus_contraction(t, mu=0.0)
    assert rho_pi == pytest.approx(1.0 / 3.0, abs=1e-9)
    assert momentum_consensus_contraction(t, 0.9, "none") == pytest.approx(0.9)
    assert momentum_consensus_contraction(t, 0.9, "mixed") == \
        pytest.approx(rho_pi)
    # below the topology rate, momentum never gates: both forms equal
    assert momentum_consensus_contraction(t, 0.2, "none") == \
        momentum_consensus_contraction(t, 0.2, "mixed") == pytest.approx(rho_pi)


def test_momentum_contraction_uses_modulus_not_lambda2():
    """Short even rings have lambda_N < 0 with |lambda_N| > lambda_2; the
    joint dynamics amplify whichever mode decays slowest, so the radius
    must be the modulus over ALL non-principal eigenvalues."""
    from repro.core.lyapunov import momentum_consensus_contraction
    t = make_topology("ring", 4)
    lams = np.linalg.eigvalsh(np.asarray(t.pi, np.float64))
    assert momentum_consensus_contraction(t, 0.0) == \
        pytest.approx(float(np.max(np.abs(lams[:-1]))), abs=1e-9)


def test_momentum_consensus_bound_ordering_and_schedules():
    """a L / (1 - rho): mixing can only tighten the steady-state consensus
    radius, strictly when mu > rho(Pi); reduces to the momentum-free
    Prop-1 radius framing and accepts TopologySchedules."""
    from repro.core.lyapunov import (momentum_consensus_bound,
                                     momentum_consensus_contraction)
    from repro.core.topology import make_topology_schedule
    t = make_topology("ring", 8)
    unmixed = momentum_consensus_bound(0.05, 1.0, t, 0.9, "none")
    mixed = momentum_consensus_bound(0.05, 1.0, t, 0.9, "mixed")
    assert mixed < unmixed
    # more inner rounds tighten the mixed bound further (rho^k)
    assert momentum_consensus_bound(0.05, 1.0, t, 0.9, "mixed", rounds=2) \
        < mixed
    s = make_topology_schedule("alternating:ring:fully_connected", 8)
    assert momentum_consensus_bound(0.05, 1.0, s, 0.9, "mixed") \
        <= momentum_consensus_bound(0.05, 1.0, s, 0.9, "none")
    assert momentum_consensus_contraction(s, 0.9, "mixed") < 1.0
    with pytest.raises(ValueError, match="momentum_mixing"):
        momentum_consensus_contraction(t, 0.9, "both")
    with pytest.raises(ValueError, match="mu"):
        momentum_consensus_contraction(t, 1.0)
