"""MixingProgram strategy layer: static / time-varying / multi-round / EF.

Pins the contracts of ISSUE 4:

* config-time validation (rounds >= 1, EF needs a quantized exchange,
  non-trivial programs need a fused optimizer);
* ``MultiRoundMixing(k=1)`` is bit-for-bit ``StaticMixing`` (the factory
  normalizes it to the static strategy, whose sync gather is the legacy
  path);
* multi-round semantics: ``x' = Pi^k x - alpha g`` against the dense
  matrix power, through the full trainer;
* time-varying semantics: ``Pi_t`` selected by the optimizer step, against
  the explicit per-step dense reference;
* error feedback: the EF-int8 trajectory tracks the f32 trajectory
  strictly better than plain int8 over 20 paper-testbed steps (the PR 2
  momentum/noise caveat measurably improved), and the residual telescopes
  (carried = quantized + residual exactly);
* wire accounting: k rounds = k x bytes, EF = +0 bytes;
* the overlap schedule composes with every strategy (round-1 carried).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import consensus as C
from repro.core import engine
from repro.core.optim import CDSGD, CDMSGD, stacked_comm_ops
from repro.core.topology import (
    fixed_schedule,
    make_topology,
    make_topology_schedule,
)
from repro.core.trainer import CollaborativeTrainer
from repro.nn.paper_models import (
    classifier_loss,
    mlp_classifier_apply,
    mlp_classifier_template,
)
from repro.nn.param import init_params

N_AGENTS = 4
LOSS = functools.partial(classifier_loss, mlp_classifier_apply)


def _testbed(seed=0):
    params = init_params(mlp_classifier_template(8, 4, width=16, depth=2),
                         jax.random.PRNGKey(seed))
    topo = make_topology("ring", N_AGENTS)
    rng = np.random.default_rng(seed)
    batch = {"x": jnp.asarray(rng.standard_normal((N_AGENTS, 8, 8)), jnp.float32),
             "y": jnp.asarray(rng.integers(0, 4, (N_AGENTS, 8)), jnp.int32)}
    return params, topo, batch


def _max_diff(a, b):
    return max(jax.tree.leaves(jax.tree.map(
        lambda x, y: float(jnp.max(jnp.abs(
            x.astype(jnp.float32) - y.astype(jnp.float32)))), a, b)))


# -------------------------------------------------------------------------
# config-time validation (the "small fix" satellite)
# -------------------------------------------------------------------------


def test_program_validation_errors():
    topo = make_topology("ring", N_AGENTS)
    with pytest.raises(ValueError, match="rounds"):
        C.make_mixing_program(topo, rounds=0)
    with pytest.raises(ValueError, match="feed back"):
        C.make_mixing_program(topo, error_feedback=True, exchange="f32")
    with pytest.raises(ValueError, match="feed back"):
        C.make_mixing_program(topo, error_feedback=True, exchange="bf16")
    with pytest.raises(ValueError, match="strategy"):
        C.make_mixing_program(topo, strategy="gossipy")
    # static strategy cannot take a period-2 schedule
    sched = make_topology_schedule("alternating:ring:fully_connected", N_AGENTS)
    with pytest.raises(ValueError, match="time_varying"):
        C.make_mixing_program(sched, strategy="static")
    # EF is valid config for int8
    p = C.make_mixing_program(topo, error_feedback=True, exchange="int8")
    assert p.error_feedback and not p.is_trivial


@pytest.mark.filterwarnings("ignore:exchange=.*only affects fused")
def test_nontrivial_program_requires_fused_optimizer():
    params, topo, _ = _testbed()
    with pytest.raises(ValueError, match="fused"):
        CollaborativeTrainer(LOSS, params, topo, CDSGD(0.05, fused=False),
                             consensus_rounds=2)
    with pytest.raises(ValueError, match="fused"):
        CollaborativeTrainer(LOSS, params, topo, CDSGD(0.05, fused=False),
                             exchange="int8", error_feedback=True)


def test_rounds_promote_and_normalize():
    topo = make_topology("ring", N_AGENTS)
    assert C.make_mixing_program(topo, rounds=2).strategy == "multi_round"
    assert C.make_mixing_program(topo, strategy="multi_round",
                                 rounds=1).strategy == "static"


# -------------------------------------------------------------------------
# MultiRoundMixing(k=1) == StaticMixing, bit-for-bit
# -------------------------------------------------------------------------


@pytest.mark.parametrize("exchange", ["f32", "int8"])
def test_multi_round_k1_is_static_bitwise(exchange):
    params, topo, batch = _testbed()
    trainers = [
        CollaborativeTrainer(LOSS, params, topo, CDSGD(5e-3, fused=True),
                             exchange=exchange, donate=False, **kw)
        for kw in ({}, {"mixing_strategy": "multi_round",
                        "consensus_rounds": 1})]
    assert trainers[1].program.strategy == "static"
    for _ in range(3):
        m0 = trainers[0].step(batch)
        m1 = trainers[1].step(batch)
    for a, b in zip(jax.tree.leaves(trainers[0].state.params),
                    jax.tree.leaves(trainers[1].state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert m0["loss"] == m1["loss"]


# -------------------------------------------------------------------------
# multi-round semantics: x' = Pi^k x - alpha g
# -------------------------------------------------------------------------


@pytest.mark.parametrize("k", [2, 3])
def test_multi_round_matches_dense_matrix_power(k):
    """f32 wire (deterministic): the full trainer's k-round CDSGD step must
    equal the dense reference x' = Pi^k x - alpha g (g = x for 0.5||x||^2;
    k=3 exercises the lax.scan over inner rounds)."""
    A, D = N_AGENTS, 300
    topo = make_topology("ring", A)
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (A, D))}

    def loss(p, b):
        return 0.5 * jnp.sum(p["w"] ** 2), {}

    tr = CollaborativeTrainer(loss, params, topo, CDSGD(0.05, fused=True),
                              stack=False, consensus_rounds=k)
    batch = {"x": jnp.zeros((A, 1))}
    pi = np.linalg.matrix_power(np.asarray(topo.pi, np.float64), k)
    x = np.asarray(params["w"], np.float64)
    for _ in range(3):
        tr.step(batch)
        x = pi @ x - 0.05 * x
    np.testing.assert_allclose(np.asarray(tr.state.params["w"]), x,
                               rtol=0, atol=1e-5)


def test_multi_round_int8_tracks_single_round_target():
    """int8 k=2 re-quantizes between rounds; the trajectory must stay near
    the exact Pi^2 mix (two unbiased SR perturbations per step)."""
    params, topo, batch = _testbed()
    outs = {}
    for exch in ("f32", "int8"):
        tr = CollaborativeTrainer(LOSS, params, topo, CDSGD(5e-3, fused=True),
                                  exchange=exch, consensus_rounds=2)
        for _ in range(10):
            m = tr.step(batch)
        outs[exch] = (tr.state.params, m["loss"])
    assert _max_diff(outs["f32"][0], outs["int8"][0]) < 5e-2
    assert abs(outs["f32"][1] - outs["int8"][1]) < 5e-2


def test_multi_round_improves_consensus_rate():
    """The point of i-CDSGD: more rounds -> lower consensus error for the
    same number of gradient steps (paper 1805.12120 Fig. 1 trend)."""
    params, topo, batch = _testbed()
    cons = {}
    for k in (1, 3):
        tr = CollaborativeTrainer(LOSS, params, topo,
                                  CDMSGD(0.05, mu=0.9, fused=True),
                                  consensus_rounds=k)
        # de-synchronize so there is disagreement to contract
        tr.state.params = jax.tree.map(
            lambda x: x + 0.05 * jax.random.normal(
                jax.random.PRNGKey(7), x.shape, x.dtype), tr.state.params)
        for _ in range(10):
            m = tr.step(batch)
        cons[k] = m["consensus_error"]
    assert cons[3] < cons[1]


# -------------------------------------------------------------------------
# time-varying semantics: Pi_t selected by the optimizer step
# -------------------------------------------------------------------------


def test_time_varying_matches_per_step_dense_reference():
    A, D = N_AGENTS, 200
    topo = make_topology("ring", A)
    sched = make_topology_schedule("alternating:ring:fully_connected", A)
    params = {"w": jax.random.normal(jax.random.PRNGKey(1), (A, D))}

    def loss(p, b):
        return 0.5 * jnp.sum(p["w"] ** 2), {}

    tr = CollaborativeTrainer(loss, params, topo, CDSGD(0.05, fused=True),
                              stack=False, mixing_strategy="time_varying",
                              topology_schedule=sched)
    batch = {"x": jnp.zeros((A, 1))}
    x = np.asarray(params["w"], np.float64)
    for t in range(4):
        tr.step(batch)
        pi_t = np.asarray(sched.topology_at(t).pi, np.float64)
        x = pi_t @ x - 0.05 * x
        np.testing.assert_allclose(np.asarray(tr.state.params["w"]), x,
                                   rtol=0, atol=1e-5)


def test_time_varying_gossip_converges_to_consensus():
    """Gossip pairs: each step mixes ONE pair (degree 1), yet the
    B-connected schedule still contracts disagreement over its period."""
    A, D = 6, 50
    topo = make_topology("fully_connected", A)
    sched = make_topology_schedule("gossip:8", A, seed=3)
    params = {"w": jax.random.normal(jax.random.PRNGKey(2), (A, D))}

    def loss(p, b):
        return jnp.sum(p["w"] * 0.0), {}          # pure mixing, no gradient

    tr = CollaborativeTrainer(loss, params, topo, CDSGD(0.0, fused=True),
                              stack=False, mixing_strategy="time_varying",
                              topology_schedule=sched)
    batch = {"x": jnp.zeros((A, 1))}
    x0 = np.asarray(params["w"])
    before = float(np.mean(np.std(x0, axis=0)))
    for _ in range(3 * sched.period):
        tr.step(batch)
    after = float(np.mean(np.std(np.asarray(tr.state.params["w"]), axis=0)))
    assert after < 0.5 * before
    # mean is preserved (doubly stochastic)
    np.testing.assert_allclose(np.asarray(tr.state.params["w"]).mean(0),
                               x0.mean(0), atol=1e-5)


# -------------------------------------------------------------------------
# error feedback: the acceptance criterion of ISSUE 4
# -------------------------------------------------------------------------


def test_error_feedback_residual_telescopes():
    """carried = dequant(payload) + residual, exactly — the EF invariant."""
    params, topo, _ = _testbed()
    tr = CollaborativeTrainer(LOSS, params, topo, CDSGD(5e-3, fused=True),
                              exchange="int8", error_feedback=True)
    fl = tr.comm.flat
    spec = fl.spec(tr.state.params)
    bufs = fl.pack(tr.state.params, spec)
    res0 = fl.strategy.residual_init(bufs)
    assert all(float(jnp.max(jnp.abs(r))) == 0.0 for r in res0)
    wire, res1 = fl.strategy.quantize_ef(bufs, jnp.int32(0), res0)
    for b, (p, sc), r in zip(bufs, wire, res1):
        np.testing.assert_allclose(
            np.asarray(b, np.float32),
            np.asarray(p.astype(jnp.float32) * sc) + np.asarray(r),
            rtol=0, atol=1e-6)
    # second step carries the error: residual stays bounded by one
    # quantization step per row (amax/127), not growing
    wire2, res2 = fl.strategy.quantize_ef(bufs, jnp.int32(1), res1)
    for b, r in zip(bufs, res2):
        amax = np.abs(np.asarray(b, np.float32)).max()
        assert float(jnp.max(jnp.abs(r))) <= 2.5 * amax / 127.0


@pytest.mark.parametrize("schedule", ["sync", "overlap"])
def test_error_feedback_beats_plain_int8_drift(schedule):
    """THE acceptance criterion: over 20 paper-testbed CDSGD steps the
    EF-int8 parameter drift vs the f32 trajectory is strictly below the
    plain-int8 drift — the PR 2 noise-accumulation caveat measurably
    improved (EF errors telescope; plain SR noise random-walks)."""
    params, topo, batch = _testbed()
    runs = {}
    for label, kw in (("f32", {"exchange": "f32"}),
                      ("int8", {"exchange": "int8"}),
                      ("int8_ef", {"exchange": "int8",
                                   "error_feedback": True})):
        tr = CollaborativeTrainer(LOSS, params, topo, CDSGD(5e-3, fused=True),
                                  schedule=schedule, **kw)
        for _ in range(20):
            m = tr.step(batch)
        runs[label] = (tr.state.params, m["loss"])
    drift_plain = _max_diff(runs["f32"][0], runs["int8"][0])
    drift_ef = _max_diff(runs["f32"][0], runs["int8_ef"][0])
    assert drift_ef < drift_plain, (drift_ef, drift_plain)
    assert runs["int8_ef"][1] == pytest.approx(runs["f32"][1], abs=5e-2)


def test_error_feedback_state_rides_opt_state():
    """The residual lives in OptState.residual (like wire), refreshed by
    the engine each step and passed through optimizer.update untouched."""
    params, topo, batch = _testbed()
    tr = CollaborativeTrainer(LOSS, params, topo, CDSGD(5e-3, fused=True),
                              exchange="int8", error_feedback=True)
    assert len(tr.state.opt_state.residual) > 0
    before = [np.asarray(r).copy() for r in tr.state.opt_state.residual]
    tr.step(batch)
    after = tr.state.opt_state.residual
    assert any(float(jnp.max(jnp.abs(b - a))) > 0
               for b, a in zip(before, after)), "residual must refresh"
    assert all(r.dtype == jnp.float32 for r in after)


# -------------------------------------------------------------------------
# wire accounting + overlap composition
# -------------------------------------------------------------------------


def test_wire_accounting_rounds_and_ef():
    params, topo, _ = _testbed()
    base = CollaborativeTrainer(LOSS, params, topo, CDSGD(5e-3, fused=True),
                                exchange="int8").wire_bytes_per_step
    k3 = CollaborativeTrainer(LOSS, params, topo, CDSGD(5e-3, fused=True),
                              exchange="int8",
                              consensus_rounds=3).wire_bytes_per_step
    ef = CollaborativeTrainer(LOSS, params, topo, CDSGD(5e-3, fused=True),
                              exchange="int8",
                              error_feedback=True).wire_bytes_per_step
    assert k3 == 3 * base
    assert ef == base


def test_schedule_wire_accounting_uses_mean_degree():
    """A gossip schedule's amortized degree (1 pair/step) must price far
    below the ring's degree-2, at identical per-neighbor bytes."""
    from repro.core import flatbuf
    params, topo, _ = _testbed()
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (6,) + x.shape), params)
    spec = flatbuf.make_flat_spec(stacked, lead=1)
    ring = C.exchange_bytes_per_step(spec, make_topology("ring", 6), "int8")
    gossip = C.exchange_bytes_per_step(
        spec, make_topology_schedule("gossip:8", 6), "int8")
    assert gossip["per_neighbor_bytes"] == ring["per_neighbor_bytes"]
    assert gossip["per_step_bytes"] == ring["per_step_bytes"] // 2


@pytest.mark.parametrize("kw", [
    {"consensus_rounds": 2},
    {"mixing_strategy": "time_varying",
     "topology_schedule": "alternating:ring:fully_connected"},
    {"error_feedback": True},
])
def test_overlap_composes_with_every_strategy(kw):
    """schedule='overlap' + {multi-round, time-varying, EF}: still descends
    and stays near the sync trajectory on the paper testbed (small-lr
    CDSGD; staleness adds one recycled step of drift, strategies add none
    beyond their documented envelopes)."""
    params, topo, batch = _testbed()
    results = {}
    for schedule in ("sync", "overlap"):
        tr = CollaborativeTrainer(LOSS, params, topo, CDSGD(5e-3, fused=True),
                                  schedule=schedule, exchange="int8", **kw)
        first = tr.step(batch)
        for _ in range(14):
            m = tr.step(batch)
        results[schedule] = (tr.state.params, first["loss"], m["loss"])
    p_s, _, last_s = results["sync"]
    p_o, first_o, last_o = results["overlap"]
    assert last_o < first_o, "overlap must still descend"
    assert abs(last_s - last_o) < 5e-2
    assert _max_diff(p_s, p_o) < 5e-2


def test_dependency_report_has_round_fields():
    params, topo, batch = _testbed()
    tr = CollaborativeTrainer(LOSS, params, topo, CDSGD(5e-3, fused=True),
                              schedule="overlap", exchange="int8",
                              consensus_rounds=2)
    rep = engine.exchange_dependency_report(
        tr._program.step_fn, tr.state.params, tr.state.opt_state, batch)
    # stacked mode: no collectives at all, but the fields must exist
    assert rep["n_ppermutes"] == 0
    assert rep["n_ppermutes_carried_only"] == 0
    assert rep["n_ppermutes_fresh"] == 0
    assert not rep["round1_off_critical_path"]
