"""MixingProgram strategy layer: static / time-varying / multi-round / EF.

Pins the contracts of ISSUE 4:

* config-time validation (rounds >= 1, EF needs a quantized exchange,
  non-trivial programs need a fused optimizer);
* ``MultiRoundMixing(k=1)`` is bit-for-bit ``StaticMixing`` (the factory
  normalizes it to the static strategy, whose sync gather is the legacy
  path);
* multi-round semantics: ``x' = Pi^k x - alpha g`` against the dense
  matrix power, through the full trainer;
* time-varying semantics: ``Pi_t`` selected by the optimizer step, against
  the explicit per-step dense reference;
* error feedback: the EF-int8 trajectory tracks the f32 trajectory
  strictly better than plain int8 over 20 paper-testbed steps (the PR 2
  momentum/noise caveat measurably improved), and the residual telescopes
  (carried = quantized + residual exactly);
* wire accounting: k rounds = k x bytes, EF = +0 bytes;
* the overlap schedule composes with every strategy (round-1 carried).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import consensus as C
from repro.core import engine
from repro.core.optim import CDSGD, CDMSGD, stacked_comm_ops
from repro.core.topology import (
    fixed_schedule,
    make_topology,
    make_topology_schedule,
)
from repro.core.trainer import CollaborativeTrainer
from repro.nn.paper_models import (
    classifier_loss,
    mlp_classifier_apply,
    mlp_classifier_template,
)
from repro.nn.param import init_params

N_AGENTS = 4
LOSS = functools.partial(classifier_loss, mlp_classifier_apply)


def _testbed(seed=0):
    params = init_params(mlp_classifier_template(8, 4, width=16, depth=2),
                         jax.random.PRNGKey(seed))
    topo = make_topology("ring", N_AGENTS)
    rng = np.random.default_rng(seed)
    batch = {"x": jnp.asarray(rng.standard_normal((N_AGENTS, 8, 8)), jnp.float32),
             "y": jnp.asarray(rng.integers(0, 4, (N_AGENTS, 8)), jnp.int32)}
    return params, topo, batch


def _max_diff(a, b):
    return max(jax.tree.leaves(jax.tree.map(
        lambda x, y: float(jnp.max(jnp.abs(
            x.astype(jnp.float32) - y.astype(jnp.float32)))), a, b)))


# -------------------------------------------------------------------------
# config-time validation (the "small fix" satellite)
# -------------------------------------------------------------------------


def test_program_validation_errors():
    topo = make_topology("ring", N_AGENTS)
    with pytest.raises(ValueError, match="rounds"):
        C.make_mixing_program(topo, rounds=0)
    with pytest.raises(ValueError, match="feed back"):
        C.make_mixing_program(topo, error_feedback=True, exchange="f32")
    with pytest.raises(ValueError, match="feed back"):
        C.make_mixing_program(topo, error_feedback=True, exchange="bf16")
    with pytest.raises(ValueError, match="strategy"):
        C.make_mixing_program(topo, strategy="gossipy")
    # static strategy cannot take a period-2 schedule
    sched = make_topology_schedule("alternating:ring:fully_connected", N_AGENTS)
    with pytest.raises(ValueError, match="time_varying"):
        C.make_mixing_program(sched, strategy="static")
    # EF is valid config for int8
    p = C.make_mixing_program(topo, error_feedback=True, exchange="int8")
    assert p.error_feedback and not p.is_trivial


@pytest.mark.filterwarnings("ignore:exchange=.*only affects fused")
def test_nontrivial_program_requires_fused_optimizer():
    params, topo, _ = _testbed()
    with pytest.raises(ValueError, match="fused"):
        CollaborativeTrainer(LOSS, params, topo, CDSGD(0.05, fused=False),
                             consensus_rounds=2)
    with pytest.raises(ValueError, match="fused"):
        CollaborativeTrainer(LOSS, params, topo, CDSGD(0.05, fused=False),
                             exchange="int8", error_feedback=True)


def test_rounds_promote_and_normalize():
    topo = make_topology("ring", N_AGENTS)
    assert C.make_mixing_program(topo, rounds=2).strategy == "multi_round"
    assert C.make_mixing_program(topo, strategy="multi_round",
                                 rounds=1).strategy == "static"


# -------------------------------------------------------------------------
# MultiRoundMixing(k=1) == StaticMixing, bit-for-bit
# -------------------------------------------------------------------------


@pytest.mark.parametrize("exchange", ["f32", "int8"])
def test_multi_round_k1_is_static_bitwise(exchange):
    params, topo, batch = _testbed()
    trainers = [
        CollaborativeTrainer(LOSS, params, topo, CDSGD(5e-3, fused=True),
                             exchange=exchange, donate=False, **kw)
        for kw in ({}, {"mixing_strategy": "multi_round",
                        "consensus_rounds": 1})]
    assert trainers[1].program.strategy == "static"
    for _ in range(3):
        m0 = trainers[0].step(batch)
        m1 = trainers[1].step(batch)
    for a, b in zip(jax.tree.leaves(trainers[0].state.params),
                    jax.tree.leaves(trainers[1].state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert m0["loss"] == m1["loss"]


# -------------------------------------------------------------------------
# multi-round semantics: x' = Pi^k x - alpha g
# -------------------------------------------------------------------------


@pytest.mark.parametrize("k", [2, 3])
def test_multi_round_matches_dense_matrix_power(k):
    """f32 wire (deterministic): the full trainer's k-round CDSGD step must
    equal the dense reference x' = Pi^k x - alpha g (g = x for 0.5||x||^2;
    k=3 exercises the lax.scan over inner rounds)."""
    A, D = N_AGENTS, 300
    topo = make_topology("ring", A)
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (A, D))}

    def loss(p, b):
        return 0.5 * jnp.sum(p["w"] ** 2), {}

    tr = CollaborativeTrainer(loss, params, topo, CDSGD(0.05, fused=True),
                              stack=False, consensus_rounds=k)
    batch = {"x": jnp.zeros((A, 1))}
    pi = np.linalg.matrix_power(np.asarray(topo.pi, np.float64), k)
    x = np.asarray(params["w"], np.float64)
    for _ in range(3):
        tr.step(batch)
        x = pi @ x - 0.05 * x
    np.testing.assert_allclose(np.asarray(tr.state.params["w"]), x,
                               rtol=0, atol=1e-5)


def test_multi_round_int8_tracks_single_round_target():
    """int8 k=2 re-quantizes between rounds; the trajectory must stay near
    the exact Pi^2 mix (two unbiased SR perturbations per step)."""
    params, topo, batch = _testbed()
    outs = {}
    for exch in ("f32", "int8"):
        tr = CollaborativeTrainer(LOSS, params, topo, CDSGD(5e-3, fused=True),
                                  exchange=exch, consensus_rounds=2)
        for _ in range(10):
            m = tr.step(batch)
        outs[exch] = (tr.state.params, m["loss"])
    assert _max_diff(outs["f32"][0], outs["int8"][0]) < 5e-2
    assert abs(outs["f32"][1] - outs["int8"][1]) < 5e-2


def test_multi_round_improves_consensus_rate():
    """The point of i-CDSGD: more rounds -> lower consensus error for the
    same number of gradient steps (paper 1805.12120 Fig. 1 trend)."""
    params, topo, batch = _testbed()
    cons = {}
    for k in (1, 3):
        tr = CollaborativeTrainer(LOSS, params, topo,
                                  CDMSGD(0.05, mu=0.9, fused=True),
                                  consensus_rounds=k)
        # de-synchronize so there is disagreement to contract
        tr.state.params = jax.tree.map(
            lambda x: x + 0.05 * jax.random.normal(
                jax.random.PRNGKey(7), x.shape, x.dtype), tr.state.params)
        for _ in range(10):
            m = tr.step(batch)
        cons[k] = m["consensus_error"]
    assert cons[3] < cons[1]


# -------------------------------------------------------------------------
# time-varying semantics: Pi_t selected by the optimizer step
# -------------------------------------------------------------------------


def test_time_varying_matches_per_step_dense_reference():
    A, D = N_AGENTS, 200
    topo = make_topology("ring", A)
    sched = make_topology_schedule("alternating:ring:fully_connected", A)
    params = {"w": jax.random.normal(jax.random.PRNGKey(1), (A, D))}

    def loss(p, b):
        return 0.5 * jnp.sum(p["w"] ** 2), {}

    tr = CollaborativeTrainer(loss, params, topo, CDSGD(0.05, fused=True),
                              stack=False, mixing_strategy="time_varying",
                              topology_schedule=sched)
    batch = {"x": jnp.zeros((A, 1))}
    x = np.asarray(params["w"], np.float64)
    for t in range(4):
        tr.step(batch)
        pi_t = np.asarray(sched.topology_at(t).pi, np.float64)
        x = pi_t @ x - 0.05 * x
        np.testing.assert_allclose(np.asarray(tr.state.params["w"]), x,
                                   rtol=0, atol=1e-5)


def test_time_varying_gossip_converges_to_consensus():
    """Gossip pairs: each step mixes ONE pair (degree 1), yet the
    B-connected schedule still contracts disagreement over its period."""
    A, D = 6, 50
    topo = make_topology("fully_connected", A)
    sched = make_topology_schedule("gossip:8", A, seed=3)
    params = {"w": jax.random.normal(jax.random.PRNGKey(2), (A, D))}

    def loss(p, b):
        return jnp.sum(p["w"] * 0.0), {}          # pure mixing, no gradient

    tr = CollaborativeTrainer(loss, params, topo, CDSGD(0.0, fused=True),
                              stack=False, mixing_strategy="time_varying",
                              topology_schedule=sched)
    batch = {"x": jnp.zeros((A, 1))}
    x0 = np.asarray(params["w"])
    before = float(np.mean(np.std(x0, axis=0)))
    for _ in range(3 * sched.period):
        tr.step(batch)
    after = float(np.mean(np.std(np.asarray(tr.state.params["w"]), axis=0)))
    assert after < 0.5 * before
    # mean is preserved (doubly stochastic)
    np.testing.assert_allclose(np.asarray(tr.state.params["w"]).mean(0),
                               x0.mean(0), atol=1e-5)


# -------------------------------------------------------------------------
# error feedback: the acceptance criterion of ISSUE 4
# -------------------------------------------------------------------------


def test_error_feedback_residual_telescopes():
    """carried = dequant(payload) + residual, exactly — the EF invariant."""
    params, topo, _ = _testbed()
    tr = CollaborativeTrainer(LOSS, params, topo, CDSGD(5e-3, fused=True),
                              exchange="int8", error_feedback=True)
    fl = tr.comm.flat
    spec = fl.spec(tr.state.params)
    bufs = fl.pack(tr.state.params, spec)
    res0 = fl.strategy.residual_init(bufs)
    assert all(float(jnp.max(jnp.abs(r))) == 0.0 for r in res0)
    wire, res1 = fl.strategy.quantize_ef(bufs, jnp.int32(0), res0)
    for b, (p, sc), r in zip(bufs, wire, res1):
        np.testing.assert_allclose(
            np.asarray(b, np.float32),
            np.asarray(p.astype(jnp.float32) * sc) + np.asarray(r),
            rtol=0, atol=1e-6)
    # second step carries the error: residual stays bounded by one
    # quantization step per row (amax/127), not growing
    wire2, res2 = fl.strategy.quantize_ef(bufs, jnp.int32(1), res1)
    for b, r in zip(bufs, res2):
        amax = np.abs(np.asarray(b, np.float32)).max()
        assert float(jnp.max(jnp.abs(r))) <= 2.5 * amax / 127.0


@pytest.mark.parametrize("schedule", ["sync", "overlap"])
def test_error_feedback_beats_plain_int8_drift(schedule):
    """THE acceptance criterion: over 20 paper-testbed CDSGD steps the
    EF-int8 parameter drift vs the f32 trajectory is strictly below the
    plain-int8 drift — the PR 2 noise-accumulation caveat measurably
    improved (EF errors telescope; plain SR noise random-walks)."""
    params, topo, batch = _testbed()
    runs = {}
    for label, kw in (("f32", {"exchange": "f32"}),
                      ("int8", {"exchange": "int8"}),
                      ("int8_ef", {"exchange": "int8",
                                   "error_feedback": True})):
        tr = CollaborativeTrainer(LOSS, params, topo, CDSGD(5e-3, fused=True),
                                  schedule=schedule, **kw)
        for _ in range(20):
            m = tr.step(batch)
        runs[label] = (tr.state.params, m["loss"])
    drift_plain = _max_diff(runs["f32"][0], runs["int8"][0])
    drift_ef = _max_diff(runs["f32"][0], runs["int8_ef"][0])
    assert drift_ef < drift_plain, (drift_ef, drift_plain)
    assert runs["int8_ef"][1] == pytest.approx(runs["f32"][1], abs=5e-2)


def test_error_feedback_state_rides_opt_state():
    """The residual lives in OptState.residual (like wire), refreshed by
    the engine each step and passed through optimizer.update untouched."""
    params, topo, batch = _testbed()
    tr = CollaborativeTrainer(LOSS, params, topo, CDSGD(5e-3, fused=True),
                              exchange="int8", error_feedback=True)
    assert len(tr.state.opt_state.residual) > 0
    before = [np.asarray(r).copy() for r in tr.state.opt_state.residual]
    tr.step(batch)
    after = tr.state.opt_state.residual
    assert any(float(jnp.max(jnp.abs(b - a))) > 0
               for b, a in zip(before, after)), "residual must refresh"
    assert all(r.dtype == jnp.float32 for r in after)


# -------------------------------------------------------------------------
# wire accounting + overlap composition
# -------------------------------------------------------------------------


def test_wire_accounting_rounds_and_ef():
    params, topo, _ = _testbed()
    base = CollaborativeTrainer(LOSS, params, topo, CDSGD(5e-3, fused=True),
                                exchange="int8").wire_bytes_per_step
    k3 = CollaborativeTrainer(LOSS, params, topo, CDSGD(5e-3, fused=True),
                              exchange="int8",
                              consensus_rounds=3).wire_bytes_per_step
    ef = CollaborativeTrainer(LOSS, params, topo, CDSGD(5e-3, fused=True),
                              exchange="int8",
                              error_feedback=True).wire_bytes_per_step
    assert k3 == 3 * base
    assert ef == base


def test_schedule_wire_accounting_uses_mean_degree():
    """A gossip schedule's amortized degree (1 pair/step) must price far
    below the ring's degree-2, at identical per-neighbor bytes."""
    from repro.core import flatbuf
    params, topo, _ = _testbed()
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (6,) + x.shape), params)
    spec = flatbuf.make_flat_spec(stacked, lead=1)
    ring = C.exchange_bytes_per_step(spec, make_topology("ring", 6), "int8")
    gossip = C.exchange_bytes_per_step(
        spec, make_topology_schedule("gossip:8", 6), "int8")
    assert gossip["per_neighbor_bytes"] == ring["per_neighbor_bytes"]
    assert gossip["per_step_bytes"] == ring["per_step_bytes"] // 2


@pytest.mark.parametrize("kw", [
    {"consensus_rounds": 2},
    {"mixing_strategy": "time_varying",
     "topology_schedule": "alternating:ring:fully_connected"},
    {"error_feedback": True},
])
def test_overlap_composes_with_every_strategy(kw):
    """schedule='overlap' + {multi-round, time-varying, EF}: still descends
    and stays near the sync trajectory on the paper testbed (small-lr
    CDSGD; staleness adds one recycled step of drift, strategies add none
    beyond their documented envelopes)."""
    params, topo, batch = _testbed()
    results = {}
    for schedule in ("sync", "overlap"):
        tr = CollaborativeTrainer(LOSS, params, topo, CDSGD(5e-3, fused=True),
                                  schedule=schedule, exchange="int8", **kw)
        first = tr.step(batch)
        for _ in range(14):
            m = tr.step(batch)
        results[schedule] = (tr.state.params, first["loss"], m["loss"])
    p_s, _, last_s = results["sync"]
    p_o, first_o, last_o = results["overlap"]
    assert last_o < first_o, "overlap must still descend"
    assert abs(last_s - last_o) < 5e-2
    assert _max_diff(p_s, p_o) < 5e-2


def test_dependency_report_has_round_fields():
    params, topo, batch = _testbed()
    tr = CollaborativeTrainer(LOSS, params, topo, CDSGD(5e-3, fused=True),
                              schedule="overlap", exchange="int8",
                              consensus_rounds=2)
    rep = engine.exchange_dependency_report(
        tr._program.step_fn, tr.state.params, tr.state.opt_state, batch)
    # stacked mode: no collectives at all, but the fields must exist
    assert rep["n_ppermutes"] == 0
    assert rep["n_ppermutes_carried_only"] == 0
    assert rep["n_ppermutes_fresh"] == 0
    assert not rep["round1_off_critical_path"]


# -------------------------------------------------------------------------
# Momentum-consensus mixing (ISSUE 5 tentpole): v rides the wire
# -------------------------------------------------------------------------


def test_momentum_mixing_validation():
    params, topo, _ = _testbed()
    with pytest.raises(ValueError, match="momentum_mixing"):
        C.make_mixing_program(topo, momentum_mixing="both")
    # CDSGD has no momentum buffer to mix
    with pytest.raises(ValueError, match="mixable momentum"):
        CollaborativeTrainer(LOSS, params, topo, CDSGD(5e-3, fused=True),
                             momentum_mixing="mixed")
    # the strategy layer needs the fused staged path
    with pytest.raises(ValueError, match="fused"):
        CollaborativeTrainer(LOSS, params, topo, CDMSGD(5e-3, fused=False),
                             momentum_mixing="mixed")
    p = C.make_mixing_program(topo, momentum_mixing="mixed")
    assert not p.is_trivial and p.n_payloads == 2


def test_momentum_mixed_matches_dense_reference():
    """f32 wire (deterministic): the full trainer's momentum-mixed CDMSGD
    step must equal the dense reference ``v' = mu (Pi v) - a g ;
    x' = Pi x + v'`` (2010.11166) — vs plain CDMSGD's ``v' = mu v - a g``."""
    A, D = N_AGENTS, 300
    topo = make_topology("ring", A)
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (A, D))}

    def loss(p, b):
        return 0.5 * jnp.sum(p["w"] ** 2), {}

    tr = CollaborativeTrainer(loss, params, topo,
                              CDMSGD(0.05, mu=0.9, fused=True),
                              stack=False, momentum_mixing="mixed")
    batch = {"x": jnp.zeros((A, 1))}
    pi = np.asarray(topo.pi, np.float64)
    x = np.asarray(params["w"], np.float64)
    v = np.zeros_like(x)
    for _ in range(4):
        tr.step(batch)
        v = 0.9 * (pi @ v) - 0.05 * x
        x = pi @ x + v
        np.testing.assert_allclose(np.asarray(tr.state.params["w"]), x,
                                   rtol=0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(tr.state.opt_state.inner["w"]), v,
                               rtol=0, atol=1e-5)


def test_momentum_mixed_multi_round_matches_dense_power():
    """rounds=2 composes: both payloads mix through Pi^2 before the fused
    final round (``v' = mu Pi^2 v - a g ; x' = Pi^2 x + v'``)."""
    A, D = N_AGENTS, 200
    topo = make_topology("ring", A)
    params = {"w": jax.random.normal(jax.random.PRNGKey(1), (A, D))}

    def loss(p, b):
        return 0.5 * jnp.sum(p["w"] ** 2), {}

    tr = CollaborativeTrainer(loss, params, topo,
                              CDMSGD(0.05, mu=0.9, fused=True),
                              stack=False, momentum_mixing="mixed",
                              consensus_rounds=2)
    batch = {"x": jnp.zeros((A, 1))}
    pi2 = np.linalg.matrix_power(np.asarray(topo.pi, np.float64), 2)
    x = np.asarray(params["w"], np.float64)
    v = np.zeros_like(x)
    for _ in range(3):
        tr.step(batch)
        v = 0.9 * (pi2 @ v) - 0.05 * x
        x = pi2 @ x + v
    np.testing.assert_allclose(np.asarray(tr.state.params["w"]), x,
                               rtol=0, atol=1e-5)


def test_momentum_mixed_nesterov_and_cdadam_match_reference():
    """The other two momentum-capable fused kernels' mixed forms, vs dense
    references: Nesterov evaluates g at the lookahead; CDAdam mixes the
    FIRST moment only (the second stays a local positive scale)."""
    from repro.core.optim import CDAdam, CDMSGDNesterov
    A, D = N_AGENTS, 200
    topo = make_topology("ring", A)
    pi = np.asarray(topo.pi, np.float64)
    params = {"w": jax.random.normal(jax.random.PRNGKey(2), (A, D))}
    batch = {"x": jnp.zeros((A, 1))}

    def loss(p, b):
        return 0.5 * jnp.sum(p["w"] ** 2), {}

    # Nesterov: g_t = lookahead_t (for this loss), v' = mu Pi v - a g
    # donate=False: fused Nesterov's initial lookahead aliases params, and
    # donating both to the jitted step would hand XLA one buffer twice
    tr = CollaborativeTrainer(loss, params, topo,
                              CDMSGDNesterov(0.05, mu=0.9, fused=True),
                              stack=False, momentum_mixing="mixed",
                              donate=False)
    x = np.asarray(params["w"], np.float64)
    v = np.zeros_like(x)
    look = x.copy()
    for _ in range(3):
        tr.step(batch)
        v = 0.9 * (pi @ v) - 0.05 * look
        x = pi @ x + v
        look = x + 0.9 * v
    np.testing.assert_allclose(np.asarray(tr.state.params["w"]), x,
                               rtol=0, atol=1e-5)

    # CDAdam: m' = b1 (Pi m) + (1-b1) g, v2 local
    b1, b2, eps = 0.9, 0.999, 1e-8
    tr = CollaborativeTrainer(loss, params, topo,
                              CDAdam(0.01, b1=b1, b2=b2, eps=eps, fused=True),
                              stack=False, momentum_mixing="mixed")
    x = np.asarray(params["w"], np.float64)
    m = np.zeros_like(x)
    v2 = np.zeros_like(x)
    for t in range(3):
        tr.step(batch)
        g = x
        m = b1 * (pi @ m) + (1 - b1) * g
        v2 = b2 * v2 + (1 - b2) * g * g
        bc1, bc2 = 1 - b1 ** (t + 1), 1 - b2 ** (t + 1)
        x = pi @ x - 0.01 * (m / bc1) / (np.sqrt(v2 / bc2) + eps)
    np.testing.assert_allclose(np.asarray(tr.state.params["w"]), x,
                               rtol=0, atol=1e-4)


def test_momentum_mixed_wire_doubles_and_ef_adds_zero():
    """Wire contract: the momentum payload exactly doubles the bytes at
    equal precision (program accounting AND the actual carried overlap
    buffers); error feedback still adds zero."""
    from repro.core import flatbuf
    params, topo, _ = _testbed()
    mk = lambda **kw: CollaborativeTrainer(
        LOSS, params, topo, CDMSGD(5e-3, mu=0.9, fused=True),
        exchange="int8", **kw)
    base = mk().wire_bytes_per_step
    mixed = mk(momentum_mixing="mixed").wire_bytes_per_step
    mixed_ef = mk(momentum_mixing="mixed",
                  error_feedback=True).wire_bytes_per_step
    assert mixed == 2 * base
    assert mixed_ef == mixed
    tr = mk(momentum_mixing="mixed", schedule="overlap")
    spec = flatbuf.make_flat_spec(tr.state.params, lead=1)
    assert engine.wire_bytes_per_neighbor(tr.state.opt_state.wire) == \
        2 * spec.exchange_bytes("int8")
    # the widened state: one wire pair and (under EF) one residual per
    # bucket per payload
    assert len(tr.state.opt_state.wire) == 2 * spec.n_buckets
    tr_ef = mk(momentum_mixing="mixed", error_feedback=True)
    assert len(tr_ef.state.opt_state.residual) == 2 * spec.n_buckets


def test_momentum_mixed_ef_residual_telescopes_per_payload():
    """With momentum mixing + EF, BOTH payloads' residuals telescope:
    carried = dequant(payload) + residual, exactly, bucket-for-bucket."""
    params, topo, _ = _testbed()
    tr = CollaborativeTrainer(LOSS, params, topo,
                              CDMSGD(5e-3, mu=0.9, fused=True),
                              exchange="int8", error_feedback=True,
                              momentum_mixing="mixed")
    fl = tr.comm.flat
    spec = fl.spec(tr.state.params)
    bufs = fl.pack(tr.state.params, spec)
    vbufs = [b + 0.5 for b in bufs]              # a nonzero momentum stand-in
    both = bufs + vbufs
    res0 = fl.strategy.residual_init(both)
    assert len(res0) == 2 * len(bufs)
    wire, res1 = fl.strategy.quantize_ef(both, jnp.int32(0), res0)
    assert len(wire) == 2 * len(bufs)
    for b, (p, sc), r in zip(both, wire, res1):
        np.testing.assert_allclose(
            np.asarray(b, np.float32),
            np.asarray(p.astype(jnp.float32) * sc) + np.asarray(r),
            rtol=0, atol=1e-6)
    # distinct payload seed stride: equal inputs quantize DIFFERENTLY
    # across the payload halves (independent SR streams)
    wire_same, _ = fl.strategy.quantize_ef(bufs + bufs, jnp.int32(0), res0)
    n = len(bufs)
    assert any(np.any(np.asarray(wire_same[i][0]) != np.asarray(wire_same[n + i][0]))
               for i in range(n))


# -------------------------------------------------------------------------
# Seed-stride decorrelation (ISSUE 5 satellite)
# -------------------------------------------------------------------------


def test_wire_seed_strides_collision_free():
    """The five strides (step/agent/bucket/round + the momentum-payload
    stride) produce no colliding int32 seeds over the realistic index
    ranges: agents<=64, buckets<=8, rounds<=8, payloads 2, crossed with
    (a) a dense 128-step window and (b) ~1000 steps strided across the
    full 1e6-step range.  (The full 1e6-step cross product holds 6.5e9
    tuples — more than 2^32 — so exhaustive injectivity is impossible by
    pigeonhole; the window catches short-range aliasing, the strided
    sample long-range.)  SR streams stay independent by construction."""
    strides = dict(step=C._SEED_STEP_STRIDE, agent=C._SEED_AGENT_STRIDE,
                   bucket=C._SEED_BUCKET_STRIDE, rnd=C._SEED_ROUND_STRIDE,
                   payload=C._SEED_PAYLOAD_STRIDE)
    assert len(set(strides.values())) == 5

    def seeds(steps):
        steps = np.asarray(steps, np.int64)
        a = np.arange(64, dtype=np.int64)
        b = np.arange(8, dtype=np.int64)
        r = np.arange(8, dtype=np.int64)
        p = np.arange(2, dtype=np.int64)
        s = (strides["step"] * (steps[:, None, None, None, None]
                                + strides["rnd"] * r[None, None, None, :, None])
             + strides["agent"] * a[None, :, None, None, None]
             + strides["bucket"] * b[None, None, :, None, None]
             + strides["payload"] * p[None, None, None, None, :])
        return (s & 0xFFFFFFFF).ravel()

    win = seeds(np.arange(128))
    assert np.unique(win).size == win.size, "short-range seed collision"
    samp = seeds((np.arange(997) * 1003 + 13) % 1_000_000)
    assert np.unique(samp).size == samp.size, "long-range seed collision"

    # the vectorized mirror above IS wire_seed (spot-checked), so the
    # uniqueness proof applies to the composition the stages implement
    rng = np.random.default_rng(0)
    for _ in range(50):
        st, ag, bu, rd, pl = (int(rng.integers(0, 1_000_000)),
                              int(rng.integers(0, 64)),
                              int(rng.integers(0, 8)),
                              int(rng.integers(0, 8)),
                              int(rng.integers(0, 2)))
        want = (strides["step"] * (st + strides["rnd"] * rd)
                + strides["agent"] * ag + strides["bucket"] * bu
                + strides["payload"] * pl)
        assert C.wire_seed(st, ag, bu, rd, pl) == int(
            np.int64(want).astype(np.int32))


def test_wire_seed_matches_actual_quantize_stage():
    """The stacked quantize stage draws exactly wire_seed's streams: the
    per-agent/bucket/payload payload bits equal sr_quantize_2d at the
    composed seed (the consistency anchor that ties the collision proof
    to the running code)."""
    from repro.kernels.consensus_update.consensus_update import sr_quantize_2d
    rng = np.random.default_rng(3)
    bufs = [jnp.asarray(rng.standard_normal((N_AGENTS, 4, 128)), jnp.float32),
            jnp.asarray(rng.standard_normal((N_AGENTS, 2, 128)), jnp.float32)]
    step = 17
    for payload in (0, 1):
        wire = C._quantize_wire_stacked(bufs, jnp.int32(step), N_AGENTS,
                                        "int8", True, payload=payload)
        for bi, (q, sc) in enumerate(wire):
            for j in range(N_AGENTS):
                qq, ss = sr_quantize_2d(
                    bufs[bi][j],
                    jnp.int32(C.wire_seed(step, j, bi, 0, payload)),
                    exchange="int8", interpret=True)
                np.testing.assert_array_equal(np.asarray(q[j]),
                                              np.asarray(qq))
                np.testing.assert_array_equal(np.asarray(sc[j]),
                                              np.asarray(ss))


def test_wire_seed_matches_actual_compress_stage():
    """ISSUE-7 satellite: the top-k compressor draws the SAME wire_seed
    streams as the dense int8 wire — the compact values are just a
    smaller int8 payload, so the stride collision proof above covers the
    compressor with no new index dimensions.  Ties the proof to the
    running code the way test_wire_seed_matches_actual_quantize_stage
    does for the dense stage: per-agent/bucket compressed payloads equal
    topk_compress_2d at the composed seed, bit-for-bit."""
    from repro.kernels.consensus_update import topk as tk
    rng = np.random.default_rng(5)
    bufs = [jnp.asarray(rng.standard_normal((N_AGENTS, 4, 128)), jnp.float32),
            jnp.asarray(rng.standard_normal((N_AGENTS, 2, 128)), jnp.float32)]
    step = 23
    topo = make_topology("ring", N_AGENTS)
    prog = C.make_mixing_program(topo, compressor="topk:0.25",
                                 error_feedback=True)
    wire, qw = C._compress_wire_stacked(bufs, jnp.int32(step), N_AGENTS,
                                        prog, True, ())
    assert qw == ()  # top-k is stateless beyond the EF residual
    for bi, entry in enumerate(wire):
        assert isinstance(entry, C.TopKWire)
        k_rows = tk.topk_k_rows(bufs[bi].shape[-2], 0.25)
        for j in range(N_AGENTS):
            v, i, s = tk.topk_compress_2d(
                bufs[bi][j], k_rows,
                jnp.int32(C.wire_seed(step, j, bi, 0, 0)), interpret=True)
            np.testing.assert_array_equal(np.asarray(entry.values[j]),
                                          np.asarray(v))
            np.testing.assert_array_equal(np.asarray(entry.indices[j]),
                                          np.asarray(i))
            np.testing.assert_array_equal(np.asarray(entry.scales[j]),
                                          np.asarray(s))


def test_wire_seed_ring_window_collision_free():
    """ISSUE-6 satellite: wire_seed composition at staleness depth S.

    The depth-S ring re-publishes carried payloads WITHOUT re-quantizing
    them (bitwise shift, asserted structurally in tests/test_faults.py::
    test_ring_slots_are_shifted_copies_never_requantized), so a slot
    carried s <= S steps keeps the SR stream seeded at its quantization
    step t-s.  That is only sound if no carried seed aliases a LIVE seed:
    over the ring's (step, payload) index space — every (t, t-s) pair
    with s <= S_MAX = 16, crossed with agents<=64, buckets<=8,
    payloads 2 — all seeds in the depth-S window must be distinct, for t
    in a dense window AND strided across the full 1e6-step range."""
    S_MAX = 16
    stride = dict(step=C._SEED_STEP_STRIDE, agent=C._SEED_AGENT_STRIDE,
                  bucket=C._SEED_BUCKET_STRIDE, payload=C._SEED_PAYLOAD_STRIDE)

    def window_seeds(t):
        # every seed the depth-S ring can hold alongside step t's live
        # quantization: generations t-S_MAX .. t, all agents/buckets/payloads
        s = np.arange(S_MAX + 1, dtype=np.int64)
        a = np.arange(64, dtype=np.int64)
        b = np.arange(8, dtype=np.int64)
        p = np.arange(2, dtype=np.int64)
        out = (stride["step"] * (t - s[:, None, None, None])
               + stride["agent"] * a[None, :, None, None]
               + stride["bucket"] * b[None, None, :, None]
               + stride["payload"] * p[None, None, None, :])
        return (out & 0xFFFFFFFF).ravel()

    for t in list(range(S_MAX, S_MAX + 4)) + \
            [int(x) for x in (np.arange(53) * 18973 + 29) % 1_000_000]:
        w = window_seeds(t)
        assert np.unique(w).size == w.size, \
            f"ring-window seed collision at step {t}"


# -------------------------------------------------------------------------
# THE ISSUE-5 acceptance: momentum-mixed int8 CDMSGD at the caveat lr
# -------------------------------------------------------------------------

# Documented envelope (measured on the seed-0 paper testbed, 20 steps,
# CDMSGD lr 0.01 mu 0.9 ring-4, drift = max |param diff| vs the SAME-
# ALGORITHM f32 run of the SAME schedule — the reference that isolates
# the wire-quantization noise; referencing overlap runs to the sync f32
# trajectory would re-measure the known one-step-staleness gap, which is
# orthogonal to what momentum mixing fixes):
#   sync:    plain-int8 0.0275   mixed-int8 0.0219   (ratio 0.80)
#   overlap: plain-int8 0.0182   mixed-int8 0.0145   (ratio 0.79)
# Mechanism: the unmixed momentum integrates wire noise through the
# gradient loop with a 1/(1-mu) = 10-step memory (disagreement modes
# contract at max(rho(Pi), mu) = 0.9); mixing v over the wire cuts that
# to rho(Pi) = 1/3 (lyapunov.momentum_consensus_contraction), at the
# price of also quantizing the v payload — a net win whenever the
# momentum buffer is small against the params (a g/(1-mu) << |x|, true
# for NN training; a stiff quadratic with a g/(1-mu) ~ |x| can invert
# it, which is why this is asserted on the paper testbed and not a toy).
MOMENTUM_MIX_DRIFT_BOUND = 5e-2


@pytest.mark.parametrize("schedule", ["sync", "overlap"])
def test_momentum_mixed_int8_beats_plain_at_caveat_lr(schedule):
    """THE acceptance criterion: at the PR 2 caveat lr (0.01, mu 0.9 —
    the regime whose momentum/quantization instability PR 2 documented
    and PR 4 queued the principled fix for), the momentum-mixed int8
    CDMSGD trajectory tracks its f32 reference strictly closer than
    plain int8 tracks its own, on both schedules, and the mixed drift is
    bounded."""
    params, topo, batch = _testbed()
    runs = {}
    for label, kw in (("f32_plain", {"exchange": "f32"}),
                      ("f32_mixed", {"exchange": "f32",
                                     "momentum_mixing": "mixed"}),
                      ("int8_plain", {"exchange": "int8"}),
                      ("int8_mixed", {"exchange": "int8",
                                      "momentum_mixing": "mixed"})):
        tr = CollaborativeTrainer(LOSS, params, topo,
                                  CDMSGD(0.01, mu=0.9, fused=True),
                                  schedule=schedule, **kw)
        for _ in range(20):
            m = tr.step(batch)
        runs[label] = (tr.state.params, m["loss"])
    drift_plain = _max_diff(runs["f32_plain"][0], runs["int8_plain"][0])
    drift_mixed = _max_diff(runs["f32_mixed"][0], runs["int8_mixed"][0])
    assert drift_mixed < MOMENTUM_MIX_DRIFT_BOUND, drift_mixed
    assert drift_mixed < drift_plain, (drift_mixed, drift_plain)
    assert runs["int8_mixed"][1] == pytest.approx(runs["f32_mixed"][1],
                                                  abs=5e-2)


def test_momentum_mixed_improves_consensus_contraction():
    """The rate side of the fix (2010.11166): with heterogeneous agent
    data, momentum-mixed CDMSGD holds a strictly smaller steady
    consensus error than plain CDMSGD at the same lr/mu — disagreement
    contracts at rho(Pi) instead of max(rho(Pi), mu) — independent of
    quantization (asserted on the f32 wire AND the int8 wire)."""
    params, topo, batch = _testbed()
    cons = {}
    for mm in ("none", "mixed"):
        for exch in ("f32", "int8"):
            tr = CollaborativeTrainer(LOSS, params, topo,
                                      CDMSGD(0.01, mu=0.9, fused=True),
                                      exchange=exch, momentum_mixing=mm)
            for _ in range(20):
                m = tr.step(batch)
            cons[(mm, exch)] = m["consensus_error"]
    assert cons[("mixed", "f32")] < cons[("none", "f32")]
    assert cons[("mixed", "int8")] < cons[("none", "int8")]
