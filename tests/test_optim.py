"""Optimizer update rules vs the paper's Algorithms 1-3 + baselines."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.optim import (
    CDSGD,
    CDMSGD,
    CDMSGDNesterov,
    CDAdam,
    CentralizedSGD,
    FedAvg,
    make_optimizer,
    stacked_comm_ops,
)
from repro.core.topology import make_topology

N, D = 5, 7
ALPHA = 0.05


@pytest.fixture
def setup():
    t = make_topology("ring", N)
    comm = stacked_comm_ops(t)
    x = jnp.asarray(np.random.randn(N, D).astype(np.float32))
    g = jnp.asarray(np.random.randn(N, D).astype(np.float32))
    return t, comm, {"w": x}, {"w": g}


def test_cdsgd_matches_eq5(setup):
    """x_{k+1} = Pi x_k - alpha g  exactly (paper eq. 5)."""
    t, comm, params, grads = setup
    opt = CDSGD(ALPHA)
    st = opt.init(params)
    new, st = opt.update(params, grads, st, comm)
    want = jnp.asarray(t.pi, jnp.float32) @ params["w"] - ALPHA * grads["w"]
    np.testing.assert_allclose(np.asarray(new["w"]), np.asarray(want), rtol=2e-5, atol=2e-5)
    assert int(st.step) == 1


def test_cdmsgd_matches_algorithm2(setup):
    t, comm, params, grads = setup
    mu = 0.9
    opt = CDMSGD(ALPHA, mu=mu)
    st = opt.init(params)
    new, st = opt.update(params, grads, st, comm)
    v1 = -ALPHA * grads["w"]                      # v0 = 0
    want = jnp.asarray(t.pi, jnp.float32) @ params["w"] + v1
    np.testing.assert_allclose(np.asarray(new["w"]), np.asarray(want), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(st.inner["w"]), np.asarray(v1), rtol=1e-6)


def test_nesterov_lookahead_point(setup):
    t, comm, params, grads = setup
    opt = CDMSGDNesterov(ALPHA, mu=0.9)
    st = opt.init(params)
    # initial momentum zero -> lookahead == params
    np.testing.assert_allclose(np.asarray(opt.grad_params(params, st)["w"]),
                               np.asarray(params["w"]))
    _, st = opt.update(params, grads, st, comm)
    look = opt.grad_params(params, st)["w"]
    want = params["w"] + 0.9 * st.inner["w"]
    np.testing.assert_allclose(np.asarray(look), np.asarray(want), rtol=1e-6)


def test_cdsgd_uniform_pi_gives_mean_minus_local_grad(setup):
    _, _, params, grads = setup
    comm = stacked_comm_ops(make_topology("fully_connected", N))
    opt = CDSGD(ALPHA)
    new, _ = opt.update(params, grads, opt.init(params), comm)
    want = jnp.mean(params["w"], 0, keepdims=True) - ALPHA * grads["w"]
    np.testing.assert_allclose(np.asarray(new["w"]), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_centralized_sgd_identical_across_agents(setup):
    _, comm, params, grads = setup
    # force identical initial params across agents
    params = {"w": jnp.broadcast_to(params["w"][:1], params["w"].shape)}
    opt = CentralizedSGD(ALPHA)
    new, _ = opt.update(params, grads, opt.init(params), comm)
    spread = float(jnp.max(jnp.abs(new["w"] - new["w"][0:1])))
    assert spread < 1e-6, "centralized SGD must keep agents in lockstep"


def test_fedavg_averages_every_e_steps(setup):
    _, comm, params, grads = setup
    opt = FedAvg(ALPHA, local_steps=2)
    st = opt.init(params)
    p1, st = opt.update(params, grads, st, comm)     # step 1: local only
    assert float(jnp.max(jnp.abs(p1["w"] - p1["w"][0:1]))) > 1e-4
    p2, st = opt.update(p1, grads, st, comm)         # step 2: average
    assert float(jnp.max(jnp.abs(p2["w"] - p2["w"][0:1]))) < 1e-6


def test_fedavg_e1_equals_mean_of_local_sgd(setup):
    _, comm, params, grads = setup
    opt = FedAvg(ALPHA, local_steps=1)
    new, _ = opt.update(params, grads, opt.init(params), comm)
    want = jnp.mean(params["w"] - ALPHA * grads["w"], 0, keepdims=True)
    np.testing.assert_allclose(np.asarray(new["w"]),
                               np.broadcast_to(np.asarray(want), (N, D)), rtol=2e-5, atol=2e-5)


def test_cdadam_moments_stay_local(setup):
    t, comm, params, grads = setup
    opt = CDAdam(1e-3)
    st = opt.init(params)
    new, st = opt.update(params, grads, st, comm)
    m, v = st.inner
    np.testing.assert_allclose(np.asarray(m["w"]), 0.1 * np.asarray(grads["w"]), rtol=1e-5)
    assert new["w"].shape == (N, D)


def test_make_optimizer_registry():
    for name in ["cdsgd", "cdmsgd", "cdmsgd_nesterov", "cdadam", "sgd", "msgd", "fedavg"]:
        assert make_optimizer(name, 0.01) is not None
    with pytest.raises(ValueError):
        make_optimizer("adamw", 0.01)


def test_diminishing_schedule_drives_step_down(setup):
    from repro.core import schedules
    _, comm, params, grads = setup
    opt = CDSGD(schedules.diminishing(theta=1.0, eps=1.0, t=1.0))
    st = opt.init(params)
    alphas = []
    p = params
    for _ in range(5):
        alphas.append(float(opt.schedule(st.step)))
        p, st = opt.update(p, grads, st, comm)
    assert all(a > b for a, b in zip(alphas, alphas[1:]))
