"""Optimizer update rules vs the paper's Algorithms 1-3 + baselines."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.optim import (
    CDSGD,
    CDMSGD,
    CDMSGDNesterov,
    CDAdam,
    CentralizedSGD,
    FedAvg,
    make_optimizer,
    stacked_comm_ops,
)
from repro.core.topology import make_topology

N, D = 5, 7
ALPHA = 0.05


@pytest.fixture
def setup():
    t = make_topology("ring", N)
    comm = stacked_comm_ops(t)
    x = jnp.asarray(np.random.randn(N, D).astype(np.float32))
    g = jnp.asarray(np.random.randn(N, D).astype(np.float32))
    return t, comm, {"w": x}, {"w": g}


def test_cdsgd_matches_eq5(setup):
    """x_{k+1} = Pi x_k - alpha g  exactly (paper eq. 5)."""
    t, comm, params, grads = setup
    opt = CDSGD(ALPHA)
    st = opt.init(params)
    new, st = opt.update(params, grads, st, comm)
    want = jnp.asarray(t.pi, jnp.float32) @ params["w"] - ALPHA * grads["w"]
    np.testing.assert_allclose(np.asarray(new["w"]), np.asarray(want), rtol=2e-5, atol=2e-5)
    assert int(st.step) == 1


def test_cdmsgd_matches_algorithm2(setup):
    t, comm, params, grads = setup
    mu = 0.9
    opt = CDMSGD(ALPHA, mu=mu)
    st = opt.init(params)
    new, st = opt.update(params, grads, st, comm)
    v1 = -ALPHA * grads["w"]                      # v0 = 0
    want = jnp.asarray(t.pi, jnp.float32) @ params["w"] + v1
    np.testing.assert_allclose(np.asarray(new["w"]), np.asarray(want), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(st.inner["w"]), np.asarray(v1), rtol=1e-6)


def test_nesterov_lookahead_point(setup):
    t, comm, params, grads = setup
    opt = CDMSGDNesterov(ALPHA, mu=0.9)
    st = opt.init(params)
    # initial momentum zero -> lookahead == params
    np.testing.assert_allclose(np.asarray(opt.grad_params(params, st)["w"]),
                               np.asarray(params["w"]))
    _, st = opt.update(params, grads, st, comm)
    look = opt.grad_params(params, st)["w"]
    want = params["w"] + 0.9 * st.inner["w"]
    np.testing.assert_allclose(np.asarray(look), np.asarray(want), rtol=1e-6)


def test_cdsgd_uniform_pi_gives_mean_minus_local_grad(setup):
    _, _, params, grads = setup
    comm = stacked_comm_ops(make_topology("fully_connected", N))
    opt = CDSGD(ALPHA)
    new, _ = opt.update(params, grads, opt.init(params), comm)
    want = jnp.mean(params["w"], 0, keepdims=True) - ALPHA * grads["w"]
    np.testing.assert_allclose(np.asarray(new["w"]), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_centralized_sgd_identical_across_agents(setup):
    _, comm, params, grads = setup
    # force identical initial params across agents
    params = {"w": jnp.broadcast_to(params["w"][:1], params["w"].shape)}
    opt = CentralizedSGD(ALPHA)
    new, _ = opt.update(params, grads, opt.init(params), comm)
    spread = float(jnp.max(jnp.abs(new["w"] - new["w"][0:1])))
    assert spread < 1e-6, "centralized SGD must keep agents in lockstep"


def test_fedavg_averages_every_e_steps(setup):
    _, comm, params, grads = setup
    opt = FedAvg(ALPHA, local_steps=2)
    st = opt.init(params)
    p1, st = opt.update(params, grads, st, comm)     # step 1: local only
    assert float(jnp.max(jnp.abs(p1["w"] - p1["w"][0:1]))) > 1e-4
    p2, st = opt.update(p1, grads, st, comm)         # step 2: average
    assert float(jnp.max(jnp.abs(p2["w"] - p2["w"][0:1]))) < 1e-6


def test_fedavg_e1_equals_mean_of_local_sgd(setup):
    _, comm, params, grads = setup
    opt = FedAvg(ALPHA, local_steps=1)
    new, _ = opt.update(params, grads, opt.init(params), comm)
    want = jnp.mean(params["w"] - ALPHA * grads["w"], 0, keepdims=True)
    np.testing.assert_allclose(np.asarray(new["w"]),
                               np.broadcast_to(np.asarray(want), (N, D)), rtol=2e-5, atol=2e-5)


def test_cdadam_moments_stay_local(setup):
    t, comm, params, grads = setup
    opt = CDAdam(1e-3)
    st = opt.init(params)
    new, st = opt.update(params, grads, st, comm)
    m, v = st.inner
    np.testing.assert_allclose(np.asarray(m["w"]), 0.1 * np.asarray(grads["w"]), rtol=1e-5)
    assert new["w"].shape == (N, D)


def test_make_optimizer_registry():
    for name in ["cdsgd", "cdmsgd", "cdmsgd_nesterov", "cdadam", "sgd", "msgd", "fedavg"]:
        assert make_optimizer(name, 0.01) is not None
    with pytest.raises(ValueError):
        make_optimizer("adamw", 0.01)


def test_diminishing_schedule_drives_step_down(setup):
    from repro.core import schedules
    _, comm, params, grads = setup
    opt = CDSGD(schedules.diminishing(theta=1.0, eps=1.0, t=1.0))
    st = opt.init(params)
    alphas = []
    p = params
    for _ in range(5):
        alphas.append(float(opt.schedule(st.step)))
        p, st = opt.update(p, grads, st, comm)
    assert all(a > b for a, b in zip(alphas, alphas[1:]))


# -------------------------------------------------------------------------
# FedAvg: gated sync collective + momentum averaging (ISSUE 5 satellites)
# -------------------------------------------------------------------------


def test_fedavg_matches_handrolled_e_step_reference(setup):
    """FedAvg E=3 mu=0.9 over 7 steps vs the hand-rolled server-side
    recurrence: E local momentum-SGD steps, then BOTH x and v replaced by
    their global means.  Before this fix the local v buffers silently
    diverged across agents between syncs and were never reconciled, so
    every post-sync step immediately pulled the averaged params back
    toward each agent's own shard."""
    _, comm, params, grads = setup
    mu, e = 0.9, 3
    opt = FedAvg(ALPHA, local_steps=e, mu=mu)
    st = opt.init(params)
    p = params
    x = np.asarray(params["w"], np.float64)
    v = np.zeros_like(x)
    g = np.asarray(grads["w"], np.float64)
    for t in range(7):
        p, st = opt.update(p, grads, st, comm)
        v = mu * v - ALPHA * g
        x = x + v
        if (t + 1) % e == 0:
            x = np.broadcast_to(x.mean(0, keepdims=True), x.shape).copy()
            v = np.broadcast_to(v.mean(0, keepdims=True), v.shape).copy()
        np.testing.assert_allclose(np.asarray(p["w"]), x, rtol=0, atol=1e-5)
        np.testing.assert_allclose(np.asarray(st.inner["w"]), v, rtol=0,
                                   atol=1e-5)


def test_fedavg_momentum_averaged_at_sync(setup):
    """The momentum buffers agree across agents right after a sync step
    (they used to keep their divergent local values forever)."""
    _, comm, params, grads = setup
    opt = FedAvg(ALPHA, local_steps=2, mu=0.9)
    st = opt.init(params)
    p, st = opt.update(params, grads, st, comm)      # local: v diverges
    assert float(jnp.max(jnp.abs(st.inner["w"] - st.inner["w"][0:1]))) > 1e-4
    p, st = opt.update(p, grads, st, comm)           # sync: v averaged
    assert float(jnp.max(jnp.abs(st.inner["w"] - st.inner["w"][0:1]))) < 1e-6


def test_fedavg_mean_gated_inside_cond(setup):
    """E>1: the averaging computation lives ONLY inside a lax.cond branch
    of the step jaxpr — the collective is paid once per E steps, i.e. 1/E
    as many mean reductions as the old unconditional mean + select.  E=1
    keeps the unconditional mean (every step syncs anyway, no cond)."""
    _, comm, params, grads = setup

    def step(e):
        opt = FedAvg(ALPHA, local_steps=e, mu=0.9)
        return jax.make_jaxpr(
            lambda p, g, s: opt.update(p, g, s, comm))(
                params, grads, FedAvg(ALPHA, local_steps=e, mu=0.9).init(params))

    def count_reduces(jaxpr, top_only):
        n = 0
        for eqn in jaxpr.eqns:
            if "reduce_sum" in eqn.primitive.name:
                n += 1
            if not top_only:
                for v in eqn.params.values():
                    for x in (v if isinstance(v, (tuple, list)) else (v,)):
                        if isinstance(x, (jax.core.Jaxpr, jax.core.ClosedJaxpr)):
                            j = x.jaxpr if isinstance(x, jax.core.ClosedJaxpr) else x
                            n += count_reduces(j, top_only)
        return n

    j3 = step(3).jaxpr
    assert any(e.primitive.name == "cond" for e in j3.eqns)
    # the agent-mean reductions (params + momentum) exist ONLY inside the
    # cond branches — nothing averages unconditionally
    assert count_reduces(j3, top_only=True) == 0
    assert count_reduces(j3, top_only=False) >= 2
    j1 = step(1).jaxpr
    assert not any(e.primitive.name == "cond" for e in j1.eqns)
    assert count_reduces(j1, top_only=True) >= 2


def test_fedavg_sync_executions_are_one_per_e_steps(setup):
    """Runtime proof of the 1/E collective count: a callback planted in
    comm.mean fires only on the 2 sync steps of 6 jitted E=3 steps — 2
    mean calls per sync (params + momentum) x 2 syncs = 4, where the old
    unconditional averaging would have fired 6 times for params alone
    (the callback counts branch EXECUTIONS, not traces)."""
    import dataclasses as _dc
    _, comm, params, grads = setup
    fired = []

    base_mean = comm.mean

    def counting_mean(tree):
        jax.debug.callback(lambda: fired.append(1))
        return base_mean(tree)

    comm_c = _dc.replace(comm, mean=counting_mean)
    opt = FedAvg(ALPHA, local_steps=3, mu=0.9)
    step = jax.jit(lambda p, g, s: opt.update(p, g, s, comm_c))
    p, st = params, opt.init(params)
    for _ in range(6):
        p, st = step(p, grads, st)
    jax.effects_barrier()
    # 6 steps / E=3 -> 2 sync executions x 2 payload means each
    assert len(fired) == 4, fired


def test_fedavg_wire_accounting_bytes_per_e():
    """mean_exchange_bytes_per_step: the gated all-reduce amortizes to
    bytes/E per step; averaging the momentum too doubles the payloads."""
    from repro.core import flatbuf
    from repro.core.consensus import mean_exchange_bytes_per_step
    spec = flatbuf.make_flat_spec(
        {"w": jax.ShapeDtypeStruct((N, 64, 128), jnp.float32)}, lead=1)
    e1 = mean_exchange_bytes_per_step(spec, N, period=1)
    e4 = mean_exchange_bytes_per_step(spec, N, period=4)
    e4m = mean_exchange_bytes_per_step(spec, N, period=4, payloads=2)
    assert e4["per_step_bytes"] == e1["per_step_bytes"] // 4
    assert e4m["per_step_bytes"] == 2 * e4["per_step_bytes"]
    assert e1["per_sync_bytes"] == int(2 * (N - 1) / N
                                       * spec.exchange_bytes("f32"))


# -------------------------------------------------------------------------
# FedAvg partial participation (ISSUE 6 satellite): k-of-N present agents
# -------------------------------------------------------------------------


def test_fedavg_partial_participation_matches_handrolled_server(setup):
    """FedAvg E=2 mu=0.9 under a fault schedule vs the hand-rolled
    k-of-N server reference: at each sync step the server averages ONLY
    the present (non-straggling) agents — masked sum renormalized by
    N/k — and broadcasts to everyone, momentum masked identically.
    Mirrors test_fedavg_matches_handrolled_e_step_reference, which this
    reduces to when every agent is present."""
    from repro.core.faults import make_fault_schedule
    _, comm, params, grads = setup
    mu, e = 0.9, 2
    # agent 1 absent at t in {1,2,3} mod 4; agent 3 absent at t in {2,3}
    faults = make_fault_schedule("stall:1:1:3,stall:3:2:2", N)
    opt = FedAvg(ALPHA, local_steps=e, mu=mu, faults=faults)
    st = opt.init(params)
    p = params
    x = np.asarray(params["w"], np.float64)
    v = np.zeros_like(x)
    g = np.asarray(grads["w"], np.float64)
    present = ~np.asarray(faults.straggle)            # (P, N)
    saw_partial = False
    for t in range(9):
        p, st = opt.update(p, grads, st, comm)
        v = mu * v - ALPHA * g
        x = x + v
        if (t + 1) % e == 0:
            m = present[t % faults.period].astype(np.float64)
            k = m.sum()
            assert k > 0
            saw_partial = saw_partial or k < N
            x = np.broadcast_to((x * m[:, None]).sum(0, keepdims=True) / k,
                                x.shape).copy()
            v = np.broadcast_to((v * m[:, None]).sum(0, keepdims=True) / k,
                                v.shape).copy()
        np.testing.assert_allclose(np.asarray(p["w"]), x, rtol=0, atol=1e-5)
        np.testing.assert_allclose(np.asarray(st.inner["w"]), v, rtol=0,
                                   atol=1e-5)
    assert saw_partial, "the schedule never exercised a k < N sync"


def test_fedavg_nobody_present_keeps_local_params(setup):
    """A sync step where EVERY agent straggles is a no-op sync: params
    keep their local values (no zeroing through the masked sum) and stay
    divergent across agents."""
    from repro.core.faults import make_fault_schedule
    _, comm, params, grads = setup
    spec = ",".join(f"stall:{j}:1:1" for j in range(N))
    faults = make_fault_schedule(spec, N)               # all absent at t=1
    opt = FedAvg(ALPHA, local_steps=2, mu=0.9, faults=faults)
    ref = FedAvg(ALPHA, local_steps=2, mu=0.9)
    p, st = params, opt.init(params)
    pr, str_ = params, ref.init(params)
    for _ in range(2):                                  # sync lands at t=1
        p, st = opt.update(p, grads, st, comm)
        pr, str_ = ref.update(pr, grads, str_, comm)
    # faulted run skipped the sync: agents still diverge, all finite
    assert float(jnp.max(jnp.abs(p["w"] - p["w"][0:1]))) > 1e-4
    assert bool(jnp.all(jnp.isfinite(p["w"])))
    # the fault-free reference DID average
    assert float(jnp.max(jnp.abs(pr["w"] - pr["w"][0:1]))) < 1e-6
    # ... and the faulted params equal plain 2-step local momentum SGD
    want = np.asarray(params["w"], np.float64)
    v = np.zeros_like(want)
    g = np.asarray(grads["w"], np.float64)
    for _ in range(2):
        v = 0.9 * v - ALPHA * g
        want = want + v
    np.testing.assert_allclose(np.asarray(p["w"]), want, rtol=0, atol=1e-5)
