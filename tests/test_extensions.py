"""Beyond-paper extensions: gossip SGD baseline + time-varying topologies
(paper future work §6.ii) + sharding-resolution unit tests + roofline math.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.consensus import consensus_error_stacked
from repro.core.optim import GossipSGD, TimeVaryingCDSGD, stacked_comm_ops
from repro.core.topology import Topology, make_topology, metropolis_pi

N, D = 6, 5


def _quadratic(seed=0):
    rng = np.random.default_rng(seed)
    eigs = jnp.asarray(rng.uniform(0.5, 2.0, (N, D)), jnp.float32)
    centers = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
    return lambda x: eigs * (x - centers), centers


# --------------------------------------------------------------------------
# gossip SGD
# --------------------------------------------------------------------------


def test_gossip_mixing_preserves_mean():
    opt = GossipSGD(0.0, n_agents=N, seed=0)   # alpha 0: pure mixing
    comm = stacked_comm_ops(make_topology("fully_connected", N))
    x = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(N, D)), jnp.float32)}
    g = {"w": jnp.zeros((N, D))}
    st = opt.init(x)
    for _ in range(5):
        x, st = opt.update(x, g, st, comm)
    np.testing.assert_allclose(np.asarray(jnp.mean(x["w"], 0)),
                               np.zeros(D) + np.asarray(jnp.mean(x["w"], 0)), rtol=1e-5)


def test_gossip_converges_on_quadratic():
    grad, centers = _quadratic()
    opt = GossipSGD(0.05, n_agents=N, seed=1)
    comm = stacked_comm_ops(make_topology("fully_connected", N))
    x = {"w": jnp.zeros((N, D))}
    st = opt.init(x)
    for _ in range(600):
        x, st = opt.update(x, {"w": grad(x["w"])}, st, comm)
    err = float(consensus_error_stacked(x["w"]))
    mean_center = jnp.mean(centers, 0)
    # random pairwise averaging consensus-optimizes to an alpha-sized floor
    # (same Prop-1 structure as CDSGD, with a random-matching mixing matrix)
    assert err < 0.4
    assert float(jnp.linalg.norm(jnp.mean(x["w"], 0) - mean_center)) < 0.5


# --------------------------------------------------------------------------
# time-varying topology
# --------------------------------------------------------------------------


def _line_graph_pair():
    """Two disconnected-ish graphs whose union is connected (grid rows/cols)."""
    # agents 0..5 as a 2x3 grid; t1 connects rows, t2 connects columns
    import numpy as np_

    def adj_from_edges(edges):
        a = np_.zeros((N, N))
        for i, j in edges:
            a[i, j] = a[j, i] = 1.0
        return a

    rows = adj_from_edges([(0, 1), (1, 2), (3, 4), (4, 5)])
    cols = adj_from_edges([(0, 3), (1, 4), (2, 5)])
    return (Topology("rows", metropolis_pi(rows)),
            Topology("cols", metropolis_pi(cols)))


def test_time_varying_union_connectivity_gives_consensus():
    t1, t2 = _line_graph_pair()
    # each graph alone is disconnected: lambda_2 == 1
    assert t1.lambda2 > 1 - 1e-9 and t2.lambda2 > 1 - 1e-9
    opt = TimeVaryingCDSGD(0.0, [t1, t2])      # pure alternating mixing
    comm = stacked_comm_ops(make_topology("fully_connected", N))
    x = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(N, D)), jnp.float32)}
    g = {"w": jnp.zeros((N, D))}
    st = opt.init(x)
    e0 = float(consensus_error_stacked(x["w"]))
    for _ in range(60):
        x, st = opt.update(x, g, st, comm)
    e1 = float(consensus_error_stacked(x["w"]))
    assert e1 < 1e-3 * e0, "alternating mixing over a connected union must reach consensus"


def test_time_varying_with_gradients_converges():
    grad, centers = _quadratic()
    t1, t2 = _line_graph_pair()
    opt = TimeVaryingCDSGD(0.05, [t1, t2])
    comm = stacked_comm_ops(make_topology("fully_connected", N))
    x = {"w": jnp.zeros((N, D))}
    st = opt.init(x)
    for _ in range(800):
        x, st = opt.update(x, {"w": grad(x["w"])}, st, comm)
    assert float(jnp.linalg.norm(jnp.mean(x["w"], 0) - jnp.mean(centers, 0))) < 0.3


# --------------------------------------------------------------------------
# sharding resolution units
# --------------------------------------------------------------------------


def test_safe_partition_specs_divisibility_fallback():
    import os, subprocess, sys, textwrap, json
    repo = __import__("os").path.dirname(__import__("os").path.dirname(__file__))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    code = textwrap.dedent("""
        import json
        import jax.numpy as jnp
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.sharding import safe_partition_specs, rules_for_mode
        from repro.nn.param import ParamDef
        mesh = make_debug_mesh(4, 2)
        t = {
            "even": ParamDef((8, 6), ("fsdp", "tp")),     # 6 % 2 == 0 -> shard
            "odd": ParamDef((8, 5), ("fsdp", "tp")),      # 5 % 2 != 0 -> replicate
        }
        specs = safe_partition_specs(t, rules_for_mode("serve", mesh), mesh)
        print("RESULT " + json.dumps({
            "even": [str(x) for x in specs["even"]],
            "odd": [str(x) for x in specs["odd"]],
        }))
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300, env=env)
    assert out.returncode == 0, out.stderr[-1500:]
    res = json.loads([l for l in out.stdout.splitlines()
                      if l.startswith("RESULT ")][-1][len("RESULT "):])
    assert res["even"] == ["data", "model"]
    assert res["odd"] == ["data"]          # trailing replicated dim dropped


# --------------------------------------------------------------------------
# roofline math units
# --------------------------------------------------------------------------


def test_roofline_terms_and_dominance():
    from repro.analysis.hlo import HloStats
    from repro.analysis.roofline import roofline_from_stats

    stats = HloStats(
        collective_bytes={"all-reduce": 50e9}, dot_flops=197e12,
        traffic_bytes=819e9 / 2, collective_count={"all-reduce": 1},
        trip_counts={})
    t = roofline_from_stats(arch="x", shape="y", mesh="m", chips=256,
                            stats=stats, model_flops_total=197e12 * 256 / 2)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(0.5)
    assert t.collective_s == pytest.approx(1.0)
    assert t.dominant in ("compute", "collective")
    assert t.useful_flops_ratio == pytest.approx(0.5)
    assert t.step_time_lower_bound == pytest.approx(1.0)


def test_model_flops_regimes():
    from repro.analysis.roofline import model_flops
    from repro.configs import get_config, INPUT_SHAPES

    cfg = get_config("granite-3-8b")
    n = cfg.active_param_count()
    tr = model_flops(cfg, INPUT_SHAPES["train_4k"])
    pf = model_flops(cfg, INPUT_SHAPES["prefill_32k"])
    de = model_flops(cfg, INPUT_SHAPES["decode_32k"])
    assert tr == pytest.approx(6 * n * 256 * 4096)
    assert pf == pytest.approx(2 * n * 32 * 32768)
    assert de == pytest.approx(2 * n * 128)
