"""StepProgram engine: phase assembly, schedules, and the critical-path proof.

The engine is the single definition of the train step for BOTH execution
modes, so these tests pin three contracts:

* ``schedule="sync"`` assembles exactly the pre-engine monolithic closure
  (same ops, same order — bitwise on this backend);
* ``schedule="overlap"`` implements the one-step-stale mixing recurrence
  ``x_{t+1} = diag(Pi) x_t + offdiag(Pi) q(x_{t-1}) - alpha g(x_t)`` with a
  fresh full-precision self term, and converges next to the sync schedule
  on the paper testbed at small lr (the PR 2 quantization caveat: momentum
  at large lr amplifies ANY per-step perturbation chaotically, so
  trajectory-level comparisons use small-lr CDSGD);
* the shared grad phase's ``microbatches`` scan is exact gradient
  accumulation (stacked parity here; sharded parity in test_sharded.py).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.consensus import consensus_error_pytree, initial_wire_state
from repro.core.optim import CDSGD, CDMSGD, FedAvg, stacked_comm_ops
from repro.core.topology import make_topology
from repro.core.trainer import CollaborativeTrainer
from repro.nn.paper_models import (
    classifier_loss,
    mlp_classifier_apply,
    mlp_classifier_template,
)
from repro.nn.param import init_params

N_AGENTS = 4
LOSS = functools.partial(classifier_loss, mlp_classifier_apply)


def _testbed(seed=0):
    """The paper's MLP-classifier testbed, one batch shared by all tests."""
    params = init_params(mlp_classifier_template(8, 4, width=16, depth=2),
                         jax.random.PRNGKey(seed))
    topo = make_topology("ring", N_AGENTS)
    rng = np.random.default_rng(seed)
    batch = {"x": jnp.asarray(rng.standard_normal((N_AGENTS, 8, 8)), jnp.float32),
             "y": jnp.asarray(rng.integers(0, 4, (N_AGENTS, 8)), jnp.int32)}
    return params, topo, batch


def _max_diff(a, b):
    return max(jax.tree.leaves(jax.tree.map(
        lambda x, y: float(jnp.max(jnp.abs(
            x.astype(jnp.float32) - y.astype(jnp.float32)))), a, b)))


# -------------------------------------------------------------------------
# sync schedule == the pre-engine monolithic closure, bit-for-bit
# -------------------------------------------------------------------------


def test_sync_schedule_matches_legacy_closure_bitwise():
    """The phase-assembled sync step must be the exact program the old
    monolithic ``CollaborativeTrainer._make_step`` closure traced."""
    params, topo, batch = _testbed()
    opt = CDMSGD(0.05, mu=0.9, fused=True)
    comm = stacked_comm_ops(topo)
    tr = CollaborativeTrainer(LOSS, params, topo, opt, donate=False)

    def legacy_step(p, s, b):
        gp = opt.grad_params(p, s)
        (losses, metrics), grads = jax.vmap(
            jax.value_and_grad(lambda pp, bb: LOSS(pp, bb), has_aux=True))(gp, b)
        new_params, new_state = opt.update(p, grads, s, comm)
        out = {"loss": jnp.mean(losses),
               "consensus_error": consensus_error_pytree(new_params)}
        for k, v in metrics.items():
            out[k] = jnp.mean(v)
        return new_params, new_state, out

    legacy = jax.jit(legacy_step)
    p_l, s_l = tr.state.params, tr.state.opt_state
    p_e, s_e = tr.state.params, tr.state.opt_state
    for _ in range(3):
        p_l, s_l, m_l = legacy(p_l, s_l, batch)
        p_e, s_e, m_e = tr._step_fn(p_e, s_e, batch)
    for a, b in zip(jax.tree.leaves(p_l), jax.tree.leaves(p_e)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(m_l["loss"]) == float(m_e["loss"])


# -------------------------------------------------------------------------
# overlap schedule semantics
# -------------------------------------------------------------------------


def test_overlap_matches_stale_mixing_recurrence():
    """f32 overlap (deterministic wire) against the explicit recurrence
    ``x_{t+1} = D x_t + O x_{t-1} - alpha x_t`` for loss 0.5||x||^2
    (g = x), with ``x_{-1} := x_0``."""
    A, D = 5, 300
    topo = make_topology("ring", A)
    comm = stacked_comm_ops(topo)
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (A, D))}
    opt = CDSGD(0.05, fused=True)

    def loss(p, b):
        return 0.5 * jnp.sum(p["w"] ** 2), {}

    prog = engine.StepProgram(
        optimizer=opt, comm=comm,
        grad_phase=engine.make_grad_phase(loss),
        update_phase=engine.make_update_phase(opt, comm, "overlap"),
        schedule="overlap")
    state = prog.init_state(params)
    batch = {"x": jnp.zeros((A, 1))}
    step = jax.jit(prog.step_fn)

    pi = np.asarray(topo.pi, np.float32)
    diag = np.diag(np.diag(pi))
    off = pi - diag
    x_prev = np.asarray(params["w"])
    x = x_prev.copy()
    p = params
    for t in range(4):
        p, state, _ = step(p, state, batch)
        x_prev, x = x, diag @ x + off @ x_prev - 0.05 * x
        np.testing.assert_allclose(np.asarray(p["w"]), x, rtol=0, atol=1e-5)


def test_overlap_first_step_uses_initial_wire():
    """Before step 0 the double-buffer holds q(x_0) (the ``x_{-1} := x_0``
    convention), quantized with seed -1."""
    params, topo, _ = _testbed()
    comm = stacked_comm_ops(topo, exchange="int8")
    opt = CDSGD(0.05, fused=True)
    prog = engine.StepProgram(
        optimizer=opt, comm=comm,
        grad_phase=engine.make_grad_phase(LOSS),
        update_phase=engine.make_update_phase(opt, comm, "overlap"),
        schedule="overlap")
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (N_AGENTS,) + x.shape), params)
    state = prog.init_state(stacked)
    want = initial_wire_state(comm.flat, stacked)
    assert len(state.wire) == len(want)
    for (p_a, s_a), (p_b, s_b) in zip(state.wire, want):
        assert p_a.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(p_a), np.asarray(p_b))
        np.testing.assert_array_equal(np.asarray(s_a), np.asarray(s_b))


# documented tolerance: the overlap neighbor term lags one step, so the
# trajectories differ by O(alpha * offdiag(Pi) * ||x_t - x_{t-1}||) per
# step (plus int8's unbiased <= row_amax/127 rounding per neighbor term).
# Measured on 20 lr-5e-3 CDSGD steps of the MLP testbed: 8.6e-3 max param
# diff (f32 wire) / 1.8e-2 (int8), loss gap 2.2e-2 while both descend from
# 1.499 to ~1.43-1.45; asserted at 5e-2 each.
OVERLAP_TRAJECTORY_TOL = 5e-2


@pytest.mark.parametrize("exchange", ["f32", "int8"])
def test_overlap_convergence_on_paper_testbed(exchange):
    """20 small-lr CDSGD steps: the overlap schedule must track the sync
    schedule's loss and parameters on the paper testbed (small-lr CDSGD per
    the PR 2 quantization caveat — momentum at large lr is chaotic)."""
    params, topo, batch = _testbed()
    results = {}
    for schedule in ("sync", "overlap"):
        tr = CollaborativeTrainer(LOSS, params, topo, CDSGD(5e-3, fused=True),
                                  schedule=schedule, exchange=exchange)
        first = tr.step(batch)
        for _ in range(19):
            m = tr.step(batch)
        results[schedule] = (tr.state.params, first["loss"], m["loss"])
    p_s, first_s, last_s = results["sync"]
    p_o, first_o, last_o = results["overlap"]
    assert last_o < first_o, "overlap schedule must still descend"
    assert abs(last_s - last_o) < OVERLAP_TRAJECTORY_TOL, (last_s, last_o)
    assert _max_diff(p_s, p_o) < OVERLAP_TRAJECTORY_TOL


@pytest.mark.parametrize("exchange", ["f32", "int8"])
def test_overlap_wire_bytes_equal_sync_exchange_bytes(exchange):
    """The carried double-buffer must put exactly the sync schedule's
    bytes on the wire per neighbor (FlatSpec.exchange_bytes) — overlap
    changes WHEN the payload moves, never how much.  For f32 wires the
    carried unit scales never cross the wire (shift-invariant, synthesized
    after the exchange), so they must not count."""
    from repro.core import flatbuf
    params, topo, _ = _testbed()
    tr = CollaborativeTrainer(LOSS, params, topo, CDSGD(5e-3, fused=True),
                              schedule="overlap", exchange=exchange)
    spec = flatbuf.make_flat_spec(tr.state.params, lead=1)
    assert engine.wire_bytes_per_neighbor(tr.state.opt_state.wire) == \
        spec.exchange_bytes(exchange)


def test_overlap_requires_fused_flat_path():
    params, topo, _ = _testbed()
    with pytest.raises(ValueError, match="fused"):
        CollaborativeTrainer(LOSS, params, topo, CDSGD(0.05, fused=False),
                             schedule="overlap")
    with pytest.raises(ValueError, match="overlap"):
        CollaborativeTrainer(LOSS, params, topo,
                             FedAvg(0.05, local_steps=2, fused=True),
                             schedule="overlap")
    with pytest.raises(ValueError, match="schedule"):
        CollaborativeTrainer(LOSS, params, topo, CDSGD(0.05, fused=True),
                             schedule="async")


# -------------------------------------------------------------------------
# shared grad phase: microbatch gradient accumulation
# -------------------------------------------------------------------------


@pytest.mark.parametrize("opt_cls,kw", [(CDSGD, {}), (CDMSGD, {"mu": 0.9})])
def test_microbatch_accumulation_parity_stacked(opt_cls, kw):
    """microbatches=2 over the same data == microbatches=1, to fp-sum
    reassociation (grads accumulate in f32)."""
    params, topo, batch = _testbed()
    trainers = [CollaborativeTrainer(LOSS, params, topo,
                                     opt_cls(0.05, **kw), microbatches=m)
                for m in (1, 2)]
    for _ in range(3):
        m1 = trainers[0].step(batch)
        m2 = trainers[1].step(batch)
    assert abs(m1["loss"] - m2["loss"]) < 1e-6
    assert _max_diff(trainers[0].state.params, trainers[1].state.params) < 1e-6


def test_grad_phase_microbatch_losses_keep_batch_mean():
    """The scan's stacked (M, A) losses mean-reduce to the full-batch loss."""
    params, topo, batch = _testbed()
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (N_AGENTS,) + x.shape), params)
    g1 = engine.make_grad_phase(LOSS, 1)
    g2 = engine.make_grad_phase(LOSS, 2)
    (l1, _), grads1 = jax.jit(g1)(stacked, batch)
    (l2, _), grads2 = jax.jit(g2)(stacked, batch)
    assert l1.shape == (N_AGENTS,) and l2.shape == (2, N_AGENTS)
    np.testing.assert_allclose(float(jnp.mean(l1)), float(jnp.mean(l2)),
                               rtol=1e-6)
    assert _max_diff(grads1, grads2) < 1e-6


# -------------------------------------------------------------------------
# critical-path analysis (stacked program: no collectives at all)
# -------------------------------------------------------------------------


def test_dependency_report_stacked_has_no_ppermutes():
    params, topo, batch = _testbed()
    tr = CollaborativeTrainer(LOSS, params, topo, CDSGD(5e-3, fused=True),
                              schedule="overlap", exchange="int8")
    rep = engine.exchange_dependency_report(
        tr._program.step_fn, tr.state.params, tr.state.opt_state, batch)
    assert rep["n_ppermutes"] == 0
    assert not rep["off_grad_update_critical_path"]


# (the build_train_step fused=False warning needs a >= 2-agent mesh, so it
# lives in the test_sharded.py subprocess suite)


# -------------------------------------------------------------------------
# _taint_walk edge cases (the engine under the static checker's census)
# -------------------------------------------------------------------------


def _walk(fn, in_labels, *args, prims=("sin",)):
    """Trace ``fn`` and walk it with one label per positional arg; returns
    (merged-hits keyed by call path, output label sets)."""
    closed = jax.make_jaxpr(fn)(*args)
    hits = []
    outs = engine._taint_walk(closed.jaxpr, [frozenset([l]) for l in in_labels],
                              hits, prims=prims)
    merged = {}
    for key, name, taint in hits:
        merged[key] = merged.get(key, frozenset()) | taint
    return merged, outs


def test_taint_walk_cond_visits_both_branches():
    """A hit inside ONE cond branch is found, tainted only by what that
    branch actually reads; the cond output unions both branches."""
    def f(p, x, y):
        return jax.lax.cond(p > 0,
                            lambda a, b: jnp.sin(a) * 1.0,
                            lambda a, b: b * 2.0, x, y)

    merged, outs = _walk(f, ["pred", "x", "y"],
                         jnp.float32(1), jnp.ones(3), jnp.ones(3))
    assert len(merged) == 1                      # sin lives in one branch
    (taint,) = merged.values()
    assert "x" in taint and "y" not in taint
    assert outs[0] >= frozenset({"x", "y"})      # union over branches


def test_taint_walk_while_fixpoint_merges_rotated_carry():
    """The body swaps the two carried slots, so after the fixpoint the hit
    inside the loop has absorbed BOTH input labels even though iteration 1
    only shows it one of them."""
    def f(a, b):
        def cond(c):
            return jnp.sum(c[0]) < 100.0

        def body(c):
            x, y = c
            return jnp.sin(y), x + 1.0

        return jax.lax.while_loop(cond, body, (a, b))

    merged, _ = _walk(f, ["a", "b"], jnp.ones(3), jnp.ones(3))
    assert len(merged) == 1                      # one site, fixpoint-deduped
    (taint,) = merged.values()
    assert taint >= frozenset({"a", "b"})


def test_taint_walk_custom_vjp_descends_into_primal_jaxpr():
    """custom_vjp_call_jaxpr is NOT opaque: the walk descends into the
    primal ``fun_jaxpr``, so an output that only reads ``x`` taints {x}
    even though the call's operands include ``y`` — while the hit recorded
    for the call itself keeps the full operand taint (the conservative
    record the census consumes)."""
    @jax.custom_vjp
    def g(x, y):
        return x * 1.0

    g.defvjp(lambda x, y: (g(x, y), (x, y)),
             lambda res, ct: (ct, ct))

    def f(x, y):
        return g(x, y) + 0.0

    merged, outs = _walk(f, ["x", "y"], jnp.ones(3), jnp.ones(3),
                         prims=("custom_vjp",))
    assert merged, "the custom_vjp call itself must be walkable"
    (taint,) = merged.values()
    assert taint == frozenset({"x", "y"})        # call-site record
    assert outs[0] == frozenset({"x"})           # precise primal data flow


def test_taint_walk_nested_scan_paths_and_labels():
    """A hit two scan levels deep carries both enclosing frames in its call
    path and the labels that actually reach it."""
    def f(a, b):
        def outer(carry, _):
            def inner(c2, __):
                return jnp.sin(c2) + jnp.min(b), None
            c, _ = jax.lax.scan(inner, carry, None, length=2)
            return c, None
        out, _ = jax.lax.scan(outer, a, None, length=2)
        return out

    merged, _ = _walk(f, ["a", "b"], jnp.float32(0), jnp.ones(3))
    assert len(merged) == 1
    ((path, _),) = merged.keys()
    assert [frame[0] for frame in path].count("scan") == 2
    (taint,) = merged.values()
    assert taint >= frozenset({"a", "b"})


def test_taint_walk_shared_jaxpr_counts_each_call_site():
    """jax shares the inner jaxpr OBJECT between two pjit call sites of the
    same jitted fn; keying hits on the enclosing call path (not bare eqn
    identity) keeps the two structurally distinct sites distinct."""
    inner = jax.jit(lambda t: jnp.sin(t))

    def f(a, b):
        return inner(a) + inner(b)

    merged, _ = _walk(f, ["a", "b"], jnp.ones(3), jnp.ones(3))
    assert len(merged) == 2, "one hit per call site, not one per eqn object"
    taints = sorted(sorted(t) for t in merged.values())
    assert taints == [["a"], ["b"]]
