"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel body on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.consensus_update.consensus_update import (
    cdsgd_update_2d,
    cdmsgd_update_2d,
)
from repro.kernels.consensus_update.ref import cdsgd_update_ref, cdmsgd_update_ref
from repro.kernels.consensus_update import ops as cons_ops
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_attention.ops import flash_attention_bshd
from repro.kernels.rwkv_scan.rwkv_scan import wkv6_pallas
from repro.kernels.rwkv_scan.ref import wkv6_ref
from repro.kernels.rwkv_scan.ops import wkv6_bsnh
from repro.nn.ssm import wkv6_scan

KEY = jax.random.PRNGKey(0)


def tol_for(dt):
    return dict(rtol=2e-2, atol=2e-2) if dt == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


# -------------------------------------------------------------------------
# consensus update
# -------------------------------------------------------------------------


@pytest.mark.parametrize("rows", [8, 64, 300, 513])
@pytest.mark.parametrize("stencil", [2, 3, 5])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_cdsgd_kernel_sweep(rows, stencil, dt):
    nb = jax.random.normal(KEY, (stencil, rows, 128)).astype(dt)
    g = jax.random.normal(jax.random.PRNGKey(1), (rows, 128)).astype(dt)
    w = jnp.full((stencil,), 1.0 / stencil, jnp.float32)
    out = cdsgd_update_2d(nb, w, g, 0.05, interpret=True)
    ref = cdsgd_update_ref(nb, w, g, 0.05)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol_for(dt))


@pytest.mark.parametrize("rows", [64, 257])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_cdmsgd_kernel_sweep(rows, dt):
    nb = jax.random.normal(KEY, (3, rows, 128)).astype(dt)
    g = jax.random.normal(jax.random.PRNGKey(1), (rows, 128)).astype(dt)
    v = jax.random.normal(jax.random.PRNGKey(2), (rows, 128)).astype(dt)
    w = jnp.array([0.5, 0.25, 0.25], jnp.float32)
    out, new_v = cdmsgd_update_2d(nb, w, g, v, 0.05, 0.9, interpret=True)
    r_out, r_v = cdmsgd_update_ref(nb, w, g, v, 0.05, 0.9)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r_out, np.float32), **tol_for(dt))
    np.testing.assert_allclose(np.asarray(new_v, np.float32),
                               np.asarray(r_v, np.float32), **tol_for(dt))


def test_consensus_tree_op_matches_optimizer_semantics():
    """Pytree wrapper == CDSGD update with a ring Pi row."""
    tree = {"a": jax.random.normal(KEY, (5, 9)), "b": jax.random.normal(KEY, (17,))}
    left = jax.tree.map(lambda x: x + 1.0, tree)
    right = jax.tree.map(lambda x: x - 2.0, tree)
    grads = jax.tree.map(jnp.ones_like, tree)
    w = jnp.array([1 / 3, 1 / 3, 1 / 3], jnp.float32)
    out = cons_ops.cdsgd_update_tree(tree, [left, right], w, grads, 0.1, interpret=True)
    want = jax.tree.map(
        lambda x, l, r, g: (x + l + r) / 3.0 - 0.1 * g, tree, left, right, grads)
    for k in tree:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(want[k]),
                                   rtol=2e-5, atol=2e-5)


# -------------------------------------------------------------------------
# flash attention
# -------------------------------------------------------------------------


@pytest.mark.parametrize("case", [
    dict(b=2, h=4, kv=2, s=256, d=64, causal=True, window=None, dt=jnp.float32),
    dict(b=1, h=4, kv=1, s=256, d=128, causal=True, window=64, dt=jnp.float32),
    dict(b=1, h=2, kv=2, s=128, d=64, causal=False, window=None, dt=jnp.float32),
    dict(b=1, h=8, kv=2, s=128, d=64, causal=True, window=32, dt=jnp.float32),
    dict(b=1, h=4, kv=4, s=256, d=64, causal=True, window=None, dt=jnp.bfloat16),
])
def test_flash_attention_sweep(case):
    dt = case["dt"]
    q = jax.random.normal(KEY, (case["b"], case["h"], case["s"], case["d"])).astype(dt)
    k = jax.random.normal(jax.random.PRNGKey(1), (case["b"], case["kv"], case["s"], case["d"])).astype(dt)
    v = jax.random.normal(jax.random.PRNGKey(2), (case["b"], case["kv"], case["s"], case["d"])).astype(dt)
    out = flash_attention(q, k, v, causal=case["causal"], window=case["window"],
                          block_q=64, block_k=64, interpret=True)
    ref = attention_ref(q, k, v, causal=case["causal"], window=case["window"])
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol_for(dt))


def test_flash_bshd_wrapper_matches_model_blockwise():
    """Kernel (b,s,h,d) wrapper vs the model's lax.scan blockwise attention."""
    from repro.nn.attention import blockwise_attention
    b, s, h, kv, d = 2, 128, 4, 2, 64
    q = jax.random.normal(KEY, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, d))
    out_kernel = flash_attention_bshd(q, k, v, causal=True, window=None,
                                      block_q=64, block_k=64, interpret=True)
    out_model = blockwise_attention(q, k, v, causal=True, chunk=64)
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_model),
                               rtol=3e-5, atol=3e-5)


def test_flash_rejects_ragged_blocks():
    q = jnp.zeros((1, 2, 100, 64))
    k = v = jnp.zeros((1, 2, 100, 64))
    with pytest.raises(ValueError):
        flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)


# -------------------------------------------------------------------------
# rwkv scan
# -------------------------------------------------------------------------


@pytest.mark.parametrize("bh,s,hs,chunk", [
    (4, 128, 64, 32), (2, 96, 32, 32), (1, 256, 64, 128), (8, 64, 16, 16),
])
def test_wkv6_kernel_sweep(bh, s, hs, chunk):
    ks = jax.random.split(KEY, 5)
    r, k, v = (jax.random.normal(ks[i], (bh, s, hs)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (bh, s, hs))) * 0.5 + 0.45
    u = 0.1 * jax.random.normal(ks[4], (bh, hs))
    y, st = wkv6_pallas(r, k, v, w, u, chunk=chunk, interpret=True)
    yr, sr = wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr), rtol=1e-4, atol=1e-4)


def test_wkv6_ops_wrapper_matches_model_scan():
    b, s, n_h, hs = 2, 64, 2, 32
    ks = jax.random.split(KEY, 5)
    r, k, v = (jax.random.normal(ks[i], (b, s, n_h, hs)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, n_h, hs))) * 0.5 + 0.45
    u = 0.1 * jax.random.normal(ks[4], (n_h, hs))
    y1, s1 = wkv6_bsnh(r, k, v, w, u, chunk=32, interpret=True)
    y2, s2 = wkv6_scan(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-4)


def test_wkv6_state_carry_equals_two_halves():
    """Chunked state carry: running two 64-chunks == one 128 scan."""
    bh, s, hs = 2, 128, 32
    ks = jax.random.split(KEY, 5)
    r, k, v = (jax.random.normal(ks[i], (bh, s, hs)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (bh, s, hs))) * 0.5 + 0.45
    u = 0.1 * jax.random.normal(ks[4], (bh, hs))
    y_one, st_one = wkv6_pallas(r, k, v, w, u, chunk=128, interpret=True)
    y_two, st_two = wkv6_pallas(r, k, v, w, u, chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(y_one), np.asarray(y_two), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_one), np.asarray(st_two), rtol=1e-4, atol=1e-4)
