"""Sharded execution tests: run in a SUBPROCESS with 8 host devices so the
main test process keeps its single-device view (the dryrun contract).

Verifies on a 4x2 ("data","model") debug mesh that:
* the sharded CDSGD train_step lowers, compiles AND runs, with per-agent
  distinct parameters sharded over the data axis,
* ppermute mixing == dense-Pi mixing numerically (same topology),
* the decode serve_step lowers and runs with a sharded KV cache,
* the production mesh builders construct (16,16) and (2,16,16) meshes.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, timeout=560) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-4000:]}"
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


@pytest.mark.slow
def test_sharded_train_step_runs_and_mixings_agree():
    res = run_sub(textwrap.dedent("""
        import dataclasses
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, INPUT_SHAPES
        from repro.configs.base import InputShape
        from repro.core.optim import make_optimizer
        from repro.launch.mesh import make_debug_mesh
        from repro.launch import steps as steps_lib
        from repro.nn.param import init_params

        # f32: differently-compiled bf16 programs pick different XLA-CPU dot
        # strategies (%-level numeric drift) which would mask real bugs here
        cfg = dataclasses.replace(get_config("granite-3-8b").reduced(),
                                  param_dtype="float32")
        shape = InputShape("tiny_train", 16, 8, "train")   # 8 batch over 4 agents
        mesh = make_debug_mesh(4, 2)

        outs = {}
        for mixing in ("dense", "ppermute"):
            opt = make_optimizer("cdsgd", 0.05)
            b = steps_lib.build_train_step(cfg, shape, mesh, opt, mode="train",
                                           topology_name="ring", mixing=mixing)
            params = init_params(b.param_template, jax.random.PRNGKey(0))
            # de-synchronize agents so mixing has something to do
            params = jax.tree.map(
                lambda x: x + 0.01 * jax.random.normal(jax.random.PRNGKey(1), x.shape, x.dtype), params)
            opt_state = opt.init(params)
            rng = np.random.default_rng(0)
            batch = {
                "inputs": jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 2, 16)), jnp.int32),
                "targets": jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 2, 16)), jnp.int32),
            }
            with mesh:
                step = jax.jit(b.step_fn)
                new_params, new_state, metrics = step(params, opt_state, batch)
            outs[mixing] = (new_params, float(metrics["loss"]))

        pd, ld = outs["dense"]; pp, lp = outs["ppermute"]
        diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), pd, pp)
        max_diff = max(jax.tree.leaves(diffs))
        print("RESULT " + json.dumps({
            "loss_dense": ld, "loss_ppermute": lp, "max_param_diff": max_diff,
            "finite": bool(all(jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(pd))),
        }))
    """))
    assert res["finite"]
    assert abs(res["loss_dense"] - res["loss_ppermute"]) < 1e-4
    assert res["max_param_diff"] < 1e-3, "ppermute mixing must equal dense Pi"


@pytest.mark.slow
def test_sharded_fused_train_step_matches_dense():
    """mixing="ppermute_fused" + fused optimizer: the whole-model flat-buffer
    update inside one shard_map region must match dense-Pi mixing, with
    exactly one pallas_call per dtype bucket and one ppermute per non-zero
    circulant shift in the step jaxpr."""
    res = run_sub(textwrap.dedent("""
        import dataclasses
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.core.optim import make_optimizer
        from repro.launch.mesh import make_debug_mesh
        from repro.launch import steps as steps_lib
        from repro.nn.param import init_params

        cfg = dataclasses.replace(get_config("granite-3-8b").reduced(),
                                  param_dtype="float32")
        shape = InputShape("tiny_train", 16, 8, "train")
        mesh = make_debug_mesh(4, 2)

        outs = {}
        for mixing, fused in (("dense", False), ("ppermute_fused", True)):
            opt = make_optimizer("cdmsgd", 0.05, mu=0.9, fused=fused)
            b = steps_lib.build_train_step(cfg, shape, mesh, opt, mode="train",
                                           topology_name="ring", mixing=mixing)
            params = init_params(b.param_template, jax.random.PRNGKey(0))
            params = jax.tree.map(
                lambda x: x + 0.01 * jax.random.normal(jax.random.PRNGKey(1), x.shape, x.dtype), params)
            opt_state = opt.init(params)
            rng = np.random.default_rng(0)
            batch = {
                "inputs": jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 2, 16)), jnp.int32),
                "targets": jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 2, 16)), jnp.int32),
            }
            with mesh:
                if mixing == "ppermute_fused":
                    # structured census via the static checker (PR 10) in
                    # place of counting substrings of the printed jaxpr
                    from repro.analysis import staticcheck
                    from repro.kernels.consensus_update import ops as kops
                    jaxpr = jax.make_jaxpr(b.step_fn)(params, opt_state, batch)
                    rep = staticcheck.check_bundle(
                        b, mesh, batch, passes=("census",))
                    counts = {"pallas": len(kops.alias_groups(jaxpr)),
                              "ppermute": rep.rule("census.ppermute_count").evidence["actual"],
                              "census_ok": rep.rule("census.ppermute_count").ok,
                              "critical_path_ok": rep.rule("census.critical_path").ok}
                step = jax.jit(b.step_fn)
                new_params, new_state, metrics = step(params, opt_state, batch)
            outs[mixing] = (new_params, float(metrics["loss"]))

        pd, ld = outs["dense"]; pp, lp = outs["ppermute_fused"]
        diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), pd, pp)
        print("RESULT " + json.dumps({
            "loss_dense": ld, "loss_fused": lp,
            "max_param_diff": max(jax.tree.leaves(diffs)),
            "n_buckets": 1, "pallas_calls": counts["pallas"],
            "ppermutes": counts["ppermute"],
            "census_ok": counts["census_ok"],
            "critical_path_ok": counts["critical_path_ok"],
        }))
    """))
    assert abs(res["loss_dense"] - res["loss_fused"]) < 1e-4
    assert res["max_param_diff"] < 1e-3, "fused update must equal dense Pi"
    assert res["pallas_calls"] == res["n_buckets"], "one kernel launch per bucket"
    assert res["ppermutes"] == 2, "ring = one ppermute per non-zero shift"
    assert res["census_ok"], "checker's closed-form count must match the trace"
    assert res["critical_path_ok"], "sync schedule: every ppermute may read params"


@pytest.mark.slow
def test_sharded_quantized_fused_tracks_dense_over_20_steps():
    """exchange="int8": the quantized ppermute_fused trajectory must track
    the unquantized dense-Pi trajectory over 20 optimizer steps, with TWO
    ppermutes per non-zero shift (int8 payload + row scales) and the
    params/opt_state donated to the jitted step.

    Documented tolerance: per step each mixed parameter absorbs unbiased
    rounding noise <= row_amax/127 per neighbor term (the native-precision
    self term pays none), so a contractive small-lr trajectory stays within
    a few row-quantization steps of exact mixing: empirically 3.8e-2 max
    |param diff| after 20 CDSGD steps at lr 5e-3 on this reduced
    transformer; asserted at 1e-1.  (Momentum at large lr amplifies any
    per-step perturbation chaotically — bf16 or int8 alike — so
    trajectory-level comparisons are only meaningful in this regime; see
    the loss-level tracking in benchmarks/README.md.)"""
    res = run_sub(textwrap.dedent("""
        import dataclasses
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.core.optim import make_optimizer
        from repro.launch.mesh import make_debug_mesh
        from repro.launch import steps as steps_lib
        from repro.nn.param import init_params

        cfg = dataclasses.replace(get_config("granite-3-8b").reduced(),
                                  param_dtype="float32")
        shape = InputShape("tiny_train", 16, 8, "train")
        mesh = make_debug_mesh(4, 2)

        outs = {}
        for mixing, fused, exch in (("dense", False, "f32"),
                                    ("ppermute_fused", True, "int8")):
            opt = make_optimizer("cdsgd", 0.005, fused=fused)
            b = steps_lib.build_train_step(cfg, shape, mesh, opt, mode="train",
                                           topology_name="ring", mixing=mixing,
                                           exchange=exch)
            params = init_params(b.param_template, jax.random.PRNGKey(0))
            params = jax.tree.map(
                lambda x: x + 0.01 * jax.random.normal(jax.random.PRNGKey(1), x.shape, x.dtype), params)
            opt_state = opt.init(params)
            rng = np.random.default_rng(0)
            batch = {
                "inputs": jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 2, 16)), jnp.int32),
                "targets": jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 2, 16)), jnp.int32),
            }
            with mesh:
                if mixing == "ppermute_fused":
                    # structured census: the checker's closed form predicts
                    # 2 fields (int8 payload + row scales) per non-zero shift
                    from repro.analysis import staticcheck
                    rep = staticcheck.check_bundle(
                        b, mesh, batch, passes=("census",))
                    counts = {"ppermute": rep.rule("census.ppermute_count").evidence["actual"],
                              "census_ok": rep.rule("census.ppermute_count").ok}
                step = jax.jit(b.step_fn, donate_argnums=b.donate_argnums)
                for _ in range(20):
                    params, opt_state, metrics = step(params, opt_state, batch)
            outs[mixing] = (params, float(metrics["loss"]))

        pd, ld = outs["dense"]; pq, lq = outs["ppermute_fused"]
        scale = max(float(jnp.max(jnp.abs(x))) for x in jax.tree.leaves(pd))
        diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), pd, pq)
        print("RESULT " + json.dumps({
            "loss_dense": ld, "loss_int8": lq,
            "max_param_diff": max(jax.tree.leaves(diffs)),
            "param_scale": scale,
            "ppermutes": counts["ppermute"],
            "census_ok": counts["census_ok"],
            "finite": bool(all(jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(pq))),
        }))
    """))
    assert res["finite"]
    # int8 payload + (rows, 1) scales each ppermute per non-zero ring shift
    assert res["ppermutes"] == 4
    assert res["census_ok"], "checker's closed-form count must match the trace"
    assert abs(res["loss_dense"] - res["loss_int8"]) < 5e-2
    assert res["max_param_diff"] < 1e-1, "int8 must track the exact mix"


@pytest.mark.slow
def test_sharded_overlap_schedule_critical_path_and_warning():
    """schedule="overlap" on the sharded path: the jaxpr taint analysis must
    show the ppermutes consuming ONLY the carried wire state (off the
    grad->update critical path — what the dryrun records per config), while
    schedule="sync" ppermutes depend on the current params; plus the
    satellite warning when mixing='ppermute_fused' is paired with a
    fused=False optimizer."""
    res = run_sub(textwrap.dedent("""
        import dataclasses, json, warnings
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.core import engine
        from repro.core.optim import make_optimizer
        from repro.launch.mesh import make_debug_mesh
        from repro.launch import steps as steps_lib
        from repro.nn.param import init_params

        cfg = dataclasses.replace(get_config("granite-3-8b").reduced(),
                                  param_dtype="float32")
        shape = InputShape("tiny_train", 16, 8, "train")
        mesh = make_debug_mesh(4, 2)
        batch = {"inputs": jnp.ones((4, 2, 16), jnp.int32),
                 "targets": jnp.ones((4, 2, 16), jnp.int32)}

        reports = {}
        for schedule, exch in (("sync", "int8"), ("overlap", "int8"),
                               ("overlap", "f32")):
            opt = make_optimizer("cdsgd", 0.005, fused=True)
            b = steps_lib.build_train_step(
                cfg, shape, mesh, opt, mode="train", topology_name="ring",
                mixing="ppermute_fused", exchange=exch, schedule=schedule)
            params = init_params(b.param_template, jax.random.PRNGKey(0))
            with mesh:
                state = b.init_state(params)
                reports[schedule + "_" + exch] = engine.exchange_dependency_report(
                    b.step_fn, params, state, batch)

        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            steps_lib.build_train_step(
                cfg, shape, mesh, make_optimizer("cdsgd", 0.005),
                mode="train", topology_name="ring", mixing="ppermute_fused")
        warned = any("fused=False" in str(w.message) for w in caught)
        print("RESULT " + json.dumps({**reports, "warned_unfused": warned}))
    """))
    # sync: the exchange payload is quantized from the current params, so
    # the collective waits on the previous update; overlap: only on the
    # carried wire buffers.
    assert res["sync_int8"]["n_ppermutes"] == 4
    assert res["sync_int8"]["depends_on_params"]
    assert not res["sync_int8"]["off_grad_update_critical_path"]
    for key in ("overlap_int8", "overlap_f32"):
        assert not res[key]["depends_on_params"]
        assert not res[key]["depends_on_batch"]
        assert res[key]["depends_on_wire_state"]
        assert res[key]["off_grad_update_critical_path"]
    assert res["overlap_int8"]["n_ppermutes"] == 4
    # f32 wire: unit scales are synthesized after the exchange, so only the
    # payload pays a collective — one ppermute per non-zero ring shift
    assert res["overlap_f32"]["n_ppermutes"] == 2
    assert res["warned_unfused"]


@pytest.mark.slow
def test_sharded_overlap_matches_stacked_over_20_steps():
    """schedule="overlap" stacked-vs-sharded 20-step parity on the reduced
    transformer (small-lr CDSGD per the PR 2 quantization caveat).

    Documented tolerance: stacked and sharded compile DIFFERENT backward
    programs (single-device vmap vs pjit), whose gradients agree only to
    ~1.5e-4 relative per step — so even the sync schedule's stacked-vs-
    sharded trajectories drift ~8e-3 apart over 20 lr-5e-3 steps (measured;
    the pre-existing sync parity tests never crossed execution modes, they
    compared two sharded programs).  The test therefore measures the sync
    cross-mode drift as its own baseline in the same subprocess and asserts
    the deterministic f32-wire overlap drift stays within 3x of it
    (measured 1.31e-2 vs 8.4e-3 — staleness recycles the drift one extra
    step but adds no divergence of its own), capped absolutely at 5e-2;
    the int8 wire additionally randomizes the SR streams (the sharded mode
    quantizes model-shard-local buckets, the stacked mode global ones) and
    is asserted at the documented 1e-1 sync-int8 envelope."""
    res = run_sub(textwrap.dedent("""
        import dataclasses, json
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.core.optim import make_optimizer
        from repro.core.trainer import CollaborativeTrainer
        from repro.launch.mesh import make_debug_mesh
        from repro.launch import steps as steps_lib
        from repro.nn.param import init_params
        from repro.nn.transformer import loss_fn

        cfg = dataclasses.replace(get_config("granite-3-8b").reduced(),
                                  param_dtype="float32")
        shape = InputShape("tiny_train", 16, 8, "train")
        mesh = make_debug_mesh(4, 2)
        rng = np.random.default_rng(0)
        batch = {
            "inputs": jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 2, 16)), jnp.int32),
            "targets": jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 2, 16)), jnp.int32),
        }
        out = {}
        for schedule, exch in (("sync", "f32"), ("overlap", "f32"),
                               ("overlap", "int8")):
            opt = make_optimizer("cdsgd", 0.005, fused=True)
            b = steps_lib.build_train_step(
                cfg, shape, mesh, opt, mode="train", topology_name="ring",
                mixing="ppermute_fused", exchange=exch, schedule=schedule)
            params0 = init_params(b.param_template, jax.random.PRNGKey(0))
            params0 = jax.tree.map(
                lambda x: x + 0.01 * jax.random.normal(jax.random.PRNGKey(1), x.shape, x.dtype), params0)

            params = params0
            with mesh:
                opt_state = b.init_state(params)
                step = jax.jit(b.step_fn, donate_argnums=b.donate_argnums)
                for _ in range(20):
                    params, opt_state, metrics = step(params, opt_state, batch)

            tr = CollaborativeTrainer(
                lambda p, bb: loss_fn(cfg, p, bb), params0, b.topology,
                make_optimizer("cdsgd", 0.005, fused=True),
                stack=False, schedule=schedule, exchange=exch)
            for _ in range(20):
                m = tr.step(batch)

            diffs = jax.tree.map(lambda a, c: float(jnp.max(jnp.abs(
                a.astype(jnp.float32) - c.astype(jnp.float32)))),
                params, tr.state.params)
            out[schedule + "_" + exch] = {
                "max_param_diff": max(jax.tree.leaves(diffs)),
                "loss_sharded": float(metrics["loss"]),
                "loss_stacked": float(m["loss"]),
                "finite": bool(all(jnp.all(jnp.isfinite(x))
                                   for x in jax.tree.leaves(params))),
            }
        print("RESULT " + json.dumps(out))
    """), timeout=840)
    for key in ("sync_f32", "overlap_f32", "overlap_int8"):
        assert res[key]["finite"]
        assert abs(res[key]["loss_sharded"] - res[key]["loss_stacked"]) < 5e-2
    base = res["sync_f32"]["max_param_diff"]          # cross-mode fp envelope
    assert res["overlap_f32"]["max_param_diff"] < max(3 * base, 1e-3), \
        "deterministic overlap wire must track the stacked oracle as " \
        "closely as the sync schedule does"
    assert res["overlap_f32"]["max_param_diff"] < 5e-2
    assert res["overlap_int8"]["max_param_diff"] < 1e-1, \
        "int8 overlap must stay inside the documented SR envelope"


@pytest.mark.slow
def test_sharded_microbatch_accumulation_parity():
    """microbatches=2 == microbatches=1 on identical data through the
    shared grad phase (satellite: this path was untested).

    Documented tolerance: single-device the accumulated gradients agree to
    ~3e-7 relative, but under pjit the scanned half-batch backward compiles
    to a differently-partitioned program and every leaf's gradient agrees
    only to ~1.5e-4 RELATIVE (uniform across leaves — dot-strategy
    reassociation, not accumulation error; the forward loss still matches
    to 1e-6).  One lr-5e-3 update turns the largest gradient (embedding
    table, |g| ~ 46) into a 3.6e-5 param diff; asserted at 2e-4.  The test
    stops after one step because the transformer's curvature amplifies this
    fp-level seed ~10x per extra step (measured, lr-independent in relative
    terms)."""
    res = run_sub(textwrap.dedent("""
        import dataclasses, json
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.core.optim import make_optimizer
        from repro.launch.mesh import make_debug_mesh
        from repro.launch import steps as steps_lib
        from repro.nn.param import init_params

        cfg = dataclasses.replace(get_config("granite-3-8b").reduced(),
                                  param_dtype="float32")
        shape = InputShape("tiny_train", 16, 8, "train")
        mesh = make_debug_mesh(4, 2)
        rng = np.random.default_rng(0)
        batch = {
            "inputs": jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 2, 16)), jnp.int32),
            "targets": jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 2, 16)), jnp.int32),
        }
        outs = {}
        for mb in (1, 2):
            opt = make_optimizer("cdsgd", 0.005)
            b = steps_lib.build_train_step(cfg, shape, mesh, opt, mode="train",
                                           topology_name="ring", mixing="dense",
                                           microbatches=mb)
            params = init_params(b.param_template, jax.random.PRNGKey(0))
            opt_state = opt.init(params)
            with mesh:
                step = jax.jit(b.step_fn)
                params, opt_state, metrics = step(params, opt_state, batch)
            outs[mb] = (params, float(metrics["loss"]))

        p1, l1 = outs[1]; p2, l2 = outs[2]
        diffs = jax.tree.map(lambda a, b_: float(jnp.max(jnp.abs(a - b_))), p1, p2)
        print("RESULT " + json.dumps({
            "loss_mb1": l1, "loss_mb2": l2,
            "max_param_diff": max(jax.tree.leaves(diffs)),
        }))
    """))
    assert abs(res["loss_mb1"] - res["loss_mb2"]) < 1e-5
    assert res["max_param_diff"] < 2e-4, \
        "gradient accumulation must equal the single-shot gradient"


@pytest.mark.slow
def test_sharded_serve_step_runs():
    res = run_sub(textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.launch.mesh import make_debug_mesh
        from repro.launch import steps as steps_lib
        from repro.nn.param import init_params
        from repro.nn.transformer import init_cache

        cfg = get_config("granite-3-8b").reduced()
        shape = InputShape("tiny_decode", 32, 8, "decode")
        mesh = make_debug_mesh(4, 2)
        b = steps_lib.build_serve_step(cfg, shape, mesh)
        params = init_params(b.param_template, jax.random.PRNGKey(0))
        cache = init_cache(cfg, 8, 32)
        tok = jnp.ones((8, 1), jnp.int32)
        with mesh:
            step = jax.jit(b.step_fn)
            nxt, cache = step(params, cache, tok, jnp.int32(0))
            nxt2, cache = step(params, cache, nxt, jnp.int32(1))
        print("RESULT " + json.dumps({
            "shape": list(nxt2.shape),
            "finite": bool(jnp.all(nxt2 >= 0)),
        }))
    """))
    assert res["shape"] == [8, 1]
    assert res["finite"]


@pytest.mark.slow
def test_production_meshes_construct():
    res = run_sub(textwrap.dedent("""
        import os, json
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        import jax
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        m2 = make_production_mesh(multi_pod=True)
        print("RESULT " + json.dumps({
            "single": dict(m1.shape), "multi": dict(m2.shape),
            "devices": jax.device_count(),
        }))
    """))
    assert res["single"] == {"data": 16, "model": 16}
    assert res["multi"] == {"pod": 2, "data": 16, "model": 16}
    assert res["devices"] == 512


@pytest.mark.slow
def test_dryrun_cli_single_pair(tmp_path):
    """The dryrun CLI end-to-end on the full production mesh (real 512-dev)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "gemma3-1b",
         "--shape", "decode_32k", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=560, env=env, cwd=REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    files = list(tmp_path.glob("*.json"))
    assert len(files) == 1
    rec = json.loads(files[0].read_text())
    assert rec["status"] == "ok"
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")


@pytest.mark.slow
def test_sharded_mixing_strategies():
    """The MixingProgram strategy layer on the sharded path:

    * multi-round k=2 sync doubles the collectives, all on the critical
      path; k=2 overlap splits them — round 1 consumes only carried wire
      state (``n_ppermutes_carried_only``), round 2 re-quantizes current
      buffers (``n_ppermutes_fresh``) — the ISSUE-4 acceptance criterion
      that overlap's round-1 ppermutes stay off the grad->update critical
      path for every strategy;
    * time-varying f32 (lax.switch over per-entry circulant shift sets)
      matches the stacked dense-Pi_t oracle over 2 steps within the
      documented cross-mode fp envelope;
    * error-feedback overlap keeps ALL collectives off the critical path
      and populates the sharded residual state.
    """
    res = run_sub(textwrap.dedent("""
        import dataclasses, json
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.core import engine
        from repro.core.optim import make_optimizer
        from repro.core.trainer import CollaborativeTrainer
        from repro.launch.mesh import make_debug_mesh
        from repro.launch import steps as steps_lib
        from repro.nn.param import init_params
        from repro.nn.transformer import loss_fn

        cfg = dataclasses.replace(get_config("granite-3-8b").reduced(),
                                  param_dtype="float32")
        shape = InputShape("tiny_train", 16, 8, "train")
        mesh = make_debug_mesh(4, 2)
        rng = np.random.default_rng(0)
        batch = {
            "inputs": jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 2, 16)), jnp.int32),
            "targets": jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 2, 16)), jnp.int32),
        }
        out = {}

        def build(**kw):
            opt = make_optimizer("cdsgd", 0.005, fused=True)
            return steps_lib.build_train_step(
                cfg, shape, mesh, opt, mode="train", topology_name="ring",
                mixing="ppermute_fused", **kw)

        # multi-round reports: sync (2x fresh) vs overlap (round 1 carried)
        for schedule in ("sync", "overlap"):
            b = build(exchange="int8", consensus_rounds=2, schedule=schedule)
            params = init_params(b.param_template, jax.random.PRNGKey(0))
            with mesh:
                state = b.init_state(params)
                out["mr2_" + schedule] = engine.exchange_dependency_report(
                    b.step_fn, params, state, batch)
                if schedule == "overlap":
                    p1, s1, m = jax.jit(b.step_fn)(params, state, batch)
                    out["mr2_overlap_run"] = {
                        "loss": float(m["loss"]),
                        "finite": bool(all(jnp.all(jnp.isfinite(x))
                                           for x in jax.tree.leaves(p1)))}

        # time-varying f32 vs the stacked dense-Pi_t oracle, 2 steps
        b = build(exchange="f32", mixing_strategy="time_varying",
                  topology_schedule="alternating:ring:fully_connected")
        params0 = init_params(b.param_template, jax.random.PRNGKey(0))
        params0 = jax.tree.map(
            lambda x: x + 0.01 * jax.random.normal(jax.random.PRNGKey(1), x.shape, x.dtype), params0)
        params = params0
        with mesh:
            state = b.init_state(params)
            step = jax.jit(b.step_fn)
            for _ in range(2):
                params, state, m = step(params, state, batch)
        tr = CollaborativeTrainer(
            lambda p, bb: loss_fn(cfg, p, bb), params0, b.topology,
            make_optimizer("cdsgd", 0.005, fused=True), stack=False,
            mixing_strategy="time_varying",
            topology_schedule="alternating:ring:fully_connected")
        for _ in range(2):
            ms = tr.step(batch)
        diffs = jax.tree.map(lambda a, c: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - c.astype(jnp.float32)))),
            params, tr.state.params)
        out["tv"] = {"max_param_diff": max(jax.tree.leaves(diffs)),
                     "loss_sharded": float(m["loss"]),
                     "loss_stacked": float(ms["loss"])}

        # time-varying + overlap: the lax.switch branches consume only the
        # carried wire (trace-only; no execution needed for the proof)
        b = build(exchange="int8", mixing_strategy="time_varying",
                  topology_schedule="alternating:ring:fully_connected",
                  schedule="overlap")
        params = init_params(b.param_template, jax.random.PRNGKey(0))
        with mesh:
            state = b.init_state(params)
            out["tv_overlap"] = engine.exchange_dependency_report(
                b.step_fn, params, state, batch)

        # error-feedback overlap: carried-only collectives + residual state
        b = build(exchange="int8", error_feedback=True, schedule="overlap")
        params = init_params(b.param_template, jax.random.PRNGKey(0))
        with mesh:
            state = b.init_state(params)
            out["ef_overlap"] = engine.exchange_dependency_report(
                b.step_fn, params, state, batch)
            p1, s1, m = jax.jit(b.step_fn)(params, state, batch)
        out["ef_overlap_run"] = {
            "loss": float(m["loss"]),
            "res_max": float(max(jnp.max(jnp.abs(r)) for r in s1.residual)),
            "n_res_bufs": len(s1.residual)}
        print("RESULT " + json.dumps(out))
    """), timeout=840)
    # sync k=2: both rounds' collectives wait on the current params
    assert res["mr2_sync"]["n_ppermutes"] == 8
    assert res["mr2_sync"]["n_ppermutes_fresh"] == 8
    assert not res["mr2_sync"]["round1_off_critical_path"]
    # overlap k=2: round 1 (4 ppermutes: 2 shifts x payload+scales) carried,
    # round 2 fresh — overlap composes with multi-round as designed
    assert res["mr2_overlap"]["n_ppermutes"] == 8
    assert res["mr2_overlap"]["n_ppermutes_carried_only"] == 4
    assert res["mr2_overlap"]["n_ppermutes_fresh"] == 4
    assert res["mr2_overlap"]["round1_off_critical_path"]
    assert not res["mr2_overlap"]["off_grad_update_critical_path"]
    assert res["mr2_overlap_run"]["finite"]
    # time-varying: the lax.switch exchange equals dense Pi_t mixing within
    # the documented cross-mode fp envelope (~2e-4/step, 2 steps)
    assert res["tv"]["max_param_diff"] < 2e-3
    assert abs(res["tv"]["loss_sharded"] - res["tv"]["loss_stacked"]) < 1e-3
    # time-varying + overlap: every switch branch's ppermutes consume only
    # carried state (ring branch 2 shifts + fully-connected branch 3, each
    # permuting int8 payload + row scales = 10 collectives, all carried)
    assert res["tv_overlap"]["n_ppermutes"] == 10
    assert res["tv_overlap"]["off_grad_update_critical_path"]
    assert res["tv_overlap"]["round1_off_critical_path"]
    # EF overlap: all collectives carried; residual state is live & sharded
    assert res["ef_overlap"]["off_grad_update_critical_path"]
    assert res["ef_overlap"]["n_ppermutes"] == 4
    assert res["ef_overlap_run"]["res_max"] > 0.0
    assert res["ef_overlap_run"]["n_res_bufs"] >= 1


@pytest.mark.slow
def test_sharded_momentum_mixing_acceptance():
    """ISSUE-5 acceptance, sharded half: the momentum-mixed int8 CDMSGD
    wire through the REAL shard_map machinery (make_local_fused_comm ->
    engine phases -> ppermutes), on the paper MLP testbed at the PR 2
    caveat lr (0.01, mu 0.9), both schedules:

    * drift(mixed-int8 vs mixed-f32, same schedule) is bounded and
      strictly below drift(plain-int8 vs plain-f32) — the same criterion
      and (mesh 4x1: no model sharding, so the shard-local SR streams
      equal the stacked oracle's) the same measured envelope as the
      stacked test in tests/test_mixing.py;
    * the wire widens structurally: int8 mixed moves BOTH payload trees
      -> 8 ppermutes per step (2 ring shifts x (payload + row scales) x
      2 payload trees) vs 4 for plain, all of them consuming ONLY
      carried wire state under schedule='overlap' (the jaxpr taint
      proof), and OptState.wire holds one pair per bucket per payload.
    """
    res = run_sub(textwrap.dedent("""
        import functools, json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core import consensus as C
        from repro.core import engine
        from repro.core.optim import CDMSGD
        from repro.core.topology import make_topology
        from repro.launch.mesh import make_debug_mesh
        from repro.launch import steps as steps_lib
        from repro.nn.paper_models import (classifier_loss,
                                           mlp_classifier_apply,
                                           mlp_classifier_template)
        from repro.nn.param import init_params

        LOSS = functools.partial(classifier_loss, mlp_classifier_apply)
        A = 4
        mesh = make_debug_mesh(A, 1)
        topo = make_topology("ring", A)
        base = init_params(mlp_classifier_template(8, 4, width=16, depth=2),
                           jax.random.PRNGKey(0))
        params0 = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (A,) + x.shape).copy(), base)
        rng = np.random.default_rng(0)
        batch = {"x": jnp.asarray(rng.standard_normal((A, 8, 8)), jnp.float32),
                 "y": jnp.asarray(rng.integers(0, 4, (A, 8)), jnp.int32)}
        pspecs = jax.tree.map(
            lambda x: P(*(("data",) + (None,) * (x.ndim - 1))), params0)
        state_sp = P("data", None, None)

        def build(mm, exch, schedule):
            opt = CDMSGD(0.01, mu=0.9, fused=True)
            program = C.make_mixing_program(topo, exchange=exch,
                                            momentum_mixing=mm)
            comm = steps_lib.make_local_fused_comm(
                topo, mesh, "train", interpret=True, exchange=exch,
                program=program)
            engine.check_program_support(opt, comm)
            opt_specs = opt.state_specs(pspecs)
            n_entries = program.n_payloads  # MLP packs into one f32 bucket
            init_wire = None
            if schedule == "overlap":
                wire_specs = tuple((state_sp, state_sp)
                                   for _ in range(n_entries))
                opt_specs = opt_specs._replace(wire=wire_specs)
                local_wire_init = engine.make_local_wire_init(comm.flat)
                init_wire = lambda p: steps_lib._shard_map(
                    local_wire_init, mesh, (pspecs,), wire_specs)(p)
            update_local = engine.make_update_phase(opt, comm, schedule)
            update_phase = lambda p, g, s: steps_lib._shard_map(
                update_local, mesh, (pspecs, pspecs, opt_specs),
                (pspecs, opt_specs))(p, g, s)
            return engine.StepProgram(
                optimizer=opt, comm=comm,
                grad_phase=engine.make_grad_phase(LOSS),
                update_phase=update_phase, schedule=schedule,
                init_wire=init_wire)

        def run(mm, exch, schedule):
            prog = build(mm, exch, schedule)
            with mesh:
                state = prog.init_state(params0)
                step = jax.jit(prog.step_fn)
                p = params0
                for _ in range(20):
                    p, state, m = step(p, state, batch)
            return p, state, float(m["loss"])

        def md(a, b):
            return max(jax.tree.leaves(jax.tree.map(
                lambda x, y: float(jnp.max(jnp.abs(x - y))), a, b)))

        out = {}
        for schedule in ("sync", "overlap"):
            rp, _, _ = run("none", "f32", schedule)
            rm, _, lm = run("mixed", "f32", schedule)
            pp, _, _ = run("none", "int8", schedule)
            pm, sm, lq = run("mixed", "int8", schedule)
            out[schedule] = {
                "drift_plain": md(rp, pp), "drift_mixed": md(rm, pm),
                "loss_gap_mixed": abs(lq - lm),
                "n_wire_entries": len(sm.wire),
                "finite": bool(all(jnp.all(jnp.isfinite(x))
                                   for x in jax.tree.leaves(pm))),
            }

        # structural: ppermute counts + the overlap taint proof
        for schedule in ("sync", "overlap"):
            for mm, key in (("none", "plain"), ("mixed", "mixed")):
                prog = build(mm, "int8", schedule)
                with mesh:
                    state = prog.init_state(params0)
                    rep = engine.exchange_dependency_report(
                        prog.step_fn, params0, state, batch)
                out[f"rep_{schedule}_{key}"] = rep
        print("RESULT " + json.dumps(out))
    """), timeout=840)
    for schedule in ("sync", "overlap"):
        r = res[schedule]
        assert r["finite"]
        # same criterion + envelope as the stacked acceptance test
        assert r["drift_mixed"] < 5e-2, r
        assert r["drift_mixed"] < r["drift_plain"], r
        assert r["loss_gap_mixed"] < 5e-2, r
    assert res["overlap"]["n_wire_entries"] == 2    # one pair per payload
    # widened wire: 2 ring shifts x (payload + scales) x 2 payload trees
    assert res["rep_sync_plain"]["n_ppermutes"] == 4
    assert res["rep_sync_mixed"]["n_ppermutes"] == 8
    assert res["rep_sync_mixed"]["depends_on_params"]
    assert res["rep_overlap_mixed"]["n_ppermutes"] == 8
    assert res["rep_overlap_mixed"]["n_ppermutes_carried_only"] == 8
    assert res["rep_overlap_mixed"]["off_grad_update_critical_path"]


@pytest.mark.slow
def test_sharded_build_train_step_momentum_mixing():
    """build_train_step threads momentum_mixing end-to-end on the real
    transformer path: the opt-state specs carry one wire pair AND one EF
    residual per bucket per payload, init_state fills them inside
    shard_map, one jitted step runs finite, and the dryrun-style record
    doubles the wire bytes (payloads=2)."""
    res = run_sub(textwrap.dedent("""
        import dataclasses, json
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.core import consensus as consensus_lib
        from repro.core import engine, flatbuf
        from repro.core.optim import make_optimizer
        from repro.launch.mesh import make_debug_mesh
        from repro.launch import steps as steps_lib
        from repro.nn.param import init_params

        cfg = dataclasses.replace(get_config("granite-3-8b").reduced(),
                                  param_dtype="float32")
        shape = InputShape("tiny_train", 16, 8, "train")
        mesh = make_debug_mesh(4, 2)
        opt = make_optimizer("cdmsgd", 0.01, mu=0.9, fused=True)
        b = steps_lib.build_train_step(
            cfg, shape, mesh, opt, mode="train", topology_name="ring",
            mixing="ppermute_fused", exchange="int8", schedule="overlap",
            error_feedback=True, momentum_mixing="mixed")
        params = init_params(b.param_template, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        batch = {
            "inputs": jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 2, 16)), jnp.int32),
            "targets": jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 2, 16)), jnp.int32),
        }
        n_buckets = flatbuf.make_flat_spec(params, lead=1).n_buckets
        with mesh:
            state = b.init_state(params)
            rep = engine.exchange_dependency_report(
                b.step_fn, params, state, batch)
            p1, s1, m = jax.jit(b.step_fn)(params, state, batch)
        wire = consensus_lib.exchange_bytes_per_step(
            flatbuf.make_flat_spec(params, lead=1), b.topology, "int8",
            b.mixing_program.rounds, b.mixing_program.n_payloads)
        base = consensus_lib.exchange_bytes_per_step(
            flatbuf.make_flat_spec(params, lead=1), b.topology, "int8")
        print("RESULT " + json.dumps({
            "n_buckets": n_buckets,
            "n_wire": len(state.wire), "n_residual": len(state.residual),
            "report": rep,
            "loss": float(m["loss"]),
            "finite": bool(all(jnp.all(jnp.isfinite(x))
                               for x in jax.tree.leaves(p1))),
            "residual_live": float(max(jnp.max(jnp.abs(r))
                                       for r in s1.residual)),
            "wire_bytes": wire["per_step_bytes"],
            "wire_bytes_base": base["per_step_bytes"],
        }))
    """), timeout=840)
    assert res["finite"]
    assert res["n_wire"] == 2 * res["n_buckets"]
    assert res["n_residual"] == 2 * res["n_buckets"]
    # overlap + momentum mixing: every collective consumes carried state
    assert res["report"]["n_ppermutes"] == 8 * res["n_buckets"]
    assert res["report"]["off_grad_update_critical_path"]
    assert res["residual_live"] > 0.0
    assert res["wire_bytes"] == 2 * res["wire_bytes_base"]


# host-side mirror of the subprocess fault table: at t = 0 mod 4 every
# sender has just published (stall window is steps 1..3)
FAULT_SEND_AGE_T0 = [0, 0, 0, 0]


@pytest.mark.slow
def test_sharded_bounded_staleness_acceptance():
    """ISSUE-6 acceptance, sharded half: the depth-S staleness ring +
    fault-injection layer through the REAL shard_map machinery
    (make_local_fused_comm -> engine phases -> ppermutes) on the paper
    MLP testbed, subprocess mesh, injected straggler schedule (one
    neighbor up to s_j = S steps stale for a 3-step window) plus one
    permanently dropped link:

    * training completes EVERY step at S in {1, 2, 4}, params finite,
      and the drift vs the fault-free overlap run is bounded — the same
      envelope as the stacked test in tests/test_faults.py;
    * S=1 with no faults (and the ENGAGED ring with no faults) is
      bit-for-bit today's overlap schedule;
    * exchange_dependency_report certifies every ppermute consumes ONLY
      carried wire state at EVERY tested S — the collective count stays
      the plain overlap schedule's 4 (2 ring shifts x (payload + row
      scales)): the ring deepens local state, never the wire.
    """
    res = run_sub(textwrap.dedent("""
        import functools, json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core import consensus as C
        from repro.core import engine
        from repro.core.faults import make_fault_schedule
        from repro.core.optim import CDSGD
        from repro.core.topology import make_topology
        from repro.launch.mesh import make_debug_mesh
        from repro.launch import steps as steps_lib
        from repro.nn.paper_models import (classifier_loss,
                                           mlp_classifier_apply,
                                           mlp_classifier_template)
        from repro.nn.param import init_params

        LOSS = functools.partial(classifier_loss, mlp_classifier_apply)
        A = 4
        mesh = make_debug_mesh(A, 1)
        topo = make_topology("ring", A)
        base = init_params(mlp_classifier_template(8, 4, width=16, depth=2),
                           jax.random.PRNGKey(0))
        params0 = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (A,) + x.shape).copy(), base)
        rng = np.random.default_rng(0)
        batch = {"x": jnp.asarray(rng.standard_normal((A, 8, 8)), jnp.float32),
                 "y": jnp.asarray(rng.integers(0, 4, (A, 8)), jnp.int32)}
        pspecs = jax.tree.map(
            lambda x: P(*(("data",) + (None,) * (x.ndim - 1))), params0)
        state_sp = P("data", None, None)
        FAULT = make_fault_schedule("stall:1:1:3,drop:0:2", A)

        def build(S, fault):
            opt = CDSGD(0.05, fused=True)
            program = C.make_mixing_program(topo, exchange="int8",
                                            staleness=S, faults=fault)
            comm = steps_lib.make_local_fused_comm(
                topo, mesh, "train", interpret=True, exchange="int8",
                program=program)
            engine.check_program_support(opt, comm)
            opt_specs = opt.state_specs(pspecs)
            n_entries = program.n_payloads
            if program.fault_tolerant:
                ring_sp = P("data", None, None, None)
                wire_specs = C.WireRing(
                    slots=tuple((ring_sp, ring_sp)
                                for _ in range(n_entries)),
                    send_age=P("data"), ages=P("data", None))
            else:
                wire_specs = tuple((state_sp, state_sp)
                                   for _ in range(n_entries))
            opt_specs = opt_specs._replace(wire=wire_specs)
            local_wire_init = engine.make_local_wire_init(comm.flat)
            init_wire = lambda p: steps_lib._shard_map(
                local_wire_init, mesh, (pspecs,), wire_specs)(p)
            update_local = engine.make_update_phase(opt, comm, "overlap")
            update_phase = lambda p, g, s: steps_lib._shard_map(
                update_local, mesh, (pspecs, pspecs, opt_specs),
                (pspecs, opt_specs))(p, g, s)
            return engine.StepProgram(
                optimizer=opt, comm=comm,
                grad_phase=engine.make_grad_phase(LOSS),
                update_phase=update_phase, schedule="overlap",
                init_wire=init_wire)

        def run(S, fault, steps=16):
            prog = build(S, fault)
            with mesh:
                state = prog.init_state(params0)
                step = jax.jit(prog.step_fn)
                p = params0
                losses = []
                for _ in range(steps):
                    p, state, m = step(p, state, batch)
                    losses.append(float(m["loss"]))
            return p, state, losses

        def md(a, b):
            return max(jax.tree.leaves(jax.tree.map(
                lambda x, y: float(jnp.max(jnp.abs(x - y))), a, b)))

        p_ref, _, _ = run(1, None)
        out = {"ring_noop_drift": md(p_ref, run(2, None)[0])}
        for S in (1, 2, 4):
            pf, sf, losses = run(S, FAULT)
            prog = build(S, FAULT)
            with mesh:
                st = prog.init_state(params0)
                rep = engine.exchange_dependency_report(
                    prog.step_fn, params0, st, batch)
            out[f"S{S}"] = {
                "drift": md(p_ref, pf),
                "all_finite": bool(all(np.isfinite(l) for l in losses)
                                   and all(jnp.all(jnp.isfinite(x))
                                           for x in jax.tree.leaves(pf))),
                "n_steps": len(losses),
                "send_age": np.asarray(sf.wire.send_age).tolist(),
                "report": rep,
            }
        print("RESULT " + json.dumps(out))
    """), timeout=840)
    # engaged ring + no faults == plain overlap, bit for bit
    assert res["ring_noop_drift"] == 0.0
    for S in (1, 2, 4):
        r = res[f"S{S}"]
        assert r["n_steps"] == 16 and r["all_finite"], r
        # bounded drift vs the fault-free run (stacked envelope, see
        # tests/test_faults.py::FAULT_DRIFT_BOUND)
        assert 0 < r["drift"] < 5e-2, r
        # every collective consumes ONLY carried wire state at every S,
        # and the count stays the plain overlap schedule's 4 — bytes on
        # the wire are independent of the ring depth
        assert r["report"]["n_ppermutes"] == 4, r
        assert r["report"]["n_ppermutes_carried_only"] == 4, r
        assert r["report"]["off_grad_update_critical_path"], r
        assert not r["report"]["depends_on_params"], r
        # the runtime send_age counters match the host fault table at the
        # consumption step the wire is positioned for (16 % period 4 = 0)
        assert r["send_age"] == FAULT_SEND_AGE_T0, r

