"""Sharded execution tests: run in a SUBPROCESS with 8 host devices so the
main test process keeps its single-device view (the dryrun contract).

Verifies on a 4x2 ("data","model") debug mesh that:
* the sharded CDSGD train_step lowers, compiles AND runs, with per-agent
  distinct parameters sharded over the data axis,
* ppermute mixing == dense-Pi mixing numerically (same topology),
* the decode serve_step lowers and runs with a sharded KV cache,
* the production mesh builders construct (16,16) and (2,16,16) meshes.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, timeout=560) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-4000:]}"
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


@pytest.mark.slow
def test_sharded_train_step_runs_and_mixings_agree():
    res = run_sub(textwrap.dedent("""
        import dataclasses
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, INPUT_SHAPES
        from repro.configs.base import InputShape
        from repro.core.optim import make_optimizer
        from repro.launch.mesh import make_debug_mesh
        from repro.launch import steps as steps_lib
        from repro.nn.param import init_params

        # f32: differently-compiled bf16 programs pick different XLA-CPU dot
        # strategies (%-level numeric drift) which would mask real bugs here
        cfg = dataclasses.replace(get_config("granite-3-8b").reduced(),
                                  param_dtype="float32")
        shape = InputShape("tiny_train", 16, 8, "train")   # 8 batch over 4 agents
        mesh = make_debug_mesh(4, 2)

        outs = {}
        for mixing in ("dense", "ppermute"):
            opt = make_optimizer("cdsgd", 0.05)
            b = steps_lib.build_train_step(cfg, shape, mesh, opt, mode="train",
                                           topology_name="ring", mixing=mixing)
            params = init_params(b.param_template, jax.random.PRNGKey(0))
            # de-synchronize agents so mixing has something to do
            params = jax.tree.map(
                lambda x: x + 0.01 * jax.random.normal(jax.random.PRNGKey(1), x.shape, x.dtype), params)
            opt_state = opt.init(params)
            rng = np.random.default_rng(0)
            batch = {
                "inputs": jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 2, 16)), jnp.int32),
                "targets": jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 2, 16)), jnp.int32),
            }
            with mesh:
                step = jax.jit(b.step_fn)
                new_params, new_state, metrics = step(params, opt_state, batch)
            outs[mixing] = (new_params, float(metrics["loss"]))

        pd, ld = outs["dense"]; pp, lp = outs["ppermute"]
        diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), pd, pp)
        max_diff = max(jax.tree.leaves(diffs))
        print("RESULT " + json.dumps({
            "loss_dense": ld, "loss_ppermute": lp, "max_param_diff": max_diff,
            "finite": bool(all(jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(pd))),
        }))
    """))
    assert res["finite"]
    assert abs(res["loss_dense"] - res["loss_ppermute"]) < 1e-4
    assert res["max_param_diff"] < 1e-3, "ppermute mixing must equal dense Pi"


@pytest.mark.slow
def test_sharded_fused_train_step_matches_dense():
    """mixing="ppermute_fused" + fused optimizer: the whole-model flat-buffer
    update inside one shard_map region must match dense-Pi mixing, with
    exactly one pallas_call per dtype bucket and one ppermute per non-zero
    circulant shift in the step jaxpr."""
    res = run_sub(textwrap.dedent("""
        import dataclasses
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.core.optim import make_optimizer
        from repro.launch.mesh import make_debug_mesh
        from repro.launch import steps as steps_lib
        from repro.nn.param import init_params

        cfg = dataclasses.replace(get_config("granite-3-8b").reduced(),
                                  param_dtype="float32")
        shape = InputShape("tiny_train", 16, 8, "train")
        mesh = make_debug_mesh(4, 2)

        outs = {}
        for mixing, fused in (("dense", False), ("ppermute_fused", True)):
            opt = make_optimizer("cdmsgd", 0.05, mu=0.9, fused=fused)
            b = steps_lib.build_train_step(cfg, shape, mesh, opt, mode="train",
                                           topology_name="ring", mixing=mixing)
            params = init_params(b.param_template, jax.random.PRNGKey(0))
            params = jax.tree.map(
                lambda x: x + 0.01 * jax.random.normal(jax.random.PRNGKey(1), x.shape, x.dtype), params)
            opt_state = opt.init(params)
            rng = np.random.default_rng(0)
            batch = {
                "inputs": jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 2, 16)), jnp.int32),
                "targets": jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 2, 16)), jnp.int32),
            }
            with mesh:
                if mixing == "ppermute_fused":
                    jaxpr = str(jax.make_jaxpr(b.step_fn)(params, opt_state, batch))
                    counts = {"pallas": jaxpr.count("pallas_call"),
                              "ppermute": jaxpr.count("ppermute")}
                step = jax.jit(b.step_fn)
                new_params, new_state, metrics = step(params, opt_state, batch)
            outs[mixing] = (new_params, float(metrics["loss"]))

        pd, ld = outs["dense"]; pp, lp = outs["ppermute_fused"]
        diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), pd, pp)
        print("RESULT " + json.dumps({
            "loss_dense": ld, "loss_fused": lp,
            "max_param_diff": max(jax.tree.leaves(diffs)),
            "n_buckets": 1, "pallas_calls": counts["pallas"],
            "ppermutes": counts["ppermute"],
        }))
    """))
    assert abs(res["loss_dense"] - res["loss_fused"]) < 1e-4
    assert res["max_param_diff"] < 1e-3, "fused update must equal dense Pi"
    assert res["pallas_calls"] == res["n_buckets"], "one kernel launch per bucket"
    assert res["ppermutes"] == 2, "ring = one ppermute per non-zero shift"


@pytest.mark.slow
def test_sharded_quantized_fused_tracks_dense_over_20_steps():
    """exchange="int8": the quantized ppermute_fused trajectory must track
    the unquantized dense-Pi trajectory over 20 optimizer steps, with TWO
    ppermutes per non-zero shift (int8 payload + row scales) and the
    params/opt_state donated to the jitted step.

    Documented tolerance: per step each mixed parameter absorbs unbiased
    rounding noise <= row_amax/127 per neighbor term (the native-precision
    self term pays none), so a contractive small-lr trajectory stays within
    a few row-quantization steps of exact mixing: empirically 3.8e-2 max
    |param diff| after 20 CDSGD steps at lr 5e-3 on this reduced
    transformer; asserted at 1e-1.  (Momentum at large lr amplifies any
    per-step perturbation chaotically — bf16 or int8 alike — so
    trajectory-level comparisons are only meaningful in this regime; see
    the loss-level tracking in benchmarks/README.md.)"""
    res = run_sub(textwrap.dedent("""
        import dataclasses
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.core.optim import make_optimizer
        from repro.launch.mesh import make_debug_mesh
        from repro.launch import steps as steps_lib
        from repro.nn.param import init_params

        cfg = dataclasses.replace(get_config("granite-3-8b").reduced(),
                                  param_dtype="float32")
        shape = InputShape("tiny_train", 16, 8, "train")
        mesh = make_debug_mesh(4, 2)

        outs = {}
        for mixing, fused, exch in (("dense", False, "f32"),
                                    ("ppermute_fused", True, "int8")):
            opt = make_optimizer("cdsgd", 0.005, fused=fused)
            b = steps_lib.build_train_step(cfg, shape, mesh, opt, mode="train",
                                           topology_name="ring", mixing=mixing,
                                           exchange=exch)
            params = init_params(b.param_template, jax.random.PRNGKey(0))
            params = jax.tree.map(
                lambda x: x + 0.01 * jax.random.normal(jax.random.PRNGKey(1), x.shape, x.dtype), params)
            opt_state = opt.init(params)
            rng = np.random.default_rng(0)
            batch = {
                "inputs": jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 2, 16)), jnp.int32),
                "targets": jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 2, 16)), jnp.int32),
            }
            with mesh:
                if mixing == "ppermute_fused":
                    jaxpr = str(jax.make_jaxpr(b.step_fn)(params, opt_state, batch))
                    counts = {"ppermute": jaxpr.count("ppermute")}
                step = jax.jit(b.step_fn, donate_argnums=b.donate_argnums)
                for _ in range(20):
                    params, opt_state, metrics = step(params, opt_state, batch)
            outs[mixing] = (params, float(metrics["loss"]))

        pd, ld = outs["dense"]; pq, lq = outs["ppermute_fused"]
        scale = max(float(jnp.max(jnp.abs(x))) for x in jax.tree.leaves(pd))
        diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), pd, pq)
        print("RESULT " + json.dumps({
            "loss_dense": ld, "loss_int8": lq,
            "max_param_diff": max(jax.tree.leaves(diffs)),
            "param_scale": scale,
            "ppermutes": counts["ppermute"],
            "finite": bool(all(jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(pq))),
        }))
    """))
    assert res["finite"]
    # int8 payload + (rows, 1) scales each ppermute per non-zero ring shift
    assert res["ppermutes"] == 4
    assert abs(res["loss_dense"] - res["loss_int8"]) < 5e-2
    assert res["max_param_diff"] < 1e-1, "int8 must track the exact mix"


@pytest.mark.slow
def test_sharded_serve_step_runs():
    res = run_sub(textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.launch.mesh import make_debug_mesh
        from repro.launch import steps as steps_lib
        from repro.nn.param import init_params
        from repro.nn.transformer import init_cache

        cfg = get_config("granite-3-8b").reduced()
        shape = InputShape("tiny_decode", 32, 8, "decode")
        mesh = make_debug_mesh(4, 2)
        b = steps_lib.build_serve_step(cfg, shape, mesh)
        params = init_params(b.param_template, jax.random.PRNGKey(0))
        cache = init_cache(cfg, 8, 32)
        tok = jnp.ones((8, 1), jnp.int32)
        with mesh:
            step = jax.jit(b.step_fn)
            nxt, cache = step(params, cache, tok, jnp.int32(0))
            nxt2, cache = step(params, cache, nxt, jnp.int32(1))
        print("RESULT " + json.dumps({
            "shape": list(nxt2.shape),
            "finite": bool(jnp.all(nxt2 >= 0)),
        }))
    """))
    assert res["shape"] == [8, 1]
    assert res["finite"]


@pytest.mark.slow
def test_production_meshes_construct():
    res = run_sub(textwrap.dedent("""
        import os, json
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        import jax
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        m2 = make_production_mesh(multi_pod=True)
        print("RESULT " + json.dumps({
            "single": dict(m1.shape), "multi": dict(m2.shape),
            "devices": jax.device_count(),
        }))
    """))
    assert res["single"] == {"data": 16, "model": 16}
    assert res["multi"] == {"pod": 2, "data": 16, "model": 16}
    assert res["devices"] == 512


@pytest.mark.slow
def test_dryrun_cli_single_pair(tmp_path):
    """The dryrun CLI end-to-end on the full production mesh (real 512-dev)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "gemma3-1b",
         "--shape", "decode_32k", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=560, env=env, cwd=REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    files = list(tmp_path.glob("*.json"))
    assert len(files) == 1
    rec = json.loads(files[0].read_text())
    assert rec["status"] == "ok"
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
