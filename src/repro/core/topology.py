"""Fixed communication topologies and agent-interaction matrices.

The paper (§2, Assumption 2) requires the agent-interaction matrix ``Pi`` to
be doubly stochastic with ``null{I - Pi} = span{1}`` (connected graph) and
``I >= Pi > 0`` (positive definite).  This module provides:

* standard graph constructions (fully-connected, ring, chain, 2-D torus,
  star, Erdos-Renyi) as adjacency matrices,
* ``Pi`` constructions: *uniform* (paper's default for fully-connected) and
  *Metropolis-Hastings* weights for arbitrary graphs, with a *lazy* blend
  ``Pi <- (1-beta) I + beta Pi`` to enforce positive-definiteness,
* spectral utilities: ``lambda_2``, ``lambda_N``, spectral gap — the
  quantities that appear in Proposition 1 / Theorems 1-4,
* a *circulant* view (neighbor shift offsets + weights) used by the
  ``shard_map`` mixing path: on a TPU mesh, a circulant topology lowers to a
  static set of ``lax.ppermute`` collectives over the agent axis.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# --------------------------------------------------------------------------
# Adjacency constructions
# --------------------------------------------------------------------------


def fully_connected_adjacency(n: int) -> np.ndarray:
    a = np.ones((n, n), dtype=np.float64)
    np.fill_diagonal(a, 0.0)
    return a


def ring_adjacency(n: int) -> np.ndarray:
    a = np.zeros((n, n), dtype=np.float64)
    for j in range(n):
        a[j, (j + 1) % n] = 1.0
        a[j, (j - 1) % n] = 1.0
    if n <= 2:  # ring of 2 collapses to a single edge
        a = np.minimum(a, 1.0)
    return a


def chain_adjacency(n: int) -> np.ndarray:
    a = np.zeros((n, n), dtype=np.float64)
    for j in range(n - 1):
        a[j, j + 1] = 1.0
        a[j + 1, j] = 1.0
    return a


def star_adjacency(n: int) -> np.ndarray:
    a = np.zeros((n, n), dtype=np.float64)
    a[0, 1:] = 1.0
    a[1:, 0] = 1.0
    return a


def torus2d_adjacency(rows: int, cols: int) -> np.ndarray:
    """2-D torus — matches the physical ICI mesh of a TPU pod slice."""
    n = rows * cols
    a = np.zeros((n, n), dtype=np.float64)

    def idx(r, c):
        return (r % rows) * cols + (c % cols)

    for r in range(rows):
        for c in range(cols):
            j = idx(r, c)
            for dr, dc in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                a[j, idx(r + dr, c + dc)] = 1.0
    np.fill_diagonal(a, 0.0)
    return a


def erdos_renyi_adjacency(n: int, p: float, seed: int = 0) -> np.ndarray:
    """Random connected graph (resamples until connected)."""
    rng = np.random.default_rng(seed)
    for _ in range(1000):
        u = rng.random((n, n)) < p
        a = np.triu(u, 1).astype(np.float64)
        a = a + a.T
        if _is_connected(a):
            return a
    raise RuntimeError(f"could not sample a connected G({n},{p}) graph")


def _is_connected(adj: np.ndarray) -> bool:
    n = adj.shape[0]
    seen = {0}
    frontier = [0]
    while frontier:
        j = frontier.pop()
        for l in np.nonzero(adj[j])[0]:
            if l not in seen:
                seen.add(int(l))
                frontier.append(int(l))
    return len(seen) == n


# --------------------------------------------------------------------------
# Pi constructions (Assumption 2)
# --------------------------------------------------------------------------


def uniform_pi(n: int) -> np.ndarray:
    """Uniform fully-connected Pi = (1/N) 11^T — the paper's default.

    Note: eigenvalues are {1, 0, ..., 0}, so Assumption 2(d) ``Pi > 0`` is
    met only in the lazy form; the paper's experiments use this matrix
    regardless, and so do we (mixing with it reproduces exact averaging).
    """
    return np.full((n, n), 1.0 / n, dtype=np.float64)


def metropolis_pi(adj: np.ndarray) -> np.ndarray:
    """Metropolis-Hastings weights: doubly stochastic for any graph."""
    n = adj.shape[0]
    deg = adj.sum(axis=1)
    pi = np.zeros_like(adj)
    for j in range(n):
        for l in np.nonzero(adj[j])[0]:
            pi[j, l] = 1.0 / (1.0 + max(deg[j], deg[l]))
    for j in range(n):
        pi[j, j] = 1.0 - pi[j].sum()
    return pi


def lazy(pi: np.ndarray, beta: float = 0.5) -> np.ndarray:
    """Blend with identity: guarantees ``Pi > 0`` (Assumption 2d)."""
    n = pi.shape[0]
    return (1.0 - beta) * np.eye(n) + beta * pi


def validate_pi(pi: np.ndarray, *, require_positive: bool = False, atol: float = 1e-8) -> None:
    """Check Assumption 2; raises ValueError on violation."""
    n = pi.shape[0]
    if pi.shape != (n, n):
        raise ValueError("Pi must be square")
    if not np.allclose(pi.sum(axis=0), 1.0, atol=atol):
        raise ValueError("Pi columns must sum to 1 (1^T Pi = 1^T)")
    if not np.allclose(pi.sum(axis=1), 1.0, atol=atol):
        raise ValueError("Pi rows must sum to 1 (Pi 1 = 1)")
    if not np.allclose(pi, pi.T, atol=atol):
        raise ValueError("Pi must be symmetric (undirected graph)")
    ev = np.linalg.eigvalsh(pi)
    if ev[-1] > 1.0 + 1e-6:
        raise ValueError(f"lambda_1(Pi) = {ev[-1]} > 1")
    # connectivity: eigenvalue 1 must be simple
    if n > 1 and ev[-2] > 1.0 - 1e-10:
        raise ValueError("graph disconnected: lambda_2(Pi) == 1")
    if require_positive and ev[0] <= 0.0:
        raise ValueError(f"lambda_N(Pi) = {ev[0]} <= 0 violates Assumption 2(d)")


# --------------------------------------------------------------------------
# Spectral quantities (Proposition 1 / Theorems 1-4)
# --------------------------------------------------------------------------


def eigenvalues(pi: np.ndarray) -> np.ndarray:
    """Eigenvalues sorted descending: lambda_1 >= ... >= lambda_N."""
    return np.linalg.eigvalsh(pi)[::-1]


def lambda_2(pi: np.ndarray) -> float:
    return float(eigenvalues(pi)[1])


def lambda_n(pi: np.ndarray) -> float:
    return float(eigenvalues(pi)[-1])


def spectral_gap(pi: np.ndarray) -> float:
    """1 - lambda_2(Pi): controls consensus (Prop. 1) and rate (Thm 1)."""
    return 1.0 - lambda_2(pi)


# --------------------------------------------------------------------------
# Topology object
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Topology:
    """A fixed communication topology over ``n_agents``.

    ``pi`` is the dense agent-interaction matrix (Assumption 2).  When the
    matrix is *circulant* (ring/torus/fully-connected with uniform weights),
    ``shift_weights`` gives the {offset: weight} decomposition
    ``Pi = sum_s w_s P^s`` with ``P`` the cyclic shift — the form consumed
    by the ``lax.ppermute`` mixing path in :mod:`repro.core.consensus`.
    """

    name: str
    pi: np.ndarray  # (n, n) float64

    @property
    def n_agents(self) -> int:
        return self.pi.shape[0]

    @property
    def lambda2(self) -> float:
        return lambda_2(self.pi)

    @property
    def lambdan(self) -> float:
        return lambda_n(self.pi)

    @property
    def spectral_gap(self) -> float:
        return spectral_gap(self.pi)

    def shift_weights(self, atol: float = 1e-12) -> Optional[Dict[int, float]]:
        """Return {offset: weight} if Pi is circulant, else None."""
        n = self.n_agents
        row0 = self.pi[0]
        for j in range(1, n):
            if not np.allclose(self.pi[j], np.roll(row0, j), atol=atol):
                return None
        return {s: float(row0[s]) for s in range(n) if abs(row0[s]) > atol}

    def neighbor_lists(self, atol: float = 1e-12) -> List[List[Tuple[int, float]]]:
        """Per-agent [(neighbor, weight)] including self."""
        out = []
        for j in range(self.n_agents):
            out.append([(int(l), float(w)) for l, w in enumerate(self.pi[j]) if abs(w) > atol])
        return out

    def degree(self) -> int:
        """Max number of non-self neighbors (communication cost proxy)."""
        return int(max((np.abs(self.pi[j]) > 1e-12).sum() - 1 for j in range(self.n_agents)))


def gossip_pair_pi(n: int, i: int, j: int) -> np.ndarray:
    """Single-pair gossip matrix ``W = I - (e_i - e_j)(e_i - e_j)^T / 2``.

    Doubly stochastic, symmetric, PSD; agents ``i`` and ``j`` average,
    everyone else keeps their value.  One of these alone is *disconnected*
    for ``n > 2`` — only the union over a schedule period mixes globally
    (B-connectivity), which :meth:`TopologySchedule.validate` checks.
    """
    pi = np.eye(n)
    pi[i, i] = pi[j, j] = 0.5
    pi[i, j] = pi[j, i] = 0.5
    return pi


def make_topology(
    name: str,
    n_agents: int,
    *,
    lazy_beta: Optional[float] = None,
    seed: int = 0,
    er_prob: float = 0.4,
    torus_shape: Optional[Tuple[int, int]] = None,
) -> Topology:
    """Factory for the topologies used across the paper's experiments.

    Names: ``fully_connected`` (uniform Pi, paper default), ``ring``,
    ``chain``, ``star``, ``torus`` (2-D, TPU-ICI-shaped), ``erdos_renyi``,
    ``disconnected_self`` (Pi = I; degenerate control).
    """
    if n_agents < 1:
        raise ValueError("n_agents must be >= 1")
    if name == "fully_connected":
        pi = uniform_pi(n_agents)
    elif name == "ring":
        pi = metropolis_pi(ring_adjacency(n_agents))
    elif name == "chain":
        pi = metropolis_pi(chain_adjacency(n_agents))
    elif name == "star":
        pi = metropolis_pi(star_adjacency(n_agents))
    elif name == "torus":
        if torus_shape is None:
            r = int(np.sqrt(n_agents))
            while n_agents % r:
                r -= 1
            torus_shape = (r, n_agents // r)
        if torus_shape[0] * torus_shape[1] != n_agents:
            raise ValueError("torus_shape must multiply to n_agents")
        pi = metropolis_pi(torus2d_adjacency(*torus_shape))
    elif name == "erdos_renyi":
        pi = metropolis_pi(erdos_renyi_adjacency(n_agents, er_prob, seed))
    elif name == "disconnected_self":
        pi = np.eye(n_agents)
    else:
        raise ValueError(f"unknown topology {name!r}")
    if lazy_beta is not None:
        pi = lazy(pi, lazy_beta)
    if name not in ("disconnected_self",):
        validate_pi(pi)
    return Topology(name=name, pi=pi)


# --------------------------------------------------------------------------
# Time-varying topology schedules (B-connected sequences of Pi_t)
# --------------------------------------------------------------------------

# step-strided PRNG seeding, matching the stochastic-rounding seed pattern
# in repro.core.consensus (_SEED_STEP_STRIDE there): schedule entry t draws
# from an rng seeded `user_seed + stride * t`, so two schedules built with
# different seeds never share a per-step stream.
_SCHEDULE_SEED_STRIDE = 1000003


@dataclasses.dataclass(frozen=True)
class TopologySchedule:
    """A static-shape periodic sequence of agent-interaction matrices.

    ``Pi_t = topologies[t % period]`` — the mixing matrix consumed at
    optimizer step ``t`` by the ``TimeVaryingMixing`` strategy
    (:mod:`repro.core.consensus`).  ``period == 1`` is the paper's fixed
    topology.  Individual entries need NOT be connected (a gossip pair
    mixes only two agents); consensus requires only the *product over one
    period* to contract the disagreement subspace — B-connectivity in the
    sense of Jiang et al. (1805.12120) — which :meth:`validate` checks and
    :meth:`effective_lambda2` quantifies.

    Spectral diagnostics: the disagreement contraction over one period is
    ``sigma_max((Pi_{T-1}^k ... Pi_0^k)(I - 11^T/n))`` for ``k`` consensus
    rounds per step, and :meth:`effective_lambda2` is its per-step
    geometric mean — the quantity that replaces ``lambda_2(Pi)`` in
    Proposition 1 / Theorem 1 (see ``repro.core.lyapunov``'s
    schedule-aware bounds).
    """

    name: str
    topologies: Tuple[Topology, ...]

    def __post_init__(self):
        if not self.topologies:
            raise ValueError("TopologySchedule needs at least one topology")
        n = self.topologies[0].n_agents
        if any(t.n_agents != n for t in self.topologies):
            raise ValueError("all schedule entries must share n_agents")

    @property
    def period(self) -> int:
        return len(self.topologies)

    @property
    def n_agents(self) -> int:
        return self.topologies[0].n_agents

    @property
    def is_static(self) -> bool:
        return self.period == 1

    def topology_at(self, step: int) -> Topology:
        return self.topologies[step % self.period]

    def pi_stack(self) -> np.ndarray:
        """(period, n, n) float64 stack of the per-step mixing matrices."""
        return np.stack([t.pi for t in self.topologies])

    def product_pi(self, rounds: int = 1) -> np.ndarray:
        """``Pi_{T-1}^k @ ... @ Pi_0^k`` — one period of k-round mixing."""
        if rounds < 1:
            raise ValueError(f"rounds must be >= 1, got {rounds}")
        prod = np.eye(self.n_agents)
        for t in self.topologies:
            prod = np.linalg.matrix_power(t.pi, rounds) @ prod
        return prod

    def effective_lambda2(self, rounds: int = 1) -> float:
        """Per-step disagreement contraction factor of the schedule.

        ``sigma_max(P (I - 11^T/n)) ** (1/period)`` for the one-period
        product ``P`` — equals ``lambda_2(Pi)^rounds`` for a static
        symmetric-PSD schedule, and is < 1 iff the schedule is B-connected
        over its period.  (The product of symmetric matrices is generally
        non-symmetric, hence the singular value, not an eigenvalue.)
        """
        n = self.n_agents
        if n == 1:
            return 0.0
        proj = np.eye(n) - np.ones((n, n)) / n
        sig = float(np.linalg.norm(self.product_pi(rounds) @ proj, ord=2))
        return sig ** (1.0 / self.period)

    def effective_spectral_gap(self, rounds: int = 1) -> float:
        """``1 - effective_lambda2`` — the schedule's per-step consensus
        rate (Prop. 1 with the product matrix)."""
        return 1.0 - self.effective_lambda2(rounds)

    def max_degree(self) -> int:
        """Worst per-step neighbor count — sizes the wire double-buffers."""
        return max(t.degree() for t in self.topologies)

    def mean_degree(self) -> float:
        """Period-averaged neighbor count — the amortized per-step wire
        cost multiplier (a gossip-pair schedule pays ~2/n of a ring)."""
        return float(np.mean([t.degree() for t in self.topologies]))

    def validate(self) -> None:
        """Per-entry Assumption 2 (minus connectivity) + B-connectivity of
        the period product.  Raises ValueError on violation."""
        for i, t in enumerate(self.topologies):
            pi = t.pi
            if not np.allclose(pi.sum(axis=0), 1.0, atol=1e-8) or \
               not np.allclose(pi.sum(axis=1), 1.0, atol=1e-8):
                raise ValueError(f"schedule entry {i} is not doubly stochastic")
            if not np.allclose(pi, pi.T, atol=1e-8):
                raise ValueError(f"schedule entry {i} is not symmetric")
        if self.n_agents > 1 and self.effective_lambda2() >= 1.0 - 1e-10:
            raise ValueError(
                f"schedule {self.name!r} is not B-connected over its period "
                f"(product disagreement norm >= 1): the union graph of "
                f"{[t.name for t in self.topologies]} does not mix")

    def diagnostics(self, rounds: int = 1) -> dict:
        """The spectral-gap-vs-wire-cost record printed by the examples and
        the dryrun: per-entry gaps, the product's effective gap (tighter
        than any single entry for rounds > 1 / alternating schedules), and
        the degree-based wire multipliers."""
        return {
            "name": self.name,
            "period": self.period,
            "n_agents": self.n_agents,
            "rounds": rounds,
            "per_matrix_lambda2": [t.lambda2 for t in self.topologies],
            "per_matrix_gap": [t.spectral_gap for t in self.topologies],
            "effective_lambda2": self.effective_lambda2(rounds),
            "effective_gap": self.effective_spectral_gap(rounds),
            "max_degree": self.max_degree(),
            "mean_degree": self.mean_degree(),
            # neighbor transfers per step, amortized over the period
            "transfers_per_step": self.mean_degree() * rounds,
        }


def fixed_schedule(topology: Topology) -> TopologySchedule:
    """The degenerate period-1 schedule (the paper's fixed topology)."""
    return TopologySchedule(name=f"fixed:{topology.name}",
                            topologies=(topology,))


def make_topology_schedule(
    spec: str,
    n_agents: int,
    *,
    period: int = 8,
    seed: int = 0,
) -> TopologySchedule:
    """Factory for the schedules used by the ``TimeVaryingMixing`` strategy.

    ``spec`` grammar:

    * a plain topology name (``"ring"``, ``"torus"``, ...) — fixed schedule;
    * ``"alternating"`` — ring/torus alternation (each entry connected, so
      the pair is trivially B-connected; the product gap beats either);
    * ``"alternating:<a>:<b>[:<c>...]"`` — cycle through named topologies;
    * ``"gossip"`` / ``"gossip:<T>"`` — ``T`` (default ``period``)
      randomized gossip-pair matrices drawn with the step-strided PRNG
      pattern of the int8 exchange seeds; individual steps mix only one
      pair (degree 1 — minimal wire), resampled until the union over the
      period is connected.
    """
    if ":" in spec:
        kind, _, rest = spec.partition(":")
    else:
        kind, rest = spec, ""
    if kind == "alternating":
        names = rest.split(":") if rest else ["ring", "torus"]
        if len(names) < 2:
            raise ValueError("alternating schedule needs >= 2 topology names")
        topos = tuple(make_topology(n, n_agents) for n in names)
        sched = TopologySchedule(name=spec, topologies=topos)
    elif kind == "gossip":
        t_period = int(rest) if rest else period
        if n_agents < 2:
            raise ValueError("gossip schedule needs >= 2 agents")
        if t_period < n_agents - 1:
            # connectivity needs a spanning tree: >= n-1 distinct edges,
            # one pair per step — shorter periods can NEVER be B-connected
            raise ValueError(
                f"gossip period {t_period} cannot connect {n_agents} agents "
                f"(union of {t_period} pair edges < the {n_agents - 1} a "
                f"spanning tree needs); use 'gossip:{n_agents - 1}' or more")
        for attempt in range(1000):
            rng_base = seed + attempt * 7919
            pairs = []
            for t in range(t_period):
                rng = np.random.default_rng(rng_base + _SCHEDULE_SEED_STRIDE * t)
                i, j = map(int, rng.choice(n_agents, size=2, replace=False))
                pairs.append((i, j))
            union = np.zeros((n_agents, n_agents))
            for i, j in pairs:
                union[i, j] = union[j, i] = 1.0
            if _is_connected(union):
                break
        else:
            raise RuntimeError(
                f"could not sample a connected {t_period}-step gossip "
                f"schedule over {n_agents} agents")
        topos = tuple(
            Topology(name=f"gossip_pair_{i}_{j}", pi=gossip_pair_pi(n_agents, i, j))
            for i, j in pairs)
        sched = TopologySchedule(name=spec, topologies=topos)
    else:
        sched = fixed_schedule(make_topology(spec, n_agents, seed=seed))
    sched.validate()
    return sched
