"""StepProgram: one phase-pipeline for both execution modes.

Every collaborative training step — stacked simulation
(:class:`repro.core.trainer.CollaborativeTrainer`) and sharded production
(:func:`repro.launch.steps.build_train_step`) — is the same five named
phases; this module is their single definition and the front-ends only
supply mode-specific comm ops and (for the sharded mode) the ``shard_map``
wrapper around the update group:

* ``grad``     — one vmapped backward over the leading agent axis,
  including the gradient-accumulation ``scan`` when ``microbatches > 1``
  (:func:`make_grad_phase`);
* ``pack``     — the parameter pytree into dtype-bucketed ``(rows, 128)``
  flat buffers (:mod:`repro.core.flatbuf`);
* ``quantize`` — stochastic-rounding int8/fp8 wire payloads + per-row f32
  scales (``FlatComm.quantize_stage``; f32/bf16 wires cast + unit scales);
* ``exchange`` — neighbor mixing operands: dense-``Pi`` stacks in the
  stacked mode, one circulant ``lax.ppermute`` per shift per bucket in the
  sharded mode (``FlatComm.exchange_stage``);
* ``update``   — the fused Pallas kernel per bucket (or the reference
  per-leaf path for unfused optimizers).

Schedules
---------
``schedule="sync"`` (default) runs quantize -> exchange -> update on the
*current* params inside the optimizer's ``comm.flat.gather`` — today's
semantics, bit-for-bit.

``schedule="overlap"`` pipelines the exchange one step deep: the quantized
buckets + row scales live double-buffered in ``OptState.wire``, so step
``t`` exchanges the payload quantized at step ``t-1`` while the backward of
step ``t`` runs.  The update becomes the one-step-stale mixing

    x^i_{t+1} = pi_ii x^i_t + sum_{j != i} pi_ij q(x^j_{t-1}) - alpha g^i_t

with the self term always fresh and full precision (it never crosses the
wire).  The staleness rides entirely in *which* buffers feed the existing
fused kernels' self-separated ``(self, wire payloads)`` weight form — no
new kernel variants.  Lian et al. (1705.09056) show decentralized SGD
tolerates exactly this stale/pipelined communication at an unchanged
convergence rate; Jiang et al. (1805.12120) generalize the mixing schedule.
The payoff is structural: the ``ppermute``\\ s consume only carried
optimizer state, so the collective is off the grad->update critical path —
:func:`exchange_dependency_report` proves it from the jaxpr and the dryrun
records it.

Mixing strategies
-----------------
What one "exchange" means per step is owned by the comm's
:class:`repro.core.consensus.MixingStrategy` (configured by a
``MixingProgram``): fixed ``Pi``, step-indexed time-varying ``Pi_t``, or
``k`` inner consensus rounds.  The engine only decides *which wire feeds
round 1* — fresh (sync) or carried (overlap; rounds ``2..k`` always stay
on the critical path) — and threads the error-feedback residual state
(``OptState.residual``) through the round-1 quantizer when the program
asks for it.  With ``momentum_mixing="mixed"`` the engine also packs the
optimizer's momentum buffer (``DistributedOptimizer.momentum_tree``) as
a second wire payload next to the params — the strategy exchanges both
with the same weights and the engine splits the operands back into the
:class:`ExchangeResult` payload groups the fused kernels consume.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import consensus
from repro.core.optim import (
    CommOps,
    DistributedOptimizer,
    ExchangeResult,
    OptState,
)

PyTree = Any

PHASES = ("grad", "pack", "quantize", "exchange", "update")
SCHEDULES = ("sync", "overlap")


# --------------------------------------------------------------------------
# grad phase (shared by both execution modes)
# --------------------------------------------------------------------------


def make_grad_phase(agent_loss: Callable, microbatches: int = 1) -> Callable:
    """The ``grad`` phase: ``(gp, batch) -> ((losses, metrics), grads)``.

    ``agent_loss(params, batch) -> (loss, metrics)`` is the single-agent
    loss; the phase vmaps its value_and_grad over the leading agent axis.
    ``microbatches > 1`` splits the per-agent batch dim and accumulates
    gradients in f32 over a ``lax.scan`` (losses/metrics keep the leading
    microbatch axis; callers reduce with ``jnp.mean`` either way).
    """
    grad_fn = jax.vmap(jax.value_and_grad(agent_loss, has_aux=True))
    if microbatches == 1:
        return grad_fn

    def grad_phase(gp, batch):
        # gradient accumulation: (A, B, ...) -> scan over (M, A, B/M, ...)
        def split(x):
            a, b = x.shape[:2]
            return jnp.moveaxis(
                x.reshape(a, microbatches, b // microbatches, *x.shape[2:]), 1, 0)

        mb = jax.tree.map(split, batch)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), gp)

        def mb_step(acc, one):
            (l, met), g = grad_fn(gp, one)
            acc = jax.tree.map(lambda a, gi: a + gi.astype(jnp.float32), acc, g)
            return acc, (l, met)

        gsum, (losses, metrics) = jax.lax.scan(mb_step, zero, mb)
        grads = jax.tree.map(lambda g: g / microbatches, gsum)
        return (losses, metrics), grads

    return grad_phase


# --------------------------------------------------------------------------
# update phase group (pack / quantize / exchange / update)
# --------------------------------------------------------------------------


def _check_fused_flat(optimizer: DistributedOptimizer, comm: CommOps,
                      what: str) -> consensus.FlatComm:
    """``what`` needs the staged flat-buffer path; fail with the reason."""
    fl = comm.flat
    if fl is None or fl.exchange_stage is None or fl.strategy is None:
        raise ValueError(
            f"{what} needs a flat-buffer comm with split "
            "quantize/exchange stages (stacked_comm_ops / "
            "make_local_fused_comm with mixing='ppermute_fused')")
    has_fused = type(optimizer).apply_fused is not DistributedOptimizer.apply_fused
    if not (getattr(optimizer, "fused", False) and has_fused):
        raise ValueError(
            f"{what} needs a fused=True consensus optimizer; "
            f"{type(optimizer).__name__}(fused="
            f"{getattr(optimizer, 'fused', False)}) has no fused update to "
            "feed the staged exchange into")
    return fl


def check_overlap_support(optimizer: DistributedOptimizer,
                          comm: CommOps) -> consensus.FlatComm:
    """Overlap needs the staged flat-buffer path; fail with the reason."""
    return _check_fused_flat(optimizer, comm, "schedule='overlap'")


def check_program_support(optimizer: DistributedOptimizer,
                          comm: CommOps) -> Optional[consensus.FlatComm]:
    """A non-trivial MixingProgram needs the staged flat-buffer path.

    Time-varying / multi-round / error-feedback / momentum mixing all live
    on the flat-buffer strategy layer; a non-fused optimizer's reference
    path would silently mix a fixed dense ``Pi`` instead, so this fails
    loudly at config time.  ``momentum_mixing="mixed"`` additionally needs
    an optimizer that *has* a mixable momentum buffer (CDMSGD family /
    CDAdam's first moment).  Trivial (or absent) programs return
    ``comm.flat`` unchecked — every optimizer supports them.
    """
    fl = comm.flat
    if fl is None or fl.program is None or fl.program.is_trivial:
        return fl
    p = fl.program
    what = (f"mixing strategy {p.strategy!r} (rounds={p.rounds}, "
            f"error_feedback={p.error_feedback}, "
            f"momentum_mixing={p.momentum_mixing})")
    fl = _check_fused_flat(optimizer, comm, what)
    if p.momentum_mixing == "mixed" and not optimizer.has_mixable_momentum:
        raise ValueError(
            f"momentum_mixing='mixed' puts the momentum buffer on the wire, "
            f"but {type(optimizer).__name__} has no mixable momentum state "
            "(use CDMSGD, CDMSGDNesterov, or CDAdam)")
    return fl


def _mixed_momentum(fl: Optional[consensus.FlatComm]) -> bool:
    return (fl is not None and fl.program is not None
            and fl.program.momentum_mixing == "mixed")


def _pack_wire_bufs(fl: consensus.FlatComm, params, momentum=None):
    """Pack the wire payload bucket list: params, then the mixed momentum.

    ``momentum=None`` with a momentum-mixing program packs zeros via
    :func:`repro.core.consensus.widen_with_momentum` (the
    state-initializer convention, ``v_{-1} := v_0 = 0``); a momentum tree
    packs against the SAME spec, so the second half of the list mirrors
    the first bucket-for-bucket.
    """
    spec = fl.spec(params)
    bufs = fl.pack(params, spec)
    mom_bufs = None
    if _mixed_momentum(fl) and momentum is not None:
        mom_bufs = fl.pack(momentum, spec)
    return spec, consensus.widen_with_momentum(fl, bufs, mom_bufs)


def _momentum_payload(optimizer: DistributedOptimizer, state: OptState):
    """The momentum tree a mixed-momentum step puts on the wire.

    Fails loudly if the optimizer claims a mixable momentum but its
    ``momentum_tree`` returns nothing for this state shape — silently
    packing zeros here would degrade the wire to ``v' = -a g`` neighbor
    terms with no error.
    """
    mom = optimizer.momentum_tree(state.inner)
    if mom is None:
        raise ValueError(
            f"momentum_mixing='mixed': {type(optimizer).__name__}."
            "momentum_tree returned None for the current optimizer state — "
            "no momentum payload to put on the wire")
    return mom


def make_local_wire_init(fl: consensus.FlatComm) -> Callable:
    """Per-shard overlap wire initializer (run it inside ``shard_map``).

    Packs the *local* params and quantizes with seed ``-1`` — the same
    ``x_{-1} := x_0`` convention as :func:`repro.core.consensus.
    initial_wire_state`, but with the local flat layout, which differs from
    the global one whenever params also shard over non-agent mesh axes.
    With momentum mixing the wire also carries the momentum payload
    (``v_{-1} := v_0 = 0``).
    """

    def local_init(params):
        _, bufs = _pack_wire_bufs(fl, params)
        # the strategy wraps the seed -1 generation into a depth-S WireRing
        # on the fault path (plain quantize_stage otherwise, bit-for-bit)
        return fl.strategy.initial_wire(bufs)

    return local_init


def make_local_residual_init(fl: consensus.FlatComm) -> Callable:
    """Per-shard error-feedback residual initializer (inside ``shard_map``).

    Zeros, shaped like the *local* packed buckets (one per bucket per wire
    payload) — the analog of :func:`make_local_wire_init` for
    ``OptState.residual``.
    """

    def local_init(params):
        _, bufs = _pack_wire_bufs(fl, params)
        return fl.strategy.residual_init(bufs)

    return local_init


def make_local_qwarm_init(fl: consensus.FlatComm) -> Callable:
    """Per-shard rank-compressor warm-start initializer (inside
    ``shard_map``): the deterministic init basis per local bucket, the
    analog of :func:`make_local_residual_init` for ``OptState.qwarm``
    (``()`` for non-rank programs)."""

    def local_init(params):
        _, bufs = _pack_wire_bufs(fl, params)
        return fl.strategy.qwarm_init(bufs)

    return local_init


def _exchange_result(spec, nbrs, w, scales, selfs, mixed: bool):
    """Split the strategy's flat per-bucket operand lists into the
    :class:`ExchangeResult` payload groups (params / mixed momentum)."""
    if not mixed:
        return ExchangeResult(spec=spec, neighbors=nbrs, weights=w,
                              scales=scales, selfs=selfs)
    b = len(nbrs) // 2
    return ExchangeResult(spec=spec, neighbors=nbrs[:b], weights=w,
                          scales=scales[:b], selfs=selfs[:b],
                          mom_neighbors=nbrs[b:], mom_scales=scales[b:],
                          mom_selfs=selfs[b:])


def make_update_phase(optimizer: DistributedOptimizer, comm: CommOps,
                      schedule: str = "sync") -> Callable:
    """The update phase group: ``(params, grads, state) -> (params', state')``.

    ``sync``: the optimizer gathers synchronously on the current params —
    bit-for-bit today's behavior for the trivial static program; the
    gather internally runs whatever :class:`repro.core.consensus.
    MixingProgram` the comm carries (time-varying ``Pi_t`` selected by the
    step, ``k`` inner consensus rounds), so non-trivial strategies need no
    special casing here.  With ``error_feedback`` the sync path is staged
    explicitly instead, because the EF quantizer must thread
    ``OptState.residual`` through the round-1 compression.

    ``overlap``: exchange the carried one-step-stale wire state (round 1 —
    the only round off the critical path), run rounds ``2..k`` on the
    partially mixed buffers, update against the final round's operands,
    then quantize the *current* params as the next step's round-1 wire
    (EF-compressed when the program asks).  In the sharded mode the
    returned callable is the function the caller wraps in ``shard_map``;
    in the stacked mode it is called directly — the same phase code serves
    both.
    """
    if schedule not in SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r}; expected one of "
                         f"{SCHEDULES}")
    fl = comm.flat
    program = fl.program if fl is not None else None
    error_feedback = program is not None and program.error_feedback
    if program is not None and program.fault_tolerant and schedule != "overlap":
        raise ValueError(
            "staleness > 1 / fault injection needs schedule='overlap': the "
            "staleness ring generalizes the overlap wire double-buffer — a "
            "sync exchange has no carried wire state to be stale in")
    mixed = _mixed_momentum(fl)
    # a non-trivial program needs the fused staged path under EVERY
    # schedule — without this, a hand-assembled StepProgram with a
    # non-fused optimizer would silently mix the fixed dense Pi instead
    # of the configured strategy (no-op for trivial/absent programs)
    check_program_support(optimizer, comm)

    if schedule == "sync" and not error_feedback and not mixed:
        def update_sync(params, grads, state):
            return optimizer.update(params, grads, state, comm)
        return update_sync

    if schedule == "sync":
        # sync + error feedback and/or momentum mixing: the engine stages
        # the pipeline explicitly, because the EF quantizer must thread
        # ``OptState.residual`` through the round-1 compression and the
        # momentum payload must be packed from the optimizer state (the
        # check above already validated the fused flat path exists).
        strategy = fl.strategy

        def update_sync_staged(params, grads, state):
            spec, bufs = _pack_wire_bufs(
                fl, params,
                _momentum_payload(optimizer, state) if mixed else None)
            if error_feedback:
                wire, new_res, new_qwarm = strategy.compress_ef(
                    bufs, state.step, state.residual, state.qwarm)
            else:
                wire = strategy.quantize_stage(bufs, state.step)
            nbrs, w, scales, selfs = strategy.continue_from_wire(
                bufs, wire, state.step)
            ex = _exchange_result(spec, nbrs, w, scales, selfs, mixed)
            new_params, new_state = optimizer.update(params, grads, state,
                                                     comm, exchanged=ex)
            if error_feedback:
                new_state = new_state._replace(residual=new_res,
                                               qwarm=new_qwarm)
            return new_params, new_state

        return update_sync_staged

    fl = check_overlap_support(optimizer, comm)
    strategy = fl.strategy

    def update_overlap(params, grads, state):
        # pack (fresh selfs): params, plus the momentum payload when mixed
        spec, bufs = _pack_wire_bufs(
            fl, params,
            _momentum_payload(optimizer, state) if mixed else None)
        # round 1 exchanges the stale carried wire; rounds 2..k (if any)
        # re-quantize the partially mixed buffers on the critical path
        nbrs, w, scales, selfs = strategy.continue_from_wire(
            bufs, state.wire, state.step)
        ex = _exchange_result(spec, nbrs, w, scales, selfs, mixed)
        new_params, new_state = optimizer.update(params, grads, state, comm,
                                                 exchanged=ex)
        # quantize (x_t, v_t) as the wire step t+1 exchanges (one step
        # stale there)
        if error_feedback:
            new_wire, new_res, new_qwarm = strategy.compress_ef(
                bufs, state.step, state.residual, state.qwarm)
            return new_params, new_state._replace(wire=new_wire,
                                                  residual=new_res,
                                                  qwarm=new_qwarm)
        # advance_wire = quantize_stage on the fault-free path; with a
        # staleness ring it also pushes the fresh generation and advances
        # the age counters (no extra bytes — the old slots never move)
        new_wire = strategy.advance_wire(bufs, state.wire, state.step)
        return new_params, new_state._replace(wire=new_wire)

    return update_overlap


# --------------------------------------------------------------------------
# the assembled program
# --------------------------------------------------------------------------


@dataclasses.dataclass
class StepProgram:
    """One training step assembled from the named phases.

    Both execution modes build this with :func:`make_grad_phase` +
    :func:`make_update_phase`; the sharded front-end additionally wraps the
    update group in ``shard_map`` (``update_phase`` is whatever callable the
    front-end hands over).  ``extra_metrics(new_params)`` appends
    mode-specific diagnostics (the stacked trainer's consensus error).
    """

    optimizer: DistributedOptimizer
    comm: CommOps
    grad_phase: Callable          # (gp, batch) -> ((losses, metrics), grads)
    update_phase: Callable        # (params, grads, state) -> (params', state')
    schedule: str = "sync"
    extra_metrics: Optional[Callable[[PyTree], Dict[str, jnp.ndarray]]] = None
    # overlap wire initializer override: the sharded front-end supplies a
    # shard_map-local packer (the local flat layout differs from the global
    # one whenever params also shard over non-agent mesh axes); None uses
    # the global agent-stacked path (the stacked trainer).
    init_wire: Optional[Callable[[PyTree], Any]] = None
    # same override for the error-feedback residual buffers
    init_residual: Optional[Callable[[PyTree], Any]] = None
    # same override for the rank compressor's warm-start basis
    init_qwarm: Optional[Callable[[PyTree], Any]] = None

    def init_state(self, params: PyTree) -> OptState:
        state = self.optimizer.init(params)
        if self.schedule == "overlap":
            fl = check_overlap_support(self.optimizer, self.comm)
            if self.init_wire is not None:
                state = state._replace(wire=self.init_wire(params))
            else:
                state = state._replace(
                    wire=consensus.initial_wire_state(fl, params))
        fl = self.comm.flat
        if fl is not None and fl.program is not None \
                and fl.program.error_feedback:
            check_program_support(self.optimizer, self.comm)
            if self.init_residual is not None:
                state = state._replace(residual=self.init_residual(params))
            else:
                state = state._replace(
                    residual=consensus.initial_residual_state(fl, params))
        if fl is not None and fl.program is not None \
                and fl.program.compressed:
            # rank warm-start basis, under BOTH schedules (sync compress_ef
            # consumes it too); independent of the wire init by design
            if self.init_qwarm is not None:
                state = state._replace(qwarm=self.init_qwarm(params))
            else:
                state = state._replace(
                    qwarm=consensus.initial_qwarm_state(fl, params))
        return state

    def step_fn(self, params: PyTree, opt_state: OptState, batch):
        gp = self.optimizer.grad_params(params, opt_state)
        (losses, metrics), grads = self.grad_phase(gp, batch)
        new_params, new_state = self.update_phase(params, grads, opt_state)
        out = {"loss": jnp.mean(losses)}
        if self.extra_metrics is not None:
            out.update(self.extra_metrics(new_params))
        for k, v in metrics.items():
            out[k] = jnp.mean(v)
        return new_params, new_state, out


def wire_bytes_per_neighbor(wire) -> int:
    """Bytes ONE neighbor transfer of a carried wire state moves, per agent,
    counted from the actual buffers — the overlap schedule must put exactly
    the sync schedule's bytes on the wire (``FlatSpec.exchange_bytes``),
    just one step later.  Row scales only cross the wire for quantized
    payloads; the unit scales of f32/bf16 wires are synthesized locally
    after the exchange (shift-invariant), so they cost nothing here.

    A :class:`repro.core.consensus.WireRing` counts ONE ring generation —
    the sender-selected slot is the only thing exchanged each step, so the
    bytes are independent of the ring depth ``S``; the stale slots and the
    age counters are local state and move nothing (asserted by
    ``benchmarks/kernel_microbench.py consensus/stale_ring``).

    Compressed entries (:class:`repro.core.consensus.TopKWire` /
    :class:`repro.core.consensus.RankWire`) count EVERY field — the
    neighbors can reconstruct nothing locally, so values, indices, scales
    and both rank factors all cross the wire.  The accounting-side figure
    is :func:`repro.core.consensus.program_bytes_per_neighbor`; the
    microbench asserts the two agree on the actual carried buffers."""

    def _entry_bytes(entry, drop_axes: int) -> int:
        if isinstance(entry, (consensus.TopKWire, consensus.RankWire)):
            fields = list(entry)
        else:
            payload, scales = entry
            quantized = jnp.dtype(payload.dtype).itemsize == 1
            fields = [payload, scales] if quantized else [payload]
        total = 0
        for x in fields:
            per_agent = 1
            for d in x.shape[drop_axes:]:
                per_agent *= d
            total += per_agent * jnp.dtype(x.dtype).itemsize
        return total

    if isinstance(wire, consensus.WireRing):
        # drop the agent AND ring axes
        return sum(_entry_bytes(e, 2) for e in wire.slots)
    return sum(_entry_bytes(e, 1) for e in wire)


# --------------------------------------------------------------------------
# critical-path proof: which step inputs reach the collective exchange?
# --------------------------------------------------------------------------


def _sub_jaxprs(params: dict):
    for v in params.values():
        vals = v if isinstance(v, (tuple, list)) else (v,)
        for x in vals:
            if isinstance(x, (jax.core.Jaxpr, jax.core.ClosedJaxpr)):
                yield x


def _taint_walk(jaxpr, in_taints, hits, prims, path=()):
    """Propagate per-invar label sets through ``jaxpr``; collect the merged
    input labels of every eqn whose primitive name contains one of
    ``prims``.  Conservative: opaque/unmatched sub-jaxprs taint all
    outputs with the union of inputs, and loop-carried sub-jaxprs
    (scan/while) iterate to a fixpoint.  Returns per-outvar label sets.

    ``path`` names the enclosing call chain as a tuple of
    ``(primitive_name, eqn_index, sub_jaxpr_index)`` frames.  Hits are
    keyed ``((path, id(eqn)), primitive_name, labels)``: jax shares
    sub-jaxpr objects between call sites (two ``pjit`` eqns of the same
    jitted fn carry the *same* inner eqn objects), so a bare ``id(eqn)``
    would merge structurally distinct collectives reached through
    different call sites — the path disambiguates them, while fixpoint
    re-walks of one site (same path) still dedupe.
    """
    env = {}

    def read(v):
        if isinstance(v, jax.core.Literal):
            return frozenset()
        return env.get(v, frozenset())

    for v, t in zip(jaxpr.invars, in_taints):
        env[v] = frozenset(t)
    for ei, eqn in enumerate(jaxpr.eqns):
        ins = [read(v) for v in eqn.invars]
        merged = frozenset().union(*ins) if ins else frozenset()
        if any(p in eqn.primitive.name for p in prims):
            hits.append(((path, id(eqn)), eqn.primitive.name, merged))
        out_ts = None
        subs = list(_sub_jaxprs(eqn.params))
        if subs:
            acc = None
            for si, sub in enumerate(subs):
                j = sub.jaxpr if isinstance(sub, jax.core.ClosedJaxpr) else sub
                n = len(j.invars)
                if n == len(ins):
                    sub_in = list(ins)
                elif n < len(ins):
                    sub_in = list(ins[len(ins) - n:])
                else:
                    sub_in = [merged] * n
                looping = eqn.primitive.name in ("scan", "while")
                sub_path = path + ((eqn.primitive.name, ei, si),)
                for _ in range(5):
                    sub_out = _taint_walk(j, sub_in, hits, prims, sub_path)
                    if not looping:
                        break
                    # feed carried-output taints back into the carried inputs
                    grown = list(sub_in)
                    nc = eqn.params.get("num_consts")
                    nk = eqn.params.get("num_carry")
                    if nc is not None and nk is not None:   # scan layout
                        for i in range(min(nk, len(sub_out))):
                            if nc + i < len(grown):
                                grown[nc + i] = grown[nc + i] | sub_out[i]
                    else:                                   # while: carry last
                        k = min(len(sub_out), len(grown))
                        for i in range(k):
                            grown[len(grown) - k + i] |= sub_out[i]
                    if grown == sub_in:
                        break
                    sub_in = grown
                if len(sub_out) == len(eqn.outvars):
                    acc = (sub_out if acc is None
                           else [a | b for a, b in zip(acc, sub_out)])
                else:
                    acc = [merged] * len(eqn.outvars)
            out_ts = acc
        if out_ts is None:
            out_ts = [merged] * len(eqn.outvars)
        for v, t in zip(eqn.outvars, out_ts):
            env[v] = t
    return [read(v) for v in jaxpr.outvars]


#: step-input label names, in the order `step_input_labels` emits them
STEP_INPUT_LABELS = ("params", "state", "wire", "residual", "qwarm", "batch")

#: primitive-name substrings of every collective the census audits
COLLECTIVE_PRIMS = ("ppermute", "psum", "all_gather", "all_to_all",
                    "all_reduce", "reduce_scatter")


def step_input_labels(params, opt_state, batch):
    """Per-flat-input label sets for ``step_fn(params, opt_state, batch)``:
    ``params`` / ``state`` / ``wire`` / ``residual`` / ``qwarm`` / ``batch``
    (the taxonomy both the dependency report and the static checker's
    collective census taint through the traced step)."""
    label_tree = (
        jax.tree.map(lambda _: "params", params),
        OptState(step="state",
                 inner=jax.tree.map(lambda _: "state", opt_state.inner),
                 wire=jax.tree.map(lambda _: "wire", opt_state.wire),
                 residual=jax.tree.map(lambda _: "residual",
                                       opt_state.residual),
                 qwarm=jax.tree.map(lambda _: "qwarm", opt_state.qwarm)),
        jax.tree.map(lambda _: "batch", batch),
    )
    return [frozenset([l]) for l in jax.tree.leaves(label_tree)]


def collective_taint_hits(step_fn, params, opt_state, batch, *,
                          prims=("ppermute",), closed=None):
    """Trace ``step_fn`` and return one record per (collective eqn,
    enclosing call path): ``{"prim", "path", "labels"}``.

    The shared engine under both ``exchange_dependency_report`` and the
    static checker's collective census.  Two structurally distinct
    collectives that happen to live in a shared (cloned) sub-jaxpr object
    are counted separately — hits key on the call path, not bare eqn
    identity — while fixpoint re-walks of loop bodies merge into one
    record per site with the union of the taints seen.

    Works on concrete arrays or ShapeDtypeStructs.  ``closed`` lets a
    caller that already traced the step (the static checker shares one
    jaxpr across passes) skip the re-trace.
    """
    labels = step_input_labels(params, opt_state, batch)
    if closed is None:
        closed = jax.make_jaxpr(step_fn)(params, opt_state, batch)
    assert len(closed.jaxpr.invars) == len(labels), \
        (len(closed.jaxpr.invars), len(labels))
    hits: list = []
    _taint_walk(closed.jaxpr, labels, hits, prims=prims)
    merged: dict = {}
    names: dict = {}
    order: list = []
    for key, name, taint in hits:
        if key not in merged:
            order.append(key)
        merged[key] = merged.get(key, frozenset()) | taint
        names[key] = name
    return [{"prim": names[k], "path": k[0], "labels": merged[k]}
            for k in order]


def exchange_dependency_report(step_fn, params, opt_state, batch) -> dict:
    """Which step inputs can reach the collective exchange, from the jaxpr.

    Labels every flat input of ``step_fn(params, opt_state, batch)`` as
    ``params`` / ``state`` / ``wire`` (the overlap double-buffer inside the
    optimizer state) / ``residual`` (error-feedback buffers) / ``batch``
    and taints them through the traced step.  The returned record is the
    dryrun's critical-path proof:

    * ``sync``    — the ``ppermute`` payload is quantized from the current
      params, so ``depends_on_params`` is True: the exchange can only start
      once the previous step's update has produced those params.
    * ``overlap`` — the round-1 payload is the carried wire state: those
      ``ppermute``\\ s taint only carried optimizer state
      (``n_ppermutes_carried_only``), i.e. they need neither the current
      params (previous update) nor the current batch (backward) —
      ``round1_off_critical_path``.  With a multi-round program the inner
      rounds ``2..k`` re-quantize partially mixed *current* buffers, so
      those collectives stay on the critical path
      (``n_ppermutes_fresh``) and the all-hits summary
      ``off_grad_update_critical_path`` is True only for ``k = 1``.

    Collectives are counted per (jaxpr equation, enclosing call path): a
    ``ppermute`` inside the multi-round ``lax.scan`` counts once regardless
    of trip count, while the same eqn object reached through two distinct
    call sites (jax shares cloned sub-jaxprs) counts twice.

    Works on concrete arrays or ShapeDtypeStructs.  Programs whose mixing
    has no ``ppermute`` (stacked dense ``Pi``) report ``n_ppermutes == 0``.
    """
    hits = collective_taint_hits(step_fn, params, opt_state, batch,
                                 prims=("ppermute",))
    taints = [h["labels"] for h in hits]
    union = frozenset().union(*taints) if taints else frozenset()
    carried = [t for t in taints if not (t & frozenset(("params", "batch")))]
    return {
        "n_ppermutes": len(taints),
        "n_ppermutes_carried_only": len(carried),
        "n_ppermutes_fresh": len(taints) - len(carried),
        "depends_on_params": "params" in union,
        "depends_on_batch": "batch" in union,
        "depends_on_wire_state": "wire" in union,
        "off_grad_update_critical_path": bool(taints)
            and "params" not in union and "batch" not in union,
        "round1_off_critical_path": len(carried) > 0,
    }
