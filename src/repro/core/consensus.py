"""Consensus mixing operators: ``w = Pi x`` over the agent population.

Three execution paths, one semantics (paper eq. 5 / eq. 6):

1. **Stacked** (`mix_stacked`, `mix_pytree_stacked`) — every leaf carries a
   leading agent axis ``(N, ...)``; mixing is a dense matmul with ``Pi``.
   Used for CPU-scale simulation (tests, paper-figure benchmarks) and as
   the oracle the sharded paths are verified against.

2. **Sharded circulant** (`make_sharded_mix_fn`) — inside ``shard_map`` over
   a named agent mesh axis, a circulant ``Pi`` decomposes into static shift
   offsets, each lowering to one ``lax.ppermute`` (TPU: `collective-permute`
   over ICI neighbours).  This is the fixed-topology, neighbor-only
   communication pattern that is the paper's whole point: cost is
   ``degree * |params|`` point-to-point transfers instead of a global
   all-reduce.

3. **Sharded general** — non-circulant ``Pi`` falls back to
   ``all_gather`` + per-agent row contraction (cost ``N * |params|``; only
   sensible for small agent counts or dense graphs, where it matches the
   all-reduce cost anyway).

`FactoredMix` composes per-axis topologies as a Kronecker product
``Pi = Pi_pod (x) Pi_data`` — mixing sequentially over each mesh axis.  This
is our TPU-native extension for multi-pod meshes: a ring over the ``pod``
axis (scarce DCN links) crossed with a denser graph over the in-pod ``data``
axis (cheap ICI links).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import flatbuf
from repro.core.topology import Topology
from repro.utils.tree import tree_weighted_sum

PyTree = Any
MixFn = Callable[[PyTree], PyTree]


# --------------------------------------------------------------------------
# Flat-buffer fused-consensus support (see repro.core.flatbuf)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FlatComm:
    """Whole-model fused-update support carried inside :class:`CommOps`.

    ``gather(bufs, seed)`` maps the packed self-buffers to kernel-ready
    neighbor operands ``(neighbor_stacks, weights, scales, selfs)``: in the
    **stacked** mode it returns the full agent stack per bucket with the
    dense ``Pi`` as ``(A, A)`` weights (the fused kernels vmap over agent
    rows); in the **sharded** mode it issues one ``lax.ppermute`` per
    circulant shift offset per bucket and returns the ``(S, rows, 128)``
    stencil stack with ``(S,)`` weights.

    ``exchange`` selects the wire precision of the neighbor stacks:
    ``"f32"`` (native bucket dtype), ``"bf16"`` (cast), or ``"int8"`` /
    ``"fp8"`` (stochastic-rounding quantization with one f32 scale per
    128-lane row).  For quantized exchanges the per-bucket ``scales`` entry
    is the ``(..., rows, 1)`` stack the fused kernels dequantize with
    in-register, and ``selfs`` carries the native-precision self buffers —
    the local parameters never cross the wire, so they are mixed exactly at
    ``weights[..., 0]`` while only true neighbor payloads pay quantization
    noise.  Both are ``None`` for unquantized exchanges.  ``seed`` (an
    int32 scalar, typically the optimizer step) drives the stochastic
    rounding; it is decorrelated per bucket and per agent, identically in
    both execution modes, so stacked and sharded quantized trajectories
    match exactly whenever their bucket layouts coincide (params sharded
    over non-agent mesh axes pack differently per device, which draws the
    same seeds at different row positions).

    Phase stages (the StepProgram engine's pipeline, see
    :mod:`repro.core.engine`): ``gather`` is the one-shot sync form;
    ``quantize_stage(bufs, seed)`` and ``exchange_stage(wire)`` expose the
    same computation as two separately schedulable halves.
    ``quantize_stage`` maps packed buckets to the **wire state** — one
    ``(payload, row_scales)`` pair per bucket, always carrying the leading
    agent axes so it can live inside the optimizer state under either
    execution mode (f32/bf16 wires carry unit scales).  ``exchange_stage``
    turns a wire state into the self-separated kernel operands
    ``(neighbor_stacks, weights_q, scale_stacks)`` with the self weight
    first — in the sharded mode this is where the ``ppermute``\\ s happen,
    and because the wire state may come from the *previous* optimizer step
    the exchange has no data dependency on the current backward (the
    ``schedule="overlap"`` one-step-stale pipeline).
    """

    lead: int                     # leading replica axes excluded from packing
    batched: bool                 # True: stacked simulation (dense Pi vmap)
    gather: Callable              # (bufs, seed) -> (nbrs, weights, scales, selfs)
    interpret: bool = True        # interpret=True for CPU; False on TPU
    exchange: str = "f32"         # wire precision: f32 | bf16 | int8 | fp8
    n_agents: int = 1
    # split phase stages (see class docstring); None on comms predating them
    quantize_stage: Optional[Callable] = None   # (bufs, seed) -> wire
    exchange_stage: Optional[Callable] = None   # (wire) -> (nbrs, weights_q, scales)

    def spec(self, tree: PyTree) -> flatbuf.FlatSpec:
        return flatbuf.make_flat_spec(tree, lead=self.lead)

    def pack(self, tree: PyTree, spec: flatbuf.FlatSpec):
        bufs = flatbuf.pack(tree, spec)
        if not self.batched and self.lead:
            # sharded: the local agent axis is fully sharded away (size 1)
            for b in bufs:
                assert all(d == 1 for d in b.shape[:self.lead]), b.shape
            bufs = [b.reshape(b.shape[self.lead:]) for b in bufs]
        return bufs

    def unpack(self, bufs, spec: flatbuf.FlatSpec) -> PyTree:
        if not self.batched and self.lead:
            bufs = [b.reshape((1,) * self.lead + b.shape) for b in bufs]
        return flatbuf.unpack(bufs, spec)


# distinct odd strides decorrelate the stochastic-rounding streams across
# steps, buckets, and agents while keeping stacked/sharded seeds identical
# (without the step stride, step t+1 / bucket b would collide with step
# t+1-7919k / bucket b+k; int32 wraparound at large steps is fine — the
# seed only needs to be a well-spread hash input).
_SEED_STEP_STRIDE = 1000003
_SEED_BUCKET_STRIDE = 7919
_SEED_AGENT_STRIDE = 104729


def _check_exchange(exchange: str) -> str:
    """Fail at comm construction, not deep inside the first traced update."""
    if exchange not in flatbuf.EXCHANGE_DTYPES:
        raise ValueError(f"unknown exchange precision {exchange!r}; "
                         f"expected one of {flatbuf.EXCHANGE_DTYPES}")
    return exchange


def _wire_payload(buf, seed, exchange: str, interpret: bool):
    """Cast/quantize one packed bucket for the wire -> (payload, scales).

    ``bf16`` casts the whole stencil *including* the self tile: without
    scales the kernels need one homogeneous neighbor operand, and the
    ~2^-8 relative rounding this adds to the self term is the mode's
    stated noise level anyway.  int8/fp8 keep self native (see ``selfs``).
    """
    if exchange == "f32":
        return buf, None
    if exchange == "bf16":
        return buf.astype(jnp.bfloat16), None
    from repro.kernels.consensus_update.consensus_update import sr_quantize_2d
    return sr_quantize_2d(buf, seed, exchange=exchange, interpret=interpret)


def _quantize_wire_stacked(bufs, seed, n: int, exchange: str, interpret: bool):
    """Quantize agent-stacked ``(A, rows, 128)`` buckets for the wire.

    Returns the wire state: one ``(payload, (A, rows, 1) f32 scales)`` pair
    per bucket.  Per-agent seeds match the sharded stage's
    ``axis_index``-derived seeds, so both execution modes produce the same
    wire bits from the same parameters.  f32/bf16 wires cast and carry
    unit scales (the fused kernels' in-register dequant multiply is then
    the identity), so every exchange precision shares one wire layout.
    """
    if exchange in ("f32", "bf16"):
        return tuple(
            (_wire_payload(b, None, exchange, interpret)[0],
             jnp.ones(b.shape[:-1] + (1,), jnp.float32)) for b in bufs)
    base = _SEED_STEP_STRIDE * jnp.asarray(seed, jnp.int32)
    agent_seeds = _SEED_AGENT_STRIDE * jnp.arange(n, dtype=jnp.int32)
    out = []
    for bi, b in enumerate(bufs):
        q, sc = jax.vmap(
            lambda x, s: _wire_payload(x, s, exchange, interpret)
        )(b, base + _SEED_BUCKET_STRIDE * bi + agent_seeds)
        out.append((q, sc))
    return tuple(out)


def stacked_flat_comm(topology: Topology, *, interpret: bool = True,
                      exchange: str = "f32") -> FlatComm:
    """FlatComm for agent-stacked pytrees (dense ``Pi``, any topology).

    Quantized exchanges quantize the agent stack once (per-agent seeds
    matching the sharded path's ``axis_index``-derived seeds) and return
    the native-precision stack as ``selfs``: agent ``j`` mixes its own
    exact parameters at ``weights[j, 0] = Pi[j, j]`` and the dequantized
    wire payloads of everyone else (``weights[j, 1:] = Pi[j, :]`` with the
    diagonal zeroed) — exactly what the sharded exchange delivers, where
    the self buffer never crosses the wire.
    """
    _check_exchange(exchange)
    pi = jnp.asarray(topology.pi, dtype=jnp.float32)
    n = topology.n_agents
    # quantized-form weights: [diag | off-diagonal rows], (A, A+1)
    pi_q = jnp.concatenate(
        [jnp.diag(pi)[:, None], pi * (1.0 - jnp.eye(n, dtype=pi.dtype))], axis=1)

    def quantize_stage(bufs, seed):
        return _quantize_wire_stacked(bufs, seed, n, exchange, interpret)

    def exchange_stage(wire):
        # stacked simulation: every agent already sees the full stack — the
        # "exchange" is handing the wire payloads to the kernels with the
        # self-separated [diag(Pi) | zero-diag Pi] weights.
        return ([p for p, _ in wire], pi_q, [sc for _, sc in wire])

    def gather(bufs, seed):
        if exchange in ("f32", "bf16"):
            return ([_wire_payload(b, None, exchange, interpret)[0] for b in bufs],
                    pi, [None] * len(bufs), [None] * len(bufs))
        nbrs, w, scales = exchange_stage(quantize_stage(bufs, seed))
        return nbrs, w, scales, list(bufs)

    return FlatComm(lead=1, batched=True, gather=gather, interpret=interpret,
                    exchange=exchange, n_agents=n,
                    quantize_stage=quantize_stage, exchange_stage=exchange_stage)


def sharded_flat_comm(factors: Sequence[Tuple[str, Topology]], *,
                      lead: int = 1, interpret: bool = True,
                      exchange: str = "f32") -> FlatComm:
    """FlatComm for use inside ``shard_map``; circulant topologies only.

    ``factors`` is ``[(axis_name, Topology), ...]`` — one entry for the
    plain single-axis agent mesh, several for a Kronecker-factored one.
    Each bucket costs one ``lax.ppermute`` per non-zero shift combination;
    weights are the (outer-)product of the per-factor circulant weights.

    With a quantized ``exchange`` each agent quantizes its bucket ONCE and
    every non-identity shift permutes the int8/fp8 payload plus its
    ``(rows, 1)`` row scales — ~3.9x fewer bytes per shift than the f32
    wire; the self term (the identity shift) stays in native precision
    since it moves no data.
    """
    import itertools

    _check_exchange(exchange)

    per_axis = []
    for axis_name, topo in factors:
        if topo.n_agents == 1:
            continue
        shifts = topo.shift_weights()
        if shifts is None:
            raise ValueError(
                f"topology {topo.name!r} on axis {axis_name!r} is not "
                "circulant; use mixing='ppermute' or 'dense' instead")
        per_axis.append((axis_name, topo.n_agents, sorted(shifts.items())))

    combos = list(itertools.product(*[s for _, _, s in per_axis])) or [()]

    def _combo_weight(combo):
        return float(np.prod([w for _, w in combo]) if combo else 1.0)

    def _is_identity(combo):
        return all(s % n == 0 for (_, n, _), (s, _w) in zip(per_axis, combo))

    weights = jnp.asarray([_combo_weight(c) for c in combos], jnp.float32)
    # quantized form: self (identity shift, native precision) first, then
    # one entry per wire-crossing shift combination.
    wire_combos = [c for c in combos if not _is_identity(c)]
    self_weight = sum(_combo_weight(c) for c in combos if _is_identity(c))
    weights_q = jnp.asarray([self_weight] + [_combo_weight(c) for c in wire_combos],
                            jnp.float32)

    def _agent_index():
        """Linearized agent index — matches the stacked topology order."""
        idx = jnp.int32(0)
        for axis_name, n, _ in per_axis:
            idx = idx * n + lax.axis_index(axis_name).astype(jnp.int32)
        return idx

    def _shift_all(x, combo):
        for (axis_name, n, _), (s, _w) in zip(per_axis, combo):
            if s % n:
                # agent j receives from agent (j + s) mod n
                perm = [((j + s) % n, j) for j in range(n)]
                x = lax.ppermute(x, axis_name, perm=perm)
        return x

    quantized = exchange in ("int8", "fp8") and wire_combos
    n_total = 1
    for _, n, _ in per_axis:
        n_total *= n

    def quantize_stage(bufs, seed):
        """Local squeezed buckets -> wire state (lead axes restored).

        Runs inside ``shard_map``: the returned pairs carry the size-1
        local agent axes so the wire state round-trips through sharded
        optimizer-state PartitionSpecs unchanged.
        """
        base = _SEED_STEP_STRIDE * jnp.asarray(seed, jnp.int32)
        if exchange in ("int8", "fp8"):
            base = base + _SEED_AGENT_STRIDE * _agent_index()
        out = []
        for bi, b in enumerate(bufs):
            if exchange in ("int8", "fp8"):
                p, sc = _wire_payload(b, base + _SEED_BUCKET_STRIDE * bi,
                                      exchange, interpret)
            else:
                p, _ = _wire_payload(b, None, exchange, interpret)
                sc = jnp.ones(b.shape[:-1] + (1,), jnp.float32)
            out.append((p.reshape((1,) * lead + p.shape),
                        sc.reshape((1,) * lead + sc.shape)))
        return tuple(out)

    def exchange_stage(wire):
        """Wire state -> (neighbor stacks, weights_q, scale stacks).

        One ``lax.ppermute`` per non-identity shift combination for the
        payload, plus one for the row scales when the wire is quantized
        (f32/bf16 wires carry unit scales, which are shift-invariant — the
        kernels' dequant operand is synthesized locally, no collective);
        the self term never moves.  The wire may be one optimizer step
        stale (``schedule="overlap"``) — nothing here reads the current
        params or gradients.
        """
        if not wire_combos:
            raise ValueError("exchange_stage needs at least one wire-crossing "
                             "shift (topology has no neighbors)")
        nbrs, scs = [], []
        for p, sc in wire:
            p = p.reshape(p.shape[lead:])
            sc = sc.reshape(sc.shape[lead:])
            nbrs.append(jnp.stack([_shift_all(p, c) for c in wire_combos]))
            if exchange in ("int8", "fp8"):
                scs.append(jnp.stack([_shift_all(sc, c) for c in wire_combos]))
            else:
                scs.append(jnp.broadcast_to(sc, (len(wire_combos),) + sc.shape))
        return nbrs, weights_q, scs

    def gather(bufs, seed):
        if not quantized:
            stacked = []
            for b in bufs:
                payload, _ = _wire_payload(b, None, exchange if exchange == "bf16"
                                           else "f32", interpret)
                stacked.append(jnp.stack([_shift_all(payload, c) for c in combos]))
            return stacked, weights, [None] * len(bufs), [None] * len(bufs)
        nbrs, w, scs = exchange_stage(quantize_stage(bufs, seed))
        return nbrs, w, scs, list(bufs)

    return FlatComm(lead=lead, batched=False, gather=gather,
                    interpret=interpret, exchange=exchange, n_agents=n_total,
                    quantize_stage=quantize_stage, exchange_stage=exchange_stage)


def initial_wire_state(fl: FlatComm, params: PyTree) -> tuple:
    """Wire state priming the ``schedule="overlap"`` double-buffer.

    The overlap schedule exchanges the *previous* step's quantized buckets;
    before step 0 there is no previous step, so the convention is
    ``x_{-1} := x_0``: quantize the initial params with seed ``-1`` (the
    per-step stages use the optimizer step ``>= 0``, so the stream never
    collides).  Computed on the *global* agent-stacked view — usable
    outside ``shard_map`` — with per-agent seeds identical to what the
    sharded ``axis_index``-seeded quantize stage produces, so both
    execution modes start from the same wire bits.

    For a *sharded* comm this global path assumes the packed layout equals
    the per-device layout — true only when params shard over no non-agent
    mesh axis; the sharded trainer instead initializes per shard with
    :func:`repro.core.engine.make_local_wire_init` inside ``shard_map``.
    """
    if fl.quantize_stage is None:
        raise ValueError("FlatComm has no quantize stage; overlap needs the "
                         "staged flat-buffer comm")
    if fl.lead != 1:
        raise ValueError("overlap wire state assumes one leading agent axis")
    spec = flatbuf.make_flat_spec(params, lead=fl.lead)
    bufs = flatbuf.pack(params, spec)           # global view, lead kept
    seed = jnp.int32(-1)
    if fl.batched:
        return fl.quantize_stage(bufs, seed)
    return _quantize_wire_stacked(bufs, seed, fl.n_agents, fl.exchange,
                                  fl.interpret)


# --------------------------------------------------------------------------
# Stacked (dense, simulation) path
# --------------------------------------------------------------------------


def mix_stacked(pi: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """``(Pi x)_j = sum_l pi_{jl} x_l`` for ``x`` of shape (N, ...)."""
    pi = jnp.asarray(pi, dtype=jnp.float32)
    flat = x.reshape(x.shape[0], -1)
    mixed = jnp.einsum("jl,ld->jd", pi, flat.astype(jnp.float32))
    return mixed.astype(x.dtype).reshape(x.shape)


def mix_pytree_stacked(pi: jnp.ndarray, tree: PyTree) -> PyTree:
    """Apply `mix_stacked` to every leaf of an agent-stacked pytree."""
    return jax.tree.map(lambda x: mix_stacked(pi, x), tree)


def mix_pytree_list(pi: np.ndarray, trees: Sequence[PyTree]) -> list:
    """Host-level mixing of a list of per-agent pytrees (tests/benchmarks)."""
    n = len(trees)
    out = []
    for j in range(n):
        out.append(tree_weighted_sum([float(pi[j, l]) for l in range(n)], list(trees)))
    return out


# --------------------------------------------------------------------------
# Sharded (shard_map) path
# --------------------------------------------------------------------------


def _circulant_mix_leaf(x, shifts, axis_name: str, n: int):
    """sum_s w_s * ppermute(x, shift s) — one collective-permute per offset."""
    acc = None
    for s, w in sorted(shifts.items()):
        w = jnp.asarray(w, dtype=x.dtype)
        if s % n == 0:
            term = w * x
        else:
            # agent j receives from agent (j + s) mod n
            perm = [((j + s) % n, j) for j in range(n)]
            term = w * lax.ppermute(x, axis_name, perm=perm)
        acc = term if acc is None else acc + term
    return acc


def _general_mix_leaf(x, pi: jnp.ndarray, axis_name: str):
    """all_gather + row contraction for arbitrary doubly-stochastic Pi."""
    j = lax.axis_index(axis_name)
    gathered = lax.all_gather(x, axis_name)  # (N, ...) local copy
    row = pi[j].astype(jnp.float32)
    flat = gathered.reshape(gathered.shape[0], -1).astype(jnp.float32)
    return (row @ flat).astype(x.dtype).reshape(gathered.shape[1:])


def make_sharded_mix_fn(topology: Topology, axis_name: str) -> MixFn:
    """Mixing function usable *inside* ``shard_map`` over ``axis_name``.

    The returned fn maps a local (per-agent) pytree to its ``Pi``-mixed
    value.  Circulant topologies use ppermute; general ones all_gather.
    """
    n = topology.n_agents
    if n == 1:
        return lambda tree: tree
    shifts = topology.shift_weights()
    if shifts is not None:
        def mix(tree: PyTree) -> PyTree:
            return jax.tree.map(lambda x: _circulant_mix_leaf(x, shifts, axis_name, n), tree)
        return mix
    pi = jnp.asarray(topology.pi, dtype=jnp.float32)

    def mix(tree: PyTree) -> PyTree:
        return jax.tree.map(lambda x: _general_mix_leaf(x, pi, axis_name), tree)

    return mix


def make_sharded_mean_fn(axis_names) -> MixFn:
    """Exact global mean over the agent axes (FedAvg server / centralized)."""

    def mean(tree: PyTree) -> PyTree:
        return jax.tree.map(lambda x: lax.pmean(x, axis_names), tree)

    return mean


@dataclasses.dataclass(frozen=True)
class FactoredMix:
    """Kronecker-factored topology over multiple mesh axes.

    ``factors`` is a sequence of (axis_name, Topology).  The effective
    agent-interaction matrix is ``Pi = Pi_1 (x) Pi_2 (x) ...`` (Kronecker
    product), which is itself doubly stochastic and symmetric PSD when the
    factors are; ``lambda_2(Pi) = max over factors of lambda_2`` (all other
    factor eigenvalues at 1).  Mixing applies each factor sequentially.
    """

    factors: Tuple[Tuple[str, Topology], ...]

    @property
    def n_agents(self) -> int:
        n = 1
        for _, t in self.factors:
            n *= t.n_agents
        return n

    def dense_pi(self) -> np.ndarray:
        pi = np.array([[1.0]])
        for _, t in self.factors:
            pi = np.kron(pi, t.pi)
        return pi

    @property
    def lambda2(self) -> float:
        # kron eigenvalues are products; second-largest = max factor lambda_2
        lams = [t.lambda2 for _, t in self.factors if t.n_agents > 1]
        return max(lams) if lams else 0.0

    @property
    def lambdan(self) -> float:
        prod = 1.0
        for _, t in self.factors:
            prod *= t.lambdan
        return prod

    def make_mix_fn(self) -> MixFn:
        fns = [make_sharded_mix_fn(t, ax) for ax, t in self.factors if t.n_agents > 1]

        def mix(tree: PyTree) -> PyTree:
            for f in fns:
                tree = f(tree)
            return tree

        return mix


# --------------------------------------------------------------------------
# Wire-cost accounting
# --------------------------------------------------------------------------


def exchange_bytes_per_step(spec: "flatbuf.FlatSpec", topology: Topology,
                            exchange: str = "f32") -> dict:
    """Per-step bytes-on-wire estimate for the fused consensus exchange.

    The paper's fixed-topology cost model (eq. 5/6): each agent sends/
    receives ``degree`` whole-model transfers per step.  ``per_neighbor``
    comes from :meth:`repro.core.flatbuf.FlatSpec.exchange_bytes` for the
    chosen wire precision (int8/fp8 add one f32 scale per 128-lane row).
    """
    per_neighbor = spec.exchange_bytes(exchange)
    degree = topology.degree()
    return {
        "exchange": exchange,
        "degree": degree,
        "per_neighbor_bytes": per_neighbor,
        "per_step_bytes": per_neighbor * degree,
        "native_per_step_bytes": spec.exchange_bytes("f32") * degree,
    }


def describe_exchange_cost(params: PyTree, topology: Topology,
                           exchange: str = "f32", *, lead: int = 1) -> str:
    """One-line human-readable :func:`exchange_bytes_per_step` report
    (shared by the train/dryrun CLIs and the examples)."""
    wire = exchange_bytes_per_step(
        flatbuf.make_flat_spec(params, lead=lead), topology, exchange)
    return (f"exchange={exchange}: {wire['per_step_bytes']:,} bytes/agent/step "
            f"on the wire ({wire['degree']} neighbors x "
            f"{wire['per_neighbor_bytes']:,} B; native "
            f"{wire['native_per_step_bytes']:,} B)")


# --------------------------------------------------------------------------
# Consensus diagnostics
# --------------------------------------------------------------------------


def consensus_error_stacked(x: jnp.ndarray) -> jnp.ndarray:
    """mean_j ||x_j - mean(x)|| for an agent-stacked leaf (Prop. 1 LHS)."""
    mean = jnp.mean(x, axis=0, keepdims=True)
    diff = (x - mean).reshape(x.shape[0], -1)
    return jnp.mean(jnp.linalg.norm(diff.astype(jnp.float32), axis=1))


def consensus_error_pytree(tree: PyTree) -> jnp.ndarray:
    """Aggregate consensus error over an agent-stacked pytree."""
    leaves = jax.tree.leaves(tree)
    n = leaves[0].shape[0]
    mean_sq = jnp.zeros((n,), dtype=jnp.float32)
    for x in leaves:
        mean = jnp.mean(x, axis=0, keepdims=True)
        d = (x - mean).reshape(n, -1).astype(jnp.float32)
        mean_sq = mean_sq + jnp.sum(d * d, axis=1)
    return jnp.mean(jnp.sqrt(mean_sq))
