"""Consensus mixing operators: ``w = Pi x`` over the agent population.

Three execution paths, one semantics (paper eq. 5 / eq. 6):

1. **Stacked** (`mix_stacked`, `mix_pytree_stacked`) — every leaf carries a
   leading agent axis ``(N, ...)``; mixing is a dense matmul with ``Pi``.
   Used for CPU-scale simulation (tests, paper-figure benchmarks) and as
   the oracle the sharded paths are verified against.

2. **Sharded circulant** (`make_sharded_mix_fn`) — inside ``shard_map`` over
   a named agent mesh axis, a circulant ``Pi`` decomposes into static shift
   offsets, each lowering to one ``lax.ppermute`` (TPU: `collective-permute`
   over ICI neighbours).  This is the fixed-topology, neighbor-only
   communication pattern that is the paper's whole point: cost is
   ``degree * |params|`` point-to-point transfers instead of a global
   all-reduce.

3. **Sharded general** — non-circulant ``Pi`` falls back to
   ``all_gather`` + per-agent row contraction (cost ``N * |params|``; only
   sensible for small agent counts or dense graphs, where it matches the
   all-reduce cost anyway).

`FactoredMix` composes per-axis topologies as a Kronecker product
``Pi = Pi_pod (x) Pi_data`` — mixing sequentially over each mesh axis.  This
is our TPU-native extension for multi-pod meshes: a ring over the ``pod``
axis (scarce DCN links) crossed with a denser graph over the in-pod ``data``
axis (cheap ICI links).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.topology import Topology
from repro.utils.tree import tree_weighted_sum

PyTree = Any
MixFn = Callable[[PyTree], PyTree]


# --------------------------------------------------------------------------
# Stacked (dense, simulation) path
# --------------------------------------------------------------------------


def mix_stacked(pi: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """``(Pi x)_j = sum_l pi_{jl} x_l`` for ``x`` of shape (N, ...)."""
    pi = jnp.asarray(pi, dtype=jnp.float32)
    flat = x.reshape(x.shape[0], -1)
    mixed = jnp.einsum("jl,ld->jd", pi, flat.astype(jnp.float32))
    return mixed.astype(x.dtype).reshape(x.shape)


def mix_pytree_stacked(pi: jnp.ndarray, tree: PyTree) -> PyTree:
    """Apply `mix_stacked` to every leaf of an agent-stacked pytree."""
    return jax.tree.map(lambda x: mix_stacked(pi, x), tree)


def mix_pytree_list(pi: np.ndarray, trees: Sequence[PyTree]) -> list:
    """Host-level mixing of a list of per-agent pytrees (tests/benchmarks)."""
    n = len(trees)
    out = []
    for j in range(n):
        out.append(tree_weighted_sum([float(pi[j, l]) for l in range(n)], list(trees)))
    return out


# --------------------------------------------------------------------------
# Sharded (shard_map) path
# --------------------------------------------------------------------------


def _circulant_mix_leaf(x, shifts, axis_name: str, n: int):
    """sum_s w_s * ppermute(x, shift s) — one collective-permute per offset."""
    acc = None
    for s, w in sorted(shifts.items()):
        w = jnp.asarray(w, dtype=x.dtype)
        if s % n == 0:
            term = w * x
        else:
            # agent j receives from agent (j + s) mod n
            perm = [((j + s) % n, j) for j in range(n)]
            term = w * lax.ppermute(x, axis_name, perm=perm)
        acc = term if acc is None else acc + term
    return acc


def _general_mix_leaf(x, pi: jnp.ndarray, axis_name: str):
    """all_gather + row contraction for arbitrary doubly-stochastic Pi."""
    j = lax.axis_index(axis_name)
    gathered = lax.all_gather(x, axis_name)  # (N, ...) local copy
    row = pi[j].astype(jnp.float32)
    flat = gathered.reshape(gathered.shape[0], -1).astype(jnp.float32)
    return (row @ flat).astype(x.dtype).reshape(gathered.shape[1:])


def make_sharded_mix_fn(topology: Topology, axis_name: str) -> MixFn:
    """Mixing function usable *inside* ``shard_map`` over ``axis_name``.

    The returned fn maps a local (per-agent) pytree to its ``Pi``-mixed
    value.  Circulant topologies use ppermute; general ones all_gather.
    """
    n = topology.n_agents
    if n == 1:
        return lambda tree: tree
    shifts = topology.shift_weights()
    if shifts is not None:
        def mix(tree: PyTree) -> PyTree:
            return jax.tree.map(lambda x: _circulant_mix_leaf(x, shifts, axis_name, n), tree)
        return mix
    pi = jnp.asarray(topology.pi, dtype=jnp.float32)

    def mix(tree: PyTree) -> PyTree:
        return jax.tree.map(lambda x: _general_mix_leaf(x, pi, axis_name), tree)

    return mix


def make_sharded_mean_fn(axis_names) -> MixFn:
    """Exact global mean over the agent axes (FedAvg server / centralized)."""

    def mean(tree: PyTree) -> PyTree:
        return jax.tree.map(lambda x: lax.pmean(x, axis_names), tree)

    return mean


@dataclasses.dataclass(frozen=True)
class FactoredMix:
    """Kronecker-factored topology over multiple mesh axes.

    ``factors`` is a sequence of (axis_name, Topology).  The effective
    agent-interaction matrix is ``Pi = Pi_1 (x) Pi_2 (x) ...`` (Kronecker
    product), which is itself doubly stochastic and symmetric PSD when the
    factors are; ``lambda_2(Pi) = max over factors of lambda_2`` (all other
    factor eigenvalues at 1).  Mixing applies each factor sequentially.
    """

    factors: Tuple[Tuple[str, Topology], ...]

    @property
    def n_agents(self) -> int:
        n = 1
        for _, t in self.factors:
            n *= t.n_agents
        return n

    def dense_pi(self) -> np.ndarray:
        pi = np.array([[1.0]])
        for _, t in self.factors:
            pi = np.kron(pi, t.pi)
        return pi

    @property
    def lambda2(self) -> float:
        # kron eigenvalues are products; second-largest = max factor lambda_2
        lams = [t.lambda2 for _, t in self.factors if t.n_agents > 1]
        return max(lams) if lams else 0.0

    @property
    def lambdan(self) -> float:
        prod = 1.0
        for _, t in self.factors:
            prod *= t.lambdan
        return prod

    def make_mix_fn(self) -> MixFn:
        fns = [make_sharded_mix_fn(t, ax) for ax, t in self.factors if t.n_agents > 1]

        def mix(tree: PyTree) -> PyTree:
            for f in fns:
                tree = f(tree)
            return tree

        return mix


# --------------------------------------------------------------------------
# Consensus diagnostics
# --------------------------------------------------------------------------


def consensus_error_stacked(x: jnp.ndarray) -> jnp.ndarray:
    """mean_j ||x_j - mean(x)|| for an agent-stacked leaf (Prop. 1 LHS)."""
    mean = jnp.mean(x, axis=0, keepdims=True)
    diff = (x - mean).reshape(x.shape[0], -1)
    return jnp.mean(jnp.linalg.norm(diff.astype(jnp.float32), axis=1))


def consensus_error_pytree(tree: PyTree) -> jnp.ndarray:
    """Aggregate consensus error over an agent-stacked pytree."""
    leaves = jax.tree.leaves(tree)
    n = leaves[0].shape[0]
    mean_sq = jnp.zeros((n,), dtype=jnp.float32)
    for x in leaves:
        mean = jnp.mean(x, axis=0, keepdims=True)
        d = (x - mean).reshape(n, -1).astype(jnp.float32)
        mean_sq = mean_sq + jnp.sum(d * d, axis=1)
    return jnp.mean(jnp.sqrt(mean_sq))
