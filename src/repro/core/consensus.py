"""Consensus mixing operators: ``w = Pi x`` over the agent population.

Three execution paths, one semantics (paper eq. 5 / eq. 6):

1. **Stacked** (`mix_stacked`, `mix_pytree_stacked`) — every leaf carries a
   leading agent axis ``(N, ...)``; mixing is a dense matmul with ``Pi``.
   Used for CPU-scale simulation (tests, paper-figure benchmarks) and as
   the oracle the sharded paths are verified against.

2. **Sharded circulant** (`make_sharded_mix_fn`) — inside ``shard_map`` over
   a named agent mesh axis, a circulant ``Pi`` decomposes into static shift
   offsets, each lowering to one ``lax.ppermute`` (TPU: `collective-permute`
   over ICI neighbours).  This is the fixed-topology, neighbor-only
   communication pattern that is the paper's whole point: cost is
   ``degree * |params|`` point-to-point transfers instead of a global
   all-reduce.

3. **Sharded general** — non-circulant ``Pi`` falls back to
   ``all_gather`` + per-agent row contraction (cost ``N * |params|``; only
   sensible for small agent counts or dense graphs, where it matches the
   all-reduce cost anyway).

`FactoredMix` composes per-axis topologies as a Kronecker product
``Pi = Pi_pod (x) Pi_data`` — mixing sequentially over each mesh axis.  This
is our TPU-native extension for multi-pod meshes: a ring over the ``pod``
axis (scarce DCN links) crossed with a denser graph over the in-pod ``data``
axis (cheap ICI links).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import flatbuf
from repro.core.topology import Topology
from repro.utils.tree import tree_weighted_sum

PyTree = Any
MixFn = Callable[[PyTree], PyTree]


# --------------------------------------------------------------------------
# Flat-buffer fused-consensus support (see repro.core.flatbuf)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FlatComm:
    """Whole-model fused-update support carried inside :class:`CommOps`.

    ``gather(bufs)`` maps the packed self-buffers to kernel-ready neighbor
    operands: in the **stacked** mode it returns the full agent stack per
    bucket with the dense ``Pi`` as ``(A, A)`` weights (the fused kernels
    vmap over agent rows); in the **sharded** mode it issues one
    ``lax.ppermute`` per circulant shift offset per bucket and returns the
    ``(S, rows, 128)`` stencil stack with ``(S,)`` weights.
    """

    lead: int                     # leading replica axes excluded from packing
    batched: bool                 # True: stacked simulation (dense Pi vmap)
    gather: Callable              # list[bufs] -> (list[neighbor stacks], weights)
    interpret: bool = True        # interpret=True for CPU; False on TPU

    def spec(self, tree: PyTree) -> flatbuf.FlatSpec:
        return flatbuf.make_flat_spec(tree, lead=self.lead)

    def pack(self, tree: PyTree, spec: flatbuf.FlatSpec):
        bufs = flatbuf.pack(tree, spec)
        if not self.batched and self.lead:
            # sharded: the local agent axis is fully sharded away (size 1)
            for b in bufs:
                assert all(d == 1 for d in b.shape[:self.lead]), b.shape
            bufs = [b.reshape(b.shape[self.lead:]) for b in bufs]
        return bufs

    def unpack(self, bufs, spec: flatbuf.FlatSpec) -> PyTree:
        if not self.batched and self.lead:
            bufs = [b.reshape((1,) * self.lead + b.shape) for b in bufs]
        return flatbuf.unpack(bufs, spec)


def stacked_flat_comm(topology: Topology, *, interpret: bool = True) -> FlatComm:
    """FlatComm for agent-stacked pytrees (dense ``Pi``, any topology)."""
    pi = jnp.asarray(topology.pi, dtype=jnp.float32)

    def gather(bufs):
        return list(bufs), pi

    return FlatComm(lead=1, batched=True, gather=gather, interpret=interpret)


def sharded_flat_comm(factors: Sequence[Tuple[str, Topology]], *,
                      lead: int = 1, interpret: bool = True) -> FlatComm:
    """FlatComm for use inside ``shard_map``; circulant topologies only.

    ``factors`` is ``[(axis_name, Topology), ...]`` — one entry for the
    plain single-axis agent mesh, several for a Kronecker-factored one.
    Each bucket costs one ``lax.ppermute`` per non-zero shift combination;
    weights are the (outer-)product of the per-factor circulant weights.
    """
    import itertools

    per_axis = []
    for axis_name, topo in factors:
        if topo.n_agents == 1:
            continue
        shifts = topo.shift_weights()
        if shifts is None:
            raise ValueError(
                f"topology {topo.name!r} on axis {axis_name!r} is not "
                "circulant; use mixing='ppermute' or 'dense' instead")
        per_axis.append((axis_name, topo.n_agents, sorted(shifts.items())))

    combos = list(itertools.product(*[s for _, _, s in per_axis])) or [()]
    weights = jnp.asarray([float(np.prod([w for _, w in combo]) if combo else 1.0)
                           for combo in combos], jnp.float32)

    def gather(bufs):
        stacked = []
        for b in bufs:
            stencil = []
            for combo in combos:
                nb = b
                for (axis_name, n, _), (s, _w) in zip(per_axis, combo):
                    if s % n:
                        # agent j receives from agent (j + s) mod n
                        perm = [((j + s) % n, j) for j in range(n)]
                        nb = lax.ppermute(nb, axis_name, perm=perm)
                stencil.append(nb)
            stacked.append(jnp.stack(stencil))
        return stacked, weights

    return FlatComm(lead=lead, batched=False, gather=gather, interpret=interpret)


# --------------------------------------------------------------------------
# Stacked (dense, simulation) path
# --------------------------------------------------------------------------


def mix_stacked(pi: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """``(Pi x)_j = sum_l pi_{jl} x_l`` for ``x`` of shape (N, ...)."""
    pi = jnp.asarray(pi, dtype=jnp.float32)
    flat = x.reshape(x.shape[0], -1)
    mixed = jnp.einsum("jl,ld->jd", pi, flat.astype(jnp.float32))
    return mixed.astype(x.dtype).reshape(x.shape)


def mix_pytree_stacked(pi: jnp.ndarray, tree: PyTree) -> PyTree:
    """Apply `mix_stacked` to every leaf of an agent-stacked pytree."""
    return jax.tree.map(lambda x: mix_stacked(pi, x), tree)


def mix_pytree_list(pi: np.ndarray, trees: Sequence[PyTree]) -> list:
    """Host-level mixing of a list of per-agent pytrees (tests/benchmarks)."""
    n = len(trees)
    out = []
    for j in range(n):
        out.append(tree_weighted_sum([float(pi[j, l]) for l in range(n)], list(trees)))
    return out


# --------------------------------------------------------------------------
# Sharded (shard_map) path
# --------------------------------------------------------------------------


def _circulant_mix_leaf(x, shifts, axis_name: str, n: int):
    """sum_s w_s * ppermute(x, shift s) — one collective-permute per offset."""
    acc = None
    for s, w in sorted(shifts.items()):
        w = jnp.asarray(w, dtype=x.dtype)
        if s % n == 0:
            term = w * x
        else:
            # agent j receives from agent (j + s) mod n
            perm = [((j + s) % n, j) for j in range(n)]
            term = w * lax.ppermute(x, axis_name, perm=perm)
        acc = term if acc is None else acc + term
    return acc


def _general_mix_leaf(x, pi: jnp.ndarray, axis_name: str):
    """all_gather + row contraction for arbitrary doubly-stochastic Pi."""
    j = lax.axis_index(axis_name)
    gathered = lax.all_gather(x, axis_name)  # (N, ...) local copy
    row = pi[j].astype(jnp.float32)
    flat = gathered.reshape(gathered.shape[0], -1).astype(jnp.float32)
    return (row @ flat).astype(x.dtype).reshape(gathered.shape[1:])


def make_sharded_mix_fn(topology: Topology, axis_name: str) -> MixFn:
    """Mixing function usable *inside* ``shard_map`` over ``axis_name``.

    The returned fn maps a local (per-agent) pytree to its ``Pi``-mixed
    value.  Circulant topologies use ppermute; general ones all_gather.
    """
    n = topology.n_agents
    if n == 1:
        return lambda tree: tree
    shifts = topology.shift_weights()
    if shifts is not None:
        def mix(tree: PyTree) -> PyTree:
            return jax.tree.map(lambda x: _circulant_mix_leaf(x, shifts, axis_name, n), tree)
        return mix
    pi = jnp.asarray(topology.pi, dtype=jnp.float32)

    def mix(tree: PyTree) -> PyTree:
        return jax.tree.map(lambda x: _general_mix_leaf(x, pi, axis_name), tree)

    return mix


def make_sharded_mean_fn(axis_names) -> MixFn:
    """Exact global mean over the agent axes (FedAvg server / centralized)."""

    def mean(tree: PyTree) -> PyTree:
        return jax.tree.map(lambda x: lax.pmean(x, axis_names), tree)

    return mean


@dataclasses.dataclass(frozen=True)
class FactoredMix:
    """Kronecker-factored topology over multiple mesh axes.

    ``factors`` is a sequence of (axis_name, Topology).  The effective
    agent-interaction matrix is ``Pi = Pi_1 (x) Pi_2 (x) ...`` (Kronecker
    product), which is itself doubly stochastic and symmetric PSD when the
    factors are; ``lambda_2(Pi) = max over factors of lambda_2`` (all other
    factor eigenvalues at 1).  Mixing applies each factor sequentially.
    """

    factors: Tuple[Tuple[str, Topology], ...]

    @property
    def n_agents(self) -> int:
        n = 1
        for _, t in self.factors:
            n *= t.n_agents
        return n

    def dense_pi(self) -> np.ndarray:
        pi = np.array([[1.0]])
        for _, t in self.factors:
            pi = np.kron(pi, t.pi)
        return pi

    @property
    def lambda2(self) -> float:
        # kron eigenvalues are products; second-largest = max factor lambda_2
        lams = [t.lambda2 for _, t in self.factors if t.n_agents > 1]
        return max(lams) if lams else 0.0

    @property
    def lambdan(self) -> float:
        prod = 1.0
        for _, t in self.factors:
            prod *= t.lambdan
        return prod

    def make_mix_fn(self) -> MixFn:
        fns = [make_sharded_mix_fn(t, ax) for ax, t in self.factors if t.n_agents > 1]

        def mix(tree: PyTree) -> PyTree:
            for f in fns:
                tree = f(tree)
            return tree

        return mix


# --------------------------------------------------------------------------
# Consensus diagnostics
# --------------------------------------------------------------------------


def consensus_error_stacked(x: jnp.ndarray) -> jnp.ndarray:
    """mean_j ||x_j - mean(x)|| for an agent-stacked leaf (Prop. 1 LHS)."""
    mean = jnp.mean(x, axis=0, keepdims=True)
    diff = (x - mean).reshape(x.shape[0], -1)
    return jnp.mean(jnp.linalg.norm(diff.astype(jnp.float32), axis=1))


def consensus_error_pytree(tree: PyTree) -> jnp.ndarray:
    """Aggregate consensus error over an agent-stacked pytree."""
    leaves = jax.tree.leaves(tree)
    n = leaves[0].shape[0]
    mean_sq = jnp.zeros((n,), dtype=jnp.float32)
    for x in leaves:
        mean = jnp.mean(x, axis=0, keepdims=True)
        d = (x - mean).reshape(n, -1).astype(jnp.float32)
        mean_sq = mean_sq + jnp.sum(d * d, axis=1)
    return jnp.mean(jnp.sqrt(mean_sq))
