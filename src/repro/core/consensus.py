"""Consensus mixing operators: ``w = Pi x`` over the agent population.

Three execution paths, one semantics (paper eq. 5 / eq. 6):

1. **Stacked** (`mix_stacked`, `mix_pytree_stacked`) — every leaf carries a
   leading agent axis ``(N, ...)``; mixing is a dense matmul with ``Pi``.
   Used for CPU-scale simulation (tests, paper-figure benchmarks) and as
   the oracle the sharded paths are verified against.

2. **Sharded circulant** (`make_sharded_mix_fn`) — inside ``shard_map`` over
   a named agent mesh axis, a circulant ``Pi`` decomposes into static shift
   offsets, each lowering to one ``lax.ppermute`` (TPU: `collective-permute`
   over ICI neighbours).  This is the fixed-topology, neighbor-only
   communication pattern that is the paper's whole point: cost is
   ``degree * |params|`` point-to-point transfers instead of a global
   all-reduce.

3. **Sharded general** — non-circulant ``Pi`` falls back to
   ``all_gather`` + per-agent row contraction (cost ``N * |params|``; only
   sensible for small agent counts or dense graphs, where it matches the
   all-reduce cost anyway).

`FactoredMix` composes per-axis topologies as a Kronecker product
``Pi = Pi_pod (x) Pi_data`` — mixing sequentially over each mesh axis.  This
is our TPU-native extension for multi-pod meshes: a ring over the ``pod``
axis (scarce DCN links) crossed with a denser graph over the in-pod ``data``
axis (cheap ICI links).

Mixing strategies (the MixingProgram layer)
-------------------------------------------
How the wire stages compose per optimizer step is a first-class
**strategy** object (:class:`StaticMixing`, :class:`TimeVaryingMixing`,
:class:`MultiRoundMixing`), configured by a :class:`MixingProgram` and
carried inside :class:`FlatComm`.  Every strategy implements the same
contract — ``quantize_stage`` / ``exchange_stage`` / ``gather`` plus the
engine-facing ``continue_from_wire`` and the error-feedback
``quantize_ef`` — so both execution modes, both exchange schedules
(``sync`` / ``overlap``), the fused kernels, the wire-byte accounting, and
the dryrun dependency proof apply to any of them unchanged (see
ARCHITECTURE.md §mixing strategies).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import flatbuf
from repro.core.faults import (FaultSchedule, arrival_masked_pi,
                               trivial_faults)
from repro.core.faults import MAX_FAULT_PERIOD as _MAX_FAULT_PERIOD
from repro.core.topology import Topology, TopologySchedule, fixed_schedule
from repro.utils.tree import tree_weighted_sum

PyTree = Any
MixFn = Callable[[PyTree], PyTree]


# --------------------------------------------------------------------------
# MixingProgram: the configuration of the mixing-strategy layer
# --------------------------------------------------------------------------

MIXING_STRATEGIES = ("static", "time_varying", "multi_round")
MOMENTUM_MIXINGS = ("none", "mixed")

# the compressor axis: dense SR quantizers (aliases for ``exchange=``) and
# the biased EF-rail compressors (see repro.kernels.consensus_update.topk)
COMPRESSOR_KINDS = ("none", "int8", "fp8", "topk", "rank")


def parse_compressor(spec: str):
    """``"none" | "int8" | "fp8" | "topk:p" | "rank:r"`` -> ``(kind, param)``.

    ``param`` is the float density ``p in (0, 1]`` for ``topk``, the int
    rank ``r >= 1`` for ``rank``, and ``None`` for the dense kinds.
    ``"topk:auto:B"`` selects adaptive per-bucket density against a total
    byte budget ``B`` per neighbor (``param = ("auto", B)``; see
    :func:`repro.kernels.consensus_update.topk.topk_auto_k_rows`).
    Raises an actionable ``ValueError`` on malformed specs — this is the
    single parser behind ``--compressor`` and ``make_mixing_program``.
    """
    if not isinstance(spec, str):
        raise TypeError(f"compressor spec must be a str, got "
                        f"{type(spec).__name__}")
    kind, _, arg = spec.partition(":")
    if kind not in COMPRESSOR_KINDS:
        raise ValueError(
            f"unknown compressor {spec!r}; expected one of "
            f"{COMPRESSOR_KINDS[:3]} or 'topk:p' (0 < p <= 1) or "
            "'rank:r' (int r >= 1)")
    if kind in ("none", "int8", "fp8"):
        if arg:
            raise ValueError(f"compressor {kind!r} takes no parameter "
                             f"(got {spec!r})")
        return kind, None
    if not arg:
        raise ValueError(
            f"compressor {kind!r} needs a parameter: "
            + ("'topk:p' with density 0 < p <= 1 (e.g. 'topk:0.01')"
               if kind == "topk" else
               "'rank:r' with int rank r >= 1 (e.g. 'rank:4')"))
    if kind == "topk":
        if arg.startswith("auto:") or arg == "auto":
            _, _, barg = arg.partition(":")
            try:
                budget = int(barg)
            except ValueError:
                raise ValueError(
                    f"topk:auto needs an int byte budget per neighbor, got "
                    f"{barg!r} in {spec!r} (e.g. 'topk:auto:65536')") from None
            if budget < 1:
                raise ValueError(f"topk:auto byte budget must be >= 1, got "
                                 f"{budget} in {spec!r}")
            return kind, ("auto", budget)
        try:
            p = float(arg)
        except ValueError:
            raise ValueError(f"top-k density must be a float, got {arg!r} "
                             f"in {spec!r}; for adaptive per-bucket density "
                             f"use 'topk:auto:B' with a byte budget") from None
        if not (0.0 < p <= 1.0):
            raise ValueError(f"top-k density must be in (0, 1], got {p!r} "
                             f"in {spec!r}")
        return kind, p
    try:
        r = int(arg)
    except ValueError:
        raise ValueError(f"rank must be an int, got {arg!r} in {spec!r}") \
            from None
    if r < 1:
        raise ValueError(f"rank must be >= 1, got {r} in {spec!r}")
    return kind, r


@dataclasses.dataclass(frozen=True)
class MixingProgram:
    """What the consensus exchange does each optimizer step.

    * ``strategy="static"``      — one fixed ``Pi``, one round (the paper's
      setting; bit-for-bit today's path);
    * ``strategy="time_varying"``— ``Pi_t = schedule[t % period]`` selected
      by the optimizer step (B-connected sequences, gossip pairs);
    * ``strategy="multi_round"`` — ``rounds`` inner consensus rounds per
      gradient step, re-quantizing between rounds: ``x' = Pi^k x - a g``
      (i-CDSGD, Jiang et al. 1805.12120).  ``rounds`` also composes with
      ``time_varying`` (``Pi_t`` applied ``k`` times).

    ``error_feedback`` compresses ``residual + payload`` instead of the raw
    payload and carries the compression error in ``OptState.residual`` —
    the principled fix for quantization-noise accumulation (requires a
    quantized ``exchange``; the residual never crosses the wire).

    ``momentum_mixing="mixed"`` widens the wire to TWO payload trees: the
    momentum buffer rides alongside the params and is mixed with the same
    agent-interaction matrix — ``v' = mu (Pi v) - a g`` instead of
    ``v' = mu v - a g`` (Gao & Huang, 2010.11166).  With an unmixed
    momentum the disagreement dynamics of the joint ``(x, v)`` system
    contract at ``max(|lambda_2|, mu)`` through a non-normal coupling, so
    any per-step wire noise persists for ``~1/(1-mu)`` steps — the PR 2
    large-lr momentum/quantization instability; mixing ``v`` over the wire
    makes both dynamics contract together at ``|lambda_2|`` (see
    :func:`repro.core.lyapunov.momentum_consensus_contraction`).  Doubles
    the wire bytes at equal precision; momentum-capable fused optimizers
    only (CDMSGD family / CDAdam's first moment).

    ``staleness=S`` / ``faults=`` engage the **bounded-staleness ring**
    (``schedule="overlap"`` only): the overlap double-buffer generalizes to
    a depth-``S`` ring of each agent's own last-``S`` quantized wire
    generations (:class:`WireRing`); under the injected
    :class:`~repro.core.faults.FaultSchedule` each sender contributes the
    freshest generation that *arrived* (up to ``S`` steps stale) and the
    mixing weights renormalize over arrived neighbors — a dropped or
    over-stale neighbor's mass folds into the receiver's self term,
    preserving row-stochasticity.  The self term stays fresh and
    full-precision exactly as today: staleness and masking ride entirely in
    *which* carried buffers and *which* weights feed the existing
    self-separated fused update (no new kernel variants), and the per-step
    wire bytes are independent of ``S`` — a stale slot moves nothing.

    Built via :func:`make_mixing_program`, which validates everything at
    config time — never inside a traced step.
    """

    schedule: TopologySchedule
    strategy: str = "static"
    rounds: int = 1
    error_feedback: bool = False
    exchange: str = "f32"
    momentum_mixing: str = "none"
    # bounded-staleness fault tolerance: ring depth S and the injected
    # fault schedule (see repro.core.faults).  staleness=1 with no faults
    # is today's overlap double-buffer, bit-for-bit.
    staleness: int = 1
    faults: Optional[FaultSchedule] = None
    # the compressor axis: "none" | "int8" | "fp8" (dense aliases — they
    # normalize ``exchange`` and change nothing else, bit-for-bit) |
    # "topk:p" | "rank:r" (biased EF-rail compressors; require
    # error_feedback=True, validated in make_mixing_program)
    compressor: str = "none"
    # sparse operand form of the fused update: with the top-k wire the
    # *_update_sparse_2d kernels consume the TopKWire fields directly
    # (scatter-accumulate, O(k_rows) neighbor reads) instead of
    # densifying via _decompress_entry first (O(rows)).  Default on for
    # topk (resolved in make_mixing_program); False keeps the dense
    # decompress path as the reference oracle.
    sparse_update: bool = False

    @property
    def fault_tolerant(self) -> bool:
        """True iff the depth-S staleness ring / arrival-masked weight path
        is engaged (``staleness > 1`` or an injected fault schedule)."""
        return self.staleness > 1 or self.faults is not None

    @property
    def compressor_kind(self) -> str:
        return parse_compressor(self.compressor)[0]

    @property
    def compressor_param(self):
        """Density ``p`` (topk) / rank ``r`` (rank); None for dense kinds."""
        return parse_compressor(self.compressor)[1]

    @property
    def compressed(self) -> bool:
        """True iff a biased (top-k / rank-r) compressor rides the wire —
        the dense int8/fp8 aliases resolve to the existing exchange path."""
        return self.compressor_kind in ("topk", "rank")

    @property
    def is_trivial(self) -> bool:
        """True iff this is exactly the legacy single-round fixed-``Pi``
        program (whose sync path must stay bit-for-bit unchanged)."""
        return (self.strategy == "static" and self.rounds == 1
                and not self.error_feedback
                and self.momentum_mixing == "none"
                and not self.fault_tolerant
                and not self.compressed)

    @property
    def n_payloads(self) -> int:
        """Payload trees on the wire: params, plus the mixed momentum."""
        return 2 if self.momentum_mixing == "mixed" else 1

    def describe(self) -> dict:
        return {
            "strategy": self.strategy,
            "schedule": self.schedule.name,
            "period": self.schedule.period,
            "rounds": self.rounds,
            "error_feedback": self.error_feedback,
            "exchange": self.exchange,
            "momentum_mixing": self.momentum_mixing,
            "staleness": self.staleness,
            "faults": self.faults.describe() if self.faults else None,
            "compressor": self.compressor,
            "sparse_update": self.sparse_update,
        }


def make_mixing_program(
    topology_or_schedule,
    *,
    strategy: str = "static",
    rounds: int = 1,
    error_feedback: bool = False,
    exchange: str = "f32",
    momentum_mixing: str = "none",
    staleness: int = 1,
    faults: Optional[FaultSchedule] = None,
    compressor: str = "none",
    sparse_update: Optional[bool] = None,
) -> MixingProgram:
    """Validate + build a :class:`MixingProgram` at config time.

    Accepts a :class:`Topology` (wrapped in a period-1 schedule) or a
    :class:`TopologySchedule`.  ``strategy="static"`` with ``rounds > 1``
    is promoted to ``"multi_round"`` (they are the same family; ``k = 1``
    multi-round is literally the static strategy object).

    ``compressor="int8"|"fp8"`` are dense aliases: they normalize
    ``exchange`` to the same precision and change nothing else (bit-for-bit
    the existing quantized path).  ``"topk:p"`` / ``"rank:r"`` engage the
    biased EF-rail compressors, which REQUIRE ``error_feedback=True`` and
    exclude staleness/faults, inner rounds, and momentum mixing — each
    rejection below names the conflicting flags and the supported
    alternative.

    ``sparse_update=None`` resolves to True exactly for the top-k
    compressor (the sparse operand form of the fused update, see
    :class:`MixingProgram`); pass ``False`` to force the dense
    decompress-then-update reference path.  Explicit ``True`` with any
    other compressor is rejected — only the top-k wire has the compact
    scatter operand form.
    """
    _check_exchange(exchange)
    ckind, _cparam = parse_compressor(compressor)
    if sparse_update is None:
        sparse_update = ckind == "topk"
    elif sparse_update and ckind != "topk":
        raise ValueError(
            f"sparse_update=True needs --compressor topk:p / topk:auto:B "
            f"(got {compressor!r}): only the top-k wire has the compact "
            "gather-dequant-accumulate operand form — drop sparse_update "
            "or switch to a top-k compressor")
    if ckind in ("int8", "fp8"):
        if exchange not in ("f32", ckind):
            raise ValueError(
                f"--compressor {ckind} conflicts with --exchange "
                f"{exchange}: the dense compressor aliases ARE the "
                f"quantized exchange — drop --exchange or set it to "
                f"{ckind!r}")
        exchange = ckind
    if ckind in ("topk", "rank"):
        if not error_feedback:
            raise ValueError(
                f"--compressor {compressor} is a biased compressor and "
                "needs --error-feedback: without the EF residual "
                "(OptState.residual) the dropped mass accumulates and the "
                "consensus diverges (Karimireddy et al. 2019) — add "
                "--error-feedback, or use --compressor int8/fp8 for an "
                "unbiased dense wire")
        if staleness > 1 or faults is not None:
            raise ValueError(
                f"--compressor {compressor} is incompatible with "
                "--staleness > 1 / --fault-schedule: the EF residual "
                "telescoping it requires assumes every carried payload is "
                "consumed exactly one step later — use --compressor "
                "int8/fp8 (no EF) with the staleness ring instead")
        if rounds > 1 or strategy == "multi_round":
            raise ValueError(
                f"--compressor {compressor} is incompatible with "
                "--consensus-rounds > 1: inner i-CDSGD rounds re-compress "
                "partially mixed buffers without an EF residual to absorb "
                "the bias — use a single round, or --compressor int8/fp8 "
                "for multi-round")
        if momentum_mixing != "none":
            raise ValueError(
                f"--compressor {compressor} is incompatible with "
                "--momentum-mixing mixed: only the params payload rides "
                "the sparse/low-rank wire — use --compressor int8/fp8 to "
                "mix the momentum buffer, or momentum_mixing='none'")
        if ckind == "topk":
            if exchange not in ("f32", "int8"):
                raise ValueError(
                    f"--compressor {compressor} ships int8 SR-quantized "
                    f"compact values; --exchange {exchange} conflicts — "
                    "drop --exchange (the compact-value precision is part "
                    "of the top-k wire contract)")
            exchange = "int8"
        else:
            if exchange != "f32":
                raise ValueError(
                    f"--compressor {compressor} ships two dense f32 "
                    f"factors; --exchange {exchange} conflicts — drop "
                    "--exchange (quantizing the factors is not part of "
                    "the rank-r wire contract)")
    if isinstance(topology_or_schedule, Topology):
        schedule = fixed_schedule(topology_or_schedule)
    elif isinstance(topology_or_schedule, TopologySchedule):
        schedule = topology_or_schedule
    else:
        raise TypeError(f"expected Topology or TopologySchedule, got "
                        f"{type(topology_or_schedule).__name__}")
    if not isinstance(rounds, int) or rounds < 1:
        raise ValueError(f"consensus rounds must be an int >= 1, got {rounds!r}")
    if strategy not in MIXING_STRATEGIES:
        raise ValueError(f"unknown mixing strategy {strategy!r}; expected one "
                         f"of {MIXING_STRATEGIES}")
    if strategy == "static" and rounds > 1:
        strategy = "multi_round"
    if strategy == "multi_round" and rounds == 1:
        # k = 1 multi-round IS the static strategy — normalizing here makes
        # the equivalence bit-for-bit by construction (same legacy gather)
        strategy = "static"
    if strategy in ("static", "multi_round") and schedule.period != 1:
        raise ValueError(
            f"strategy={strategy!r} takes a fixed topology but the schedule "
            f"{schedule.name!r} has period {schedule.period}; use "
            "strategy='time_varying'")
    if error_feedback and exchange not in ("int8", "fp8") \
            and ckind not in ("topk", "rank"):
        raise ValueError(
            "--error-feedback needs a lossy wire to feed back: set "
            "--exchange int8/fp8 (quantization error) or --compressor "
            f"topk:p/rank:r (compression error); exchange={exchange!r} "
            "with a dense compressor has no error to carry")
    if momentum_mixing not in MOMENTUM_MIXINGS:
        raise ValueError(f"unknown momentum_mixing {momentum_mixing!r}; "
                         f"expected one of {MOMENTUM_MIXINGS}")
    if not isinstance(staleness, int) or staleness < 1:
        raise ValueError(f"staleness must be an int >= 1, got {staleness!r}")
    if faults is not None:
        if not isinstance(faults, FaultSchedule):
            raise TypeError(f"faults must be a FaultSchedule, got "
                            f"{type(faults).__name__}")
        if faults.n_agents != schedule.n_agents:
            raise ValueError(f"fault schedule covers {faults.n_agents} agents "
                             f"but the topology has {schedule.n_agents}")
        faults.validate()
        if faults.is_trivial:
            faults = None  # the all-arrive schedule IS the no-fault program
    if error_feedback and (staleness > 1 or faults is not None):
        raise ValueError(
            "--error-feedback is incompatible with --staleness > 1 / "
            "--fault-schedule: the residual telescoping assumes every "
            "carried wire payload is consumed exactly one step later, which "
            "bounded staleness breaks by design — drop --error-feedback "
            "(plain SR quantization is unbiased) or run staleness=1 with "
            "no fault schedule")
    return MixingProgram(schedule=schedule, strategy=strategy, rounds=rounds,
                         error_feedback=error_feedback, exchange=exchange,
                         momentum_mixing=momentum_mixing,
                         staleness=staleness, faults=faults,
                         compressor=compressor, sparse_update=sparse_update)


# --------------------------------------------------------------------------
# Flat-buffer fused-consensus support (see repro.core.flatbuf)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FlatComm:
    """Whole-model fused-update support carried inside :class:`CommOps`.

    ``gather(bufs, seed)`` maps the packed self-buffers to kernel-ready
    neighbor operands ``(neighbor_stacks, weights, scales, selfs)``: in the
    **stacked** mode it returns the full agent stack per bucket with the
    dense ``Pi`` as ``(A, A)`` weights (the fused kernels vmap over agent
    rows); in the **sharded** mode it issues one ``lax.ppermute`` per
    circulant shift offset per bucket and returns the ``(S, rows, 128)``
    stencil stack with ``(S,)`` weights.

    ``exchange`` selects the wire precision of the neighbor stacks:
    ``"f32"`` (native bucket dtype), ``"bf16"`` (cast), or ``"int8"`` /
    ``"fp8"`` (stochastic-rounding quantization with one f32 scale per
    128-lane row).  For quantized exchanges the per-bucket ``scales`` entry
    is the ``(..., rows, 1)`` stack the fused kernels dequantize with
    in-register, and ``selfs`` carries the native-precision self buffers —
    the local parameters never cross the wire, so they are mixed exactly at
    ``weights[..., 0]`` while only true neighbor payloads pay quantization
    noise.  Both are ``None`` for unquantized exchanges.  ``seed`` (an
    int32 scalar, typically the optimizer step) drives the stochastic
    rounding; it is decorrelated per bucket and per agent, identically in
    both execution modes, so stacked and sharded quantized trajectories
    match exactly whenever their bucket layouts coincide (params sharded
    over non-agent mesh axes pack differently per device, which draws the
    same seeds at different row positions).

    Phase stages (the StepProgram engine's pipeline, see
    :mod:`repro.core.engine`): ``gather`` is the one-shot sync form;
    ``quantize_stage(bufs, seed)`` and ``exchange_stage(wire, step)``
    expose the same computation as two separately schedulable halves.
    ``quantize_stage`` maps packed buckets to the **wire state** — one
    ``(payload, row_scales)`` pair per bucket, always carrying the leading
    agent axes so it can live inside the optimizer state under either
    execution mode (f32/bf16 wires carry unit scales).  ``exchange_stage``
    turns a wire state into the self-separated kernel operands
    ``(neighbor_stacks, weights_q, scale_stacks)`` with the self weight
    first — in the sharded mode this is where the ``ppermute``\\ s happen,
    and because the wire state may come from the *previous* optimizer step
    the exchange has no data dependency on the current backward (the
    ``schedule="overlap"`` one-step-stale pipeline).  ``step`` indexes the
    schedule of a time-varying strategy (ignored by fixed topologies).

    All three callables delegate to ``strategy`` — the
    :class:`MixingStrategy` object configured by ``program`` — which also
    carries the multi-round pipeline (``continue_from_wire``) and the
    error-feedback quantizer (``quantize_ef``) the engine schedules.
    """

    lead: int                     # leading replica axes excluded from packing
    batched: bool                 # True: stacked simulation (dense Pi vmap)
    gather: Callable              # (bufs, seed) -> (nbrs, weights, scales, selfs)
    interpret: bool = True        # interpret=True for CPU; False on TPU
    exchange: str = "f32"         # wire precision: f32 | bf16 | int8 | fp8
    n_agents: int = 1
    # split phase stages (see class docstring); None on comms predating them
    quantize_stage: Optional[Callable] = None   # (bufs, seed) -> wire
    exchange_stage: Optional[Callable] = None   # (wire, step) -> (nbrs, weights_q, scales)
    # the mixing-strategy layer (None only on hand-rolled test comms)
    strategy: Optional["MixingStrategy"] = None
    program: Optional[MixingProgram] = None

    def spec(self, tree: PyTree) -> flatbuf.FlatSpec:
        return flatbuf.make_flat_spec(tree, lead=self.lead)

    def pack(self, tree: PyTree, spec: flatbuf.FlatSpec):
        bufs = flatbuf.pack(tree, spec)
        if not self.batched and self.lead:
            # sharded: the local agent axis is fully sharded away (size 1)
            for b in bufs:
                assert all(d == 1 for d in b.shape[:self.lead]), b.shape
            bufs = [b.reshape(b.shape[self.lead:]) for b in bufs]
        return bufs

    def unpack(self, bufs, spec: flatbuf.FlatSpec) -> PyTree:
        if not self.batched and self.lead:
            bufs = [b.reshape((1,) * self.lead + b.shape) for b in bufs]
        return flatbuf.unpack(bufs, spec)


# distinct odd strides decorrelate the stochastic-rounding streams across
# steps, buckets, agents, inner consensus rounds, and wire payloads
# (params vs mixed momentum) while keeping stacked/sharded seeds identical
# (without the step stride, step t+1 / bucket b would collide with step
# t+1-7919k / bucket b+k; int32 wraparound at large steps is fine — the
# seed only needs to be a well-spread hash input).  The composition is
# documented by :func:`wire_seed` and pinned collision-free over the
# realistic index ranges in tests/test_mixing.py.
_SEED_STEP_STRIDE = 1000003
_SEED_BUCKET_STRIDE = 7919
_SEED_AGENT_STRIDE = 104729
_SEED_ROUND_STRIDE = 611953
_SEED_PAYLOAD_STRIDE = 2750161


def wire_seed(step, agent: int = 0, bucket: int = 0, rnd: int = 0,
              payload: int = 0) -> int:
    """The SR-stream seed of one quantized wire payload, as a host int.

    This is THE seed composition both execution modes implement (the
    stacked mode vectorizes the agent term, the sharded mode derives it
    from ``lax.axis_index``):

        seed = STEP * (step + ROUND * rnd) + AGENT * agent
             + BUCKET * bucket + PAYLOAD * payload      (mod 2^32)

    ``rnd`` is the inner consensus round (0 = the round-1 wire, whose seed
    is the bare optimizer step); ``payload`` is 0 for params and 1 for the
    mixed momentum buffer.  Returns the signed int32 value the stages feed
    the quantizer (the traced arithmetic wraps identically).  Exposed so
    tests can assert the strides stay collision-free over the realistic
    index ranges by construction.
    """
    s = np.int64(step) + np.int64(_SEED_ROUND_STRIDE) * np.int64(rnd)
    seed = (np.int64(_SEED_STEP_STRIDE) * s
            + np.int64(_SEED_AGENT_STRIDE) * np.int64(agent)
            + np.int64(_SEED_BUCKET_STRIDE) * np.int64(bucket)
            + np.int64(_SEED_PAYLOAD_STRIDE) * np.int64(payload))
    return int(np.int64(seed).astype(np.int32))


def _check_exchange(exchange: str) -> str:
    """Fail at comm construction, not deep inside the first traced update."""
    if exchange not in flatbuf.EXCHANGE_DTYPES:
        raise ValueError(f"unknown exchange precision {exchange!r}; "
                         f"expected one of {flatbuf.EXCHANGE_DTYPES}")
    return exchange


def _wire_payload(buf, seed, exchange: str, interpret: bool):
    """Cast/quantize one packed bucket for the wire -> (payload, scales).

    ``bf16`` casts the whole stencil *including* the self tile: without
    scales the kernels need one homogeneous neighbor operand, and the
    ~2^-8 relative rounding this adds to the self term is the mode's
    stated noise level anyway.  int8/fp8 keep self native (see ``selfs``).
    """
    if exchange == "f32":
        return buf, None
    if exchange == "bf16":
        return buf.astype(jnp.bfloat16), None
    from repro.kernels.consensus_update.consensus_update import sr_quantize_2d
    return sr_quantize_2d(buf, seed, exchange=exchange, interpret=interpret)


def _quantize_wire_stacked(bufs, seed, n: int, exchange: str, interpret: bool,
                           payload: int = 0):
    """Quantize agent-stacked ``(A, rows, 128)`` buckets for the wire.

    Returns the wire state: one ``(payload, (A, rows, 1) f32 scales)`` pair
    per bucket.  Per-agent seeds match the sharded stage's
    ``axis_index``-derived seeds, so both execution modes produce the same
    wire bits from the same parameters.  f32/bf16 wires cast and carry
    unit scales (the fused kernels' in-register dequant multiply is then
    the identity), so every exchange precision shares one wire layout.
    ``payload`` decorrelates the SR streams of the second payload tree
    (the mixed momentum buffer) from the params' — see :func:`wire_seed`.
    """
    if exchange in ("f32", "bf16"):
        return tuple(
            (_wire_payload(b, None, exchange, interpret)[0],
             jnp.ones(b.shape[:-1] + (1,), jnp.float32)) for b in bufs)
    base = _SEED_STEP_STRIDE * jnp.asarray(seed, jnp.int32) \
        + jnp.int32(_SEED_PAYLOAD_STRIDE * payload)
    agent_seeds = _SEED_AGENT_STRIDE * jnp.arange(n, dtype=jnp.int32)
    out = []
    for bi, b in enumerate(bufs):
        q, sc = jax.vmap(
            lambda x, s: _wire_payload(x, s, exchange, interpret)
        )(b, base + _SEED_BUCKET_STRIDE * bi + agent_seeds)
        out.append((q, sc))
    return tuple(out)


# --------------------------------------------------------------------------
# Compressed wire payloads (the biased EF-rail compressors)
# --------------------------------------------------------------------------


class TopKWire(NamedTuple):
    """Static-shape wire contract of one top-k-compressed bucket.

    The ragged ``ceil(p * n)`` selection is rounded up to a lane-aligned
    compact tile (:func:`repro.kernels.consensus_update.topk.topk_k_rows`),
    so every ppermute moves three fixed-shape arrays (a NamedTuple — i.e.
    a pytree — so checkpointing, PartitionSpecs, and the dependency-report
    labeling treat it as more wire leaves with zero special casing):

    * ``values``  — int8 ``(*lead, k_rows, 128)`` SR-quantized compact
      values;
    * ``indices`` — int32 ``(*lead, k_rows, 128)`` flat dense positions
      (``row * 128 + lane``);
    * ``scales``  — f32 ``(*lead, k_rows, 1)`` per-compact-row scales.

    Unlike the dense wire's locally synthesized unit scales, ALL three
    fields cross the wire (the receiver cannot reconstruct any of them),
    which the byte accounting prices accordingly.
    """

    values: Any
    indices: Any
    scales: Any


class RankWire(NamedTuple):
    """Wire contract of one rank-r-compressed bucket: two dense f32
    factors (``reconstruction = p @ qt``), both crossing the wire.

    * ``p``  — f32 ``(*lead, rows, r)`` orthonormal left factor;
    * ``qt`` — f32 ``(*lead, r, 128)`` right factor.

    The warm-start basis ``Q (128, r)`` is NOT part of the wire — it is
    local state carried in ``OptState.qwarm`` (like the EF residual, it
    never crosses the wire).
    """

    p: Any
    qt: Any


def _decompress_entry(entry, rows: int):
    """Compressed wire entry -> dense f32 bucket, any leading axes.

    Flattens every axis before the trailing two, maps the per-bucket
    decompressor, and restores the lead shape — the one gather-dequant
    form both execution modes (and the EF residual update) share.
    """
    from repro.kernels.consensus_update.topk import (
        rank_decompress_2d, topk_decompress_2d)

    if isinstance(entry, TopKWire):
        lead_shape = entry.values.shape[:-2]
        fn = lambda v, i, s: topk_decompress_2d(v, i, s, rows)
        args = (entry.values, entry.indices, entry.scales)
    elif isinstance(entry, RankWire):
        lead_shape = entry.p.shape[:-2]
        fn = rank_decompress_2d
        args = (entry.p, entry.qt)
    else:
        raise TypeError(f"not a compressed wire entry: {type(entry).__name__}")
    flat = [a.reshape((-1,) + a.shape[len(lead_shape):]) for a in args]
    out = jax.vmap(fn)(*flat)
    return out.reshape(lead_shape + out.shape[-2:])


def _is_compressed_entry(entry) -> bool:
    return isinstance(entry, (TopKWire, RankWire))


def _compress_wire_stacked(bufs, seed, n: int, program: MixingProgram,
                           interpret: bool, qwarm):
    """Compress agent-stacked ``(A, rows, 128)`` buckets for the wire.

    The compressed analog of :func:`_quantize_wire_stacked`: per-agent
    top-k value-SR seeds follow the SAME :func:`wire_seed` composition as
    the dense int8 wire (step/agent/bucket strides — the compact values
    are just a smaller int8 payload), so stacked and sharded trajectories
    match bit-for-bit.  Returns ``(wire, qwarm')`` where ``qwarm`` is the
    per-bucket ``(A, 128, r)`` warm-start stack of the rank compressor
    (``()`` in and out for top-k).
    """
    from repro.kernels.consensus_update import topk as tk

    kind, param = parse_compressor(program.compressor)
    if kind == "topk":
        base = _SEED_STEP_STRIDE * jnp.asarray(seed, jnp.int32)
        agent_seeds = _SEED_AGENT_STRIDE * jnp.arange(n, dtype=jnp.int32)
        out = []
        k_list = tk.topk_k_rows_for([b.shape[-2] for b in bufs], param)
        for bi, (b, k_rows) in enumerate(zip(bufs, k_list)):
            v, i, s = jax.vmap(
                lambda x, sd: tk.topk_compress_2d(x, k_rows, sd,
                                                  interpret=interpret)
            )(b.astype(jnp.float32), base + _SEED_BUCKET_STRIDE * bi
              + agent_seeds)
            out.append(TopKWire(values=v, indices=i, scales=s))
        return tuple(out), ()
    assert kind == "rank", kind
    wire, nq = [], []
    for b, q in zip(bufs, qwarm):
        p, qt, q2 = jax.vmap(tk.rank_compress_2d)(b.astype(jnp.float32), q)
        wire.append(RankWire(p=p, qt=qt))
        nq.append(q2)
    return tuple(wire), tuple(nq)


def _qwarm_init_stacked(bufs, n: int, program: MixingProgram):
    """Initial warm-start state: one ``(A, 128, r)`` orthonormal basis per
    bucket for the rank compressor, ``()`` otherwise (top-k is stateless
    beyond the EF residual)."""
    from repro.kernels.consensus_update.topk import rank_init_q

    kind, param = parse_compressor(program.compressor)
    if kind != "rank":
        return ()
    q0 = rank_init_q(param)
    return tuple(jnp.broadcast_to(q0, (n,) + q0.shape) + 0.0 for _ in bufs)


# --------------------------------------------------------------------------
# Bounded-staleness wire ring (fault-tolerant overlap schedule)
# --------------------------------------------------------------------------


class WireRing(NamedTuple):
    """Depth-``S`` generalization of the overlap schedule's wire state.

    Lives in ``OptState.wire`` exactly where the one-deep ``(payload,
    scales)`` tuple lives today (a NamedTuple, so checkpointing and the
    dependency-report labeling treat it as more wire leaves — bit-exact
    round-trips with zero checkpoint changes):

    * ``slots`` — one ``(payload, scales)`` pair per bucket x payload tree,
      with a ring axis inserted after the agent axis: ``(A, S, rows, 128)``
      stacked / ``(1, S, rows, 128)`` shard-local.  Ring index 0 is the
      agent's own freshest quantized generation (what the plain overlap
      wire carries), index ``k`` is ``k`` steps older.  Carried slots are
      never re-quantized — each generation keeps the SR bits it was born
      with, so stale consumption cannot collide with a live SR stream.
    * ``send_age`` — ``(A,)`` / ``(1,)`` int32: the ring index the agent
      *contributes* this step (its freshest generation that escaped the
      injected straggler delays; ``S`` = nothing within the ring arrived
      and receivers mask it out).  The sender selects ONE generation for
      all receivers, so the exchanged operand stays a single per-bucket
      stack and the existing self-separated kernels apply unchanged.
    * ``ages`` — ``(A, A)`` / ``(1, A)`` int32 bookkeeping: receiver row
      ``i``, the staleness-minus-1 of what sender ``j`` delivered (0 =
      normal one-step-stale; sentinel ``S`` = masked by drop/over-stale;
      diagonal 0 — the self term is always fresh).  Deterministic given
      the fault schedule; carried so checkpoints/dryruns expose the
      arrival state without re-deriving it.
    """

    slots: Tuple
    send_age: Any
    ages: Any


def _ring_select(ring: WireRing, staleness: int):
    """Sender-side slot selection: ring -> plain per-bucket wire pairs.

    Each agent contributes ``ring[min(send_age, S-1)]`` — its freshest
    arrived generation.  A fully masked sender (``send_age == S``) selects
    the oldest slot harmlessly: every receiver weights it zero.
    """
    sel = jnp.minimum(ring.send_age.astype(jnp.int32), staleness - 1)
    out = []
    for p, sc in ring.slots:
        idx = sel.reshape((-1,) + (1,) * (p.ndim - 1))
        out.append((jnp.take_along_axis(p, idx, axis=1)[:, 0],
                    jnp.take_along_axis(sc, idx, axis=1)[:, 0]))
    return tuple(out)


def _ring_push(old, new):
    """Shift one ring buffer: fresh generation in, oldest out."""
    return jnp.concatenate([new[:, None], old[:, :-1]], axis=1)


def _fault_tables(program: MixingProgram) -> dict:
    """Host-precomputed fault-path tables over the combined period.

    Everything the runtime indexes with ``step % period`` is a static
    numpy table baked into the jitted step — the fault layer adds zero
    collectives and zero device randomness, and both execution modes read
    the identical tables (:class:`~repro.core.faults.FaultSchedule` is
    seeded host-side like ``TopologySchedule``):

    * ``send_age (P, A)`` — steady state of the carried ``send_age``
      counter recurrence (valid because ``straggle[0]`` is all-False);
    * ``arrive (P, A, A)`` — receiver ``i`` uses sender ``j`` this step;
    * ``weights (P, A, A+1)`` — arrival-masked renormalized
      self-separated weights (:func:`repro.core.faults.arrival_masked_pi`
      of each schedule entry's ``Pi``);
    * ``ages (P, A, A)`` — the :class:`WireRing` bookkeeping rows.
    """
    s = program.staleness
    sched = program.schedule
    f = program.faults or trivial_faults(sched.n_agents)
    tb = f.tables(s)
    pw = int(np.lcm(sched.period, f.period))
    if pw > _MAX_FAULT_PERIOD:
        raise ValueError(
            f"combined schedule x fault period {pw} exceeds "
            f"{_MAX_FAULT_PERIOD}; align the fault period with the "
            "topology schedule period")
    ts = np.arange(pw)
    straggle = f.straggle[ts % f.period]
    send_age = tb["send_age"][ts % f.period]
    arrive = tb["arrive"][ts % f.period]
    weights = np.stack([
        _self_separated_weights(arrival_masked_pi(
            sched.topologies[t % sched.period].pi, arrive[t]))
        for t in range(pw)])
    ages = np.where(arrive, send_age[:, None, :], s).astype(np.int32)
    di = np.arange(sched.n_agents)
    ages[:, di, di] = 0
    return {"period": pw, "S": s, "straggle": straggle,
            "send_age": send_age, "arrive": arrive,
            "weights": weights, "ages": ages}


# --------------------------------------------------------------------------
# MixingStrategy: how the wire stages compose per optimizer step
# --------------------------------------------------------------------------


class MixingStrategy:
    """Base strategy: one consensus round of a (possibly step-indexed) Pi.

    Subclasses select behavior via ``rounds`` and ``_entry``; the heavy
    lifting lives in four execution-mode-specific primitives supplied by
    :func:`stacked_flat_comm` / :func:`sharded_flat_comm`:

    * ``quantize(bufs, seed) -> wire`` — packed buckets to wire state;
    * ``exchange_t(wire, t) -> (nbrs, weights_q, scales)`` — one round of
      neighbor exchange under schedule entry ``t`` (``None`` = entry 0,
      statically); in the sharded mode this is where the ``ppermute``\\ s
      (under ``lax.switch`` for time-varying schedules) happen;
    * ``combine(nbrs, weights_q, scales, selfs) -> bufs`` — the mixing sum
      in full precision, used *between* inner rounds (the final round is
      fused into the update kernel);
    * ``wire_to_bufs(wire) -> bufs_f32`` — local dequantization, used by
      the error-feedback residual update.

    The engine-facing entry points are :meth:`continue_from_wire` (rounds
    1..k given the round-1 wire — carried state under ``schedule="overlap"``,
    fresh under ``sync``) and :meth:`quantize_ef`.
    """

    name = "static"

    def __init__(self, program: MixingProgram, *, quantize, exchange_t,
                 combine, wire_to_bufs, legacy_gather=None,
                 bufs_to_state=None, state_to_bufs=None, fault_ops=None,
                 compress=None, qwarm_init=None, meta=None):
        self.program = program
        self.rounds = program.rounds
        self.mixed_momentum = program.momentum_mixing == "mixed"
        self.compressed = program.compressed
        self._quantize = quantize
        self._exchange_t = exchange_t
        self._combine = combine
        self._wire_to_bufs = wire_to_bufs
        self._legacy_gather = legacy_gather
        # biased-compressor primitives (topk/rank programs only):
        # compress(bufs, seed, qwarm) -> (wire, qwarm'), and the
        # qwarm initializer; the shared ``meta`` dict carries the static
        # dense bucket row counts the decompressors need (set on every
        # bufs-seeing call — the compact top-k payload alone cannot
        # recover the dense shape)
        self._compress = compress
        self._qwarm_init = qwarm_init
        self._meta = meta if meta is not None else {}
        # execution-mode-specific fault-path closures (None = fault-free;
        # see stacked_flat_comm / sharded_flat_comm): masked_weights(t),
        # own_straggle(t), next_ages(t), init_state(), period, S
        self.fault_ops = fault_ops
        # residual buffers live in the optimizer state with the leading
        # agent axes kept (like the wire pairs) so sharded PartitionSpecs
        # apply; the sharded mode's packed bufs are squeezed, so these two
        # convert between the layouts (identity in the stacked mode).
        ident = lambda bufs: list(bufs)
        self._bufs_to_state = bufs_to_state or ident
        self._state_to_bufs = state_to_bufs or ident

    # -- schedule indexing --------------------------------------------------
    def _entry(self, step):
        """Schedule entry for optimizer step ``step`` (None = static 0)."""
        return None

    # -- static bucket-shape bookkeeping (compressed programs) --------------
    def _note_bufs(self, bufs):
        """Record the static dense row counts the decompressors need.

        Called on every path that sees the packed buckets *before* an
        exchange can run (quantize/compress, ``continue_from_wire``,
        residual/qwarm init) — the values are static ints fixed by the
        comm's bucket layout, so re-recording is idempotent."""
        if self.compressed:
            self._meta["rows"] = [int(b.shape[-2]) for b in bufs]

    # -- payload splitting (momentum_mixing="mixed") ------------------------
    def _quantize_payloads(self, bufs, seed):
        """Quantize the wire payload(s): params, plus the mixed momentum.

        With ``momentum_mixing="mixed"`` every bucket list the strategy
        sees is the concatenation ``params_bufs + momentum_bufs`` (equal
        halves — momentum mirrors the param spec); the momentum half draws
        its SR streams with the payload seed stride so the two payloads'
        rounding noise stays independent (see :func:`wire_seed`).
        """
        if not self.mixed_momentum:
            return tuple(self._quantize(bufs, seed))
        b = len(bufs) // 2
        assert len(bufs) == 2 * b, len(bufs)
        return (tuple(self._quantize(bufs[:b], seed))
                + tuple(self._quantize(bufs[b:], seed, payload=1)))

    # -- the FlatComm stage contract ---------------------------------------
    def quantize_stage(self, bufs, seed):
        if self.compressed:
            # reachable only from initial_wire (the x_{-1} := x_0 priming):
            # the per-step compressions all go through compress_ef — a
            # biased compressor without EF is rejected at config time.
            # The warm start consumed here is the deterministic init basis;
            # OptState.qwarm starts from the same basis, so step 0 re-runs
            # the iteration one step less warm (a quality ramp, not a
            # correctness dependency).
            self._note_bufs(bufs)
            wire, _ = self._compress(bufs, seed, self._qwarm_init(bufs))
            return wire
        return self._quantize_payloads(bufs, seed)

    def exchange_stage(self, wire, step=None):
        """One round of neighbor exchange; fault-aware when engaged.

        On the fault path ``wire`` is either the carried :class:`WireRing`
        (round 1 — the sender-selected slot is exchanged) or a freshly
        quantized plain tuple (inner multi-round rounds — a masked sender's
        live transmissions miss the whole step, so the same per-step
        arrival mask applies); either way the schedule's weights are
        replaced by the arrival-masked renormalized row(s), which is the
        *only* thing that changes about the exchanged operands — same
        ppermutes, same shapes, same kernels.
        """
        if self.fault_ops is None:
            return self._exchange_t(wire, self._entry(step))
        fo = self.fault_ops
        if step is None:
            raise ValueError("fault-tolerant mixing needs the optimizer "
                             "step; exchange_stage(wire, step)")
        t = jnp.mod(jnp.asarray(step, jnp.int32), fo["period"])
        if isinstance(wire, WireRing):
            wire = _ring_select(wire, fo["S"])
        nbrs, _w, scs = self._exchange_t(wire, self._entry(step))
        return nbrs, fo["masked_weights"](t), scs

    def combine(self, nbrs, weights_q, scales, selfs):
        return self._combine(nbrs, weights_q, scales, selfs)

    # -- carried wire state (schedule="overlap") ----------------------------
    def advance_wire(self, bufs, old_wire, step):
        """Produce the wire state step ``step + 1`` will consume.

        Fault-free: exactly today's double-buffer — quantize the current
        buckets, drop the old wire.  Fault path: push the fresh generation
        into the :class:`WireRing` and advance the age counters by the
        recurrence whose steady state is the precomputed ``send_age``
        table (``a' = min(a + 1, S)`` while straggling, else 0) — asserted
        equal in tests, and load-bearing for the sender's slot selection.
        """
        fresh = self.quantize_stage(bufs, step)
        if self.fault_ops is None:
            return fresh
        fo = self.fault_ops
        slots = tuple((_ring_push(op, p), _ring_push(osc, sc))
                      for (op, osc), (p, sc) in zip(old_wire.slots, fresh))
        t1 = jnp.mod(jnp.asarray(step, jnp.int32) + 1, fo["period"])
        send_age = jnp.where(
            fo["own_straggle"](t1),
            jnp.minimum(old_wire.send_age + 1, fo["S"]),
            0).astype(jnp.int32)
        return WireRing(slots=slots, send_age=send_age,
                        ages=fo["next_ages"](t1))

    def initial_wire(self, bufs):
        """Wire state priming step 0 (the ``x_{-1} := x_0`` convention).

        Fault path: the seed ``-1`` generation *replicated* across the ring
        slots (replication, not re-quantization — one SR draw, copied), so
        whichever slot a straggler schedule selects early on carries the
        same bits today's overlap init would.  ``send_age`` starts 0 for
        everyone: ``straggle[0]`` is all-False by construction ("step 0
        publishes"), so the counters match the steady-state tables from
        the very first step.
        """
        wire = self.quantize_stage(bufs, jnp.int32(-1))
        if self.fault_ops is None:
            return wire
        fo = self.fault_ops
        slots = tuple((jnp.repeat(p[:, None], fo["S"], axis=1),
                       jnp.repeat(sc[:, None], fo["S"], axis=1))
                      for p, sc in wire)
        send_age, ages = fo["init_state"]()
        return WireRing(slots=slots, send_age=send_age, ages=ages)

    def continue_from_wire(self, bufs, wire, step):
        """Rounds 1..k of the per-step pipeline, round 1 from ``wire``.

        ``wire`` is either the freshly quantized current params (sync) or
        the carried one-step-stale buffer (overlap — only round 1 consumes
        it; rounds 2..k re-quantize the partially mixed buffers and stay on
        the grad->update critical path).  Returns the final round's kernel
        operands ``(nbrs, weights, scales, selfs)`` where ``selfs`` is the
        round-(k-1) mixed buffer (the fused kernel applies round k +
        gradient in one launch).  Inner rounds run under ``lax.scan``.
        """
        self._note_bufs(bufs)
        nbrs, w, sc = self.exchange_stage(wire, step)
        if self.rounds == 1:
            return nbrs, w, sc, list(bufs)
        b = self._combine(nbrs, w, sc, bufs)              # round 1
        if self.rounds > 2:
            step_i = jnp.asarray(step, jnp.int32)
            seeds = step_i + _SEED_ROUND_STRIDE * jnp.arange(
                1, self.rounds - 1, dtype=jnp.int32)

            def round_body(carry, seed_r):
                wire_r = self._quantize_payloads(list(carry), seed_r)
                nb, wr, scr = self.exchange_stage(wire_r, step)
                return tuple(self._combine(nb, wr, scr, list(carry))), None

            b, _ = lax.scan(round_body, tuple(b), seeds)
            b = list(b)
        seed_k = jnp.asarray(step, jnp.int32) + \
            _SEED_ROUND_STRIDE * (self.rounds - 1)
        wire_k = self._quantize_payloads(b, seed_k)
        nbrs, w, sc = self.exchange_stage(wire_k, step)
        return nbrs, w, sc, list(b)

    def gather(self, bufs, seed):
        """One-shot sync form: quantize current params, run all rounds."""
        if self._legacy_gather is not None and self.program.is_trivial:
            # bit-for-bit the pre-strategy path (incl. the dense-weight
            # unquantized stacked form)
            return self._legacy_gather(bufs, seed)
        wire = self._quantize_payloads(bufs, seed)
        return self.continue_from_wire(bufs, wire, seed)

    # -- error feedback -----------------------------------------------------
    def quantize_ef(self, bufs, seed, residual):
        """EF-compress the round-1 wire payload: ``Q(x + e)``.

        Returns ``(wire, new_residual)`` with ``new_residual = (x + e) -
        dequant(Q(x + e))`` — the compression error carried to the next
        step so quantization noise telescopes instead of accumulating
        (Seide et al. 2014 / Karimireddy et al. 2019).  The residual is
        f32, never crosses the wire, and applies to the round-1 payload(s)
        only; inner multi-round payloads are fresh each step and use plain
        stochastic rounding.  With ``momentum_mixing="mixed"`` the
        residual list has one buffer per bucket per payload (params first,
        momentum second) and each payload's compression error telescopes
        independently.
        """
        res = self._state_to_bufs(residual)
        carried = [b.astype(jnp.float32) + e for b, e in zip(bufs, res)]
        wire = self._quantize_payloads(carried, seed)
        deq = self._wire_to_bufs(wire)
        new_residual = tuple(self._bufs_to_state(
            [c - d for c, d in zip(carried, deq)]))
        return wire, new_residual

    def compress_ef(self, bufs, seed, residual, qwarm):
        """The compressor-axis generalization of :meth:`quantize_ef`.

        ``C(x + e)`` for whatever compressor the program carries, threading
        the warm-start state of the rank compressor: returns ``(wire,
        new_residual, new_qwarm)``.  Dense programs delegate to
        :meth:`quantize_ef` and pass ``qwarm`` through untouched, so the
        engine calls this unconditionally at both EF sites.  For the biased
        compressors the residual update uses the same gather-dequant
        decompression the receivers apply — ``new_residual = (x + e) -
        decompress(C(x + e))`` — which is exactly what makes the
        delta-contraction of the EF bound hold
        (:func:`repro.core.lyapunov.ef_compressed_consensus_bound`).
        """
        if not self.compressed:
            wire, new_residual = self.quantize_ef(bufs, seed, residual)
            return wire, new_residual, qwarm
        self._note_bufs(bufs)
        res = self._state_to_bufs(residual)
        carried = [b.astype(jnp.float32) + e for b, e in zip(bufs, res)]
        wire, new_qwarm = self._compress(carried, seed, qwarm)
        deq = self._wire_to_bufs(wire)
        new_residual = tuple(self._bufs_to_state(
            [c - d for c, d in zip(carried, deq)]))
        return wire, new_residual, new_qwarm

    def residual_init(self, bufs):
        """Zero-initialized f32 residuals, one per packed bucket (leading
        agent axes kept, matching the wire state's layout)."""
        self._note_bufs(bufs)
        return tuple(self._bufs_to_state(
            [jnp.zeros(b.shape, jnp.float32) for b in bufs]))

    def qwarm_init(self, bufs):
        """Initial compressor warm-start state for ``OptState.qwarm``:
        the rank compressor's per-bucket orthonormal basis (leading agent
        axes kept, like the wire/residual), ``()`` for everything else."""
        if not self.compressed:
            return ()
        self._note_bufs(bufs)
        return self._qwarm_init(bufs)

    # -- wire-byte pricing (the single accounting source) -------------------
    def bytes_per_neighbor(self, spec: "flatbuf.FlatSpec") -> int:
        """Bytes ONE whole-model neighbor transfer moves under this
        program — dense, quantized, and compressed payloads priced in one
        place (:func:`program_bytes_per_neighbor`); `exchange_bytes_per_
        step`, the trainer/dryrun printouts, and the microbench all quote
        this, and ``repro.core.engine.wire_bytes_per_neighbor`` asserts it
        against the actual carried buffers."""
        return program_bytes_per_neighbor(spec, self.program)


class StaticMixing(MixingStrategy):
    """The paper's fixed ``Pi``, one round — bit-for-bit the legacy path."""

    name = "static"


class TimeVaryingMixing(MixingStrategy):
    """``Pi_t = schedule[t % period]`` selected by the optimizer step.

    Stacked mode: the dense self-separated weights are indexed out of a
    ``(T, A, A+1)`` stack.  Sharded mode: each entry's circulant shift set
    is its own ``lax.switch`` branch of ``ppermute``\\ s (padded to the
    union stencil with zero-weight slots), so a step only pays its own
    entry's collectives.
    """

    name = "time_varying"

    def __init__(self, program, **kw):
        super().__init__(program, **kw)
        self._period = program.schedule.period

    def _entry(self, step):
        if step is None:
            raise ValueError("TimeVaryingMixing needs the optimizer step to "
                             "select Pi_t; exchange_stage(wire, step)")
        return jnp.mod(jnp.asarray(step, jnp.int32), self._period)


class MultiRoundMixing(MixingStrategy):
    """``rounds`` inner consensus rounds per gradient step (i-CDSGD).

    ``x' = Pi^k x - alpha g``: rounds 1..k-1 mix in full precision between
    re-quantizations (``lax.scan``), round k is fused into the update
    kernel.  Wire cost is exactly ``k x`` the single-round bytes.
    ``MultiRoundMixing`` with ``rounds=1`` is never constructed — the
    factories return :class:`StaticMixing` (identical by definition).
    """

    name = "multi_round"


def _make_strategy(program: MixingProgram, **prims) -> MixingStrategy:
    if program.strategy == "time_varying":
        return TimeVaryingMixing(program, **prims)
    if program.strategy == "multi_round" and program.rounds > 1:
        return MultiRoundMixing(program, **prims)
    return StaticMixing(program, **prims)


def _self_separated_weights(pi: np.ndarray) -> np.ndarray:
    """``[diag(Pi) | zero-diag Pi]`` — the quantized-form (A, A+1) weights."""
    n = pi.shape[0]
    pi = np.asarray(pi, np.float64)
    return np.concatenate([np.diag(pi)[:, None],
                           pi * (1.0 - np.eye(n))], axis=1)


def stacked_flat_comm(topology: Topology, *, interpret: bool = True,
                      exchange: str = "f32",
                      program: Optional[MixingProgram] = None) -> FlatComm:
    """FlatComm for agent-stacked pytrees (dense ``Pi``, any topology).

    Quantized exchanges quantize the agent stack once (per-agent seeds
    matching the sharded path's ``axis_index``-derived seeds) and return
    the native-precision stack as ``selfs``: agent ``j`` mixes its own
    exact parameters at ``weights[j, 0] = Pi[j, j]`` and the dequantized
    wire payloads of everyone else (``weights[j, 1:] = Pi[j, :]`` with the
    diagonal zeroed) — exactly what the sharded exchange delivers, where
    the self buffer never crosses the wire.

    ``program`` selects the mixing strategy (default: the trivial static
    program over ``topology``); its schedule entries supply the per-step
    ``Pi_t`` of a time-varying strategy.
    """
    if program is None:
        program = make_mixing_program(topology, exchange=exchange)
    exchange = _check_exchange(program.exchange)
    schedule = program.schedule
    pi = jnp.asarray(schedule.topologies[0].pi, dtype=jnp.float32)
    n = schedule.n_agents
    # quantized-form weights per schedule entry: [diag | off-diag], (T, A, A+1)
    pi_q_stack = jnp.asarray(
        np.stack([_self_separated_weights(t.pi) for t in schedule.topologies]),
        jnp.float32)
    period = schedule.period

    meta: dict = {}

    def _rows_of(bi: int) -> int:
        rows = meta.get("rows")
        if rows is None:
            raise RuntimeError(
                "compressed exchange before any bufs-seeing stage: call "
                "quantize_stage/compress_ef (or continue_from_wire) once so "
                "the strategy records the dense bucket row counts")
        return rows[bi]

    def quantize(bufs, seed, payload=0):
        return _quantize_wire_stacked(bufs, seed, n, exchange, interpret,
                                      payload=payload)

    def compress(bufs, seed, qwarm):
        return _compress_wire_stacked(bufs, seed, n, program, interpret,
                                      qwarm)

    def qwarm_init(bufs):
        return _qwarm_init_stacked(bufs, n, program)

    def exchange_t(wire, t):
        # stacked simulation: every agent already sees the full stack — the
        # "exchange" is handing the wire payloads to the kernels with the
        # self-separated [diag(Pi_t) | zero-diag Pi_t] weights.  Compressed
        # entries decompress to dense f32 stacks with unit scales (the
        # kernels' in-register dequant multiply becomes the identity) and
        # feed the same self-separated path — the self term never crossed
        # the wire and stays full precision at weights[..., 0].
        if t is None or period == 1:
            w = pi_q_stack[0]
        else:
            w = jnp.take(pi_q_stack, t, axis=0)
        nbrs, scs = [], []
        for bi, e in enumerate(wire):
            if isinstance(e, TopKWire) and program.sparse_update:
                # sparse operand form: hand the compact wire fields to the
                # *_update_sparse_2d kernels untouched — no dense
                # decompressed stack is ever materialized.  scales ride
                # inside the SparseNeighbors tuple (scs entry None).
                from repro.kernels.consensus_update.ops import SparseNeighbors
                nbrs.append(SparseNeighbors(e.values, e.indices, e.scales))
                scs.append(None)
            elif _is_compressed_entry(e):
                d = _decompress_entry(e, _rows_of(bi))
                nbrs.append(d)
                scs.append(jnp.ones(d.shape[:-1] + (1,), jnp.float32))
            else:
                nbrs.append(e[0])
                scs.append(e[1])
        return nbrs, w, scs

    def wire_to_bufs(wire):
        return [_decompress_entry(e, _rows_of(bi)) if _is_compressed_entry(e)
                else e[0].astype(jnp.float32) * e[1]
                for bi, e in enumerate(wire)]

    def combine(nbrs, weights_q, scales, selfs):
        """Full-precision one-round mix of the agent stack (inner rounds).

        ``mixed_j = w[j,0] self_j + sum_l w[j,1+l] dequant(payload_l)`` —
        the same sum the fused kernels evaluate, materialized because the
        next round re-quantizes it.
        """
        out = []
        for p, sc, sf in zip(nbrs, scales, selfs):
            deq = p.astype(jnp.float32) * sc              # (A, rows, 128)
            mixed = jnp.einsum("jl,lrc->jrc", weights_q[:, 1:], deq)
            mixed = mixed + weights_q[:, :1, None] * sf.astype(jnp.float32)
            out.append(mixed.astype(sf.dtype))
        return out

    def legacy_gather(bufs, seed):
        if exchange in ("f32", "bf16"):
            return ([_wire_payload(b, None, exchange, interpret)[0] for b in bufs],
                    pi, [None] * len(bufs), [None] * len(bufs))
        nbrs, w, scales = exchange_t(quantize(bufs, seed), None)
        return nbrs, w, scales, list(bufs)

    fault_ops = None
    if program.fault_tolerant:
        ft = _fault_tables(program)
        w_masked = jnp.asarray(ft["weights"], jnp.float32)    # (P, A, A+1)
        straggle_t = jnp.asarray(ft["straggle"])              # (P, A) bool
        ages_t = jnp.asarray(ft["ages"], jnp.int32)           # (P, A, A)
        fault_ops = {
            "period": ft["period"], "S": ft["S"],
            "masked_weights": lambda t: jnp.take(w_masked, t, axis=0),
            "own_straggle": lambda t: jnp.take(straggle_t, t, axis=0),
            "next_ages": lambda t: jnp.take(ages_t, t, axis=0),
            "init_state": lambda: (jnp.zeros((n,), jnp.int32), ages_t[0]),
        }

    strategy = _make_strategy(program, quantize=quantize, exchange_t=exchange_t,
                              combine=combine, wire_to_bufs=wire_to_bufs,
                              legacy_gather=legacy_gather, fault_ops=fault_ops,
                              compress=compress, qwarm_init=qwarm_init,
                              meta=meta)

    return FlatComm(lead=1, batched=True, gather=strategy.gather,
                    interpret=interpret, exchange=exchange, n_agents=n,
                    quantize_stage=strategy.quantize_stage,
                    exchange_stage=strategy.exchange_stage,
                    strategy=strategy, program=program)


def sharded_flat_comm(factors: Sequence[Tuple[str, Topology]], *,
                      lead: int = 1, interpret: bool = True,
                      exchange: str = "f32",
                      program: Optional[MixingProgram] = None) -> FlatComm:
    """FlatComm for use inside ``shard_map``; circulant topologies only.

    ``factors`` is ``[(axis_name, Topology), ...]`` — one entry for the
    plain single-axis agent mesh, several for a Kronecker-factored one.
    Each bucket costs one ``lax.ppermute`` per non-zero shift combination;
    weights are the (outer-)product of the per-factor circulant weights.

    With a quantized ``exchange`` each agent quantizes its bucket ONCE and
    every non-identity shift permutes the int8/fp8 payload plus its
    ``(rows, 1)`` row scales — ~3.9x fewer bytes per shift than the f32
    wire; the self term (the identity shift) stays in native precision
    since it moves no data.

    A time-varying ``program`` (single agent axis only) compiles one
    ``lax.switch`` branch per schedule entry: branch ``t`` issues only
    entry ``t``'s circulant ``ppermute``\\ s, padding the neighbor stack to
    the union stencil with zero slots (whose weights are zero in that
    entry's weight row).
    """
    import itertools

    if program is not None:
        exchange = program.exchange
    _check_exchange(exchange)

    def _axis_data(per_factor):
        """[(axis, n, sorted shift items)] for one schedule entry."""
        out = []
        for axis_name, topo in per_factor:
            if topo.n_agents == 1:
                continue
            shifts = topo.shift_weights()
            if shifts is None:
                raise ValueError(
                    f"topology {topo.name!r} on axis {axis_name!r} is not "
                    "circulant; use mixing='ppermute' or 'dense' instead")
            out.append((axis_name, topo.n_agents, sorted(shifts.items())))
        return out

    time_varying = program is not None and program.strategy == "time_varying"
    if time_varying:
        live = [(a, t) for a, t in factors if t.n_agents > 1]
        if len(live) != 1:
            raise ValueError(
                "time-varying mixing supports a single agent mesh axis "
                f"(got {[a for a, _ in factors]}); factored multi-axis "
                "meshes need per-axis schedules, which are not implemented")
        axis_name = live[0][0]
        entries = [_axis_data([(axis_name, t)])
                   for t in program.schedule.topologies]
    else:
        entries = [_axis_data(factors)]

    # per-entry shift combinations; the wire stencil is their union so every
    # schedule entry returns identically shaped operands (lax.switch).
    def _combos(per_axis):
        return list(itertools.product(*[s for _, _, s in per_axis])) or [()]

    def _combo_weight(combo):
        return float(np.prod([w for _, w in combo]) if combo else 1.0)

    def _is_identity(per_axis, combo):
        return all(s % nn == 0 for (_, nn, _), (s, _w) in zip(per_axis, combo))

    def _combo_key(per_axis, combo):
        return tuple((ax, s % nn) for (ax, nn, _), (s, _w)
                     in zip(per_axis, combo))

    # union stencil over entries, keyed by (axis, shift mod n)
    union_keys: list = []
    entry_wire: list = []      # per entry: {key: (per_axis_index->shift, weight)}
    entry_selfw: list = []
    for per_axis in entries:
        wire_map = {}
        selfw = 0.0
        for c in _combos(per_axis):
            if _is_identity(per_axis, c):
                selfw += _combo_weight(c)
            else:
                k = _combo_key(per_axis, c)
                wire_map[k] = (per_axis, c, _combo_weight(c))
                if k not in union_keys:
                    union_keys.append(k)
        entry_wire.append(wire_map)
        entry_selfw.append(selfw)
    union_keys = sorted(union_keys)

    # (T, 1 + U) self-separated weights; zero where an entry lacks a shift
    weights_q_stack = jnp.asarray(
        [[sw] + [wm[k][2] if k in wm else 0.0 for k in union_keys]
         for sw, wm in zip(entry_selfw, entry_wire)], jnp.float32)

    # legacy single-entry views (static path keeps today's exact layout)
    per_axis0 = entries[0]
    combos0 = _combos(per_axis0)
    weights = jnp.asarray([_combo_weight(c) for c in combos0], jnp.float32)
    wire_combos0 = [c for c in combos0 if not _is_identity(per_axis0, c)]
    weights_q = weights_q_stack[0]

    def _agent_index():
        """Linearized agent index — matches the stacked topology order."""
        idx = jnp.int32(0)
        for axis_name, nn, _ in per_axis0:
            idx = idx * nn + lax.axis_index(axis_name).astype(jnp.int32)
        return idx

    def _shift_all(x, per_axis, combo):
        for (axis_name, nn, _), (s, _w) in zip(per_axis, combo):
            if s % nn:
                # agent j receives from agent (j + s) mod n
                perm = [((j + s) % nn, j) for j in range(nn)]
                x = lax.ppermute(x, axis_name, perm=perm)
        return x

    quantized = exchange in ("int8", "fp8") and union_keys
    n_total = int(np.prod([t.n_agents for _, t in factors])) if factors else 1

    meta: dict = {}

    def _rows_of(bi: int) -> int:
        rows = meta.get("rows")
        if rows is None:
            raise RuntimeError(
                "compressed exchange before any bufs-seeing stage: call "
                "quantize_stage/compress_ef (or continue_from_wire) once so "
                "the strategy records the dense bucket row counts")
        return rows[bi]

    def _restore_lead(a):
        return a.reshape((1,) * lead + a.shape)

    def compress(bufs, seed, qwarm):
        """Local squeezed buckets -> compressed wire entries (lead axes
        restored, like ``quantize``).  Top-k value-SR seeds derive from
        ``lax.axis_index`` with the same :func:`wire_seed` composition as
        the stacked path; the rank factors draw no randomness."""
        from repro.kernels.consensus_update import topk as tk

        kind, param = parse_compressor(program.compressor)
        if kind == "topk":
            base = _SEED_STEP_STRIDE * jnp.asarray(seed, jnp.int32) \
                + _SEED_AGENT_STRIDE * _agent_index()
            out = []
            k_list = tk.topk_k_rows_for([b.shape[-2] for b in bufs], param)
            for bi, (b, k_rows) in enumerate(zip(bufs, k_list)):
                v, i, s = tk.topk_compress_2d(
                    b.astype(jnp.float32), k_rows,
                    base + _SEED_BUCKET_STRIDE * bi, interpret=interpret)
                out.append(TopKWire(values=_restore_lead(v),
                                    indices=_restore_lead(i),
                                    scales=_restore_lead(s)))
            return tuple(out), ()
        assert kind == "rank", kind
        wire, nq = [], []
        for b, q in zip(bufs, qwarm):
            p, qt, q2 = tk.rank_compress_2d(b.astype(jnp.float32),
                                            q.reshape(q.shape[lead:]))
            wire.append(RankWire(p=_restore_lead(p), qt=_restore_lead(qt)))
            nq.append(_restore_lead(q2))
        return tuple(wire), tuple(nq)

    def qwarm_init(bufs):
        from repro.kernels.consensus_update.topk import rank_init_q

        kind, param = parse_compressor(program.compressor)
        if kind != "rank":
            return ()
        q0 = rank_init_q(param)
        return tuple(_restore_lead(q0) for _ in bufs)

    def quantize(bufs, seed, payload=0):
        """Local squeezed buckets -> wire state (lead axes restored).

        Runs inside ``shard_map``: the returned pairs carry the size-1
        local agent axes so the wire state round-trips through sharded
        optimizer-state PartitionSpecs unchanged.  ``payload`` selects the
        SR stream of the second (mixed momentum) payload tree.
        """
        base = _SEED_STEP_STRIDE * jnp.asarray(seed, jnp.int32) \
            + jnp.int32(_SEED_PAYLOAD_STRIDE * payload)
        if exchange in ("int8", "fp8"):
            base = base + _SEED_AGENT_STRIDE * _agent_index()
        out = []
        for bi, b in enumerate(bufs):
            if exchange in ("int8", "fp8"):
                p, sc = _wire_payload(b, base + _SEED_BUCKET_STRIDE * bi,
                                      exchange, interpret)
            else:
                p, _ = _wire_payload(b, None, exchange, interpret)
                sc = jnp.ones(b.shape[:-1] + (1,), jnp.float32)
            out.append((p.reshape((1,) * lead + p.shape),
                        sc.reshape((1,) * lead + sc.shape)))
        return tuple(out)

    def _entry_branch(entry_idx: int):
        """Exchange branch for one schedule entry: its own ppermutes only,
        padded to the union stencil with zero slots.

        Compressed entries (:class:`TopKWire` / :class:`RankWire`) shift
        every compact field through the SAME ppermutes — the only arrays
        that cross the wire are the compact payloads — then decompress
        per arrived stencil slot into a dense f32 neighbor tile with unit
        scales, feeding the fused kernels' self-separated path unchanged.
        """
        wm = entry_wire[entry_idx]

        def branch(wire):
            from repro.kernels.consensus_update.ops import SparseNeighbors

            nbrs, scs = [], []
            for bi, e in enumerate(wire):
                if isinstance(e, TopKWire) and program.sparse_update:
                    # sparse operand form: the ppermuted compact fields
                    # feed the *_update_sparse_2d kernels unchanged; an
                    # absent union slot ships all-zero values (dequant 0.0
                    # — and its weight is zero in this entry's row anyway)
                    local = jax.tree.map(
                        lambda a: a.reshape(a.shape[lead:]), e)
                    slots = []
                    for k in union_keys:
                        if k in wm:
                            per_axis, combo, _w = wm[k]
                            slots.append(jax.tree.map(
                                lambda a: _shift_all(a, per_axis, combo),
                                local))
                        else:
                            slots.append(jax.tree.map(jnp.zeros_like, local))
                    nbrs.append(SparseNeighbors(
                        *(jnp.stack([getattr(s, f) for s in slots])
                          for f in SparseNeighbors._fields)))
                    scs.append(None)
                    continue
                if _is_compressed_entry(e):
                    rows = _rows_of(bi)
                    local = jax.tree.map(
                        lambda a: a.reshape(a.shape[lead:]), e)
                    stack = []
                    for k in union_keys:
                        if k in wm:
                            per_axis, combo, _w = wm[k]
                            shifted = jax.tree.map(
                                lambda a: _shift_all(a, per_axis, combo),
                                local)
                            stack.append(_decompress_entry(shifted, rows))
                        else:
                            stack.append(
                                jnp.zeros((rows, flatbuf.LANE), jnp.float32))
                    nbrs.append(jnp.stack(stack))
                    scs.append(jnp.ones((len(union_keys), rows, 1),
                                        jnp.float32))
                    continue
                p, sc = e
                p = p.reshape(p.shape[lead:])
                sc = sc.reshape(sc.shape[lead:])
                stack, sstack = [], []
                for k in union_keys:
                    if k in wm:
                        per_axis, combo, _w = wm[k]
                        stack.append(_shift_all(p, per_axis, combo))
                        sstack.append(_shift_all(sc, per_axis, combo)
                                      if quantized else sc)
                    else:
                        stack.append(jnp.zeros_like(p))
                        sstack.append(jnp.zeros_like(sc) if quantized else sc)
                nbrs.append(jnp.stack(stack))
                scs.append(jnp.stack(sstack))
            return tuple(nbrs), tuple(scs)

        return branch

    branches = [_entry_branch(i) for i in range(len(entries))]

    def exchange_t(wire, t):
        """Wire state -> (neighbor stacks, weights_q, scale stacks).

        One ``lax.ppermute`` per non-identity shift combination for the
        payload, plus one for the row scales when the wire is quantized
        (f32/bf16 wires carry unit scales, which are shift-invariant — the
        kernels' dequant operand is synthesized locally, no collective);
        the self term never moves.  The wire may be one optimizer step
        stale (``schedule="overlap"``) — nothing here reads the current
        params or gradients.  ``t`` (traced) switches between the schedule
        entries' shift sets; ``None`` / period 1 runs entry 0 directly.
        """
        if not union_keys:
            raise ValueError("exchange_stage needs at least one wire-crossing "
                             "shift (topology has no neighbors)")
        if t is None or len(entries) == 1:
            nbrs, scs = branches[0](wire)
            return list(nbrs), weights_q, list(scs)
        t = jnp.asarray(t, jnp.int32)
        nbrs, scs = lax.switch(t, branches, wire)
        return list(nbrs), jnp.take(weights_q_stack, t, axis=0), list(scs)

    def wire_to_bufs(wire):
        out = []
        for bi, e in enumerate(wire):
            if _is_compressed_entry(e):
                local = jax.tree.map(lambda a: a.reshape(a.shape[lead:]), e)
                out.append(_decompress_entry(local, _rows_of(bi)))
            else:
                p, sc = e
                out.append(p.reshape(p.shape[lead:]).astype(jnp.float32)
                           * sc.reshape(sc.shape[lead:]))
        return out

    def bufs_to_state(bufs):
        return [b.reshape((1,) * lead + b.shape) for b in bufs]

    def state_to_bufs(state):
        return [b.reshape(b.shape[lead:]) for b in state]

    def combine(nbrs, w, scs, selfs):
        """Full-precision one-round mix of the local shard (inner rounds)."""
        out = []
        for p, sc, sf in zip(nbrs, scs, selfs):
            deq = p.astype(jnp.float32) * sc              # (U, rows, 128)
            mixed = jnp.tensordot(w[1:], deq, axes=1)
            mixed = mixed + w[0] * sf.astype(jnp.float32)
            out.append(mixed.astype(sf.dtype))
        return out

    def legacy_gather(bufs, seed):
        if not (quantized and wire_combos0):
            stacked = []
            for b in bufs:
                payload, _ = _wire_payload(b, None, exchange if exchange == "bf16"
                                           else "f32", interpret)
                stacked.append(jnp.stack(
                    [_shift_all(payload, per_axis0, c) for c in combos0]))
            return stacked, weights, [None] * len(bufs), [None] * len(bufs)
        nbrs, w, scs = exchange_t(quantize(bufs, seed), None)
        return nbrs, w, scs, list(bufs)

    if program is None:
        program = make_mixing_program(
            factors[0][1] if len(factors) == 1 else
            Topology(name="factored", pi=_factored_pi(factors)),
            exchange=exchange)

    fault_ops = None
    if program.fault_tolerant:
        live = [(a, t) for a, t in factors if t.n_agents > 1]
        if len(live) != 1:
            raise ValueError(
                "fault-tolerant mixing supports a single agent mesh axis "
                f"(got {[a for a, _ in factors]}); factored multi-axis "
                "meshes need per-axis fault schedules, not implemented")
        nn = live[0][1].n_agents
        ft = _fault_tables(program)
        # per-agent masked weight rows in the union-stencil layout: slot k
        # at agent i receives from sender (i + shift_k) mod n, so the
        # dense arrival mask folds into a (P, A, 1+U) table exactly the
        # way _self_separated_weights folds the dense Pi
        sched_period = program.schedule.period
        wtab = np.zeros((ft["period"], nn, 1 + len(union_keys)))
        for t in range(ft["period"]):
            e = (t % sched_period) if time_varying else 0
            wm = entry_wire[e]
            for i in range(nn):
                self_w = entry_selfw[e]
                for ki, k in enumerate(union_keys):
                    if k not in wm:
                        continue
                    sender = (i + k[0][1]) % nn
                    if ft["arrive"][t, i, sender]:
                        wtab[t, i, 1 + ki] = wm[k][2]
                    else:
                        self_w += wm[k][2]
                wtab[t, i, 0] = self_w
        w_masked = jnp.asarray(wtab, jnp.float32)
        straggle_t = jnp.asarray(ft["straggle"])
        ages_t = jnp.asarray(ft["ages"], jnp.int32)
        fault_ops = {
            "period": ft["period"], "S": ft["S"],
            "masked_weights":
                lambda t: jnp.take(w_masked, t, axis=0)[_agent_index()],
            "own_straggle":
                lambda t: jnp.take(straggle_t, t, axis=0)[_agent_index()],
            "next_ages":
                lambda t: jnp.take(ages_t, t, axis=0)[_agent_index()][None],
            "init_state":
                lambda: (jnp.zeros((1,), jnp.int32),
                         ages_t[0][_agent_index()][None]),
        }

    strategy = _make_strategy(program, quantize=quantize, exchange_t=exchange_t,
                              combine=combine, wire_to_bufs=wire_to_bufs,
                              legacy_gather=legacy_gather,
                              bufs_to_state=bufs_to_state,
                              state_to_bufs=state_to_bufs,
                              fault_ops=fault_ops,
                              compress=compress, qwarm_init=qwarm_init,
                              meta=meta)

    return FlatComm(lead=lead, batched=False, gather=strategy.gather,
                    interpret=interpret, exchange=exchange, n_agents=n_total,
                    quantize_stage=strategy.quantize_stage,
                    exchange_stage=strategy.exchange_stage,
                    strategy=strategy, program=program)


def _factored_pi(factors) -> np.ndarray:
    pi = np.array([[1.0]])
    for _, t in factors:
        pi = np.kron(pi, t.pi)
    return pi


def widen_with_momentum(fl: FlatComm, bufs, momentum_bufs=None):
    """THE wire-widening convention of ``momentum_mixing="mixed"``, in one
    place: the strategy-facing bucket list is ``params_bufs +
    momentum_bufs`` — equal halves, the momentum half mirroring the param
    buckets one-for-one against the same :class:`FlatSpec`.
    ``momentum_bufs=None`` appends zeros (the initializer convention:
    ``v_{-1} := v_0 = 0`` — the optimizers zero-init their momentum /
    first-moment buffers).  No-op for programs that don't mix momentum.
    """
    if fl.program is None or fl.program.momentum_mixing != "mixed":
        assert momentum_bufs is None, "momentum payload without a mixed program"
        return list(bufs)
    if momentum_bufs is None:
        momentum_bufs = [jnp.zeros_like(b) for b in bufs]
    assert len(momentum_bufs) == len(bufs), (len(momentum_bufs), len(bufs))
    return list(bufs) + list(momentum_bufs)


def initial_wire_state(fl: FlatComm, params: PyTree) -> tuple:
    """Wire state priming the ``schedule="overlap"`` double-buffer.

    The overlap schedule exchanges the *previous* step's quantized buckets;
    before step 0 there is no previous step, so the convention is
    ``x_{-1} := x_0``: quantize the initial params with seed ``-1`` (the
    per-step stages use the optimizer step ``>= 0``, so the stream never
    collides).  Computed on the *global* agent-stacked view — usable
    outside ``shard_map`` — with per-agent seeds identical to what the
    sharded ``axis_index``-seeded quantize stage produces, so both
    execution modes start from the same wire bits.

    For a *sharded* comm this global path assumes the packed layout equals
    the per-device layout — true only when params shard over no non-agent
    mesh axis; the sharded trainer instead initializes per shard with
    :func:`repro.core.engine.make_local_wire_init` inside ``shard_map``.
    """
    if fl.quantize_stage is None:
        raise ValueError("FlatComm has no quantize stage; overlap needs the "
                         "staged flat-buffer comm")
    if fl.lead != 1:
        raise ValueError("overlap wire state assumes one leading agent axis")
    spec = flatbuf.make_flat_spec(params, lead=fl.lead)
    bufs = widen_with_momentum(fl, flatbuf.pack(params, spec))
    seed = jnp.int32(-1)
    if fl.batched:
        # the strategy's initial_wire wraps the seed -1 generation into a
        # WireRing on the fault path (plain quantize_stage otherwise)
        if fl.strategy is not None:
            return fl.strategy.initial_wire(bufs)
        return fl.quantize_stage(bufs, seed)
    # sharded comm, global agent-stacked view: the strategy's quantize is
    # the shard-local one, so replay _quantize_payloads' split on the
    # global quantizer (payload 1 = the momentum half's seed stride)
    if fl.program is not None and fl.program.compressed:
        # compressed wires: replay the stacked compressor with seed -1 and
        # the same per-agent seed composition as the sharded compress;
        # warm-start output discarded (initial_qwarm_state is the basis)
        wire, _ = _compress_wire_stacked(
            bufs, seed, fl.n_agents, fl.program, fl.interpret,
            _qwarm_init_stacked(bufs, fl.n_agents, fl.program))
        return wire
    mixed = fl.program is not None and fl.program.momentum_mixing == "mixed"
    b = len(bufs) // 2 if mixed else len(bufs)
    wire = _quantize_wire_stacked(bufs[:b], seed, fl.n_agents, fl.exchange,
                                  fl.interpret)
    if mixed:
        wire = tuple(wire) + tuple(_quantize_wire_stacked(
            bufs[b:], seed, fl.n_agents, fl.exchange, fl.interpret, payload=1))
    if fl.program is not None and fl.program.fault_tolerant:
        # global view of the per-shard ring init: replicate the seed -1
        # generation across the ring, age counters at their step-0 tables
        ft = _fault_tables(fl.program)
        wire = WireRing(
            slots=tuple((jnp.repeat(p[:, None], ft["S"], axis=1),
                         jnp.repeat(sc[:, None], ft["S"], axis=1))
                        for p, sc in wire),
            send_age=jnp.zeros((fl.n_agents,), jnp.int32),
            ages=jnp.asarray(ft["ages"][0], jnp.int32))
    return wire


def initial_residual_state(fl: FlatComm, params: PyTree) -> tuple:
    """Zero error-feedback residuals for the global agent-stacked view.

    One f32 buffer per flat bucket, shaped like the packed params (leading
    agent axis kept).  The sharded trainer initializes per shard instead
    (:func:`repro.core.engine.make_local_residual_init`) because the local
    flat layout differs whenever params shard over non-agent axes — for
    zeros only the shapes differ, but the shapes are exactly what the
    optimizer-state PartitionSpecs must match.  Both paths build the
    buffers through the same ``MixingStrategy.residual_init``.
    """
    spec = flatbuf.make_flat_spec(params, lead=fl.lead)
    bufs = widen_with_momentum(fl, flatbuf.pack(params, spec))
    return fl.strategy.residual_init(bufs)


def initial_qwarm_state(fl: FlatComm, params: PyTree) -> tuple:
    """Warm-start compressor state for the global agent-stacked view.

    ``()`` unless the program runs the rank-r compressor, in which case
    one ``(A, 128, r)`` orthonormal basis per bucket — the deterministic
    :func:`repro.kernels.consensus_update.topk.rank_init_q` basis,
    identical across agents, buckets and execution modes.  Deliberately
    independent of :func:`initial_wire_state`: the seed ``-1`` priming
    compress discards its warm-start output, so the power-iteration chain
    starts from the init basis in both modes (a quality ramp, not a
    correctness dependency).  The sharded trainer initializes per shard
    via :func:`repro.core.engine.make_local_qwarm_init` instead.
    """
    if fl.program is None or not fl.program.compressed:
        return ()
    spec = flatbuf.make_flat_spec(params, lead=fl.lead)
    bufs = widen_with_momentum(fl, flatbuf.pack(params, spec))
    if fl.batched:
        return fl.strategy.qwarm_init(bufs)
    # sharded comm, global agent-stacked view: replicate the shard-local
    # init basis across the agent axis (it is agent-independent)
    return _qwarm_init_stacked(bufs, fl.n_agents, fl.program)


# --------------------------------------------------------------------------
# Stacked (dense, simulation) path
# --------------------------------------------------------------------------


def mix_stacked(pi: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """``(Pi x)_j = sum_l pi_{jl} x_l`` for ``x`` of shape (N, ...)."""
    pi = jnp.asarray(pi, dtype=jnp.float32)
    flat = x.reshape(x.shape[0], -1)
    mixed = jnp.einsum("jl,ld->jd", pi, flat.astype(jnp.float32))
    return mixed.astype(x.dtype).reshape(x.shape)


def mix_pytree_stacked(pi: jnp.ndarray, tree: PyTree) -> PyTree:
    """Apply `mix_stacked` to every leaf of an agent-stacked pytree."""
    return jax.tree.map(lambda x: mix_stacked(pi, x), tree)


def mix_pytree_list(pi: np.ndarray, trees: Sequence[PyTree]) -> list:
    """Host-level mixing of a list of per-agent pytrees (tests/benchmarks)."""
    n = len(trees)
    out = []
    for j in range(n):
        out.append(tree_weighted_sum([float(pi[j, l]) for l in range(n)], list(trees)))
    return out


# --------------------------------------------------------------------------
# Sharded (shard_map) path
# --------------------------------------------------------------------------


def _circulant_mix_leaf(x, shifts, axis_name: str, n: int):
    """sum_s w_s * ppermute(x, shift s) — one collective-permute per offset."""
    acc = None
    for s, w in sorted(shifts.items()):
        w = jnp.asarray(w, dtype=x.dtype)
        if s % n == 0:
            term = w * x
        else:
            # agent j receives from agent (j + s) mod n
            perm = [((j + s) % n, j) for j in range(n)]
            term = w * lax.ppermute(x, axis_name, perm=perm)
        acc = term if acc is None else acc + term
    return acc


def _general_mix_leaf(x, pi: jnp.ndarray, axis_name: str):
    """all_gather + row contraction for arbitrary doubly-stochastic Pi."""
    j = lax.axis_index(axis_name)
    gathered = lax.all_gather(x, axis_name)  # (N, ...) local copy
    row = pi[j].astype(jnp.float32)
    flat = gathered.reshape(gathered.shape[0], -1).astype(jnp.float32)
    return (row @ flat).astype(x.dtype).reshape(gathered.shape[1:])


def make_sharded_mix_fn(topology: Topology, axis_name: str) -> MixFn:
    """Mixing function usable *inside* ``shard_map`` over ``axis_name``.

    The returned fn maps a local (per-agent) pytree to its ``Pi``-mixed
    value.  Circulant topologies use ppermute; general ones all_gather.
    """
    n = topology.n_agents
    if n == 1:
        return lambda tree: tree
    shifts = topology.shift_weights()
    if shifts is not None:
        def mix(tree: PyTree) -> PyTree:
            return jax.tree.map(lambda x: _circulant_mix_leaf(x, shifts, axis_name, n), tree)
        return mix
    pi = jnp.asarray(topology.pi, dtype=jnp.float32)

    def mix(tree: PyTree) -> PyTree:
        return jax.tree.map(lambda x: _general_mix_leaf(x, pi, axis_name), tree)

    return mix


def make_sharded_mean_fn(axis_names) -> MixFn:
    """Exact global mean over the agent axes (FedAvg server / centralized)."""

    def mean(tree: PyTree) -> PyTree:
        return jax.tree.map(lambda x: lax.pmean(x, axis_names), tree)

    return mean


@dataclasses.dataclass(frozen=True)
class FactoredMix:
    """Kronecker-factored topology over multiple mesh axes.

    ``factors`` is a sequence of (axis_name, Topology).  The effective
    agent-interaction matrix is ``Pi = Pi_1 (x) Pi_2 (x) ...`` (Kronecker
    product), which is itself doubly stochastic and symmetric PSD when the
    factors are; ``lambda_2(Pi) = max over factors of lambda_2`` (all other
    factor eigenvalues at 1).  Mixing applies each factor sequentially.
    """

    factors: Tuple[Tuple[str, Topology], ...]

    @property
    def n_agents(self) -> int:
        n = 1
        for _, t in self.factors:
            n *= t.n_agents
        return n

    def dense_pi(self) -> np.ndarray:
        pi = np.array([[1.0]])
        for _, t in self.factors:
            pi = np.kron(pi, t.pi)
        return pi

    @property
    def lambda2(self) -> float:
        # kron eigenvalues are products; second-largest = max factor lambda_2
        lams = [t.lambda2 for _, t in self.factors if t.n_agents > 1]
        return max(lams) if lams else 0.0

    @property
    def lambdan(self) -> float:
        prod = 1.0
        for _, t in self.factors:
            prod *= t.lambdan
        return prod

    def make_mix_fn(self) -> MixFn:
        fns = [make_sharded_mix_fn(t, ax) for ax, t in self.factors if t.n_agents > 1]

        def mix(tree: PyTree) -> PyTree:
            for f in fns:
                tree = f(tree)
            return tree

        return mix


# --------------------------------------------------------------------------
# Wire-cost accounting
# --------------------------------------------------------------------------


def program_bytes_per_neighbor(spec: "flatbuf.FlatSpec",
                               program: Optional[MixingProgram],
                               exchange: str = "f32",
                               payloads: int = 1) -> int:
    """Bytes one whole-model transfer moves to ONE neighbor — THE payload
    pricing source (satellite of ISSUE 8).

    Every consumer — :func:`exchange_bytes_per_step`, the trainer/CLI
    printouts, ``engine``'s estimates, and the microbench frontier — prices
    through here, so a new wire contract (e.g. the ragged top-k payload)
    changes the figure everywhere at once instead of silently mispricing
    wherever a dense-payload assumption was duplicated.

    Dense wires (``compressor`` none/int8/fp8) price via
    :meth:`repro.core.flatbuf.FlatSpec.exchange_bytes` at the program's
    wire precision.  Compressed wires price the actual carried fields:

    * ``topk:p`` — per bucket ``k_rows*128`` int8 values + ``k_rows*128``
      int32 indices + ``k_rows`` f32 row scales (ALL of
      :class:`TopKWire` crosses the wire — indices are most of the cost,
      which is why the ≥25x headline needs p≈0.01, not 0.2).
    * ``rank:r`` — per bucket the two dense f32 factors:
      ``(rows*r + r*128) * 4``.

    ``program=None`` falls back to the dense pricing of the ``exchange``/
    ``payloads`` arguments (legacy callers without a program).
    """
    if program is None:
        return int(spec.exchange_bytes(exchange) * payloads)
    kind, param = parse_compressor(program.compressor)
    if kind in ("none", "int8", "fp8"):
        return int(spec.exchange_bytes(program.exchange) * program.n_payloads)
    from repro.kernels.consensus_update import topk as tk

    total = 0
    if kind == "topk":
        k_list = tk.topk_k_rows_for([b.rows for b in spec.buckets], param)
        for k_rows in k_list:
            total += k_rows * tk.TOPK_LANE_ROW_BYTES
    else:
        assert kind == "rank", kind
        r = int(param)
        for b in spec.buckets:
            total += (b.rows * r + r * flatbuf.LANE) * 4
    return total * program.n_payloads


def exchange_bytes_per_step(spec: "flatbuf.FlatSpec", topology,
                            exchange: str = "f32", rounds: int = 1,
                            payloads: int = 1,
                            program: Optional[MixingProgram] = None) -> dict:
    """Per-step bytes-on-wire estimate for the fused consensus exchange.

    The paper's fixed-topology cost model (eq. 5/6): each agent sends/
    receives ``degree`` whole-model transfers per step.  ``per_neighbor``
    comes from :func:`program_bytes_per_neighbor` — dense wires price via
    :meth:`repro.core.flatbuf.FlatSpec.exchange_bytes` for the chosen wire
    precision (int8/fp8 add one f32 scale per 128-lane row); passing
    ``program`` prices compressed wires (top-k / rank-r) from their actual
    carried fields.  ``topology`` may be a
    :class:`repro.core.topology.TopologySchedule` (degree = period
    average), ``rounds`` inner consensus rounds multiply every transfer
    (k-round i-CDSGD moves exactly ``k x`` the single-round bytes; error
    feedback moves zero extra — the residual is local state), and
    ``payloads`` counts the trees on the wire per transfer
    (``momentum_mixing="mixed"`` moves params + momentum = 2).
    """
    per_neighbor = program_bytes_per_neighbor(spec, program, exchange,
                                              payloads)
    if program is not None:
        exchange = (program.compressor if program.compressed
                    else program.exchange)
        payloads = program.n_payloads
    if isinstance(topology, TopologySchedule):
        degree = topology.mean_degree()
    else:
        degree = topology.degree()
    per_step = int(per_neighbor * degree * rounds)
    return {
        "exchange": exchange,
        "degree": degree,
        "rounds": rounds,
        "payloads": payloads,
        "per_neighbor_bytes": per_neighbor,
        "per_step_bytes": per_step,
        "native_per_step_bytes": int(spec.exchange_bytes("f32") * payloads
                                     * degree * rounds),
    }


def mean_exchange_bytes_per_step(spec: "flatbuf.FlatSpec", n_agents: int,
                                 period: int = 1, payloads: int = 1) -> dict:
    """Per-step bytes-on-wire estimate for a *global-mean* optimizer.

    FedAvg's sync step is a brute-force all-reduce of the whole model
    (ring all-reduce: ``2 (N-1)/N`` native-precision model transfers per
    agent), amortized over the ``period = local_steps`` between syncs —
    the collective now being gated on the sync step, an agent pays
    ``bytes / E`` per step instead of the full all-reduce every step.
    ``payloads`` counts the averaged trees (2 when the momentum buffer is
    averaged at sync too, i.e. ``mu != 0``).
    """
    native = spec.exchange_bytes("f32") * payloads
    per_sync = 2.0 * (n_agents - 1) / max(n_agents, 1) * native
    return {
        "exchange": "f32",
        "local_steps": period,
        "payloads": payloads,
        "per_sync_bytes": int(per_sync),
        "per_step_bytes": int(per_sync / max(period, 1)),
    }


def describe_exchange_cost(params: PyTree, topology,
                           exchange: str = "f32", *, lead: int = 1,
                           rounds: int = 1, payloads: int = 1,
                           program: Optional[MixingProgram] = None) -> str:
    """One-line human-readable :func:`exchange_bytes_per_step` report
    (shared by the train/dryrun CLIs and the examples)."""
    spec = flatbuf.make_flat_spec(params, lead=lead)
    wire = exchange_bytes_per_step(spec, topology, exchange, rounds,
                                   payloads, program=program)
    per_round = "" if rounds == 1 else f" x {rounds} rounds"
    per_payload = "" if payloads == 1 else f" ({payloads} payload trees)"
    auto = ""
    if program is not None and program.compressor_kind == "topk" \
            and isinstance(program.compressor_param, tuple):
        # topk:auto:B — surface the per-bucket densities the budget
        # solver actually chose (not the nominal spec string)
        from repro.kernels.consensus_update import topk as tk

        rows_list = [b.rows for b in spec.buckets]
        k_list = tk.topk_k_rows_for(rows_list, program.compressor_param)
        dens = ", ".join(f"{k / r:.3g}" for k, r in zip(k_list, rows_list))
        auto = f"; auto per-bucket p=[{dens}]"
    # the dict relabels compressed wires by their compressor (topk:p/rank:r)
    return (f"exchange={wire['exchange']}: "
            f"{wire['per_step_bytes']:,} bytes/agent/step "
            f"on the wire ({wire['degree']:g} neighbors x "
            f"{wire['per_neighbor_bytes']:,} B{per_round}{per_payload}; native "
            f"{wire['native_per_step_bytes']:,} B){auto}")


# --------------------------------------------------------------------------
# Consensus diagnostics
# --------------------------------------------------------------------------


def consensus_error_stacked(x: jnp.ndarray) -> jnp.ndarray:
    """mean_j ||x_j - mean(x)|| for an agent-stacked leaf (Prop. 1 LHS)."""
    mean = jnp.mean(x, axis=0, keepdims=True)
    diff = (x - mean).reshape(x.shape[0], -1)
    return jnp.mean(jnp.linalg.norm(diff.astype(jnp.float32), axis=1))


def consensus_error_pytree(tree: PyTree) -> jnp.ndarray:
    """Aggregate consensus error over an agent-stacked pytree."""
    leaves = jax.tree.leaves(tree)
    n = leaves[0].shape[0]
    mean_sq = jnp.zeros((n,), dtype=jnp.float32)
    for x in leaves:
        mean = jnp.mean(x, axis=0, keepdims=True)
        d = (x - mean).reshape(n, -1).astype(jnp.float32)
        mean_sq = mean_sq + jnp.sum(d * d, axis=1)
    return jnp.mean(jnp.sqrt(mean_sq))
