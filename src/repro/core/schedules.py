"""Step-size schedules.

The paper analyses two regimes: *fixed* step size (Theorems 1-2: linear
convergence to a neighborhood) and *diminishing* step size satisfying
``sum a_k = inf, sum a_k^2 < inf`` (Theorems 3-4: exact convergence).  The
paper's concrete diminishing choice (Remark 4) is ``a_k = Theta/(k^eps + t)``
with ``eps in (0.5, 1]``.  Warmup-cosine is provided for the modern LM
configs (framework completeness; not part of the paper's analysis).
"""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]  # step (int array) -> alpha


def fixed(alpha: float) -> Schedule:
    def sched(step):
        return jnp.full((), alpha, dtype=jnp.float32)

    return sched


def diminishing(theta: float = 1.0, eps: float = 1.0, t: float = 1.0) -> Schedule:
    """``a_k = Theta / (k^eps + t)`` — paper Remark 4; requires eps in (0.5, 1]."""
    if not (0.5 < eps <= 1.0):
        raise ValueError("eps must lie in (0.5, 1] for Theorem 3/4 to apply")

    def sched(step):
        k = jnp.asarray(step, dtype=jnp.float32) + 1.0
        return jnp.asarray(theta, jnp.float32) / (k**eps + t)

    return sched


def exponential_decay(alpha0: float, decay: float, every: int = 1) -> Schedule:
    def sched(step):
        k = jnp.asarray(step, dtype=jnp.float32)
        return jnp.asarray(alpha0, jnp.float32) * decay ** (k / every)

    return sched


def warmup_cosine(alpha_peak: float, warmup: int, total: int, alpha_min: float = 0.0) -> Schedule:
    def sched(step):
        k = jnp.asarray(step, dtype=jnp.float32)
        warm = alpha_peak * (k + 1.0) / max(warmup, 1)
        prog = jnp.clip((k - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = alpha_min + 0.5 * (alpha_peak - alpha_min) * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(k < warmup, warm, cos).astype(jnp.float32)

    return sched


def paper_step_size_bound(zeta1: float, qm: float, gamma_m: float, lambda_n: float) -> float:
    """Sufficient fixed-step bound (eq. 15 expanded):
    ``0 < alpha <= (zeta1 - (1 - lambda_N(Pi)) Qm) / (gamma_m Qm)``.

    Returns the upper bound; non-positive means the topology is too
    ill-conditioned for the theory to admit a fixed step.
    """
    return (zeta1 - (1.0 - lambda_n) * qm) / (gamma_m * qm)
