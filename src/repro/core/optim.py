"""Distributed optimizers: CDSGD, CDMSGD (Polyak & Nesterov) + baselines.

Every optimizer operates on an opaque parameter pytree and a ``CommOps``
bundle describing the collective operations available on the agent axis:

* ``comm.mix``  — ``w = Pi x`` over the fixed topology (paper eq. 5),
* ``comm.mean`` — exact global average (parameter-server emulation, used
  by FedAvg / centralized baselines),
* ``comm.lambda2 / lambdan`` — spectral constants for theory utilities.

The same optimizer code runs in both execution modes:

* **stacked simulation** — leaves carry a leading agent axis; ``comm`` is
  built by :func:`stacked_comm_ops` (dense ``Pi`` matmul);
* **sharded production** — inside ``shard_map``; ``comm`` is built from
  :func:`repro.core.consensus.make_sharded_mix_fn` (ppermute collectives).

Update rules (paper Algorithm 1-3):

    CDSGD:            x_{k+1} = Pi x_k - a_k g(x_k)
    CDMSGD (Polyak):  w = Pi x_k ; v_{k+1} = mu v_k - a_k g(x_k)
                      x_{k+1} = w + v_{k+1}
    CDMSGD (Nesterov): same, but g evaluated at x_k + mu v_k
    FedAvg:           E local SGD(+momentum) steps, then x <- mean(x)
    Centralized SGD:  g <- mean(g) every step; x_{k+1} = x_k - a_k g
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import consensus
from repro.core.schedules import Schedule, fixed
from repro.utils.tree import tree_axpy, tree_zeros_like

PyTree = Any
MixFn = Callable[[PyTree], PyTree]


@dataclasses.dataclass(frozen=True)
class CommOps:
    """Collective operations over the agent population."""

    mix: MixFn            # w = Pi x  (fixed topology)
    mean: MixFn           # exact global average
    n_agents: int
    lambda2: float = 0.0
    lambdan: float = 1.0
    # whole-model fused-update support (flat buffers + Pallas kernels);
    # None disables the optimizers' ``fused=True`` fast path.
    flat: Optional[consensus.FlatComm] = None


def identity_comm_ops() -> CommOps:
    """Single-agent degenerate comm (centralized training)."""
    ident = lambda t: t
    return CommOps(mix=ident, mean=ident, n_agents=1, lambda2=0.0, lambdan=1.0)


def stacked_comm_ops(topology, *, interpret: bool = True,
                     exchange: str = "f32",
                     program: Optional[consensus.MixingProgram] = None) -> CommOps:
    """CommOps for agent-stacked pytrees (leading axis = agent).

    ``exchange`` sets the fused path's simulated wire precision
    (f32 | bf16 | int8 | fp8 — see :class:`repro.core.consensus.FlatComm`);
    ``program`` selects the mixing strategy of the fused path (time-varying
    ``Pi_t``, multi-round i-CDSGD, error feedback — see
    :class:`repro.core.consensus.MixingProgram`).
    """
    pi = jnp.asarray(topology.pi, dtype=jnp.float32)

    def mix(tree):
        return consensus.mix_pytree_stacked(pi, tree)

    def mean(tree):
        return jax.tree.map(lambda x: jnp.broadcast_to(jnp.mean(x, 0, keepdims=True), x.shape), tree)

    return CommOps(mix=mix, mean=mean, n_agents=topology.n_agents,
                   lambda2=topology.lambda2, lambdan=topology.lambdan,
                   flat=consensus.stacked_flat_comm(topology, interpret=interpret,
                                                    exchange=exchange,
                                                    program=program))


def sharded_comm_ops(topology, axis_name: str) -> CommOps:
    """CommOps for use inside shard_map over ``axis_name``."""
    mix = consensus.make_sharded_mix_fn(topology, axis_name)
    mean = consensus.make_sharded_mean_fn(axis_name)
    return CommOps(mix=mix, mean=mean, n_agents=topology.n_agents,
                   lambda2=topology.lambda2, lambdan=topology.lambdan)


def factored_comm_ops(factored: consensus.FactoredMix, axis_names) -> CommOps:
    mix = factored.make_mix_fn()
    mean = consensus.make_sharded_mean_fn(tuple(axis_names))
    return CommOps(mix=mix, mean=mean, n_agents=factored.n_agents,
                   lambda2=factored.lambda2, lambdan=factored.lambdan)


# --------------------------------------------------------------------------
# Optimizer protocol
# --------------------------------------------------------------------------


class OptState(NamedTuple):
    step: jnp.ndarray      # scalar int32
    inner: Any             # optimizer-specific (momentum, adam moments, ...)
    # in-flight wire buffers of the overlap schedule: one (quantized
    # payload, row scales) pair per flat bucket, quantized from the params
    # at the *previous* step (see repro.core.engine).  () under
    # schedule="sync" — the StepProgram engine owns filling/refreshing it.
    wire: Any = ()
    # error-feedback residuals (MixingProgram(error_feedback=True)): one
    # f32 buffer per flat bucket carrying the compression error of the
    # last quantized wire payload; local state, never crosses the wire.
    # () when error feedback is off — the engine owns filling/refreshing.
    residual: Any = ()
    # warm-start state of the rank-r wire compressor (compressor="rank:r"):
    # one (A, 128, r) / (1, 128, r) orthonormal basis per flat bucket,
    # carried like the wire and refreshed by compress_ef each step.  ()
    # for every other program — the engine owns filling/refreshing.
    qwarm: Any = ()


@dataclasses.dataclass(frozen=True)
class ExchangeResult:
    """Kernel-ready mixing operands produced by the engine's phase pipeline.

    ``DistributedOptimizer.update(..., exchanged=...)`` consumes this
    instead of calling ``comm.flat.gather`` itself: the StepProgram engine
    ran pack / quantize / exchange as separately scheduled phases (possibly
    against one-step-stale wire state) and hands the fused kernels their
    operands.  ``selfs`` is always the *fresh* native-precision packed
    params — the self term never crosses the wire and never goes stale.

    With ``momentum_mixing="mixed"`` the wire carried a second payload
    tree: ``mom_neighbors`` / ``mom_scales`` / ``mom_selfs`` are the
    momentum buffer's exchanged operands (same weights as the params —
    one agent-interaction matrix mixes both), ``None`` otherwise.
    ``mom_selfs`` is the momentum buffer the fused kernels mix the self
    weight against — the freshly packed momentum (single round) or the
    round-``k-1`` partially mixed buffer (multi-round), exactly mirroring
    ``selfs``.

    Sparse operand variant (``MixingProgram.sparse_update`` with the top-k
    compressor): a bucket's ``neighbors`` entry is a
    :class:`repro.kernels.consensus_update.ops.SparseNeighbors` tuple (the
    raw ``TopKWire`` compact fields) and its ``scales`` entry is ``None``
    — the per-compact-row scales ride inside the tuple and the fused
    kernels scatter-accumulate straight from the wire instead of reading
    a dense decompressed stack.
    """

    spec: Any                     # flatbuf.FlatSpec of the param pytree
    neighbors: Sequence           # per-bucket wire payload stacks
    weights: jnp.ndarray          # self-separated weights (self first)
    scales: Sequence              # per-bucket row-scale stacks
    selfs: Sequence               # per-bucket fresh native self buffers
    # the mixed-momentum payload's operands (momentum_mixing="mixed" only)
    mom_neighbors: Optional[Sequence] = None
    mom_scales: Optional[Sequence] = None
    mom_selfs: Optional[Sequence] = None

    @property
    def momentum_mixed(self) -> bool:
        return self.mom_neighbors is not None


class DistributedOptimizer:
    """Base: subclasses implement `init_inner` and `apply`.

    ``fused=True`` (consensus optimizers only) routes the update through the
    flat-buffer Pallas path when the ``CommOps`` carries a
    :class:`repro.core.consensus.FlatComm`: the whole model is packed into
    dtype-bucketed ``(rows, 128)`` buffers and updated with one kernel
    launch per bucket (see :mod:`repro.kernels.consensus_update`).  When the
    comm has no flat support the optimizer falls back to the per-leaf
    reference ``apply`` with identical semantics.  Pallas interpret-vs-
    compiled mode is owned by the ``FlatComm`` (True on CPU, False on TPU).
    """

    #: declared in-place contract of the fused path: how many
    #: ``(input, output)`` ``input_output_aliases`` pairs every fused bucket
    #: launch must carry (params always alias in place; momentum-family
    #: optimizers alias their inner buffers too).  ``None`` = no fused
    #: in-place contract (baselines / reference-path optimizers).  The
    #: static checker's alias-coverage pass audits the traced step against
    #: this number (see :mod:`repro.analysis.staticcheck`).
    fused_alias_pairs = None

    def __init__(self, schedule: Schedule | float, *, fused: bool = False):
        self.schedule: Schedule = fixed(schedule) if isinstance(schedule, (int, float)) else schedule
        self.fused = fused

    # -- public API --------------------------------------------------------
    def init(self, params: PyTree) -> OptState:
        return OptState(step=jnp.zeros((), jnp.int32), inner=self.init_inner(params))

    def grad_params(self, params: PyTree, state: OptState) -> PyTree:
        """Point at which the caller should evaluate the gradient."""
        return params

    def update(self, params: PyTree, grads: PyTree, state: OptState,
               comm: CommOps, *, exchanged: Optional[ExchangeResult] = None):
        """One optimizer step.

        ``exchanged`` carries pre-computed mixing operands from the
        StepProgram engine's pack/quantize/exchange phases (the overlap
        schedule's one-step-stale wire); when None the fused path gathers
        synchronously via ``comm.flat``.  The wire and residual fields of
        the state are passed through untouched — the engine refreshes them.
        """
        alpha = self.schedule(state.step)
        # fused is a perf hint: optimizers without a fused implementation
        # (baselines) and comms without flat support use the reference path.
        has_fused = type(self).apply_fused is not DistributedOptimizer.apply_fused
        if self.fused and has_fused and comm.flat is not None:
            new_params, new_inner = self.apply_fused(
                params, grads, state.inner, alpha, comm, state.step,
                exchanged=exchanged)
        elif exchanged is not None:
            raise ValueError(
                f"{type(self).__name__} cannot consume exchanged operands: "
                "the engine's exchange phase feeds fused optimizers only")
        else:
            new_params, new_inner = self.apply(params, grads, state.inner, alpha, comm, state.step)
        return new_params, OptState(step=state.step + 1, inner=new_inner,
                                    wire=state.wire, residual=state.residual,
                                    qwarm=state.qwarm)

    def state_specs(self, param_specs: PyTree) -> "OptState":
        """PartitionSpec tree mirroring init() (for pjit in_shardings)."""
        from jax.sharding import PartitionSpec
        return OptState(step=PartitionSpec(), inner=self.inner_specs(param_specs))

    def inner_specs(self, param_specs: PyTree) -> Any:
        return ()

    # -- to implement -------------------------------------------------------
    def init_inner(self, params: PyTree) -> Any:
        return ()

    def apply(self, params, grads, inner, alpha, comm: CommOps, step):
        raise NotImplementedError

    def apply_fused(self, params, grads, inner, alpha, comm: CommOps, step,
                    *, exchanged: Optional[ExchangeResult] = None):
        """Flat-buffer fast path; same contract as ``apply``."""
        raise NotImplementedError(f"{type(self).__name__} has no fused path")

    @property
    def uses_consensus(self) -> bool:
        return True

    # -- momentum-consensus mixing (MixingProgram momentum_mixing="mixed") --
    @property
    def has_mixable_momentum(self) -> bool:
        """True when the optimizer carries a momentum-like buffer the wire
        can mix alongside the params (CDMSGD family's ``v``, CDAdam's first
        moment).  Optimizers without one reject ``momentum_mixing``."""
        return False

    def momentum_tree(self, inner) -> Optional[PyTree]:
        """The momentum pytree to put on the wire (param-structured), or
        ``None``.  The engine packs it next to the params when the comm's
        program mixes momentum."""
        return None


# --------------------------------------------------------------------------
# The paper's algorithms
# --------------------------------------------------------------------------


def _flat_setup(fl, params, step, *trees, exchanged=None):
    """Pack params (+ same-structured trees) against one shared FlatSpec.

    ``step`` seeds the stochastic rounding of quantized exchanges (the
    gather decorrelates it per bucket/agent); unquantized exchanges ignore
    it and return ``None`` scales.  When the engine already ran the
    pack/quantize/exchange phases (``exchanged`` given) only the extra
    trees are packed here; the mixing operands come from the phase outputs.
    """
    if exchanged is not None:
        others = [fl.pack(t, exchanged.spec) for t in trees]
        return (exchanged.spec, exchanged.neighbors, exchanged.weights,
                exchanged.scales, exchanged.selfs, others)
    if fl.program is not None and fl.program.momentum_mixing == "mixed":
        # the momentum payload lives on the engine's staged pipeline (the
        # engine packs params + momentum and splits the exchanged operands);
        # a bare gather here would see the params-only bucket list
        raise ValueError(
            "momentum_mixing='mixed' needs the StepProgram engine's staged "
            "exchange (CollaborativeTrainer / build_train_step); the "
            "optimizer cannot gather the momentum payload itself")
    spec = fl.spec(params)
    bufs = fl.pack(params, spec)
    others = [fl.pack(t, spec) for t in trees]
    nbrs, weights, scales, selfs = fl.gather(bufs, jnp.asarray(step, jnp.int32))
    return spec, nbrs, weights, scales, selfs, others


class CDSGD(DistributedOptimizer):
    """Algorithm 1: ``x_{k+1} = Pi x_k - alpha g(x_k)``."""

    fused_alias_pairs = 1   # params in-place

    def apply(self, params, grads, inner, alpha, comm, step):
        mixed = comm.mix(params)
        # final .astype keeps bf16 params bf16 (traced f32 alpha promotes)
        new_params = jax.tree.map(
            lambda w, g: (w - alpha * g.astype(w.dtype)).astype(w.dtype),
            mixed, grads)
        return new_params, inner

    def apply_fused(self, params, grads, inner, alpha, comm, step, *,
                    exchanged=None):
        from repro.kernels.consensus_update import ops as kops
        fl = comm.flat
        spec, nbrs, w, scs, sfs, (g,) = _flat_setup(fl, params, step, grads,
                                                    exchanged=exchanged)
        outs = [kops.cdsgd_update_flat(nb, w, gb, alpha, scales=sc,
                                       self_buf=sf, interpret=fl.interpret)
                for nb, sc, sf, gb in zip(nbrs, scs, sfs, g)]
        return fl.unpack(outs, spec), inner


class CDMSGD(DistributedOptimizer):
    """Algorithm 2 (Polyak momentum):
    ``v' = mu v - alpha g(x); x' = Pi x + v'``.

    With ``momentum_mixing="mixed"`` the momentum buffer rides the wire and
    is mixed with the same ``Pi``: ``v' = mu (Pi v) - alpha g`` (momentum-
    accelerated consensus, 2010.11166) — the consensus and momentum
    dynamics then contract together instead of fighting, which is what
    stabilizes quantized exchanges at large step sizes.
    """

    fused_alias_pairs = 2   # params + momentum v in-place

    def __init__(self, schedule, mu: float = 0.9, **kw):
        super().__init__(schedule, **kw)
        self.mu = mu

    def init_inner(self, params):
        return tree_zeros_like(params)

    def inner_specs(self, param_specs):
        return param_specs

    @property
    def has_mixable_momentum(self):
        return True

    def momentum_tree(self, inner):
        return inner

    def apply(self, params, grads, v, alpha, comm, step):
        mixed = comm.mix(params)
        new_v = jax.tree.map(
            lambda vi, g: (self.mu * vi - alpha * g.astype(vi.dtype)).astype(vi.dtype),
            v, grads)
        new_params = jax.tree.map(lambda w, nv: (w + nv).astype(w.dtype), mixed, new_v)
        return new_params, new_v

    def apply_fused(self, params, grads, v, alpha, comm, step, *,
                    exchanged=None):
        from repro.kernels.consensus_update import ops as kops
        fl = comm.flat
        if exchanged is not None and exchanged.momentum_mixed:
            # mixed-momentum operand form: the momentum self buffer is the
            # engine's mom_selfs (= packed v, or the round-(k-1) partially
            # mixed v under a multi-round program), not a fresh pack of v
            spec = exchanged.spec
            g = fl.pack(grads, spec)
            pairs = [kops.cdmsgd_update_flat(nb, exchanged.weights, gb, vi,
                                             alpha, self.mu, scales=sc,
                                             self_buf=sf, mom_neighbors=mnb,
                                             mom_scales=msc,
                                             interpret=fl.interpret)
                     for nb, sc, sf, gb, vi, mnb, msc in zip(
                         exchanged.neighbors, exchanged.scales,
                         exchanged.selfs, g, exchanged.mom_selfs,
                         exchanged.mom_neighbors, exchanged.mom_scales)]
            new_params = fl.unpack([p for p, _ in pairs], spec)
            new_v = fl.unpack([nv for _, nv in pairs], spec)
            return new_params, new_v
        spec, nbrs, w, scs, sfs, (g, vb) = _flat_setup(fl, params, step, grads,
                                                       v, exchanged=exchanged)
        pairs = [kops.cdmsgd_update_flat(nb, w, gb, vi, alpha, self.mu,
                                         scales=sc, self_buf=sf,
                                         interpret=fl.interpret)
                 for nb, sc, sf, gb, vi in zip(nbrs, scs, sfs, g, vb)]
        new_params = fl.unpack([p for p, _ in pairs], spec)
        new_v = fl.unpack([nv for _, nv in pairs], spec)
        return new_params, new_v


class CDMSGDNesterov(CDMSGD):
    """Algorithm 3: gradient evaluated at the lookahead point x + mu v.

    Unfused, the state is the momentum ``v`` and the lookahead is a
    ``tree_axpy`` recomputed before every backward.  Fused, the state is
    ``(v, lookahead)``: the kernel emits ``x' + mu v'`` in the same HBM
    sweep as the update, so ``grad_params`` is a free state lookup.
    """

    fused_alias_pairs = 2   # params + momentum v in-place (lookahead is new)

    def init_inner(self, params):
        if self.fused:
            # lookahead_0 = x_0 + mu * 0 = x_0 — copied, NOT aliased: the
            # trainer donates params and optimizer state to the jitted
            # step, and donating the same buffer through both arguments is
            # a runtime error on the very first step
            return (tree_zeros_like(params), jax.tree.map(jnp.copy, params))
        return tree_zeros_like(params)

    def inner_specs(self, param_specs):
        if self.fused:
            return (param_specs, param_specs)
        return param_specs

    def grad_params(self, params, state):
        if self.fused:
            return state.inner[1]
        return tree_axpy(self.mu, state.inner, params)

    def momentum_tree(self, inner):
        return inner[0] if self.fused else inner

    def apply(self, params, grads, inner, alpha, comm, step):
        # reference path for fused-shaped state (comm without flat support)
        if self.fused:
            v, _ = inner
            new_params, new_v = super().apply(params, grads, v, alpha, comm, step)
            look = tree_axpy(self.mu, new_v, new_params)
            return new_params, (new_v, look)
        return super().apply(params, grads, inner, alpha, comm, step)

    def apply_fused(self, params, grads, inner, alpha, comm, step, *,
                    exchanged=None):
        from repro.kernels.consensus_update import ops as kops
        fl = comm.flat
        v, _ = inner
        if exchanged is not None and exchanged.momentum_mixed:
            spec = exchanged.spec
            g = fl.pack(grads, spec)
            triples = [kops.cdmsgd_nesterov_update_flat(
                           nb, exchanged.weights, gb, vi, alpha, self.mu,
                           scales=sc, self_buf=sf, mom_neighbors=mnb,
                           mom_scales=msc, interpret=fl.interpret)
                       for nb, sc, sf, gb, vi, mnb, msc in zip(
                           exchanged.neighbors, exchanged.scales,
                           exchanged.selfs, g, exchanged.mom_selfs,
                           exchanged.mom_neighbors, exchanged.mom_scales)]
        else:
            spec, nbrs, w, scs, sfs, (g, vb) = _flat_setup(
                fl, params, step, grads, v, exchanged=exchanged)
            triples = [kops.cdmsgd_nesterov_update_flat(nb, w, gb, vi, alpha,
                                                        self.mu, scales=sc,
                                                        self_buf=sf,
                                                        interpret=fl.interpret)
                       for nb, sc, sf, gb, vi in zip(nbrs, scs, sfs, g, vb)]
        new_params = fl.unpack([t[0] for t in triples], spec)
        new_v = fl.unpack([t[1] for t in triples], spec)
        look = fl.unpack([t[2] for t in triples], spec)
        return new_params, (new_v, look)


class CDAdam(DistributedOptimizer):
    """Beyond-paper extension: consensus mixing of parameters with local
    Adam moments (``x' = Pi x - alpha * adam_dir(g)``).  Moments stay local
    (they are statistics of the *local* data distribution); parameters mix.

    ``momentum_mixing="mixed"`` mixes the FIRST moment over the wire
    (``m' = b1 (Pi m) + (1-b1) g``, the Adam analog of 2010.11166's
    momentum-accelerated consensus); the second moment stays local — it is
    a positive per-coordinate scale, not a direction, and mixing it would
    skew the bias correction.
    """

    fused_alias_pairs = 3   # params + both Adam moments in-place

    def __init__(self, schedule, b1=0.9, b2=0.999, eps=1e-8, **kw):
        super().__init__(schedule, **kw)
        self.b1, self.b2, self.eps = b1, b2, eps

    def init_inner(self, params):
        return (tree_zeros_like(params), tree_zeros_like(params))

    def inner_specs(self, param_specs):
        return (param_specs, param_specs)

    @property
    def has_mixable_momentum(self):
        return True

    def momentum_tree(self, inner):
        return inner[0]

    def apply(self, params, grads, inner, alpha, comm, step):
        m, v = inner
        t = (step + 1).astype(jnp.float32)
        new_m = jax.tree.map(lambda mi, g: self.b1 * mi + (1 - self.b1) * g.astype(mi.dtype), m, grads)
        new_v = jax.tree.map(lambda vi, g: self.b2 * vi + (1 - self.b2) * jnp.square(g.astype(vi.dtype)), v, grads)
        bc1 = 1.0 - self.b1**t
        bc2 = 1.0 - self.b2**t
        mixed = comm.mix(params)
        new_params = jax.tree.map(
            lambda w, mi, vi: w - (alpha * (mi / bc1) / (jnp.sqrt(vi / bc2) + self.eps)).astype(w.dtype),
            mixed, new_m, new_v)
        return new_params, (new_m, new_v)

    def apply_fused(self, params, grads, inner, alpha, comm, step, *,
                    exchanged=None):
        from repro.kernels.consensus_update import ops as kops
        fl = comm.flat
        m, v = inner
        t = (step + 1).astype(jnp.float32)
        bc1 = 1.0 - self.b1**t
        bc2 = 1.0 - self.b2**t
        if exchanged is not None and exchanged.momentum_mixed:
            spec = exchanged.spec
            g = fl.pack(grads, spec)
            vb = fl.pack(v, spec)
            triples = [kops.cdadam_update_flat(
                           nb, exchanged.weights, gb, mi, vi, alpha, self.b1,
                           self.b2, self.eps, bc1, bc2, scales=sc,
                           self_buf=sf, mom_neighbors=mnb, mom_scales=msc,
                           interpret=fl.interpret)
                       for nb, sc, sf, gb, mi, vi, mnb, msc in zip(
                           exchanged.neighbors, exchanged.scales,
                           exchanged.selfs, g, exchanged.mom_selfs, vb,
                           exchanged.mom_neighbors, exchanged.mom_scales)]
        else:
            spec, nbrs, w, scs, sfs, (g, mb, vb) = _flat_setup(
                fl, params, step, grads, m, v, exchanged=exchanged)
            triples = [kops.cdadam_update_flat(nb, w, gb, mi, vi, alpha,
                                               self.b1, self.b2, self.eps,
                                               bc1, bc2, scales=sc,
                                               self_buf=sf,
                                               interpret=fl.interpret)
                       for nb, sc, sf, gb, mi, vi in zip(nbrs, scs, sfs, g,
                                                         mb, vb)]
        new_params = fl.unpack([t_[0] for t_ in triples], spec)
        new_m = fl.unpack([t_[1] for t_ in triples], spec)
        new_v = fl.unpack([t_[2] for t_ in triples], spec)
        return new_params, (new_m, new_v)


# --------------------------------------------------------------------------
# Baselines
# --------------------------------------------------------------------------


class CentralizedSGD(DistributedOptimizer):
    """Data-parallel SGD: grads averaged across agents every step."""

    def apply(self, params, grads, inner, alpha, comm, step):
        g = comm.mean(grads)
        return jax.tree.map(
            lambda x, gi: (x - alpha * gi.astype(x.dtype)).astype(x.dtype),
            params, g), inner

    @property
    def uses_consensus(self):
        return False


class CentralizedMSGD(DistributedOptimizer):
    """Data-parallel Polyak-momentum SGD (paper's 'MSGD')."""

    def __init__(self, schedule, mu: float = 0.9, **kw):
        super().__init__(schedule, **kw)
        self.mu = mu

    def init_inner(self, params):
        return tree_zeros_like(params)

    def inner_specs(self, param_specs):
        return param_specs

    def apply(self, params, grads, v, alpha, comm, step):
        g = comm.mean(grads)
        new_v = jax.tree.map(
            lambda vi, gi: (self.mu * vi - alpha * gi.astype(vi.dtype)).astype(vi.dtype),
            v, g)
        return jax.tree.map(lambda x, nv: (x + nv).astype(x.dtype), params, new_v), new_v

    @property
    def uses_consensus(self):
        return False


class FedAvg(DistributedOptimizer):
    """Federated Averaging [McMahan et al. 2016] with C=1 (all clients).

    Each agent takes local SGD(+momentum) steps; every ``local_steps``
    steps the parameters AND the momentum buffer are replaced by their
    global averages — a brute-force consensus through a central parameter
    server (paper §5.1 discussion).  The averaging collective runs under
    ``lax.cond`` gated on the sync step, so ``local_steps = E > 1`` pays
    the all-reduce once per E steps instead of every step (it used to run
    unconditionally with the result discarded on non-sync steps), and the
    momentum average keeps the local ``v`` buffers from silently diverging
    across agents between syncs — without it each agent's momentum keeps
    pulling toward its own shard after every sync, which is NOT the E-step
    server-side FedAvg recurrence (asserted against the hand-rolled
    reference in tests/test_optim.py).

    ``faults`` (a :class:`repro.core.faults.FaultSchedule`) enables
    **partial participation**: at a sync step whose fault table marks
    agents as straggling, the server averages over the ``k``-of-``N``
    *present* agents only (masked sum renormalized by ``N/k``) instead of
    silently including the absent agents' stale params, and broadcasts the
    result to everyone — the deterministic analog of client sampling.  A
    sync step where nobody is present keeps the local params (no sync
    happened).  The momentum average is masked identically.  Agent-stacked
    execution mode (the FedAvg baseline's home); asserted against a
    hand-rolled k-of-N server reference in tests/test_optim.py.
    """

    def __init__(self, schedule, local_steps: int = 1, mu: float = 0.0,
                 faults=None, **kw):
        super().__init__(schedule, **kw)
        self.local_steps = int(local_steps)
        self.mu = mu
        self.faults = faults
        if faults is not None:
            faults.validate()
            # presence = NOT straggling at the sync step (link drops are a
            # neighbor-exchange concept; the server round-trip only cares
            # whether the client reported in)
            self._present = jnp.asarray(
                (~faults.straggle).astype("float32"))     # (P, A)
            self._fault_period = faults.period

    def init_inner(self, params):
        return tree_zeros_like(params)

    def inner_specs(self, param_specs):
        return param_specs

    def apply(self, params, grads, v, alpha, comm, step):
        new_v = jax.tree.map(
            lambda vi, g: (self.mu * vi - alpha * g.astype(vi.dtype)).astype(vi.dtype),
            v, grads)
        local = jax.tree.map(lambda x, nv: (x + nv).astype(x.dtype), params, new_v)

        def sync(args):
            p, vv = args
            if self.faults is None:
                # mu == 0: v is identically -alpha g, already consumed —
                # skip the second collective
                return comm.mean(p), (comm.mean(vv) if self.mu else vv)
            tp = jnp.mod(jnp.asarray(step, jnp.int32), self._fault_period)
            m = jnp.take(self._present, tp, axis=0)       # (A,) f32
            k = jnp.sum(m)
            scale = m.shape[0] / jnp.maximum(k, 1.0)

            def masked_mean(tree):
                wsum = comm.mean(jax.tree.map(
                    lambda x: x * m.reshape((-1,) + (1,) * (x.ndim - 1)),
                    tree))
                return jax.tree.map(
                    lambda mn, x: jnp.where(k > 0, (mn * scale).astype(x.dtype), x),
                    wsum, tree)

            return masked_mean(p), (masked_mean(vv) if self.mu else vv)

        if self.local_steps <= 1:
            return sync((local, new_v))
        do_avg = (step + 1) % self.local_steps == 0
        return lax.cond(do_avg, sync, lambda args: args, (local, new_v))

    @property
    def uses_consensus(self):
        return False


class GossipSGD(DistributedOptimizer):
    """Gossip SGD baseline [Jin et al. 2016, paper Table 1 row 4].

    Decentralized but *unconstrained* communication: each step every agent
    averages with one uniformly random partner (mixing matrix
    ``W_k = (I + P_k)/2`` for a random permutation ``P_k`` — doubly
    stochastic, changes every step), then takes a local SGD step.  Contrast
    with CDSGD where the communication graph is FIXED — the paper's whole
    point is that random pairwise exchange is infeasible in mesh-constrained
    deployments.  Stacked-simulation execution mode only.
    """

    def __init__(self, schedule, n_agents: int, seed: int = 0, **kw):
        super().__init__(schedule, **kw)
        self.n_agents = n_agents
        self.seed = seed

    def apply(self, params, grads, inner, alpha, comm, step):
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        perm = jax.random.permutation(key, self.n_agents)

        def mix_leaf(x):
            return 0.5 * (x + x[perm])

        mixed = jax.tree.map(mix_leaf, params)
        return jax.tree.map(
            lambda w, g: (w - alpha * g.astype(w.dtype)).astype(w.dtype),
            mixed, grads), inner


class TimeVaryingCDSGD(DistributedOptimizer):
    """CDSGD over a time-varying topology (paper future work §6.ii).

    Cycles through a list of agent-interaction matrices ``Pi_k`` (one per
    step, modulo the list length).  Consensus requires only that the
    *union* graph is connected — e.g. alternating horizontal/vertical line
    graphs on a grid — which the tests verify.  Stacked execution mode.
    """

    def __init__(self, schedule, topologies, **kw):
        super().__init__(schedule, **kw)
        import numpy as _np
        self.pis = jnp.asarray(_np.stack([t.pi for t in topologies]), jnp.float32)

    def apply(self, params, grads, inner, alpha, comm, step):
        pi = self.pis[step % self.pis.shape[0]]
        mixed = consensus.mix_pytree_stacked(pi, params)
        return jax.tree.map(
            lambda w, g: (w - alpha * g.astype(w.dtype)).astype(w.dtype),
            mixed, grads), inner


def make_optimizer(name: str, schedule, **kw) -> DistributedOptimizer:
    """Registry used by configs / CLI (`--optimizer cdsgd` etc.)."""
    name = name.lower()
    table = {
        "cdsgd": CDSGD,
        "cdmsgd": CDMSGD,
        "cdmsgd_nesterov": CDMSGDNesterov,
        "cdadam": CDAdam,
        "sgd": CentralizedSGD,
        "msgd": CentralizedMSGD,
        "fedavg": FedAvg,
        "gossip": GossipSGD,
        "cdsgd_tv": TimeVaryingCDSGD,
    }
    if name not in table:
        raise ValueError(f"unknown optimizer {name!r}; available: {sorted(table)}")
    return table[name](schedule, **kw)
