"""Multi-agent collaborative trainer (stacked simulation execution mode).

Simulates the paper's N-agent fixed-topology network on any backend: every
parameter leaf carries a leading agent axis and the step is assembled from
the shared :class:`repro.core.engine.StepProgram` phases — the same
grad/pack/quantize/exchange/update pipeline the sharded production mode
(:mod:`repro.launch.steps`) wraps in ``shard_map``.  This front-end only
supplies the stacked ``CommOps`` (dense ``Pi``) and the consensus-error
metric; it is the execution mode behind every paper-figure benchmark and
the theory tests, and the oracle the sharded trainers are verified
against.  ``schedule="overlap"`` selects the one-step-stale pipelined
exchange (see :mod:`repro.core.engine`).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, flatbuf
from repro.core.consensus import (
    MixingProgram,
    consensus_error_pytree,
    exchange_bytes_per_step,
    make_mixing_program,
    mean_exchange_bytes_per_step,
)
from repro.core.optim import (
    CommOps,
    DistributedOptimizer,
    FedAvg,
    stacked_comm_ops,
)
from repro.core.topology import Topology, TopologySchedule, make_topology_schedule
from repro.utils.metrics import MetricHistory

PyTree = Any
LossFn = Callable[[PyTree, Dict[str, jnp.ndarray]], Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]]


def broadcast_to_agents(params: PyTree, n_agents: int) -> PyTree:
    """Replicate a single parameter set to all agents (common init)."""
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n_agents,) + x.shape).copy(), params)


def perturb_per_agent(params: PyTree, key, scale: float = 0.01) -> PyTree:
    """Optionally de-synchronize agent initializations."""
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(key, len(leaves))
    out = [x + scale * jax.random.normal(k, x.shape, x.dtype) for x, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


@dataclasses.dataclass
class TrainState:
    params: PyTree            # stacked (A, ...)
    opt_state: Any
    step: int = 0


class CollaborativeTrainer:
    """Drives N collaborating agents through a DistributedOptimizer.

    An optimizer constructed with ``fused=True`` runs the whole-model
    flat-buffer update here: the stacked ``CommOps`` carries a ``FlatComm``
    (dense ``Pi`` on packed buffers), so each step issues exactly one
    ``pallas_call`` per parameter dtype bucket instead of one mix + axpy
    per pytree leaf.  ``interpret`` selects Pallas interpret mode (True on
    CPU, False on TPU).

    ``exchange`` simulates the neighbor-exchange wire precision of the
    fused path (``"f32"`` native, ``"bf16"``, or ``"int8"``/``"fp8"``
    stochastic-rounding quantization — the bandwidth knob of the sharded
    trainer, see :class:`repro.core.consensus.FlatComm`).  ``donate=True``
    (default) donates params and optimizer state to the jitted step, so
    together with the kernels' ``input_output_aliases`` the model updates
    in place instead of allocating a fresh copy per optimizer slot.

    ``schedule="overlap"`` double-buffers the quantized wire payloads in
    the optimizer state (one-step-stale neighbor mixing, fresh self term);
    ``microbatches`` enables the shared gradient-accumulation scan.

    The **mixing strategy** of the fused path is configurable
    (:class:`repro.core.consensus.MixingProgram`): ``mixing_strategy``
    selects ``static`` / ``time_varying`` / ``multi_round``,
    ``consensus_rounds`` sets the inner i-CDSGD round count,
    ``topology_schedule`` supplies the time-varying ``Pi_t`` sequence (a
    :class:`repro.core.topology.TopologySchedule` or a factory spec like
    ``"alternating:ring:torus"`` / ``"gossip:8"``), and
    ``error_feedback=True`` carries quantization residuals in the
    optimizer state, and ``momentum_mixing="mixed"`` puts the momentum
    buffer on the wire next to the params (``v' = mu (Pi v) - a g``,
    2010.11166 — the principled fix for the momentum/quantization
    large-lr instability; 2x the wire bytes, momentum-capable optimizers
    only).  ``staleness=S`` / ``fault_schedule=`` (a
    :class:`repro.core.faults.FaultSchedule` or a spec string like
    ``"stall:1:1:3,drop:0:2"``) engage the bounded-staleness wire ring with
    arrival-masked mixing under ``schedule="overlap"`` — injected
    stragglers/drops cost bounded drift instead of a stalled step.
    ``compressor=`` selects the wire compressor axis (``"int8"`` /
    ``"fp8"`` alias the exchange precisions; ``"topk:p"`` / ``"rank:r"``
    are the biased sparse / low-rank compressors riding the EF rail —
    they require ``error_feedback=True`` and normalize ``exchange``
    themselves; ``"topk:auto:B"`` picks per-bucket densities against a
    byte budget).  With a top-k compressor ``sparse_update`` (default on)
    feeds the compact wire fields straight to the fused sparse kernels —
    ``sparse_update=False`` forces the dense decompress-then-update
    reference path.  Everything validates at construction; non-trivial
    programs require a ``fused=True`` consensus optimizer.
    """

    def __init__(
        self,
        loss_fn: LossFn,
        params: PyTree,                   # single-agent params (will be stacked)
        topology: Topology,
        optimizer: DistributedOptimizer,
        *,
        stack: bool = True,
        donate: bool = True,
        interpret: bool = True,
        exchange: str = "f32",
        schedule: str = "sync",
        microbatches: int = 1,
        mixing_strategy: str = "static",
        consensus_rounds: int = 1,
        topology_schedule=None,           # TopologySchedule | factory spec str
        error_feedback: bool = False,
        momentum_mixing: str = "none",
        staleness: int = 1,
        fault_schedule=None,              # FaultSchedule | spec str (faults.py)
        compressor: str = "none",
        sparse_update: Optional[bool] = None,
    ):
        self.loss_fn = loss_fn
        self.topology = topology
        self.optimizer = optimizer
        self.exchange = exchange
        self.schedule = schedule
        if exchange != "f32" and not getattr(optimizer, "fused", False):
            import warnings
            warnings.warn(
                f"exchange={exchange!r} only affects fused optimizers; "
                f"{type(optimizer).__name__}(fused=False) will mix in native "
                "precision", stacklevel=2)
        if isinstance(topology_schedule, str):
            topology_schedule = make_topology_schedule(
                topology_schedule, topology.n_agents)
        if topology_schedule is not None and \
                topology_schedule.n_agents != topology.n_agents:
            raise ValueError(
                f"topology_schedule spans {topology_schedule.n_agents} agents "
                f"but the topology has {topology.n_agents}")
        if isinstance(fault_schedule, str):
            from repro.core.faults import make_fault_schedule
            fault_schedule = make_fault_schedule(fault_schedule,
                                                 topology.n_agents)
        self.program: MixingProgram = make_mixing_program(
            topology_schedule if topology_schedule is not None else topology,
            strategy=mixing_strategy, rounds=consensus_rounds,
            error_feedback=error_feedback, exchange=exchange,
            momentum_mixing=momentum_mixing,
            staleness=staleness, faults=fault_schedule,
            compressor=compressor, sparse_update=sparse_update)
        self.exchange = exchange = self.program.exchange
        self.faults = self.program.faults
        self.comm: CommOps = stacked_comm_ops(topology, interpret=interpret,
                                              exchange=exchange,
                                              program=self.program)
        # non-trivial strategies live on the fused flat-buffer path only —
        # fail here, at config time, not deep inside the first traced step
        engine.check_program_support(optimizer, self.comm)
        stacked = broadcast_to_agents(params, topology.n_agents) if stack else params
        self._program = engine.StepProgram(
            optimizer=optimizer,
            comm=self.comm,
            grad_phase=engine.make_grad_phase(loss_fn, microbatches),
            update_phase=engine.make_update_phase(optimizer, self.comm, schedule),
            schedule=schedule,
            extra_metrics=lambda p: {"consensus_error": consensus_error_pytree(p)},
        )
        self.state = TrainState(params=stacked,
                                opt_state=self._program.init_state(stacked))
        self.history = MetricHistory()
        # recorded for the static checker's alias/donation-coverage pass
        self.donate_argnums = (0, 1) if donate else ()
        self._step_fn = jax.jit(self._program.step_fn,
                                donate_argnums=self.donate_argnums)
        self._eval_fn = jax.jit(self._make_eval())
        # per-step neighbor-exchange cost of the fused flat path (estimate;
        # train_loop reports the cumulative figure alongside steps/sec).
        # k consensus rounds move exactly k x the single-round bytes; a
        # time-varying schedule amortizes its period-mean degree; momentum
        # mixing doubles the payload trees per transfer.  FedAvg pays a
        # whole-model all-reduce once per local_steps (the collective is
        # gated on the sync step), amortized here as bytes/E per step.
        self.wire_bytes_per_step = 0
        if optimizer.uses_consensus:
            self.wire_bytes_per_step = exchange_bytes_per_step(
                flatbuf.make_flat_spec(stacked, lead=1),
                self.program.schedule if not self.program.schedule.is_static
                else topology,
                exchange, rounds=self.program.rounds,
                payloads=self.program.n_payloads,
                program=self.program)["per_step_bytes"]
        elif isinstance(optimizer, FedAvg):
            self.wire_bytes_per_step = mean_exchange_bytes_per_step(
                flatbuf.make_flat_spec(stacked, lead=1), topology.n_agents,
                period=optimizer.local_steps,
                payloads=2 if optimizer.mu else 1)["per_step_bytes"]

    def _make_eval(self):
        loss_fn = self.loss_fn

        def evaluate(params, batch):
            """Every agent evaluated on the same (global) eval batch."""

            def agent_eval(p):
                loss, metrics = loss_fn(p, batch)
                return loss, metrics

            losses, metrics = jax.vmap(agent_eval)(params)
            out = {"loss_mean": jnp.mean(losses), "loss_var": jnp.var(losses)}
            for k, v in metrics.items():
                out[f"{k}_mean"] = jnp.mean(v)
                out[f"{k}_var"] = jnp.var(v)
            return out

        return evaluate

    # ------------------------------------------------------------------
    def step(self, batch: Dict[str, np.ndarray]) -> Dict[str, float]:
        p, o, metrics = self._step_fn(self.state.params, self.state.opt_state, batch)
        self.state = TrainState(params=p, opt_state=o, step=self.state.step + 1)
        out = {k: float(v) for k, v in metrics.items()}
        self.history.log(self.state.step, **out)
        return out

    def evaluate(self, batch: Dict[str, np.ndarray]) -> Dict[str, float]:
        return {k: float(v) for k, v in self._eval_fn(self.state.params, batch).items()}

    def mean_params(self) -> PyTree:
        """The consensus (agent-averaged) model."""
        return jax.tree.map(lambda x: jnp.mean(x, axis=0), self.state.params)

    def agent_params(self, j: int) -> PyTree:
        return jax.tree.map(lambda x: x[j], self.state.params)


def train_loop(
    trainer: CollaborativeTrainer,
    batches,
    n_steps: int,
    *,
    eval_batch: Optional[Dict[str, np.ndarray]] = None,
    eval_every: int = 0,
    log_every: int = 0,
    printer: Optional[Callable[[str], None]] = None,
) -> MetricHistory:
    printer = printer or (lambda s: None)
    wire_per_step = getattr(trainer, "wire_bytes_per_step", 0)
    t0 = time.time()
    for i in range(n_steps):
        m = trainer.step(next(batches))
        if log_every and (i + 1) % log_every == 0:
            dt = time.time() - t0
            sps = (i + 1) / dt if dt > 0 else float("inf")
            wire = ""
            if wire_per_step:
                wire = f" wire={wire_per_step * (i + 1) / 1e6:.1f}MB"
            printer(f"step {i+1}/{n_steps} loss={m['loss']:.4f} "
                    f"cons={m['consensus_error']:.3e} {sps:.2f} steps/s"
                    f"{wire} ({dt:.1f}s)")
        if eval_batch is not None and eval_every and (i + 1) % eval_every == 0:
            em = trainer.evaluate(eval_batch)
            trainer.history.log(trainer.state.step, **{f"eval_{k}": v for k, v in em.items()})
    return trainer.history
