"""Core of the reproduction: consensus-based distributed SGD (CDSGD).

The paper's contribution — decentralized data-parallel SGD over a fixed
communication topology — lives here, independent of any model family:

* :mod:`repro.core.topology` — graphs, agent-interaction matrices ``Pi``
  (Assumption 2), spectral quantities.
* :mod:`repro.core.consensus` — the mixing operator ``w = Pi x`` in
  stacked-simulation and sharded (ppermute / all_gather) forms.
* :mod:`repro.core.optim` — CDSGD / CDMSGD (Polyak, Nesterov) / CDAdam and
  the baselines (centralized SGD/MSGD, FedAvg).
* :mod:`repro.core.engine` — the StepProgram phase pipeline (grad / pack /
  quantize / exchange / update) shared by both execution modes, with the
  ``sync`` | ``overlap`` exchange schedules (see ARCHITECTURE.md).
* :mod:`repro.core.schedules` — fixed and diminishing step sizes.
* :mod:`repro.core.lyapunov` — the paper's Lyapunov analysis as code
  (eq. 7-9, Proposition 1, Theorem 1 constants).
"""

from repro.core.topology import Topology, make_topology
from repro.core.consensus import FactoredMix
from repro.core.engine import StepProgram
from repro.core.optim import (
    CDSGD,
    CDMSGD,
    CDMSGDNesterov,
    CDAdam,
    CentralizedSGD,
    CentralizedMSGD,
    FedAvg,
    CommOps,
    make_optimizer,
    stacked_comm_ops,
    sharded_comm_ops,
    factored_comm_ops,
    identity_comm_ops,
)
from repro.core import schedules, lyapunov

__all__ = [
    "Topology",
    "make_topology",
    "FactoredMix",
    "StepProgram",
    "CDSGD",
    "CDMSGD",
    "CDMSGDNesterov",
    "CDAdam",
    "CentralizedSGD",
    "CentralizedMSGD",
    "FedAvg",
    "CommOps",
    "make_optimizer",
    "stacked_comm_ops",
    "sharded_comm_ops",
    "factored_comm_ops",
    "identity_comm_ops",
    "schedules",
    "lyapunov",
]
