"""Deterministic fault injection for the bounded-staleness exchange.

A :class:`FaultSchedule` is the communication-fault analog of
:class:`repro.core.topology.TopologySchedule`: a host-precomputed, periodic
table of per-step per-agent faults that both execution modes index with the
optimizer step, so stacked and subprocess-sharded runs inject *identically*
(the tables are plain numpy baked into the jitted step as constants — no
device randomness, no run-to-run drift).

Two fault kinds, matching what the depth-``S`` staleness ring tolerates
(see ARCHITECTURE.md "Exchange schedules"):

* **straggler** — ``straggle[t, j]`` means agent ``j``'s freshest payload
  misses consumption step ``t``: its outgoing wire slot goes one step
  staler instead of refreshing.  A window of ``k`` consecutive straggle
  bits makes the agent's contributed payload up to ``k + 1`` steps stale;
  once the staleness would exceed the ring depth ``S`` the receivers mask
  the agent out entirely (arrival-masked weight renormalization).
* **link drop** — ``linkup[t, i, j] = False`` means the directed link
  ``i <- j`` is down at step ``t``: receiver ``i`` masks sender ``j``
  regardless of staleness and renormalizes ``j``'s mixing weight into its
  own self term (row-stochasticity preserved).

The tables are periodic; windowed events (``stall:``/``droplink:``) repeat
every cycle, so give them a period at least as long as the run when a
one-shot fault is intended.  ``straggle[0]`` must be all-False (every agent
publishes at the cycle start) — this makes the sender-age recurrence
exactly periodic, so the ring-index/arrival tables the mixing weights are
built from agree bit-for-bit with the ``send_age`` counters carried in
``OptState.wire`` (asserted in tests/test_faults.py).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

# seed stride between steps of a seeded (random:) fault table; mirrors
# repro.core.topology._SCHEDULE_SEED_STRIDE so fault streams and gossip
# topology streams with the same base seed still decorrelate per step
_FAULT_SEED_STRIDE = 1000003

# hard cap on the (lcm-combined) table period: the masked weight stacks are
# materialized per step, so an accidental lcm blowup should fail loudly
MAX_FAULT_PERIOD = 8192


@dataclasses.dataclass(frozen=True, eq=False)
class FaultSchedule:
    """Periodic per-step fault masks (see module docstring).

    ``straggle``: ``(period, n_agents)`` bool — sender ``j`` fails to
    publish a fresh payload for consumption step ``t``.
    ``linkup``: ``(period, n_agents, n_agents)`` bool — directed link
    ``i <- j`` is up at step ``t`` (diagonal always True: the self term
    never crosses the wire and is never faulted).
    """

    name: str
    n_agents: int
    period: int
    straggle: np.ndarray
    linkup: np.ndarray
    seed: int = 0

    @property
    def is_trivial(self) -> bool:
        """No straggles, no drops — the fault-free schedule."""
        return bool((~self.straggle).all() and self.linkup.all())

    def validate(self) -> None:
        a, p = self.n_agents, self.period
        if self.straggle.shape != (p, a):
            raise ValueError(f"straggle shape {self.straggle.shape} != {(p, a)}")
        if self.linkup.shape != (p, a, a):
            raise ValueError(f"linkup shape {self.linkup.shape} != {(p, a, a)}")
        if not all(self.linkup[t].diagonal().all() for t in range(p)):
            raise ValueError("linkup diagonal must be True: the self term "
                             "never crosses the wire and cannot be dropped")
        if self.straggle[0].any():
            raise ValueError(
                "straggle[0] must be all-False (every agent publishes at the "
                "cycle start); shift the straggle window to start >= 1 — this "
                "keeps the sender-age recurrence exactly periodic so the "
                "precomputed arrival tables match the carried age counters")

    def tables(self, staleness: int) -> dict:
        """Derived per-step tables at ring depth ``staleness`` (host numpy).

        * ``send_age (period, A) int32`` — the age of the ring slot sender
          ``j`` contributes at consumption step ``t`` (0 = the normal
          one-step-stale generation ``t - 1``), clamped at ``staleness``
          (the sentinel: nothing within the ring arrived).  This is the
          steady state of the counter recurrence the runtime carries:
          ``a_t = a_{t-1} + 1`` if straggling else ``0``.
        * ``arrive (period, A, A) bool`` — receiver ``i`` uses sender
          ``j``'s payload at step ``t``: the link is up AND the contributed
          slot is within the ring (``send_age < staleness``).  Diagonal
          True.  Mixing weights renormalize over exactly these arrivals.
        """
        if not isinstance(staleness, int) or staleness < 1:
            raise ValueError(f"staleness must be an int >= 1, got {staleness!r}")
        self.validate()
        p, a = self.period, self.n_agents
        send_age = np.zeros((p, a), np.int32)
        for t in range(1, p):
            send_age[t] = np.where(self.straggle[t],
                                   np.minimum(send_age[t - 1] + 1, staleness), 0)
        arrive = self.linkup & (send_age < staleness)[:, None, :]
        for t in range(p):
            np.fill_diagonal(arrive[t], True)
        return {"send_age": send_age, "arrive": arrive}

    def arrival_accounting(self, staleness: int, steps: Optional[int] = None) -> list:
        """Per-step arrival record (the dryrun's staleness accounting).

        One dict per step over ``steps`` (default: one period): how many of
        the ``A * (A - 1)`` directed off-diagonal links delivered, how many
        were masked, and the max/mean staleness (in steps; fresh overlap
        payloads have staleness 1) among the arrived links.
        """
        tb = self.tables(staleness)
        steps = self.period if steps is None else int(steps)
        off = ~np.eye(self.n_agents, dtype=bool)
        out = []
        for t in range(steps):
            tp = t % self.period
            arr = tb["arrive"][tp] & off
            stale = (tb["send_age"][tp] + 1)[None, :] * arr
            n_arr = int(arr.sum())
            out.append({
                "step": t,
                "arrived_links": n_arr,
                "masked_links": int(off.sum()) - n_arr,
                "max_staleness": int(stale.max()) if n_arr else 0,
                "mean_staleness": float(stale.sum() / n_arr) if n_arr else 0.0,
            })
        return out

    def describe(self) -> dict:
        off = ~np.eye(self.n_agents, dtype=bool)
        return {
            "spec": self.name,
            "n_agents": self.n_agents,
            "period": self.period,
            "seed": self.seed,
            "straggle_fraction": float(self.straggle.mean()),
            "drop_fraction": float((~self.linkup & off).mean()),
        }


def trivial_faults(n_agents: int, period: int = 1) -> FaultSchedule:
    """The all-arrive schedule (staleness > 1 with no injected faults)."""
    return FaultSchedule(
        name="none", n_agents=n_agents, period=period,
        straggle=np.zeros((period, n_agents), bool),
        linkup=np.ones((period, n_agents, n_agents), bool))


def arrival_masked_pi(pi: np.ndarray, arrive: np.ndarray) -> np.ndarray:
    """THE arrival-mask renormalization rule, as a dense row-stochastic Pi.

    Off-diagonal weights of non-arrived neighbors are zeroed and their mass
    folds into the receiver's self weight — row sums are preserved exactly
    and the self term stays fresh.  Both execution modes' masked weight
    stacks and the Lyapunov bound build from this one function.
    """
    pi = np.asarray(pi, np.float64)
    n = pi.shape[0]
    off = pi * (1.0 - np.eye(n))
    m = np.asarray(arrive, np.float64)
    w_self = np.diag(pi) + np.sum(off * (1.0 - m), axis=1)
    out = off * m
    out[np.arange(n), np.arange(n)] = w_self
    return out


def _int(v: str, what: str) -> int:
    try:
        return int(v)
    except ValueError:
        raise ValueError(f"fault spec: {what} must be an int, got {v!r}")


def make_fault_schedule(spec: Optional[str], n_agents: int, *,
                        period: Optional[int] = None,
                        seed: int = 0) -> Optional[FaultSchedule]:
    """Build a :class:`FaultSchedule` from a spec string.

    Comma-joined parts; the table period is the lcm of the parts' natural
    periods (and ``period=`` when given).  Grammar:

    * ``straggler:<agent>:<delay>`` — periodically slow agent: publishes
      once every ``delay + 1`` steps (straggles the other ``delay``), so
      its contributed payload cycles through staleness ``1..delay + 1``.
    * ``stall:<agent>:<start>:<len>`` — windowed stall: the agent straggles
      steps ``[start, start + len)`` of every cycle (``start >= 1``).
    * ``drop:<i>:<j>`` — directed link ``i <- j`` down permanently.
    * ``droplink:<i>:<j>:<start>:<len>`` — windowed directed link drop.
    * ``random:<p>:<T>`` — iid off-diagonal link drops with probability
      ``p`` over a period of ``T`` steps, seeded per step like
      ``TopologySchedule``'s gossip factory (``default_rng(seed +
      STRIDE * t)``) so every execution mode draws the same masks.

    ``spec=None`` / ``"none"`` / ``""`` returns ``None`` (no fault layer).
    """
    if spec is None or spec in ("", "none"):
        return None
    parts = [p.strip() for p in str(spec).split(",") if p.strip()]
    if not parts:
        return None

    natural = [int(period)] if period else []
    parsed = []
    for part in parts:
        f = part.split(":")
        kind = f[0]
        if kind == "straggler" and len(f) == 3:
            agent, delay = _int(f[1], "agent"), _int(f[2], "delay")
            if delay < 1:
                raise ValueError(f"straggler delay must be >= 1, got {delay}")
            parsed.append(("straggler", agent, delay))
            natural.append(delay + 1)
        elif kind == "stall" and len(f) == 4:
            agent, start, ln = (_int(f[1], "agent"), _int(f[2], "start"),
                                _int(f[3], "len"))
            if start < 1:
                raise ValueError(
                    f"stall start must be >= 1 (agents publish at the cycle "
                    f"start), got {start}")
            parsed.append(("stall", agent, start, ln))
            natural.append(start + ln)
        elif kind == "drop" and len(f) == 3:
            i, j = _int(f[1], "receiver"), _int(f[2], "sender")
            parsed.append(("drop", i, j))
            natural.append(1)
        elif kind == "droplink" and len(f) == 5:
            i, j, start, ln = (_int(f[1], "receiver"), _int(f[2], "sender"),
                               _int(f[3], "start"), _int(f[4], "len"))
            parsed.append(("droplink", i, j, start, ln))
            natural.append(start + ln)
        elif kind == "random" and len(f) == 3:
            try:
                p = float(f[1])
            except ValueError:
                raise ValueError(f"fault spec: drop probability must be a "
                                 f"float, got {f[1]!r}")
            t_per = _int(f[2], "period")
            if not 0.0 <= p <= 1.0 or t_per < 1:
                raise ValueError(f"random:<p>:<T> needs 0 <= p <= 1 and "
                                 f"T >= 1, got p={p}, T={t_per}")
            parsed.append(("random", p, t_per))
            natural.append(t_per)
        else:
            raise ValueError(
                f"unknown fault spec part {part!r}; expected "
                "straggler:<agent>:<delay>, stall:<agent>:<start>:<len>, "
                "drop:<i>:<j>, droplink:<i>:<j>:<start>:<len>, or "
                "random:<p>:<T>")

    full = math.lcm(*natural) if natural else 1
    if full > MAX_FAULT_PERIOD:
        raise ValueError(f"fault schedule period lcm {full} exceeds "
                         f"{MAX_FAULT_PERIOD}; shorten the windows or pass "
                         "period= explicitly")

    def _agent_ok(a, what="agent"):
        if not 0 <= a < n_agents:
            raise ValueError(f"fault spec {what} {a} out of range for "
                             f"{n_agents} agents")

    straggle = np.zeros((full, n_agents), bool)
    linkup = np.ones((full, n_agents, n_agents), bool)
    for item in parsed:
        kind = item[0]
        if kind == "straggler":
            _, agent, delay = item
            _agent_ok(agent)
            for t in range(full):
                straggle[t, agent] |= (t % (delay + 1)) != 0
        elif kind == "stall":
            _, agent, start, ln = item
            _agent_ok(agent)
            nat = start + ln
            for t in range(full):
                straggle[t, agent] |= start <= (t % nat) < start + ln
        elif kind in ("drop", "droplink"):
            i, j = item[1], item[2]
            _agent_ok(i, "receiver")
            _agent_ok(j, "sender")
            if i == j:
                raise ValueError("cannot drop the self link (the self term "
                                 "never crosses the wire)")
            if kind == "drop":
                linkup[:, i, j] = False
            else:
                start, ln = item[3], item[4]
                nat = start + ln
                for t in range(full):
                    if start <= (t % nat) < start + ln:
                        linkup[t, i, j] = False
        elif kind == "random":
            _, p, t_per = item
            off = ~np.eye(n_agents, dtype=bool)
            for t in range(full):
                rng = np.random.default_rng(seed + _FAULT_SEED_STRIDE
                                            * (t % t_per))
                drops = (rng.random((n_agents, n_agents)) < p) & off
                linkup[t] &= ~drops

    sched = FaultSchedule(name=str(spec), n_agents=n_agents, period=full,
                          straggle=straggle, linkup=linkup, seed=seed)
    sched.validate()
    return sched
