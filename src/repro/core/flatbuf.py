"""Flat parameter buffers: pack a pytree into dtype-bucketed (rows, 128) tiles.

The consensus optimizers (CDSGD family) are purely memory-bound elementwise
updates over the *whole* parameter vector.  Applying them leaf-by-leaf costs
one kernel launch + one padded HBM sweep + (sharded) one ``ppermute``
collective *per leaf per neighbor* — hundreds of launches and collectives
per step for a transformer.  This module gives every consensus path a flat
view instead:

* leaves are grouped into **dtype buckets** (bf16 params never mix bits with
  f32 gains/biases), preserving first-appearance order;
* within a bucket every leaf is padded up to a whole number of 128-wide rows
  and assigned a static ``row_start`` — so the packed buffer is a
  ``(*lead, rows, 128)`` array whose layout is described entirely by
  compile-time metadata (:class:`FlatSpec`);
* ``pack`` is a cast + reshape + single concatenate per bucket (reshape-only
  when the bucket has one leaf of aligned size); ``unpack`` is a static
  slice + reshape per leaf — no gathers, no scatter, no host work.

``lead`` counts leading *replica* axes excluded from flattening: the stacked
simulation packs ``(A, ...)`` leaves with ``lead=1`` into ``(A, rows, 128)``
buffers; the sharded trainer packs its local shard (agent axis of size 1)
the same way and squeezes.

The fused update kernels in :mod:`repro.kernels.consensus_update` then walk
one bucket in a single ``pallas_call``, and the sharded circulant exchange
issues one ``lax.ppermute`` per shift offset per bucket — instead of one
per leaf — which is the whole-step communication pattern the paper's
fixed-topology argument (eq. 5/6) assumes.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Sequence, Tuple

import jax
import jax.numpy as jnp

PyTree = Any

LANE = 128


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """Placement of one pytree leaf inside its dtype bucket."""

    index: int                      # position in the flattened-tree order
    shape: Tuple[int, ...]          # per-replica shape (lead axes excluded)
    size: int                       # prod(shape)
    row_start: int                  # first 128-wide row in the bucket
    rows: int                       # rows occupied (size padded up to LANE)


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    dtype: Any                      # canonical jnp dtype of the bucket
    rows: int                       # total rows = sum(slot.rows)
    slots: Tuple[LeafSlot, ...]

    @property
    def n_padded(self) -> int:
        return self.rows * LANE

    @property
    def n_real(self) -> int:
        return sum(s.size for s in self.slots)

    @property
    def bytes(self) -> int:
        return self.n_padded * jnp.dtype(self.dtype).itemsize


@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Static packing metadata for one pytree structure."""

    treedef: Any
    n_leaves: int
    lead: int                       # leading replica axes excluded from packing
    buckets: Tuple[BucketSpec, ...]

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    @property
    def total_bytes(self) -> int:
        return sum(b.bytes for b in self.buckets)


def make_flat_spec(tree: PyTree, lead: int = 0) -> FlatSpec:
    """Build the bucketed layout for ``tree`` (shapes/dtypes only, no data)."""
    leaves, treedef = jax.tree.flatten(tree)
    order: List[Any] = []           # bucket dtypes in first-appearance order
    grouped = {}
    for index, leaf in enumerate(leaves):
        dt = jnp.dtype(leaf.dtype)
        shape = tuple(leaf.shape[lead:])
        size = 1
        for d in shape:
            size *= d
        if dt not in grouped:
            grouped[dt] = []
            order.append(dt)
        grouped[dt].append((index, shape, size))
    buckets = []
    for dt in order:
        slots = []
        row = 0
        for index, shape, size in grouped[dt]:
            rows = -(-size // LANE)
            slots.append(LeafSlot(index=index, shape=shape, size=size,
                                  row_start=row, rows=rows))
            row += rows
        buckets.append(BucketSpec(dtype=dt, rows=row, slots=tuple(slots)))
    return FlatSpec(treedef=treedef, n_leaves=len(leaves), lead=lead,
                    buckets=tuple(buckets))


def pack(tree: PyTree, spec: FlatSpec) -> List[jnp.ndarray]:
    """Pack ``tree`` into one ``(*lead, rows, 128)`` buffer per dtype bucket.

    Leaves are cast to their bucket dtype (grads/momenta packed against a
    parameter spec inherit the unfused ``g.astype(param.dtype)`` semantics).
    """
    leaves, treedef = jax.tree.flatten(tree)
    if treedef != spec.treedef:
        raise ValueError(f"tree structure {treedef} != spec structure {spec.treedef}")
    out = []
    for bucket in spec.buckets:
        pieces = []
        lead_shape = None
        for slot in bucket.slots:
            x = leaves[slot.index]
            if tuple(x.shape[spec.lead:]) != slot.shape:
                raise ValueError(
                    f"leaf {slot.index}: shape {x.shape} != spec {slot.shape} "
                    f"(lead={spec.lead})")
            lead_shape = tuple(x.shape[:spec.lead])
            flat = x.astype(bucket.dtype).reshape(lead_shape + (slot.size,))
            padding = slot.rows * LANE - slot.size
            if padding:
                flat = jnp.pad(flat, [(0, 0)] * spec.lead + [(0, padding)])
            pieces.append(flat)
        buf = pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces, axis=-1)
        out.append(buf.reshape(lead_shape + (bucket.rows, LANE)))
    return out


def unpack(bufs: Sequence[jnp.ndarray], spec: FlatSpec) -> PyTree:
    """Inverse of :func:`pack`: static slice + reshape per leaf."""
    if len(bufs) != spec.n_buckets:
        raise ValueError(f"{len(bufs)} buffers != {spec.n_buckets} buckets")
    leaves: List[Any] = [None] * spec.n_leaves
    for bucket, buf in zip(spec.buckets, bufs):
        lead_shape = tuple(buf.shape[:-2])
        flat = buf.reshape(lead_shape + (bucket.rows * LANE,))
        for slot in bucket.slots:
            start = slot.row_start * LANE
            piece = flat[..., start:start + slot.size]
            leaves[slot.index] = piece.reshape(lead_shape + slot.shape)
    return jax.tree.unflatten(spec.treedef, leaves)
