"""Flat parameter buffers: pack a pytree into dtype-bucketed (rows, 128) tiles.

The consensus optimizers (CDSGD family) are purely memory-bound elementwise
updates over the *whole* parameter vector.  Applying them leaf-by-leaf costs
one kernel launch + one padded HBM sweep + (sharded) one ``ppermute``
collective *per leaf per neighbor* — hundreds of launches and collectives
per step for a transformer.  This module gives every consensus path a flat
view instead:

* leaves are grouped into **dtype buckets** (bf16 params never mix bits with
  f32 gains/biases), preserving first-appearance order;
* within a bucket leaves are packed **contiguously** at static element
  ``offset``\\ s; only the bucket tail is zero-padded up to a whole number of
  128-wide rows — so the packed buffer is a ``(*lead, rows, 128)`` array
  whose layout is described entirely by compile-time metadata
  (:class:`FlatSpec`);
* ``pack`` is a cast + reshape + **one** concatenate + **one** tail pad per
  bucket (reshape-only when the bucket is a single 128-aligned leaf);
  ``unpack`` is a static slice + reshape per leaf — no gathers, no scatter,
  no host work.

``lead`` counts leading *replica* axes excluded from flattening: the stacked
simulation packs ``(A, ...)`` leaves with ``lead=1`` into ``(A, rows, 128)``
buffers; the sharded trainer packs its local shard (agent axis of size 1)
the same way and squeezes.

:func:`make_flat_spec` memoizes by ``(treedef, shapes, dtypes, lead)`` so
retraced steps reuse the same slot metadata instead of rebuilding it.

The fused update kernels in :mod:`repro.kernels.consensus_update` then walk
one bucket in a single ``pallas_call``, and the sharded circulant exchange
issues one ``lax.ppermute`` per shift offset per bucket — instead of one
per leaf — which is the whole-step communication pattern the paper's
fixed-topology argument (eq. 5/6) assumes.

Exchange precision
------------------
What each ``ppermute`` carries is selectable (``FlatComm(exchange=...)`` in
:mod:`repro.core.consensus`): ``"f32"`` moves the native bucket bytes,
``"bf16"`` halves f32 buckets, and ``"int8"`` / ``"fp8"`` move one byte per
element plus one f32 scale per 128-lane row (stochastic-rounding
quantization; dequantized in-register inside the fused kernels).
:meth:`FlatSpec.exchange_bytes` is the bytes-on-wire estimator used by the
benchmarks, examples and the dryrun to report per-step exchange cost.

Because leaves pack contiguously, a 128-lane row at a leaf boundary can
span two leaves, and a quantized exchange then shares one scale across
them — a small-magnitude leaf adjacent to a large-magnitude one absorbs
rounding noise proportional to the neighbor's row amax in that row.  At
most ``n_leaves - 1`` of the bucket's rows are affected; the documented
int8 trajectory tolerances (tests/test_flatbuf_fused.py,
tests/test_sharded.py) are measured on real mixed-magnitude models and
include this effect.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

PyTree = Any

LANE = 128

# bytes per element moved over the wire, per exchange precision; quantized
# exchanges additionally move one f32 scale per LANE-wide row (see
# `BucketSpec.exchange_bytes`).  "f32" means *native* bucket precision.
EXCHANGE_DTYPES = ("f32", "bf16", "int8", "fp8")


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """Placement of one pytree leaf inside its dtype bucket."""

    index: int                      # position in the flattened-tree order
    shape: Tuple[int, ...]          # per-replica shape (lead axes excluded)
    size: int                       # prod(shape)
    offset: int                     # element offset in the flattened bucket


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    dtype: Any                      # canonical jnp dtype of the bucket
    rows: int                       # ceil(sum(slot.size) / LANE)
    slots: Tuple[LeafSlot, ...]

    @property
    def n_padded(self) -> int:
        return self.rows * LANE

    @property
    def n_real(self) -> int:
        return sum(s.size for s in self.slots)

    @property
    def bytes(self) -> int:
        return self.n_padded * jnp.dtype(self.dtype).itemsize

    def exchange_bytes(self, exchange: str = "f32") -> int:
        """Bytes one neighbor transfer of this bucket puts on the wire."""
        if exchange == "f32":               # native bucket precision
            return self.bytes
        if exchange == "bf16":
            return self.n_padded * min(2, jnp.dtype(self.dtype).itemsize)
        if exchange in ("int8", "fp8"):
            # 1 byte/element + one f32 scale per 128-lane row
            return self.n_padded + self.rows * 4
        raise ValueError(f"unknown exchange precision {exchange!r}; "
                         f"expected one of {EXCHANGE_DTYPES}")


@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Static packing metadata for one pytree structure."""

    treedef: Any
    n_leaves: int
    lead: int                       # leading replica axes excluded from packing
    buckets: Tuple[BucketSpec, ...]

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    @property
    def total_bytes(self) -> int:
        return sum(b.bytes for b in self.buckets)

    def exchange_bytes(self, exchange: str = "f32") -> int:
        """Bytes-on-wire for ONE neighbor transfer of the whole model."""
        return sum(b.exchange_bytes(exchange) for b in self.buckets)


# spec cache: keyed on everything make_flat_spec reads — retraced steps hand
# in fresh tracers but identical (treedef, shapes, dtypes, lead) signatures.
_SPEC_CACHE: Dict[Any, FlatSpec] = {}


def make_flat_spec(tree: PyTree, lead: int = 0) -> FlatSpec:
    """Build the bucketed layout for ``tree`` (shapes/dtypes only, no data).

    Memoized: repeated calls with the same structure/shapes/dtypes return
    the identical :class:`FlatSpec` object.
    """
    leaves, treedef = jax.tree.flatten(tree)
    key = (treedef, lead,
           tuple((tuple(x.shape), jnp.dtype(x.dtype).name) for x in leaves))
    cached = _SPEC_CACHE.get(key)
    if cached is not None:
        return cached
    order: List[Any] = []           # bucket dtypes in first-appearance order
    grouped = {}
    for index, leaf in enumerate(leaves):
        dt = jnp.dtype(leaf.dtype)
        shape = tuple(leaf.shape[lead:])
        size = 1
        for d in shape:
            size *= d
        if dt not in grouped:
            grouped[dt] = []
            order.append(dt)
        grouped[dt].append((index, shape, size))
    buckets = []
    for dt in order:
        slots = []
        offset = 0
        for index, shape, size in grouped[dt]:
            slots.append(LeafSlot(index=index, shape=shape, size=size,
                                  offset=offset))
            offset += size
        rows = -(-offset // LANE)
        buckets.append(BucketSpec(dtype=dt, rows=rows, slots=tuple(slots)))
    spec = FlatSpec(treedef=treedef, n_leaves=len(leaves), lead=lead,
                    buckets=tuple(buckets))
    _SPEC_CACHE[key] = spec
    return spec


def pack(tree: PyTree, spec: FlatSpec) -> List[jnp.ndarray]:
    """Pack ``tree`` into one ``(*lead, rows, 128)`` buffer per dtype bucket.

    Leaves are cast to their bucket dtype (grads/momenta packed against a
    parameter spec inherit the unfused ``g.astype(param.dtype)`` semantics).
    Each bucket is ONE concatenate of the flattened leaves plus ONE tail pad
    up to the row boundary; a single 128-aligned leaf is a pure reshape.
    """
    leaves, treedef = jax.tree.flatten(tree)
    if treedef != spec.treedef:
        raise ValueError(f"tree structure {treedef} != spec structure {spec.treedef}")
    out = []
    for bucket in spec.buckets:
        pieces = []
        lead_shape = None
        for slot in bucket.slots:
            x = leaves[slot.index]
            if tuple(x.shape[spec.lead:]) != slot.shape:
                raise ValueError(
                    f"leaf {slot.index}: shape {x.shape} != spec {slot.shape} "
                    f"(lead={spec.lead})")
            lead_shape = tuple(x.shape[:spec.lead])
            pieces.append(x.astype(bucket.dtype).reshape(lead_shape + (slot.size,)))
        flat = pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces, axis=-1)
        padding = bucket.n_padded - bucket.n_real
        if padding:
            flat = jnp.pad(flat, [(0, 0)] * spec.lead + [(0, padding)])
        out.append(flat.reshape(lead_shape + (bucket.rows, LANE)))
    return out


def unpack(bufs: Sequence[jnp.ndarray], spec: FlatSpec) -> PyTree:
    """Inverse of :func:`pack`: static slice + reshape per leaf."""
    if len(bufs) != spec.n_buckets:
        raise ValueError(f"{len(bufs)} buffers != {spec.n_buckets} buckets")
    leaves: List[Any] = [None] * spec.n_leaves
    for bucket, buf in zip(spec.buckets, bufs):
        lead_shape = tuple(buf.shape[:-2])
        flat = buf.reshape(lead_shape + (bucket.rows * LANE,))
        for slot in bucket.slots:
            piece = flat[..., slot.offset:slot.offset + slot.size]
            leaves[slot.index] = piece.reshape(lead_shape + slot.shape)
    return jax.tree.unflatten(spec.treedef, leaves)
