"""Lyapunov-function machinery from the paper's analysis (§3.2, §4).

The paper rewrites CDSGD as plain SGD on the Lyapunov function

    V(x, a) = (N/n) 1^T F(x) + (1/2a) ||x||^2_{I-Pi}          (eq. 9)

with the *Stochastic Lyapunov Gradient*

    grad J(x) = g(x) + a^{-1} (I - Pi) x                       (eq. 7)

so that ``x_{k+1} = x_k - a grad J(x_k)`` (eq. 8).  This module implements
V, grad J, the derived constants (gamma_hat, H_hat), and the closed-form
bounds of Proposition 1 / Theorem 1 so tests and benchmarks can check the
*numbers*, not just the trends.

All functions here operate on agent-stacked arrays ``x`` of shape (N, d)
(simulation mode) — the theory is stated in exactly that space.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import Topology


def quadratic_norm(x: jnp.ndarray, m: jnp.ndarray) -> jnp.ndarray:
    """||x||^2_M = <x, M x> with x (N, d), M (N, N)."""
    xf = x.astype(jnp.float32).reshape(x.shape[0], -1)
    return jnp.sum(xf * (m.astype(jnp.float32) @ xf))


def lyapunov_value(sum_f: jnp.ndarray, x: jnp.ndarray, pi: jnp.ndarray, alpha) -> jnp.ndarray:
    """V(x, a) given the already-evaluated objective term (N/n) 1^T F(x)."""
    n_agents = x.shape[0]
    i_minus_pi = jnp.eye(n_agents, dtype=jnp.float32) - pi.astype(jnp.float32)
    return sum_f + quadratic_norm(x, i_minus_pi) / (2.0 * alpha)


def stochastic_lyapunov_gradient(g: jnp.ndarray, x: jnp.ndarray, pi: jnp.ndarray, alpha) -> jnp.ndarray:
    """grad J(x) = g(x) + a^{-1} (I - Pi) x  (eq. 7)."""
    n_agents = x.shape[0]
    xf = x.astype(jnp.float32).reshape(n_agents, -1)
    i_minus_pi = jnp.eye(n_agents, dtype=jnp.float32) - pi.astype(jnp.float32)
    corr = (i_minus_pi @ xf).reshape(x.shape) / alpha
    return g + corr.astype(g.dtype)


def cdsgd_step_via_lyapunov(x: jnp.ndarray, g: jnp.ndarray, pi: jnp.ndarray, alpha) -> jnp.ndarray:
    """x - a grad J(x): must equal ``Pi x - a g`` exactly (eq. 7 == eq. 5).

    Used by tests to verify the paper's central algebraic identity.
    """
    return x - alpha * stochastic_lyapunov_gradient(g, x, pi, alpha)


@dataclasses.dataclass(frozen=True)
class TheoryConstants:
    """Constants of Theorems 1-2 for a given problem + topology + step."""

    gamma_m: float   # max_j smoothness of f_j
    h_m: float       # min_j strong-convexity of f_j
    alpha: float
    lambda2: float
    lambdan: float
    zeta1: float = 1.0   # Assumption 3(a) lower bound (exact gradients: 1)
    q: float = 0.0       # gradient-noise second moment (Assumption 3b)
    qm: float = 1.0      # Q_V + zeta2^2

    @property
    def gamma_hat(self) -> float:
        """gamma_m + a^{-1} (1 - lambda_N(Pi)) — smoothness of V."""
        return self.gamma_m + (1.0 - self.lambdan) / self.alpha

    @property
    def h_hat(self) -> float:
        """H_m + (2a)^{-1} (1 - lambda_2(Pi)) — strong convexity of V."""
        return self.h_m + (1.0 - self.lambda2) / (2.0 * self.alpha)

    @property
    def contraction(self) -> float:
        """Theorem 1 per-step factor ``1 - a H_hat zeta1``."""
        return 1.0 - self.alpha * self.h_hat * self.zeta1

    @property
    def noise_radius(self) -> float:
        """Theorem 1 asymptotic radius ``a gamma_hat Q / (2 H_hat zeta1)``."""
        if self.q == 0.0:
            return 0.0
        return self.alpha * self.gamma_hat * self.q / (2.0 * self.h_hat * self.zeta1)

    @property
    def max_step_size(self) -> float:
        """Sufficient condition (eq. 15 expanded)."""
        return (self.zeta1 - (1.0 - self.lambdan) * self.qm) / (self.gamma_m * self.qm)


def consensus_bound(alpha: float, grad_norm_bound: float, topology: Topology) -> float:
    """Proposition 1 RHS: ``a L / (1 - lambda_2(Pi))``."""
    gap = 1.0 - topology.lambda2
    if gap <= 0:
        return float("inf")
    return alpha * grad_norm_bound / gap


def theorem1_envelope(v1_minus_vstar: float, const: TheoryConstants, steps: int) -> np.ndarray:
    """The full Theorem-1 upper envelope E[V(x_k) - V*] for k = 1..steps."""
    rho = const.contraction
    noise = const.alpha**2 * const.gamma_hat * const.q / 2.0
    out = np.empty(steps)
    acc = v1_minus_vstar
    out[0] = acc
    for k in range(1, steps):
        acc = rho * acc + noise
        out[k] = acc
    return out


# --------------------------------------------------------------------------
# Time-varying / multi-round extensions (Jiang et al. 1805.12120)
# --------------------------------------------------------------------------


def schedule_consensus_bound(alpha: float, grad_norm_bound: float,
                             schedule, rounds: int = 1) -> float:
    """Proposition 1 generalized to a mixing schedule with k inner rounds.

    For time-varying B-connected ``Pi_t`` (and/or ``k`` consensus rounds
    per gradient step) the per-step disagreement contraction is the
    schedule's *effective* ``lambda_2`` — the period-geometric-mean
    disagreement norm of ``prod_t Pi_t^k``
    (:meth:`repro.core.topology.TopologySchedule.effective_lambda2`) —
    so the steady-state consensus radius is

        a L / (1 - lambda_eff(schedule, k))

    which reduces to ``a L / (1 - lambda_2(Pi))`` for the static
    single-round case and is monotonically non-increasing in ``k``
    (more rounds -> smaller lambda_eff -> tighter consensus), the
    consensus side of the consensus-optimality trade-off: each extra round
    costs one more full exchange of wire bytes per step.
    """
    lam = schedule.effective_lambda2(rounds)
    gap = 1.0 - lam
    if gap <= 0:
        return float("inf")
    return alpha * grad_norm_bound / gap


def schedule_theory_constants(alpha: float, gamma_m: float, h_m: float,
                              schedule, rounds: int = 1,
                              **kw) -> TheoryConstants:
    """Theorem-1 constants with the schedule's effective spectrum.

    Substitutes ``lambda_2 -> lambda_eff`` and, for the smoothness side,
    ``lambda_N -> lambda_N(prod)^(1/period)`` lower-bounded at
    ``min_t lambda_N(Pi_t)^rounds`` (the product of symmetric PSD factors
    need not be symmetric; the conservative bound keeps ``gamma_hat`` an
    upper bound).
    """
    lam2 = schedule.effective_lambda2(rounds)
    # eigenvalues of Pi^k are the k-th powers of Pi's, so the floor is the
    # min over POWERED eigenvalues — min(lambda)^k alone is wrong for
    # indefinite Pi at even k ((-0.8)^2 > 0.25^1 etc.)
    lamn = min(float(np.min(np.linalg.eigvalsh(t.pi) ** rounds))
               for t in schedule.topologies)
    return TheoryConstants(gamma_m=gamma_m, h_m=h_m, alpha=alpha,
                           lambda2=lam2, lambdan=lamn, **kw)


# --------------------------------------------------------------------------
# Bounded-staleness / fault-masked consensus (Lian et al. 1705.09056)
# --------------------------------------------------------------------------


def masked_effective_lambda2(topology_or_schedule, faults=None,
                             staleness: int = 1) -> float:
    """Effective disagreement norm of the arrival-masked mixing schedule.

    Builds the per-step *masked* agent-interaction matrices — each
    schedule entry's ``Pi`` with the non-arrived off-diagonal mass folded
    into the self weights, exactly the renormalization the runtime applies
    (:func:`repro.core.faults.arrival_masked_pi` over the fault schedule's
    arrival table at ring depth ``staleness``) — and returns the
    period-geometric-mean disagreement norm of their product, the
    :meth:`~repro.core.topology.TopologySchedule.effective_lambda2`
    construction applied to the faulted sequence.  With no faults this IS
    ``effective_lambda2`` (the mask is all-arrive and the masked ``Pi``
    equals ``Pi``).
    """
    from repro.core.faults import arrival_masked_pi, trivial_faults
    from repro.core.topology import TopologySchedule, fixed_schedule

    if isinstance(topology_or_schedule, Topology):
        schedule = fixed_schedule(topology_or_schedule)
    elif isinstance(topology_or_schedule, TopologySchedule):
        schedule = topology_or_schedule
    else:
        raise TypeError(f"expected Topology or TopologySchedule, got "
                        f"{type(topology_or_schedule).__name__}")
    f = faults or trivial_faults(schedule.n_agents)
    tb = f.tables(staleness)
    period = int(np.lcm(schedule.period, f.period))
    n = schedule.n_agents
    prod = np.eye(n)
    for t in range(period):
        pi = np.asarray(schedule.topologies[t % schedule.period].pi,
                        np.float64)
        prod = arrival_masked_pi(pi, tb["arrive"][t % f.period]) @ prod
    proj = prod @ (np.eye(n) - np.ones((n, n)) / n)
    sigma = float(np.linalg.norm(proj, 2))
    return sigma ** (1.0 / period)


def bounded_staleness_consensus_bound(alpha: float, grad_norm_bound: float,
                                      topology_or_schedule, *,
                                      staleness: int = 1,
                                      faults=None) -> float:
    """Proposition 1 under bounded-staleness arrival-masked mixing.

    With a depth-``S`` staleness ring a consumed neighbor payload lags by
    up to ``S`` steps, so the disagreement a step can inject grows to the
    ``S``-step gradient drift ``a L S``, while the per-step contraction
    degrades to the arrival-masked schedule product — the asynchronous
    decentralized-SGD picture of Lian et al. (1705.09056) specialized to
    this deterministic fault model:

        radius(S) = a L S / (1 - max_{s <= S} lambda_mask(s))

    The contraction takes the worst masked spectrum over ring depths
    ``s <= S`` (an adversary within depth ``S`` may realize any shallower
    arrival pattern), which makes the bound **monotone non-decreasing in
    S** by construction — deeper tolerated staleness never claims a
    tighter radius.  ``staleness=1`` with no faults reduces exactly to
    :func:`schedule_consensus_bound` (``a L / (1 - lambda_eff)``); infinite
    when the masked gap closes (e.g. a fault schedule that disconnects the
    union graph for the whole period).
    """
    if not isinstance(staleness, int) or staleness < 1:
        raise ValueError(f"staleness must be an int >= 1, got {staleness!r}")
    lam = max(masked_effective_lambda2(topology_or_schedule, faults, s)
              for s in range(1, staleness + 1))
    gap = 1.0 - lam
    if gap <= 0:
        return float("inf")
    return alpha * grad_norm_bound * staleness / gap


# --------------------------------------------------------------------------
# Momentum-consensus mixing (Gao & Huang 2010.11166)
# --------------------------------------------------------------------------


def _disagreement_radius(topology_or_schedule, rounds: int = 1) -> float:
    """Modulus of the largest non-principal ``Pi``-mode: the per-step
    disagreement contraction of plain (momentum-free) consensus.

    A :class:`repro.core.topology.TopologySchedule` contributes its
    effective disagreement norm (a spectral-norm upper bound on the
    radius); a fixed :class:`Topology` the exact
    ``max(|lambda_2|, |lambda_N|)`` — ``lambda_N`` can be negative with
    ``|lambda_N| > lambda_2`` (e.g. short rings), and the momentum
    coupling amplifies whichever mode decays slowest.
    """
    if isinstance(topology_or_schedule, Topology):
        lams = np.linalg.eigvalsh(np.asarray(topology_or_schedule.pi,
                                             np.float64))
        return float(np.max(np.abs(lams[:-1])) ** rounds)
    return float(topology_or_schedule.effective_lambda2(rounds))


def momentum_consensus_contraction(topology_or_schedule, mu: float,
                                   momentum_mixing: str = "none",
                                   rounds: int = 1) -> float:
    """Per-step disagreement contraction of the joint ``(x, v)`` dynamics.

    CDMSGD's disagreement subsystem (gradients exogenous) is, per
    ``Pi``-eigenmode ``lam``:

        unmixed (``v' = mu v - a g``):      [[lam, mu ], [0, mu ]]
        mixed   (``v' = mu Pi v - a g``):   [[lam, mu lam], [0, mu lam]]

    both upper triangular, so the spectral radii are ``max(|lam|, mu)``
    and ``max(|lam|, mu |lam|) = |lam|``.  Over the disagreement modes:

    * ``momentum_mixing="none"``  -> ``max(rho_Pi, mu)`` — at large
      momentum (``mu > rho_Pi``) the *momentum* mode gates the rate, and
      the ``mu I`` coupling is non-normal: per-step wire noise injected
      into ``v`` persists ``~1/(1-mu)`` steps while leaking into ``x`` —
      the documented large-lr momentum/quantization instability;
    * ``momentum_mixing="mixed"`` -> ``rho_Pi`` — the momentum buffer
      contracts WITH the consensus (2010.11166), restoring the
      momentum-free CDSGD rate and damping injected noise geometrically
      at the topology's own gap.

    ``rho_Pi`` is :func:`_disagreement_radius` (schedule-aware; ``rounds``
    inner consensus rounds power it).
    """
    if momentum_mixing not in ("none", "mixed"):
        raise ValueError(f"unknown momentum_mixing {momentum_mixing!r}")
    if not 0.0 <= mu < 1.0:
        raise ValueError(f"momentum mu must be in [0, 1), got {mu}")
    rho = _disagreement_radius(topology_or_schedule, rounds)
    if momentum_mixing == "mixed":
        return rho
    return max(rho, float(mu))


def momentum_consensus_bound(alpha: float, grad_norm_bound: float,
                             topology_or_schedule, mu: float,
                             momentum_mixing: str = "none",
                             rounds: int = 1) -> float:
    """Proposition-1-style steady-state consensus radius for CDMSGD:
    ``a L / (1 - rho)`` with the joint-dynamics contraction ``rho`` of
    :func:`momentum_consensus_contraction` — the gap-vs-rate framing of
    1805.12120 extended to the momentum state.  Mixing the momentum can
    only tighten it (``rho_mixed <= rho_unmixed``), strictly whenever
    ``mu > rho_Pi``.
    """
    rho = momentum_consensus_contraction(topology_or_schedule, mu,
                                         momentum_mixing, rounds)
    gap = 1.0 - rho
    if gap <= 0:
        return float("inf")
    return alpha * grad_norm_bound / gap


# --------------------------------------------------------------------------
# Error-feedback compressed consensus (Karimireddy et al. 1901.09847)
# --------------------------------------------------------------------------


def compressor_delta(compressor: str) -> float:
    """Worst-case contraction defect ``delta`` of a wire compressor ``C``:
    the smallest constant with ``||C(x) - x||^2 <= delta ||x||^2``.

    * ``none`` / ``int8`` / ``fp8`` — 0.  The SR quantizers are unbiased
      and their (bounded, scale-relative) noise is already carried by the
      Theorem-1 variance terms, not the EF contraction; in the
      delta-contractive EF framing they sit at ``delta = 0``.
    * ``topk:p`` — ``1 - p``: keeping the top ``k = p d`` magnitudes of a
      ``d``-vector retains at least fraction ``p`` of the energy in the
      worst (flat) case, the classical top-k bound.
    * ``rank:r`` — ``1 - r/128``: a rank-``r`` projection of a
      ``(rows, 128)`` bucket retains at least ``r/128`` of the Frobenius
      energy in the worst (isotropic-spectrum) case; one warm-started
      power iteration only does better on decaying spectra.
    """
    from repro.core.consensus import parse_compressor

    kind, param = parse_compressor(compressor)
    if kind in ("none", "int8", "fp8"):
        return 0.0
    if kind == "topk":
        return 1.0 - float(param)
    assert kind == "rank", kind
    return max(0.0, 1.0 - float(param) / 128.0)


def ef_compressed_consensus_bound(alpha: float, grad_norm_bound: float,
                                  topology_or_schedule, *,
                                  compressor: str = "none",
                                  rounds: int = 1) -> float:
    """Proposition 1 under a delta-contractive EF-compressed wire.

    With error feedback, a biased compressor of contraction defect
    ``delta`` (:func:`compressor_delta`) behaves like the exact exchange
    plus a telescoping residual whose steady-state norm is at most
    ``2 delta / (1 - delta)`` times the per-step update magnitude
    (Karimireddy et al. 1901.09847, Lemma 3 applied to the consensus
    recursion): the residual re-enters the next step's payload, so the
    disagreement radius inflates by exactly that carried mass —

        radius(delta) = [a L / (1 - lambda_eff)] * (1 + 2 delta/(1-delta))

    which reduces **exactly** to :func:`schedule_consensus_bound` (the
    PR 4 EF bound) at ``delta = 0``, grows mildly for ``topk:0.1``
    (``delta = 0.9`` -> 19x) and steeply as ``p -> 0`` — the
    bytes-vs-drift frontier the ``consensus/compressor_frontier``
    microbench measures empirically.  Infinite when the mixing gap closes
    or ``delta = 1`` (a compressor that may drop everything).
    """
    from repro.core.topology import fixed_schedule

    delta = compressor_delta(compressor)
    if delta >= 1.0:
        return float("inf")
    sched = (fixed_schedule(topology_or_schedule)
             if isinstance(topology_or_schedule, Topology)
             else topology_or_schedule)
    base = schedule_consensus_bound(alpha, grad_norm_bound, sched, rounds)
    return base * (1.0 + 2.0 * delta / (1.0 - delta))
