"""gemma3-1b [dense] — 5:1 local:global interleave, 128k context.

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144 [hf:google/gemma-3-1b-pt].
Local layers use a 512-token sliding window; every 6th layer is global.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    attn_kind="local_global",
    window=512,
    local_global_period=6,
    rope_theta=1e6,
    act="gelu_tanh",
    tie_embeddings=True,
    param_dtype="bfloat16",
    source="hf:google/gemma-3-1b-pt",
)
