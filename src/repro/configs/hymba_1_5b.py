"""hymba-1.5b [hybrid] — parallel attention + mamba heads, ssm_state=16.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001 [arXiv:2411.13676].
Attention heads use a sliding window (Hymba mixes SWA + a few global
layers; we use SWA uniformly — noted in DESIGN.md); the Mamba path is
global with O(1) state, which is what keeps long-context decode cheap.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    attn_kind="swa",
    window=1024,
    hybrid=True,
    ssm_kind="mamba",
    ssm_state=16,
    rope_theta=1e4,
    act="silu",
    param_dtype="bfloat16",
    source="arXiv:2411.13676",
)
