"""deepseek-v2-236b [moe] — MLA (kv_lora=512), 2 shared + 160 routed top-6.

60L d_model=5120 128H d_ff_expert=1536 vocab=102400 [arXiv:2405.04434].
First layer uses a dense FFN (d_ff=12288), per the model card.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=12288,                 # dense FFN of the first layer
    vocab_size=102400,
    attn_kind="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    n_experts=160,
    n_shared_experts=2,
    top_k=6,
    d_ff_expert=1536,
    n_dense_layers=1,
    rope_theta=1e4,
    act="silu",
    param_dtype="bfloat16",
    source="arXiv:2405.04434",
)
