"""rwkv6-1.6b [ssm] — Finch, data-dependent decay; attention-free.

24L d_model=2048 d_ff=7168 vocab=65536 [arXiv:2404.05892].
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,                  # 32 WKV heads of size 64
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    attn_kind="none",
    ssm_kind="rwkv6",
    norm_kind="layernorm",
    act="relu2",
    mlp_gated=False,
    param_dtype="bfloat16",
    source="arXiv:2404.05892",
)
