"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 routed top-8.

61L d_model=7168 64H (GQA kv=8) d_ff_expert=2048 vocab=163840
[arXiv:2501.kimi2 per assignment table]. First layer dense (d_ff=18432).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=18432,                 # dense FFN of the first layer
    vocab_size=163840,
    attn_kind="full",
    n_experts=384,
    n_shared_experts=1,
    top_k=8,
    d_ff_expert=2048,
    n_dense_layers=1,
    rope_theta=5e4,
    act="silu",
    param_dtype="bfloat16",
    source="arXiv:2501.kimi2",
)
