"""Architecture + run configuration schema.

One :class:`ArchConfig` describes any of the supported families (dense /
moe / ssm / hybrid / audio / vlm); :func:`ArchConfig.reduced` derives the
CPU-smoke-test variant (2 layers, d_model <= 512, <= 4 experts) required
for every assigned architecture.  :class:`RunConfig` bundles the
CDSGD-specific knobs (agents, topology, optimizer, schedule).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // n_heads

    # attention flavour
    attn_kind: str = "full"          # full | swa | local_global | mla | none
    window: int = 0                  # swa / local layers
    local_global_period: int = 0     # every k-th layer is global (gemma3: 6)

    # MLA (deepseek-v2)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_dense_layers: int = 0          # leading dense-FFN layers (deepseek/kimi: 1)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # SSM / hybrid
    ssm_kind: str = "none"           # rwkv6 | mamba | none
    ssm_state: int = 0
    hybrid: bool = False             # parallel attention + mamba heads (hymba)

    # encoder-decoder (seamless)
    is_encoder_decoder: bool = False
    enc_layers: int = 0

    # modality frontends (stubs per spec carve-out)
    modality: str = "text"           # text | audio | vlm
    frontend_tokens: int = 0         # patches / audio frames fed by the stub
    frontend_dim: int = 0            # embedding dim produced by the stub

    # misc
    rope_theta: float = 1e4
    norm_kind: str = "rmsnorm"       # rmsnorm | layernorm
    act: str = "silu"
    mlp_gated: bool = True
    tie_embeddings: bool = False
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    attn_chunk: int = 512            # blockwise-attention KV chunk
    source: str = ""                 # citation from the assignment table

    # ---- derived -----------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic per-token decode (long_500k eligibility)."""
        return self.ssm_kind != "none" or self.attn_kind in ("swa", "local_global") or self.hybrid

    @property
    def has_decode(self) -> bool:
        return True  # no encoder-only archs in this assignment

    def layer_is_global(self, i: int) -> bool:
        """local_global interleave: every `period`-th layer attends globally."""
        if self.attn_kind != "local_global":
            return True
        p = self.local_global_period
        return (i % p) == (p - 1)

    def param_count(self) -> int:
        """Analytic parameter count (validated against the template)."""
        from repro.nn.transformer import model_template
        from repro.nn.param import count_params
        return count_params(model_template(self))

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if not self.is_moe:
            return self.param_count()
        total = self.param_count()
        per_expert = self.d_ff_expert * self.d_model * (3 if self.mlp_gated else 2)
        n_moe_layers = self.n_layers - self.n_dense_layers
        inactive = n_moe_layers * per_expert * (self.n_experts - self.top_k)
        return total - inactive

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: 2 layers, d_model <= 512, <= 4 experts."""
        d = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        head_dim = 64 if self.attn_kind != "mla" else None
        n_kv = min(self.n_kv_heads, n_heads)
        while n_heads % n_kv:
            n_kv -= 1
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=2,
            enc_layers=2 if self.is_encoder_decoder else 0,
            d_model=d,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4) if self.is_moe else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            top_k=min(self.top_k, 2) if self.is_moe else 0,
            d_ff_expert=min(self.d_ff_expert, 128) if self.is_moe else 0,
            n_dense_layers=min(self.n_dense_layers, 1),
            kv_lora_rank=min(self.kv_lora_rank, 32),
            q_lora_rank=min(self.q_lora_rank, 32),
            qk_nope_head_dim=min(self.qk_nope_head_dim, 32),
            qk_rope_head_dim=min(self.qk_rope_head_dim, 16),
            v_head_dim=min(self.v_head_dim, 32),
            window=min(self.window, 8) if self.window else 0,
            local_global_period=min(self.local_global_period, 2) if self.local_global_period else 0,
            frontend_tokens=min(self.frontend_tokens, 8),
            frontend_dim=min(self.frontend_dim, 64) if self.frontend_dim else 0,
            attn_chunk=16,
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned global input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """CDSGD run settings (shared across architectures)."""

    n_agents: int = 5                    # paper default
    topology: str = "fully_connected"    # paper default
    lazy_beta: Optional[float] = None
    optimizer: str = "cdsgd"
    step_size: float = 0.01              # paper default
    momentum: float = 0.9
    schedule: str = "fixed"              # fixed | diminishing
    diminishing_eps: float = 1.0
    diminishing_t: float = 1.0
    fedavg_local_steps: int = 1          # E (paper comparison uses E=1)
    batch_size: int = 128                # per paper (mini-batch 128)
    seed: int = 0
    non_iid: bool = False                # label-skew partition
