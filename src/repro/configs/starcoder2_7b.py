"""starcoder2-7b [dense] — GQA kv=4, RoPE.

32L d_model=4608 36H d_ff=18432 vocab=49152 [arXiv:2402.19173].
Plain (non-gated) GELU MLP + LayerNorm, per the model card.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    attn_kind="full",
    rope_theta=1e5,
    norm_kind="layernorm",
    act="gelu_tanh",
    mlp_gated=False,
    param_dtype="bfloat16",
    source="arXiv:2402.19173",
)
