"""seamless-m4t-medium [audio] — encoder-decoder, multimodal.

12L (enc) + 12L (dec) d_model=1024 16H (kv=16) d_ff=4096 vocab=256206
[arXiv:2308.11596].  The mel-spectrogram + conv feature extractor is a
STUB per the assignment carve-out: ``input_specs()`` feeds precomputed
frame embeddings of shape (batch, frames, 1024) into the encoder.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,                 # decoder layers
    enc_layers=12,
    is_encoder_decoder=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    attn_kind="full",
    modality="audio",
    frontend_tokens=1024,        # audio frames after the (stubbed) conv stack
    frontend_dim=1024,
    rope_theta=1e4,
    norm_kind="layernorm",
    act="relu",
    mlp_gated=False,
    param_dtype="bfloat16",
    source="arXiv:2308.11596",
)
