"""internvl2-2b [vlm] — InternViT + InternLM2 backbone.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553 [arXiv:2404.16821].
The InternViT vision encoder + MLP projector frontend is a STUB per the
assignment carve-out: ``input_specs()`` provides 256 patch embeddings of
dim 1024 which the trainable projector maps into the LM.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    attn_kind="full",
    modality="vlm",
    frontend_tokens=256,         # ViT patches per image
    frontend_dim=1024,
    rope_theta=1e6,
    act="silu",
    param_dtype="bfloat16",
    source="arXiv:2404.16821",
)
