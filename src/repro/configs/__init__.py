"""Config registry: ``get_config("<arch-id>")`` / ``--arch <id>`` on CLIs.

Ten assigned architectures (public-literature pool) + the paper's own
experiment models (CIFAR CNN, MNIST MLP — see repro.nn.paper_models).
"""

from __future__ import annotations

from typing import Dict, List

from repro.configs.base import ArchConfig, InputShape, INPUT_SHAPES, RunConfig

from repro.configs.deepseek_v2_236b import CONFIG as _deepseek_v2
from repro.configs.kimi_k2_1t_a32b import CONFIG as _kimi_k2
from repro.configs.rwkv6_1_6b import CONFIG as _rwkv6
from repro.configs.granite_3_8b import CONFIG as _granite
from repro.configs.starcoder2_7b import CONFIG as _starcoder2
from repro.configs.gemma3_1b import CONFIG as _gemma3
from repro.configs.hymba_1_5b import CONFIG as _hymba
from repro.configs.h2o_danube_3_4b import CONFIG as _danube
from repro.configs.seamless_m4t_medium import CONFIG as _seamless
from repro.configs.internvl2_2b import CONFIG as _internvl2

ARCH_CONFIGS: Dict[str, ArchConfig] = {
    c.name: c
    for c in [
        _deepseek_v2,
        _kimi_k2,
        _rwkv6,
        _granite,
        _starcoder2,
        _gemma3,
        _hymba,
        _danube,
        _seamless,
        _internvl2,
    ]
}


def get_config(name: str) -> ArchConfig:
    if name.endswith("-reduced"):
        return get_config(name[: -len("-reduced")]).reduced()
    if name not in ARCH_CONFIGS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCH_CONFIGS)}")
    return ARCH_CONFIGS[name]


def list_archs() -> List[str]:
    return sorted(ARCH_CONFIGS)


__all__ = [
    "ArchConfig",
    "InputShape",
    "INPUT_SHAPES",
    "RunConfig",
    "ARCH_CONFIGS",
    "get_config",
    "list_archs",
]
