"""Launch layer: production mesh, sharded steps, dry-run, train/serve CLIs."""
