"""Serving launcher: batched greedy decoding with a KV cache.

On this CPU container use ``--preset tiny``; the same ``decode_step`` is
what the decode dry-run shapes lower on the production mesh.

Example:
  python -m repro.launch.serve --arch gemma3-1b --preset tiny \
      --batch 4 --prompt-len 8 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.nn import (model_template, init_params, init_cache, decode_step,
                          encode_for_decode)

    cfg = get_config(args.arch)
    if args.preset == "tiny":
        cfg = cfg.reduced()

    params = init_params(model_template(cfg), jax.random.PRNGKey(args.seed))
    max_len = args.prompt_len + args.new_tokens
    enc_len = cfg.frontend_tokens if cfg.is_encoder_decoder else 0
    cache = init_cache(cfg, args.batch, max_len, enc_len=enc_len)
    if cfg.is_encoder_decoder:
        fe = jnp.ones((args.batch, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32)
        cache["enc_out"] = encode_for_decode(cfg, params, fe)

    step = jax.jit(lambda p, c, t, i: decode_step(cfg, p, c, t, i))
    rng = np.random.default_rng(args.seed)
    prompt = rng.integers(1, cfg.vocab_size, size=(args.batch, args.prompt_len))

    t0 = time.time()
    tok = jnp.asarray(prompt[:, :1], jnp.int32)
    out_tokens = [np.asarray(tok)]
    for i in range(max_len - 1):
        logits, cache = step(params, cache, tok, jnp.int32(i))
        if i + 1 < args.prompt_len:          # teacher-force the prompt
            tok = jnp.asarray(prompt[:, i + 1 : i + 2], jnp.int32)
        else:
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
    dt = time.time() - t0
    seqs = np.concatenate(out_tokens, axis=1)
    print(f"[serve] {cfg.name}: decoded {args.batch}x{max_len} tokens "
          f"in {dt:.2f}s ({args.batch * max_len / dt:.1f} tok/s on CPU)")
    print("[serve] first sequence:", seqs[0].tolist())


if __name__ == "__main__":
    main()
