import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

The two lines above MUST stay first: jax locks the device count at first
init, and the production meshes need 512 placeholder host devices.  Do not
import this module from tests/benchmarks — they must see 1 device.

Per pair this script:
  1. builds the sharded step (train_step / prefill_step / serve_step),
  2. ``jax.jit(step).lower(**ShapeDtypeStruct inputs).compile()`` —
     allocation-free; success proves the distribution config is coherent,
  3. prints ``memory_analysis()`` (fits-or-not per device) and
     ``cost_analysis()`` (XLA's FLOPs/bytes, loop bodies counted once),
  4. runs the trip-count-aware HLO analysis (collective bytes, dot FLOPs),
  5. derives the three roofline terms and writes
     ``results/dryrun/<arch>__<shape>__<mesh>__<mode>.json``.

Usage:
  python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
  python -m repro.launch.dryrun --all                  # 16x16 baseline grid
  python -m repro.launch.dryrun --all --multi-pod      # 2x16x16 proof
"""

import argparse
import json
import sys
import time
import traceback
import warnings


def _build(arch: str, shape_name: str, *, multi_pod: bool, mode: str,
           mixing: str, optimizer_name: str, topology: str, microbatches: int = 1,
           context_parallel: bool = False, fused: bool = False,
           exchange: str = "f32", schedule: str = "sync",
           mixing_strategy: str = "static", consensus_rounds: int = 1,
           topology_schedule=None, error_feedback: bool = False,
           momentum_mixing: str = "none", staleness: int = 1,
           fault_schedule=None, compressor: str = "none"):
    import jax
    from repro.configs import get_config, INPUT_SHAPES
    from repro.core.optim import make_optimizer
    from repro.launch.mesh import make_production_mesh
    from repro.launch import steps as steps_lib

    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)

    if shape.name == "long_500k" and not cfg.supports_long_context:
        return None, "skip: full-attention arch at 500k decode (DESIGN.md)"

    if shape.kind == "train":
        kw = {"mu": 0.9} if optimizer_name in ("cdmsgd", "cdmsgd_nesterov", "msgd") else {}
        if fused:
            kw["fused"] = True
        opt = make_optimizer(optimizer_name, 0.01, **kw)
        try:
            bundle = steps_lib.build_train_step(
                cfg, shape, mesh, opt, mode=mode, topology_name=topology, mixing=mixing,
                microbatches=microbatches, exchange=exchange, schedule=schedule,
                mixing_strategy=mixing_strategy, consensus_rounds=consensus_rounds,
                topology_schedule=topology_schedule, error_feedback=error_feedback,
                momentum_mixing=momentum_mixing, staleness=staleness,
                fault_schedule=fault_schedule, compressor=compressor)
        except ValueError as e:
            if "agent-only sharding" in str(e):
                # compressed wires don't shard over the production mesh's
                # model axes (yet) — record the skip instead of crashing
                # the sweep; the stacked trainer covers compressed perf
                return None, f"skip: {e}"
            raise
        params = bundle.param_structs(mesh)
        opt_state = bundle.opt_state_structs(mesh, opt)
        args = (params, opt_state, bundle.batch_specs)
        fn = bundle.step_fn
        return (fn, args, mesh, cfg, shape, bundle), None
    elif shape.kind == "prefill":
        bundle = steps_lib.build_prefill_step(cfg, shape, mesh,
                                              context_parallel=context_parallel)
        args = (bundle.param_structs(mesh),) + bundle.input_structs
        fn = bundle.step_fn
    else:
        bundle = steps_lib.build_serve_step(cfg, shape, mesh)
        cache, tokens, cur = bundle.input_structs
        args = (bundle.param_structs(mesh), cache, tokens, cur)
        fn = bundle.step_fn
    return (fn, args, mesh, cfg, shape, None), None


def run_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
             mode: str = "train", mixing: str = "dense",
             optimizer_name: str = "cdmsgd", topology: str = "ring",
             out_dir: str = "results/dryrun", tag: str = "",
             analyze: bool = True, verbose: bool = True, microbatches: int = 1,
             context_parallel: bool = False, fused: bool = False,
             exchange: str = "f32", schedule: str = "sync",
             mixing_strategy: str = "static", consensus_rounds: int = 1,
             topology_schedule=None, error_feedback: bool = False,
             momentum_mixing: str = "none", staleness: int = 1,
             fault_schedule=None, compressor: str = "none"):
    import jax
    from repro.analysis.hlo import analyze_hlo
    from repro.analysis.roofline import model_flops, roofline_from_stats

    mesh_name = "2x16x16" if multi_pod else "16x16"
    label = f"{arch}__{shape_name}__{mesh_name}__{mode}_{mixing}{tag}"
    t0 = time.time()
    built, skip = _build(arch, shape_name, multi_pod=multi_pod, mode=mode,
                         mixing=mixing, optimizer_name=optimizer_name, topology=topology,
                         microbatches=microbatches, context_parallel=context_parallel,
                         fused=fused, exchange=exchange, schedule=schedule,
                         mixing_strategy=mixing_strategy,
                         consensus_rounds=consensus_rounds,
                         topology_schedule=topology_schedule,
                         error_feedback=error_feedback,
                         momentum_mixing=momentum_mixing, staleness=staleness,
                         fault_schedule=fault_schedule, compressor=compressor)
    from repro.analysis.records import DRYRUN_SCHEMA_VERSION
    record = {"version": DRYRUN_SCHEMA_VERSION,
              "arch": arch, "shape": shape_name, "mesh": mesh_name, "mode": mode,
              "mixing": mixing, "topology": topology, "optimizer": optimizer_name,
              "microbatches": microbatches, "exchange": exchange,
              "schedule": schedule, "staleness": staleness,
              "compressor": compressor, "verify": None}
    if skip:
        record["status"] = skip
        _dump(out_dir, label, record)
        if verbose:
            print(f"[dryrun] {label}: {skip}")
        return record

    fn, args, mesh, cfg, shape, bundle = built
    if bundle is not None:
        # analytic bytes-on-wire for the consensus exchange — visible even
        # on hosts where the step itself can't run.  The exchange knob only
        # acts on the fused flat-buffer path; other mixings move native
        # bytes regardless of --exchange, and the record must say so.
        from repro.core import consensus as consensus_lib
        from repro.core import flatbuf
        live = exchange if (mixing == "ppermute_fused" and fused) else "f32"
        if live != exchange and verbose:
            print(f"[dryrun] {label}: --exchange {exchange} has no effect on "
                  f"mixing={mixing!r} fused={fused} — reporting native bytes")
        program = bundle.mixing_program
        if program is not None and mixing == "ppermute_fused" and fused:
            # the compressor aliases (int8/fp8) normalize the exchange at
            # program-build time; price what the wire actually carries
            live = program.exchange
        rounds = program.rounds if program is not None else 1
        payloads = program.n_payloads if program is not None else 1
        wire_topo = bundle.topology
        if program is not None and not program.schedule.is_static:
            wire_topo = program.schedule
            record["topology_schedule"] = program.schedule.diagnostics(rounds)
        if program is not None:
            # k rounds => k x exchange_bytes; momentum mixing doubles the
            # payload trees; error feedback adds 0 wire bytes (the residual
            # is local optimizer state)
            record["mixing_program"] = program.describe()
        if program is not None and program.fault_tolerant:
            # staleness config + per-step arrival accounting: which links
            # delivered a fresh/stale payload and which were masked out of
            # the (renormalized) mixing row, for every step of the fault
            # period — the record a postmortem reads to see what the ring
            # actually absorbed
            from repro.core.faults import trivial_faults
            f = program.faults or trivial_faults(bundle.n_agents)
            record["staleness_config"] = {"staleness": program.staleness,
                                          "faults": f.describe()}
            record["arrival_accounting"] = f.arrival_accounting(
                program.staleness)
        # price through the program so compressed wires (topk/rank) report
        # their actual carried fields; None when the knob isn't live (the
        # non-fused fallback moves native f32 regardless of the program)
        live_program = program if live != "f32" or (
            program is not None and program.compressed) else None
        flat_spec = flatbuf.make_flat_spec(args[0], lead=1)
        record["exchange_bytes_per_step"] = consensus_lib.exchange_bytes_per_step(
            flat_spec, wire_topo, live, rounds, payloads,
            program=live_program)
        if program is not None and program.compressor_kind == "topk":
            # dense-vs-sparse operand bytes/FLOPs of the fused update per
            # bucket (the compute-side analog of exchange_bytes_per_step),
            # plus which form the program actually runs
            from repro.analysis.roofline import consensus_update_cost
            degree = (wire_topo.mean_degree()
                      if hasattr(wire_topo, "mean_degree")
                      else wire_topo.degree())
            record["update_cost"] = {
                "sparse_update": program.sparse_update,
                **consensus_update_cost(flat_spec, program, int(degree)),
            }
        if verbose:
            print(f"[dryrun] {label} " + consensus_lib.describe_exchange_cost(
                args[0], wire_topo, live, rounds=rounds, payloads=payloads,
                program=live_program))
        # which step inputs reach the collective exchange (the overlap
        # schedule's proof: ppermutes consume only carried wire state, so
        # they are off the grad->update critical path)
        try:
            from repro.core import engine
            with mesh:
                record["exchange_schedule"] = engine.exchange_dependency_report(
                    fn, *args)
            if verbose:
                print(f"[dryrun] {label} exchange_schedule: "
                      f"{record['exchange_schedule']}")
        except Exception as e:  # analysis must never sink the record
            record["exchange_schedule"] = f"FAIL: {type(e).__name__}: {e}"
    donate = bundle.donate_argnums if bundle is not None else ()
    stats = None
    try:
        with mesh:
            # record donation warnings from this one compile so the static
            # checker's alias.dropped_donations rule can audit them without
            # paying for a second compile
            with warnings.catch_warnings(record=True) as wlog:
                warnings.simplefilter("always")
                lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
                t_lower = time.time() - t0
                compiled = lowered.compile()
            drop_msgs = [str(w.message) for w in wlog
                         if "donat" in str(w.message).lower()]
            t_compile = time.time() - t0 - t_lower
            ma = compiled.memory_analysis()
            ca = compiled.cost_analysis() or {}
            if isinstance(ca, (list, tuple)):   # jax 0.4.x: one dict per program
                ca = ca[0] if ca else {}
            print(f"[dryrun] {label} memory_analysis: {ma}")
            print(f"[dryrun] {label} cost_analysis flops={ca.get('flops')} "
                  f"bytes={ca.get('bytes accessed')}")
            chips = 512 if multi_pod else 256
            per_device_bytes = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                                + ma.output_size_in_bytes - ma.alias_size_in_bytes)
            record.update({
                "status": "ok",
                "lower_s": round(t_lower, 1),
                "compile_s": round(t_compile, 1),
                "chips": chips,
                "argument_bytes_per_device": ma.argument_size_in_bytes,
                "temp_bytes_per_device": ma.temp_size_in_bytes,
                "output_bytes_per_device": ma.output_size_in_bytes,
                "peak_bytes_per_device": per_device_bytes,
                "fits_v5e_16gb": bool(per_device_bytes < 16e9),
                "xla_cost_flops": ca.get("flops"),
                "xla_cost_bytes": ca.get("bytes accessed"),
            })
            if analyze:
                stats = analyze_hlo(compiled.as_text())
                terms = roofline_from_stats(
                    arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
                    stats=stats, model_flops_total=model_flops(cfg, shape),
                    xla_cost_flops=ca.get("flops"),
                    peak_memory_bytes=per_device_bytes)
                record["roofline"] = terms.as_dict()
                record["collective_bytes"] = stats.collective_bytes
                record["collective_count"] = stats.collective_count
                record["while_trip_counts"] = stats.trip_counts
            if bundle is not None and mode == "train":
                # static wire-contract certification (PR 10): census, alias/
                # donation coverage, byte accounting, seed streams, sparse
                # invariants — the record-level proof that this config's
                # program honors its declared contract
                try:
                    from repro.analysis import staticcheck
                    rep = staticcheck.check_bundle(
                        bundle, mesh, label=label, hlo_stats=stats,
                        dropped_donations=drop_msgs)
                    record["verify"] = rep.as_dict()
                    if verbose:
                        print(f"[dryrun] {label} verify: {rep.summary()}")
                except Exception as e:  # analysis must never sink the record
                    record["verify"] = f"FAIL: {type(e).__name__}: {e}"
    except Exception as e:
        record["status"] = f"FAIL: {type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    _dump(out_dir, label, record)
    if verbose:
        print(f"[dryrun] {label}: {record['status']} ({time.time()-t0:.0f}s)")
    return record


def _dump(out_dir: str, label: str, record: dict) -> None:
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, label + ".json"), "w") as f:
        json.dump(record, f, indent=2, default=str)


def main() -> int:
    from repro.configs import INPUT_SHAPES, list_archs

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=sorted(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", default="train", choices=["train", "train_hier"])
    ap.add_argument("--mixing", default="dense",
                    choices=["dense", "ppermute", "ppermute_fused"])
    ap.add_argument("--optimizer", default="cdmsgd")
    ap.add_argument("--fused", action="store_true",
                    help="flat-buffer fused optimizer update (pairs with "
                         "--mixing ppermute_fused)")
    ap.add_argument("--exchange", default="f32",
                    choices=["f32", "bf16", "int8", "fp8"],
                    help="neighbor-exchange wire precision for the fused "
                         "path (int8/fp8: quantize before ppermute)")
    ap.add_argument("--schedule", default="sync", choices=["sync", "overlap"],
                    help="exchange schedule: 'overlap' exchanges the "
                         "previous step's quantized buckets (double-buffered "
                         "in the optimizer state) so the collective-permute "
                         "leaves the grad->update critical path; the record's "
                         "exchange_schedule field proves the dependency "
                         "structure")
    ap.add_argument("--topology", default="ring")
    ap.add_argument("--mixing-strategy", default="static",
                    choices=["static", "time_varying", "multi_round"],
                    help="mixing strategy of the fused path (pairs with "
                         "--mixing ppermute_fused --fused)")
    ap.add_argument("--consensus-rounds", type=int, default=1,
                    help="inner i-CDSGD rounds per step; the record's "
                         "exchange_bytes_per_step scales by k")
    ap.add_argument("--topology-schedule", default=None,
                    help="time-varying Pi_t spec (e.g. "
                         "'alternating:ring:torus', 'gossip:8'); diagnostics "
                         "recorded as topology_schedule")
    ap.add_argument("--error-feedback", action="store_true",
                    help="EF residuals for quantized exchanges (0 extra "
                         "wire bytes; residual state rides the opt state)")
    ap.add_argument("--momentum-mixing", default="none",
                    choices=["none", "mixed"],
                    help="'mixed': the momentum buffer rides the wire and "
                         "mixes with the same Pi (2010.11166); the record's "
                         "exchange_bytes_per_step doubles (payloads=2)")
    ap.add_argument("--staleness", type=int, default=1,
                    help="bounded-staleness ring depth S (pairs with "
                         "--schedule overlap); the record gains a "
                         "staleness_config + per-step arrival_accounting "
                         "section and exchange_schedule proves every "
                         "ppermute stays carried-only at this S")
    ap.add_argument("--fault-schedule", default=None,
                    help="deterministic fault-injection spec (e.g. "
                         "'stall:1:1:3,drop:0:2', 'random:0.1:16'; see "
                         "repro.core.faults.make_fault_schedule)")
    ap.add_argument("--compressor", default="none",
                    help="wire compressor axis: 'none', 'int8'/'fp8' "
                         "(aliases), 'topk:p', 'topk:auto:B' (per-bucket "
                         "density against a byte budget) or 'rank:r' "
                         "(biased; require --error-feedback); the record's "
                         "exchange_bytes_per_step prices the compressed "
                         "payload fields and top-k records update_cost "
                         "(dense vs sparse operand bytes per bucket)")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--no-analyze", action="store_true")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--context-parallel", action="store_true")
    args = ap.parse_args()

    pairs = []
    if args.all:
        for arch in list_archs():
            for shape in INPUT_SHAPES:
                pairs.append((arch, shape))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        pairs = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in pairs:
        rec = run_pair(arch, shape, multi_pod=args.multi_pod, mode=args.mode,
                       mixing=args.mixing, optimizer_name=args.optimizer,
                       topology=args.topology, out_dir=args.out, tag=args.tag,
                       analyze=not args.no_analyze, microbatches=args.microbatch,
                       context_parallel=args.context_parallel, fused=args.fused,
                       exchange=args.exchange, schedule=args.schedule,
                       mixing_strategy=args.mixing_strategy,
                       consensus_rounds=args.consensus_rounds,
                       topology_schedule=args.topology_schedule,
                       error_feedback=args.error_feedback,
                       momentum_mixing=args.momentum_mixing,
                       staleness=args.staleness,
                       fault_schedule=args.fault_schedule,
                       compressor=args.compressor)
        if str(rec.get("status", "")).startswith("FAIL"):
            failures += 1
    print(f"[dryrun] done: {len(pairs)} pairs, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
