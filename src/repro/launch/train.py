"""Training launcher: collaborative CDSGD training for any --arch.

On real hardware this drives the pjit'd sharded step over the production
mesh; on this CPU container use ``--preset tiny`` (reduced config,
simulated agents) which exercises the identical optimizer/consensus code.

Examples:
  python -m repro.launch.train --arch gemma3-1b --preset tiny --steps 50
  python -m repro.launch.train --arch rwkv6-1.6b --preset tiny \
      --optimizer cdmsgd --topology ring --agents 8
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--agents", type=int, default=5)
    ap.add_argument("--topology", default="fully_connected")
    ap.add_argument("--optimizer", default="cdsgd")
    ap.add_argument("--fused", action="store_true",
                    help="flat-buffer fused consensus update (one Pallas "
                         "launch per dtype bucket; consensus optimizers only)")
    ap.add_argument("--exchange", default="f32",
                    choices=["f32", "bf16", "int8", "fp8"],
                    help="neighbor-exchange wire precision of the fused "
                         "path: int8/fp8 = stochastic-rounding quantization "
                         "before the exchange, ~4x fewer bytes per neighbor")
    ap.add_argument("--schedule", default="sync", choices=["sync", "overlap"],
                    help="exchange schedule: 'overlap' double-buffers the "
                         "quantized wire payloads in the optimizer state "
                         "(one-step-stale neighbor mixing, exchange off the "
                         "grad->update critical path; implies --fused)")
    ap.add_argument("--mixing-strategy", default="static",
                    choices=["static", "time_varying", "multi_round"],
                    help="mixing strategy of the fused consensus path: "
                         "'time_varying' cycles --topology-schedule's Pi_t, "
                         "'multi_round' runs --consensus-rounds inner "
                         "i-CDSGD rounds per step (implies --fused)")
    ap.add_argument("--consensus-rounds", type=int, default=1,
                    help="inner consensus rounds per gradient step (k-round "
                         "i-CDSGD: x' = Pi^k x - a g; k x the wire bytes)")
    ap.add_argument("--topology-schedule", default=None,
                    help="time-varying Pi_t schedule spec, e.g. "
                         "'alternating:ring:torus' or 'gossip:8' "
                         "(see repro.core.topology.make_topology_schedule)")
    ap.add_argument("--error-feedback", action="store_true",
                    help="carry quantization residuals in the optimizer "
                         "state and compress residual+payload (int8/fp8 "
                         "exchanges only; adds 0 wire bytes)")
    ap.add_argument("--momentum-mixing", default="none",
                    choices=["none", "mixed"],
                    help="'mixed' puts the momentum buffer on the wire and "
                         "mixes it with the same Pi (v' = mu Pi v - a g, "
                         "2010.11166) — stabilizes quantized exchanges at "
                         "large lr; 2x wire bytes; momentum optimizers only "
                         "(implies --fused)")
    ap.add_argument("--staleness", type=int, default=1,
                    help="bounded-staleness ring depth S: each neighbor slot "
                         "may be up to S steps stale before its weight is "
                         "masked out (arrival-renormalized mixing; requires "
                         "--schedule overlap, implies --fused)")
    ap.add_argument("--fault-schedule", default=None,
                    help="deterministic fault-injection spec, e.g. "
                         "'straggler:1:2', 'stall:1:1:3,drop:0:2', "
                         "'random:0.1:16' or 'none' (see "
                         "repro.core.faults.make_fault_schedule; requires "
                         "--schedule overlap, implies --fused)")
    ap.add_argument("--compressor", default="none",
                    help="wire compressor: 'none', 'int8'/'fp8' (alias the "
                         "--exchange precisions), 'topk:p' (top-k sparse, "
                         "density p, e.g. topk:0.01), 'topk:auto:B' "
                         "(adaptive per-bucket density against a byte "
                         "budget B per neighbor, e.g. topk:auto:65536) or "
                         "'rank:r' (rank-r PowerSGD-style factors, e.g. "
                         "rank:4); topk/rank are biased and require "
                         "--error-feedback (implies --fused)")
    ap.add_argument("--sparse-update", default=None,
                    choices=["on", "off"],
                    help="top-k compressor only: 'on' (the default for "
                         "topk) feeds the compact wire fields straight to "
                         "the fused sparse scatter-accumulate kernels "
                         "(O(k_rows) neighbor reads); 'off' forces the "
                         "dense decompress-then-update reference path "
                         "(O(rows))")
    ap.add_argument("--microbatch", type=int, default=1,
                    help="gradient-accumulation microbatches per step")
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--momentum", type=float, default=0.9)
    ap.add_argument("--local-steps", type=int, default=1,
                    help="FedAvg E: local steps between (gated) all-reduce "
                         "sync averages; wire accounting reports bytes/E")
    ap.add_argument("--lr-schedule", default="fixed", choices=["fixed", "diminishing"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--resume", action="store_true",
                    help="restore params AND the full optimizer state "
                         "(incl. overlap wire buffers / error-feedback "
                         "residuals) from --checkpoint-dir before training")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.core import make_topology, make_optimizer, schedules
    from repro.core.trainer import CollaborativeTrainer, train_loop
    from repro.data import make_lm_tokens, lm_agent_batches
    from repro.nn import model_template, init_params, loss_fn, count_params
    from repro.checkpoint import restore_train_state, save_train_state

    cfg = get_config(args.arch)
    if args.preset == "tiny":
        cfg = cfg.reduced()

    template = model_template(cfg)
    params = init_params(template, jax.random.PRNGKey(args.seed))
    print(f"[train] {cfg.name}: {count_params(template):,} params, "
          f"{args.agents} agents over {args.topology}")

    sched = (args.lr if args.lr_schedule == "fixed"
             else schedules.diminishing(theta=args.lr * 10, eps=1.0, t=10.0))
    kw = {}
    if args.optimizer in ("cdmsgd", "cdmsgd_nesterov", "msgd", "fedavg"):
        kw["mu"] = args.momentum
    if args.optimizer == "fedavg":
        kw["local_steps"] = args.local_steps
    if args.exchange != "f32" and not args.fused:
        # the exchange knob lives on the fused flat-buffer path
        print(f"[train] --exchange {args.exchange} implies --fused; enabling")
        args.fused = True
    if args.schedule == "overlap" and not args.fused:
        # the overlap wire double-buffer lives on the fused flat-buffer path
        print("[train] --schedule overlap implies --fused; enabling")
        args.fused = True
    fault_tolerant = (args.staleness > 1
                      or (args.fault_schedule not in (None, "none")))
    if fault_tolerant and args.schedule != "overlap":
        ap.error("--staleness > 1 / --fault-schedule need --schedule overlap "
                 "(the staleness ring generalizes the overlap wire buffer)")
    nontrivial_mixing = (args.mixing_strategy != "static"
                         or args.consensus_rounds > 1 or args.error_feedback
                         or args.momentum_mixing != "none" or fault_tolerant
                         or args.compressor != "none")
    if nontrivial_mixing and not args.fused:
        # the strategy layer lives on the fused flat-buffer path
        print("[train] non-static mixing strategy implies --fused; enabling")
        args.fused = True
    if args.fused:
        kw["fused"] = True
    opt = make_optimizer(args.optimizer, sched, **kw)
    topo = make_topology(args.topology, args.agents)

    def lm_loss(p, batch):
        extra = {}
        if cfg.modality in ("audio", "vlm"):
            extra["frontend"] = jnp.ones(
                (batch["inputs"].shape[0], cfg.frontend_tokens, cfg.frontend_dim),
                jnp.float32)
        return loss_fn(cfg, p, {**batch, **extra})

    trainer = CollaborativeTrainer(lm_loss, params, topo, opt,
                                   exchange=args.exchange,
                                   schedule=args.schedule,
                                   microbatches=args.microbatch,
                                   mixing_strategy=args.mixing_strategy,
                                   consensus_rounds=args.consensus_rounds,
                                   topology_schedule=args.topology_schedule,
                                   error_feedback=args.error_feedback,
                                   momentum_mixing=args.momentum_mixing,
                                   staleness=args.staleness,
                                   fault_schedule=args.fault_schedule,
                                   compressor=args.compressor,
                                   sparse_update=(None if args.sparse_update
                                                  is None else
                                                  args.sparse_update == "on"))

    from repro.core.consensus import describe_exchange_cost
    program = trainer.program
    if not program.is_trivial:
        print(f"[train] mixing program: {program.describe()}")
        if not program.schedule.is_static:
            d = program.schedule.diagnostics(program.rounds)
            print(f"[train] schedule effective gap "
                  f"{d['effective_gap']:.4f} (per-matrix "
                  f"{['%.4f' % g for g in d['per_matrix_gap']]})")
    if args.optimizer == "fedavg":
        # FedAvg moves no neighbor traffic — its cost is the whole-model
        # all-reduce once per E sync steps (gated; amortized bytes/E)
        print(f"[train] fedavg all-reduce: {trainer.wire_bytes_per_step:,} "
              f"bytes/agent/step amortized (sync every "
              f"{opt.local_steps} steps"
              + (", params + momentum averaged" if opt.mu else "") + ")")
    else:
        print("[train] " + describe_exchange_cost(
            trainer.state.params,
            program.schedule if not program.schedule.is_static else topo,
            trainer.exchange, rounds=program.rounds,
            payloads=program.n_payloads, program=program))
    tokens = make_lm_tokens(1 << 15, vocab=cfg.vocab_size, seed=args.seed)
    batches = lm_agent_batches(tokens, args.agents, args.batch, args.seq, seed=args.seed)

    if args.resume:
        if not args.checkpoint_dir:
            ap.error("--resume needs --checkpoint-dir")
        from repro.core.trainer import TrainState
        p0, o0 = restore_train_state(args.checkpoint_dir,
                                     trainer.state.params,
                                     trainer.state.opt_state)
        trainer.state = TrainState(params=p0, opt_state=o0,
                                   step=int(o0.step))
        # fast-forward the (deterministic, seed-keyed) batch stream past the
        # steps the checkpointed run already consumed — otherwise the
        # resumed run re-trains on batches 0..step and the trajectory
        # silently diverges from an uninterrupted run
        for _ in range(trainer.state.step):
            next(batches)
        print(f"[train] resumed at step {trainer.state.step} (full opt "
              "state incl. wire/residual buffers; batch stream "
              "fast-forwarded)")

    train_loop(trainer, batches, args.steps, log_every=args.log_every, printer=print)
    final = trainer.history.rows[-1]
    print(f"[train] done: loss={final['loss']:.4f} "
          f"consensus_error={final['consensus_error']:.3e}")
    if args.checkpoint_dir:
        p = save_train_state(args.checkpoint_dir, trainer.state.step,
                             trainer.state.params, trainer.state.opt_state)
        print(f"[train] checkpoint: {p}")


if __name__ == "__main__":
    main()
