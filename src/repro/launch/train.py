"""Training launcher: collaborative CDSGD training for any --arch.

On real hardware this drives the pjit'd sharded step over the production
mesh; on this CPU container use ``--preset tiny`` (reduced config,
simulated agents) which exercises the identical optimizer/consensus code.

Examples:
  python -m repro.launch.train --arch gemma3-1b --preset tiny --steps 50
  python -m repro.launch.train --arch rwkv6-1.6b --preset tiny \
      --optimizer cdmsgd --topology ring --agents 8
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--agents", type=int, default=5)
    ap.add_argument("--topology", default="fully_connected")
    ap.add_argument("--optimizer", default="cdsgd")
    ap.add_argument("--fused", action="store_true",
                    help="flat-buffer fused consensus update (one Pallas "
                         "launch per dtype bucket; consensus optimizers only)")
    ap.add_argument("--exchange", default="f32",
                    choices=["f32", "bf16", "int8", "fp8"],
                    help="neighbor-exchange wire precision of the fused "
                         "path: int8/fp8 = stochastic-rounding quantization "
                         "before the exchange, ~4x fewer bytes per neighbor")
    ap.add_argument("--schedule", default="sync", choices=["sync", "overlap"],
                    help="exchange schedule: 'overlap' double-buffers the "
                         "quantized wire payloads in the optimizer state "
                         "(one-step-stale neighbor mixing, exchange off the "
                         "grad->update critical path; implies --fused)")
    ap.add_argument("--microbatch", type=int, default=1,
                    help="gradient-accumulation microbatches per step")
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--momentum", type=float, default=0.9)
    ap.add_argument("--lr-schedule", default="fixed", choices=["fixed", "diminishing"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.core import make_topology, make_optimizer, schedules
    from repro.core.trainer import CollaborativeTrainer, train_loop
    from repro.data import make_lm_tokens, lm_agent_batches
    from repro.nn import model_template, init_params, loss_fn, count_params
    from repro.checkpoint import save_checkpoint

    cfg = get_config(args.arch)
    if args.preset == "tiny":
        cfg = cfg.reduced()

    template = model_template(cfg)
    params = init_params(template, jax.random.PRNGKey(args.seed))
    print(f"[train] {cfg.name}: {count_params(template):,} params, "
          f"{args.agents} agents over {args.topology}")

    sched = (args.lr if args.lr_schedule == "fixed"
             else schedules.diminishing(theta=args.lr * 10, eps=1.0, t=10.0))
    kw = {}
    if args.optimizer in ("cdmsgd", "cdmsgd_nesterov", "msgd", "fedavg"):
        kw["mu"] = args.momentum
    if args.exchange != "f32" and not args.fused:
        # the exchange knob lives on the fused flat-buffer path
        print(f"[train] --exchange {args.exchange} implies --fused; enabling")
        args.fused = True
    if args.schedule == "overlap" and not args.fused:
        # the overlap wire double-buffer lives on the fused flat-buffer path
        print("[train] --schedule overlap implies --fused; enabling")
        args.fused = True
    if args.fused:
        kw["fused"] = True
    opt = make_optimizer(args.optimizer, sched, **kw)
    topo = make_topology(args.topology, args.agents)

    def lm_loss(p, batch):
        extra = {}
        if cfg.modality in ("audio", "vlm"):
            extra["frontend"] = jnp.ones(
                (batch["inputs"].shape[0], cfg.frontend_tokens, cfg.frontend_dim),
                jnp.float32)
        return loss_fn(cfg, p, {**batch, **extra})

    trainer = CollaborativeTrainer(lm_loss, params, topo, opt,
                                   exchange=args.exchange,
                                   schedule=args.schedule,
                                   microbatches=args.microbatch)

    from repro.core.consensus import describe_exchange_cost
    print("[train] " + describe_exchange_cost(trainer.state.params, topo,
                                              args.exchange))
    tokens = make_lm_tokens(1 << 15, vocab=cfg.vocab_size, seed=args.seed)
    batches = lm_agent_batches(tokens, args.agents, args.batch, args.seq, seed=args.seed)

    train_loop(trainer, batches, args.steps, log_every=args.log_every, printer=print)
    final = trainer.history.rows[-1]
    print(f"[train] done: loss={final['loss']:.4f} "
          f"consensus_error={final['consensus_error']:.3e}")
    if args.checkpoint_dir:
        p = save_checkpoint(args.checkpoint_dir, trainer.state.step,
                            {"params": trainer.state.params})
        print(f"[train] checkpoint: {p}")


if __name__ == "__main__":
    main()
