"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — device count is locked at first jax init,
and only `dryrun.py` forces the 512-placeholder-device environment.
"""

from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    """jax.make_mesh across versions: 0.4.x has no ``axis_types`` kwarg."""
    if hasattr(jax.sharding, "AxisType"):   # jax >= 0.5
        auto = (jax.sharding.AxisType.Auto,) * len(axes)
        return jax.make_mesh(shape, axes, axis_types=auto)
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    Axes: ``data`` (CDSGD agent axis in the paper-faithful mapping),
    ``model`` (tensor/expert parallel), and ``pod`` (multi-pod; agents in
    the hierarchical mapping — see DESIGN.md §5).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 4, n_model: int = 2, *, multi_pod: bool = False):
    """Small host-device mesh for tests (requires the XLA host-device flag)."""
    if multi_pod:
        return _make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return _make_mesh((n_data, n_model), ("data", "model"))
