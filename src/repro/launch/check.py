import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Certify the wire contract of every supported StepProgram configuration.

The two lines above MUST stay first: the sharded matrix runs on 8 host
placeholder devices and jax locks the device count at first init.  Do not
import this module from tests — they manage their own device count (the
subprocess idiom in test_sharded.py).

``python -m repro.launch.check`` assembles every supported (schedule x
exchange x mixing-strategy x compressor x staleness) configuration in BOTH
execution modes — the stacked MLP-testbed trainer and the sharded
``build_train_step`` bundle on debug meshes — and runs the static
contract checker (``repro.analysis.staticcheck``) over each.  Tracing
only: no config in the default matrix compiles or executes a step, so the
full sweep is CI-cheap (~2 min on 2 cores).

``--hlo N`` additionally compiles the first N HLO-tier configs on the
agent-only 8x1 mesh and cross-checks the collective-permute bytes that XLA
actually emitted against the analytic accounting
(``bytes.hlo_collective_permute``), and audits jax's dropped-donation
warnings (``alias.dropped_donations``).

Exit status is non-zero iff any rule fails, so CI can use this as a hard
gate.  ``--json-out`` writes a BENCH-style record with one entry per
config: label, ok, walltime, and the full per-rule evidence.

Usage:
  python -m repro.launch.check                    # full matrix, both modes
  python -m repro.launch.check --mode stacked     # trainer matrix only
  python -m repro.launch.check --only topk        # label substring filter
  python -m repro.launch.check --hlo 2 --json-out BENCH_10.json
  python -m repro.launch.check --list             # print matrix and exit
"""

import argparse
import functools
import json
import sys
import time


# --------------------------------------------------------------------------
# the supported configuration matrix
#
# Each entry is (label, optimizer_name, trainer/build kwargs).  The same
# knobs drive both modes; entries whose knobs only exist in one mode carry
# a "modes" key.  Keep this list in sync with ROADMAP.md's supported-config
# table — a config missing here is a config CI does not certify.
# --------------------------------------------------------------------------

ALT = "alternating:ring:fully_connected"

MATRIX = [
    ("sync_f32", "cdsgd", {}),
    ("sync_int8", "cdmsgd", dict(exchange="int8")),
    ("sync_nesterov_f32", "cdmsgd_nesterov", {}),
    ("overlap_f32", "cdsgd", dict(schedule="overlap")),
    ("overlap_int8", "cdmsgd", dict(schedule="overlap", exchange="int8")),
    ("sync_rounds2", "cdsgd",
     dict(exchange="int8", mixing_strategy="multi_round", consensus_rounds=2)),
    ("sync_rounds3_adam", "cdadam",
     dict(exchange="int8", mixing_strategy="multi_round", consensus_rounds=3)),
    ("overlap_rounds3", "cdmsgd",
     dict(schedule="overlap", exchange="int8",
          mixing_strategy="multi_round", consensus_rounds=3)),
    ("sync_tv_int8", "cdmsgd",
     dict(exchange="int8", mixing_strategy="time_varying",
          topology_schedule=ALT)),
    ("overlap_tv_int8", "cdmsgd",
     dict(schedule="overlap", exchange="int8",
          mixing_strategy="time_varying", topology_schedule=ALT)),
    ("overlap_mom_mixed", "cdmsgd",
     dict(schedule="overlap", exchange="int8", momentum_mixing="mixed")),
    ("overlap_S4", "cdsgd",
     dict(schedule="overlap", exchange="int8", staleness=4)),
    ("overlap_S4_faults", "cdsgd",
     dict(schedule="overlap", exchange="int8", staleness=4,
          fault_schedule="stall:1:1:3")),
    ("sync_ef_topk", "cdsgd",
     dict(error_feedback=True, compressor="topk:0.25")),
    ("overlap_ef_topk", "cdsgd",
     dict(schedule="overlap", exchange="int8", error_feedback=True,
          compressor="topk:0.25")),
    ("overlap_ef_topk_auto", "cdmsgd",
     dict(schedule="overlap", error_feedback=True,
          compressor="topk:auto:65536")),
    ("overlap_ef_rank", "cdmsgd_nesterov",
     dict(schedule="overlap", error_feedback=True, compressor="rank:2")),
]

# compressed wires require every bucket row on one shard, so those sharded
# configs run on the agent-only 8x1 debug mesh; dense configs exercise the
# model axis (4 agents x 2-way model sharding) where per-shard re-padding
# is live in the byte accounting
COMPRESSED = {"sync_ef_topk", "overlap_ef_topk", "overlap_ef_topk_auto",
              "overlap_ef_rank"}

# configs the --hlo tier compiles (agent-only mesh: the analytic cp-bytes
# closed form is exact there), in priority order
HLO_TIER = ["overlap_int8", "sync_int8", "overlap_ef_topk", "overlap_S4"]


def stacked_reports(entries, *, verbose=True):
    """Run the stacked matrix: the paper's MLP testbed on a 4-agent ring."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.analysis import staticcheck
    from repro.core.optim import make_optimizer
    from repro.core.topology import make_topology
    from repro.core.trainer import CollaborativeTrainer
    from repro.nn.paper_models import (classifier_loss, mlp_classifier_apply,
                                       mlp_classifier_template)
    from repro.nn.param import init_params

    loss = functools.partial(classifier_loss, mlp_classifier_apply)
    params = init_params(mlp_classifier_template(8, 4, width=16, depth=2),
                         jax.random.PRNGKey(0))
    topo = make_topology("ring", 4)
    rng = np.random.default_rng(0)
    batch = {"x": jnp.asarray(rng.standard_normal((4, 8, 8)), jnp.float32),
             "y": jnp.asarray(rng.integers(0, 4, (4, 8)), jnp.int32)}

    reports = []
    for label, opt_name, kw in entries:
        opt = make_optimizer(opt_name, 0.05, fused=True)
        tr = CollaborativeTrainer(loss, params, topo, opt, **kw)
        rep = staticcheck.check_trainer(tr, batch, label=f"stacked/{label}",
                                        checkify_indices=True)
        reports.append(rep)
        if verbose:
            print(rep.summary())
    return reports


def sharded_reports(entries, *, hlo_n=0, verbose=True):
    """Run the sharded matrix: ``build_train_step`` bundles on debug meshes
    (shape templates only — compile is reserved for the --hlo tier)."""
    import dataclasses

    from repro.analysis import staticcheck
    from repro.configs import get_config
    from repro.configs.base import InputShape
    from repro.core.optim import make_optimizer
    from repro.launch import steps as steps_lib
    from repro.launch.mesh import make_debug_mesh

    cfg = dataclasses.replace(get_config("granite-3-8b").reduced(),
                              param_dtype="float32")
    shape = InputShape("tiny_train", 16, 8, "train")
    hlo_labels = [l for l in HLO_TIER
                  if any(e[0] == l for e in entries)][:max(0, hlo_n)]

    reports = []
    for label, opt_name, kw in entries:
        dims = (8, 1) if label in COMPRESSED else (4, 2)
        mesh = make_debug_mesh(*dims)
        opt = make_optimizer(opt_name, 0.05, fused=True)
        bundle = steps_lib.build_train_step(
            cfg, shape, mesh, opt, mode="train", topology_name="ring",
            mixing="ppermute_fused", **kw)
        full = f"sharded/{label} {dims[0]}x{dims[1]}"
        with mesh:
            rep = staticcheck.check_bundle(bundle, mesh, label=full)
        reports.append(rep)
        if verbose:
            print(rep.summary())
        if label in hlo_labels:
            reports.append(_hlo_report(cfg, shape, opt_name, kw, label,
                                       verbose=verbose))
    return reports


def _hlo_report(cfg, shape, opt_name, kw, label, *, verbose=True):
    """Compile one config on the agent-only mesh and certify against the
    HLO the compiler actually emitted (collective bytes + donation audit)."""
    import warnings

    import jax
    from repro.analysis import staticcheck
    from repro.analysis.hlo import analyze_hlo
    from repro.core.optim import make_optimizer
    from repro.launch import steps as steps_lib
    from repro.launch.mesh import make_debug_mesh

    mesh = make_debug_mesh(8, 1)
    opt = make_optimizer(opt_name, 0.05, fused=True)
    bundle = steps_lib.build_train_step(
        cfg, shape, mesh, opt, mode="train", topology_name="ring",
        mixing="ppermute_fused", **kw)
    with mesh:
        params = bundle.param_structs(mesh)
        opt_state = bundle.opt_state_structs(mesh, opt)
        with warnings.catch_warnings(record=True) as wlog:
            warnings.simplefilter("always")
            compiled = jax.jit(
                bundle.step_fn, donate_argnums=bundle.donate_argnums,
            ).lower(params, opt_state, bundle.batch_specs).compile()
        dropped = [str(w.message) for w in wlog
                   if "donat" in str(w.message).lower()]
        stats = analyze_hlo(compiled.as_text())
        rep = staticcheck.check_bundle(
            bundle, mesh, label=f"sharded/{label} 8x1 +hlo",
            hlo_stats=stats, dropped_donations=dropped)
    if verbose:
        print(rep.summary())
    return rep


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.check", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--mode", choices=["stacked", "sharded", "all"],
                    default="all")
    ap.add_argument("--only", default="",
                    help="run only configs whose label contains this substring")
    ap.add_argument("--hlo", type=int, default=0, metavar="N",
                    help="compile the first N HLO-tier configs on the 8x1 "
                         "mesh and cross-check emitted collective bytes")
    ap.add_argument("--json-out", default="",
                    help="write a BENCH-style JSON record of every report")
    ap.add_argument("--list", action="store_true",
                    help="print the config matrix and exit")
    args = ap.parse_args(argv)

    entries = [e for e in MATRIX if args.only in e[0]]
    if args.list:
        for label, opt_name, kw in entries:
            print(f"{label:24s} {opt_name:16s} {kw}")
        return 0
    if not entries:
        print(f"[check] no config label contains {args.only!r}", file=sys.stderr)
        return 2

    t0 = time.time()
    reports = []
    if args.mode in ("stacked", "all"):
        reports += stacked_reports(entries)
    if args.mode in ("sharded", "all"):
        reports += sharded_reports(entries, hlo_n=args.hlo)

    n_rules = sum(len(r.results) for r in reports)
    failures = [(r.label, f) for r in reports for f in r.failures()]
    print(f"\n[check] {len(reports)} configs, {n_rules} rules, "
          f"{len(failures)} failures ({time.time() - t0:.0f}s)")
    for label, f in failures:
        print(f"[check] FAIL {label} :: {f.rule}: {f.detail}")

    if args.json_out:
        record = {
            "bench": "staticcheck",
            "version": 1,
            "mode": args.mode,
            "ok": not failures,
            "n_configs": len(reports),
            "n_rules": n_rules,
            "walltime_s": round(time.time() - t0, 1),
            "configs": [r.as_dict() for r in reports],
        }
        with open(args.json_out, "w") as f:
            json.dump(record, f, indent=1, default=str)
        print(f"[check] wrote {args.json_out}")

    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
