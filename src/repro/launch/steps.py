"""Sharded production step builders: train_step / prefill_step / serve_step.

``build_train_step`` is a thin front-end over the shared
:class:`repro.core.engine.StepProgram` phase pipeline (grad -> pack ->
quantize -> exchange -> update — the same phases the stacked
``CollaborativeTrainer`` assembles): this module only supplies the
mesh-specific comm ops and wraps the update phase group in ``shard_map``.
The consensus mixing runs either as

* ``mixing="dense"``   — stacked ``Pi`` einsum under pjit (paper-faithful
  semantics, naive collective schedule: XLA lowers it to all-gathers over
  the agent axis),
* ``mixing="ppermute"``— a ``shard_map`` region whose circulant topology
  lowers to `collective-permute`s between ICI neighbours, applied leaf by
  leaf (one collective per leaf per shift), or
* ``mixing="ppermute_fused"`` — the whole optimizer update runs inside one
  ``shard_map`` region on dtype-bucketed flat buffers
  (:mod:`repro.core.flatbuf`): one ``lax.ppermute`` per circulant shift
  offset per bucket for the *entire model*, followed by the fused Pallas
  update kernel (one launch per bucket) in the same region.  This is the
  §Perf fast path and expects a ``fused=True`` optimizer (a non-fused one
  still runs correctly inside the region, with a warning).

``schedule="overlap"`` (fused path only) pipelines the exchange one step
deep: the quantized buckets + row scales double-buffer in the optimizer
state, so the ``ppermute``\\ s consume only carried state and drop off the
grad->update critical path (one-step-stale neighbor mixing, fresh
full-precision self term — see :mod:`repro.core.engine`; the dryrun's
``exchange_schedule`` record proves the dependency structure per config).

The fused path exposes the **exchange-precision knob**
(``exchange="f32"|"bf16"|"int8"|"fp8"``): int8/fp8 quantize each packed
bucket (stochastic rounding, one f32 scale per 128-lane row) before the
circulant ``ppermute`` so every shift moves ~3.9x fewer bytes, and the
fused kernels dequantize in-register.  It also carries the **mixing
strategy** (:class:`repro.core.consensus.MixingProgram`, see
ARCHITECTURE.md §mixing strategies): ``mixing_strategy`` /
``topology_schedule`` select time-varying ``Pi_t`` (one ``lax.switch``
branch of ppermutes per schedule entry), ``consensus_rounds`` the inner
i-CDSGD round count (k x the wire bytes), ``error_feedback`` the
quantization-residual state riding ``OptState.residual`` (sharded like
the wire buffers, initialized inside ``shard_map``), and
``momentum_mixing="mixed"`` the widened two-payload wire (the momentum
buffer mixes with the same ``Pi``; wire/residual state and ppermute
count double — one wire pair and one EF residual per bucket per
payload).  The fused kernels also alias their
gradient/state inputs to their outputs (``input_output_aliases``); jit the
returned ``step_fn`` with ``donate_argnums=TrainStepBundle.donate_argnums``
to let params, momentum, and Adam moments update in place (saving roughly
one model copy of peak HBM per optimizer slot).

`serve_step` decodes one token against the sharded KV cache; `prefill_step`
is the full-sequence forward (compute-equivalent to cache-filling prefill;
it returns last-position logits).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs.base import ArchConfig, InputShape
from repro.core import consensus as consensus_lib
from repro.core import engine, flatbuf
from repro.core.optim import CommOps, DistributedOptimizer, stacked_comm_ops
from repro.core.topology import Topology, make_topology, make_topology_schedule
from repro.launch import sharding as shlib
from repro.nn.param import stack_agent_axis
from repro.nn.transformer import decode_step, forward, loss_fn, model_template

P = PartitionSpec
PyTree = Any


def _shard_map(fn, mesh, in_specs, out_specs):
    if hasattr(jax, "shard_map"):          # jax >= 0.6
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm  # jax 0.4.x
    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


@dataclasses.dataclass
class TrainStepBundle:
    step_fn: Callable                     # (params, opt_state, batch) -> (params, opt_state, metrics)
    param_template: PyTree                # ParamDef tree (agent-stacked)
    param_specs: PyTree                   # PartitionSpec tree
    opt_state_specs: Any
    batch_specs: Dict[str, jax.ShapeDtypeStruct]
    n_agents: int
    topology: Topology
    exchange: str = "f32"                 # neighbor-exchange wire precision
    schedule: str = "sync"                # exchange schedule: sync | overlap
    # the mixing-strategy configuration of the fused path (None only when
    # the comm carries no flat support, e.g. mixing="dense")
    mixing_program: Optional[consensus_lib.MixingProgram] = None
    # params + opt_state update in place every step: pass to jax.jit so the
    # fused kernels' input_output_aliases actually elide the output copies.
    donate_argnums: Tuple[int, ...] = (0, 1)
    # StepProgram state initializer (fills the overlap wire double-buffer);
    # falls back to optimizer.init when absent.
    init_state: Optional[Callable] = None
    # the optimizer the step was assembled around — the static checker
    # (repro.analysis.staticcheck) reads its declared alias contract and
    # the dryrun verify block threads it through without re-deriving the
    # launch configuration.
    optimizer: Optional[DistributedOptimizer] = None

    def param_structs(self, mesh: Mesh) -> PyTree:
        def leaf(pd, spec):
            return jax.ShapeDtypeStruct(pd.shape, pd.dtype, sharding=NamedSharding(mesh, spec))
        return jax.tree.map(leaf, self.param_template, self.param_specs,
                            is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "init"))

    def opt_state_structs(self, mesh: Mesh, optimizer) -> Any:
        init = self.init_state if self.init_state is not None else optimizer.init
        structs = jax.eval_shape(init, self.param_structs(mesh))
        specs = self.opt_state_specs
        return jax.tree.map(
            lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
            structs, specs)


def _agent_factors(mesh: Mesh, agent_axes) -> consensus_lib.FactoredMix:
    """Per-axis circulant factors for a multi-axis agent mesh."""
    factors = []
    for a in agent_axes:
        s = mesh.shape[a]
        t = make_topology("ring" if s > 2 else "fully_connected", s)
        factors.append((a, t))
    return consensus_lib.FactoredMix(tuple(factors))


def make_local_fused_comm(
    topology: Topology, mesh: Mesh, mode: str, *, interpret: bool = True,
    exchange: str = "f32",
    program: Optional[consensus_lib.MixingProgram] = None,
) -> CommOps:
    """CommOps whose every member runs *inside* a shard_map region.

    Carries a :class:`repro.core.consensus.FlatComm` so ``fused=True``
    optimizers run the flat-buffer ppermute + Pallas-kernel fast path; the
    ``mix``/``mean`` members are the local (non-shard_map-wrapped) circulant
    fns so non-fused optimizers work in the same region.  ``exchange``
    selects the ppermute wire precision (f32 | bf16 | int8 | fp8);
    ``program`` the mixing strategy (time-varying schedules compile one
    ``lax.switch`` branch of ppermutes per entry — single agent axis only).
    """
    rules = shlib.rules_for_mode(mode, mesh)
    agent_axes = rules["agent"]
    axes = agent_axes if isinstance(agent_axes, tuple) else (agent_axes,)
    if len(axes) > 1:
        fm = _agent_factors(mesh, axes)
        flat = consensus_lib.sharded_flat_comm(fm.factors, interpret=interpret,
                                               exchange=exchange,
                                               program=program)
        local_mix = fm.make_mix_fn()
        lam2, lamn, n_agents = fm.lambda2, fm.lambdan, fm.n_agents
    else:
        flat = consensus_lib.sharded_flat_comm([(axes[0], topology)],
                                               interpret=interpret,
                                               exchange=exchange,
                                               program=program)
        local_mix = consensus_lib.make_sharded_mix_fn(topology, axes[0])
        lam2, lamn, n_agents = topology.lambda2, topology.lambdan, topology.n_agents
    local_mean = consensus_lib.make_sharded_mean_fn(axes)
    return CommOps(mix=local_mix, mean=local_mean, n_agents=n_agents,
                   lambda2=lam2, lambdan=lamn, flat=flat)


def make_mix_comm(
    topology: Topology, mesh: Mesh, param_specs: PyTree, mode: str, mixing: str,
) -> CommOps:
    """CommOps over the agent axis for the sharded trainer."""
    rules = shlib.rules_for_mode(mode, mesh)
    agent_axes = rules["agent"]
    if mixing == "dense":
        # no FlatComm here: under pjit the batched (vmapped) fused kernel
        # would force all-gathers of the stacked params — the sharded fused
        # fast path is mixing="ppermute_fused"; dense stays the reference.
        return dataclasses.replace(stacked_comm_ops(topology), flat=None)
    if mixing != "ppermute":
        raise ValueError(f"unknown mixing {mixing!r}")

    if isinstance(agent_axes, tuple) and len(agent_axes) > 1:
        # factored topology: one circulant factor per mesh axis
        fm = _agent_factors(mesh, agent_axes)
        local_mix = fm.make_mix_fn()
        lam2, lamn = fm.lambda2, fm.lambdan
        n_agents = fm.n_agents
    else:
        axis = agent_axes[0] if isinstance(agent_axes, tuple) else agent_axes
        local_mix = consensus_lib.make_sharded_mix_fn(topology, axis)
        lam2, lamn = topology.lambda2, topology.lambdan
        n_agents = topology.n_agents

    # built once per bundle, not once per mean() invocation
    ax = agent_axes if isinstance(agent_axes, tuple) else (agent_axes,)
    local_mean = consensus_lib.make_sharded_mean_fn(ax)

    def mix(tree: PyTree) -> PyTree:
        return _shard_map(local_mix, mesh, (param_specs,), param_specs)(tree)

    def mean(tree: PyTree) -> PyTree:
        return _shard_map(local_mean, mesh, (param_specs,), param_specs)(tree)

    return CommOps(mix=mix, mean=mean, n_agents=n_agents, lambda2=lam2, lambdan=lamn)


def build_train_step(
    cfg: ArchConfig,
    shape: InputShape,
    mesh: Mesh,
    optimizer: DistributedOptimizer,
    *,
    mode: str = "train",
    topology_name: str = "ring",
    mixing: str = "dense",
    remat: bool = True,
    microbatches: int = 1,
    interpret: bool = True,       # Pallas interpret mode (fused path; False on TPU)
    exchange: str = "f32",        # ppermute wire precision (fused path only)
    schedule: str = "sync",       # exchange schedule: sync | overlap
    mixing_strategy: str = "static",   # static | time_varying | multi_round
    consensus_rounds: int = 1,    # inner i-CDSGD rounds per step (fused path)
    topology_schedule: Optional[str] = None,  # TopologySchedule factory spec
    error_feedback: bool = False,  # EF residuals for quantized exchanges
    momentum_mixing: str = "none",  # "mixed": momentum rides the wire too
    staleness: int = 1,           # bounded-staleness ring depth S (overlap)
    fault_schedule=None,          # FaultSchedule | spec str (repro.core.faults)
    compressor: str = "none",     # none | int8 | fp8 | topk:p|auto:B | rank:r
    sparse_update: Optional[bool] = None,  # sparse fused update (topk default)
) -> TrainStepBundle:
    rules = shlib.rules_for_mode(mode, mesh)
    n_agents = shlib.agent_count(mesh, mode)
    topology = make_topology(topology_name, n_agents)
    sched_obj = None
    if topology_schedule is not None:
        sched_obj = make_topology_schedule(topology_schedule, n_agents)
    if isinstance(fault_schedule, str):
        from repro.core.faults import make_fault_schedule
        fault_schedule = make_fault_schedule(fault_schedule, n_agents)
    program = consensus_lib.make_mixing_program(
        sched_obj if sched_obj is not None else topology,
        strategy=mixing_strategy, rounds=consensus_rounds,
        error_feedback=error_feedback, exchange=exchange,
        momentum_mixing=momentum_mixing,
        staleness=staleness, faults=fault_schedule,
        compressor=compressor, sparse_update=sparse_update)
    exchange = program.exchange   # compressor aliases normalize the precision
    if not program.is_trivial and mixing != "ppermute_fused":
        raise ValueError(
            f"mixing strategy {program.strategy!r} (rounds={program.rounds}, "
            f"error_feedback={program.error_feedback}) lives on the "
            f"flat-buffer path: requires mixing='ppermute_fused', got "
            f"mixing={mixing!r}")

    base_t = model_template(cfg)
    template = stack_agent_axis(base_t, n_agents)
    pspecs = shlib.safe_partition_specs(template, rules, mesh)
    opt_specs = optimizer.state_specs(pspecs)
    batch_specs = shlib.train_batch_specs(cfg, shape, mesh, mode)
    if mixing == "ppermute_fused":
        # the whole update phase group (pack -> quantize -> exchange ->
        # fused kernel) runs inside one shard_map region; comm members are
        # local fns.
        if not getattr(optimizer, "fused", False):
            warnings.warn(
                f"mixing='ppermute_fused' with {type(optimizer).__name__}"
                "(fused=False): the update falls back to the per-leaf "
                "reference path inside the shard_map region — pass "
                "fused=True for the flat-buffer fast path", stacklevel=2)
        comm = make_local_fused_comm(topology, mesh, mode, interpret=interpret,
                                     exchange=exchange, program=program)
        # non-trivial strategies additionally need the fused optimizer —
        # validate here, not deep inside the first traced step
        engine.check_program_support(optimizer, comm)
    else:
        if exchange != "f32":
            warnings.warn(
                f"exchange={exchange!r} only affects mixing='ppermute_fused'; "
                f"mixing={mixing!r} moves native bytes", stacklevel=2)
        comm = make_mix_comm(topology, mesh, pspecs, mode, mixing)
    init_wire = None
    init_residual = None
    init_qwarm = None
    agent_axes_t = rules["agent"] if isinstance(rules["agent"], tuple) \
        else (rules["agent"],)
    other_axes = tuple(a for a in mesh.axis_names if a not in agent_axes_t)
    state_sp = P(rules["agent"], other_axes or None, None)
    if program.compressed and any(mesh.shape[a] > 1 for a in other_axes):
        raise ValueError(
            f"compressor={program.compressor!r} supports agent-only sharding: "
            f"the rank factors / warm-start bases ((r, 128) and (128, r)) and "
            f"the top-k index payload do not shard over the non-agent mesh "
            f"axes {other_axes}; use an agent-only mesh or a dense "
            f"compressor (int8/fp8)")

    def _n_buckets():
        # one wire/residual entry per flat bucket per payload tree — the
        # mixed momentum payload mirrors the param buckets one-for-one
        return program.n_payloads * flatbuf.make_flat_spec(
            jax.tree.map(lambda pd: jax.ShapeDtypeStruct(pd.shape, pd.dtype),
                         template,
                         is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "init")),
            lead=1).n_buckets

    if program.error_feedback:
        # EF residuals ride the optimizer state like the wire buffers do:
        # one f32 buffer per flat bucket per payload, rows sharded over the
        # non-agent mesh axes (shard-local flat layout), initialized inside
        # shard_map.
        residual_specs = tuple(state_sp for _ in range(_n_buckets()))
        opt_specs = opt_specs._replace(residual=residual_specs)
        local_residual_init = engine.make_local_residual_init(comm.flat)

        def init_residual(params):
            return _shard_map(local_residual_init, mesh, (pspecs,),
                              residual_specs)(params)

    if program.compressed and program.compressor_kind == "rank":
        # the rank compressor's warm-start bases ride the optimizer state
        # like the wire: one (A, 128, r) stack per bucket, agent-sharded,
        # initialized inside shard_map (needed under BOTH schedules — the
        # sync compress_ef consumes them too)
        qwarm_specs = tuple(state_sp for _ in range(_n_buckets()))
        opt_specs = opt_specs._replace(qwarm=qwarm_specs)
        local_qwarm_init = engine.make_local_qwarm_init(comm.flat)

        def init_qwarm(params):
            return _shard_map(local_qwarm_init, mesh, (pspecs,),
                              qwarm_specs)(params)

    if schedule == "overlap":
        if mixing != "ppermute_fused":
            raise ValueError(
                "schedule='overlap' requires mixing='ppermute_fused' (the "
                "one-step-stale wire double-buffer lives on the flat-buffer "
                f"path); got mixing={mixing!r}")
        fl = engine.check_overlap_support(optimizer, comm)
        # The wire double-buffer rides in the optimizer state: one
        # (payload, row-scales) pair per flat bucket, agent axis leading.
        # Buckets pack the *local* shard, so the rows dim shards over every
        # non-agent mesh axis (a model-parallel device pair carries two
        # different row blocks — the wire is never read as one global
        # buffer, only round-tripped shard-to-shard between steps).
        if program.fault_tolerant:
            # Depth-S staleness ring: the ring axis (dim 1) is unsharded —
            # every shard keeps its own S generations locally; rows still
            # shard over the non-agent axes exactly like the flat buffers.
            ring_sp = P(rules["agent"], None, other_axes or None, None)
            wire_specs = consensus_lib.WireRing(
                slots=tuple((ring_sp, ring_sp) for _ in range(_n_buckets())),
                send_age=P(rules["agent"]),
                ages=P(rules["agent"], None))
        elif program.compressed:
            # compressed wire entries are NamedTuples (TopKWire/RankWire);
            # every field carries the leading agent axis and two trailing
            # unsharded dims, so state_sp applies field-wise (agent-only
            # meshes — validated above)
            if program.compressor_kind == "topk":
                wire_specs = tuple(
                    consensus_lib.TopKWire(values=state_sp, indices=state_sp,
                                           scales=state_sp)
                    for _ in range(_n_buckets()))
            else:
                wire_specs = tuple(
                    consensus_lib.RankWire(p=state_sp, qt=state_sp)
                    for _ in range(_n_buckets()))
        else:
            wire_specs = tuple((state_sp, state_sp)
                               for _ in range(_n_buckets()))
        opt_specs = opt_specs._replace(wire=wire_specs)
        local_wire_init = engine.make_local_wire_init(fl)

        def init_wire(params):
            return _shard_map(local_wire_init, mesh, (pspecs,),
                              wire_specs)(params)

    grad_phase = engine.make_grad_phase(
        lambda p, b: loss_fn(cfg, p, b, remat=remat), microbatches)
    update_local = engine.make_update_phase(optimizer, comm, schedule)
    if mixing == "ppermute_fused":
        def update_phase(params, grads, opt_state):
            return _shard_map(
                update_local, mesh,
                (pspecs, pspecs, opt_specs), (pspecs, opt_specs),
            )(params, grads, opt_state)
    else:
        update_phase = update_local

    step_program = engine.StepProgram(
        optimizer=optimizer,
        comm=comm,
        grad_phase=grad_phase,
        update_phase=update_phase,
        schedule=schedule,
        init_wire=init_wire,
        init_residual=init_residual,
        init_qwarm=init_qwarm,
    )

    return TrainStepBundle(
        step_fn=step_program.step_fn,
        param_template=template,
        param_specs=pspecs,
        opt_state_specs=opt_specs,
        batch_specs=batch_specs,
        n_agents=n_agents,
        topology=topology,
        exchange=exchange,
        schedule=schedule,
        mixing_program=program if mixing == "ppermute_fused" else None,
        init_state=step_program.init_state,
        optimizer=optimizer,
    )


@dataclasses.dataclass
class ServeStepBundle:
    step_fn: Callable
    param_template: PyTree
    param_specs: PyTree
    input_structs: Tuple                  # (cache, tokens, cur_index) or batch
    kind: str

    def param_structs(self, mesh: Mesh) -> PyTree:
        def leaf(pd, spec):
            return jax.ShapeDtypeStruct(pd.shape, pd.dtype, sharding=NamedSharding(mesh, spec))
        return jax.tree.map(leaf, self.param_template, self.param_specs,
                            is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "init"))


def build_prefill_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                       *, context_parallel: bool = False) -> ServeStepBundle:
    from repro.nn import attention as attn_lib

    template = model_template(cfg)
    pspecs = shlib.safe_partition_specs(template, shlib.rules_for_mode("serve", mesh), mesh)
    batch_specs = shlib.prefill_batch_specs(cfg, shape, mesh)
    b_axes = shlib.serve_batch_count(shape, mesh)[1]

    def prefill_step(params, batch):
        if context_parallel:
            with attn_lib.context_parallel(b_axes, "model"):
                logits, _ = forward(cfg, params, batch, remat=False)
        else:
            logits, _ = forward(cfg, params, batch, remat=False)
        return logits[:, -1, :]

    return ServeStepBundle(step_fn=prefill_step, param_template=template,
                           param_specs=pspecs, input_structs=(batch_specs,), kind="prefill")


def build_serve_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh) -> ServeStepBundle:
    template = model_template(cfg)
    pspecs = shlib.safe_partition_specs(template, shlib.rules_for_mode("serve", mesh), mesh)
    cache, tokens, cur = shlib.decode_input_specs(cfg, shape, mesh)

    def serve_step(params, cache, tokens, cur_index):
        logits, new_cache = decode_step(cfg, params, cache, tokens, cur_index)
        next_tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return next_tok, new_cache

    return ServeStepBundle(step_fn=serve_step, param_template=template,
                           param_specs=pspecs, input_structs=(cache, tokens, cur),
                           kind="decode")
