"""Sharding resolution: logical axes -> mesh axes, with divisibility guards.

Rules by execution mode (axis names refer to `make_production_mesh`):

* ``train`` (paper-faithful CDSGD): every agent is one slice of the agent
  axes (``data``, or ``pod x data`` multi-pod); params carry a leading
  ``agent`` axis sharded there; tensor-parallel (``tp``) and ``expert``
  dims shard over ``model``; ``fsdp`` dims replicate.
* ``train_hier`` (hierarchical CDSGD — beyond-paper): agents live on the
  ``pod`` axis only; ``fsdp`` dims shard over ``data`` (ZeRO-style weight
  sharding *within* an agent — consistent because Pi-mixing is linear and
  applied shard-wise).
* ``serve``: no agent axis; ``fsdp`` dims shard over ``data`` so very
  large checkpoints spread over the whole pod.

A logical dim is sharded only if its size divides the mapped mesh-axis
product; otherwise it silently replicates (e.g. starcoder2's 36 heads or
granite's 49155-token vocab on a 16-wide model axis).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs.base import ArchConfig, InputShape
from repro.nn.param import ParamDef

P = PartitionSpec


def rules_for_mode(mode: str, mesh: Mesh) -> Dict[str, Any]:
    multi_pod = "pod" in mesh.axis_names
    if mode == "train":
        agent = ("pod", "data") if multi_pod else ("data",)
        return {"agent": agent, "tp": "model", "expert": "model", "fsdp": None}
    if mode == "train_hier":
        if not multi_pod:
            # single-pod hierarchical: agents on data axis are impossible to
            # split further, so fsdp rides the model axis's orthogonal dim.
            return {"agent": ("data",), "tp": "model", "expert": "model", "fsdp": None}
        return {"agent": ("pod",), "tp": "model", "expert": "model", "fsdp": "data"}
    if mode == "serve":
        return {"tp": "model", "expert": "model", "fsdp": "data"}
    raise ValueError(f"unknown mode {mode!r}")


def _axes_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, str):
        return mesh.shape[entry]
    return math.prod(mesh.shape[a] for a in entry)


def safe_partition_specs(template, rules: Dict[str, Any], mesh: Mesh):
    """partition_specs with divisibility fallback per dimension."""

    def leaf(pd: ParamDef) -> PartitionSpec:
        resolved = []
        for dim, ax in zip(pd.shape, pd.axes):
            m = rules.get(ax) if ax is not None else None
            if m is not None and dim % _axes_size(mesh, m) != 0:
                m = None
            resolved.append(m)
        while resolved and resolved[-1] is None:
            resolved.pop()
        return PartitionSpec(*resolved)

    return jax.tree.map(leaf, template, is_leaf=lambda x: isinstance(x, ParamDef))


def named(mesh: Mesh, spec: PartitionSpec) -> NamedSharding:
    return NamedSharding(mesh, spec)


def named_tree(mesh: Mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        specs, is_leaf=lambda x: isinstance(x, PartitionSpec))


# --------------------------------------------------------------------------
# agent geometry
# --------------------------------------------------------------------------


def agent_count(mesh: Mesh, mode: str) -> int:
    rules = rules_for_mode(mode, mesh)
    if "agent" not in rules:
        return 1
    return _axes_size(mesh, rules["agent"])


def batch_axes(mesh: Mesh, mode: str):
    """Mesh axes over which the *within-agent* batch dim shards."""
    if mode == "train_hier" and "pod" in mesh.axis_names:
        return "data"
    return None


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, shardable, no allocation)
# --------------------------------------------------------------------------


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def train_batch_specs(cfg: ArchConfig, shape: InputShape, mesh: Mesh, mode: str):
    """Per-agent stacked batch {"inputs","targets"[,"frontend"]}."""
    rules = rules_for_mode(mode, mesh)
    a = agent_count(mesh, mode)
    agent_ax = rules["agent"]
    b_ax = batch_axes(mesh, mode)
    if shape.global_batch % a:
        raise ValueError(f"global_batch {shape.global_batch} not divisible by {a} agents")
    b_local = shape.global_batch // a
    seq = shape.seq_len
    front = 0
    if cfg.modality in ("audio", "vlm"):
        front = min(cfg.frontend_tokens, seq // 2)
        if not cfg.is_encoder_decoder:
            seq = seq - front   # frontend tokens + text tokens = seq_len budget
    spec3 = P(agent_ax, b_ax, None)
    out = {
        "inputs": _sds((a, b_local, seq), jnp.int32, mesh, spec3),
        "targets": _sds((a, b_local, seq), jnp.int32, mesh, spec3),
    }
    if front:
        out["frontend"] = _sds((a, b_local, front, cfg.frontend_dim), jnp.bfloat16,
                               mesh, P(agent_ax, b_ax, None, None))
    return out


def serve_batch_count(shape: InputShape, mesh: Mesh) -> Tuple[int, Any]:
    """(batch, batch mesh axes) for serve mode."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    size = math.prod(mesh.shape[a] for a in axes)
    b = shape.global_batch
    if b % size == 0:
        return b, tuple(axes)
    if b % mesh.shape["data"] == 0:
        return b, ("data",)
    return b, None


def prefill_batch_specs(cfg: ArchConfig, shape: InputShape, mesh: Mesh):
    b, b_ax = serve_batch_count(shape, mesh)
    seq = shape.seq_len
    front = 0
    if cfg.modality in ("audio", "vlm"):
        front = min(cfg.frontend_tokens, seq // 2)
        if not cfg.is_encoder_decoder:
            seq = seq - front
    out = {
        "inputs": _sds((b, seq), jnp.int32, mesh, P(b_ax, None)),
        "targets": _sds((b, seq), jnp.int32, mesh, P(b_ax, None)),
    }
    if front:
        out["frontend"] = _sds((b, front, cfg.frontend_dim), jnp.bfloat16,
                               mesh, P(b_ax, None, None))
    return out


# --------------------------------------------------------------------------
# decode cache specs
# --------------------------------------------------------------------------


def cache_partition_specs(cfg: ArchConfig, shape: InputShape, mesh: Mesh):
    """PartitionSpec tree mirroring init_cache(cfg, b, max_len).

    Heuristics: shard batch over data axes when divisible; otherwise (the
    long_500k single-request case) shard the *sequence* dim of KV caches
    over all axes.  KV-head dims shard over ``model`` when divisible, else
    the sequence dim takes ``model`` too.
    """
    from repro.nn.transformer import init_cache  # local import to avoid cycle

    b, b_ax = serve_batch_count(shape, mesh)
    model_sz = mesh.shape["model"]
    all_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    enc_len = cfg.frontend_tokens if cfg.is_encoder_decoder else 0
    structure = jax.eval_shape(lambda: init_cache(cfg, b, shape.seq_len, enc_len=enc_len))

    def leaf_spec(path, leaf) -> PartitionSpec:
        keys = [getattr(p, "key", None) for p in path]
        shp = leaf.shape
        if "enc_out" in keys:           # (b, F, d)
            return P(b_ax, None, None)
        if "S" in keys:                 # rwkv state (L, b, n_h, hs, hs)
            nh_ax = "model" if shp[2] % model_sz == 0 else None
            return P(None, b_ax, nh_ax, None, None)
        if "mamba" in keys and len(shp) == 4:
            pass                         # falls through to the mamba rule below
        if "shift" in keys or keys[-1] == "cm":   # (L, b, d)
            d_ax = "model" if shp[2] % model_sz == 0 else None
            return P(None, b_ax, d_ax)
        if "mamba" in keys:             # (L, b, di, n)
            d_ax = "model" if shp[2] % model_sz == 0 else None
            return P(None, b_ax, d_ax, None)
        if keys[-1] in ("k", "v"):      # (L[, period], b, S, KV, hd)
            lead = len(shp) - 4          # leading stack dims (1 or 2)
            if b_ax is None:            # long-context single request
                return P(*([None] * (lead + 1)), all_axes + ("model",), None, None)
            kv_ax = "model" if shp[lead + 2] % model_sz == 0 else None
            seq_ax = None if kv_ax else ("model" if shp[lead + 1] % model_sz == 0 else None)
            return P(*([None] * lead), b_ax, seq_ax, kv_ax, None)
        if keys[-1] in ("c", "kr"):     # MLA (L, b, S, r)
            if b_ax is None:
                return P(None, None, all_axes + ("model",), None)
            seq_ax = "model" if shp[2] % model_sz == 0 else None
            return P(None, b_ax, seq_ax, None)
        # fallback: batch-shard dim 1 if it matches
        return P(*([None] * len(shp)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(structure)
    specs = [leaf_spec(path, leaf) for path, leaf in flat]
    return jax.tree.unflatten(treedef, specs)


def decode_input_specs(cfg: ArchConfig, shape: InputShape, mesh: Mesh):
    """(cache, tokens, cur_index) ShapeDtypeStructs for serve_step lowering."""
    from repro.nn.transformer import init_cache

    b, b_ax = serve_batch_count(shape, mesh)
    enc_len = cfg.frontend_tokens if cfg.is_encoder_decoder else 0
    structure = jax.eval_shape(lambda: init_cache(cfg, b, shape.seq_len, enc_len=enc_len))
    specs = cache_partition_specs(cfg, shape, mesh)
    cache = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        structure, specs)
    tokens = _sds((b, 1), jnp.int32, mesh, P(b_ax, None))
    cur = jax.ShapeDtypeStruct((), jnp.int32)
    return cache, tokens, cur
