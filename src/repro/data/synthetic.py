"""Synthetic datasets + the per-agent partitioner (data parallelism).

The paper's experiments use MNIST / CIFAR-10 / CIFAR-100 with the training
set *distributed across agents* — each agent sees only its own shard
(§2: "agents only have access to their own respective training datasets").
This container is offline, so we generate deterministic synthetic datasets
with the same contracts:

* :func:`make_classification` — Gaussian-mixture "images" with K classes
  (stands in for MNIST/CIFAR in the paper-figure benchmarks; accuracy
  *levels* are dataset-relative, the paper's *relative orderings* between
  algorithms/topologies are what the benchmarks reproduce).
* :func:`make_lm_tokens` — bigram-structured token streams (so an LM's
  loss actually decreases) for the ten assigned architectures.
* :class:`AgentPartitioner` — splits a dataset across N agents, IID
  (shuffled round-robin) or non-IID (label-sorted contiguous shards, the
  standard federated-learning skew), and serves per-agent minibatches
  stacked along a leading agent axis.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Dataset:
    """In-memory dataset: features + integer labels."""

    x: np.ndarray       # (n, ...) float32
    y: np.ndarray       # (n,) int32

    def __len__(self) -> int:
        return self.x.shape[0]


def make_classification(
    n: int = 4096,
    *,
    n_classes: int = 10,
    image_hw: Optional[int] = None,     # if set: (hw, hw, 3) images, else flat
    dim: int = 64,
    noise: float = 1.2,
    seed: int = 0,
    train_fraction: float = 0.85,
) -> Tuple[Dataset, Dataset]:
    """Gaussian-mixture classification; returns (train, validation)."""
    rng = np.random.default_rng(seed)
    if image_hw is not None:
        dim = image_hw * image_hw * 3
    centers = rng.normal(size=(n_classes, dim)).astype(np.float32)
    y = rng.integers(0, n_classes, size=n).astype(np.int32)
    x = centers[y] + noise * rng.normal(size=(n, dim)).astype(np.float32)
    x = x.astype(np.float32)
    if image_hw is not None:
        x = x.reshape(n, image_hw, image_hw, 3)
    split = int(n * train_fraction)
    return Dataset(x[:split], y[:split]), Dataset(x[split:], y[split:])


def make_lm_tokens(
    n_tokens: int = 1 << 16,
    *,
    vocab: int = 512,
    seed: int = 0,
    order: int = 1,
) -> np.ndarray:
    """Markov token stream: learnable structure for LM smoke training."""
    rng = np.random.default_rng(seed)
    # sparse-ish transition table: each token prefers ~8 successors
    prefs = rng.integers(0, vocab, size=(vocab, 8))
    out = np.empty(n_tokens, dtype=np.int32)
    t = rng.integers(0, vocab)
    for i in range(n_tokens):
        out[i] = t
        if rng.random() < 0.85:
            t = int(prefs[t, rng.integers(0, 8)])
        else:
            t = int(rng.integers(0, vocab))
    return out


def lm_batches(
    tokens: np.ndarray, batch: int, seq: int, *, seed: int = 0
) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite iterator of {"inputs","targets"} windows."""
    rng = np.random.default_rng(seed)
    n = tokens.shape[0] - seq - 1
    while True:
        starts = rng.integers(0, n, size=batch)
        inp = np.stack([tokens[s : s + seq] for s in starts])
        tgt = np.stack([tokens[s + 1 : s + seq + 1] for s in starts])
        yield {"inputs": inp, "targets": tgt}


class AgentPartitioner:
    """Distributes a dataset over N agents and serves stacked minibatches.

    IID: a global shuffle then round-robin assignment.  Non-IID: sort by
    label, split into N contiguous shards (each agent sees a biased label
    subset) — the paper's future-work §6(i) setting, used by the non-IID
    ablation benchmark.
    """

    def __init__(self, ds: Dataset, n_agents: int, *, non_iid: bool = False, seed: int = 0):
        self.n_agents = n_agents
        rng = np.random.default_rng(seed)
        idx = np.argsort(ds.y, kind="stable") if non_iid else rng.permutation(len(ds))
        shards = np.array_split(idx, n_agents)
        m = min(len(s) for s in shards)
        self.shards = [s[:m] for s in shards]   # equal shard sizes
        self.ds = ds
        self._rng = np.random.default_rng(seed + 1)

    @property
    def shard_size(self) -> int:
        return len(self.shards[0])

    def batches(self, batch: int) -> Iterator[Dict[str, np.ndarray]]:
        """Infinite iterator of {"x": (A,b,...), "y": (A,b)} stacked batches."""
        while True:
            xs, ys = [], []
            for s in self.shards:
                take = self._rng.choice(s, size=batch, replace=batch > len(s))
                xs.append(self.ds.x[take])
                ys.append(self.ds.y[take])
            yield {"x": np.stack(xs), "y": np.stack(ys)}

    def full_shards(self) -> Dict[str, np.ndarray]:
        xs = np.stack([self.ds.x[s] for s in self.shards])
        ys = np.stack([self.ds.y[s] for s in self.shards])
        return {"x": xs, "y": ys}

    def label_histograms(self) -> np.ndarray:
        """(A, K) label counts per agent — used to verify non-IID skew."""
        k = int(self.ds.y.max()) + 1
        return np.stack([np.bincount(self.ds.y[s], minlength=k) for s in self.shards])


def lm_agent_batches(
    tokens: np.ndarray, n_agents: int, batch_per_agent: int, seq: int, *, seed: int = 0
) -> Iterator[Dict[str, np.ndarray]]:
    """Per-agent LM batches: agent j samples only from its token shard."""
    shards = np.array_split(tokens, n_agents)
    rng = np.random.default_rng(seed)
    while True:
        inp, tgt = [], []
        for sh in shards:
            n = sh.shape[0] - seq - 1
            starts = rng.integers(0, n, size=batch_per_agent)
            inp.append(np.stack([sh[s : s + seq] for s in starts]))
            tgt.append(np.stack([sh[s + 1 : s + seq + 1] for s in starts]))
        yield {"inputs": np.stack(inp), "targets": np.stack(tgt)}
