"""Data pipeline: synthetic datasets + per-agent partitioning."""

from repro.data.synthetic import (
    Dataset,
    AgentPartitioner,
    make_classification,
    make_lm_tokens,
    lm_batches,
    lm_agent_batches,
)

__all__ = [
    "Dataset",
    "AgentPartitioner",
    "make_classification",
    "make_lm_tokens",
    "lm_batches",
    "lm_agent_batches",
]
