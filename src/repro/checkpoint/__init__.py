"""Checkpointing: pytree <-> .npz with path-keyed entries."""

from repro.checkpoint.checkpoint import (
    latest_step,
    restore_checkpoint,
    restore_train_state,
    save_checkpoint,
    save_train_state,
)

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "save_train_state", "restore_train_state"]
