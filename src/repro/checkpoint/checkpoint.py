"""Checkpointing: pytrees saved as .npz keyed by flattened tree paths.

Per-agent training state (stacked params, optimizer momenta, step counter)
round-trips exactly; restore validates structure against a reference
template so a config change can't silently load mismatched weights.

:func:`save_train_state` / :func:`restore_train_state` checkpoint the FULL
collaborative state — params plus the whole ``OptState``, including the
``schedule="overlap"`` wire double-buffer (int8/fp8 payloads + row scales)
and the error-feedback residuals — so a resumed run continues bit-exact.
Saving params alone and re-initializing the optimizer state would silently
reset the carried wire to the ``x_{-1} := x_0`` convention and the
residuals to zero, changing the trajectory from the restore point on.
"""

from __future__ import annotations

import os
import re
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any

_SEP = "::"


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return _SEP.join(parts)


def _to_numpy_native(arr: np.ndarray) -> np.ndarray:
    """npz can't store ml_dtypes (bfloat16 etc.) — reinterpret as raw bytes."""
    if arr.dtype.kind in "biufc":
        return arr
    return arr.view(np.uint8)


def save_checkpoint(directory: str, step: int, tree: PyTree) -> str:
    """Writes ``<dir>/ckpt_<step>.npz``; returns the path."""
    os.makedirs(directory, exist_ok=True)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {_path_str(path): _to_numpy_native(np.asarray(leaf)) for path, leaf in flat}
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for f in os.listdir(directory):
        m = re.fullmatch(r"ckpt_(\d+)\.npz", f)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def save_train_state(directory: str, step: int, params: PyTree,
                     opt_state: Any) -> str:
    """Checkpoint params + the full optimizer state (momenta, step counter,
    overlap wire buffers, error-feedback residuals) as one tree."""
    return save_checkpoint(directory, step,
                           {"params": params, "opt_state": opt_state})


def restore_train_state(directory: str, params_like: PyTree, opt_state_like: Any,
                        step: Optional[int] = None):
    """Restore ``(params, opt_state)`` into the given reference structures.

    ``opt_state_like`` must come from the SAME step-program configuration
    (e.g. ``StepProgram.init_state``) so the wire/residual buffers exist in
    the template; a checkpoint written without them (or with a different
    schedule/strategy) fails loudly instead of silently resetting state.
    """
    tree = restore_checkpoint(directory,
                              {"params": params_like, "opt_state": opt_state_like},
                              step=step)
    return tree["params"], tree["opt_state"]


def restore_checkpoint(directory: str, like: PyTree, step: Optional[int] = None) -> PyTree:
    """Restore into the structure of ``like`` (shapes/dtypes validated)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    with np.load(path) as data:
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for p, ref in flat:
            k = _path_str(p)
            if k not in data:
                raise KeyError(f"checkpoint {path} missing key {k!r}")
            arr = data[k]
            ref_np = np.asarray(ref)
            if ref_np.dtype.kind not in "biufc" and arr.dtype == np.uint8:
                arr = arr.view(ref_np.dtype)   # raw-byte round-trip (bfloat16 etc.)
            if tuple(arr.shape) != tuple(ref_np.shape):
                raise ValueError(f"{k}: checkpoint shape {arr.shape} != expected {ref_np.shape}")
            leaves.append(arr.astype(ref_np.dtype))
    return jax.tree.unflatten(treedef, [l for _, l in zip(flat, leaves)])
