"""Chunked WKV6 recurrence — Pallas TPU kernel.

The RWKV6 time-mix recurrence (per batch x head, head size ``hs``):

    y_t  = r_t . (S_t + (u * k_t) v_t^T)
    S_t+1 = diag(w_t) S_t + k_t v_t^T

is sequential over time, but the working set per step is tiny (an
``hs x hs`` f32 state).  The TPU-native formulation processes the sequence
in VMEM-resident chunks: grid ``(batch*heads, n_chunks)`` with the chunk
dimension innermost, the state matrix living in VMEM scratch across the
chunk sweep, and each grid step streaming one ``(chunk, hs)`` tile of
r/k/v/w from HBM.  HBM traffic is exactly one read of the inputs and one
write of the outputs — the recurrence state never round-trips to HBM
(the pure-jnp ``lax.scan`` version re-materializes the carry per step).

Validated in ``interpret=True`` against :func:`repro.nn.ssm.wkv6_scan`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_out_ref, s_scr,
                 *, chunk: int, hs: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros((hs, hs), jnp.float32)

    r = r_ref[0].astype(jnp.float32)     # (chunk, hs)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)     # (hs,)

    def body(t, carry):
        s, ybuf = carry
        rt = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)[0]
        kt = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)[0]
        vt = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)[0]
        wt = jax.lax.dynamic_slice_in_dim(w, t, 1, 0)[0]
        kv = kt[:, None] * vt[None, :]                    # (hs, hs)
        y = jnp.einsum("i,ij->j", rt, s + u[:, None] * kv)
        s_new = wt[:, None] * s + kv
        ybuf = jax.lax.dynamic_update_slice_in_dim(ybuf, y[None], t, 0)
        return s_new, ybuf

    s0 = s_scr[...]
    y0 = jnp.zeros((chunk, hs), jnp.float32)
    s_fin, ybuf = jax.lax.fori_loop(0, chunk, body, (s0, y0))
    s_scr[...] = s_fin
    o_ref[0] = ybuf.astype(o_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        s_out_ref[0] = s_fin.astype(s_out_ref.dtype)


def wkv6_pallas(
    r: jnp.ndarray,          # (BH, S, hs) — batch*heads folded
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,          # data-dependent decay in (0,1)
    u: jnp.ndarray,          # (BH, hs) per-head bonus (broadcast over batch)
    *,
    chunk: int = 128,
    interpret: bool = False,
):
    """Returns (y (BH, S, hs), final_state (BH, hs, hs))."""
    bh, s, hs = r.shape
    chunk = min(chunk, s)
    if s % chunk:
        raise ValueError(f"seq {s} must divide chunk {chunk}")
    n_chunks = s // chunk

    kernel = functools.partial(_wkv6_kernel, chunk=chunk, hs=hs, n_chunks=n_chunks)
    seq_spec = pl.BlockSpec((1, chunk, hs), lambda bhi, ci: (bhi, ci, 0))
    y, s_fin = pl.pallas_call(
        kernel,
        grid=(bh, n_chunks),
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, hs), lambda bhi, ci: (bhi, 0)),
        ],
        out_specs=(
            seq_spec,
            pl.BlockSpec((1, hs, hs), lambda bhi, ci: (bhi, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((bh, s, hs), r.dtype),
            jax.ShapeDtypeStruct((bh, hs, hs), jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((hs, hs), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return y, s_fin
