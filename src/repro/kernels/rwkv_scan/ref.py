"""Pure-jnp oracle for the WKV6 kernel (same recurrence as repro.nn.ssm)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u):
    """r,k,v,w: (BH, S, hs); u: (BH, hs). Returns (y, final_state (BH,hs,hs))."""
    f32 = jnp.float32
    bh, s, hs = r.shape
    r, k, v, w = (a.astype(f32) for a in (r, k, v, w))
    u = u.astype(f32)

    def one(rb, kb, vb, wb, ub):
        def step(state, xs):
            rt, kt, vt, wt = xs
            kv = kt[:, None] * vt[None, :]
            y = jnp.einsum("i,ij->j", rt, state + ub[:, None] * kv)
            return wt[:, None] * state + kv, y

        state, ys = jax.lax.scan(step, jnp.zeros((hs, hs), f32), (rb, kb, vb, wb))
        return ys, state

    y, state = jax.vmap(one)(r, k, v, w, u)
    return y, state
