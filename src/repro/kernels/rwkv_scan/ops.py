"""jit'd wrapper: WKV6 kernel in model layout (b, s, n_h, hs)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv_scan.rwkv_scan import wkv6_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_bsnh(r, k, v, w, u, *, chunk: int = 128, interpret: bool = True):
    """r,k,v,w: (b, s, n_h, hs); u: (n_h, hs).

    Returns (y (b, s, n_h, hs), state (b, n_h, hs, hs)) — drop-in for
    :func:`repro.nn.ssm.wkv6_scan` with zero initial state.
    """
    b, s, n_h, hs = r.shape

    def fold(x):
        return jnp.swapaxes(x, 1, 2).reshape(b * n_h, s, hs)

    uf = jnp.broadcast_to(u[None], (b, n_h, hs)).reshape(b * n_h, hs)
    y, state = wkv6_pallas(fold(r), fold(k), fold(v), fold(w), uf,
                           chunk=chunk, interpret=interpret)
    y = jnp.swapaxes(y.reshape(b, n_h, s, hs), 1, 2)
    return y, state.reshape(b, n_h, hs, hs)
