"""jit'd wrappers: whole-model fused consensus updates on flat buffers.

The pytree entry points (``cdsgd_update_tree`` & co.) pack the entire model
into dtype-bucketed ``(rows, 128)`` buffers (:mod:`repro.core.flatbuf`) and
run **one** ``pallas_call`` per dtype bucket — not one per leaf.  For a
transformer that collapses hundreds of kernel launches (each with its own
padding waste) into one whole-model HBM sweep per bucket.

``neighbor_trees`` are the already-communicated neighbor parameter pytrees
(the ppermute outputs in the sharded trainer, or plain stacked slices in
simulation) in the same order as ``weights``.

The ``*_update_flat`` entry points operate on already-packed buffers and
dispatch on ``weights.ndim``:

* ``weights (S,)``   — one agent's stencil: ``neighbors (S, rows, 128)``,
  per-agent operands ``(rows, 128)`` (the sharded path inside shard_map);
* ``weights (A, A)`` — the dense stacked simulation: ``neighbors`` is the
  full agent stack ``(A, rows, 128)`` shared by every agent, per-agent
  operands ``(A, rows, 128)``, and the kernel is vmapped over agent rows of
  ``Pi`` (still a single batched ``pallas_call`` in the jaxpr).

``scales`` (same leading shape as ``neighbors``, trailing ``(rows, 1)``)
marks the neighbor stack as int8/fp8-quantized wire payloads
(:func:`repro.kernels.consensus_update.consensus_update.sr_quantize_2d`);
the kernels dequantize in-register during the mixing accumulation.  In that
form ``neighbors`` excludes the self tile — the native-precision self
buffer rides in ``self_buf`` at ``weights[0]`` (per-agent ``(A, rows, 128)``
in the stacked mode, with ``weights (A, A+1)`` = ``[diag(Pi), off-diag
rows]``), since the local parameters never cross the wire.

On CPU (this container) the kernels run with ``interpret=True``; on TPU
pass ``interpret=False`` for the compiled path.
"""

from __future__ import annotations

import functools
from typing import Any, List, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import flatbuf
from repro.kernels.consensus_update.consensus_update import (
    LANE,
    cdsgd_update_2d,
    cdmsgd_update_2d,
    cdmsgd_nesterov_update_2d,
    cdadam_update_2d,
    cdsgd_update_sparse_2d,
    cdmsgd_update_sparse_2d,
    cdmsgd_nesterov_update_sparse_2d,
    cdadam_update_sparse_2d,
)

PyTree = Any


class SparseNeighbors(NamedTuple):
    """Top-k compact neighbor operands for one dtype bucket.

    Passing this as ``neighbors`` to a ``*_update_flat`` entry point selects
    the sparse operand form: the kernel scatter-accumulates straight from the
    wire fields instead of reading a dense decompressed stack.  The fields
    are the :class:`repro.core.consensus.TopKWire` payloads stacked over the
    stencil — ``(S, k_rows, 128)`` int8 values, int32 flat dense indices,
    and ``(S, k_rows, 1)`` f32 scales.  ``self_buf`` is required (the self
    tile never crosses the wire) and ``scales=None`` (per-compact-row scales
    ride inside this tuple).  In the stacked simulation the same compact
    stack is shared by every agent, exactly like the dense quantized form.
    """

    values: jnp.ndarray
    indices: jnp.ndarray
    scales: jnp.ndarray


def _eqn_sub_jaxprs(params: dict):
    for v in params.values():
        vals = v if isinstance(v, (tuple, list)) else (v,)
        for x in vals:
            if isinstance(x, (jax.core.Jaxpr, jax.core.ClosedJaxpr)):
                yield x.jaxpr if isinstance(x, jax.core.ClosedJaxpr) else x


def alias_groups(jaxpr) -> List[List[Tuple[int, int]]]:
    """``input_output_aliases`` pairs per ``pallas_call`` eqn in a jaxpr.

    Shared accounting helper (tests, benchmarks, and the static checker's
    alias-coverage pass): one inner list per launch in eqn order, each
    entry an ``(input_index, output_index)`` alias pair, read structurally
    from ``eqn.params["input_output_aliases"]``.  Accepts a ``Jaxpr`` or
    ``ClosedJaxpr`` (e.g. ``jax.make_jaxpr(fn)(*args)``) and recurses into
    call/control-flow sub-jaxprs; the kernel body itself is not descended
    into.  Printed jaxpr text is rejected — the old regex parse of it
    silently returned ``[]`` whenever jax's pretty-printer elided or
    reformatted the params.
    """
    if isinstance(jaxpr, str):
        raise TypeError(
            "alias_groups walks jaxpr eqns structurally; pass the jaxpr "
            "object from jax.make_jaxpr(...), not its printed text")
    j = jaxpr.jaxpr if isinstance(jaxpr, jax.core.ClosedJaxpr) else jaxpr
    out: List[List[Tuple[int, int]]] = []

    def walk(jx):
        for eqn in jx.eqns:
            if eqn.primitive.name == "pallas_call":
                pairs = eqn.params.get("input_output_aliases", ())
                out.append([(int(a), int(b)) for a, b in pairs])
                continue
            for sub in _eqn_sub_jaxprs(eqn.params):
                walk(sub)

    walk(j)
    return out


# --------------------------------------------------------------------------
# bucket-level entry points (packed buffers in, packed buffers out)
# --------------------------------------------------------------------------


def cdsgd_update_flat(neighbors, weights, grad, alpha, *, scales=None,
                      self_buf=None, interpret: bool = True):
    if isinstance(neighbors, SparseNeighbors):
        nb = neighbors
        if weights.ndim == 2:
            return jax.vmap(lambda w, sb, g: cdsgd_update_sparse_2d(
                nb.values, nb.indices, nb.scales, w, g, alpha, self_buf=sb,
                interpret=interpret))(weights, self_buf, grad)
        return cdsgd_update_sparse_2d(nb.values, nb.indices, nb.scales,
                                      weights, grad, alpha,
                                      self_buf=self_buf, interpret=interpret)
    if weights.ndim == 2:
        if scales is not None:
            return jax.vmap(lambda w, sb, g: cdsgd_update_2d(
                neighbors, w, g, alpha, scales=scales, self_buf=sb,
                interpret=interpret))(weights, self_buf, grad)
        return jax.vmap(lambda w, g: cdsgd_update_2d(
            neighbors, w, g, alpha, interpret=interpret))(weights, grad)
    return cdsgd_update_2d(neighbors, weights, grad, alpha, scales=scales,
                           self_buf=self_buf, interpret=interpret)


def cdmsgd_update_flat(neighbors, weights, grad, momentum, alpha, mu, *,
                       scales=None, self_buf=None, mom_neighbors=None,
                       mom_scales=None, interpret: bool = True):
    if isinstance(neighbors, SparseNeighbors):
        nb = neighbors
        if weights.ndim == 2:
            return jax.vmap(lambda w, sb, g, v: cdmsgd_update_sparse_2d(
                nb.values, nb.indices, nb.scales, w, g, v, alpha, mu,
                self_buf=sb, interpret=interpret))(
                    weights, self_buf, grad, momentum)
        return cdmsgd_update_sparse_2d(nb.values, nb.indices, nb.scales,
                                       weights, grad, momentum, alpha, mu,
                                       self_buf=self_buf, interpret=interpret)
    if weights.ndim == 2:
        if mom_neighbors is not None:
            # mixed momentum: the per-agent momentum row is the momentum
            # SELF tile; the shared wire stacks carry everyone's payloads
            return jax.vmap(lambda w, sb, g, v: cdmsgd_update_2d(
                neighbors, w, g, v, alpha, mu, scales=scales, self_buf=sb,
                mom_neighbors=mom_neighbors, mom_scales=mom_scales,
                interpret=interpret))(weights, self_buf, grad, momentum)
        if scales is not None:
            return jax.vmap(lambda w, sb, g, v: cdmsgd_update_2d(
                neighbors, w, g, v, alpha, mu, scales=scales, self_buf=sb,
                interpret=interpret))(weights, self_buf, grad, momentum)
        return jax.vmap(lambda w, g, v: cdmsgd_update_2d(
            neighbors, w, g, v, alpha, mu,
            interpret=interpret))(weights, grad, momentum)
    return cdmsgd_update_2d(neighbors, weights, grad, momentum, alpha, mu,
                            scales=scales, self_buf=self_buf,
                            mom_neighbors=mom_neighbors,
                            mom_scales=mom_scales, interpret=interpret)


def cdmsgd_nesterov_update_flat(neighbors, weights, grad, momentum, alpha, mu,
                                *, scales=None, self_buf=None,
                                mom_neighbors=None, mom_scales=None,
                                interpret: bool = True):
    if isinstance(neighbors, SparseNeighbors):
        nb = neighbors
        if weights.ndim == 2:
            return jax.vmap(
                lambda w, sb, g, v: cdmsgd_nesterov_update_sparse_2d(
                    nb.values, nb.indices, nb.scales, w, g, v, alpha, mu,
                    self_buf=sb, interpret=interpret))(
                        weights, self_buf, grad, momentum)
        return cdmsgd_nesterov_update_sparse_2d(
            nb.values, nb.indices, nb.scales, weights, grad, momentum,
            alpha, mu, self_buf=self_buf, interpret=interpret)
    if weights.ndim == 2:
        if mom_neighbors is not None:
            return jax.vmap(lambda w, sb, g, v: cdmsgd_nesterov_update_2d(
                neighbors, w, g, v, alpha, mu, scales=scales, self_buf=sb,
                mom_neighbors=mom_neighbors, mom_scales=mom_scales,
                interpret=interpret))(weights, self_buf, grad, momentum)
        if scales is not None:
            return jax.vmap(lambda w, sb, g, v: cdmsgd_nesterov_update_2d(
                neighbors, w, g, v, alpha, mu, scales=scales, self_buf=sb,
                interpret=interpret))(weights, self_buf, grad, momentum)
        return jax.vmap(lambda w, g, v: cdmsgd_nesterov_update_2d(
            neighbors, w, g, v, alpha, mu,
            interpret=interpret))(weights, grad, momentum)
    return cdmsgd_nesterov_update_2d(neighbors, weights, grad, momentum,
                                     alpha, mu, scales=scales,
                                     self_buf=self_buf,
                                     mom_neighbors=mom_neighbors,
                                     mom_scales=mom_scales,
                                     interpret=interpret)


def cdadam_update_flat(neighbors, weights, grad, m, v, alpha, b1, b2, eps,
                       bc1, bc2, *, scales=None, self_buf=None,
                       mom_neighbors=None, mom_scales=None,
                       interpret: bool = True):
    if isinstance(neighbors, SparseNeighbors):
        nb = neighbors
        if weights.ndim == 2:
            return jax.vmap(lambda w, sb, g, mi, vi: cdadam_update_sparse_2d(
                nb.values, nb.indices, nb.scales, w, g, mi, vi, alpha, b1,
                b2, eps, bc1, bc2, self_buf=sb, interpret=interpret))(
                    weights, self_buf, grad, m, v)
        return cdadam_update_sparse_2d(nb.values, nb.indices, nb.scales,
                                       weights, grad, m, v, alpha, b1, b2,
                                       eps, bc1, bc2, self_buf=self_buf,
                                       interpret=interpret)
    if weights.ndim == 2:
        if mom_neighbors is not None:
            return jax.vmap(lambda w, sb, g, mi, vi: cdadam_update_2d(
                neighbors, w, g, mi, vi, alpha, b1, b2, eps, bc1, bc2,
                scales=scales, self_buf=sb, mom_neighbors=mom_neighbors,
                mom_scales=mom_scales, interpret=interpret))(
                    weights, self_buf, grad, m, v)
        if scales is not None:
            return jax.vmap(lambda w, sb, g, mi, vi: cdadam_update_2d(
                neighbors, w, g, mi, vi, alpha, b1, b2, eps, bc1, bc2,
                scales=scales, self_buf=sb, interpret=interpret))(
                    weights, self_buf, grad, m, v)
        return jax.vmap(lambda w, g, mi, vi: cdadam_update_2d(
            neighbors, w, g, mi, vi, alpha, b1, b2, eps, bc1, bc2,
            interpret=interpret))(weights, grad, m, v)
    return cdadam_update_2d(neighbors, weights, grad, m, v, alpha, b1, b2,
                            eps, bc1, bc2, scales=scales, self_buf=self_buf,
                            mom_neighbors=mom_neighbors,
                            mom_scales=mom_scales, interpret=interpret)


# --------------------------------------------------------------------------
# pytree entry points (one kernel launch per dtype bucket)
# --------------------------------------------------------------------------


def _pack_all(spec, self_tree, neighbor_trees, *other_trees):
    """Pack self+neighbors into stacked (S, rows, 128) buckets + extras."""
    self_bufs = flatbuf.pack(self_tree, spec)
    nbr_bufs = [flatbuf.pack(t, spec) for t in neighbor_trees]
    stacked = [jnp.stack([sb] + [nb[i] for nb in nbr_bufs])
               for i, sb in enumerate(self_bufs)]
    others = [flatbuf.pack(t, spec) for t in other_trees]
    return stacked, others


@functools.partial(jax.jit, static_argnames=("interpret",))
def cdsgd_update_tree(
    self_tree: PyTree,
    neighbor_trees: Sequence[PyTree],
    weights: jnp.ndarray,          # (S,) — weight 0 applies to self_tree
    grad_tree: PyTree,
    alpha,
    *,
    interpret: bool = True,
) -> PyTree:
    spec = flatbuf.make_flat_spec(self_tree)
    stacked, (grads,) = _pack_all(spec, self_tree, neighbor_trees, grad_tree)
    outs = [cdsgd_update_2d(nb, weights, g, alpha, interpret=interpret)
            for nb, g in zip(stacked, grads)]
    return flatbuf.unpack(outs, spec)


@functools.partial(jax.jit, static_argnames=("interpret",))
def cdmsgd_update_tree(
    self_tree: PyTree,
    neighbor_trees: Sequence[PyTree],
    weights: jnp.ndarray,
    grad_tree: PyTree,
    momentum_tree: PyTree,
    alpha,
    mu,
    *,
    interpret: bool = True,
):
    spec = flatbuf.make_flat_spec(self_tree)
    stacked, (grads, moms) = _pack_all(
        spec, self_tree, neighbor_trees, grad_tree, momentum_tree)
    pairs = [cdmsgd_update_2d(nb, weights, g, v, alpha, mu, interpret=interpret)
             for nb, g, v in zip(stacked, grads, moms)]
    params = flatbuf.unpack([p for p, _ in pairs], spec)
    mom = flatbuf.unpack([v for _, v in pairs], spec)
    return params, mom


@functools.partial(jax.jit, static_argnames=("interpret",))
def cdmsgd_nesterov_update_tree(
    self_tree: PyTree,
    neighbor_trees: Sequence[PyTree],
    weights: jnp.ndarray,
    grad_tree: PyTree,            # evaluated at the current lookahead point
    momentum_tree: PyTree,
    alpha,
    mu,
    *,
    interpret: bool = True,
):
    """Returns ``(params', momentum', lookahead')`` in one sweep per bucket."""
    spec = flatbuf.make_flat_spec(self_tree)
    stacked, (grads, moms) = _pack_all(
        spec, self_tree, neighbor_trees, grad_tree, momentum_tree)
    triples = [cdmsgd_nesterov_update_2d(nb, weights, g, v, alpha, mu,
                                         interpret=interpret)
               for nb, g, v in zip(stacked, grads, moms)]
    params = flatbuf.unpack([t[0] for t in triples], spec)
    mom = flatbuf.unpack([t[1] for t in triples], spec)
    look = flatbuf.unpack([t[2] for t in triples], spec)
    return params, mom, look


@functools.partial(jax.jit, static_argnames=("interpret",))
def cdadam_update_tree(
    self_tree: PyTree,
    neighbor_trees: Sequence[PyTree],
    weights: jnp.ndarray,
    grad_tree: PyTree,
    m_tree: PyTree,
    v_tree: PyTree,
    alpha,
    b1,
    b2,
    eps,
    bc1,
    bc2,
    *,
    interpret: bool = True,
):
    """Returns ``(params', m', v')``; moments stay local, params mix."""
    spec = flatbuf.make_flat_spec(self_tree)
    stacked, (grads, ms, vs) = _pack_all(
        spec, self_tree, neighbor_trees, grad_tree, m_tree, v_tree)
    triples = [cdadam_update_2d(nb, weights, g, m, v, alpha, b1, b2, eps,
                                bc1, bc2, interpret=interpret)
               for nb, g, m, v in zip(stacked, grads, ms, vs)]
    params = flatbuf.unpack([t[0] for t in triples], spec)
    new_m = flatbuf.unpack([t[1] for t in triples], spec)
    new_v = flatbuf.unpack([t[2] for t in triples], spec)
    return params, new_m, new_v
