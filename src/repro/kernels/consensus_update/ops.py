"""jit'd wrappers: pytree-level fused consensus updates.

`cdsgd_update_tree` applies the fused kernel leaf-by-leaf: each leaf is
flattened, padded to a (rows, 128) tile, updated in one HBM sweep, and
reshaped back.  ``neighbor_trees`` are the already-communicated neighbor
parameter pytrees (the ppermute outputs in the sharded trainer, or plain
stacked slices in simulation) in the same order as ``weights``.

On CPU (this container) the kernels run with ``interpret=True``; on TPU
pass ``interpret=False`` for the compiled path.
"""

from __future__ import annotations

import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.kernels.consensus_update.consensus_update import (
    LANE,
    cdsgd_update_2d,
    cdmsgd_update_2d,
)

PyTree = Any


def _to_tiles(x: jnp.ndarray):
    flat = x.reshape(-1)
    n = flat.shape[0]
    rows = -(-n // LANE)
    pad = rows * LANE - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(rows, LANE), n


def _from_tiles(t: jnp.ndarray, n: int, shape, dtype):
    return t.reshape(-1)[:n].reshape(shape).astype(dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def cdsgd_update_tree(
    self_tree: PyTree,
    neighbor_trees: Sequence[PyTree],
    weights: jnp.ndarray,          # (S,) — weight 0 applies to self_tree
    grad_tree: PyTree,
    alpha,
    *,
    interpret: bool = True,
) -> PyTree:
    def leaf(x, g, *nbrs):
        tiles = [_to_tiles(t)[0] for t in (x,) + nbrs]
        gt, n = _to_tiles(g)
        stacked = jnp.stack(tiles)
        out = cdsgd_update_2d(stacked, weights, gt, alpha, interpret=interpret)
        return _from_tiles(out, n, x.shape, x.dtype)

    return jax.tree.map(leaf, self_tree, grad_tree, *neighbor_trees)


@functools.partial(jax.jit, static_argnames=("interpret",))
def cdmsgd_update_tree(
    self_tree: PyTree,
    neighbor_trees: Sequence[PyTree],
    weights: jnp.ndarray,
    grad_tree: PyTree,
    momentum_tree: PyTree,
    alpha,
    mu,
    *,
    interpret: bool = True,
):
    def leaf(x, g, v, *nbrs):
        tiles = [_to_tiles(t)[0] for t in (x,) + nbrs]
        gt, n = _to_tiles(g)
        vt, _ = _to_tiles(v)
        stacked = jnp.stack(tiles)
        out, new_v = cdmsgd_update_2d(stacked, weights, gt, vt, alpha, mu,
                                      interpret=interpret)
        return (_from_tiles(out, n, x.shape, x.dtype),
                _from_tiles(new_v, n, v.shape, v.dtype))

    pairs = jax.tree.map(leaf, self_tree, grad_tree, momentum_tree, *neighbor_trees)
    flat, treedef = jax.tree.flatten(pairs, is_leaf=lambda t: isinstance(t, tuple))
    params = jax.tree.unflatten(treedef, [p for p, _ in flat])
    mom = jax.tree.unflatten(treedef, [v for _, v in flat])
    return params, mom
