"""Fused consensus-SGD update kernel (paper eq. 5) — Pallas TPU.

Per optimization step, every agent computes

    x' = sum_s w_s * neighbor_s  -  alpha * g          (CDSGD)
    v' = mu v - alpha g ; x' = sum_s w_s * neighbor_s + v'   (CDMSGD)

over the *entire* parameter vector.  Unfused, that is >= deg+2 separate
HBM sweeps (one per neighbor buffer, one for the gradient, one write);
on TPU the op is purely memory-bound, so fusing mixing + momentum + update
into a single pass halves-to-thirds the HBM traffic of the optimizer step.

Layout: parameters are flattened to 2-D ``(rows, 128)`` tiles (lane dim
128-aligned for the VPU); neighbors are stacked ``(S, rows, 128)``.  The
grid walks row-blocks; each grid step loads one ``(block_rows, 128)`` tile
of self/neighbors/grad into VMEM, accumulates in f32, and writes the
updated tile.  ``S`` (the neighbor-stencil size = topology degree + self)
is static — for a ring it is 3, for a 2-D torus 5.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
DEFAULT_BLOCK_ROWS = 256


def _cdsgd_kernel(w_ref, alpha_ref, nbrs_ref, grad_ref, out_ref, *, n_stencil: int):
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for s in range(n_stencil):
        acc += w_ref[s] * nbrs_ref[s].astype(jnp.float32)
    acc -= alpha_ref[0] * grad_ref[...].astype(jnp.float32)
    out_ref[...] = acc.astype(out_ref.dtype)


def _cdmsgd_kernel(w_ref, alpha_ref, mu_ref, nbrs_ref, grad_ref, mom_ref,
                   out_ref, new_mom_ref, *, n_stencil: int):
    v = mu_ref[0] * mom_ref[...].astype(jnp.float32) \
        - alpha_ref[0] * grad_ref[...].astype(jnp.float32)
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for s in range(n_stencil):
        acc += w_ref[s] * nbrs_ref[s].astype(jnp.float32)
    out_ref[...] = (acc + v).astype(out_ref.dtype)
    new_mom_ref[...] = v.astype(new_mom_ref.dtype)


def _cdmsgd_nesterov_kernel(w_ref, alpha_ref, mu_ref, nbrs_ref, grad_ref,
                            mom_ref, out_ref, new_mom_ref, look_ref,
                            *, n_stencil: int):
    """CDMSGD + the *next* step's Nesterov lookahead point in the same sweep.

    ``look = x' + mu v'`` is where Algorithm 3 evaluates the next gradient;
    emitting it here saves the separate ``tree_axpy`` HBM pass the unfused
    path pays before every backward.
    """
    mu = mu_ref[0]
    v = mu * mom_ref[...].astype(jnp.float32) \
        - alpha_ref[0] * grad_ref[...].astype(jnp.float32)
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for s in range(n_stencil):
        acc += w_ref[s] * nbrs_ref[s].astype(jnp.float32)
    x = acc + v
    out_ref[...] = x.astype(out_ref.dtype)
    new_mom_ref[...] = v.astype(new_mom_ref.dtype)
    look_ref[...] = (x + mu * v).astype(look_ref.dtype)


def _cdadam_kernel(w_ref, scal_ref, nbrs_ref, grad_ref, m_ref, v_ref,
                   out_ref, new_m_ref, new_v_ref, *, n_stencil: int):
    """Consensus mixing + local Adam moments, one f32-accumulated pass.

    ``scal_ref`` packs [alpha, b1, b2, eps, bc1, bc2] — the bias corrections
    ``bc = 1 - beta^t`` depend on the (traced) step and are computed outside.
    """
    alpha, b1, b2, eps, bc1, bc2 = (scal_ref[i] for i in range(6))
    g = grad_ref[...].astype(jnp.float32)
    m = b1 * m_ref[...].astype(jnp.float32) + (1.0 - b1) * g
    v = b2 * v_ref[...].astype(jnp.float32) + (1.0 - b2) * g * g
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for s in range(n_stencil):
        acc += w_ref[s] * nbrs_ref[s].astype(jnp.float32)
    step_dir = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
    out_ref[...] = (acc - alpha * step_dir).astype(out_ref.dtype)
    new_m_ref[...] = m.astype(new_m_ref.dtype)
    new_v_ref[...] = v.astype(new_v_ref.dtype)


def _grid_and_specs(rows: int, block_rows: int, n_stencil: int):
    grid = (pl.cdiv(rows, block_rows),)
    nbr_spec = pl.BlockSpec((n_stencil, block_rows, LANE), lambda i: (0, i, 0))
    mat_spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    return grid, nbr_spec, mat_spec


def cdsgd_update_2d(
    neighbors: jnp.ndarray,       # (S, rows, 128) — neighbor (incl. self) tiles
    weights: jnp.ndarray,         # (S,) f32 — Pi row restricted to the stencil
    grad: jnp.ndarray,            # (rows, 128)
    alpha,                        # scalar
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jnp.ndarray:
    s, rows, lane = neighbors.shape
    assert lane == LANE and grad.shape == (rows, lane)
    block_rows = min(block_rows, rows)
    grid, nbr_spec, mat_spec = _grid_and_specs(rows, block_rows, s)
    kernel = functools.partial(_cdsgd_kernel, n_stencil=s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((s,), lambda i: (0,)),        # weights (whole, tiny)
            pl.BlockSpec((1,), lambda i: (0,)),        # alpha
            nbr_spec,
            mat_spec,
        ],
        out_specs=mat_spec,
        out_shape=jax.ShapeDtypeStruct((rows, lane), neighbors.dtype),
        interpret=interpret,
    )(weights.astype(jnp.float32), jnp.asarray([alpha], jnp.float32), neighbors, grad)


def cdmsgd_update_2d(
    neighbors: jnp.ndarray,       # (S, rows, 128)
    weights: jnp.ndarray,         # (S,)
    grad: jnp.ndarray,            # (rows, 128)
    momentum: jnp.ndarray,        # (rows, 128)
    alpha,
    mu,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
):
    s, rows, lane = neighbors.shape
    block_rows = min(block_rows, rows)
    grid, nbr_spec, mat_spec = _grid_and_specs(rows, block_rows, s)
    kernel = functools.partial(_cdmsgd_kernel, n_stencil=s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((s,), lambda i: (0,)),        # weights
            pl.BlockSpec((1,), lambda i: (0,)),        # alpha
            pl.BlockSpec((1,), lambda i: (0,)),        # mu
            nbr_spec,
            mat_spec,
            mat_spec,
        ],
        out_specs=(mat_spec, mat_spec),
        out_shape=(
            jax.ShapeDtypeStruct((rows, lane), neighbors.dtype),
            jax.ShapeDtypeStruct((rows, lane), momentum.dtype),
        ),
        interpret=interpret,
    )(weights.astype(jnp.float32), jnp.asarray([alpha], jnp.float32),
      jnp.asarray([mu], jnp.float32), neighbors, grad, momentum)


def cdmsgd_nesterov_update_2d(
    neighbors: jnp.ndarray,       # (S, rows, 128)
    weights: jnp.ndarray,         # (S,)
    grad: jnp.ndarray,            # (rows, 128) — evaluated at the lookahead
    momentum: jnp.ndarray,        # (rows, 128)
    alpha,
    mu,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
):
    """Returns ``(x', v', x' + mu v')`` — params, momentum, next lookahead."""
    s, rows, lane = neighbors.shape
    block_rows = min(block_rows, rows)
    grid, nbr_spec, mat_spec = _grid_and_specs(rows, block_rows, s)
    kernel = functools.partial(_cdmsgd_nesterov_kernel, n_stencil=s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((s,), lambda i: (0,)),        # weights
            pl.BlockSpec((1,), lambda i: (0,)),        # alpha
            pl.BlockSpec((1,), lambda i: (0,)),        # mu
            nbr_spec,
            mat_spec,
            mat_spec,
        ],
        out_specs=(mat_spec, mat_spec, mat_spec),
        out_shape=(
            jax.ShapeDtypeStruct((rows, lane), neighbors.dtype),
            jax.ShapeDtypeStruct((rows, lane), momentum.dtype),
            jax.ShapeDtypeStruct((rows, lane), neighbors.dtype),
        ),
        interpret=interpret,
    )(weights.astype(jnp.float32), jnp.asarray([alpha], jnp.float32),
      jnp.asarray([mu], jnp.float32), neighbors, grad, momentum)


def cdadam_update_2d(
    neighbors: jnp.ndarray,       # (S, rows, 128)
    weights: jnp.ndarray,         # (S,)
    grad: jnp.ndarray,            # (rows, 128)
    m: jnp.ndarray,               # (rows, 128) first moment (local)
    v: jnp.ndarray,               # (rows, 128) second moment (local)
    alpha,
    b1,
    b2,
    eps,
    bc1,                          # 1 - b1**t (traced; computed by the caller)
    bc2,                          # 1 - b2**t
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
):
    """Returns ``(x', m', v')`` — mixed params with a local-Adam step."""
    s, rows, lane = neighbors.shape
    block_rows = min(block_rows, rows)
    grid, nbr_spec, mat_spec = _grid_and_specs(rows, block_rows, s)
    kernel = functools.partial(_cdadam_kernel, n_stencil=s)
    scal = jnp.stack([jnp.asarray(x, jnp.float32) for x in
                      (alpha, b1, b2, eps, bc1, bc2)])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((s,), lambda i: (0,)),        # weights
            pl.BlockSpec((6,), lambda i: (0,)),        # packed scalars
            nbr_spec,
            mat_spec,
            mat_spec,
            mat_spec,
        ],
        out_specs=(mat_spec, mat_spec, mat_spec),
        out_shape=(
            jax.ShapeDtypeStruct((rows, lane), neighbors.dtype),
            jax.ShapeDtypeStruct((rows, lane), m.dtype),
            jax.ShapeDtypeStruct((rows, lane), v.dtype),
        ),
        interpret=interpret,
    )(weights.astype(jnp.float32), scal, neighbors, grad, m, v)
