"""Fused consensus-SGD update kernel (paper eq. 5) — Pallas TPU.

Per optimization step, every agent computes

    x' = sum_s w_s * neighbor_s  -  alpha * g          (CDSGD)
    v' = mu v - alpha g ; x' = sum_s w_s * neighbor_s + v'   (CDMSGD)

over the *entire* parameter vector.  Unfused, that is >= deg+2 separate
HBM sweeps (one per neighbor buffer, one for the gradient, one write);
on TPU the op is purely memory-bound, so fusing mixing + momentum + update
into a single pass halves-to-thirds the HBM traffic of the optimizer step.

Layout: parameters are flattened to 2-D ``(rows, 128)`` tiles (lane dim
128-aligned for the VPU); neighbors are stacked ``(S, rows, 128)``.  The
grid walks row-blocks; each grid step loads one ``(block_rows, 128)`` tile
of self/neighbors/grad into VMEM, accumulates in f32, and writes the
updated tile.  ``S`` (the neighbor-stencil size = topology degree + self)
is static — for a ring it is 3, for a 2-D torus 5.

Quantized neighbor exchange
---------------------------
The neighbor stack may arrive **quantized** (int8 or fp8-e4m3, one f32
scale per 128-lane row: ``scales (S, rows, 1)``) — the form produced by
:func:`sr_quantize_2d` before the circulant ``ppermute`` so each shift
moves ~4x fewer bytes.  Passing ``scales`` (plus the native-precision
``self_buf``, which never crossed the wire and therefore pays no
quantization noise — ``weights[0]`` applies to it, ``weights[1:]`` to the
wire payloads) to any ``*_update_2d`` wrapper dequantizes **in-register**
during the mixing accumulation (one extra VPU multiply per element); the
dequantized neighbor tiles are never materialized in HBM.

Quantization uses stochastic rounding — unbiased, so consensus averaging
stays centered — via ``pltpu.prng_random_bits`` on TPU and a
``jax.random``-based fallback under interpret mode (the TPU PRNG
primitives have no CPU lowering).

Sparse (top-k wire) operand form
--------------------------------
The neighbor stack may also arrive **top-k compressed** — the
:class:`repro.core.consensus.TopKWire` compact fields (int8 ``values
(S, k_rows, 128)``, int32 flat ``indices (S, k_rows, 128)``, f32 ``scales
(S, k_rows, 1)``) — consumed directly by the ``*_update_sparse_2d`` entry
points: the kernel scatter-accumulates ``w[s+1] * scale * dequant(value)``
into the self-separated f32 accumulator, so the neighbor mix reads
``k_rows * 128`` elements per neighbor instead of ``rows * 128`` and the
dense decompressed buffer is never materialized in HBM.  The compact
operands stay resident across the row-block grid (constant index_map);
each grid step masks the flat indices into its own block's element range
``[row0 * 128, (row0 + block_rows) * 128)`` using a per-block ``row0``
OPERAND — like the quantize seeds, ``pl.program_id`` would silently
re-bind under the stacked mode's vmap over agents.  The in-kernel scatter
is a value-level ``.at[].add`` on the flattened VMEM tile (exact under
interpret mode; a compiled TPU lowering routes it through Mosaic's
scatter support or falls back to XLA outside the kernel — this container
runs interpret).  The dense gather-dequant path
(:func:`repro.kernels.consensus_update.topk.topk_decompress_2d` + the
dense kernels) stays exported as the reference oracle; the two paths
agree bit-for-bit at f32 accumulation (tested).

In-place updates
----------------
Every fused kernel threads ``input_output_aliases``: the gradient operand
donates its buffer to the updated params and each optimizer-state operand
(momentum / Adam moments) donates to its successor, so the whole update
allocates no extra HBM output copy per model/slot (``alias=False`` opts
out, e.g. when a caller reuses the gradient afterwards).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
DEFAULT_BLOCK_ROWS = 256

_QMAX = {"int8": 127.0, "fp8": 448.0}          # fp8 = float8_e4m3fn
_QDTYPE = {"int8": jnp.int8, "fp8": jnp.float8_e4m3fn}


# --------------------------------------------------------------------------
# quantize stage (runs before the ppermute exchange)
# --------------------------------------------------------------------------


# decorrelates the PRNG streams of adjacent row blocks; a per-block seed
# OPERAND (not `pl.program_id`) keeps the streams correct when the whole
# pallas_call is vmapped over agents (the batching rule prepends the batch
# axis to the grid, which would silently re-bind program_id(0)).
_SEED_BLOCK_STRIDE = 15485863


def _quantize_math(xf, u, qmax: float, qdtype):
    """Shared per-row scale + rounding math of both sr_quantize_2d paths.

    ``u`` is the uniform-[0,1) stochastic-rounding draw, or None for
    deterministic nearest rounding (fp8).  One definition keeps the TPU
    kernel and the CPU-interpret fallback from drifting apart.
    """
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    scaled = xf / scale
    if u is not None:
        scaled = jnp.clip(jnp.floor(scaled + u), -qmax, qmax)
    return scaled.astype(qdtype), scale


def _sr_quantize_kernel(seed_ref, x_ref, q_ref, scale_ref, *, qmax: float,
                        stochastic: bool):
    """Per-row (128-lane block) scaled quantization with stochastic rounding."""
    from jax.experimental.pallas import tpu as pltpu

    u = None
    if stochastic:
        pltpu.prng_seed(seed_ref[0])          # per-block seed operand
        bits = pltpu.bitcast(pltpu.prng_random_bits(x_ref.shape), jnp.uint32)
        # top 24 bits: exactly representable in f32, so u stays strictly < 1
        # (a raw 2^-32 scaling rounds the largest uint32s up to u == 1.0,
        # which would bias floor(x + u) upward by a full quantization step)
        u = (bits >> 8).astype(jnp.float32) * (1.0 / 16777216.0)
    q, scale = _quantize_math(x_ref[...].astype(jnp.float32), u, qmax,
                              q_ref.dtype)
    q_ref[...] = q
    scale_ref[...] = scale


def sr_quantize_2d(
    x: jnp.ndarray,               # (rows, 128) — one packed flat bucket
    seed,                         # int32 scalar (traced ok); per-step seed
    *,
    exchange: str = "int8",       # "int8" (stochastic) | "fp8" (nearest)
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> tuple:
    """Quantize a flat bucket for the wire: ``(q, scales)``.

    ``q`` is ``(rows, 128)`` int8 / float8_e4m3fn, ``scales`` is
    ``(rows, 1)`` f32 — one scale per 128-element row block, so a transfer
    costs ``rows * (128 + 4)`` bytes instead of ``rows * 512`` (f32).

    int8 uses stochastic rounding (unbiased: ``E[q * scale] = x``); fp8
    e4m3 uses nearest rounding (its 3-bit mantissa makes SR needless for
    consensus averaging).  On CPU/interpret the TPU PRNG primitives do not
    lower, so the stochastic path draws its uniforms from ``jax.random``
    with the same per-``seed`` determinism.
    """
    rows, lane = x.shape
    assert lane == LANE, x.shape
    qmax = _QMAX[exchange]
    qdtype = _QDTYPE[exchange]
    stochastic = exchange == "int8"
    if interpret:
        u = None
        if stochastic:
            key = jax.random.PRNGKey(jnp.asarray(seed, jnp.int32))
            u = jax.random.uniform(key, x.shape, jnp.float32)
        return _quantize_math(x.astype(jnp.float32), u, qmax, qdtype)
    block_rows = min(block_rows, rows)
    n_blocks = pl.cdiv(rows, block_rows)
    kernel = functools.partial(_sr_quantize_kernel, qmax=qmax,
                               stochastic=stochastic)
    block_seeds = (jnp.asarray(seed, jnp.int32)
                   + _SEED_BLOCK_STRIDE * jnp.arange(n_blocks, dtype=jnp.int32))
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),                 # per-block seed
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((rows, lane), qdtype),
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        ),
        interpret=interpret,
    )(block_seeds, x)


def sr_dequantize_2d(q: jnp.ndarray, scales: jnp.ndarray,
                     dtype=jnp.float32) -> jnp.ndarray:
    """Reference inverse of :func:`sr_quantize_2d` (tests / oracle only —
    the fused kernels dequantize in-register and never materialize this)."""
    return (q.astype(jnp.float32) * scales).astype(dtype)


# --------------------------------------------------------------------------
# fused update kernels
# --------------------------------------------------------------------------


def _mix_stencil(w_ref, nbrs_ref, scales_ref, self_ref, n_stencil: int, shape):
    """f32 mixing accumulation.

    Unquantized (``scales_ref is None``): ``neighbors`` includes self and
    ``weights`` is the full ``(S,)`` stencil row.  Quantized: the self
    buffer stays in native precision (it never crosses the wire) at
    ``weights[0]``; ``neighbors`` holds the ``n_stencil`` int8/fp8 wire
    payloads which are dequantized in-register with their per-row scales
    at ``weights[1:]``.
    """
    if scales_ref is None:
        acc = jnp.zeros(shape, jnp.float32)
        for s in range(n_stencil):
            acc += w_ref[s] * nbrs_ref[s].astype(jnp.float32)
        return acc
    acc = w_ref[0] * self_ref[...].astype(jnp.float32)
    for s in range(n_stencil):
        acc += w_ref[s + 1] * (nbrs_ref[s].astype(jnp.float32) * scales_ref[s])
    return acc


def _sparse_stencil(w_ref, row0_ref, vals_ref, idx_ref, sc_ref, self_ref,
                    n_stencil: int, shape):
    """f32 mixing accumulation over top-k compact neighbor payloads.

    The self tile stays dense at ``weights[0]`` exactly like the quantized
    form; each neighbor contributes ``w[s+1] * scale * dequant(value)``
    scatter-accumulated at its flat dense indices.  ``row0_ref`` holds this
    grid step's first dense row (a per-block operand, NOT ``program_id`` —
    see the quantize-seed comment above): indices outside the block's
    element range are masked to contribute 0.0 at position 0, so a compact
    element lands in exactly one grid step.  Per element the accumulation
    order matches the dense oracle (stencil-major, f32), so the two forms
    agree bit-for-bit.
    """
    block_elems = shape[0] * shape[1]
    acc = (w_ref[0] * self_ref[...].astype(jnp.float32)).reshape(block_elems)
    base = row0_ref[0] * LANE
    for s in range(n_stencil):
        deq = vals_ref[s].astype(jnp.float32) * sc_ref[s]   # (k_rows, 128)
        li = idx_ref[s].reshape(-1) - base
        ok = (li >= 0) & (li < block_elems)
        contrib = jnp.where(ok, w_ref[s + 1] * deq.reshape(-1), 0.0)
        acc = acc.at[jnp.where(ok, li, 0)].add(contrib)
    return acc.reshape(shape)


def _cdsgd_body(w_ref, alpha_ref, nbrs_ref, scales_ref, self_ref, grad_ref,
                out_ref, *, n_stencil: int):
    acc = _mix_stencil(w_ref, nbrs_ref, scales_ref, self_ref, n_stencil,
                       out_ref.shape)
    acc -= alpha_ref[0] * grad_ref[...].astype(jnp.float32)
    out_ref[...] = acc.astype(out_ref.dtype)


def _cdsgd_kernel(w, a, nbrs, grad, out, *, n_stencil):
    _cdsgd_body(w, a, nbrs, None, None, grad, out, n_stencil=n_stencil)


def _cdsgd_kernel_q(w, a, slf, nbrs, scales, grad, out, *, n_stencil):
    _cdsgd_body(w, a, nbrs, scales, slf, grad, out, n_stencil=n_stencil)


def _cdmsgd_body(w_ref, alpha_ref, mu_ref, nbrs_ref, scales_ref, self_ref,
                 grad_ref, mom_ref, out_ref, new_mom_ref, *, n_stencil: int):
    v = mu_ref[0] * mom_ref[...].astype(jnp.float32) \
        - alpha_ref[0] * grad_ref[...].astype(jnp.float32)
    acc = _mix_stencil(w_ref, nbrs_ref, scales_ref, self_ref, n_stencil,
                       out_ref.shape)
    out_ref[...] = (acc + v).astype(out_ref.dtype)
    new_mom_ref[...] = v.astype(new_mom_ref.dtype)


def _cdmsgd_kernel(w, a, m, nbrs, grad, mom, out, nmom, *, n_stencil):
    _cdmsgd_body(w, a, m, nbrs, None, None, grad, mom, out, nmom,
                 n_stencil=n_stencil)


def _cdmsgd_kernel_q(w, a, m, slf, nbrs, scales, grad, mom, out, nmom,
                     *, n_stencil):
    _cdmsgd_body(w, a, m, nbrs, scales, slf, grad, mom, out, nmom,
                 n_stencil=n_stencil)


def _cdmsgd_kernel_qm(w, a, m, slf, nbrs, scales, vnbrs, vscales, grad, mom,
                      out, nmom, *, n_stencil):
    """Mixed-momentum CDMSGD: ``v' = mu (Pi v) - a g ; x' = Pi x + v'``.

    The momentum buffer rode the wire next to the params, so both mixing
    sums share the same self-separated weights; the local momentum operand
    ``mom`` is the momentum SELF tile (fresh, full precision — it never
    crossed the wire), mixed at ``weights[0]`` exactly like the params.
    """
    vmix = _mix_stencil(w, vnbrs, vscales, mom, n_stencil, out.shape)
    v = m[0] * vmix - a[0] * grad[...].astype(jnp.float32)
    acc = _mix_stencil(w, nbrs, scales, slf, n_stencil, out.shape)
    out[...] = (acc + v).astype(out.dtype)
    nmom[...] = v.astype(nmom.dtype)


def _cdmsgd_nesterov_body(w_ref, alpha_ref, mu_ref, nbrs_ref, scales_ref,
                          self_ref, grad_ref, mom_ref, out_ref, new_mom_ref,
                          look_ref, *, n_stencil: int):
    """CDMSGD + the *next* step's Nesterov lookahead point in the same sweep.

    ``look = x' + mu v'`` is where Algorithm 3 evaluates the next gradient;
    emitting it here saves the separate ``tree_axpy`` HBM pass the unfused
    path pays before every backward.
    """
    mu = mu_ref[0]
    v = mu * mom_ref[...].astype(jnp.float32) \
        - alpha_ref[0] * grad_ref[...].astype(jnp.float32)
    acc = _mix_stencil(w_ref, nbrs_ref, scales_ref, self_ref, n_stencil,
                       out_ref.shape)
    x = acc + v
    out_ref[...] = x.astype(out_ref.dtype)
    new_mom_ref[...] = v.astype(new_mom_ref.dtype)
    look_ref[...] = (x + mu * v).astype(look_ref.dtype)


def _cdmsgd_nesterov_kernel(w, a, m, nbrs, grad, mom, out, nmom, look,
                            *, n_stencil):
    _cdmsgd_nesterov_body(w, a, m, nbrs, None, None, grad, mom, out, nmom,
                          look, n_stencil=n_stencil)


def _cdmsgd_nesterov_kernel_q(w, a, m, slf, nbrs, scales, grad, mom, out,
                              nmom, look, *, n_stencil):
    _cdmsgd_nesterov_body(w, a, m, nbrs, scales, slf, grad, mom, out, nmom,
                          look, n_stencil=n_stencil)


def _cdmsgd_nesterov_kernel_qm(w, a, m, slf, nbrs, scales, vnbrs, vscales,
                               grad, mom, out, nmom, look, *, n_stencil):
    """Mixed-momentum Nesterov: the momentum mix feeds both the update and
    the emitted lookahead ``x' + mu v'`` in the same sweep."""
    mu = m[0]
    vmix = _mix_stencil(w, vnbrs, vscales, mom, n_stencil, out.shape)
    v = mu * vmix - a[0] * grad[...].astype(jnp.float32)
    acc = _mix_stencil(w, nbrs, scales, slf, n_stencil, out.shape)
    x = acc + v
    out[...] = x.astype(out.dtype)
    nmom[...] = v.astype(nmom.dtype)
    look[...] = (x + mu * v).astype(look.dtype)


def _cdadam_body(w_ref, scal_ref, nbrs_ref, scales_ref, self_ref, grad_ref,
                 m_ref, v_ref, out_ref, new_m_ref, new_v_ref,
                 *, n_stencil: int):
    """Consensus mixing + local Adam moments, one f32-accumulated pass.

    ``scal_ref`` packs [alpha, b1, b2, eps, bc1, bc2] — the bias corrections
    ``bc = 1 - beta^t`` depend on the (traced) step and are computed outside.
    """
    alpha, b1, b2, eps, bc1, bc2 = (scal_ref[i] for i in range(6))
    g = grad_ref[...].astype(jnp.float32)
    m = b1 * m_ref[...].astype(jnp.float32) + (1.0 - b1) * g
    v = b2 * v_ref[...].astype(jnp.float32) + (1.0 - b2) * g * g
    acc = _mix_stencil(w_ref, nbrs_ref, scales_ref, self_ref, n_stencil,
                       out_ref.shape)
    step_dir = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
    out_ref[...] = (acc - alpha * step_dir).astype(out_ref.dtype)
    new_m_ref[...] = m.astype(new_m_ref.dtype)
    new_v_ref[...] = v.astype(new_v_ref.dtype)


def _cdadam_kernel(w, sc, nbrs, grad, m, v, out, nm, nv, *, n_stencil):
    _cdadam_body(w, sc, nbrs, None, None, grad, m, v, out, nm, nv,
                 n_stencil=n_stencil)


def _cdadam_kernel_q(w, sc, slf, nbrs, scales, grad, m, v, out, nm, nv,
                     *, n_stencil):
    _cdadam_body(w, sc, nbrs, scales, slf, grad, m, v, out, nm, nv,
                 n_stencil=n_stencil)


def _cdadam_kernel_qm(w, scal, slf, nbrs, scales, mnbrs, mscales, grad, m, v,
                      out, nm, nv, *, n_stencil):
    """Mixed-momentum CDAdam: ``m' = b1 (Pi m) + (1-b1) g``; the second
    moment stays local (a positive scale, not a direction)."""
    alpha, b1, b2, eps, bc1, bc2 = (scal[i] for i in range(6))
    g = grad[...].astype(jnp.float32)
    mmix = _mix_stencil(w, mnbrs, mscales, m, n_stencil, out.shape)
    new_m = b1 * mmix + (1.0 - b1) * g
    new_v = b2 * v[...].astype(jnp.float32) + (1.0 - b2) * g * g
    acc = _mix_stencil(w, nbrs, scales, slf, n_stencil, out.shape)
    step_dir = (new_m / bc1) / (jnp.sqrt(new_v / bc2) + eps)
    out[...] = (acc - alpha * step_dir).astype(out.dtype)
    nm[...] = new_m.astype(nm.dtype)
    nv[...] = new_v.astype(nv.dtype)


def _cdsgd_kernel_s(w, a, row0, slf, vals, idx, sc, grad, out, *, n_stencil):
    acc = _sparse_stencil(w, row0, vals, idx, sc, slf, n_stencil, out.shape)
    acc -= a[0] * grad[...].astype(jnp.float32)
    out[...] = acc.astype(out.dtype)


def _cdmsgd_kernel_s(w, a, m, row0, slf, vals, idx, sc, grad, mom, out, nmom,
                     *, n_stencil):
    v = m[0] * mom[...].astype(jnp.float32) \
        - a[0] * grad[...].astype(jnp.float32)
    acc = _sparse_stencil(w, row0, vals, idx, sc, slf, n_stencil, out.shape)
    out[...] = (acc + v).astype(out.dtype)
    nmom[...] = v.astype(nmom.dtype)


def _cdmsgd_nesterov_kernel_s(w, a, m, row0, slf, vals, idx, sc, grad, mom,
                              out, nmom, look, *, n_stencil):
    mu = m[0]
    v = mu * mom[...].astype(jnp.float32) \
        - a[0] * grad[...].astype(jnp.float32)
    acc = _sparse_stencil(w, row0, vals, idx, sc, slf, n_stencil, out.shape)
    x = acc + v
    out[...] = x.astype(out.dtype)
    nmom[...] = v.astype(nmom.dtype)
    look[...] = (x + mu * v).astype(look.dtype)


def _cdadam_kernel_s(w, scal, row0, slf, vals, idx, sc, grad, m, v, out, nm,
                     nv, *, n_stencil):
    alpha, b1, b2, eps, bc1, bc2 = (scal[i] for i in range(6))
    g = grad[...].astype(jnp.float32)
    new_m = b1 * m[...].astype(jnp.float32) + (1.0 - b1) * g
    new_v = b2 * v[...].astype(jnp.float32) + (1.0 - b2) * g * g
    acc = _sparse_stencil(w, row0, vals, idx, sc, slf, n_stencil, out.shape)
    step_dir = (new_m / bc1) / (jnp.sqrt(new_v / bc2) + eps)
    out[...] = (acc - alpha * step_dir).astype(out.dtype)
    nm[...] = new_m.astype(nm.dtype)
    nv[...] = new_v.astype(nv.dtype)


def _grid_and_specs(rows: int, block_rows: int, n_stencil: int):
    grid = (pl.cdiv(rows, block_rows),)
    nbr_spec = pl.BlockSpec((n_stencil, block_rows, LANE), lambda i: (0, i, 0))
    scale_spec = pl.BlockSpec((n_stencil, block_rows, 1), lambda i: (0, i, 0))
    mat_spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    return grid, nbr_spec, scale_spec, mat_spec


def _aliases(enabled: bool, pairs):
    """input_output_aliases dict; ``pairs`` is ((input_idx, output_idx), ...)."""
    return dict(pairs) if enabled else {}


def _mix_operands(quantized, s, nbr_spec, scale_spec, mat_spec,
                  neighbors, scales, self_buf,
                  mom_neighbors=None, mom_scales=None):
    """Mixing operand group: ``[self,] neighbors [, scales] [, momentum]``.

    Quantized form: ``neighbors (S, rows, 128)`` int8/fp8 are the wire
    payloads only; the native-precision ``self_buf`` rides separately at
    ``weights[0]`` (it never crossed the wire, so it is never quantized).
    Unquantized form: ``neighbors`` includes the self tile, no extras.
    Mixed-momentum form (``mom_neighbors`` given — always the wire-operand
    form, since the staged engine carries unit scales even for f32 wires):
    the momentum payload's ``(S, rows, 128)`` stack + scales follow the
    params'; the momentum SELF tile is the kernels' existing ``momentum``
    operand, so it adds no operand here.  Returns ``(in_specs, args,
    n_weights)``.
    """
    if mom_neighbors is not None:
        assert quantized and self_buf is not None and scales.shape[0] == s
        assert mom_neighbors.shape == neighbors.shape
        return ([mat_spec, nbr_spec, scale_spec, nbr_spec, scale_spec],
                [self_buf, neighbors, scales, mom_neighbors, mom_scales],
                s + 1)
    if not quantized:
        return [nbr_spec], [neighbors], s
    assert self_buf is not None and scales.shape[0] == s
    return ([mat_spec, nbr_spec, scale_spec],
            [self_buf, neighbors, scales], s + 1)


def _sparse_operands(values, indices, scales, self_buf, grad,
                     block_rows: int):
    """Shared setup of the ``*_update_sparse_2d`` entry points.

    Validates the compact-field shapes, builds the grid over the DENSE row
    blocks (the outputs/self/grad are dense — only the neighbor operands
    shrink), and returns ``(grid, mat_spec, sparse_specs, sparse_args,
    s)``: the compact stacks get whole-array BlockSpecs (constant
    index_map — they stay resident across grid steps) and the per-block
    ``row0`` operand tells each step which dense element range it owns.
    """
    s, k_rows, lane = values.shape
    assert lane == LANE, values.shape
    assert indices.shape == (s, k_rows, LANE), (indices.shape, values.shape)
    assert scales.shape == (s, k_rows, 1), (scales.shape, values.shape)
    assert self_buf is not None, "sparse operand form needs the self buffer"
    rows, lane2 = self_buf.shape
    assert lane2 == LANE and grad.shape == (rows, LANE)
    assert k_rows <= rows, (k_rows, rows)
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)
    mat_spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    row0s = block_rows * jnp.arange(grid[0], dtype=jnp.int32)
    sparse_specs = [
        pl.BlockSpec((1,), lambda i: (i,)),                    # row0
        mat_spec,                                              # self tile
        pl.BlockSpec((s, k_rows, LANE), lambda i: (0, 0, 0)),  # values
        pl.BlockSpec((s, k_rows, LANE), lambda i: (0, 0, 0)),  # indices
        pl.BlockSpec((s, k_rows, 1), lambda i: (0, 0, 0)),     # scales
    ]
    sparse_args = [row0s, self_buf, values, indices.astype(jnp.int32), scales]
    return grid, mat_spec, sparse_specs, sparse_args, s


def cdsgd_update_sparse_2d(
    values: jnp.ndarray,          # (S, k_rows, 128) int8 compact values
    indices: jnp.ndarray,         # (S, k_rows, 128) int32 flat dense indices
    scales: jnp.ndarray,          # (S, k_rows, 1) f32 per-compact-row scales
    weights: jnp.ndarray,         # (S+1,) f32 self-separated weights
    grad: jnp.ndarray,            # (rows, 128) — donated to out
    alpha,
    *,
    self_buf: jnp.ndarray,        # (rows, 128) native self tile
    block_rows: int = DEFAULT_BLOCK_ROWS,
    alias: bool = True,
    interpret: bool = False,
) -> jnp.ndarray:
    """CDSGD update consuming the top-k wire directly (see module docs)."""
    grid, mat_spec, sp_specs, sp_args, s = _sparse_operands(
        values, indices, scales, self_buf, grad, block_rows)
    assert weights.shape == (s + 1,), (weights.shape, s)
    kernel = functools.partial(_cdsgd_kernel_s, n_stencil=s)
    in_specs = [
        pl.BlockSpec((s + 1,), lambda i: (0,)),    # weights
        pl.BlockSpec((1,), lambda i: (0,)),        # alpha
        *sp_specs,
        mat_spec,                                  # grad
    ]
    args = [weights.astype(jnp.float32), jnp.asarray([alpha], jnp.float32),
            *sp_args, grad]
    grad_idx = len(args) - 1
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=mat_spec,
        out_shape=jax.ShapeDtypeStruct(grad.shape, grad.dtype),
        input_output_aliases=_aliases(alias, ((grad_idx, 0),)),
        interpret=interpret,
    )(*args)


def cdmsgd_update_sparse_2d(
    values: jnp.ndarray,
    indices: jnp.ndarray,
    scales: jnp.ndarray,
    weights: jnp.ndarray,         # (S+1,)
    grad: jnp.ndarray,            # donated to params out
    momentum: jnp.ndarray,        # donated to new momentum
    alpha,
    mu,
    *,
    self_buf: jnp.ndarray,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    alias: bool = True,
    interpret: bool = False,
):
    """CDMSGD update on the sparse operand form (local momentum only — the
    top-k programs exclude ``momentum_mixing`` at config time)."""
    grid, mat_spec, sp_specs, sp_args, s = _sparse_operands(
        values, indices, scales, self_buf, grad, block_rows)
    assert weights.shape == (s + 1,), (weights.shape, s)
    kernel = functools.partial(_cdmsgd_kernel_s, n_stencil=s)
    in_specs = [
        pl.BlockSpec((s + 1,), lambda i: (0,)),    # weights
        pl.BlockSpec((1,), lambda i: (0,)),        # alpha
        pl.BlockSpec((1,), lambda i: (0,)),        # mu
        *sp_specs,
        mat_spec, mat_spec,                        # grad, momentum
    ]
    args = [weights.astype(jnp.float32), jnp.asarray([alpha], jnp.float32),
            jnp.asarray([mu], jnp.float32), *sp_args, grad, momentum]
    g_idx = len(args) - 2
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=(mat_spec, mat_spec),
        out_shape=(
            jax.ShapeDtypeStruct(grad.shape, grad.dtype),
            jax.ShapeDtypeStruct(momentum.shape, momentum.dtype),
        ),
        input_output_aliases=_aliases(alias, ((g_idx, 0), (g_idx + 1, 1))),
        interpret=interpret,
    )(*args)


def cdmsgd_nesterov_update_sparse_2d(
    values: jnp.ndarray,
    indices: jnp.ndarray,
    scales: jnp.ndarray,
    weights: jnp.ndarray,
    grad: jnp.ndarray,
    momentum: jnp.ndarray,
    alpha,
    mu,
    *,
    self_buf: jnp.ndarray,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    alias: bool = True,
    interpret: bool = False,
):
    """Returns ``(x', v', x' + mu v')`` like the dense Nesterov form, with
    the neighbor mix on the sparse operands."""
    grid, mat_spec, sp_specs, sp_args, s = _sparse_operands(
        values, indices, scales, self_buf, grad, block_rows)
    assert weights.shape == (s + 1,), (weights.shape, s)
    kernel = functools.partial(_cdmsgd_nesterov_kernel_s, n_stencil=s)
    in_specs = [
        pl.BlockSpec((s + 1,), lambda i: (0,)),    # weights
        pl.BlockSpec((1,), lambda i: (0,)),        # alpha
        pl.BlockSpec((1,), lambda i: (0,)),        # mu
        *sp_specs,
        mat_spec, mat_spec,                        # grad, momentum
    ]
    args = [weights.astype(jnp.float32), jnp.asarray([alpha], jnp.float32),
            jnp.asarray([mu], jnp.float32), *sp_args, grad, momentum]
    g_idx = len(args) - 2
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=(mat_spec, mat_spec, mat_spec),
        out_shape=(
            jax.ShapeDtypeStruct(grad.shape, grad.dtype),
            jax.ShapeDtypeStruct(momentum.shape, momentum.dtype),
            jax.ShapeDtypeStruct(grad.shape, grad.dtype),
        ),
        input_output_aliases=_aliases(alias, ((g_idx, 0), (g_idx + 1, 1))),
        interpret=interpret,
    )(*args)


def cdadam_update_sparse_2d(
    values: jnp.ndarray,
    indices: jnp.ndarray,
    scales: jnp.ndarray,
    weights: jnp.ndarray,
    grad: jnp.ndarray,
    m: jnp.ndarray,
    v: jnp.ndarray,
    alpha,
    b1,
    b2,
    eps,
    bc1,
    bc2,
    *,
    self_buf: jnp.ndarray,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    alias: bool = True,
    interpret: bool = False,
):
    """Returns ``(x', m', v')`` — local Adam moments, sparse neighbor mix."""
    grid, mat_spec, sp_specs, sp_args, s = _sparse_operands(
        values, indices, scales, self_buf, grad, block_rows)
    assert weights.shape == (s + 1,), (weights.shape, s)
    kernel = functools.partial(_cdadam_kernel_s, n_stencil=s)
    scal = jnp.stack([jnp.asarray(x, jnp.float32) for x in
                      (alpha, b1, b2, eps, bc1, bc2)])
    in_specs = [
        pl.BlockSpec((s + 1,), lambda i: (0,)),    # weights
        pl.BlockSpec((6,), lambda i: (0,)),        # packed scalars
        *sp_specs,
        mat_spec, mat_spec, mat_spec,              # grad, m, v
    ]
    args = [weights.astype(jnp.float32), scal, *sp_args, grad, m, v]
    g_idx = len(args) - 3
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=(mat_spec, mat_spec, mat_spec),
        out_shape=(
            jax.ShapeDtypeStruct(grad.shape, grad.dtype),
            jax.ShapeDtypeStruct(m.shape, m.dtype),
            jax.ShapeDtypeStruct(v.shape, v.dtype),
        ),
        input_output_aliases=_aliases(
            alias, ((g_idx, 0), (g_idx + 1, 1), (g_idx + 2, 2))),
        interpret=interpret,
    )(*args)


def cdsgd_update_2d(
    neighbors: jnp.ndarray,       # (S, rows, 128) — neighbor tiles (see below)
    weights: jnp.ndarray,         # (S,) f32 — Pi row restricted to the stencil
    grad: jnp.ndarray,            # (rows, 128) — bucket dtype; donated to out
    alpha,                        # scalar
    *,
    scales: jnp.ndarray = None,   # (S, rows, 1) f32 when neighbors quantized
    self_buf: jnp.ndarray = None, # (rows, 128) native self tile (quantized form)
    block_rows: int = DEFAULT_BLOCK_ROWS,
    alias: bool = True,
    interpret: bool = False,
) -> jnp.ndarray:
    s, rows, lane = neighbors.shape
    assert lane == LANE and grad.shape == (rows, lane)
    block_rows = min(block_rows, rows)
    grid, nbr_spec, scale_spec, mat_spec = _grid_and_specs(rows, block_rows, s)
    quantized = scales is not None
    kernel = functools.partial(
        _cdsgd_kernel_q if quantized else _cdsgd_kernel, n_stencil=s)
    mix_specs, mix_args, n_w = _mix_operands(
        quantized, s, nbr_spec, scale_spec, mat_spec, neighbors, scales, self_buf)
    assert weights.shape == (n_w,)
    in_specs = [
        pl.BlockSpec((n_w,), lambda i: (0,)),      # weights (whole, tiny)
        pl.BlockSpec((1,), lambda i: (0,)),        # alpha
        *mix_specs,
        mat_spec,                                  # grad
    ]
    args = [weights.astype(jnp.float32), jnp.asarray([alpha], jnp.float32),
            *mix_args, grad]
    grad_idx = len(args) - 1
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=mat_spec,
        out_shape=jax.ShapeDtypeStruct((rows, lane), grad.dtype),
        input_output_aliases=_aliases(alias, ((grad_idx, 0),)),
        interpret=interpret,
    )(*args)


def cdmsgd_update_2d(
    neighbors: jnp.ndarray,       # (S, rows, 128)
    weights: jnp.ndarray,         # (S,)
    grad: jnp.ndarray,            # (rows, 128) — donated to params out
    momentum: jnp.ndarray,        # (rows, 128) — donated to new momentum
    alpha,
    mu,
    *,
    scales: jnp.ndarray = None,
    self_buf: jnp.ndarray = None,
    mom_neighbors: jnp.ndarray = None,   # (S, rows, 128) momentum wire payloads
    mom_scales: jnp.ndarray = None,      # (S, rows, 1) momentum row scales
    block_rows: int = DEFAULT_BLOCK_ROWS,
    alias: bool = True,
    interpret: bool = False,
):
    """``mom_neighbors`` (+ ``mom_scales``) selects the mixed-momentum form
    ``v' = mu (Pi v) - a g``: the momentum buffer crossed the wire like the
    params and ``momentum`` becomes its fresh full-precision self tile."""
    s, rows, lane = neighbors.shape
    block_rows = min(block_rows, rows)
    grid, nbr_spec, scale_spec, mat_spec = _grid_and_specs(rows, block_rows, s)
    quantized = scales is not None
    mixed = mom_neighbors is not None
    kernel = functools.partial(
        _cdmsgd_kernel_qm if mixed else
        _cdmsgd_kernel_q if quantized else _cdmsgd_kernel, n_stencil=s)
    mix_specs, mix_args, n_w = _mix_operands(
        quantized, s, nbr_spec, scale_spec, mat_spec, neighbors, scales,
        self_buf, mom_neighbors, mom_scales)
    in_specs = [
        pl.BlockSpec((n_w,), lambda i: (0,)),      # weights
        pl.BlockSpec((1,), lambda i: (0,)),        # alpha
        pl.BlockSpec((1,), lambda i: (0,)),        # mu
        *mix_specs,
        mat_spec, mat_spec,                        # grad, momentum
    ]
    args = [weights.astype(jnp.float32), jnp.asarray([alpha], jnp.float32),
            jnp.asarray([mu], jnp.float32), *mix_args, grad, momentum]
    g_idx = len(args) - 2
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=(mat_spec, mat_spec),
        out_shape=(
            jax.ShapeDtypeStruct((rows, lane), grad.dtype),
            jax.ShapeDtypeStruct((rows, lane), momentum.dtype),
        ),
        input_output_aliases=_aliases(alias, ((g_idx, 0), (g_idx + 1, 1))),
        interpret=interpret,
    )(*args)


def cdmsgd_nesterov_update_2d(
    neighbors: jnp.ndarray,       # (S, rows, 128)
    weights: jnp.ndarray,         # (S,)
    grad: jnp.ndarray,            # (rows, 128) — evaluated at the lookahead
    momentum: jnp.ndarray,        # (rows, 128)
    alpha,
    mu,
    *,
    scales: jnp.ndarray = None,
    self_buf: jnp.ndarray = None,
    mom_neighbors: jnp.ndarray = None,
    mom_scales: jnp.ndarray = None,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    alias: bool = True,
    interpret: bool = False,
):
    """Returns ``(x', v', x' + mu v')`` — params, momentum, next lookahead.

    ``grad`` donates to ``x'`` and ``momentum`` to ``v'``; the lookahead is
    the one genuinely new buffer of the step.  ``mom_neighbors`` selects
    the mixed-momentum form (see :func:`cdmsgd_update_2d`).
    """
    s, rows, lane = neighbors.shape
    block_rows = min(block_rows, rows)
    grid, nbr_spec, scale_spec, mat_spec = _grid_and_specs(rows, block_rows, s)
    quantized = scales is not None
    mixed = mom_neighbors is not None
    kernel = functools.partial(
        _cdmsgd_nesterov_kernel_qm if mixed else
        _cdmsgd_nesterov_kernel_q if quantized else _cdmsgd_nesterov_kernel,
        n_stencil=s)
    mix_specs, mix_args, n_w = _mix_operands(
        quantized, s, nbr_spec, scale_spec, mat_spec, neighbors, scales,
        self_buf, mom_neighbors, mom_scales)
    in_specs = [
        pl.BlockSpec((n_w,), lambda i: (0,)),      # weights
        pl.BlockSpec((1,), lambda i: (0,)),        # alpha
        pl.BlockSpec((1,), lambda i: (0,)),        # mu
        *mix_specs,
        mat_spec, mat_spec,                        # grad, momentum
    ]
    args = [weights.astype(jnp.float32), jnp.asarray([alpha], jnp.float32),
            jnp.asarray([mu], jnp.float32), *mix_args, grad, momentum]
    g_idx = len(args) - 2
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=(mat_spec, mat_spec, mat_spec),
        out_shape=(
            jax.ShapeDtypeStruct((rows, lane), grad.dtype),
            jax.ShapeDtypeStruct((rows, lane), momentum.dtype),
            jax.ShapeDtypeStruct((rows, lane), grad.dtype),
        ),
        input_output_aliases=_aliases(alias, ((g_idx, 0), (g_idx + 1, 1))),
        interpret=interpret,
    )(*args)


def cdadam_update_2d(
    neighbors: jnp.ndarray,       # (S, rows, 128)
    weights: jnp.ndarray,         # (S,)
    grad: jnp.ndarray,            # (rows, 128) — donated to params out
    m: jnp.ndarray,               # (rows, 128) first moment; donated to m'
    v: jnp.ndarray,               # (rows, 128) second moment; donated to v'
    alpha,
    b1,
    b2,
    eps,
    bc1,                          # 1 - b1**t (traced; computed by the caller)
    bc2,                          # 1 - b2**t
    *,
    scales: jnp.ndarray = None,
    self_buf: jnp.ndarray = None,
    mom_neighbors: jnp.ndarray = None,   # first-moment wire payloads (mixed)
    mom_scales: jnp.ndarray = None,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    alias: bool = True,
    interpret: bool = False,
):
    """Returns ``(x', m', v')`` — mixed params with a local-Adam step.
    ``mom_neighbors`` mixes the first moment over the wire too
    (``m' = b1 (Pi m) + (1-b1) g``); ``m`` is then its fresh self tile."""
    s, rows, lane = neighbors.shape
    block_rows = min(block_rows, rows)
    grid, nbr_spec, scale_spec, mat_spec = _grid_and_specs(rows, block_rows, s)
    quantized = scales is not None
    mixed = mom_neighbors is not None
    kernel = functools.partial(
        _cdadam_kernel_qm if mixed else
        _cdadam_kernel_q if quantized else _cdadam_kernel, n_stencil=s)
    scal = jnp.stack([jnp.asarray(x, jnp.float32) for x in
                      (alpha, b1, b2, eps, bc1, bc2)])
    mix_specs, mix_args, n_w = _mix_operands(
        quantized, s, nbr_spec, scale_spec, mat_spec, neighbors, scales,
        self_buf, mom_neighbors, mom_scales)
    in_specs = [
        pl.BlockSpec((n_w,), lambda i: (0,)),      # weights
        pl.BlockSpec((6,), lambda i: (0,)),        # packed scalars
        *mix_specs,
        mat_spec, mat_spec, mat_spec,              # grad, m, v
    ]
    args = [weights.astype(jnp.float32), scal, *mix_args, grad, m, v]
    g_idx = len(args) - 3
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=(mat_spec, mat_spec, mat_spec),
        out_shape=(
            jax.ShapeDtypeStruct((rows, lane), grad.dtype),
            jax.ShapeDtypeStruct((rows, lane), m.dtype),
            jax.ShapeDtypeStruct((rows, lane), v.dtype),
        ),
        input_output_aliases=_aliases(
            alias, ((g_idx, 0), (g_idx + 1, 1), (g_idx + 2, 2))),
        interpret=interpret,
    )(*args)
