"""Top-k sparse + rank-r low-rank wire compressors for the flat buckets.

These are the two *biased* compressors of the ``MixingProgram`` compressor
axis (``compressor="topk:p" | "rank:r"``, see
:mod:`repro.core.consensus`).  Both operate on one packed flat bucket at a
time — the ``(rows, 128)`` layout of :mod:`repro.core.flatbuf` — and both
ride the error-feedback rail (``OptState.residual``): a biased compressor
without EF diverges, which ``make_mixing_program`` rejects at config time.

Top-k (``topk:p``)
------------------
Keep the ``K = k_rows * 128`` largest-magnitude elements of the bucket
(``k_rows = ceil(ceil(p * rows * 128) / 128)`` — the ragged ``ceil(p*n)``
count rounded up to a lane-aligned static shape), ship them as a compact
``(k_rows, 128)`` tile:

* ``values``  — int8, SR-quantized via the existing
  :func:`~repro.kernels.consensus_update.consensus_update.sr_quantize_2d`
  Pallas kernel on the *compact* layout (one f32 scale per compact row);
* ``indices`` — int32 flat positions ``row * 128 + lane`` into the dense
  bucket;
* ``scales``  — the ``(k_rows, 1)`` f32 row scales of the values.

Selection itself is ``jax.lax.top_k`` over the flattened magnitudes:
element-granular gather/scatter has no efficient direct TPU-Pallas
lowering (the TPU vector unit has no scatter; XLA owns those HLOs), so
the exact select/gather/scatter legs go through XLA while the Pallas
surface here is :func:`topk_threshold_2d` — a single-HBM-sweep
magnitude-threshold kernel that brackets the k-th magnitude with a
geometric threshold histogram (the standard TPU fast path: threshold
stats in one sweep, then a compaction against ``tau``).  The threshold
kernel is benchmarked and tested against the exact selection
(``consensus/compressor_frontier``).

The decompressed ("gather-dequant") form is a dense f32 bucket with the
un-selected elements at zero — it feeds the fused update kernels'
existing self-separated weight path unchanged (dense neighbor stacks with
unit scales; the self term never crossed the wire).

Rank-r (``rank:r``)
-------------------
One PowerSGD-style power iteration per step (Vogels et al., 1905.13727):

    P = orth(M @ Q)          # (rows, r)
    Qt = P^T @ M             # (r, 128)   — ship (P, Qt)
    M_hat = P @ Qt           # reconstruction
    Q' = orth(Qt^T)          # (128, r)   — warm start, carried in OptState

The two factors ride the ``ppermute`` as two *dense* payloads —
``4 * (rows*r + r*128)`` bytes per neighbor versus ``4 * rows * 128``
for f32.  The warm-started ``Q`` lives in ``OptState.qwarm`` next to the
wire, checkpointing and resuming like any other optimizer state.

All functions are deterministic: ``lax.top_k`` breaks ties by index, the
Gram-Schmidt orthonormalization is a fixed static-``r`` loop with a
zero-column guard (no ``jnp.linalg.qr`` in the step), and the SR bits of
the compact values draw from the same ``wire_seed`` composition as the
dense int8 wire.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.consensus_update.consensus_update import (
    DEFAULT_BLOCK_ROWS,
    LANE,
    sr_quantize_2d,
)


# --------------------------------------------------------------------------
# static shape math (the single source the byte accounting prices from)
# --------------------------------------------------------------------------


def topk_k_rows(rows: int, p: float) -> int:
    """Lane-aligned compact row count for density ``p`` over ``rows*128``.

    ``K = ceil(p * rows * 128)`` elements, rounded up to whole 128-lane
    rows so the compact payload keeps the flat-bucket tile shape (and the
    per-row scale layout of :func:`sr_quantize_2d`); clamped to ``rows``.
    """
    if not (0.0 < p <= 1.0):
        raise ValueError(f"top-k density must be in (0, 1], got {p!r}")
    k = max(1, math.ceil(p * rows * LANE))
    return min(rows, max(1, -(-k // LANE)))


#: Wire bytes of ONE compact lane row: 128 int8 values + 128 int32 flat
#: indices + one f32 row scale.  The single price every byte account and
#: the ``topk:auto`` budget solver use.
TOPK_LANE_ROW_BYTES = LANE * (1 + 4) + 4


def topk_auto_k_rows(rows_list, budget: int):
    """Per-bucket compact row counts meeting a total byte budget per neighbor.

    ``topk:auto:B`` adaptive density: given the dense row counts of every
    bucket, choose ``k_rows[i]`` so that ``sum(k_rows) *
    TOPK_LANE_ROW_BYTES <= budget`` with at least one lane row per bucket
    (a bucket that ships nothing would stall its EF residual forever).
    Rows are spread proportionally to each bucket's size, then a greedy
    top-up hands the integer remainder to the largest uncovered buckets
    (deterministic: ties break toward the lower bucket index) — so unless
    every bucket saturates at full density, the shortfall under ``budget``
    is less than one lane row total.
    """
    rows_list = list(rows_list)
    n = len(rows_list)
    floor_bytes = n * TOPK_LANE_ROW_BYTES
    if budget < floor_bytes:
        raise ValueError(
            f"topk:auto budget {budget} B cannot cover one compact lane row "
            f"per bucket ({n} buckets x {TOPK_LANE_ROW_BYTES} B = "
            f"{floor_bytes} B minimum)")
    afford = budget // TOPK_LANE_ROW_BYTES
    k = [1] * n
    rem = afford - n
    frac = [r - 1 for r in rows_list]
    total_frac = sum(frac)
    if total_frac > 0:
        for i in range(n):
            k[i] += min(frac[i], rem * frac[i] // total_frac)
    while sum(k) < afford:
        cands = [(rows_list[i] - k[i], -i) for i in range(n)
                 if k[i] < rows_list[i]]
        if not cands:
            break                       # every bucket already full density
        uncovered, neg_i = max(cands)
        k[-neg_i] += 1
    return k


def topk_k_rows_for(rows_list, param):
    """Per-bucket ``k_rows`` for a parsed ``topk`` compressor parameter.

    ``param`` is either a float density ``p`` (``topk:p`` — applied to each
    bucket independently) or the tuple ``("auto", budget_bytes)`` from
    ``topk:auto:B`` (the byte-budget solver above).
    """
    if isinstance(param, tuple):
        kind, budget = param
        assert kind == "auto", param
        return topk_auto_k_rows(rows_list, budget)
    return [topk_k_rows(r, param) for r in rows_list]


# --------------------------------------------------------------------------
# Pallas magnitude-threshold kernel (one HBM sweep)
# --------------------------------------------------------------------------


def _threshold_count_kernel(taus_ref, x_ref, counts_ref, *, n_bins: int,
                            rows: int, block_rows: int):
    """Accumulate ``count(|x| >= tau_b)`` per geometric threshold bin.

    Sequential-grid accumulation: block 0 zeroes the (1, n_bins) counts,
    every block adds its tile's per-bin counts.  Rows past ``rows`` (the
    zero-padded tail of the last block) are masked to a negative sentinel
    so they never count against the strictly positive thresholds.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    ax = jnp.abs(x_ref[...].astype(jnp.float32))
    row0 = i * block_rows
    ridx = row0 + jax.lax.broadcasted_iota(jnp.int32, ax.shape, 0)
    ax = jnp.where(ridx < rows, ax, -1.0)
    taus = taus_ref[...]                                  # (1, n_bins)
    bidx = jax.lax.broadcasted_iota(jnp.int32, (1, n_bins), 1)
    acc = jnp.zeros((1, n_bins), jnp.float32)
    for b in range(n_bins):
        cnt = jnp.sum((ax >= taus[0, b]).astype(jnp.float32))
        acc = acc + jnp.where(bidx == b, cnt, 0.0)
    counts_ref[...] += acc


def topk_threshold_2d(x: jnp.ndarray, k: int, *, n_bins: int = 16,
                      span: float = 1e-4,
                      block_rows: int = DEFAULT_BLOCK_ROWS,
                      interpret: bool = False):
    """Bracket the k-th largest magnitude of a flat bucket in ONE sweep.

    Sweeps the ``(rows, 128)`` bucket once, counting ``|x| >= tau_b`` for
    ``n_bins`` geometric thresholds ``tau_b = amax * span^(b/(n_bins-1))``
    (``tau_0 = amax`` down to ``amax * span``), and returns ``(tau,
    counts)`` where ``tau`` is the smallest threshold whose count is
    ``<= k`` — so the true k-th magnitude lies within one geometric bin
    below ``tau`` (tested).  ``counts`` is the ``(n_bins,)`` f32 histogram.

    This is the TPU fast-path statistic for top-k selection (threshold
    then compact); the exact selection of :func:`topk_compress_2d` uses
    ``lax.top_k`` — see the module docstring for why the element-granular
    gather stays in XLA.
    """
    rows, lane = x.shape
    assert lane == LANE, x.shape
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    ratios = jnp.asarray(
        [span ** (b / max(n_bins - 1, 1)) for b in range(n_bins)],
        jnp.float32)
    # degenerate all-zero bucket: keep taus strictly positive so the
    # padded/zero elements never count (tau selects nothing, count 0 <= k)
    taus = (jnp.maximum(amax, 1e-30) * ratios).reshape(1, n_bins)
    block_rows = min(block_rows, rows)
    n_blocks = pl.cdiv(rows, block_rows)
    kernel = functools.partial(_threshold_count_kernel, n_bins=n_bins,
                               rows=rows, block_rows=block_rows)
    counts = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, n_bins), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, n_bins), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n_bins), jnp.float32),
        interpret=interpret,
    )(taus, x)[0]
    # counts are nondecreasing in b (taus decreasing); pick the smallest
    # tau still selecting <= k elements — prefix-sum of the <=k mask
    ok = (counts <= jnp.float32(k)).astype(jnp.int32)
    idx = jnp.maximum(jnp.sum(ok) - 1, 0)
    return taus[0, idx], counts


# --------------------------------------------------------------------------
# top-k compress / decompress (exact selection)
# --------------------------------------------------------------------------


def topk_compress_2d(x: jnp.ndarray, k_rows: int, seed, *,
                     block_rows: int = DEFAULT_BLOCK_ROWS,
                     interpret: bool = False):
    """Compress one dense bucket to its lane-aligned top-K compact form.

    Returns ``(values, indices, scales)``: int8 ``(k_rows, 128)`` compact
    values (SR-quantized with the shared :func:`sr_quantize_2d` kernel —
    same ``wire_seed`` stream discipline as the dense int8 wire), int32
    ``(k_rows, 128)`` flat dense positions (``row * 128 + lane``, sorted
    ascending — ``lax.top_k`` is deterministic, ties break by index), and
    the ``(k_rows, 1)`` f32 per-compact-row scales.
    """
    rows, lane = x.shape
    assert lane == LANE, x.shape
    assert 1 <= k_rows <= rows, (k_rows, rows)
    kk = k_rows * LANE
    flat = x.reshape(-1).astype(jnp.float32)
    _, idx = jax.lax.top_k(jnp.abs(flat), kk)
    idx = jnp.sort(idx).astype(jnp.int32)
    vals = flat[idx].reshape(k_rows, LANE)
    q, sc = sr_quantize_2d(vals, seed, exchange="int8",
                           block_rows=block_rows, interpret=interpret)
    return q, idx.reshape(k_rows, LANE), sc


def topk_decompress_2d(values: jnp.ndarray, indices: jnp.ndarray,
                       scales: jnp.ndarray, rows: int) -> jnp.ndarray:
    """Gather-dequant form: compact payload -> dense f32 ``(rows, 128)``.

    Un-selected elements are zero; the result feeds the fused kernels as
    a dense neighbor buffer with unit scales (the in-register dequant
    multiply is then the identity).  Indices are unique by construction,
    so a plain scatter-set suffices.
    """
    deq = values.astype(jnp.float32) * scales
    flat = jnp.zeros((rows * LANE,), jnp.float32)
    flat = flat.at[indices.reshape(-1)].set(deq.reshape(-1))
    return flat.reshape(rows, LANE)


# --------------------------------------------------------------------------
# rank-r power-iteration compressor (PowerSGD-style)
# --------------------------------------------------------------------------


def _orthonormalize_cols(a: jnp.ndarray, eps: float = 1e-8) -> jnp.ndarray:
    """Modified Gram-Schmidt over the (static, small) column count.

    A numerically degenerate column collapses to zero instead of NaN —
    it then contributes nothing to the reconstruction, and every agent
    collapses identically (deterministic), so the modes stay in lockstep.
    """
    cols = []
    for i in range(a.shape[1]):
        v = a[:, i].astype(jnp.float32)
        for u in cols:
            v = v - jnp.dot(u, v) * u
        nrm = jnp.sqrt(jnp.sum(v * v))
        cols.append(jnp.where(nrm > eps, v / jnp.maximum(nrm, eps),
                              jnp.zeros_like(v)))
    return jnp.stack(cols, axis=1)


def rank_init_q(r: int, seed: int = 0) -> jnp.ndarray:
    """Deterministic orthonormal ``(128, r)`` warm-start basis.

    Identical across agents, buckets, and execution modes — the power
    iteration re-aligns it to the data from step 0, and a shared init
    keeps stacked/sharded trajectories bit-identical.
    """
    if not isinstance(r, int) or r < 1 or r > LANE:
        raise ValueError(f"rank must be an int in [1, {LANE}], got {r!r}")
    g = jax.random.normal(jax.random.PRNGKey(seed), (LANE, r), jnp.float32)
    return _orthonormalize_cols(g)


def rank_compress_2d(m: jnp.ndarray, q: jnp.ndarray):
    """One warm-started power iteration: ``m (rows, 128)`` -> factors.

    Returns ``(p, qt, q_next)``: the orthonormal left factor ``(rows, r)``,
    the right factor ``(r, 128)`` (``p^T m`` — the two wire payloads), and
    the orthonormalized ``(128, r)`` warm start for the next step.
    Reconstruction is ``p @ qt`` (:func:`rank_decompress_2d`).
    """
    m = m.astype(jnp.float32)
    p = _orthonormalize_cols(m @ q)
    qt = p.T @ m
    q_next = _orthonormalize_cols(qt.T)
    return p, qt, q_next


def rank_decompress_2d(p: jnp.ndarray, qt: jnp.ndarray) -> jnp.ndarray:
    """Reconstruct the dense f32 bucket from the two wire factors."""
    return (p.astype(jnp.float32) @ qt.astype(jnp.float32))
