"""Fused consensus-update kernels (paper eq. 5/6) on flat parameter buffers.

Flat-buffer layout contract (:mod:`repro.core.flatbuf`)
-------------------------------------------------------

The whole parameter pytree is packed into **dtype buckets**: per bucket a
single ``(*lead, rows, 128)`` array in which leaves sit contiguously at
static element ``offset``\\ s with one zero-padded tail row block.  The
fused update is then **one** ``pallas_call`` per dtype bucket per step —
the kernel grid walks ``(block_rows, 128)`` tiles, loads
self/neighbor/gradient/state tiles into VMEM, accumulates in f32 and
writes the updated tiles — instead of one launch (plus per-leaf padding
waste) per pytree leaf.

Kernels: ``cdsgd_update_2d`` (Algorithm 1), ``cdmsgd_update_2d``
(Algorithm 2, Polyak), ``cdmsgd_nesterov_update_2d`` (Algorithm 3 — also
emits the next lookahead point ``x' + mu v'`` in the same sweep), and
``cdadam_update_2d`` (beyond-paper: consensus mixing with local Adam
moments).  All take ``neighbors (S, rows, 128)`` + ``weights (S,)`` where
``S`` = stencil size (degree + self), and run ``interpret=True`` on CPU.

Two perf levers ride on every kernel:

* **Quantized exchange** — ``sr_quantize_2d`` turns a bucket into int8 (or
  fp8-e4m3) payloads with one f32 scale per 128-lane row *before* the
  ``ppermute``; passing the matching ``scales`` operand makes the kernels
  dequantize in-register during mixing, so the wire moves ~4x fewer bytes
  and no dequantized neighbor copy ever lands in HBM.
* **In-place updates** — ``input_output_aliases`` donate the gradient
  buffer to the updated params and each optimizer-state buffer to its
  successor, eliminating the extra HBM output copy per model/slot.

``mixing="ppermute_fused"`` contract (sharded trainer)
------------------------------------------------------

Under :func:`repro.launch.steps.build_train_step` with
``mixing="ppermute_fused"``, the entire optimizer update executes inside a
single ``shard_map`` region over the agent mesh axes: pack → (optionally
quantize) → one ``lax.ppermute`` per circulant shift offset *per bucket*
(NOT per leaf) → fused update kernel → unpack.  Total per-step collective
count is ``len(shift_offsets) - 1`` per dtype bucket (self-shift moves no
data) — times two when the exchange is quantized (payload + row scales,
still ~3.9x fewer bytes); total kernel-launch count equals the number of
dtype buckets.  Requires a circulant topology
(``Topology.shift_weights() is not None``); non-circulant graphs must use
``mixing="ppermute"`` (per-leaf) or ``"dense"``.

The stacked simulation reaches the same kernels through
``CommOps.flat`` (see :func:`repro.core.consensus.stacked_flat_comm`): the
dense ``Pi`` becomes an ``(A, A)`` weight matrix and the kernel is vmapped
over agent rows — still a single batched ``pallas_call`` per bucket.
"""
