"""Pure-jnp oracle for the fused consensus update kernel."""

from __future__ import annotations

import jax.numpy as jnp


def cdsgd_update_ref(neighbors, weights, grad, alpha):
    """neighbors (S, rows, 128); weights (S,); grad (rows, 128)."""
    mixed = jnp.einsum("s,sre->re", weights.astype(jnp.float32),
                       neighbors.astype(jnp.float32))
    out = mixed - alpha * grad.astype(jnp.float32)
    return out.astype(neighbors.dtype)


def cdmsgd_update_ref(neighbors, weights, grad, momentum, alpha, mu):
    v = mu * momentum.astype(jnp.float32) - alpha * grad.astype(jnp.float32)
    mixed = jnp.einsum("s,sre->re", weights.astype(jnp.float32),
                       neighbors.astype(jnp.float32))
    return (mixed + v).astype(neighbors.dtype), v.astype(momentum.dtype)
