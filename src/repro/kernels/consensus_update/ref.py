"""Pure-jnp oracle for the fused consensus update kernel."""

from __future__ import annotations

import jax.numpy as jnp


def cdsgd_update_ref(neighbors, weights, grad, alpha):
    """neighbors (S, rows, 128); weights (S,); grad (rows, 128)."""
    mixed = jnp.einsum("s,sre->re", weights.astype(jnp.float32),
                       neighbors.astype(jnp.float32))
    out = mixed - alpha * grad.astype(jnp.float32)
    return out.astype(neighbors.dtype)


def cdmsgd_update_ref(neighbors, weights, grad, momentum, alpha, mu):
    v = mu * momentum.astype(jnp.float32) - alpha * grad.astype(jnp.float32)
    mixed = jnp.einsum("s,sre->re", weights.astype(jnp.float32),
                       neighbors.astype(jnp.float32))
    return (mixed + v).astype(neighbors.dtype), v.astype(momentum.dtype)


def cdmsgd_nesterov_update_ref(neighbors, weights, grad, momentum, alpha, mu):
    """CDMSGD + the next lookahead point ``x' + mu v'`` (Algorithm 3)."""
    v = mu * momentum.astype(jnp.float32) - alpha * grad.astype(jnp.float32)
    mixed = jnp.einsum("s,sre->re", weights.astype(jnp.float32),
                       neighbors.astype(jnp.float32))
    x = mixed + v
    return (x.astype(neighbors.dtype), v.astype(momentum.dtype),
            (x + mu * v).astype(neighbors.dtype))


def cdadam_update_ref(neighbors, weights, grad, m, v, alpha, b1, b2, eps,
                      bc1, bc2):
    """Consensus mixing + local Adam moments (beyond-paper extension)."""
    g = grad.astype(jnp.float32)
    new_m = b1 * m.astype(jnp.float32) + (1.0 - b1) * g
    new_v = b2 * v.astype(jnp.float32) + (1.0 - b2) * g * g
    mixed = jnp.einsum("s,sre->re", weights.astype(jnp.float32),
                       neighbors.astype(jnp.float32))
    out = mixed - alpha * (new_m / bc1) / (jnp.sqrt(new_v / bc2) + eps)
    return (out.astype(neighbors.dtype), new_m.astype(m.dtype),
            new_v.astype(v.dtype))
