"""Pallas TPU kernels for the compute hot-spots.

* ``consensus_update`` — fused Pi-mixing + (momentum) SGD update, the
  paper's per-step parameter sweep (eq. 5) in one HBM pass.
* ``flash_attention`` — blockwise online-softmax attention for prefill
  (causal / sliding-window / GQA).
* ``rwkv_scan`` — chunked WKV6 recurrence with VMEM-resident state.

Each subpackage ships ``<name>.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jit'd wrapper in model layout) and ``ref.py`` (pure-jnp
oracle); tests sweep shapes/dtypes in ``interpret=True`` on CPU.
"""
