"""Pure-jnp oracle for the flash attention kernel (naive, materializes S)."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jnp.ndarray,          # (B, H, Sq, D)
    k: jnp.ndarray,          # (B, KV, Sk, D)
    v: jnp.ndarray,          # (B, KV, Sk, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    b, h, sq, d = q.shape
    kv, sk = k.shape[1], k.shape[2]
    g = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, kv, g, sq, d).astype(jnp.float32) * scale
    s = jnp.einsum("bngqd,bnkd->bngqk", qg, k.astype(jnp.float32))
    rows = jnp.arange(sq)[:, None]
    cols = jnp.arange(sk)[None, :]
    allowed = jnp.ones((sq, sk), bool)
    if causal:
        allowed &= cols <= rows
    if window is not None:
        allowed &= cols > rows - window
    s = jnp.where(allowed, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngqk,bnkd->bngqd", p, v.astype(jnp.float32))
    return o.reshape(b, h, sq, d).astype(q.dtype)
