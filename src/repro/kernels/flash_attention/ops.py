"""jit'd wrapper exposing the flash kernel in model layout (b, s, h, d)."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                              "block_k", "interpret"))
def flash_attention_bshd(
    q: jnp.ndarray,          # (b, s, H, d) — model layout
    k: jnp.ndarray,          # (b, s, KV, d)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention(qt, kt, vt, causal=causal, window=window,
                          block_q=block_q, block_k=block_k, interpret=interpret)
    return jnp.swapaxes(out, 1, 2)
