"""Blockwise (flash) attention — Pallas TPU kernel for the prefill path.

Grid layout ``(batch*heads, q_blocks, kv_blocks)`` with the KV dimension
innermost: TPU grids execute sequentially minor-to-major, so the f32
running-max / running-sum / accumulator live in VMEM scratch and persist
across the KV sweep of one q block; the output tile is written once, on
the final KV step.  HBM traffic per q block is therefore
``O(S_kv * (bk x d))`` reads + one ``(bq x d)`` write — the flash
property — instead of materializing the ``(S_q x S_kv)`` score matrix.

Masking (causal and/or sliding window) is computed from global index
iotas against the block offsets; fully-masked positions are excluded from
the probability mass explicitly (`p *= allowed`) so a fully-masked KV
block cannot poison the running max.

GQA: the KV block index map folds the query-head index onto its KV group,
so no KV repetition is materialized.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: Optional[int],
                  bq: int, bk: int, n_kv: int):
    ki = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, NEG_INF, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    q = q_ref[0].astype(jnp.float32) * scale            # (bq, d)
    k = k_ref[0].astype(jnp.float32)                    # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (bq, bk)

    rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    allowed = jnp.ones((bq, bk), dtype=bool)
    if causal:
        allowed &= cols <= rows
    if window is not None:
        allowed &= cols > rows - window
    s = jnp.where(allowed, s, NEG_INF)

    m_prev = m_scr[...]                                 # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new) * allowed.astype(jnp.float32)
    corr = jnp.exp(m_prev - m_new)                      # (bq, 1)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    m_scr[...] = m_new
    v = v_ref[0].astype(jnp.float32)                    # (bk, d)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(p, v)

    @pl.when(ki == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,          # (B, H, Sq, D)
    k: jnp.ndarray,          # (B, KV, Sk, D)
    v: jnp.ndarray,          # (B, KV, Sk, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, sq, d = q.shape
    kv, sk = k.shape[1], k.shape[2]
    g = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    if sq % bq or sk % bk:
        raise ValueError(f"seq lens ({sq},{sk}) must divide blocks ({bq},{bk})")
    n_q, n_kv = sq // bq, sk // bk

    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * kv, sk, d)
    vf = v.reshape(b * kv, sk, d)

    def kv_index(bh, qi, ki):
        return ((bh // h) * kv + (bh % h) // g, ki, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, n_kv=n_kv)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running sum
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d)
