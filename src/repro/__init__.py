"""repro: consensus-based distributed deep learning (CDSGD, NIPS 2017) in JAX.

A production-grade reproduction of "Collaborative Deep Learning in Fixed
Topology Networks" (Jiang, Balu, Hegde, Sarkar) with a multi-architecture
model zoo, multi-pod sharded training, and Pallas TPU kernels.
"""

__version__ = "1.0.0"
