"""StepProgram contract checker: static verification of the wire contract.

CDSGD's convergence guarantees hold only if the compiled step actually
implements the configured wire contract — and after the schedule ×
strategy × compressor × staleness × momentum-mixing × faults product
space, that contract is too wide to audit by hand.  This module certifies
any assembled :class:`repro.core.engine.StepProgram` (stacked or sharded)
*before it runs*, by tracing it once and running named passes over the
jaxpr (plus optional HLO evidence), returning a machine-readable
:class:`CheckReport` with pass/fail/evidence per rule.

Pass catalog (rule ids)::

    census.ppermute_count      actual collective-permute eqn count ==
                               closed-form prediction from MixingProgram
    census.critical_path       fresh vs carried-only classification per
                               hit matches the schedule (overlap round 1
                               carries only wire/state labels, 1705.09056)
    census.clean_collectives   no psum/all-gather/… ever touches wire data
    alias.fused_coverage       every fused pallas_call carries the
                               optimizer-declared input_output_aliases
    alias.donation_declared    donate_argnums covers params + opt_state
                               whenever an in-place contract is declared
    alias.double_donation      no buffer is reachable through two donated
                               arguments (the PR 9 Nesterov init bug class)
    alias.dropped_donations    no silently-dropped donations at compile
                               (fed from the HLO buffer-donation report)
    bytes.wire_vs_program      program_bytes_per_neighbor == bytes of the
                               actual carried wire buffers
    bytes.hlo_collective_permute  HLO collective-permute operand bytes ==
                               the accounting prediction (trip-aware)
    seeds.strides_distinct     the five wire_seed strides are distinct
    seeds.window_collision_free  SR seed streams of the configured program
                               are disjoint over a dense + strided window
    seeds.ring_window          …including the depth-S staleness ring window
    sparse.shape_contract      TopKWire/RankWire field shapes + dtypes
    sparse.k_rows_clamp        1 <= k_rows <= rows (and the auto budget)
    sparse.index_bounds        opt-in checkify proof the top-k indices are
                               in range (concrete wire only)

Closed-form collective census (validated on the debug mesh, PR 10)::

    n_ppermute_eqns = sum_entries(non-identity circulant shifts)
                      x fields x n_buckets x n_payloads x callsites
    fields    = 3 (topk: values+indices+scales) | 2 (rank: p+qt)
              | 2 (int8/fp8: payload+scales)    | 1 (f32/bf16)
    callsites = 1 (rounds=1) | 2 (rounds=2) | 3 (rounds>=3; the inner
                rounds live in one lax.scan body, counted once per eqn)
    carried   = total/callsites under schedule="overlap" (round 1 consumes
                the carried wire), 0 under "sync"; stacked mode = 0 total.
    Staleness S never changes the count (one ring slot crosses per shift).

A deliberately-broken program (fresh collective on the claimed-carried
round, a dropped alias, colliding seed strides …) fails the matching
named rule with actionable evidence; tests/test_staticcheck.py asserts
this on hand-assembled breakages.

Adding a pass: write ``pass_<name>(ctx) -> list[RuleResult]`` over the
shared :class:`CheckContext` (one trace, shared by every pass), register
it in ``PASSES``, and document the rule ids above + in ARCHITECTURE.md.
"""

from __future__ import annotations

import dataclasses
import traceback
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus, engine, flatbuf

PyTree = Any

SCHEMA_VERSION = 1


# --------------------------------------------------------------------------
# report types
# --------------------------------------------------------------------------


@dataclasses.dataclass
class RuleResult:
    """One named rule's verdict: pass/fail/skip plus evidence."""

    rule: str
    ok: bool
    detail: str = ""
    evidence: Dict[str, Any] = dataclasses.field(default_factory=dict)
    skipped: bool = False          # not applicable / not provable here

    def as_dict(self) -> dict:
        return {"rule": self.rule, "ok": bool(self.ok),
                "skipped": bool(self.skipped), "detail": self.detail,
                "evidence": _jsonable(self.evidence)}


@dataclasses.dataclass
class CheckReport:
    """Machine-readable verdict of every pass over one program config."""

    label: str
    mode: str                      # "stacked" | "sharded"
    schedule: str
    results: List[RuleResult] = dataclasses.field(default_factory=list)
    walltime_s: float = 0.0

    @property
    def ok(self) -> bool:
        return all(r.ok for r in self.results)

    def failures(self) -> List[RuleResult]:
        return [r for r in self.results if not r.ok]

    def rule(self, rule_id: str) -> RuleResult:
        for r in self.results:
            if r.rule == rule_id:
                return r
        raise KeyError(rule_id)

    def as_dict(self) -> dict:
        return {"version": SCHEMA_VERSION, "label": self.label,
                "mode": self.mode, "schedule": self.schedule,
                "ok": self.ok, "walltime_s": round(self.walltime_s, 3),
                "rules": [r.as_dict() for r in self.results]}

    def summary(self) -> str:
        lines = [f"[{'OK' if self.ok else 'FAIL'}] {self.label} "
                 f"({self.mode}/{self.schedule})"]
        for r in self.results:
            mark = "skip" if r.skipped else ("ok" if r.ok else "FAIL")
            line = f"  {mark:>4}  {r.rule}"
            if r.detail and (not r.ok or r.skipped):
                line += f" — {r.detail}"
            lines.append(line)
        return "\n".join(lines)


def _jsonable(x):
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple, set, frozenset)):
        return [_jsonable(v) for v in x]
    if isinstance(x, (np.integer,)):
        return int(x)
    if isinstance(x, (np.floating,)):
        return float(x)
    if isinstance(x, (str, int, float, bool)) or x is None:
        return x
    return str(x)


# --------------------------------------------------------------------------
# context: one trace shared by every pass
# --------------------------------------------------------------------------


@dataclasses.dataclass
class CheckContext:
    """Everything the passes consume, assembled once per configuration."""

    label: str
    mode: str                           # "stacked" | "sharded"
    schedule: str                       # "sync" | "overlap"
    program: Optional[consensus.MixingProgram]
    optimizer: Any
    spec: flatbuf.FlatSpec              # global (agent-stacked) flat layout
    n_agents: int
    step_fn: Any
    params: PyTree                      # concrete arrays or SDS structs
    opt_state: Any
    batch: Any
    donate_argnums: Tuple[int, ...] = ()
    hlo_stats: Any = None               # repro.analysis.hlo.HloStats | None
    row_shard: int = 1                  # model-axis shards of each bucket row
    dropped_donations: Optional[List[str]] = None
    checkify_indices: bool = False
    # filled by assemble():
    closed_jaxpr: Any = None
    hits: Optional[List[dict]] = None   # collective taint hits
    wire_carried: Any = None            # the actual carried wire entries
    wire_global: Any = None             # global-layout template (eval_shape)

    def assemble(self) -> "CheckContext":
        self.closed_jaxpr = jax.make_jaxpr(self.step_fn)(
            self.params, self.opt_state, self.batch)
        self.hits = engine.collective_taint_hits(
            self.step_fn, self.params, self.opt_state, self.batch,
            prims=engine.COLLECTIVE_PRIMS, closed=self.closed_jaxpr)
        wire = getattr(self.opt_state, "wire", ())
        if isinstance(wire, consensus.WireRing) or (
                isinstance(wire, (tuple, list)) and len(wire)):
            self.wire_carried = wire
        self.wire_global = self._synthesize_global_wire()
        return self

    @property
    def wire_template(self):
        """Best wire-contract template available: the carried entries when
        they follow the global layout, else the synthesized one (sync
        schedules carry none; model-sharded buckets re-pad per shard)."""
        if self.wire_carried is not None and self.row_shard == 1:
            return self.wire_carried
        return self.wire_global or self.wire_carried

    def _synthesize_global_wire(self):
        """The wire contract of the *global* flat layout, synthesized
        shape-only via ``jax.eval_shape`` of the stacked wire initializer
        — no kernel runs, works on concrete arrays or structs."""
        if self.program is None:
            return None
        try:
            topo = self.program.schedule.topologies[0]
            fl = consensus.stacked_flat_comm(
                topo, interpret=True, exchange=self.program.exchange,
                program=self.program)
            return jax.eval_shape(
                lambda p: consensus.initial_wire_state(fl, p), self.params)
        except Exception:
            return None


# --------------------------------------------------------------------------
# closed-form collective prediction
# --------------------------------------------------------------------------


def predict_collectives(program: Optional[consensus.MixingProgram],
                        spec: flatbuf.FlatSpec, schedule: str,
                        mode: str) -> dict:
    """Closed-form ppermute census of a program configuration.

    Returns ``{total, carried, fresh, breakdown}`` where breakdown names
    every factor; ``total`` is None when the config is outside the model
    (non-circulant sharded topology, factored multi-axis mesh)."""
    if mode == "stacked":
        return {"total": 0, "carried": 0, "fresh": 0,
                "breakdown": {"mode": "stacked — dense Pi matmul, "
                                      "no collectives"}}
    if program is None:
        return {"total": None, "carried": None, "fresh": None,
                "breakdown": {"reason": "no MixingProgram (dense/ppermute "
                                        "legacy mixing)"}}
    entry_shifts = []
    for topo in program.schedule.topologies:
        sw = topo.shift_weights()
        if sw is None:
            return {"total": None, "carried": None, "fresh": None,
                    "breakdown": {"reason": f"topology {topo.name!r} is "
                                            "not circulant"}}
        n = topo.n_agents
        entry_shifts.append(len([s for s in sw if s % n != 0]))
    kind = program.compressor_kind
    if kind == "topk":
        fields = 3                     # values + indices + scales
    elif kind == "rank":
        fields = 2                     # p + qt factors
    elif program.exchange in ("int8", "fp8"):
        fields = 2                     # payload + row scales
    else:
        fields = 1                     # f32/bf16 payload only
    rounds = program.rounds
    callsites = 1 if rounds == 1 else (2 if rounds == 2 else 3)
    per_site = sum(entry_shifts) * fields * spec.n_buckets \
        * program.n_payloads
    total = per_site * callsites
    carried = per_site if schedule == "overlap" else 0
    return {
        "total": total, "carried": carried, "fresh": total - carried,
        "breakdown": {
            "entry_shifts": entry_shifts, "fields": fields,
            "n_buckets": spec.n_buckets, "n_payloads": program.n_payloads,
            "rounds": rounds, "callsites": callsites,
            "staleness": program.staleness,
        },
    }


# --------------------------------------------------------------------------
# pass 1: collective census
# --------------------------------------------------------------------------


def pass_collective_census(ctx: CheckContext) -> List[RuleResult]:
    pred = predict_collectives(ctx.program, ctx.spec, ctx.schedule, ctx.mode)
    pp = [h for h in ctx.hits if "ppermute" in h["prim"]]
    carried = [h for h in pp
               if not (h["labels"] & frozenset(("params", "batch")))]
    fresh = [h for h in pp if h["labels"] & frozenset(("params", "batch"))]
    ev = {
        "actual": len(pp), "actual_carried": len(carried),
        "actual_fresh": len(fresh), "predicted": pred["total"],
        "predicted_carried": pred["carried"],
        "predicted_fresh": pred["fresh"], "breakdown": pred["breakdown"],
    }
    out = []
    if pred["total"] is None:
        out.append(RuleResult(
            "census.ppermute_count", ok=True, skipped=True,
            detail=f"no closed-form prediction: "
                   f"{pred['breakdown'].get('reason')}", evidence=ev))
        out.append(RuleResult("census.critical_path", ok=True, skipped=True,
                              detail="prediction unavailable", evidence=ev))
    else:
        out.append(RuleResult(
            "census.ppermute_count", ok=len(pp) == pred["total"],
            detail=(f"{len(pp)} collective-permute eqns, predicted "
                    f"{pred['total']} = sum(shifts)"
                    f"{pred['breakdown'].get('entry_shifts', '')} x "
                    f"{pred['breakdown'].get('fields')} fields x "
                    f"{pred['breakdown'].get('n_buckets')} buckets x "
                    f"{pred['breakdown'].get('n_payloads')} payloads x "
                    f"{pred['breakdown'].get('callsites')} callsites"),
            evidence=ev))
        cls_ev = dict(ev)
        cls_ev["fresh_hits"] = [
            {"prim": h["prim"], "labels": sorted(h["labels"])}
            for h in fresh]
        ok = (len(carried) == pred["carried"]
              and len(fresh) == pred["fresh"])
        detail = (f"{len(carried)} carried-only / {len(fresh)} fresh; "
                  f"predicted {pred['carried']}/{pred['fresh']} under "
                  f"schedule={ctx.schedule!r}")
        if not ok and ctx.schedule == "overlap" \
                and len(carried) < (pred["carried"] or 0):
            detail += (" — a collective the overlap contract requires to "
                       "consume only carried wire state reads fresh "
                       "params/batch: the exchange is back on the "
                       "grad->update critical path")
        out.append(RuleResult("census.critical_path", ok=ok, detail=detail,
                              evidence=cls_ev))
    others = [h for h in ctx.hits if "ppermute" not in h["prim"]]
    bad = [h for h in others if "wire" in h["labels"]
           or "params" in h["labels"]]
    out.append(RuleResult(
        "census.clean_collectives", ok=not bad,
        detail=("no non-ppermute collective touches wire/param data"
                if not bad else
                f"{len(bad)} unintended collective(s) carry wire/param "
                f"data: {[h['prim'] for h in bad]}"),
        evidence={"non_ppermute_collectives": [
            {"prim": h["prim"], "labels": sorted(h["labels"])}
            for h in others]}))
    return out


# --------------------------------------------------------------------------
# pass 2: alias / donation coverage
# --------------------------------------------------------------------------


def pass_alias_donation(ctx: CheckContext) -> List[RuleResult]:
    from repro.kernels.consensus_update import ops as kops

    out = []
    expected = getattr(ctx.optimizer, "fused_alias_pairs", None)
    fused = bool(getattr(ctx.optimizer, "fused", False))
    if expected is None or not fused:
        out.append(RuleResult(
            "alias.fused_coverage", ok=True, skipped=True,
            detail="optimizer declares no fused in-place contract"))
    else:
        groups = kops.alias_groups(ctx.closed_jaxpr)
        aliased = [g for g in groups if g]
        bad_len = [g for g in aliased if len(g) != expected]
        ok = len(aliased) == ctx.spec.n_buckets and not bad_len
        detail = (f"{len(aliased)}/{ctx.spec.n_buckets} fused launches "
                  f"alias in place, {expected} pair(s) each declared by "
                  f"{type(ctx.optimizer).__name__}")
        if len(aliased) < ctx.spec.n_buckets:
            detail += (" — a fused bucket launch dropped its "
                       "input_output_aliases: the update silently copies "
                       "instead of updating in place")
        elif bad_len:
            detail += (f" — launches with wrong pair counts: "
                       f"{[len(g) for g in bad_len]}")
        out.append(RuleResult(
            "alias.fused_coverage", ok=ok, detail=detail,
            evidence={"groups": groups, "expected_pairs": expected,
                      "n_buckets": ctx.spec.n_buckets}))
        cov = set(ctx.donate_argnums) >= {0, 1}
        out.append(RuleResult(
            "alias.donation_declared", ok=cov,
            detail=("params + opt_state donated to the jitted step"
                    if cov else
                    f"donate_argnums={ctx.donate_argnums} does not cover "
                    "(params, opt_state): the declared in-place aliases "
                    "cannot elide the output copies"),
            evidence={"donate_argnums": list(ctx.donate_argnums)}))

    out.append(_double_donation_rule(ctx))

    if ctx.dropped_donations is None:
        out.append(RuleResult(
            "alias.dropped_donations", ok=True, skipped=True,
            detail="no compile-time donation report supplied"))
    else:
        real = [w for w in ctx.dropped_donations
                if "not implemented for" not in w]
        platform_only = [w for w in ctx.dropped_donations
                         if "not implemented for" in w]
        out.append(RuleResult(
            "alias.dropped_donations", ok=not real,
            detail=("no silently-dropped donations" if not real else
                    f"{len(real)} donation(s) dropped at compile"),
            evidence={"dropped": real,
                      "platform_unsupported": platform_only}))
    return out


def _double_donation_rule(ctx: CheckContext) -> RuleResult:
    """The PR 9 Nesterov bug class: the same buffer reachable through two
    donated jit arguments is donated twice — a runtime error on the first
    step, invisible to shape-level checks."""
    if not set(ctx.donate_argnums) >= {0, 1}:
        return RuleResult("alias.double_donation", ok=True, skipped=True,
                          detail="params/opt_state not both donated")
    donated = {0: ctx.params, 1: ctx.opt_state}
    leaves: Dict[int, List[str]] = {}
    concrete = True
    for argi, tree in donated.items():
        flat, _ = jax.tree_util.tree_flatten_with_path(tree)
        for path, leaf in flat:
            if not isinstance(leaf, jax.Array):
                concrete = False
                continue
            key = id(leaf)
            name = f"arg{argi}{jax.tree_util.keystr(path)}"
            leaves.setdefault(key, []).append(name)
    if not concrete and not leaves:
        return RuleResult("alias.double_donation", ok=True, skipped=True,
                          detail="abstract (ShapeDtypeStruct) inputs — "
                                 "buffer identity not checkable")
    dups = {names[0]: names for names in leaves.values() if len(names) > 1}
    return RuleResult(
        "alias.double_donation", ok=not dups,
        detail=("no buffer is donated twice" if not dups else
                f"{len(dups)} buffer(s) reachable through multiple donated "
                "leaves — donating the same buffer twice is a runtime "
                "error on the first step (copy at init instead, like "
                "CDMSGDNesterov.init_inner's lookahead)"),
        evidence={"duplicates": list(dups.values())})


def compile_donation_report(step_fn, donate_argnums, *args) -> List[str]:
    """Compile ``step_fn`` capturing jax's dropped-donation warnings; feed
    the result to :class:`CheckContext` as ``dropped_donations``."""
    import warnings as _warnings

    with _warnings.catch_warnings(record=True) as caught:
        _warnings.simplefilter("always")
        jax.jit(step_fn, donate_argnums=donate_argnums).lower(
            *args).compile()
    return [str(w.message) for w in caught
            if "donat" in str(w.message).lower()]


# --------------------------------------------------------------------------
# pass 3: byte-accounting cross-check
# --------------------------------------------------------------------------


def pass_byte_accounting(ctx: CheckContext) -> List[RuleResult]:
    out = []
    if ctx.program is None:
        return [RuleResult("bytes.wire_vs_program", ok=True, skipped=True,
                           detail="no MixingProgram to price")]
    per_nbr = consensus.program_bytes_per_neighbor(
        ctx.spec, ctx.program, ctx.program.exchange, ctx.program.n_payloads)
    if ctx.wire_template is None:
        out.append(RuleResult(
            "bytes.wire_vs_program", ok=True, skipped=True,
            detail="no carried wire and the template synthesis failed",
            evidence={"program_bytes_per_neighbor": per_nbr}))
    else:
        actual = engine.wire_bytes_per_neighbor(ctx.wire_template)
        ev = {"wire_bytes_per_neighbor": actual,
              "program_bytes_per_neighbor": per_nbr}
        detail = (f"wire contract moves {actual} B/neighbor, accounting "
                  f"prices {per_nbr} B")
        if ctx.wire_carried is not None and ctx.row_shard != 1:
            # model-sharded buckets re-pad per shard, so the carried
            # struct's global shape over-counts padding; the rule compares
            # the global-layout template and records the carried figure
            carried = engine.wire_bytes_per_neighbor(ctx.wire_carried)
            ev["carried_bytes_per_neighbor"] = carried
            ev["per_shard_padding_bytes"] = carried - actual
            detail += (f" (carried per-shard layout: {carried} B, "
                       f"+{carried - actual} B repadding over "
                       f"{ctx.row_shard} row shards)")
        out.append(RuleResult(
            "bytes.wire_vs_program", ok=actual == per_nbr, detail=detail,
            evidence=ev))

    if ctx.hlo_stats is None:
        out.append(RuleResult(
            "bytes.hlo_collective_permute", ok=True, skipped=True,
            detail="no HLO stats supplied"))
        return out
    cp_bytes = int(ctx.hlo_stats.collective_bytes.get(
        "collective-permute", 0))
    pred = predict_collectives(ctx.program, ctx.spec, ctx.schedule, ctx.mode)
    shifts = pred["breakdown"].get("entry_shifts")
    if ctx.mode == "stacked":
        out.append(RuleResult(
            "bytes.hlo_collective_permute", ok=cp_bytes == 0,
            detail=f"stacked mode must ship 0 collective-permute bytes, "
                   f"HLO shows {cp_bytes}",
            evidence={"hlo_cp_bytes": cp_bytes}))
        return out
    if shifts is None or ctx.row_shard != 1:
        out.append(RuleResult(
            "bytes.hlo_collective_permute", ok=True, skipped=True,
            detail=(f"model-sharded buckets (row_shard={ctx.row_shard}) "
                    "re-pad per shard; per-device equality not provable "
                    "from the global spec" if ctx.row_shard != 1 else
                    "no closed-form shift count"),
            evidence={"hlo_cp_bytes": cp_bytes,
                      "program_bytes_per_neighbor": per_nbr}))
        return out
    # trip-aware HLO totals: every switch branch counts once, the
    # multi-round scan body counts trip times -> rounds multiplier
    expect = per_nbr * sum(shifts) * ctx.program.rounds
    out.append(RuleResult(
        "bytes.hlo_collective_permute", ok=cp_bytes == expect,
        detail=(f"HLO moves {cp_bytes} B through collective-permute; "
                f"accounting predicts {expect} = {per_nbr} B/neighbor x "
                f"sum(shifts){shifts} x {ctx.program.rounds} round(s)"),
        evidence={"hlo_cp_bytes": cp_bytes, "expected": expect,
                  "per_neighbor": per_nbr, "entry_shifts": shifts,
                  "rounds": ctx.program.rounds,
                  "hlo_cp_count": int(ctx.hlo_stats.collective_count.get(
                      "collective-permute", 0))}))
    return out


# --------------------------------------------------------------------------
# pass 4: seed-stream lint
# --------------------------------------------------------------------------


def _seed_grid(steps: np.ndarray, rounds: int, agents: int, buckets: int,
               payloads: int) -> np.ndarray:
    """Vectorized wire_seed over the full index grid, wrapped to uint32.
    ``steps`` may be any broadcastable integer array (dense windows, the
    staleness ring's ``t - s`` plane, strided probes)."""
    st = np.int64(consensus._SEED_STEP_STRIDE)
    ag = np.int64(consensus._SEED_AGENT_STRIDE)
    bu = np.int64(consensus._SEED_BUCKET_STRIDE)
    ro = np.int64(consensus._SEED_ROUND_STRIDE)
    pa = np.int64(consensus._SEED_PAYLOAD_STRIDE)
    s = (st * (steps[..., None, None, None, None]
               + ro * np.arange(rounds)[:, None, None, None])
         + ag * np.arange(agents)[:, None, None]
         + bu * np.arange(buckets)[:, None]
         + pa * np.arange(payloads))
    return (s & 0xFFFFFFFF).ravel()


def pass_seed_streams(ctx: CheckContext) -> List[RuleResult]:
    prog = ctx.program
    quantized = prog is not None and (
        prog.exchange in ("int8", "fp8") or prog.compressor_kind == "topk")
    strides = {
        "step": consensus._SEED_STEP_STRIDE,
        "agent": consensus._SEED_AGENT_STRIDE,
        "bucket": consensus._SEED_BUCKET_STRIDE,
        "round": consensus._SEED_ROUND_STRIDE,
        "payload": consensus._SEED_PAYLOAD_STRIDE,
    }
    out = [RuleResult(
        "seeds.strides_distinct",
        ok=len(set(strides.values())) == len(strides)
        and all(v != 0 for v in strides.values()),
        detail="the five wire_seed strides are distinct and nonzero",
        evidence={"strides": strides})]
    if not quantized:
        out.append(RuleResult(
            "seeds.window_collision_free", ok=True, skipped=True,
            detail="no stochastic rounding on this wire "
                   f"(exchange={getattr(prog, 'exchange', 'f32')!r})"))
        return out

    rounds, agents = prog.rounds, ctx.n_agents
    buckets, payloads = ctx.spec.n_buckets, prog.n_payloads

    def _distinct(steps):
        seeds = _seed_grid(np.asarray(steps, np.int64), rounds, agents,
                           buckets, payloads)
        return len(np.unique(seeds)) == seeds.size, seeds.size

    dense_ok, dense_n = _distinct(np.arange(128))
    probe_ok, probe_n = _distinct((np.arange(997) * 1003 + 13) % 1_000_000)
    # spot-check the vectorized grid against the canonical wire_seed
    rng = np.random.default_rng(0)
    spot_ok = True
    for _ in range(8):
        t = int(rng.integers(0, 1_000_000))
        a = int(rng.integers(0, agents))
        b = int(rng.integers(0, buckets))
        r = int(rng.integers(0, rounds))
        p = int(rng.integers(0, payloads))
        want = consensus.wire_seed(t, a, b, r, p) & 0xFFFFFFFF
        got = int(_seed_grid(np.asarray([t], np.int64), r + 1, a + 1,
                             b + 1, p + 1)[-1])
        spot_ok = spot_ok and got == want
    out.append(RuleResult(
        "seeds.window_collision_free",
        ok=dense_ok and probe_ok and spot_ok,
        detail=(f"SR streams disjoint over a dense 128-step window "
                f"({dense_n} seeds) and a 997-step strided probe "
                f"({probe_n} seeds) at {agents} agents x {buckets} "
                f"buckets x {rounds} round(s) x {payloads} payload(s)"
                + ("" if spot_ok else
                   " — grid disagrees with wire_seed()")),
        evidence={"dense_window_ok": dense_ok, "probe_ok": probe_ok,
                  "matches_wire_seed": spot_ok,
                  "dense_seeds": dense_n, "probe_seeds": probe_n}))

    if prog.staleness > 1:
        S = prog.staleness
        base = np.arange(64) + S
        window = base[:, None] - np.arange(S + 1)     # (steps, S+1)
        ring_seeds = _seed_grid(window.astype(np.int64), rounds, agents,
                                buckets, payloads)
        # the same (t - s) plane repeats across consecutive steps; dedupe
        # per distinct step value, then require global uniqueness
        uniq_steps = np.unique(window)
        flat = _seed_grid(uniq_steps.astype(np.int64), rounds, agents,
                          buckets, payloads)
        ok = len(np.unique(flat)) == flat.size
        out.append(RuleResult(
            "seeds.ring_window", ok=ok,
            detail=f"depth-{S} staleness ring window seeds are disjoint "
                   f"({flat.size} seeds over {len(uniq_steps)} steps)",
            evidence={"staleness": S, "n_seeds": int(flat.size),
                      "n_window_seeds": int(ring_seeds.size)}))
    return out


# --------------------------------------------------------------------------
# pass 5: sparse-wire invariants
# --------------------------------------------------------------------------


def pass_sparse_wire(ctx: CheckContext) -> List[RuleResult]:
    prog = ctx.program
    if prog is None or not prog.compressed:
        return [RuleResult("sparse.shape_contract", ok=True, skipped=True,
                           detail="dense wire (no compressor)")]
    from repro.kernels.consensus_update import topk as tk

    kind, param = consensus.parse_compressor(prog.compressor)
    rows = [b.rows for b in ctx.spec.buckets]
    entries = _wire_entries(ctx.wire_template)
    out = []
    if entries is None:
        out.append(RuleResult(
            "sparse.shape_contract", ok=True, skipped=True,
            detail="no wire template to validate",
            evidence={"compressor": prog.compressor}))
        return out

    problems: List[str] = []
    if kind == "topk":
        k_list = tk.topk_k_rows_for(rows, param)
        for bi, (e, k, r) in enumerate(zip(entries, k_list, rows)):
            if not isinstance(e, consensus.TopKWire):
                problems.append(f"bucket {bi}: expected TopKWire, got "
                                f"{type(e).__name__}")
                continue
            for fname, f, shp, dt in (
                    ("values", e.values, (k, flatbuf.LANE), jnp.int8),
                    ("indices", e.indices, (k, flatbuf.LANE), jnp.int32),
                    ("scales", e.scales, (k, 1), jnp.float32)):
                if tuple(f.shape[-2:]) != shp or f.dtype != dt:
                    problems.append(
                        f"bucket {bi} {fname}: {f.shape}/{f.dtype} != "
                        f"(*, {shp[0]}, {shp[1]})/{jnp.dtype(dt).name}")
        clamp_ok = all(1 <= k <= r for k, r in zip(k_list, rows))
        clamp_detail = (f"k_rows {k_list} clamped into [1, rows] "
                        f"{rows}")
        budget_ev = {}
        if isinstance(param, tuple):          # ("auto", budget_bytes)
            budget = int(param[1])
            spend = sum(k * tk.TOPK_LANE_ROW_BYTES for k in k_list)
            over = spend > budget and any(k > 1 for k in k_list)
            clamp_ok = clamp_ok and not over
            budget_ev = {"budget_bytes": budget, "spend_bytes": spend}
            clamp_detail += f"; auto budget {budget} B, spend {spend} B"
        out.append(RuleResult(
            "sparse.k_rows_clamp", ok=clamp_ok, detail=clamp_detail,
            evidence={"k_rows": list(k_list), "rows": rows, **budget_ev}))
    else:
        assert kind == "rank", kind
        r = int(param)
        for bi, (e, rw) in enumerate(zip(entries, rows)):
            if not isinstance(e, consensus.RankWire):
                problems.append(f"bucket {bi}: expected RankWire, got "
                                f"{type(e).__name__}")
                continue
            for fname, f, shp in (("p", e.p, (rw, r)),
                                  ("qt", e.qt, (r, flatbuf.LANE))):
                if tuple(f.shape[-2:]) != shp or f.dtype != jnp.float32:
                    problems.append(
                        f"bucket {bi} {fname}: {f.shape}/{f.dtype} != "
                        f"(*, {shp[0]}, {shp[1]})/float32")
        out.append(RuleResult(
            "sparse.k_rows_clamp", ok=1 <= r,
            detail=f"rank r={r} >= 1", evidence={"rank": r, "rows": rows}))
    out.insert(0, RuleResult(
        "sparse.shape_contract", ok=not problems,
        detail=("every compressed wire field matches the static "
                f"{kind} contract" if not problems else
                "; ".join(problems)),
        evidence={"compressor": prog.compressor,
                  "n_entries": len(entries), "problems": problems}))

    out.append(_index_bounds_rule(ctx, kind, rows, entries))
    return out


def _wire_entries(wire):
    if wire is None:
        return None
    if isinstance(wire, consensus.WireRing):
        return list(wire.slots)
    if isinstance(wire, (tuple, list)) and len(wire):
        return list(wire)
    return None


def _index_bounds_rule(ctx, kind, rows, entries) -> RuleResult:
    if kind != "topk":
        return RuleResult("sparse.index_bounds", ok=True, skipped=True,
                          detail="rank wire carries no indices")
    if not ctx.checkify_indices:
        return RuleResult("sparse.index_bounds", ok=True, skipped=True,
                          detail="opt-in: pass checkify_indices=True")
    if any(not isinstance(f, jax.Array)
           for e in entries for f in (e.indices,)):
        return RuleResult("sparse.index_bounds", ok=True, skipped=True,
                          detail="abstract wire — checkify needs concrete "
                                 "indices")
    from jax.experimental import checkify

    msgs = []
    for bi, (e, r) in enumerate(zip(entries, rows)):
        dense = r * flatbuf.LANE

        def gather(idx, dense=dense):
            return jnp.zeros((dense,), jnp.float32)[idx.reshape(-1)]

        err, _ = checkify.checkify(
            gather, errors=checkify.index_checks)(e.indices)
        m = err.get()
        if m:
            msgs.append(f"bucket {bi}: {m}")
    return RuleResult(
        "sparse.index_bounds", ok=not msgs,
        detail=("checkify proves every top-k index in range"
                if not msgs else "; ".join(msgs)),
        evidence={"buckets_checked": len(entries), "errors": msgs})


# --------------------------------------------------------------------------
# orchestration
# --------------------------------------------------------------------------


PASSES = {
    "census": pass_collective_census,
    "alias": pass_alias_donation,
    "bytes": pass_byte_accounting,
    "seeds": pass_seed_streams,
    "sparse": pass_sparse_wire,
}


def run_passes(ctx: CheckContext,
               passes: Optional[Sequence[str]] = None) -> CheckReport:
    """Assemble the shared trace and run every (or the named) pass."""
    import time

    t0 = time.perf_counter()
    ctx.assemble()
    results: List[RuleResult] = []
    for name in (passes or PASSES):
        try:
            results.extend(PASSES[name](ctx))
        except Exception:
            results.append(RuleResult(
                f"{name}.error", ok=False,
                detail="pass crashed (checker bug or unsupported program "
                       "shape)",
                evidence={"traceback": traceback.format_exc(limit=8)}))
    return CheckReport(label=ctx.label, mode=ctx.mode, schedule=ctx.schedule,
                       results=results,
                       walltime_s=time.perf_counter() - t0)


def check_program(step_fn, params, opt_state, batch, *, program, optimizer,
                  schedule: str, mode: str, n_agents: int, spec=None,
                  label: str = "", donate_argnums: Tuple[int, ...] = (0, 1),
                  hlo_stats=None, row_shard: int = 1,
                  dropped_donations=None, checkify_indices: bool = False,
                  passes: Optional[Sequence[str]] = None) -> CheckReport:
    """Certify one assembled step function (the low-level entry point).

    ``params``/``opt_state``/``batch`` may be concrete arrays or
    ``ShapeDtypeStruct`` templates — the checker only traces.  ``spec``
    defaults to the global flat layout of ``params``.
    """
    if spec is None:
        spec = flatbuf.make_flat_spec(params, lead=1)
    ctx = CheckContext(
        label=label or f"{mode}/{schedule}", mode=mode, schedule=schedule,
        program=program, optimizer=optimizer, spec=spec, n_agents=n_agents,
        step_fn=step_fn, params=params, opt_state=opt_state, batch=batch,
        donate_argnums=tuple(donate_argnums), hlo_stats=hlo_stats,
        row_shard=row_shard, dropped_donations=dropped_donations,
        checkify_indices=checkify_indices)
    return run_passes(ctx, passes)


def check_trainer(trainer, batch, *, label: str = "", hlo_stats=None,
                  dropped_donations=None, checkify_indices: bool = False,
                  passes: Optional[Sequence[str]] = None) -> CheckReport:
    """Certify a stacked :class:`repro.core.trainer.CollaborativeTrainer`."""
    return check_program(
        trainer._program.step_fn, trainer.state.params,
        trainer.state.opt_state, batch,
        program=trainer.program, optimizer=trainer.optimizer,
        schedule=trainer.schedule, mode="stacked",
        n_agents=trainer.topology.n_agents,
        label=label or f"stacked/{trainer.schedule}",
        donate_argnums=getattr(trainer, "donate_argnums", (0, 1)),
        hlo_stats=hlo_stats, dropped_donations=dropped_donations,
        checkify_indices=checkify_indices, passes=passes)


def check_bundle(bundle, mesh, batch=None, *, label: str = "",
                 hlo_stats=None, row_shard: Optional[int] = None,
                 dropped_donations=None,
                 passes: Optional[Sequence[str]] = None) -> CheckReport:
    """Certify a sharded :class:`repro.launch.steps.TrainStepBundle` from
    its shape templates (no data, no compile)."""
    params = bundle.param_structs(mesh)
    opt_state = bundle.opt_state_structs(mesh, bundle.optimizer)
    if batch is None:
        batch = bundle.batch_specs
    if row_shard is None:
        # "data"/"pod" carry agents (rows stay whole); every other axis
        # ("model", …) shards the bucket rows and re-pads per shard
        agent_axes = {"replica", "agent", "data", "pod"}
        row_shard = 1
        for name, size in dict(mesh.shape).items():
            if name not in agent_axes:
                row_shard *= int(size)
    return check_program(
        bundle.step_fn, params, opt_state, batch,
        program=bundle.mixing_program, optimizer=bundle.optimizer,
        schedule=bundle.schedule, mode="sharded",
        n_agents=bundle.n_agents, label=label or f"sharded/{bundle.schedule}",
        donate_argnums=bundle.donate_argnums, hlo_stats=hlo_stats,
        row_shard=row_shard, dropped_donations=dropped_donations,
        passes=passes)
