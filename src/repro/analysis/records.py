"""Loaders for launch-produced JSON records, with schema versioning.

``launch/dryrun.py`` has been writing ``results/dryrun/*.json`` since PR 4
without a version stamp.  PR 10 adds two fields:

* ``version`` — integer schema version (``DRYRUN_SCHEMA_VERSION``);
* ``verify``  — the static contract-checker report
  (``repro.analysis.staticcheck.CheckReport.as_dict()``), or ``None`` when
  the checker did not run (non-train modes, pre-PR-10 records, or checker
  failure — failures land as a ``"FAIL: ..."`` string, never an exception).

``load_dryrun_record`` normalizes records from any era so downstream
consumers (benchmarks/README.md tables, CI diffing) can read one shape:
missing ``version`` means 1 (pre-checker), missing ``verify`` means None.
"""

import json

# bump when the dryrun record shape changes; loaders must keep reading
# every older version
DRYRUN_SCHEMA_VERSION = 2


def load_dryrun_record(path):
    """Read one ``results/dryrun/*.json`` record, normalized to the current
    schema: ``version`` defaults to 1 and ``verify`` to None for records
    written before PR 10."""
    with open(path) as f:
        rec = json.load(f)
    rec.setdefault("version", 1)
    rec.setdefault("verify", None)
    return rec


def verify_summary(rec):
    """One-line human summary of a record's verify block: ``"not run"``,
    the failure string, or ``"ok (N rules)"`` / ``"FAIL: rule, rule"``."""
    v = rec.get("verify")
    if v is None:
        return "not run"
    if isinstance(v, str):
        return v
    rules = v.get("rules", [])
    bad = [r["rule"] for r in rules if not r.get("ok", False)
           and not r.get("skipped", False)]
    if bad:
        return "FAIL: " + ", ".join(bad)
    return f"ok ({len(rules)} rules)"
