"""Trip-count-aware HLO analysis for the roofline report.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body **once** (a
10-iteration scan under-reports FLOPs by exactly 10x — verified), and no
API exposes collective traffic.  Since every model here scans over layers,
naive numbers would be off by 24-61x.  This module parses
``compiled.as_text()`` (post-optimization, post-SPMD HLO, shapes are the
per-device shards) and:

* reconstructs the computation call graph (entry -> while bodies / calls /
  conditionals), reading each while loop's **trip count** from its
  ``backend_config known_trip_count`` (fallback: the comparison constant in
  the condition computation);
* resolves operand shapes through a per-computation symbol table (HLO
  instruction lines reference operands by name only);
* sums **collective bytes** (all-reduce, all-gather, reduce-scatter,
  all-to-all, collective-permute) as operand bytes x enclosing trip counts;
* estimates **trip-aware FLOPs** from ``dot``/``convolution`` instructions
  (recursing into fusion computations), cross-checked against the analytic
  ``6 * N_active * D``;
* estimates **HBM traffic** as operand+output bytes of top-level (post-
  fusion) instructions x trip counts — a traffic proxy that excludes
  fusion-internal temporaries.

Documented limits (EXPERIMENTS.md §Methodology): elementwise/transcendental
FLOPs excluded; traffic counts tuple-shuffling ops like get-tuple-element
as zero-cost only when they produce tuples (bitcast/copy are counted — XLA
CPU materializes copies, TPU mostly doesn't, so the memory term is an upper
bound).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# ops whose operand/output bytes we do not count as HBM traffic
_FREE_OPS = {"get-tuple-element", "tuple", "parameter", "constant", "bitcast",
             "partition-id", "replica-id", "after-all", "iota"}


def _dtype_bytes(dt: str) -> int:
    return _DTYPE_BYTES.get(dt, 0)


def _shape_list_bytes(text: str) -> int:
    """Total bytes of every array shape literal appearing in `text`."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class Instruction:
    name: str
    opcode: str
    line: str
    out_shape_text: str
    out_bytes: int
    operand_names: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction]
    by_name: Dict[str, Instruction]


_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"\)\s*([a-z][a-z0-9\-]*)\(|^([a-z][a-z0-9\-]*)\(")


def _split_rhs(rhs: str) -> Tuple[str, str, List[str]]:
    """rhs -> (out_shape_text, opcode, operand names)."""
    # output shape: everything before the opcode token
    m = re.search(r"\b([a-z][a-z0-9\-]*)\(", rhs)
    # the first `word(` after the shape part is the opcode; shapes never
    # precede '(' directly, but tuple shapes start with '(' at pos 0.
    opcode, args_start = "", -1
    depth = 0
    i = 0
    # skip a leading tuple shape "(...)"
    if rhs.startswith("("):
        depth = 0
        for j, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    i = j + 1
                    break
    m = re.compile(r"\s*([a-z][a-z0-9\-]*)\(").search(rhs, i)
    if not m:
        return rhs, "", []
    opcode = m.group(1)
    out_shape_text = rhs[: m.start()]
    # operands: %name tokens inside the top-level parens after opcode
    depth = 0
    args = ""
    for j in range(m.end() - 1, len(rhs)):
        ch = rhs[j]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                args = rhs[m.end() : j]
                break
    operands = re.findall(r"%([\w\.\-]+)", args)
    return out_shape_text, opcode, operands


def _parse_computations(hlo: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        s = line.strip()
        if cur is None:
            m = _HEADER_RE.match(s)
            if m:
                cur = Computation(m.group(2), [], {})
                if m.group(1):
                    entry = m.group(2)
            continue
        if s.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(s)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # strip metadata / backend_config tails for shape parsing of output
        out_shape_text, opcode, operands = _split_rhs(rhs)
        ins = Instruction(
            name=name,
            opcode=opcode,
            line=s,
            out_shape_text=out_shape_text if out_shape_text else rhs,
            out_bytes=_shape_list_bytes(out_shape_text if out_shape_text else rhs.split(" ", 1)[0]),
            operand_names=operands,
        )
        cur.instructions.append(ins)
        cur.by_name[name] = ins
    return comps, entry


def _known_trip_count(line: str) -> Optional[int]:
    m = re.search(r'known_trip_count.....n.:.(\d+)', line)
    return int(m.group(1)) if m else None


def _cond_trip_count(cond: Computation) -> int:
    best = 1
    for ins in cond.instructions:
        for m in re.finditer(r"constant\((\d+)\)", ins.line):
            best = max(best, int(m.group(1)))
    return best


def _while_edges(comp: Computation) -> List[Tuple[str, str, Optional[int]]]:
    out = []
    for ins in comp.instructions:
        if ins.opcode == "while":
            c = re.search(r"condition=%?([\w\.\-]+)", ins.line)
            b = re.search(r"body=%?([\w\.\-]+)", ins.line)
            if c and b:
                out.append((c.group(1), b.group(1), _known_trip_count(ins.line)))
    return out


def _call_edges(comp: Computation) -> List[str]:
    out = []
    for ins in comp.instructions:
        if ins.opcode == "fusion":
            continue
        m = re.search(r"to_apply=%?([\w\.\-]+)", ins.line)
        if m and ins.opcode in ("call", "custom-call", "map"):
            out.append(m.group(1))
        m = re.search(r"branch_computations=\{([^}]*)\}", ins.line)
        if m:
            for name in m.group(1).split(","):
                out.append(name.strip().lstrip("%"))
        if ins.opcode == "conditional":
            for m2 in re.finditer(r"(?:true_computation|false_computation)=%?([\w\.\-]+)", ins.line):
                out.append(m2.group(1))
    return out


def _fusion_callees(comp: Computation) -> List[str]:
    out = []
    for ins in comp.instructions:
        if ins.opcode == "fusion":
            m = re.search(r"calls=%?([\w\.\-]+)", ins.line)
            if m:
                out.append(m.group(1))
    return out


def _operand_bytes(comp: Computation, ins: Instruction) -> int:
    total = 0
    for name in ins.operand_names:
        src = comp.by_name.get(name)
        if src is not None:
            total += src.out_bytes
    return total


def _dot_flops(comp: Computation, ins: Instruction) -> int:
    if ins.opcode not in ("dot", "convolution"):
        return 0
    shapes = _SHAPE_RE.findall(ins.out_shape_text)
    if not shapes:
        return 0
    out_elems = _shape_elems(shapes[0][1])
    if ins.opcode == "convolution":
        # 2 * out_elems * (kernel spatial x input channels): parse rhs kernel
        if len(ins.operand_names) >= 2:
            k = comp.by_name.get(ins.operand_names[1])
            if k:
                ks = _SHAPE_RE.findall(k.out_shape_text)
                if ks:
                    kel = _shape_elems(ks[0][1])
                    # kernel elems includes output channels; divide them out
                    out_ch = int(ks[0][1].split(",")[-1]) if ks[0][1] else 1
                    return 2 * out_elems * max(kel // max(out_ch, 1), 1)
        return 0
    lhs = comp.by_name.get(ins.operand_names[0]) if ins.operand_names else None
    if lhs is None:
        return 0
    lshapes = _SHAPE_RE.findall(lhs.out_shape_text)
    if not lshapes:
        return 0
    lhs_dims = lshapes[0][1].split(",") if lshapes[0][1] else []
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
    contract = 1
    if m and m.group(1):
        for d in m.group(1).split(","):
            if int(d) < len(lhs_dims):
                contract *= int(lhs_dims[int(d)])
    return 2 * out_elems * contract


@dataclasses.dataclass
class HloStats:
    """Trip-aware totals over the compiled module (per-device shapes)."""

    collective_bytes: Dict[str, int]
    dot_flops: int
    traffic_bytes: int
    collective_count: Dict[str, int]
    trip_counts: Dict[str, int]

    @property
    def total_collective_bytes(self) -> int:
        return sum(self.collective_bytes.values())


def analyze_hlo(hlo: str) -> HloStats:
    comps, entry = _parse_computations(hlo)
    if entry is None:
        entry = max(comps, key=lambda k: len(comps[k].instructions)) if comps else ""

    # computation -> multiplier (sum over call paths of enclosing trip counts)
    mult: Dict[str, int] = {}
    trip_counts: Dict[str, int] = {}

    def visit(name: str, m: int, depth: int = 0):
        if name not in comps or depth > 64:
            return
        mult[name] = mult.get(name, 0) + m
        comp = comps[name]
        for cond, body, tc in _while_edges(comp):
            if tc is None:
                tc = _cond_trip_count(comps[cond]) if cond in comps else 1
            trip_counts[body] = tc
            visit(cond, m, depth + 1)
            visit(body, m * tc, depth + 1)
        for callee in _call_edges(comp):
            visit(callee, m, depth + 1)

    visit(entry, 1)

    coll_bytes = {c: 0 for c in _COLLECTIVES}
    coll_count = {c: 0 for c in _COLLECTIVES}
    flops = 0
    traffic = 0

    fusion_flops_cache: Dict[str, int] = {}

    def fusion_flops(name: str, depth: int = 0) -> int:
        if name in fusion_flops_cache:
            return fusion_flops_cache[name]
        if name not in comps or depth > 64:
            return 0
        total = 0
        comp = comps[name]
        for ins in comp.instructions:
            total += _dot_flops(comp, ins)
        for callee in _fusion_callees(comp):
            total += fusion_flops(callee, depth + 1)
        fusion_flops_cache[name] = total
        return total

    for name, comp in comps.items():
        m = mult.get(name, 0)
        if m == 0:
            continue
        for ins in comp.instructions:
            for c in _COLLECTIVES:
                if ins.opcode == c or ins.opcode.startswith(c + "-"):
                    b = _operand_bytes(comp, ins)
                    coll_bytes[c] += m * b
                    coll_count[c] += m
            flops += m * _dot_flops(comp, ins)
            if ins.opcode == "fusion":
                mfus = re.search(r"calls=%?([\w\.\-]+)", ins.line)
                if mfus:
                    flops += m * fusion_flops(mfus.group(1))
            if ins.opcode not in _FREE_OPS:
                traffic += m * (ins.out_bytes + _operand_bytes(comp, ins))

    return HloStats(collective_bytes=coll_bytes, dot_flops=flops,
                    traffic_bytes=traffic, collective_count=coll_count,
                    trip_counts=trip_counts)
