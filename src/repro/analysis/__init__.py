"""Compiled-artifact analysis: trip-count-aware HLO stats + roofline terms."""

from repro.analysis.hlo import analyze_hlo, HloStats
from repro.analysis.roofline import RooflineTerms, roofline_from_stats, HW_V5E

__all__ = ["analyze_hlo", "HloStats", "RooflineTerms", "roofline_from_stats", "HW_V5E"]
