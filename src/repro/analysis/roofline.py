"""Roofline terms from the compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

Hardware constants (TPU v5e target): 197 TFLOP/s bf16 per chip, 819 GB/s
HBM, ~50 GB/s/link ICI.  The FLOPs/bytes inputs come from the trip-aware
HLO analysis (:mod:`repro.analysis.hlo`) because XLA's cost_analysis counts
scan bodies once; both numbers are recorded side by side in EXPERIMENTS.md.

All byte/FLOP totals parsed from post-SPMD HLO are *per-device* quantities
(SPMD partitioning rewrites shapes to the local shard), so the terms below
divide by bandwidth/throughput of ONE chip; `chips` enters only through the
MODEL_FLOPS utilization ratio.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.analysis.hlo import HloStats


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float          # per chip, bf16
    hbm_bw: float              # bytes/s per chip
    ici_bw: float              # bytes/s per link
    hbm_bytes: float           # capacity per chip


HW_V5E = Hardware(name="tpu_v5e", peak_flops=197e12, hbm_bw=819e9,
                  ici_bw=50e9, hbm_bytes=16e9)


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device seconds
    compute_s: float
    memory_s: float
    collective_s: float
    # raw inputs
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    collective_bytes_per_device: float
    model_flops_total: float             # 6*N_active*D analytic
    xla_cost_flops: Optional[float] = None   # cost_analysis (loop bodies once)
    peak_memory_bytes: Optional[float] = None

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs x chips): remat/redundancy waste check."""
        denom = self.hlo_flops_per_device * self.chips
        return self.model_flops_total / denom if denom else float("nan")

    @property
    def mfu_bound(self) -> float:
        """Best-case MFU if the dominant term were fully overlapped."""
        t = self.step_time_lower_bound
        if t <= 0:
            return float("nan")
        return self.model_flops_total / (self.chips * 197e12 * t)

    def as_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "hlo_flops_per_device": self.hlo_flops_per_device,
            "hlo_bytes_per_device": self.hlo_bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "model_flops_total": self.model_flops_total,
            "useful_flops_ratio": self.useful_flops_ratio,
            "xla_cost_flops": self.xla_cost_flops,
            "peak_memory_bytes": self.peak_memory_bytes,
            "step_time_lower_bound_s": self.step_time_lower_bound,
        }


def roofline_from_stats(
    *, arch: str, shape: str, mesh: str, chips: int, stats: HloStats,
    model_flops_total: float, hw: Hardware = HW_V5E,
    xla_cost_flops: Optional[float] = None,
    peak_memory_bytes: Optional[float] = None,
) -> RooflineTerms:
    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        compute_s=stats.dot_flops / hw.peak_flops,
        memory_s=stats.traffic_bytes / hw.hbm_bw,
        collective_s=stats.total_collective_bytes / hw.ici_bw,
        hlo_flops_per_device=float(stats.dot_flops),
        hlo_bytes_per_device=float(stats.traffic_bytes),
        collective_bytes_per_device=float(stats.total_collective_bytes),
        model_flops_total=model_flops_total,
        xla_cost_flops=xla_cost_flops,
        peak_memory_bytes=peak_memory_bytes,
    )


def consensus_update_cost(spec, program, n_neighbors: int) -> Dict:
    """Analytic HBM bytes + FLOPs of the fused consensus update per step.

    Prices BOTH operand forms of the top-k wire (``compressor="topk:..."``)
    from the :class:`repro.core.flatbuf.FlatSpec` bucket geometry, per
    agent per step, so the ``consensus/sparse_update`` microbench has a
    model to compare its measured walltime ratio against:

    * **dense** (decompress-then-update reference): per neighbor the
      compact wire is read (``k_rows`` lane rows), a dense f32 bucket is
      written by the decompress and read back by the kernel —
      ``2 * 4 * rows * 128`` bytes of dense traffic per neighbor;
    * **sparse** (``sparse_update=True``): the kernel reads the compact
      fields directly — no dense neighbor traffic at all.

    Both forms share the self read, grad read, and output write at native
    bucket precision.  FLOPs count dequant + weight-multiply + accumulate
    per touched element (``O(rows)`` dense vs ``O(k_rows)`` sparse per
    neighbor).  Returns per-bucket rows plus the dense/sparse totals and
    their ratios.
    """
    from repro.kernels.consensus_update import topk as tk

    kind, param = program.compressor_kind, program.compressor_param
    if kind != "topk":
        raise ValueError(
            f"consensus_update_cost prices the top-k operand forms; program "
            f"has compressor={program.compressor!r}")
    rows_list = [b.rows for b in spec.buckets]
    k_list = tk.topk_k_rows_for(rows_list, param)
    per_bucket = []
    for b, k_rows in zip(spec.buckets, k_list):
        itemsize = b.bytes // b.n_padded
        elems = b.n_padded                       # rows * 128
        k_elems = k_rows * 128
        compact = k_rows * tk.TOPK_LANE_ROW_BYTES
        common = 3 * elems * itemsize            # self + grad reads, out write
        dense_b = common + n_neighbors * (compact + 2 * 4 * elems)
        sparse_b = common + n_neighbors * compact
        dense_f = 3 * elems + n_neighbors * (3 * elems + 2 * k_elems)
        sparse_f = 3 * elems + n_neighbors * 3 * k_elems
        per_bucket.append({
            "rows": b.rows, "k_rows": k_rows,
            "dense_bytes": dense_b, "sparse_bytes": sparse_b,
            "dense_flops": dense_f, "sparse_flops": sparse_f,
        })
    tot = lambda key: sum(pb[key] for pb in per_bucket)
    out = {
        "n_neighbors": n_neighbors,
        "per_bucket": per_bucket,
        "dense_bytes": tot("dense_bytes"),
        "sparse_bytes": tot("sparse_bytes"),
        "dense_flops": tot("dense_flops"),
        "sparse_flops": tot("sparse_flops"),
    }
    out["bytes_ratio"] = (out["dense_bytes"] / out["sparse_bytes"]
                          if out["sparse_bytes"] else float("nan"))
    out["flops_ratio"] = (out["dense_flops"] / out["sparse_flops"]
                          if out["sparse_flops"] else float("nan"))
    return out


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6*N_active*D for training, 2*N_active*D_new for
    decode (one token per request), 2*N_active*D for prefill."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    # decode: one new token per sequence
    return 2.0 * n_active * shape.global_batch
