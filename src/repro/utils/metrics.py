"""Lightweight metric logging (CSV + in-memory history)."""

from __future__ import annotations

import csv
import os
from typing import Dict, List, Optional


class MetricHistory:
    """In-memory step -> metrics store with simple reductions."""

    def __init__(self) -> None:
        self._rows: List[Dict[str, float]] = []

    def log(self, step: int, **metrics: float) -> None:
        row = {"step": float(step)}
        row.update({k: float(v) for k, v in metrics.items()})
        self._rows.append(row)

    @property
    def rows(self) -> List[Dict[str, float]]:
        return list(self._rows)

    def series(self, key: str) -> List[float]:
        return [r[key] for r in self._rows if key in r]

    def last(self, key: str) -> Optional[float]:
        s = self.series(key)
        return s[-1] if s else None

    def moving_average(self, key: str, window: int = 10) -> List[float]:
        s = self.series(key)
        out = []
        for i in range(len(s)):
            lo = max(0, i - window + 1)
            out.append(sum(s[lo : i + 1]) / (i - lo + 1))
        return out


class CSVLogger:
    """Append-only CSV metric logger (creates header lazily)."""

    def __init__(self, path: str) -> None:
        self.path = path
        self._fields: Optional[List[str]] = None
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)

    def log(self, **metrics) -> None:
        first = self._fields is None
        if first:
            self._fields = list(metrics.keys())
        with open(self.path, "a", newline="") as f:
            w = csv.DictWriter(f, fieldnames=self._fields, extrasaction="ignore")
            if first:
                w.writeheader()
            w.writerow(metrics)
