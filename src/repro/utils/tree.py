"""Pytree arithmetic helpers.

The CDSGD family of optimizers treats the model as an opaque parameter
pytree; every update rule in :mod:`repro.core.optim` is expressed with the
small algebra below so that a single implementation covers dense, MoE, SSM
and encoder-decoder models alike.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

PyTree = Any


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(s, tree: PyTree) -> PyTree:
    return jax.tree.map(lambda x: s * x, tree)


def tree_axpy(a, x: PyTree, y: PyTree) -> PyTree:
    """a * x + y, leaf-wise."""
    return jax.tree.map(lambda xi, yi: a * xi + yi, x, y)


def tree_weighted_sum(weights: Sequence, trees: Sequence[PyTree]) -> PyTree:
    """sum_i weights[i] * trees[i], leaf-wise.

    This is the pytree form of one row of the agent-interaction matrix
    multiply ``(Pi x)_j = sum_l pi_{jl} x_l`` (paper eq. 5).
    """
    if len(weights) != len(trees):
        raise ValueError(f"{len(weights)} weights vs {len(trees)} trees")

    def leaf(*leaves):
        acc = weights[0] * leaves[0]
        for w, l in zip(weights[1:], leaves[1:]):
            acc = acc + w * l
        return acc

    return jax.tree.map(leaf, *trees)


def tree_dot(a: PyTree, b: PyTree):
    """Inner product over all leaves (computed in f32)."""
    leaves = jax.tree.leaves(jax.tree.map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b))
    return jnp.sum(jnp.stack(leaves)) if leaves else jnp.float32(0.0)


def tree_l2_norm(tree: PyTree):
    return jnp.sqrt(tree_dot(tree, tree))


def tree_cast(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def tree_size(tree: PyTree) -> int:
    """Total number of scalar parameters."""
    return sum(x.size for x in jax.tree.leaves(tree))


def tree_bytes(tree: PyTree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))
