"""PRNG helpers: named, deterministic key derivation."""

from __future__ import annotations

import hashlib

import jax


def split_key(key, n: int):
    return list(jax.random.split(key, n))


def fold_in_name(key, name: str):
    """Derive a subkey deterministically from a string name."""
    h = int.from_bytes(hashlib.sha256(name.encode()).digest()[:4], "little")
    return jax.random.fold_in(key, h)
