"""Shared utilities: pytree arithmetic, PRNG helpers, metrics logging."""

from repro.utils.tree import (
    tree_add,
    tree_axpy,
    tree_dot,
    tree_l2_norm,
    tree_scale,
    tree_sub,
    tree_weighted_sum,
    tree_zeros_like,
    tree_cast,
    tree_size,
    tree_bytes,
)
from repro.utils.prng import split_key, fold_in_name
from repro.utils.metrics import CSVLogger, MetricHistory

__all__ = [
    "tree_add",
    "tree_axpy",
    "tree_dot",
    "tree_l2_norm",
    "tree_scale",
    "tree_sub",
    "tree_weighted_sum",
    "tree_zeros_like",
    "tree_cast",
    "tree_size",
    "tree_bytes",
    "split_key",
    "fold_in_name",
    "CSVLogger",
    "MetricHistory",
]
