"""Parameter templates: shapes + logical sharding + init in one tree.

Instead of a stateful module system (no flax in this environment — and the
dry-run needs allocation-free parameter *descriptions* anyway), every model
is described by a **template pytree** whose leaves are :class:`ParamDef`:

* ``init_params(template, key)``      -> materialized parameter pytree
* ``shape_structs(template, ...)``    -> ``jax.ShapeDtypeStruct`` tree (dry-run)
* ``partition_specs(template, rules)``-> ``PartitionSpec`` tree for pjit

Logical axis names used in templates (resolved via a rules dict):

* ``"agent"``  — leading per-agent axis (CDSGD replica axis)
* ``"layers"`` — stacked layer axis consumed by ``lax.scan`` (never sharded)
* ``"model"``  — tensor-parallel axis (attention heads / FFN / vocab)
* ``"expert"`` — expert-parallel axis (MoE), usually mapped to ``model``
* ``"fsdp"``   — ZeRO-style weight shard axis (hierarchical CDSGD variant)
* ``None``     — replicated dimension
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.utils.prng import fold_in_name

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """A single parameter: shape, logical axes, initializer."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]      # logical axis name per dim
    init: str = "normal"                 # normal|zeros|ones|scaled|embed
    scale: float = 1.0                   # fan-in override for "scaled"
    dtype: Any = jnp.float32

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes} rank mismatch")


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _init_leaf(pd: ParamDef, key) -> jnp.ndarray:
    if pd.init == "zeros":
        return jnp.zeros(pd.shape, pd.dtype)
    if pd.init == "ones":
        return jnp.ones(pd.shape, pd.dtype)
    if pd.init == "normal":
        return (0.02 * jax.random.normal(key, pd.shape)).astype(pd.dtype)
    if pd.init == "embed":
        return (0.05 * jax.random.normal(key, pd.shape)).astype(pd.dtype)
    if pd.init == "scaled":  # variance-scaling on fan-in (2nd-to-last dim)
        fan_in = pd.shape[-2] if len(pd.shape) >= 2 else pd.shape[-1]
        std = pd.scale / math.sqrt(max(fan_in, 1))
        return (std * jax.random.normal(key, pd.shape)).astype(pd.dtype)
    if pd.init == "conv_scaled":  # HWIO conv kernels: fan-in = H*W*I
        fan_in = math.prod(pd.shape[:-1])
        std = pd.scale / math.sqrt(max(fan_in, 1))
        return (std * jax.random.normal(key, pd.shape)).astype(pd.dtype)
    raise ValueError(f"unknown init {pd.init!r}")


def init_params(template: PyTree, key) -> PyTree:
    """Materialize parameters; keys derived per tree path (deterministic)."""

    flat, treedef = jax.tree_util.tree_flatten_with_path(template, is_leaf=_is_def)
    leaves = []
    for path, pd in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        leaves.append(_init_leaf(pd, fold_in_name(key, name)))
    return jax.tree.unflatten(treedef, leaves)


def shape_structs(template: PyTree, sharding_fn: Optional[Callable[[ParamDef], Any]] = None) -> PyTree:
    """ShapeDtypeStruct tree for allocation-free lowering (dry-run)."""

    def leaf(pd: ParamDef):
        sh = sharding_fn(pd) if sharding_fn is not None else None
        return jax.ShapeDtypeStruct(pd.shape, pd.dtype, sharding=sh)

    return jax.tree.map(leaf, template, is_leaf=_is_def)


def partition_specs(template: PyTree, rules: Dict[str, Any]) -> PyTree:
    """Resolve logical axes -> mesh axes via ``rules``.

    ``rules`` maps logical name -> mesh axis name (str), tuple of names, or
    None (replicate).  Missing names replicate.
    """

    def leaf(pd: ParamDef) -> PartitionSpec:
        resolved = []
        for ax in pd.axes:
            m = rules.get(ax) if ax is not None else None
            resolved.append(m)
        # drop trailing Nones for tidiness
        while resolved and resolved[-1] is None:
            resolved.pop()
        return PartitionSpec(*resolved)

    return jax.tree.map(leaf, template, is_leaf=_is_def)


def count_params(template: PyTree) -> int:
    return sum(math.prod(pd.shape) for pd in jax.tree.leaves(template, is_leaf=_is_def))


def template_bytes(template: PyTree) -> int:
    return sum(
        math.prod(pd.shape) * jnp.dtype(pd.dtype).itemsize
        for pd in jax.tree.leaves(template, is_leaf=_is_def)
    )


def stack_agent_axis(template: PyTree, n_agents: int) -> PyTree:
    """Prefix every ParamDef with a leading ``agent`` axis (CDSGD replicas)."""

    def leaf(pd: ParamDef) -> ParamDef:
        return ParamDef(
            shape=(n_agents,) + pd.shape,
            axes=("agent",) + pd.axes,
            init=pd.init,
            scale=pd.scale,
            dtype=pd.dtype,
        )

    return jax.tree.map(leaf, template, is_leaf=_is_def)
