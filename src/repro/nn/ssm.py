"""State-space / linear-recurrence layers: RWKV6 ("Finch") and a selective
SSM (Mamba-style) used by the Hymba hybrid heads.

Both are written as ``lax.scan`` over time with O(1) recurrent state —
training shapes scan the full sequence; decode carries the state across
steps, which is what makes these architectures eligible for the
``long_500k`` input shape (cost per new token independent of context).

TPU adaptation notes (see DESIGN.md): the RWKV6 WKV recurrence keeps a
per-head (head_size x head_size) state matrix; the chunked Pallas kernel in
:mod:`repro.kernels.rwkv_scan` processes the sequence in VMEM-resident
chunks with the same semantics (validated against :func:`wkv6_scan`).
Simplifications vs the reference implementation, recorded in DESIGN.md:
static token-shift interpolation weights (no inner LoRA on the mix
coefficients) and RMS output norm instead of per-head GroupNorm; the
data-dependent decay LoRA — the defining feature of RWKV-*6* — is kept.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.nn.layers import rmsnorm, rmsnorm_template
from repro.nn.param import ParamDef


# --------------------------------------------------------------------------
# RWKV6
# --------------------------------------------------------------------------


def rwkv6_template(d: int, d_ff: int, *, head_size: int = 64, decay_lora: int = 64,
                   dtype=jnp.float32) -> Dict[str, Any]:
    n_h = d // head_size
    tm = {
        # token-shift interpolation coefficients (static simplification)
        "mu_r": ParamDef((d,), (None,), init="zeros", dtype=dtype),
        "mu_k": ParamDef((d,), (None,), init="zeros", dtype=dtype),
        "mu_v": ParamDef((d,), (None,), init="zeros", dtype=dtype),
        "mu_w": ParamDef((d,), (None,), init="zeros", dtype=dtype),
        "mu_g": ParamDef((d,), (None,), init="zeros", dtype=dtype),
        "wr": ParamDef((d, d), ("fsdp", "tp"), init="scaled", dtype=dtype),
        "wk": ParamDef((d, d), ("fsdp", "tp"), init="scaled", dtype=dtype),
        "wv": ParamDef((d, d), ("fsdp", "tp"), init="scaled", dtype=dtype),
        "wg": ParamDef((d, d), ("fsdp", "tp"), init="scaled", dtype=dtype),
        "wo": ParamDef((d, d), ("tp", "fsdp"), init="scaled", dtype=dtype),
        # data-dependent decay: w_t = exp(-exp(w0 + tanh(x_w A) B))
        "w0": ParamDef((d,), (None,), init="zeros", dtype=dtype),
        "wA": ParamDef((d, decay_lora), ("fsdp", None), init="scaled", dtype=dtype),
        "wB": ParamDef((decay_lora, d), (None, "fsdp"), init="scaled", scale=0.1, dtype=dtype),
        "u": ParamDef((n_h, head_size), (None, None), init="zeros", dtype=dtype),  # bonus
        "ln_out": rmsnorm_template(d, dtype),
    }
    cm = {
        "mu_ck": ParamDef((d,), (None,), init="zeros", dtype=dtype),
        "mu_cr": ParamDef((d,), (None,), init="zeros", dtype=dtype),
        "wck": ParamDef((d, d_ff), ("fsdp", "tp"), init="scaled", dtype=dtype),
        "wcv": ParamDef((d_ff, d), ("tp", "fsdp"), init="scaled", dtype=dtype),
        "wcr": ParamDef((d, d), ("fsdp", None), init="scaled", dtype=dtype),
    }
    return {"time_mix": tm, "channel_mix": cm}


def _token_shift(x: jnp.ndarray, prev: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """x[t] -> x[t-1]; first position uses `prev` (or zeros)."""
    first = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None, :]
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _lerp(x, x_prev, mu):
    return x + (x_prev - x) * mu


def wkv6_scan(r, k, v, w, u, state0=None):
    """The WKV6 recurrence.

    r,k,v,w: (b, s, n_h, hs); u: (n_h, hs); state: (b, n_h, hs, hs)
      y_t   = r_t . (S_t + (u * k_t) v_t^T)
      S_t+1 = diag(w_t) S_t + k_t v_t^T
    Returns (y (b,s,n_h,hs), final state).
    """
    b, s, n_h, hs = r.shape
    f32 = jnp.float32
    r, k, v, w = (a.astype(f32) for a in (r, k, v, w))
    s0 = jnp.zeros((b, n_h, hs, hs), f32) if state0 is None else state0.astype(f32)

    def step(S, xs):
        rt, kt, vt, wt = xs                       # (b, n_h, hs)
        kv = kt[..., :, None] * vt[..., None, :]  # (b, n_h, hs, hs)
        y = jnp.einsum("bhi,bhij->bhj", rt, S + u[None, :, :, None] * kv)
        S_new = wt[..., :, None] * S + kv
        return S_new, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    S, ys = lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), S


def wkv6_chunked(r, k, v, w, u, state0=None, *, chunk: int = 32):
    """Chunked (matmul-form) WKV6 — numerically identical recurrence,
    O(S/C) scan steps instead of O(S), intra-chunk work on the MXU.

    Within a block with cumulative decays ``A_t = prod_{tau<=t} w_tau``:

        y_t   = (r_t * A_{t-1}) . S_0
              + sum_{tau<t} [ (r_t * A_{t-1}/A_tau) . k_tau ] v_tau
              + (r_t . (u * k_t)) v_t
        S_C   = diag(A_C) S_0 + sum_tau diag(A_C/A_tau) k_tau v_tau^T

    ``chunk`` sets the scan/state granularity (trip count = S/chunk); the
    intra-chunk pair term is evaluated on ``sub``-sized blocks because its
    factored ``1/A_tau`` terms are the only place exponent range matters —
    the state-update ratios ``A_C/A_tau`` are always <= 1 and stable at any
    chunk size.  With the mid-block shift, ``sub=16`` keeps the f32 exp
    range safe down to per-step decays of ~1e-5 (harsher than any practical
    RWKV decay).  This is the §Perf optimization for the rwkv6
    prefill/train memory term: the scan trip count drops ``chunk``x and the
    state stops round-tripping per token.
    """
    b, s, n_h, hs = r.shape
    chunk = min(chunk, s)
    if s % chunk:
        raise ValueError(f"seq {s} must be a multiple of chunk {chunk}")
    sub = min(16, chunk)
    while chunk % sub:
        sub -= 1
    n_chunks = s // chunk
    f32 = jnp.float32
    r, k, v, w = (a.astype(f32) for a in (r, k, v, w))
    u = u.astype(f32)
    s0 = jnp.zeros((b, n_h, hs, hs), f32) if state0 is None else state0.astype(f32)

    # (n_chunks, b, C, n_h, hs)
    def to_chunks(a):
        return jnp.moveaxis(a.reshape(b, n_chunks, chunk, n_h, hs), 1, 0)

    rc, kc, vc, wc = (to_chunks(a) for a in (r, k, v, w))
    mask = jnp.tril(jnp.ones((sub, sub), bool), -1)       # strict lower: tau < t

    def block(S, rb, kb, vb, wb):
        """One sub-block: (y, S') from the factored log-space form."""
        lw = jnp.log(jnp.maximum(wb, 1e-38))
        l_inc = jnp.cumsum(lw, axis=1)               # log A_t (inclusive)
        mid = l_inc[:, sub // 2 : sub // 2 + 1]      # per-(b,h,hs) shift
        a_inc = jnp.exp(l_inc - mid)
        a_exc = jnp.exp(l_inc - lw - mid)            # A_{t-1} (exclusive)
        r_dec = rb * a_exc                           # r_t * A_{t-1} * e^-mid
        k_dec = kb / a_inc                           # k_tau * e^mid / A_tau
        # inter-block: y_inter[t] = (r_t A_{t-1}) . S; undo the shift on S's
        # contracted dim (S_shift[i,j] = e^{mid_i} S[i,j])
        s_shift = jnp.exp(mid[:, 0])[..., None] * S  # (b, n_h, hs, hs)
        y_inter = jnp.einsum("bchi,bhij->bchj", r_dec, s_shift)
        # intra-block pair scores: shifts cancel in r_dec . k_dec
        p = jnp.einsum("bthi,bchi->bhtc", r_dec, k_dec)
        p = jnp.where(mask[None, None], p, 0.0)
        y_intra = jnp.einsum("bhtc,bchj->bthj", p, vb)
        # current-token bonus: (r_t . (u * k_t)) v_t
        y_diag = vb * jnp.sum(rb * u[None, None] * kb, -1, keepdims=True)
        y = y_inter + y_intra + y_diag
        # state update: S' = diag(A_C) S + sum_tau diag(A_C/A_tau) k_tau v_tau^T
        a_last_true = jnp.exp(l_inc[:, -1])          # (b, n_h, hs)
        k_scaled = kb * (a_inc[:, -1:] / a_inc)      # A_C/A_tau (shift cancels)
        s_new = a_last_true[..., None] * S + jnp.einsum("bchi,bchj->bhij", k_scaled, vb)
        return s_new, y

    def step(S, xs):
        rb, kb, vb, wb = xs                          # (b, C, n_h, hs)
        ys = []
        for i in range(chunk // sub):                # static unroll
            sl = slice(i * sub, (i + 1) * sub)
            S, y = block(S, rb[:, sl], kb[:, sl], vb[:, sl], wb[:, sl])
            ys.append(y)
        return S, ys[0] if len(ys) == 1 else jnp.concatenate(ys, axis=1)

    S, ys = lax.scan(step, s0, (rc, kc, vc, wc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, n_h, hs)
    return y, S


def rwkv6_time_mix(params, x, *, head_size: int = 64,
                   state: Optional[Dict[str, jnp.ndarray]] = None,
                   chunk: int = 32):
    # any chunk size is decay-safe: the intra-chunk pair term runs on
    # 16-wide sub-blocks (see wkv6_chunked), so chunk only trades scan trip
    # count against the (b, chunk, n_h, hs) activation term
    # (EXPERIMENTS.md §Perf C3).
    """Returns (y, new_state). state = {"shift": (b,d), "S": (b,n_h,hs,hs)}."""
    b, s, d = x.shape
    n_h = d // head_size
    prev = None if state is None else state["shift"]
    xp = _token_shift(x, prev)
    xr = _lerp(x, xp, params["mu_r"])
    xk = _lerp(x, xp, params["mu_k"])
    xv = _lerp(x, xp, params["mu_v"])
    xw = _lerp(x, xp, params["mu_w"])
    xg = _lerp(x, xp, params["mu_g"])

    r = jnp.einsum("bsd,de->bse", xr, params["wr"]).reshape(b, s, n_h, head_size)
    k = jnp.einsum("bsd,de->bse", xk, params["wk"]).reshape(b, s, n_h, head_size)
    v = jnp.einsum("bsd,de->bse", xv, params["wv"]).reshape(b, s, n_h, head_size)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, params["wg"]))

    dd = jnp.einsum("bsd,dr->bsr", jnp.tanh(jnp.einsum("bsd,dr->bsr", xw, params["wA"])), params["wB"])
    w = jnp.exp(-jnp.exp(params["w0"].astype(jnp.float32) + dd.astype(jnp.float32)))
    w = w.reshape(b, s, n_h, head_size)

    s0 = None if state is None else state["S"]
    # chunked matmul form for long sequences (see wkv6_chunked); per-step
    # scan for short/decode shapes where state carry across calls matters
    if s >= 64 and s % chunk == 0:
        y, S = wkv6_chunked(r, k, v, w, params["u"].astype(jnp.float32), s0, chunk=chunk)
    else:
        y, S = wkv6_scan(r, k, v, w, params["u"].astype(jnp.float32), s0)
    y = rmsnorm(params["ln_out"], y.reshape(b, s, d).astype(x.dtype)) * g
    out = jnp.einsum("bse,ed->bsd", y, params["wo"])
    new_state = {"shift": x[:, -1, :], "S": S}
    return out, new_state


def rwkv6_channel_mix(params, x, state: Optional[jnp.ndarray] = None):
    """state = (b, d) previous token. Returns (y, new_state)."""
    xp = _token_shift(x, state)
    xk = _lerp(x, xp, params["mu_ck"])
    xr = _lerp(x, xp, params["mu_cr"])
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, params["wck"])))
    kv = jnp.einsum("bsf,fd->bsd", k, params["wcv"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, params["wcr"]))
    return r * kv, x[:, -1, :]


def rwkv6_init_state(batch: int, d: int, *, head_size: int = 64, dtype=jnp.float32):
    n_h = d // head_size
    return {
        "tm": {"shift": jnp.zeros((batch, d), dtype),
               "S": jnp.zeros((batch, n_h, head_size, head_size), jnp.float32)},
        "cm": jnp.zeros((batch, d), dtype),
    }


# --------------------------------------------------------------------------
# Selective SSM (Mamba-style) for Hymba hybrid heads
# --------------------------------------------------------------------------


def mamba_template(d: int, *, d_inner: Optional[int] = None, n_state: int = 16,
                   dtype=jnp.float32) -> Dict[str, ParamDef]:
    di = d_inner or d
    return {
        "w_in": ParamDef((d, 2 * di), ("fsdp", "tp"), init="scaled", dtype=dtype),
        "w_dt": ParamDef((d, di), ("fsdp", "tp"), init="scaled", scale=0.1, dtype=dtype),
        "dt_bias": ParamDef((di,), ("tp",), init="zeros", dtype=dtype),
        "w_b": ParamDef((d, n_state), ("fsdp", None), init="scaled", dtype=dtype),
        "w_c": ParamDef((d, n_state), ("fsdp", None), init="scaled", dtype=dtype),
        "a_log": ParamDef((di, n_state), ("tp", None), init="zeros", dtype=dtype),
        "d_skip": ParamDef((di,), ("tp",), init="ones", dtype=dtype),
        "w_out": ParamDef((di, d), ("tp", "fsdp"), init="scaled", dtype=dtype),
    }


def mamba_scan(u, dt, b_in, c_in, a, state0=None):
    """h_t = exp(dt*A) h_{t-1} + dt * (B_t outer u_t); y_t = h_t . C_t.

    u,dt: (b, s, di); b_in,c_in: (b, s, n); a: (di, n).
    state: (b, di, n).  Returns (y (b,s,di), final state).
    """
    bsz, s, di = u.shape
    n = b_in.shape[-1]
    f32 = jnp.float32
    u, dt, b_in, c_in = (x.astype(f32) for x in (u, dt, b_in, c_in))
    h0 = jnp.zeros((bsz, di, n), f32) if state0 is None else state0.astype(f32)
    a = a.astype(f32)

    def step(h, xs):
        ut, dtt, bt, ct = xs                              # (b,di), (b,di), (b,n), (b,n)
        decay = jnp.exp(dtt[..., None] * a[None])          # (b, di, n); a <= 0
        h_new = decay * h + (dtt * ut)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h_new, ct)
        return h_new, y

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (u, dt, b_in, c_in))
    h, ys = lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h


def mamba_chunked(u, dt, b_in, c_in, a, state0=None, *, chunk: int = 32):
    """Chunked selective-SSM scan (prefix-sum form) — §Perf optimization.

    Within a chunk, with per-step decays ``a_t = exp(dt_t * A)`` and drives
    ``g_t = dt_t u_t (x) B_t``:  ``h_t = P_t * (h_0 + sum_{tau<=t} g_tau/P_tau)``
    where ``P_t = prod_{tau<=t} a_tau`` — a cumulative product + cumulative
    sum instead of an O(S) sequential scan.  Same overflow bound as
    :func:`wkv6_chunked` (ratios only; 1/P_tau bounds chunk size).
    """
    bsz, s, di = u.shape
    n = b_in.shape[-1]
    chunk = min(chunk, s)
    if s % chunk:
        raise ValueError(f"seq {s} must divide chunk {chunk}")
    n_chunks = s // chunk
    f32 = jnp.float32
    u, dt, b_in, c_in = (x.astype(f32) for x in (u, dt, b_in, c_in))
    a = a.astype(f32)
    h0 = jnp.zeros((bsz, di, n), f32) if state0 is None else state0.astype(f32)

    def to_chunks(x):
        return jnp.moveaxis(x.reshape(bsz, n_chunks, chunk, *x.shape[2:]), 1, 0)

    uc, dtc, bc, cc = (to_chunks(x) for x in (u, dt, b_in, c_in))

    def step(h, xs):
        ub, dtb, bb, cb = xs                                   # (b, C, ...)
        decay = jnp.exp(dtb[..., None] * a[None, None])        # (b, C, di, n)
        g = (dtb * ub)[..., None] * bb[:, :, None, :]          # (b, C, di, n)

        # stable intra-chunk composition (no divisions): the recurrence
        # h' = a h + g composes as (a2,g2)o(a1,g1) = (a1 a2, a2 g1 + g2)
        def combine(x, y):
            a1, g1 = x
            a2, g2 = y
            return a1 * a2, a2 * g1 + g2

        p_inc, z = lax.associative_scan(combine, (decay, g), axis=1)
        h_t = p_inc * h[:, None] + z                           # (b, C, di, n)
        y = jnp.einsum("bcdn,bcn->bcd", h_t, cb)
        return h_t[:, -1], y

    h, ys = lax.scan(step, h0, (uc, dtc, bc, cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, di)
    return y, h


def mamba_apply(params, x, state: Optional[jnp.ndarray] = None):
    """Returns (y (b,s,d), new_state (b,di,n))."""
    xz = jnp.einsum("bsd,de->bse", x, params["w_in"])
    u, z = jnp.split(xz, 2, axis=-1)
    u = jax.nn.silu(u)
    dt = jax.nn.softplus(jnp.einsum("bsd,de->bse", x, params["w_dt"]) + params["dt_bias"])
    b_in = jnp.einsum("bsd,dn->bsn", x, params["w_b"])
    c_in = jnp.einsum("bsd,dn->bsn", x, params["w_c"])
    a = -jnp.exp(params["a_log"].astype(jnp.float32))       # negative definite
    s = x.shape[1]
    if s >= 64 and s % 32 == 0:
        y, h = mamba_chunked(u, dt, b_in, c_in, a, state, chunk=32)
    else:
        y, h = mamba_scan(u, dt, b_in, c_in, a, state)
    y = (y.astype(x.dtype) + params["d_skip"] * u) * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", y, params["w_out"]), h


def mamba_init_state(batch: int, d_inner: int, n_state: int, dtype=jnp.float32):
    return jnp.zeros((batch, d_inner, n_state), jnp.float32)
