"""NN substrate: parameter templates, layers, attention, MoE, SSM, models."""

from repro.nn.param import (
    ParamDef,
    init_params,
    shape_structs,
    partition_specs,
    count_params,
    template_bytes,
    stack_agent_axis,
)
from repro.nn.transformer import (
    model_template,
    forward,
    loss_fn,
    init_cache,
    decode_step,
    encode_for_decode,
)

__all__ = [
    "ParamDef",
    "init_params",
    "shape_structs",
    "partition_specs",
    "count_params",
    "template_bytes",
    "stack_agent_axis",
    "model_template",
    "forward",
    "loss_fn",
    "init_cache",
    "decode_step",
    "encode_for_decode",
]
